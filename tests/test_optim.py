"""AdamW vs a hand-rolled numpy reference; schedules; clipping."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim.adamw import (AdamW, AdamWConfig, cosine_schedule,
                               constant_schedule, global_norm,
                               clip_by_global_norm)


def _np_adamw(params, grads, m, v, step, lr, b1, b2, eps, wd, decay_mask):
    out_p, out_m, out_v = {}, {}, {}
    for k in params:
        g = grads[k]
        out_m[k] = b1 * m[k] + (1 - b1) * g
        out_v[k] = b2 * v[k] + (1 - b2) * g ** 2
        mh = out_m[k] / (1 - b1 ** step)
        vh = out_v[k] / (1 - b2 ** step)
        delta = mh / (np.sqrt(vh) + eps)
        if decay_mask[k]:
            delta = delta + wd * params[k]
        out_p[k] = params[k] - lr * delta
    return out_p, out_m, out_v


def test_adamw_matches_reference(rng):
    params = {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(3,)), jnp.float32)}
    opt = AdamW(constant_schedule(1e-2),
                AdamWConfig(clip_norm=None, weight_decay=0.1))
    state = opt.init(params)
    np_p = {k: np.asarray(v) for k, v in params.items()}
    np_m = {k: np.zeros_like(v) for k, v in np_p.items()}
    np_v = {k: np.zeros_like(v) for k, v in np_p.items()}
    mask = {"w": True, "b": False}     # wd only on rank≥2
    for step in range(1, 6):
        grads = {k: jnp.asarray(rng.normal(size=v.shape), jnp.float32)
                 for k, v in params.items()}
        params, state, _ = opt.update(grads, state, params)
        np_g = {k: np.asarray(v) for k, v in grads.items()}
        np_p, np_m, np_v = _np_adamw(np_p, np_g, np_m, np_v, step,
                                     1e-2, 0.9, 0.95, 1e-8, 0.1, mask)
        for k in params:
            np.testing.assert_allclose(params[k], np_p[k], atol=1e-5,
                                       err_msg=f"step {step} {k}")


def test_clipping():
    tree = {"a": jnp.full((10,), 3.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(norm, np.sqrt(90.0), rtol=1e-6)
    np.testing.assert_allclose(global_norm(clipped), 1.0, rtol=1e-5)
    # under the limit: unchanged
    small = {"a": jnp.full((4,), 0.1)}
    out, _ = clip_by_global_norm(small, 10.0)
    np.testing.assert_allclose(out["a"], small["a"], rtol=1e-6)


def test_cosine_schedule():
    lr = cosine_schedule(1.0, warmup=10, total=110, final_frac=0.1)
    assert float(lr(0)) == 0.0
    np.testing.assert_allclose(float(lr(5)), 0.5, rtol=1e-6)
    np.testing.assert_allclose(float(lr(10)), 1.0, rtol=1e-6)
    np.testing.assert_allclose(float(lr(110)), 0.1, rtol=1e-5)
    assert float(lr(60)) < 1.0


def test_no_master_for_f32_params():
    """All-f32 params keep master=None so the state tree (and checkpoints)
    match pre-mixed-precision revisions exactly."""
    opt = AdamW(constant_schedule(1e-2))
    params = {"w": jnp.ones((4, 4), jnp.float32)}
    state = opt.init(params)
    assert state.master is None
    new_params, state2, _ = opt.update(
        {"w": jnp.full((4, 4), 1e-3, jnp.float32)}, state, params)
    assert state2.master is None
    assert new_params["w"].dtype == jnp.float32


def test_bf16_params_get_f32_master_and_track_f32_run():
    """bf16 storage: the optimizer steps from an f32 master, so the master
    trajectory equals an all-f32 run fed the same grads — and tiny updates
    are not swallowed by bf16 rounding."""
    opt = AdamW(constant_schedule(1e-3),
                AdamWConfig(weight_decay=0.0, clip_norm=None))
    w0 = jnp.full((8, 8), 1.0, jnp.float32)
    p16 = {"w": w0.astype(jnp.bfloat16)}
    p32 = {"w": w0}
    s16, s32 = opt.init(p16), opt.init(p32)
    assert s16.master is not None
    assert s16.master["w"].dtype == jnp.float32
    g = {"w": jnp.full((8, 8), 1e-4, jnp.float32)}
    for _ in range(10):
        p16, s16, _ = opt.update(g, s16, p16)
        p32, s32, _ = opt.update(g, s32, p32)
    # identical grads -> the master IS the f32 trajectory
    np.testing.assert_allclose(s16.master["w"], p32["w"], rtol=0, atol=1e-7)
    # the bf16 copy is the rounded master, and it did move
    np.testing.assert_allclose(np.asarray(p16["w"], np.float32),
                               np.asarray(s16.master["w"]).astype(
                                   np.float32), rtol=8e-3)
    assert not np.array_equal(np.asarray(p16["w"], np.float32), w0)


def test_loss_decreases_on_quadratic():
    """End-to-end sanity: AdamW minimizes a quadratic."""
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"x": jnp.zeros(3)}
    opt = AdamW(constant_schedule(0.1))
    state = opt.init(params)

    def loss(p):
        return jnp.sum((p["x"] - target) ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params)
    assert float(loss(params)) < 1e-2
