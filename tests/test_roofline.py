"""Roofline machinery: while-aware static HLO analysis (flops × trip count,
collective operand bytes, traffic model) + term arithmetic."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.roofline.hlo_static import analyze, HloStaticAnalysis
from repro.roofline.analysis import Roofline, model_flops_per_step, V5E
from repro.configs.base import get_config


def test_matmul_flops_exact():
    f = lambda a, b: a @ b
    hlo = jax.jit(f).lower(jnp.zeros((128, 256)),
                           jnp.zeros((256, 64))).compile().as_text()
    r = analyze(hlo)
    assert r["flops"] == 2 * 128 * 256 * 64


def test_scan_flops_times_trip_count():
    def body(x, w):
        return x @ w, ()

    def fs(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    hlo = jax.jit(fs).lower(jnp.zeros((8, 128)),
                            jnp.zeros((4, 128, 128))).compile().as_text()
    r = analyze(hlo)
    assert r["flops"] == 4 * 2 * 8 * 128 * 128
    # naive cost_analysis undercounts — the reason this module exists
    cost = jax.jit(fs).lower(jnp.zeros((8, 128)),
                             jnp.zeros((4, 128, 128))).compile() \
        .cost_analysis()
    if isinstance(cost, list):   # pinned JAX returns one dict per device
        cost = cost[0]
    assert cost["flops"] < r["flops"] / 2


def test_nested_scan():
    def body(x, w):
        return x @ w, ()

    def f2(x, ws):
        def outer(x, _):
            return jax.lax.scan(body, x, ws)[0], ()
        return jax.lax.scan(outer, x, jnp.arange(3))[0]

    hlo = jax.jit(f2).lower(jnp.zeros((8, 128)),
                            jnp.zeros((4, 128, 128))).compile().as_text()
    assert analyze(hlo)["flops"] == 3 * 4 * 2 * 8 * 128 * 128


def test_traffic_positive_and_bounded():
    f = lambda a, b: jax.nn.relu(a @ b)
    hlo = jax.jit(f).lower(jnp.zeros((64, 64)),
                           jnp.zeros((64, 64))).compile().as_text()
    r = analyze(hlo)
    # at least inputs+output once; at most a small multiple
    lo = 3 * 64 * 64 * 4
    assert lo <= r["traffic_bytes"] <= 10 * lo


def test_roofline_terms():
    rl = Roofline(flops=197e12, hbm_bytes=819e9, coll_bytes=50e9, chips=1,
                  model_flops=98.5e12)
    assert rl.t_compute == pytest.approx(1.0)
    assert rl.t_memory == pytest.approx(1.0)
    assert rl.t_collective == pytest.approx(1.0)
    assert rl.useful_flops_ratio == pytest.approx(0.5)
    assert rl.roofline_fraction == pytest.approx(0.5)
    rl2 = Roofline(flops=1e12, hbm_bytes=819e9 * 10, coll_bytes=0, chips=1)
    assert rl2.dominant == "memory"


def test_model_flops_moe_uses_active_params():
    dense = get_config("stablelm-1.6b")
    moe = get_config("mixtral-8x22b")
    f_dense = model_flops_per_step(dense, "train", 1, 1)
    f_moe = model_flops_per_step(moe, "train", 1, 1)
    from repro.roofline.analysis import active_params
    total_moe_params_lower_bound = \
        moe.n_experts * moe.n_layers * 3 * moe.d_model * moe.d_ff
    # active params must be well below total (top-2 of 8 experts)
    assert active_params(moe) < 0.5 * total_moe_params_lower_bound
    assert f_dense == pytest.approx(6 * active_params(dense))
    assert f_moe == pytest.approx(6 * active_params(moe))


def test_collective_bytes_from_sharded_module():
    import subprocess
    import sys
    import os
    src = r"""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.roofline.hlo_static import analyze
from repro.distributed.compat import make_mesh
mesh = make_mesh((4,), ("model",))
a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
b = jax.ShapeDtypeStruct((256, 128), jnp.float32)
f = jax.jit(lambda a, b: a @ b,
            in_shardings=(NamedSharding(mesh, P(None, "model")),
                          NamedSharding(mesh, P("model", None))),
            out_shardings=NamedSharding(mesh, P(None, None)))
r = analyze(f.lower(a, b).compile().as_text())
assert r["flops"] == 2 * 128 * 64 * 128, r["flops"]   # per-device share
assert r["collective_bytes"] == 128 * 128 * 4, r      # partial-sum AR operand
assert "all-reduce" in r["collectives_by_op"]
print("COLL_OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", src], env=env,
                         capture_output=True, text=True, timeout=300)
    assert "COLL_OK" in out.stdout, out.stderr[-1500:]
