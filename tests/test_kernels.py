"""Pallas kernels vs pure-jnp oracle: shape/dtype sweeps, fwd + grads.

Kernels run in interpret mode on CPU (the TPU target is validated
structurally: BlockSpecs, VMEM scratch, grid semantics)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.ops import selective_scan, conv1d_pack
from repro.kernels.ref import selective_scan_ref, conv1d_pack_ref


def _scan_inputs(rng, Bz, L, Dm, N, dtype):
    u = jnp.asarray(rng.normal(size=(Bz, L, Dm)), dtype)
    dt = jnp.asarray(rng.uniform(0.05, 0.5, (Bz, L, Dm)), dtype)
    A = -jnp.exp(jnp.asarray(rng.normal(size=(Dm, N)), jnp.float32))
    Bm = jnp.asarray(rng.normal(size=(Bz, L, N)), dtype)
    Cm = jnp.asarray(rng.normal(size=(Bz, L, N)), dtype)
    Dk = jnp.asarray(rng.normal(size=(Dm,)), jnp.float32)
    # packed positions: a few segments per row
    pos = np.zeros((Bz, L), np.int32)
    for b in range(Bz):
        cuts = sorted(rng.choice(np.arange(1, L), size=min(3, L - 1),
                                 replace=False)) if L > 2 else []
        prev = 0
        for c in list(cuts) + [L]:
            pos[b, prev:c] = np.arange(c - prev)
            prev = c
    return u, dt, A, Bm, Cm, Dk, jnp.asarray(pos)


SCAN_SHAPES = [(1, 8, 4, 2), (2, 24, 10, 4), (1, 64, 16, 16), (3, 17, 5, 3)]


@pytest.mark.parametrize("Bz,L,Dm,N", SCAN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_selective_scan_fwd(Bz, L, Dm, N, dtype):
    rng = np.random.default_rng(Bz * 100 + L)
    u, dt, A, Bm, Cm, Dk, pos = _scan_inputs(rng, Bz, L, Dm, N, dtype)
    y_ref = selective_scan_ref(u, dt, A, Bm, Cm, Dk, pos)
    y_pal = selective_scan(u, dt, A, Bm, Cm, Dk, pos, backend="pallas",
                           block_d=8, chunk=8)
    y_xla = selective_scan(u, dt, A, Bm, Cm, Dk, pos, backend="xla",
                           xla_chunk=8)
    atol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y_pal, np.float32),
                               np.asarray(y_ref, np.float32), atol=atol)
    np.testing.assert_allclose(np.asarray(y_xla, np.float32),
                               np.asarray(y_ref, np.float32), atol=atol)


@pytest.mark.parametrize("Bz,L,Dm,N", [(2, 24, 10, 4), (1, 16, 8, 16)])
def test_selective_scan_grads(Bz, L, Dm, N):
    rng = np.random.default_rng(5)
    u, dt, A, Bm, Cm, Dk, pos = _scan_inputs(rng, Bz, L, Dm, N, jnp.float32)

    def lp(*args):
        return (selective_scan(*args, pos, backend="pallas",
                               block_d=8, chunk=8) ** 2).sum()

    def lr(*args):
        return (selective_scan_ref(*args, pos) ** 2).sum()

    gp = jax.grad(lp, argnums=tuple(range(6)))(u, dt, A, Bm, Cm, Dk)
    gr = jax.grad(lr, argnums=tuple(range(6)))(u, dt, A, Bm, Cm, Dk)
    for name, a, b in zip("u dt A B C D".split(), gp, gr):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-3,
                                   err_msg=f"grad {name}")


def test_selective_scan_reset_blocks_grad():
    """The paper's backward claim on the actual kernel: no gradient crosses
    a packed-sequence boundary."""
    rng = np.random.default_rng(6)
    u, dt, A, Bm, Cm, Dk, _ = _scan_inputs(rng, 1, 16, 8, 4, jnp.float32)
    pos = jnp.concatenate([jnp.arange(8), jnp.arange(8)])[None]

    def loss(u_in):
        y = selective_scan(u_in, dt, A, Bm, Cm, Dk, pos, backend="pallas",
                           block_d=8, chunk=8)
        return (y[:, 8:] ** 2).sum()

    g = jax.grad(loss)(u)
    np.testing.assert_allclose(g[:, :8], 0.0, atol=1e-7)
    assert float(jnp.abs(g[:, 8:]).max()) > 0


CONV_SHAPES = [(1, 8, 4, 2), (2, 24, 10, 4), (1, 64, 16, 4), (3, 17, 5, 3)]


@pytest.mark.parametrize("Bz,L,Dm,W", CONV_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv1d_pack_fwd(Bz, L, Dm, W, dtype):
    rng = np.random.default_rng(Bz * 31 + L)
    x = jnp.asarray(rng.normal(size=(Bz, L, Dm)), dtype)
    w = jnp.asarray(rng.normal(size=(W, Dm)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(Dm,)), jnp.float32)
    pos = jnp.asarray(np.tile(
        np.concatenate([np.arange(L // 2), np.arange(L - L // 2)]),
        (Bz, 1)).astype(np.int32))
    y_ref = conv1d_pack_ref(x, w, b, pos)
    y_pal = conv1d_pack(x, w, b, pos, backend="pallas", block_d=8, chunk=8)
    y_xla = conv1d_pack(x, w, b, pos, backend="xla")
    atol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y_pal, np.float32),
                               np.asarray(y_ref, np.float32), atol=atol)
    np.testing.assert_allclose(np.asarray(y_xla, np.float32),
                               np.asarray(y_ref, np.float32), atol=atol)


def test_conv1d_pack_grads():
    rng = np.random.default_rng(8)
    Bz, L, Dm, W = 2, 24, 10, 4
    x = jnp.asarray(rng.normal(size=(Bz, L, Dm)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(W, Dm)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(Dm,)), jnp.float32)
    pos = jnp.asarray(np.tile(np.concatenate([np.arange(9), np.arange(15)]),
                              (Bz, 1)).astype(np.int32))

    def lp(x, w, b):
        return (conv1d_pack(x, w, b, pos, backend="pallas",
                            block_d=8, chunk=8) ** 2).sum()

    def lr(x, w, b):
        return (conv1d_pack_ref(x, w, b, pos) ** 2).sum()

    gp = jax.grad(lp, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(lr, argnums=(0, 1, 2))(x, w, b)
    for name, a, bb in zip("x w b".split(), gp, gr):
        np.testing.assert_allclose(a, bb, atol=1e-4, rtol=1e-3,
                                   err_msg=f"grad {name}")


def test_kernels_under_jit_and_vmapless_batching():
    rng = np.random.default_rng(9)
    u, dt, A, Bm, Cm, Dk, pos = _scan_inputs(rng, 2, 16, 8, 4, jnp.float32)
    f = jax.jit(lambda *a: selective_scan(*a, backend="pallas",
                                          block_d=8, chunk=8))
    y1 = f(u, dt, A, Bm, Cm, Dk, pos)
    y2 = selective_scan(u, dt, A, Bm, Cm, Dk, pos, backend="pallas",
                        block_d=8, chunk=8)
    np.testing.assert_allclose(y1, y2, atol=1e-6)
