"""Attention: masks, GQA, chunked online-softmax vs full, decode, M-RoPE."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.attention import (attention, decode_attention, rope, mrope,
                                  NEG_INF)


def _qkv(rng, B, L, H, Hkv, Dh):
    q = jnp.asarray(rng.normal(size=(B, L, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, L, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, L, Hkv, Dh)), jnp.float32)
    return q, k, v


def test_causal_mask(rng):
    q, k, v = _qkv(rng, 1, 8, 2, 2, 4)
    y = attention(q, k, v, causal=True)
    # perturbing the future must not change the past
    k2 = k.at[:, 5:].add(100.0)
    v2 = v.at[:, 5:].add(100.0)
    y2 = attention(q, k2, v2, causal=True)
    np.testing.assert_allclose(y[:, :5], y2[:, :5], atol=1e-5)
    assert float(jnp.abs(y[:, 5:] - y2[:, 5:]).max()) > 1e-3


def test_sliding_window(rng):
    q, k, v = _qkv(rng, 1, 16, 2, 2, 4)
    y = attention(q, k, v, causal=True, window=4)
    # token 12 must not see token ≤ 8
    k2 = k.at[:, :8].add(100.0)
    v2 = v.at[:, :8].add(100.0)
    y2 = attention(q, k2, v2, causal=True, window=4)
    np.testing.assert_allclose(y[:, 12:], y2[:, 12:], atol=1e-5)


def test_gqa_matches_repeated_mha(rng):
    B, L, H, Hkv, Dh = 2, 10, 8, 2, 4
    q, k, v = _qkv(rng, B, L, H, Hkv, Dh)
    y_gqa = attention(q, k, v, causal=True)
    k_rep = jnp.repeat(k, H // Hkv, axis=2)
    v_rep = jnp.repeat(v, H // Hkv, axis=2)
    y_mha = attention(q, k_rep, v_rep, causal=True)
    np.testing.assert_allclose(y_gqa, y_mha, atol=1e-5)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 6])
def test_chunked_equals_full(rng, causal, window):
    B, L = 2, 32
    q, k, v = _qkv(rng, B, L, 4, 2, 8)
    seg = jnp.asarray(np.tile(np.concatenate(
        [np.full(20, 1), np.full(10, 2), np.zeros(2)]), (B, 1)).astype(np.int32))
    y_full = attention(q, k, v, segment_ids_q=seg, segment_ids_kv=seg,
                       causal=causal, window=window)
    y_chun = attention(q, k, v, segment_ids_q=seg, segment_ids_kv=seg,
                       causal=causal, window=window, chunk_kv=8)
    np.testing.assert_allclose(y_full, y_chun, atol=1e-4)


def test_padding_rows_zero(rng):
    """Fully-masked (padding) queries return 0, not NaN."""
    q, k, v = _qkv(rng, 1, 8, 2, 2, 4)
    seg = jnp.zeros((1, 8), jnp.int32)      # everything is padding
    y = attention(q, k, v, segment_ids_q=seg, segment_ids_kv=seg, causal=True)
    assert not bool(jnp.isnan(y).any())
    np.testing.assert_allclose(y, 0.0, atol=1e-6)
    y2 = attention(q, k, v, segment_ids_q=seg, segment_ids_kv=seg,
                   causal=True, chunk_kv=4)
    assert not bool(jnp.isnan(y2).any())
    np.testing.assert_allclose(y2, 0.0, atol=1e-6)


def test_decode_attention_matches_full(rng):
    B, L, H, Hkv, Dh = 2, 12, 4, 2, 8
    q, k, v = _qkv(rng, B, L, H, Hkv, Dh)
    y_full = attention(q, k, v, causal=True)
    for t in [0, 5, 11]:
        y_t = decode_attention(q[:, t], k, v, jnp.full((B,), t))
        np.testing.assert_allclose(y_t, y_full[:, t], atol=1e-5)


def test_rope_is_relative(rng):
    """RoPE scores depend only on relative positions — shifting both q and k
    positions by a constant leaves attention unchanged."""
    B, L, H, Dh = 1, 6, 2, 8
    q, k, v = _qkv(rng, B, L, H, H, Dh)
    p0 = jnp.arange(L)[None]
    y0 = attention(rope(q, p0), rope(k, p0), v, causal=True)
    p1 = p0 + 37
    y1 = attention(rope(q, p1), rope(k, p1), v, causal=True)
    np.testing.assert_allclose(y0, y1, atol=1e-4)


def test_mrope_text_degenerates_to_rope(rng):
    """With all three position channels equal, M-RoPE == RoPE (text mode)."""
    B, L, H, Dh = 1, 6, 2, 16
    q = jnp.asarray(rng.normal(size=(B, L, H, Dh)), jnp.float32)
    pos = jnp.arange(L)[None]
    pos3 = jnp.repeat(pos[..., None], 3, axis=-1)
    a = rope(q, pos)
    b = mrope(q, pos3, sections=(2, 3, 3))
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_mrope_sections_validated(rng):
    q = jnp.zeros((1, 4, 2, 16))
    with pytest.raises(ValueError):
        mrope(q, jnp.zeros((1, 4, 3), jnp.int32), sections=(2, 2, 2))
