"""MoE: sort-based dispatch vs naive dense reference, capacity semantics,
shared experts, aux losses."""
import dataclasses
import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # tier-1 env has no hypothesis: fixed-seed fallback
    from _hypothesis_fallback import given, settings, st

from repro.configs.base import get_config
from repro.models import blocks as B


def _cfg(E=4, K=2, cf=4.0, shared=0):
    base = get_config("mixtral-8x22b").reduced()
    return dataclasses.replace(base, n_experts=E, top_k=K,
                               capacity_factor=cf,
                               n_shared_experts=shared)


def _naive(p, x, cfg):
    logits = (x @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gv, idx = jax.lax.top_k(probs, cfg.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    out = np.zeros(x.shape, np.float32)
    for t in range(x.shape[0]):
        for k in range(cfg.top_k):
            e = int(idx[t, k])
            g = jax.nn.silu(x[t] @ p["experts_gate"][e])
            u = x[t] @ p["experts_up"][e]
            out[t] += float(gv[t, k]) * 0 + np.asarray(
                gv[t, k] * ((g * u) @ p["experts_down"][e]))
    if "shared_gate" in p:
        g = jax.nn.silu(x @ p["shared_gate"])
        u = x @ p["shared_up"]
        out += np.asarray((g * u) @ p["shared_down"])
    return out


@pytest.mark.slow          # 10-example (T, E, K) grid, ~80s of recompiles
@given(st.integers(1, 24), st.integers(2, 6), st.integers(1, 2))
@settings(max_examples=10, deadline=None)
def test_sort_dispatch_matches_naive(T, E, K):
    cfg = _cfg(E=E, K=min(K, E), cf=8.0)
    rng = np.random.default_rng(T * 7 + E)
    p = B.init_moe(jax.random.PRNGKey(E), cfg)
    x = jnp.asarray(rng.normal(size=(T, cfg.d_model)), jnp.float32)
    y, aux = B._moe_ffn(p, x, cfg)
    ref = _naive(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), ref, atol=2e-4)
    assert np.isfinite(float(aux["lb_loss"]))
    assert np.isfinite(float(aux["z_loss"]))


def test_shared_experts():
    cfg = _cfg(shared=1)
    rng = np.random.default_rng(3)
    p = B.init_moe(jax.random.PRNGKey(1), cfg)
    assert "shared_gate" in p
    x = jnp.asarray(rng.normal(size=(8, cfg.d_model)), jnp.float32)
    y, _ = B._moe_ffn(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), _naive(p, x, cfg), atol=2e-4)


def test_capacity_drops_tokens():
    """With capacity_factor → tiny, overflow tokens contribute zero (the
    standard drop semantics), never NaN or crash."""
    cfg = _cfg(E=2, K=1, cf=0.01)
    rng = np.random.default_rng(4)
    p = B.init_moe(jax.random.PRNGKey(2), cfg)
    x = jnp.asarray(rng.normal(size=(64, cfg.d_model)), jnp.float32)
    y, _ = B._moe_ffn(p, x, cfg)
    assert not bool(jnp.isnan(y).any())
    # capacity is 8 (floor); ≤ 16 of 64 tokens can be served
    served = (jnp.abs(y).sum(-1) > 1e-9).sum()
    assert int(served) <= 16


def test_load_balance_loss_uniform_vs_skewed():
    cfg = _cfg(E=4, K=1, cf=8.0)
    # uniform routing → lb_loss ≈ 1; fully skewed → ≈ E
    T, E = 1024, 4
    probs_u = jnp.full((T, E), 0.25)
    me = probs_u.mean(0)
    idx = jnp.tile(jnp.arange(E), T // E)
    ce = jnp.zeros(E).at[idx].add(1.0) / T
    lb_uniform = E * jnp.sum(me * ce)
    np.testing.assert_allclose(float(lb_uniform), 1.0, rtol=1e-5)
    idx_skew = jnp.zeros(T, jnp.int32)
    ce_s = jnp.zeros(E).at[idx_skew].add(1.0) / T
    lb_skew = E * jnp.sum(me * ce_s)
    assert float(lb_skew) > float(lb_uniform) - 1e-6


def test_moe_grads_flow():
    cfg = _cfg()
    rng = np.random.default_rng(5)
    p = B.init_moe(jax.random.PRNGKey(3), cfg)
    x = jnp.asarray(rng.normal(size=(16, cfg.d_model)), jnp.float32)

    def loss(p):
        y, aux = B._moe_ffn(p, x, cfg)
        return (y ** 2).sum() + aux["lb_loss"]

    g = jax.grad(loss)(p)
    for name in ("router", "experts_gate", "experts_down"):
        assert float(jnp.abs(g[name]).max()) > 0, name
