"""Minimal fixed-seed stand-in for `hypothesis` when it isn't installed.

The tier-1 environment has no `hypothesis`; rather than skipping the
property-test modules entirely, this shim runs each ``@given`` test over a
deterministic set of examples drawn from the same strategy ranges
(fixed-seed ``random.Random`` per example index, so failures reproduce).
It implements exactly the strategy surface the test-suite uses:
``integers``, ``floats``, ``booleans``, ``sampled_from``, ``lists``.

No shrinking, no database, no `@example` — if a case fails here, rerun
under real hypothesis for minimization. Usage (in test modules):

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, st
"""
import random

DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda r: r.random() < 0.5)

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda r: r.choice(elements))

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        return _Strategy(lambda r: [elements.example(r)
                                    for _ in range(r.randint(min_size,
                                                             max_size))])


st = _Strategies()


def given(*strategies, **kw_strategies):
    def deco(fn):
        # NOTE: deliberately no functools.wraps — pytest must see a zero-arg
        # signature, not the strategy parameters (it would treat them as
        # fixtures). The @given tests in this suite take only strategy args.
        def run():
            n = getattr(run, "_max_examples",
                        getattr(fn, "_max_examples", DEFAULT_MAX_EXAMPLES))
            for i in range(n):
                rng = random.Random(0xC0FFEE + i)
                ex = [s.example(rng) for s in strategies]
                kex = {k: s.example(rng) for k, s in kw_strategies.items()}
                fn(*ex, **kex)
        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        run.__module__ = fn.__module__
        run.hypothesis_fallback = True
        return run
    return deco


def settings(max_examples=DEFAULT_MAX_EXAMPLES, **_ignored):
    def deco(fn):
        # keep runtimes reasonable without hypothesis' dedup machinery
        fn._max_examples = min(max_examples, DEFAULT_MAX_EXAMPLES)
        return fn
    return deco
