"""Segmented scan: schedule equivalence + the paper's §3.4 reset algebra."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # tier-1 env has no hypothesis: fixed-seed fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.scan import segmented_scan, scan_step, apply_reset


def _rand(rng, shape):
    return jnp.asarray(rng.uniform(-1, 1, shape), jnp.float32)


@given(st.integers(1, 3), st.integers(2, 40), st.integers(1, 5),
       st.integers(1, 8), st.floats(0.0, 0.5))
@settings(max_examples=25, deadline=None)
def test_schedules_agree(B, L, D, chunk, p_reset):
    rng = np.random.default_rng(42)
    a = jnp.asarray(rng.uniform(0.1, 1.0, (B, L, D)), jnp.float32)
    b = _rand(rng, (B, L, D))
    reset = jnp.asarray(rng.random((B, L)) < p_reset)
    outs = {}
    for m in ("sequential", "associative", "chunked"):
        kw = {"chunk": chunk} if m == "chunked" else {}
        h, hl = segmented_scan(a, b, reset, method=m, **kw)
        outs[m] = (h, hl)
    for m in ("associative", "chunked"):
        np.testing.assert_allclose(outs["sequential"][0], outs[m][0],
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(outs["sequential"][1], outs[m][1],
                                   atol=1e-5, rtol=1e-5)


@given(st.integers(2, 30), st.integers(1, 4))
@settings(max_examples=25, deadline=None)
def test_reset_blocks_information(L, D):
    """Paper §3.4: once a boundary's multiplicative term is zero, NOTHING
    before it can influence anything at or after it — under any schedule."""
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.uniform(0.5, 1.0, (1, L, D)), jnp.float32)
    b = _rand(rng, (1, L, D))
    cut = L // 2
    reset = jnp.zeros((1, L), bool).at[0, cut].set(True).at[0, 0].set(True)
    h1, _ = segmented_scan(a, b, reset, method="associative")
    # perturb everything before the cut
    b2 = b.at[:, :cut].add(_rand(rng, (1, cut, D)) * 100)
    a2 = a.at[:, :cut].multiply(0.123)
    h2, _ = segmented_scan(a2, b2, reset, method="associative")
    np.testing.assert_allclose(h1[:, cut:], h2[:, cut:], atol=1e-5)


def test_scan_matches_per_segment(rng):
    """Packed scan == independent scans of each segment."""
    lens = [5, 9, 3]
    L = sum(lens)
    D = 4
    a = jnp.asarray(rng.uniform(0.2, 1.0, (1, L, D)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(1, L, D)), jnp.float32)
    pos = jnp.asarray(np.concatenate([np.arange(n) for n in lens]))[None]
    h_packed, _ = segmented_scan(a, b, pos == 0, method="chunked", chunk=4)
    off = 0
    for n in lens:
        hs, _ = segmented_scan(a[:, off:off + n], b[:, off:off + n],
                               reset=None, method="sequential")
        np.testing.assert_allclose(h_packed[:, off:off + n], hs, atol=1e-5)
        off += n


def test_scan_step_matches_scan(rng):
    B, L, D = 2, 9, 3
    a = jnp.asarray(rng.uniform(0.2, 1.0, (B, L, D)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, L, D)), jnp.float32)
    reset = jnp.zeros((B, L), bool).at[:, 4].set(True)
    h_all, h_last = segmented_scan(a, b, reset, method="sequential")
    h = jnp.zeros((B, D))
    for t in range(L):
        h = scan_step(h, a[:, t], b[:, t], reset[:, t])
        np.testing.assert_allclose(h, h_all[:, t], atol=1e-6)
    np.testing.assert_allclose(h, h_last, atol=1e-6)


def test_h0_carry(rng):
    """split-pack state carry: scanning [x1; x2] == scan x2 with h0 from x1."""
    D = 3
    a = jnp.asarray(rng.uniform(0.2, 1.0, (1, 10, D)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(1, 10, D)), jnp.float32)
    h_all, h_last = segmented_scan(a, b, None, method="chunked", chunk=4)
    _, h5 = segmented_scan(a[:, :5], b[:, :5], None, method="sequential")
    h_rest, h_end = segmented_scan(a[:, 5:], b[:, 5:], None, h0=h5,
                                   method="chunked", chunk=2)
    np.testing.assert_allclose(h_rest, h_all[:, 5:], atol=1e-5)
    np.testing.assert_allclose(h_end, h_last, atol=1e-5)


def test_grad_does_not_cross_boundary(rng):
    """Backward PUI (paper §3.4): ∂loss(after cut)/∂input(before cut) = 0."""
    L, D = 12, 3
    a = jnp.asarray(rng.uniform(0.2, 1.0, (1, L, D)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(1, L, D)), jnp.float32)
    reset = jnp.zeros((1, L), bool).at[0, 6].set(True)

    def loss(b_in):
        h, _ = segmented_scan(a, b_in, reset, method="chunked", chunk=4)
        return (h[:, 6:] ** 2).sum()

    g = jax.grad(loss)(b)
    np.testing.assert_allclose(g[:, :6], 0.0, atol=1e-7)
    assert float(jnp.abs(g[:, 6:]).max()) > 0
