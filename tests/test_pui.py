"""Packing–Unpacking Invariance (paper §3.1–3.4): f(S) = unpack(f(pack(S)))
for every sequence-wise operator and for whole models.

These are the paper's central correctness claims, tested as properties over
random segment layouts.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # tier-1 env has no hypothesis: fixed-seed fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.attention import attention
from repro.core.recurrence import rglru, mlstm, slstm
from repro.core.ssm import selective_scan
from repro.core.conv import conv1d_pack
from repro.configs.base import get_config
from repro.models.lm import build_model


def _pack_rows(vals, lens, cap):
    """Pack per-seq (n, ...) arrays into rows of capacity cap sequentially."""
    rows, cur, used = [], [], 0
    for i, n in enumerate(lens):
        if used + n > cap:
            rows.append(cur)
            cur, used = [], 0
        cur.append(i)
        used += n
    rows.append(cur)
    R = len(rows)
    tail = vals[0].shape[1:]
    buf = np.zeros((R, cap) + tail, vals[0].dtype)
    pos = np.zeros((R, cap), np.int32)
    seg = np.zeros((R, cap), np.int32)
    locs = {}
    for r, row in enumerate(rows):
        off = 0
        for s, i in enumerate(row, 1):
            n = lens[i]
            buf[r, off:off + n] = vals[i]
            pos[r, off:off + n] = np.arange(n)
            seg[r, off:off + n] = s
            locs[i] = (r, off)
            off += n
    return jnp.asarray(buf), jnp.asarray(pos), jnp.asarray(seg), locs


lens_strategy = st.lists(st.integers(1, 20), min_size=1, max_size=6)


@given(lens_strategy)
@settings(max_examples=15, deadline=None)
def test_pui_conv(lens):
    rng = np.random.default_rng(sum(lens))
    D, W = 6, 4
    vals = [rng.normal(size=(n, D)).astype(np.float32) for n in lens]
    w = jnp.asarray(rng.normal(size=(W, D)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(D,)), jnp.float32)
    buf, pos, seg, locs = _pack_rows(vals, lens, 32)
    y = conv1d_pack(buf, w, b, pos)
    for i, v in enumerate(vals):
        r, off = locs[i]
        ref = conv1d_pack(jnp.asarray(v)[None], w, b,
                          jnp.arange(len(v))[None])[0]
        np.testing.assert_allclose(y[r, off:off + len(v)], ref, atol=1e-5)


@given(lens_strategy)
@settings(max_examples=15, deadline=None)
def test_pui_selective_scan(lens):
    rng = np.random.default_rng(sum(lens) + 1)
    D, N = 6, 4
    u = [rng.normal(size=(n, D)).astype(np.float32) for n in lens]
    dt = [rng.uniform(0.05, 0.5, (n, D)).astype(np.float32) for n in lens]
    Bm = [rng.normal(size=(n, N)).astype(np.float32) for n in lens]
    Cm = [rng.normal(size=(n, N)).astype(np.float32) for n in lens]
    A = -jnp.exp(jnp.asarray(rng.normal(size=(D, N)), jnp.float32))
    Dk = jnp.asarray(rng.normal(size=(D,)), jnp.float32)
    bu, pos, seg, locs = _pack_rows(u, lens, 32)
    bdt = _pack_rows(dt, lens, 32)[0]
    bB = _pack_rows(Bm, lens, 32)[0]
    bC = _pack_rows(Cm, lens, 32)[0]
    y = selective_scan(bu, bdt, A, bB, bC, Dk, positions=pos,
                       method="chunked", chunk=8)
    for i in range(len(lens)):
        r, off = locs[i]
        n = lens[i]
        ref = selective_scan(jnp.asarray(u[i])[None],
                             jnp.asarray(dt[i])[None], A,
                             jnp.asarray(Bm[i])[None],
                             jnp.asarray(Cm[i])[None], Dk,
                             positions=jnp.arange(n)[None],
                             method="sequential")[0]
        np.testing.assert_allclose(y[r, off:off + n], ref, atol=1e-4)


@given(lens_strategy, st.booleans(), st.sampled_from([None, 4]))
@settings(max_examples=15, deadline=None)
def test_pui_attention(lens, causal, window):
    rng = np.random.default_rng(sum(lens) + 2)
    H, Hkv, Dh = 4, 2, 8
    qs = [rng.normal(size=(n, H, Dh)).astype(np.float32) for n in lens]
    ks = [rng.normal(size=(n, Hkv, Dh)).astype(np.float32) for n in lens]
    vs = [rng.normal(size=(n, Hkv, Dh)).astype(np.float32) for n in lens]
    bq, pos, seg, locs = _pack_rows(qs, lens, 32)
    bk = _pack_rows(ks, lens, 32)[0]
    bv = _pack_rows(vs, lens, 32)[0]
    y = attention(bq, bk, bv, segment_ids_q=seg, segment_ids_kv=seg,
                  causal=causal, window=window)
    for i in range(len(lens)):
        r, off = locs[i]
        n = lens[i]
        ref = attention(jnp.asarray(qs[i])[None], jnp.asarray(ks[i])[None],
                        jnp.asarray(vs[i])[None], causal=causal,
                        window=window)[0]
        np.testing.assert_allclose(y[r, off:off + n], ref, atol=1e-5)


@given(lens_strategy)
@settings(max_examples=10, deadline=None)
def test_pui_rglru(lens):
    rng = np.random.default_rng(sum(lens) + 3)
    D = 6
    xs = [rng.normal(size=(n, D)).astype(np.float32) for n in lens]
    rs = [(1 / (1 + np.exp(-rng.normal(size=(n, D))))).astype(np.float32)
          for n in lens]
    is_ = [(1 / (1 + np.exp(-rng.normal(size=(n, D))))).astype(np.float32)
           for n in lens]
    ap = jnp.asarray(rng.normal(size=(D,)), jnp.float32)
    bx, pos, seg, locs = _pack_rows(xs, lens, 32)
    br = _pack_rows(rs, lens, 32)[0]
    bi = _pack_rows(is_, lens, 32)[0]
    y, _ = rglru(bx, br, bi, ap, pos, method="chunked", chunk=8)
    for i in range(len(lens)):
        r, off = locs[i]
        n = lens[i]
        ref, _ = rglru(jnp.asarray(xs[i])[None], jnp.asarray(rs[i])[None],
                       jnp.asarray(is_[i])[None], ap,
                       jnp.arange(n)[None], method="sequential")
        np.testing.assert_allclose(y[r, off:off + n], ref[0], atol=1e-5)


@given(st.lists(st.integers(2, 14), min_size=1, max_size=4))
@settings(max_examples=10, deadline=None)
def test_pui_mlstm(lens):
    rng = np.random.default_rng(sum(lens) + 4)
    H, dk = 2, 4
    qs = [rng.normal(size=(n, H, dk)).astype(np.float32) for n in lens]
    ks = [rng.normal(size=(n, H, dk)).astype(np.float32) for n in lens]
    vs = [rng.normal(size=(n, H, dk)).astype(np.float32) for n in lens]
    fs = [rng.normal(size=(n, H)).astype(np.float32) for n in lens]
    is_ = [rng.normal(size=(n, H)).astype(np.float32) for n in lens]
    bq, pos, seg, locs = _pack_rows(qs, lens, 24)
    bk = _pack_rows(ks, lens, 24)[0]
    bv = _pack_rows(vs, lens, 24)[0]
    bf = _pack_rows(fs, lens, 24)[0]
    bi = _pack_rows(is_, lens, 24)[0]
    y = mlstm(bq, bk, bv, bf, bi, positions=pos, chunk=8)
    for i in range(len(lens)):
        r, off = locs[i]
        n = lens[i]
        ref = mlstm(jnp.asarray(qs[i])[None], jnp.asarray(ks[i])[None],
                    jnp.asarray(vs[i])[None], jnp.asarray(fs[i])[None],
                    jnp.asarray(is_[i])[None],
                    positions=jnp.arange(n)[None], chunk=8)
        # 5e-4: the m-stabilized f32 accumulator renormalizes at different
        # steps for packed vs per-sequence layouts; worst observed ~3e-4
        np.testing.assert_allclose(y[r, off:off + n], ref[0], atol=5e-4)


@pytest.mark.parametrize("arch", ["mamba-110m", "recurrentgemma-2b",
                                  "xlstm-125m", "stablelm-1.6b",
                                  "mixtral-8x22b"])
def test_pui_whole_model_logits(arch):
    """unpack(model(pack(S))) == [model(s) for s in S] at the logit level."""
    rng = np.random.default_rng(11)
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lens = [7, 12, 5]
    toks = [rng.integers(1, cfg.vocab, size=(n,)).astype(np.int32)
            for n in lens]
    buf, pos, seg, locs = _pack_rows([t[:, None] for t in toks], lens, 32)
    batch = {"tokens": buf[..., 0], "positions": pos, "segment_ids": seg}
    logits = model.forward(params, batch)
    for i, t in enumerate(toks):
        r, off = locs[i]
        n = lens[i]
        sb = {"tokens": jnp.asarray(t)[None],
              "positions": jnp.arange(n)[None],
              "segment_ids": jnp.ones((1, n), jnp.int32)}
        ref = model.forward(params, sb)[0]
        np.testing.assert_allclose(logits[r, off:off + n], ref,
                                   atol=5e-3, rtol=1e-3,
                                   err_msg=f"{arch} seq {i}")


def test_pui_loss_equals_concat_loss():
    """Packed CE == CE over individually processed sequences (same token
    set, same mask) — the training-level PUI consequence."""
    rng = np.random.default_rng(13)
    cfg = get_config("mamba-110m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lens = [6, 9, 4]
    toks = [rng.integers(1, cfg.vocab, size=(n,)).astype(np.int32)
            for n in lens]
    buf, pos, seg, locs = _pack_rows([t[:, None] for t in toks], lens, 32)
    batch = {"tokens": buf[..., 0], "positions": pos, "segment_ids": seg}
    loss_packed, m = model.loss(params, batch)
    tot, cnt = 0.0, 0.0
    for t in toks:
        n = len(t)
        sb = {"tokens": jnp.asarray(t)[None],
              "positions": jnp.arange(n)[None],
              "segment_ids": jnp.ones((1, n), jnp.int32)}
        li, mi = model.loss(params, sb)
        tot += float(li) * float(mi["tokens"])
        cnt += float(mi["tokens"])
    np.testing.assert_allclose(float(loss_packed), tot / cnt, rtol=2e-4)
