"""bf16 mixed-precision lane (ISSUE 8): activations/params may be bf16 but
the numerically sensitive paths stay f32 — scan/rglru/mLSTM recurrence
carries, the logit/loss reduction, and the optimizer's master weights.

Covers: carry dtypes at the public entry points under bf16 inputs; a jaxpr
walk proving every lax.scan float carry in the bf16 model forward is f32;
bf16-vs-f32 loss/grad-norm trajectory parity over 20+ train steps on both
SSM variants; and low-precision parameter storage with f32 masters.
"""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import recurrence as rec
from repro.core import ssm as core_ssm
from repro.data.dataset import SyntheticCorpus, CorpusConfig
from repro.data.packing_loader import PackingLoader, LoaderConfig
from repro.models.lm import build_model
from repro.optim.adamw import AdamW, constant_schedule, cosine_schedule
from repro.train.trainer import Trainer, TrainerConfig


def _tiny(**kw):
    cfg = get_config("mamba-110m").reduced()
    return dataclasses.replace(cfg, vocab=128, n_layers=2, d_model=32, **kw)


def _loader(rows=4, seq=64):
    corpus = SyntheticCorpus(CorpusConfig(vocab=128, seed=0, len_min=5,
                                          len_max=40, mu=3.0, sigma=0.5))
    return PackingLoader(corpus, LoaderConfig(rows=rows, seq_len=seq,
                                              mode="pack"))


# ---------------------------------------------------------------------------
# carries are f32 even when activations are bf16
# ---------------------------------------------------------------------------

def test_scan_heads_bf16_in_f32_carry_out():
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.normal(size=(2, 32, 3, 8)), jnp.bfloat16)
    dt = jnp.asarray(rng.uniform(0.1, 0.4, (2, 32, 3)), jnp.bfloat16)
    Bm = jnp.asarray(rng.normal(size=(2, 32, 4)), jnp.bfloat16)
    A = -jnp.exp(jnp.asarray(rng.normal(size=(3,)), jnp.float32))
    y, h_last = core_ssm.selective_scan_heads(
        u, dt, A, Bm, Bm, None, method="blocked", chunk=16,
        return_state=True)
    assert y.dtype == jnp.bfloat16          # activations round-trip bf16
    assert h_last.dtype == jnp.float32      # the carry never drops to bf16


def test_rglru_and_mlstm_bf16_in_f32_state_out():
    rng = np.random.default_rng(1)
    bf = lambda *s: jnp.asarray(rng.normal(size=s), jnp.bfloat16)
    x, r, i = bf(2, 32, 8), bf(2, 32, 8), bf(2, 32, 8)
    h, h_last = rec.rglru(x, jax.nn.sigmoid(r), jax.nn.sigmoid(i),
                          jnp.ones((8,), jnp.float32))
    assert h.dtype == jnp.bfloat16 and h_last.dtype == jnp.float32
    q, k, v = bf(2, 32, 2, 8), bf(2, 32, 2, 8), bf(2, 32, 2, 8)
    gates = bf(2, 32, 2)
    out, (C, n, m) = rec.mlstm(q, k, v, gates, gates, chunk=16,
                               return_state=True)
    assert out.dtype == jnp.bfloat16
    assert C.dtype == n.dtype == m.dtype == jnp.float32


def _iter_jaxprs(jaxpr):
    yield jaxpr
    for eqn in jaxpr.eqns:
        for val in eqn.params.values():
            for sub in _sub_jaxprs(val):
                yield from _iter_jaxprs(sub)


def _sub_jaxprs(val):
    if hasattr(val, "jaxpr"):            # ClosedJaxpr
        yield val.jaxpr
    elif hasattr(val, "eqns"):           # raw Jaxpr
        yield val
    elif isinstance(val, (tuple, list)):
        for v in val:
            yield from _sub_jaxprs(v)


def _assert_scan_carries_f32(jaxpr):
    n_scans = 0
    for jp in _iter_jaxprs(jaxpr.jaxpr):
        for eqn in jp.eqns:
            if eqn.primitive.name != "scan":
                continue
            n_scans += 1
            carries = eqn.params["jaxpr"].in_avals[:eqn.params["num_carry"]]
            for aval in carries:
                if jnp.issubdtype(aval.dtype, jnp.floating):
                    assert aval.dtype == jnp.float32, \
                        f"bf16 scan carry leaked into the trace: {aval}"
    assert n_scans > 0                   # the walk actually saw the scans


@pytest.mark.parametrize("variant", ["mamba1", "mamba2"])
def test_bf16_recurrence_jaxpr_scan_carries_are_f32(variant):
    """Structural proof: with bf16 inputs, every floating lax.scan carry in
    the recurrence entry points is f32 — the blanket-cast failure mode
    (state degraded to bf16) cannot trace. (The model's layer-stack scan
    legitimately carries bf16 *activations*; the recurrence state is the
    sensitive path.)"""
    rng = np.random.default_rng(0)
    bf = lambda *s: jnp.asarray(rng.normal(size=s), jnp.bfloat16)
    if variant == "mamba1":
        u, dt = bf(2, 64, 6), jnp.asarray(
            rng.uniform(0.1, 0.4, (2, 64, 6)), jnp.bfloat16)
        A = -jnp.exp(jnp.asarray(rng.normal(size=(6, 4)), jnp.float32))
        Bm = bf(2, 64, 4)
        fn = lambda u, dt, Bm: core_ssm.selective_scan(
            u, dt, A, Bm, Bm, method="chunked", chunk=16)
        jaxpr = jax.make_jaxpr(fn)(u, dt, Bm)
    else:
        u = bf(2, 64, 3, 8)
        dt = jnp.asarray(rng.uniform(0.1, 0.4, (2, 64, 3)), jnp.bfloat16)
        A = -jnp.exp(jnp.asarray(rng.normal(size=(3,)), jnp.float32))
        Bm = bf(2, 64, 4)
        fn = lambda u, dt, Bm: core_ssm.selective_scan_heads(
            u, dt, A, Bm, Bm, None, method="blocked", chunk=16)
        jaxpr = jax.make_jaxpr(fn)(u, dt, Bm)
    _assert_scan_carries_f32(jaxpr)


def test_bf16_rglru_jaxpr_scan_carries_are_f32():
    rng = np.random.default_rng(2)
    bf = lambda *s: jnp.asarray(rng.normal(size=s), jnp.bfloat16)
    x, r, i = bf(2, 64, 8), bf(2, 64, 8), bf(2, 64, 8)
    fn = lambda x, r, i: rec.rglru(x, jax.nn.sigmoid(r), jax.nn.sigmoid(i),
                                   jnp.ones((8,), jnp.float32), chunk=16)
    _assert_scan_carries_f32(jax.make_jaxpr(fn)(x, r, i))


def test_bf16_logits_and_loss_are_f32():
    cfg = _tiny(dtype="bfloat16")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _loader().batch(0)
    loss, metrics = model.loss(params, batch)
    assert loss.dtype == jnp.float32


# ---------------------------------------------------------------------------
# trajectory parity: bf16 lane trains like f32 within tolerance
# ---------------------------------------------------------------------------

def _train_hist(cfg, steps=22):
    model = build_model(cfg)
    opt = AdamW(cosine_schedule(3e-3, warmup=5, total=steps))
    tr = Trainer(model, opt, _loader(), TrainerConfig(steps=steps,
                                                      log_every=1000))
    _, hist = tr.train(jax.random.PRNGKey(0), verbose=False)
    return hist


@pytest.mark.slow
@pytest.mark.parametrize("variant", ["mamba1", "mamba2"])
def test_bf16_vs_f32_training_parity(variant):
    """Loss/grad-norm trajectories of the bf16 lane track f32 over 20+
    steps — the carry-aware cast (not a blanket one) keeps optimization
    dynamics intact at tiny scale."""
    kw = {} if variant == "mamba1" else {"ssm_variant": "mamba2",
                                         "ssm_head_dim": 16}
    h32 = _train_hist(_tiny(dtype="float32", **kw))
    h16 = _train_hist(_tiny(dtype="bfloat16", **kw))
    l32 = np.array([h["loss"] for h in h32])
    l16 = np.array([h["loss"] for h in h16])
    assert np.isfinite(l16).all()
    # same optimization trajectory, bf16 rounding noise allowed
    assert np.abs(l16 - l32).max() < 0.35
    assert abs(l16[-5:].mean() - l32[-5:].mean()) < 0.2
    # both actually train
    assert l16[-5:].mean() < l16[:5].mean() - 0.2
    g32 = np.array([h["grad_norm"] for h in h32])
    g16 = np.array([h["grad_norm"] for h in h16])
    assert np.abs(g16 - g32).max() < 0.5 + 0.25 * g32.max()


@pytest.mark.slow
def test_bf16_param_storage_trains_with_masters():
    """param_dtype=bf16: parameters are stored bf16 (masters live in the
    optimizer) and the loss still goes down."""
    cfg = _tiny(dtype="bfloat16", param_dtype="bfloat16")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    float_leaves = [x for x in jax.tree.leaves(params)
                    if jnp.issubdtype(x.dtype, jnp.floating)]
    assert float_leaves and all(x.dtype == jnp.bfloat16
                                for x in float_leaves)
    hist = _train_hist(cfg)
    loss = np.array([h["loss"] for h in hist])
    assert np.isfinite(loss).all()
    assert loss[-5:].mean() < loss[:5].mean() - 0.2
