"""Sharding rules + mini distributed dry-runs.

Rules are tested in-process against fake meshes (no devices needed);
actual sharded lower/compile/run happens in a subprocess with
--xla_force_host_platform_device_count=8 so the main pytest process keeps
its single CPU device (per the dry-run isolation requirement).
"""
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.distributed import sharding as shd
from repro.models.lm import build_model


class FakeMesh:
    """Duck-typed mesh: only .shape (dict) is used by the rules."""
    def __init__(self, **axes):
        self.shape = axes


def test_param_rules_divisibility_guard():
    mesh = FakeMesh(data=16, model=16)
    # divisible: sharded
    assert shd._param_rule("w_gate", (2048, 5632), mesh) == P("data", "model")
    # non-divisible dim: that axis dropped
    assert shd._param_rule("w_gate", (2048, 5630), mesh) == P("data", None)
    assert shd._param_rule("embed", (50280, 64), mesh) == P(None, "data")
    # 1-device mesh: everything falls back to replication
    one = FakeMesh(data=1, model=1)
    spec = shd._param_rule("w_gate", (8, 8), one)
    assert spec == P("data", "model")      # axis size 1 divides everything


def test_moe_expert_rules():
    mesh = FakeMesh(data=16, model=16)
    # 64 experts: EP over model
    assert shd._param_rule("experts_gate", (64, 2048, 1408), mesh) == \
        P("model", "data", None)
    # 8 experts < 16: TP inside expert
    assert shd._param_rule("experts_gate", (8, 6144, 16384), mesh) == \
        P(None, "data", "model")
    assert shd._param_rule("experts_down", (8, 16384, 6144), mesh) == \
        P(None, "model", "data")


def test_param_pspecs_tree_matches_params():
    mesh = FakeMesh(data=4, model=2)
    cfg = get_config("recurrentgemma-2b").reduced()
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = shd.param_pspecs(shapes, mesh)
    flat_shapes = jax.tree_util.tree_flatten_with_path(shapes)[0]
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_shapes) == len(flat_specs)
    for (path, leaf), spec in zip(flat_shapes, flat_specs):
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
        # every sharded dim must divide
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if ax is not None:
                size = {"data": 4, "model": 2}[ax if isinstance(ax, str)
                                               else ax[0]]
                assert dim % size == 0, (path, spec, leaf.shape)


def test_batch_axis_fallbacks():
    mesh = FakeMesh(pod=2, data=16, model=16)
    assert shd.batch_axis(mesh, 256) == ("pod", "data")
    assert shd.batch_axis(mesh, 16) == "data"
    assert shd.batch_axis(mesh, 1) is None
    single = FakeMesh(data=16, model=16)
    assert shd.batch_axis(single, 256) == "data"


MINI_DRYRUN = r"""
import jax, dataclasses
from repro.configs.base import get_config
from repro.launch.shapes import build_cell, SHAPES
from repro.distributed.compat import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
SHAPES["train_4k"] = dict(kind="train", seq=128, batch=8)
SHAPES["decode_32k"] = dict(kind="decode", seq=128, batch=8)
for arch in ARCHS:
    cfg = get_config(arch).reduced()
    for shape in ("train_4k", "decode_32k"):
        from repro.launch.shapes import cell_supported
        ok, _ = cell_supported(cfg, shape)
        if not ok:
            continue
        cell = build_cell(cfg, mesh, shape)
        with mesh:
            c = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                        out_shardings=cell.out_shardings).lower(
                *cell.args).compile()
        cost = c.cost_analysis()
        if isinstance(cost, list):   # pinned JAX: one dict per device
            cost = cost[0] if cost else {}
        assert cost.get("flops", 0) > 0
        print("OK", arch, shape)
print("ALL_OK")
"""


@pytest.mark.parametrize("archs", [["stablelm-1.6b", "mamba-110m"],
                                   ["mixtral-8x22b", "recurrentgemma-2b"]])
def test_sharded_compile_8dev(archs):
    src = f"ARCHS = {archs!r}\n" + MINI_DRYRUN
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", src], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "ALL_OK" in out.stdout, out.stderr[-2000:]


def test_sharded_train_step_numerics_8dev():
    """Sharded train step == single-device train step (same batch/params)."""
    src = r"""
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs.base import get_config
from repro.models.lm import build_model
from repro.optim.adamw import AdamW, constant_schedule
from repro.train.trainer import make_train_step
from repro.distributed import sharding as shd
from repro.distributed.compat import make_mesh
from jax.sharding import NamedSharding, PartitionSpec as P

cfg = dataclasses.replace(get_config("mamba-110m").reduced(), dtype="float32")
model = build_model(cfg)
opt = AdamW(constant_schedule(1e-3))
step = make_train_step(model, opt)
rng = np.random.default_rng(0)
B, L = 8, 32
batch = {"tokens": jnp.asarray(rng.integers(1, cfg.vocab, (B, L)), jnp.int32),
         "positions": jnp.tile(jnp.arange(L)[None], (B, 1)),
         "segment_ids": jnp.ones((B, L), jnp.int32)}
params = model.init(jax.random.PRNGKey(0))
state = {"params": params, "opt": opt.init(params)}
ref_state, ref_metrics = jax.jit(step)(state, batch)

mesh = make_mesh((4, 2), ("data", "model"))
pspec = shd.param_pspecs(jax.eval_shape(model.init, jax.random.PRNGKey(0)),
                         mesh)
ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                            is_leaf=lambda x: isinstance(x, P))
state_spec = {"params": pspec, "opt": type(state["opt"])(
    step=P(), m=pspec, v=pspec)}
bspec = shd.batch_pspecs(batch, mesh)
with mesh:
    sh_state = jax.device_put(state, ns(state_spec))
    sh_batch = jax.device_put(batch, ns(bspec))
    out_state, metrics = jax.jit(step)(sh_state, sh_batch)
np.testing.assert_allclose(float(metrics["loss"]), float(ref_metrics["loss"]),
                           rtol=1e-5)
for a, b in zip(jax.tree.leaves(out_state["params"]),
                jax.tree.leaves(ref_state["params"])):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-5, rtol=1e-4)
print("NUMERIC_OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", src], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "NUMERIC_OK" in out.stdout, out.stderr[-2000:]
