"""Head-structured (Mamba-2 / SSD) selective scan: parity, resets, decode.

Acceptance surface of the single-matmul blocked path:
  * ``selective_scan_heads(method='blocked')`` fwd + grads vs the sequential
    per-head reference, random packed resets, chunk not dividing L, h0 carry
  * the Pallas ``schedule='blocked_heads'`` kernels (interpret mode)
    fwd + grads vs the same reference
  * packed-reset boundary rule: gradients never cross a pos==0 boundary
  * Mamba-1 degenerate dispatch: ``selective_scan`` ≡ heads with dh = 1
  * mamba2 block: single-token ``step_`` decode == full-sequence apply
  * structural memory claim: no (B, L, H, dh, N) trajectory in the jaxpr
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import ssm as core_ssm
from repro.kernels.ops import selective_scan_heads as kops_heads


def _packed_pos(rng, Bz, L, max_cuts=3):
    """Random packed position ids; cuts straddle power-of-two chunks."""
    pos = np.zeros((Bz, L), np.int32)
    for b in range(Bz):
        cuts = sorted(rng.choice(np.arange(1, L),
                                 size=min(max_cuts, L - 1),
                                 replace=False)) if L > 2 else []
        prev = 0
        for c in list(cuts) + [L]:
            pos[b, prev:c] = np.arange(c - prev)
            prev = c
    return jnp.asarray(pos)


def _heads_inputs(rng, Bz, L, H, P, N):
    u = jnp.asarray(rng.normal(size=(Bz, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.5, (Bz, L, H)), jnp.float32)
    A = -jnp.exp(jnp.asarray(rng.normal(size=(H,)), jnp.float32))
    Bm = jnp.asarray(rng.normal(size=(Bz, L, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(Bz, L, N)), jnp.float32)
    Dk = jnp.asarray(rng.normal(size=(H,)), jnp.float32)
    return u, dt, A, Bm, Cm, Dk, _packed_pos(rng, Bz, L)


# ---------------------------------------------------------------------------
# XLA blocked heads path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Bz,L,H,P,N,T", [(2, 24, 3, 4, 5, 8),
                                          (1, 17, 2, 1, 3, 8),
                                          (1, 64, 4, 8, 16, 16)])
def test_blocked_heads_fwd(rng, Bz, L, H, P, N, T):
    u, dt, A, Bm, Cm, Dk, pos = _heads_inputs(rng, Bz, L, H, P, N)
    y_seq, h_seq = core_ssm.selective_scan_heads(
        u, dt, A, Bm, Cm, Dk, pos, method="sequential", return_state=True)
    y_blk, h_blk = core_ssm.selective_scan_heads(
        u, dt, A, Bm, Cm, Dk, pos, method="blocked", chunk=T,
        return_state=True)
    np.testing.assert_allclose(np.asarray(y_blk), np.asarray(y_seq),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_blk), np.asarray(h_seq),
                               atol=1e-4, rtol=1e-4)


def test_blocked_heads_grads(rng):
    Bz, L, H, P, N, T = 2, 24, 3, 4, 5, 8
    u, dt, A, Bm, Cm, Dk, pos = _heads_inputs(rng, Bz, L, H, P, N)

    def grads(method):
        def f(u, dt, A, Bm, Cm, Dk):
            y = core_ssm.selective_scan_heads(u, dt, A, Bm, Cm, Dk, pos,
                                              method=method, chunk=T)
            return (y ** 2).sum()
        return jax.grad(f, argnums=tuple(range(6)))(u, dt, A, Bm, Cm, Dk)

    for name, a, b in zip("u dt A B C D".split(), grads("sequential"),
                          grads("blocked")):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-3,
                                   err_msg=f"grad {name}")


def test_blocked_heads_h0_carry(rng):
    """Split-pack state carry: scan [x1; x2] == scan x2 with h0 from x1."""
    Bz, L, H, P, N = 1, 20, 2, 3, 4
    u, dt, A, Bm, Cm, Dk, _ = _heads_inputs(rng, Bz, L, H, P, N)
    pos = jnp.tile(jnp.arange(1, L + 1, dtype=jnp.int32), (Bz, 1))  # no reset
    y_all, h_all = core_ssm.selective_scan_heads(
        u, dt, A, Bm, Cm, Dk, pos, method="blocked", chunk=8,
        return_state=True)
    _, h_mid = core_ssm.selective_scan_heads(
        u[:, :11], dt[:, :11], A, Bm[:, :11], Cm[:, :11], Dk, pos[:, :11],
        method="sequential", return_state=True)
    y_rest, h_end = core_ssm.selective_scan_heads(
        u[:, 11:], dt[:, 11:], A, Bm[:, 11:], Cm[:, 11:], Dk, pos[:, 11:],
        h0=h_mid, method="blocked", chunk=4, return_state=True)
    np.testing.assert_allclose(np.asarray(y_rest), np.asarray(y_all[:, 11:]),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_end), np.asarray(h_all),
                               atol=1e-4, rtol=1e-4)


def test_blocked_heads_grad_does_not_cross_boundary(rng):
    """Backward PUI (paper §3.4) on the head-structured path."""
    Bz, L, H, P, N = 1, 16, 2, 3, 4
    u, dt, A, Bm, Cm, Dk, _ = _heads_inputs(rng, Bz, L, H, P, N)
    pos = jnp.concatenate([jnp.arange(8), jnp.arange(8)])[None]

    def loss(u_in):
        y = core_ssm.selective_scan_heads(u_in, dt, A, Bm, Cm, Dk, pos,
                                          method="blocked", chunk=8)
        return (y[:, 8:] ** 2).sum()

    g = jax.grad(loss)(u)
    np.testing.assert_allclose(g[:, :8], 0.0, atol=1e-7)
    assert float(jnp.abs(g[:, 8:]).max()) > 0


def test_mamba1_degenerate_dispatch(rng):
    """selective_scan (per-channel) ≡ selective_scan_heads with dh = 1."""
    Bz, L, Dm, N = 2, 24, 6, 4
    u = jnp.asarray(rng.normal(size=(Bz, L, Dm)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.5, (Bz, L, Dm)), jnp.float32)
    A = -jnp.exp(jnp.asarray(rng.normal(size=(Dm, N)), jnp.float32))
    Bm = jnp.asarray(rng.normal(size=(Bz, L, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(Bz, L, N)), jnp.float32)
    Dk = jnp.asarray(rng.normal(size=(Dm,)), jnp.float32)
    pos = _packed_pos(rng, Bz, L)
    y_flat = core_ssm.selective_scan(u, dt, A, Bm, Cm, Dk, pos,
                                     method="blocked", chunk=8)
    y_heads = core_ssm.selective_scan_heads(u[..., None], dt, A, Bm, Cm, Dk,
                                            pos, method="blocked", chunk=8)
    np.testing.assert_allclose(np.asarray(y_heads[..., 0]),
                               np.asarray(y_flat), atol=1e-5, rtol=1e-5)
    with pytest.raises(ValueError):
        core_ssm.selective_scan_heads(
            jnp.repeat(u[..., None], 2, -1), dt, A, Bm, Cm, Dk, pos)


# ---------------------------------------------------------------------------
# Pallas blocked_heads kernels (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Bz,L,H,P,N", [(2, 24, 3, 4, 5), (1, 33, 2, 8, 16)])
def test_pallas_blocked_heads_fwd(rng, Bz, L, H, P, N):
    u, dt, A, Bm, Cm, Dk, pos = _heads_inputs(rng, Bz, L, H, P, N)
    y_ref = core_ssm.selective_scan_heads(u, dt, A, Bm, Cm, Dk, pos,
                                          method="sequential")
    y = kops_heads(u, dt, A, Bm, Cm, Dk, pos, backend="pallas", chunk=8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)


def test_pallas_blocked_heads_grads(rng):
    Bz, L, H, P, N = 2, 24, 3, 4, 5
    u, dt, A, Bm, Cm, Dk, pos = _heads_inputs(rng, Bz, L, H, P, N)

    def lp(*args):
        return (kops_heads(*args, pos, backend="pallas", chunk=8) ** 2).sum()

    def lr(*args):
        return (core_ssm.selective_scan_heads(
            *args, pos, method="sequential") ** 2).sum()

    gp = jax.grad(lp, argnums=tuple(range(6)))(u, dt, A, Bm, Cm, Dk)
    gr = jax.grad(lr, argnums=tuple(range(6)))(u, dt, A, Bm, Cm, Dk)
    for name, a, b in zip("u dt A B C D".split(), gp, gr):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-3,
                                   err_msg=f"grad {name}")


def test_pallas_blocked_heads_reset_blocks_grad(rng):
    u, dt, A, Bm, Cm, Dk, _ = _heads_inputs(rng, 1, 16, 2, 4, 4)
    pos = jnp.concatenate([jnp.arange(8), jnp.arange(8)])[None]

    def loss(u_in):
        y = kops_heads(u_in, dt, A, Bm, Cm, Dk, pos, backend="pallas",
                       chunk=8)
        return (y[:, 8:] ** 2).sum()

    g = jax.grad(loss)(u)
    np.testing.assert_allclose(g[:, :8], 0.0, atol=1e-7)
    assert float(jnp.abs(g[:, 8:]).max()) > 0


# ---------------------------------------------------------------------------
# mamba2 block: decode vs full-sequence parity (packed-aware resets)
# ---------------------------------------------------------------------------

def _smoke_cfg():
    from repro.configs.base import get_config
    return dataclasses.replace(get_config("mamba2-370m").reduced(),
                               dtype="float32", d_state=8)


def test_mamba2_step_matches_apply(rng):
    from repro.models import blocks as B
    cfg = _smoke_cfg()
    key = jax.random.PRNGKey(0)
    p = B.init_mamba2(key, cfg)
    Bz, L = 2, 12
    x = jnp.asarray(rng.normal(size=(Bz, L, cfg.d_model)), jnp.float32)
    # packed rows: a reset mid-row exercises the packed-aware decode reset
    pos = np.concatenate([np.arange(5), np.arange(L - 5)])
    pos = jnp.tile(jnp.asarray(pos, jnp.int32)[None], (Bz, 1))
    ctx = B.Ctx(positions=pos,
                segment_ids=jnp.ones((Bz, L), jnp.int32))
    y_full = B.apply_mamba2(p, x, ctx, cfg)
    cache = B.init_mamba2_cache(cfg, Bz, jnp.float32)
    ys = []
    for t in range(L):
        sctx = B.Ctx(reset_t=pos[:, t] == 0)
        y_t, cache = B.step_mamba2(p, x[:, t:t + 1], cache, sctx, cfg)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               atol=2e-5, rtol=1e-4)


def test_mamba2_sharding_rules():
    """Head-structured param leaves pattern-match into PartitionSpecs."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed import sharding as shd

    class FakeMesh:
        def __init__(self, **axes):
            self.shape = axes

    mesh = FakeMesh(data=4, model=2)
    assert shd._param_rule("bc_proj", (128, 32), mesh) == P("model", "data")
    assert shd._param_rule("dt_proj", (128, 8), mesh) == P("model", None)
    assert shd._param_rule("A_log", (8,), mesh) == P("model")       # mamba2
    assert shd._param_rule("A_log", (128, 16), mesh) == P("model", None)
    # head-structured decode cache: (B, H, dh, N) shards heads over model
    cache = {"ssm": jax.ShapeDtypeStruct((8, 4, 16, 8), jnp.float32)}
    spec = shd.cache_pspecs(cache, mesh, batch_size=8)
    assert spec["ssm"] == P("data", "model", None, None)


# ---------------------------------------------------------------------------
# structural memory claim
# ---------------------------------------------------------------------------

def test_blocked_heads_jaxpr_has_no_full_trajectory():
    """`blocked` heads never materializes the (B, L, H, dh, N) state
    trajectory — only chunk-local (B, T, H, dh, N) slices."""
    Bz, L, H, P, N, T = 1, 512, 4, 8, 16, 32
    args = (jnp.zeros((Bz, L, H, P)), jnp.full((Bz, L, H), 0.1),
            -jnp.ones((H,)), jnp.zeros((Bz, L, N)),
            jnp.zeros((Bz, L, N)), jnp.zeros((H,)),
            jnp.zeros((Bz, L), jnp.int32))

    jaxpr = jax.make_jaxpr(lambda *a: core_ssm.selective_scan_heads(
        *a, method="blocked", chunk=T))(*args)
    want = (Bz, L, H, P, N)

    def subjaxprs(val):
        if isinstance(val, jax.core.Jaxpr):
            yield val
        elif isinstance(val, jax.core.ClosedJaxpr):
            yield val.jaxpr
        elif isinstance(val, (tuple, list)):
            for v in val:
                yield from subjaxprs(v)

    def shapes(jx):
        for eqn in jx.eqns:
            for v in eqn.outvars:
                yield getattr(v.aval, "shape", None)
            for val in eqn.params.values():
                for sub in subjaxprs(val):
                    yield from shapes(sub)

    assert not any(s == want for s in shapes(jaxpr.jaxpr))
