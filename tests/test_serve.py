"""Packed-prefill serving handoff + continuous-batching engine.

Parity contract (the tentpole's acceptance bar): a packed multi-prompt
prefill (``model.prefill_packed``) must hand off per-segment decode caches
and segment-end logits that match N individual ``model.prefill`` calls, for
every cached block kind (attn full + windowed, mamba, mamba2, rec, mlstm,
slstm). The engine tests then cover EOS termination, mid-flight slot refill
and agreement with per-request reference decoding — including the
OVERLAPPED engine (async prefill left in flight across decode steps), the
TTFT-driven admission policy (scripted clock), batched
temperature/top-k/top-p sampling (exact parity vs a scripted key-stream
reference, plus distribution sanity), and ``ServeStats`` accounting against
a fully scripted admission trace.

Scheduler v2 (the perf PR): chunked prefill of prompts longer than the
largest bucket (slab-by-slab resume through a side cache, bit-identical to
the unchunked reference, across every cached block kind), the multi-prefill
pipeline (``max_inflight_prefills``) against the blocking engine, the
TTFT-aware bucket policy under a scripted clock, and snapshot/restore in
the middle of a chunked prefill.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.core import packing
from repro.launch.serve import ServeEngine, ServeStats
from repro.models import blocks as B
from repro.models.lm import build_model


def _pack_prompts(prompts, rows, cap, max_segments):
    """first-fit pack + ends; returns (batch dict, ends, (row, seg) map)."""
    pb = packing.pack(prompts, cap, policy="first_fit", num_rows=rows)
    ends = packing.segment_ends(pb, max_segments)
    where = {}
    for r, ids in enumerate(pb.seq_ids):
        for s, i in enumerate(ids):
            where[i] = (r, s)
    batch = {"tokens": pb.tokens, "positions": pb.positions,
             "segment_ids": pb.segment_ids}
    return batch, jnp.asarray(ends), where


# xlstm's chunkwise-parallel mLSTM re-associates its f32 reductions when a
# segment sits at a different offset, and the error compounds over depth —
# same reason tests/test_prefill.py uses 2e-3 on logits. Everything else
# meets the 1e-5 handoff bar.
CASES = [("stablelm-1.6b", None, 1e-5), ("stablelm-1.6b",
                                         {"attn_window": 5}, 1e-5),
         ("mamba-110m", None, 1e-5), ("mamba2-370m", None, 1e-5),
         ("mamba2-370m", {"ssm_norm": "rms_gate"}, 1e-5),
         ("recurrentgemma-2b", None, 1e-5), ("xlstm-125m", None, 5e-4)]


@pytest.mark.parametrize("arch,mod,atol", CASES)
def test_packed_prefill_matches_per_prompt(arch, mod, atol, rng):
    cfg = get_config(arch).reduced()
    if mod:
        cfg = dataclasses.replace(cfg, **mod)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    plens = (9, 14, 5, 11)
    prompts = [rng.integers(1, cfg.vocab, size=n).astype(np.int32)
               for n in plens]
    batch, ends, where = _pack_prompts(prompts, rows=2, cap=24,
                                       max_segments=3)
    if cfg.family == "vlm":
        batch["mrope_positions"] = jnp.asarray(
            np.repeat(np.asarray(batch["positions"])[..., None], 3, axis=-1))
    max_len = 32
    logits, states, seg_lens = model.prefill_packed(params, batch, max_len,
                                                    ends)
    for i, prompt in enumerate(prompts):
        r, s = where[i]
        n = len(prompt)
        assert int(seg_lens[r, s]) == n
        single = {"tokens": jnp.asarray(prompt)[None],
                  "positions": jnp.arange(n, dtype=jnp.int32)[None],
                  "segment_ids": jnp.ones((1, n), jnp.int32)}
        lg_ref, cache_ref, clen = model.prefill(params, single, max_len)
        np.testing.assert_allclose(logits[r, s], lg_ref[0], atol=atol,
                                   rtol=1e-4, err_msg=f"{arch} prompt {i}")

        def check(path, packed_leaf, ref_leaf):
            stacked = any(getattr(p, "key", None) == "units" for p in path)
            got = packed_leaf[:, r, s] if stacked else packed_leaf[r, s]
            want = ref_leaf[:, 0] if stacked else ref_leaf[0]
            np.testing.assert_allclose(
                got, want, atol=atol, rtol=1e-4,
                err_msg=f"{arch} prompt {i} leaf "
                        f"{'/'.join(str(getattr(p, 'key', p)) for p in path)}")

        jax.tree_util.tree_map_with_path(check, states, cache_ref)


def test_absent_segments_zero_and_logits_masked(rng):
    """ends == -1 entries yield zero states and zero logits."""
    cfg = get_config("mamba-110m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [rng.integers(1, cfg.vocab, size=7).astype(np.int32)]
    batch, ends, _ = _pack_prompts(prompts, rows=2, cap=16, max_segments=2)
    logits, states, seg_lens = model.prefill_packed(params, batch, 24, ends)
    assert np.asarray(ends)[0, 1] == -1          # absent segment exists
    np.testing.assert_array_equal(logits[0, 1], 0.0)
    np.testing.assert_array_equal(logits[1], 0.0)    # empty row
    assert int(seg_lens[0, 1]) == 0

    def zero(path, leaf):
        np.testing.assert_array_equal(leaf[:, 0, 1], 0.0)

    jax.tree_util.tree_map_with_path(zero, states)


def test_scatter_into_cache_slots_and_sentinel(rng):
    """Scatter lands states in the addressed slots only; the num_slots
    sentinel drops an entry; untouched slots stay intact."""
    cfg = get_config("mamba-110m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = [rng.integers(1, cfg.vocab, size=n).astype(np.int32)
               for n in (6, 9)]
    batch, ends, where = _pack_prompts(prompts, rows=1, cap=16,
                                       max_segments=2)
    _, states, _ = model.prefill_packed(params, batch, 24, ends)
    nslots = 4
    marker = jax.tree.map(lambda a: jnp.full_like(a, 7.0),
                          model.init_cache(nslots, 24))
    src = jnp.asarray([1, 0, 0], jnp.int32)      # seg1 → slot 0, seg0 → 2
    dst = jnp.asarray([0, 2, nslots], jnp.int32)     # third entry dropped
    out = model.scatter_into_cache(marker, states, src, dst)

    def check(path, got, st):
        stacked = any(getattr(p, "key", None) == "units" for p in path)
        if stacked:
            np.testing.assert_allclose(got[:, 0], st[:, 0, 1], atol=0)
            np.testing.assert_allclose(got[:, 2], st[:, 0, 0], atol=0)
            np.testing.assert_array_equal(got[:, 1], 7.0)
            np.testing.assert_array_equal(got[:, 3], 7.0)
        else:
            np.testing.assert_allclose(got[0], st[0, 1], atol=0)
            np.testing.assert_array_equal(got[1], 7.0)

    jax.tree_util.tree_map_with_path(check, out, states)


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

def _reference_decode(model, params, prompt, max_new, max_len, eos=-1):
    n = len(prompt)
    batch = {"tokens": jnp.asarray(prompt)[None],
             "positions": jnp.arange(n, dtype=jnp.int32)[None],
             "segment_ids": jnp.ones((1, n), jnp.int32)}
    lg, cache, clen = model.prefill(params, batch, max_len)
    out = [int(jnp.argmax(lg[0]))]
    tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
    for t in range(max_new - 1):
        if out[-1] == eos:
            break
        lg, cache = model.decode_step(params, cache, tok, clen + t)
        tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        out.append(int(tok[0, 0]))
    return out


@pytest.fixture(scope="module")
def tiny_engine_model():
    cfg = get_config("mamba-110m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.mark.slow
def test_engine_mixed_lengths_midflight_refill(tiny_engine_model, rng):
    """More requests than slots, mixed prompt AND output lengths: every
    request matches its per-request reference, refills happen while other
    slots are mid-decode, and prefill compiles stay bucket-bounded."""
    cfg, model, params = tiny_engine_model
    prompts = [rng.integers(1, cfg.vocab, size=int(n)).astype(np.int32)
               for n in rng.integers(4, 30, size=10)]
    budgets = [int(b) for b in rng.integers(3, 9, size=10)]
    engine = ServeEngine(model, params, num_slots=3, max_len=64,
                         prefill_rows=2, buckets=(32,), max_segments=2,
                         refill_threshold=1)
    for p, b in zip(prompts, budgets):
        engine.submit(p, b)
    outs = engine.run()
    assert sorted(outs) == list(range(10))
    for i, (p, b) in enumerate(zip(prompts, budgets)):
        assert len(outs[i]) == b
        ref = _reference_decode(model, params, p, b, 64)
        assert outs[i] == ref, f"request {i}"
    st = engine.stats
    assert st.midflight_refills > 0          # refilled without draining
    assert st.buckets == {(2, 32)}           # one compiled prefill shape
    assert not engine._active_slots() and not engine.queue
    assert len(st.ttft_ms) == 10             # one TTFT per request
    assert len(st.itl_ms) == sum(budgets) - 10   # every non-first token


@pytest.mark.slow
def test_overlap_engine_token_identical_greedy(tiny_engine_model, rng):
    """TENTPOLE acceptance: the overlapped engine (prefill left in flight
    while decode keeps stepping) emits token streams identical to the
    per-request reference. The readiness probe is scripted to stay False
    for several engine steps, forcing a wide overlap window."""
    cfg, model, params = tiny_engine_model
    prompts = [rng.integers(1, cfg.vocab, size=int(n)).astype(np.int32)
               for n in rng.integers(4, 30, size=8)]
    budgets = [int(b) for b in rng.integers(3, 8, size=8)]
    engine = ServeEngine(model, params, num_slots=3, max_len=64,
                         prefill_rows=2, buckets=(32,), max_segments=2,
                         refill_threshold=1, overlap=True)
    orig_ready = engine._prefill_ready
    probes = {"n": 0}

    def slow_device(inflight):          # not ready for the first 3 probes
        probes["n"] += 1
        return probes["n"] % 4 == 0 and orig_ready(inflight)

    engine._prefill_ready = slow_device
    for p, b in zip(prompts, budgets):
        engine.submit(p, b)
    outs = engine.run()
    for i, (p, b) in enumerate(zip(prompts, budgets)):
        assert outs[i] == _reference_decode(model, params, p, b, 64), \
            f"request {i}"
    st = engine.stats
    assert st.overlapped_prefills > 0    # prefills landed mid-decode
    assert not engine._active_slots() and not engine.queue
    assert engine._inflight is None


def test_engine_eos_terminates_slot(tiny_engine_model, rng):
    """A request stops the moment greedy decode emits its EOS token (the
    EOS itself is kept), freeing the slot for the queue."""
    cfg, model, params = tiny_engine_model
    prompt = rng.integers(1, cfg.vocab, size=11).astype(np.int32)
    free_run = _reference_decode(model, params, prompt, 8, 64)
    eos = free_run[2]                        # a token greedy decode emits
    hit = free_run.index(eos)                # first time it appears
    engine = ServeEngine(model, params, num_slots=2, max_len=64,
                         prefill_rows=1, buckets=(16,), max_segments=1)
    rid = engine.submit(prompt, 8, eos=eos)
    outs = engine.run()
    assert outs[rid] == free_run[:hit + 1]
    assert outs[rid][-1] == eos
    assert len(outs[rid]) < len(free_run)


def test_decode_batch_eos_stops_appending(tiny_engine_model, rng):
    """Satellite: the padded-wave baseline terminates rows on EOS instead
    of ignoring the argument."""
    cfg, model, params = tiny_engine_model
    prompts = [rng.integers(1, cfg.vocab, size=n).astype(np.int32)
               for n in (7, 12)]
    engine = ServeEngine(model, params, num_slots=2, max_len=64)
    free = engine.decode_batch(prompts, 8)
    assert all(len(o) == 8 for o in free)
    eos = free[0][1]
    engine2 = ServeEngine(model, params, num_slots=2, max_len=64)
    outs = engine2.decode_batch(prompts, 8, eos=eos)
    assert outs[0] == free[0][:2] and outs[0][-1] == eos
    ref1 = [t for t in free[1]]
    cut = ref1.index(eos) + 1 if eos in ref1 else len(ref1)
    assert outs[1] == ref1[:cut]


def test_engine_per_request_budgets_decode_batch(tiny_engine_model, rng):
    """decode_batch honours per-prompt budgets (list form)."""
    cfg, model, params = tiny_engine_model
    prompts = [rng.integers(1, cfg.vocab, size=n).astype(np.int32)
               for n in (5, 9, 6)]
    engine = ServeEngine(model, params, num_slots=3, max_len=64)
    outs = engine.decode_batch(prompts, [2, 5, 3])
    assert [len(o) for o in outs] == [2, 5, 3]


def test_engine_matches_wave_outputs(tiny_engine_model, rng):
    """Continuous engine and padded-wave baseline produce identical greedy
    tokens for the same requests (same handoff numerics, different
    batching schedule)."""
    cfg, model, params = tiny_engine_model
    prompts = [rng.integers(1, cfg.vocab, size=n).astype(np.int32)
               for n in (8, 15, 4)]
    wave = ServeEngine(model, params, num_slots=3, max_len=64)
    wave_outs = wave.decode_batch(prompts, 6)
    engine = ServeEngine(model, params, num_slots=3, max_len=64,
                         prefill_rows=2, buckets=(16, 32), max_segments=2)
    rids = [engine.submit(p, 6) for p in prompts]
    outs = engine.run()
    for rid, w in zip(rids, wave_outs):
        assert outs[rid] == w


# ---------------------------------------------------------------------------
# batched sampling
# ---------------------------------------------------------------------------

def _reference_decode_sampled(model, params, prompt, max_new, rid, seed,
                              temperature, top_k, top_p, max_len=64):
    """Scripted key-stream reference: fold (seed, rid) into a key exactly as
    the engine does, sample the prefill token, then decode+sample per step."""
    n = len(prompt)
    batch = {"tokens": jnp.asarray(prompt)[None],
             "positions": jnp.arange(n, dtype=jnp.int32)[None],
             "segment_ids": jnp.ones((1, n), jnp.int32)}
    lg, cache, clen = model.prefill(params, batch, max_len)
    keys = B.request_keys(seed, [rid])
    ta = jnp.asarray([temperature], jnp.float32)
    ka = jnp.asarray([top_k], jnp.int32)
    pa = jnp.asarray([top_p], jnp.float32)
    tok, keys = B.sample_from_logits(lg, keys, ta, ka, pa)
    out = [int(tok[0])]
    for t in range(max_new - 1):
        lg, cache = model.decode_step(params, cache, tok[:, None], clen + t)
        tok, keys = B.sample_from_logits(lg, keys, ta, ka, pa)
        out.append(int(tok[0]))
    return out


@pytest.mark.slow
def test_sampled_engine_matches_scripted_reference(tiny_engine_model, rng):
    """Sampling parity: a request's (seed, rid)-derived key stream makes its
    sampled tokens independent of slot placement and admission order — the
    engine matches a per-request scripted reference token for token."""
    cfg, model, params = tiny_engine_model
    prompts = [rng.integers(1, cfg.vocab, size=int(n)).astype(np.int32)
               for n in rng.integers(5, 28, size=6)]
    engine = ServeEngine(model, params, num_slots=3, max_len=64,
                         prefill_rows=2, buckets=(32,), max_segments=2,
                         refill_threshold=1, sample_seed=7)
    rids = [engine.submit(p, 5, temperature=0.8, top_k=5)
            for p in prompts]
    # one greedy request rides in the same slots: a mixed batch must keep
    # BOTH contracts (greedy rows are exact argmax inside the sampled step)
    greedy_prompt = rng.integers(1, cfg.vocab, size=13).astype(np.int32)
    rg = engine.submit(greedy_prompt, 5)
    outs = engine.run()
    for i, rid in enumerate(rids):
        ref = _reference_decode_sampled(model, params, prompts[i], 5, rid,
                                        7, 0.8, 5, 1.0)
        assert outs[rid] == ref, f"request {i}"
    assert outs[rg] == _reference_decode(model, params, greedy_prompt, 5, 64)


def test_sampling_distribution_sanity():
    """sample_from_logits unit contract: greedy at temperature 0; top-k=1
    and tiny top-p collapse to argmax; sampled tokens stay inside the top-k
    set; a hot temperature actually spreads mass across > 1 token."""
    logits = jnp.asarray(np.tile(
        np.array([4.0, 3.5, 3.0, -1.0, -2.0, -30.0], np.float32), (64, 1)))
    keys = jax.vmap(lambda i: jax.random.fold_in(jax.random.PRNGKey(3), i))(
        jnp.arange(64))
    zeros = jnp.zeros((64,), jnp.float32)
    ones_p = jnp.ones((64,), jnp.float32)
    k0 = jnp.zeros((64,), jnp.int32)
    # temperature 0 → argmax, whatever the key
    tok, keys2 = B.sample_from_logits(logits, keys, zeros, k0, ones_p)
    np.testing.assert_array_equal(np.asarray(tok), 0)
    assert not np.array_equal(np.asarray(keys2), np.asarray(keys))
    # top_k=1 → argmax even when hot
    tok, _ = B.sample_from_logits(logits, keys, zeros + 2.0,
                                  k0 + 1, ones_p)
    np.testing.assert_array_equal(np.asarray(tok), 0)
    # tiny top_p keeps only the argmax bucket
    tok, _ = B.sample_from_logits(logits, keys, zeros + 2.0, k0,
                                  ones_p * 1e-4)
    np.testing.assert_array_equal(np.asarray(tok), 0)
    # hot + top_k=3: every sample in {0,1,2}, and both mass spread and key
    # advance are visible across the 64 independent rows
    tok, _ = B.sample_from_logits(logits, keys, zeros + 2.0, k0 + 3,
                                  ones_p)
    t = np.asarray(tok)
    assert set(t.tolist()) <= {0, 1, 2}
    assert len(set(t.tolist())) > 1


# ---------------------------------------------------------------------------
# latency-aware admission + ServeStats accounting (scripted traces)
# ---------------------------------------------------------------------------

def test_latency_aware_admission_scripted_clock(tiny_engine_model, rng):
    """The TTFT policy admits below the refill threshold once the oldest
    queued request has waited past the target; without a target the same
    trace waits for the throughput threshold."""
    cfg, model, params = tiny_engine_model
    a = rng.integers(1, cfg.vocab, size=7).astype(np.int32)
    b = rng.integers(1, cfg.vocab, size=9).astype(np.int32)
    t = {"now": 0.0}

    def mk(target):
        return ServeEngine(model, params, num_slots=2, max_len=64,
                           prefill_rows=1, buckets=(16,), max_segments=1,
                           refill_threshold=2, overlap=False,
                           target_ttft_ms=target, clock=lambda: t["now"])

    # --- with a 50ms target: b is admitted the moment its wait blows it
    t["now"] = 0.0
    eng = mk(50.0)
    ra = eng.submit(a, 6)
    eng.step()                       # a admitted (nothing was decoding)
    rb = eng.submit(b, 3)
    eng.step()                       # wait 0ms < 50ms → b stays queued
    assert eng.stats.prefills == 1 and len(eng.queue) == 1
    t["now"] = 0.2                   # 200ms > 50ms target
    eng.step()
    assert eng.stats.prefills == 2       # admitted below the threshold
    assert eng.stats.early_admits == 1
    assert eng.stats.midflight_refills == 1
    outs = eng.run()
    assert outs[ra] == _reference_decode(model, params, a, 6, 64)
    assert outs[rb] == _reference_decode(model, params, b, 3, 64)
    # TTFT accounting: a was admitted at once, b waited the scripted 200ms
    assert len(eng.stats.ttft_ms) == 2
    assert eng.stats.ttft_ms[0] == pytest.approx(0.0)
    assert eng.stats.ttft_ms[1] == pytest.approx(200.0)
    pct = eng.stats.ttft_percentiles()
    assert set(pct) == {"p50", "p95"} and pct["p50"] <= pct["p95"]

    # --- same trace, no target: the threshold rule alone never fires while
    # a is decoding; b waits for a to drain
    t["now"] = 0.0
    eng = mk(None)
    eng.submit(a, 6)
    eng.step()
    eng.submit(b, 3)
    eng.step()
    t["now"] = 0.2
    eng.step()
    assert eng.stats.prefills == 1       # still waiting
    eng.run()
    assert eng.stats.prefills == 2       # admitted only once a finished
    assert eng.stats.early_admits == 0


def test_serve_stats_accounting_scripted_trace(tiny_engine_model, rng):
    """Every ServeStats counter against a hand-scripted admission trace:
    2 slots, 3 requests (budgets 2/3/2) → 2 prefills (one mid-flight),
    2 fused decode steps, 7 tokens, 15 prefilled prompt tokens."""
    cfg, model, params = tiny_engine_model
    prompts = [rng.integers(1, cfg.vocab, size=n).astype(np.int32)
               for n in (4, 5, 6)]
    engine = ServeEngine(model, params, num_slots=2, max_len=32,
                         prefill_rows=2, buckets=(16,), max_segments=2,
                         refill_threshold=1, overlap=False)
    for p, budget in zip(prompts, (2, 3, 2)):
        engine.submit(p, budget)
    outs = engine.run()
    assert [len(outs[i]) for i in range(3)] == [2, 3, 2]
    st = engine.stats
    assert st.prefills == 2
    assert st.prefill_tokens == 4 + 5 + 6
    assert st.midflight_refills == 1     # req2 joined while req1 decoded
    assert st.decode_steps == 2          # step1: reqs 0+1; step2: reqs 1+2
    assert st.generated == 7
    assert st.buckets == {(2, 16)}
    assert st.early_admits == 0 and st.overlapped_prefills == 0
    assert len(st.ttft_ms) == 3          # one per request
    assert len(st.itl_ms) == 7 - 3       # every token after each first
    assert all(v >= 0 for v in st.ttft_ms + st.itl_ms)
    # a reset (the benchmark's per-round discipline) starts from zeros
    fresh = ServeStats()
    assert fresh.ttft_percentiles() == {} and fresh.buckets == set()


def test_submit_validation(tiny_engine_model):
    cfg, model, params = tiny_engine_model
    engine = ServeEngine(model, params, num_slots=2, max_len=32,
                         buckets=(16,))
    # scheduler v2: over-bucket prompts are ACCEPTED — the chunk lane
    # serves them (the old unconditional rejection is gone)
    long_rid = engine.submit(np.ones(20, np.int32), 4)
    with pytest.raises(ValueError):
        engine.submit(np.ones(10, np.int32), 30)     # prompt+new > max_len
    with pytest.raises(ValueError, match="non-empty"):
        engine.submit(np.ones(0, np.int32), 4)       # empty prompt
    with pytest.raises(ValueError, match="max_new"):
        engine.submit(np.ones(5, np.int32), 0)       # no token budget
    with pytest.raises(ValueError, match="max_new"):
        engine.submit(np.ones(5, np.int32), -3)
    with pytest.raises(ValueError, match="temperature"):
        engine.submit(np.ones(5, np.int32), 2, temperature=-0.5)
    with pytest.raises(ValueError, match="top_k"):
        engine.submit(np.ones(5, np.int32), 2, top_k=-5)
    with pytest.raises(ValueError, match="top_p"):
        engine.submit(np.ones(5, np.int32), 2, top_p=0.0)
    engine.submit(np.ones(5, np.int32), 2)
    with pytest.raises(RuntimeError):                # would clobber slots
        engine.decode_batch([np.ones(5, np.int32)], 2)
    engine.run()
    engine.decode_batch([np.ones(5, np.int32)], 2)   # drained: fine
    assert engine.status[long_rid] == "done"         # chunk lane served it
    assert engine.stats.chunked_prefills == 1
    # the explicit prompt-length bound replaces the old over-bucket guard
    bounded = ServeEngine(model, params, num_slots=2, max_len=32,
                          buckets=(16,), max_prompt_len=16)
    with pytest.raises(ValueError, match="max_prompt_len"):
        bounded.submit(np.ones(20, np.int32), 4)
    bounded.submit(np.ones(16, np.int32), 4)         # at the bound: fine
    # with the chunk lane disabled the over-bucket rejection still fires
    unchunked = ServeEngine(model, params, num_slots=2, max_len=32,
                            buckets=(16,), chunk_rows=0)
    with pytest.raises(ValueError, match="chunked prefill is unavailable"):
        unchunked.submit(np.ones(20, np.int32), 4)
    with pytest.raises(ValueError, match="bucket_policy"):
        ServeEngine(model, params, num_slots=2, max_len=32,
                    buckets=(16,), bucket_policy="widest")


# ---------------------------------------------------------------------------
# scheduler v2: chunked prefill, prefill pipelining, TTFT bucket policy
# ---------------------------------------------------------------------------

# every cached block kind resumes mid-prompt: attn (full + windowed ring),
# mamba, mamba2, rec, mlstm/slstm. The windowed case must chunk BELOW the
# ring size (chunk_attn rejects slabs wider than the ring statically).
CHUNK_CASES = [("stablelm-1.6b", None, 8),
               ("stablelm-1.6b", {"attn_window": 5}, 4),
               ("mamba-110m", None, 8), ("mamba2-370m", None, 8),
               ("recurrentgemma-2b", None, 8), ("xlstm-125m", None, 8)]


@pytest.mark.slow
@pytest.mark.parametrize("arch,mod,chunk", CHUNK_CASES)
def test_chunked_prefill_matches_reference(arch, mod, chunk, rng):
    """TENTPOLE acceptance: a prompt longer than the largest bucket is
    consumed in fixed-size slabs resuming from carried state, and the
    resulting greedy stream is bit-identical to the unchunked per-request
    reference — for every cached block kind. A short prompt rides along on
    the packed path to prove the two admission lanes coexist."""
    cfg = get_config(arch).reduced()
    if mod:
        cfg = dataclasses.replace(cfg, **mod)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    assert model.supports_chunked_prefill
    long_p = rng.integers(1, cfg.vocab, size=37).astype(np.int32)
    short = rng.integers(1, cfg.vocab, size=6).astype(np.int32)
    engine = ServeEngine(model, params, num_slots=2, max_len=64,
                         prefill_rows=1, buckets=(8,), max_segments=1,
                         chunk_size=chunk)
    rl = engine.submit(long_p, 4)
    rs = engine.submit(short, 4)
    outs = engine.run()
    assert outs[rl] == _reference_decode(model, params, long_p, 4, 64), arch
    assert outs[rs] == _reference_decode(model, params, short, 4, 64), arch
    st = engine.stats
    assert st.chunked_prefills == 1
    assert st.chunk_rounds == -(-37 // chunk)    # ceil: one slab per round
    assert st.chunk_tokens == 37
    assert st.prefill_ms > 0 and st.chunk_ms > 0 and st.decode_ms > 0


@pytest.mark.slow
def test_long_prompt_4x_bucket_decodes_alongside(tiny_engine_model, rng):
    """ISSUE acceptance: a prompt 4× the largest bucket completes via
    chunked prefill while short concurrent requests keep decoding — the
    slab rounds interleave with fused decode steps instead of head-of-line
    blocking them, and every stream still matches its reference."""
    cfg, model, params = tiny_engine_model
    long_p = rng.integers(1, cfg.vocab, size=64).astype(np.int32)   # 4×16
    shorts = [rng.integers(1, cfg.vocab, size=int(n)).astype(np.int32)
              for n in rng.integers(4, 14, size=4)]
    engine = ServeEngine(model, params, num_slots=3, max_len=96,
                         prefill_rows=2, buckets=(16,), max_segments=2,
                         refill_threshold=1, chunk_size=16)
    rl = engine.submit(long_p, 5)
    rshorts = [engine.submit(p, 3) for p in shorts]
    saw_decode_mid_chunk = False
    prev_decode = 0
    while engine.step():
        if engine._chunk_active() and engine.stats.decode_steps > prev_decode:
            saw_decode_mid_chunk = True
        prev_decode = engine.stats.decode_steps
    assert saw_decode_mid_chunk          # decode progressed mid-chunk
    outs = engine.outputs
    assert outs[rl] == _reference_decode(model, params, long_p, 5, 96)
    for rid, p in zip(rshorts, shorts):
        assert outs[rid] == _reference_decode(model, params, p, 3, 96)
    st = engine.stats
    assert st.chunk_rounds == 4 and st.chunked_prefills == 1
    assert st.chunk_tokens == 64
    assert all(engine.status[r] == "done" for r in outs)


@pytest.mark.slow
def test_pipelined_chunked_engine_bit_identical(tiny_engine_model, rng):
    """TENTPOLE acceptance: the pipelined engine (prefill pool of 3, two
    chunk rows, overlap on) emits token streams bit-identical to the
    blocking single-prefill engine on the same mixed greedy + sampled
    request set — the (seed, rid) key streams make schedule changes
    invisible in the tokens."""
    cfg, model, params = tiny_engine_model
    lens = [5, 40, 9, 13, 26, 7, 11, 33]       # 40/26/33 > largest bucket
    prompts = [rng.integers(1, cfg.vocab, size=n).astype(np.int32)
               for n in lens]
    budgets = [int(b) for b in rng.integers(3, 7, size=len(lens))]
    temps = [0.0, 0.7, 0.0, 0.9, 0.0, 0.8, 0.0, 0.6]

    def run_engine(**kw):
        eng = ServeEngine(model, params, num_slots=3, max_len=64,
                          prefill_rows=2, buckets=(16,), max_segments=2,
                          refill_threshold=1, sample_seed=11, **kw)
        rids = [eng.submit(p, b, temperature=tp, top_k=7)
                for p, b, tp in zip(prompts, budgets, temps)]
        outs = eng.run()
        return [outs[r] for r in rids], eng.stats

    base, _ = run_engine(overlap=False, max_inflight_prefills=1)
    pipe, st = run_engine(overlap=True, max_inflight_prefills=3,
                          chunk_rows=2)
    assert base == pipe
    assert st.chunked_prefills == 3


def test_ttft_percentiles_edge_cases():
    st = ServeStats()
    assert st.ttft_percentiles() == {}           # no samples: empty dict
    st.ttft_ms.append(12.5)
    pct = st.ttft_percentiles()                  # single sample: p50 == p95
    assert pct["p50"] == pytest.approx(12.5)
    assert pct["p95"] == pytest.approx(12.5)


def test_ttft_bucket_policy_scripted_clock(tiny_engine_model, rng):
    """bucket_policy='ttft' under a scripted clock: with slack against the
    target the engine upgrades to the bucket that admits strictly more
    queued requests; once the head has already waited out the whole
    allowance the upgrade is deferred and the smallest fit wins."""
    cfg, model, params = tiny_engine_model
    t = {"now": 0.0}

    def mk():
        # refill_threshold=4: once anything decodes, a new round needs ALL
        # slots free — so ONE admission round happens per scripted step
        return ServeEngine(model, params, num_slots=4, max_len=64,
                           prefill_rows=1, buckets=(8, 32), max_segments=4,
                           overlap=False, refill_threshold=4,
                           bucket_policy="ttft", target_ttft_ms=100.0,
                           clock=lambda: t["now"])

    # four 8-token prompts: the 8-bucket admits only the head (1 row), the
    # 32-bucket packs all four (4 segments in the one row). Wait 0 is well
    # inside the 100ms allowance → upgrade and admit everything in one
    # round.
    t["now"] = 0.0
    eng = mk()
    for _ in range(4):
        eng.submit(rng.integers(1, cfg.vocab, size=8).astype(np.int32), 2)
    assert eng.stats.queue_depth_max == 4
    eng.step()
    assert eng.stats.bucket_upgrades == 1
    assert eng.stats.prefills == 1 and eng.stats.buckets == {(1, 32)}
    eng.run()
    assert eng.stats.buckets == {(1, 32)}        # one compiled shape
    # same queue, but the head has already waited 120ms ≥ the whole 100ms
    # allowance — it is late NOW, a bigger forward only makes it later →
    # every admission round stays small (and the early-admit override
    # keeps admitting rounds below the threshold): four 1-request
    # prefills, upgrades deferred while >1 request is queued
    t["now"] = 0.0
    eng = mk()
    for _ in range(4):
        eng.submit(rng.integers(1, cfg.vocab, size=8).astype(np.int32), 2)
    t["now"] = 0.12
    eng.step()
    assert eng.stats.deferred_upgrades == 3
    assert eng.stats.bucket_upgrades == 0
    assert eng.stats.early_admits >= 1
    assert eng.stats.prefills == 4 and eng.stats.buckets == {(1, 8)}
    eng.run()


@pytest.mark.slow
def test_snapshot_restore_mid_chunked_prefill(tiny_engine_model, rng,
                                              tmp_path):
    """A request mid-chunked-prefill survives snapshot/restore: a fresh
    engine resumes the slab stream where it left off and completes every
    request with the exact tokens an uninterrupted run produces."""
    from repro.checkpoint.checkpoint import CheckpointManager
    cfg, model, params = tiny_engine_model
    long_p = rng.integers(1, cfg.vocab, size=48).astype(np.int32)
    short = rng.integers(1, cfg.vocab, size=7).astype(np.int32)

    def mk():
        return ServeEngine(model, params, num_slots=2, max_len=96,
                           prefill_rows=1, buckets=(16,), max_segments=1,
                           refill_threshold=1, chunk_size=16)

    ref_l = _reference_decode(model, params, long_p, 4, 96)
    ref_s = _reference_decode(model, params, short, 3, 96)
    eng = mk()
    rl = eng.submit(long_p, 4)
    rs = eng.submit(short, 3)
    eng.step()
    assert eng._chunk_active()                   # slab 1 of 3 consumed
    assert 0 < eng.chunk_off[0] < len(long_p)
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    eng.snapshot(mgr, step=1)
    eng2 = mk()
    eng2.restore(mgr)
    assert eng2._chunk_active()                  # mid-chunk row came back
    outs = eng2.run()
    assert outs[rl] == ref_l and outs[rs] == ref_s
    assert rl in eng2.resumed and rs in eng2.resumed
    assert eng2.status[rl] == "done" and eng2.status[rs] == "done"


# ---------------------------------------------------------------------------
# satellites: rms_gate variant, sharding rules, packing helper
# ---------------------------------------------------------------------------

def test_mamba2_rms_gate_param_and_effect(rng):
    cfg = dataclasses.replace(get_config("mamba2-370m").reduced(),
                              ssm_norm="rms_gate")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    unit0 = jax.tree.map(lambda a: a[0], params["units"])
    name = next(n for n in unit0 if n.endswith("mamba2"))
    assert "ssm_norm_w" in unit0[name]
    assert unit0[name]["ssm_norm_w"].shape == (cfg.d_inner,)
    # apply vs step parity (full-seq forward == token-by-token decode)
    n = 9
    toks = rng.integers(1, cfg.vocab, size=n).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks)[None],
             "positions": jnp.arange(n, dtype=jnp.int32)[None],
             "segment_ids": jnp.ones((1, n), jnp.int32)}
    full = model.forward(params, batch)
    cache = model.init_cache(1, 16)
    for t in range(n):
        lg, cache = model.decode_step(
            params, cache, jnp.asarray([[toks[t]]]),
            jnp.asarray([t]), jnp.asarray([t == 0]))
    np.testing.assert_allclose(lg[0], full[0, -1], atol=2e-4, rtol=1e-4)
    # the knob actually changes the function
    cfg2 = dataclasses.replace(cfg, ssm_norm="none")
    model2 = build_model(cfg2)
    params2 = jax.tree.map(lambda a: a,
                           {k: v for k, v in params.items()})
    params2["units"] = jax.tree.map(
        lambda a: a, {name: {k: v for k, v in params["units"][name].items()
                             if k != "ssm_norm_w"}
                      for name in params["units"]})
    out2 = model2.forward(params2, batch)
    assert float(jnp.abs(out2 - full).max()) > 1e-3


def test_sharding_rules_serve_states():
    from repro.distributed.sharding import (param_pspecs, packed_state_pspecs,
                                            cache_pspecs)
    from jax.sharding import Mesh, PartitionSpec as P
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    cfg = dataclasses.replace(get_config("mamba2-370m").reduced(),
                              ssm_norm="rms_gate")
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = param_pspecs(params, mesh)
    unit0 = specs["units"]
    name = next(n for n in unit0 if n.endswith("mamba2"))
    assert isinstance(unit0[name]["ssm_norm_w"], P)
    # packed prefill states: (n_units, B, S, …) leaves get a replicated
    # segment axis and cache-like specs elsewhere
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "positions": jnp.zeros((2, 16), jnp.int32),
             "segment_ids": jnp.zeros((2, 16), jnp.int32)}
    ends = jnp.zeros((2, 3), jnp.int32)
    _, states, _ = jax.eval_shape(
        lambda p, b, e: model.prefill_packed(p, b, 24, e),
        params, batch, ends)
    sspecs = packed_state_pspecs(states, mesh)
    cspecs = cache_pspecs(jax.eval_shape(lambda: model.init_cache(4, 24)),
                          mesh, 4)
    for (pth, sspec), (_, cspec) in zip(
            jax.tree_util.tree_leaves_with_path(sspecs),
            jax.tree_util.tree_leaves_with_path(cspecs)):
        assert len(sspec) == len(cspec) + 1     # extra segment axis
        assert sspec[2] is None                 # segments replicated


def test_segment_ends_helper(rng):
    prompts = [rng.integers(1, 50, size=n).astype(np.int32)
               for n in (4, 6, 3)]
    pb = packing.pack(prompts, 12, policy="first_fit", num_rows=2)
    ends = packing.segment_ends(pb, 3)
    assert ends.shape == (2, 3)
    # row 0: segs of 4 and 6 → ends 3, 9
    np.testing.assert_array_equal(ends[0], [3, 9, -1])
    with pytest.raises(ValueError):
        packing.segment_ends(pb, 1)
