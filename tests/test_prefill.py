"""Prefill-with-cache: one forward pass hands off per-layer decode caches
(O(L) serving handoff). Reference = token-by-token replay with per-row
freezing of finished rows. Covers ring-buffer attention (window < max_len),
SSM/RG-LRU/mLSTM/sLSTM state freezing across right-padding."""
import dataclasses
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.models.lm import build_model


def _replay(model, params, toks, lens, max_len):
    B = len(lens)
    cache = model.init_cache(B, max_len)
    lg = None
    for t in range(max(lens)):
        tk = jnp.stack([toks[b, min(t, lens[b] - 1)]
                        for b in range(B)])[:, None]
        cur = jnp.minimum(jnp.full((B,), t), jnp.asarray(lens) - 1)
        lg_t, cache_new = model.decode_step(params, cache, tk, cur)
        mask = jnp.asarray([t < n for n in lens])

        def freeze(path, new, old):
            # unit-scanned cache leaves carry a leading n_units dim
            stacked = any(getattr(p, "key", None) == "units" for p in path)
            ax = 1 if stacked else 0
            shape = [1] * new.ndim
            shape[ax] = B
            return jnp.where(mask.reshape(shape), new, old)

        cache = jax.tree_util.tree_map_with_path(freeze, cache_new, cache)
        lg = lg_t if lg is None else jnp.where(
            (jnp.asarray(lens) - 1 == t)[:, None], lg_t, lg)
    return lg, cache


CASES = [("stablelm-1.6b", None), ("stablelm-1.6b", {"attn_window": 5}),
         ("mamba-110m", None), ("recurrentgemma-2b", None),
         ("xlstm-125m", None), ("mixtral-8x22b", None),
         ("qwen2-vl-2b", None)]


@pytest.mark.parametrize("arch,mod", CASES)
def test_prefill_handoff_matches_replay(arch, mod, rng):
    cfg = get_config(arch).reduced()
    if mod:
        cfg = dataclasses.replace(cfg, **mod)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lens, L, max_len = [7, 11], 12, 24
    toks = np.zeros((2, L), np.int32)
    seg = np.zeros((2, L), np.int32)
    pos = np.zeros((2, L), np.int32)
    for b, n in enumerate(lens):
        toks[b, :n] = rng.integers(1, cfg.vocab, n)
        seg[b, :n] = 1
        pos[b, :n] = np.arange(n)
    batch = {"tokens": jnp.asarray(toks), "positions": jnp.asarray(pos),
             "segment_ids": jnp.asarray(seg)}
    if cfg.family == "vlm":
        batch["mrope_positions"] = jnp.asarray(
            np.repeat(pos[..., None], 3, axis=-1))
    logits, cache, clen = model.prefill(params, batch, max_len)
    lg_ref, cache_ref = _replay(model, params, jnp.asarray(toks), lens,
                                max_len)
    np.testing.assert_allclose(logits, lg_ref, atol=2e-3, rtol=1e-3)
    np.testing.assert_array_equal(np.asarray(clen), lens)
    # decode continuation: 3 greedy tokens, both paths identical
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    tok_r = jnp.argmax(lg_ref, -1)[:, None].astype(jnp.int32)
    for i in range(3):
        l1, cache = model.decode_step(params, cache, tok, clen + i)
        l2, cache_ref = model.decode_step(params, cache_ref, tok_r,
                                          jnp.asarray(lens) + i)
        np.testing.assert_allclose(l1, l2, atol=2e-3, rtol=1e-3,
                                   err_msg=f"{arch} step {i}")
        tok = jnp.argmax(l1, -1)[:, None].astype(jnp.int32)
        tok_r = jnp.argmax(l2, -1)[:, None].astype(jnp.int32)


def test_prefill_logits_consistent_with_prefill():
    cfg = get_config("mamba-110m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    n, L = 9, 12
    toks = np.zeros((1, L), np.int32)
    toks[0, :n] = rng.integers(1, cfg.vocab, n)
    seg = (np.arange(L) < n).astype(np.int32)[None]
    pos = (np.arange(L) * (np.arange(L) < n)).astype(np.int32)[None]
    batch = {"tokens": jnp.asarray(toks), "positions": jnp.asarray(pos),
             "segment_ids": jnp.asarray(seg)}
    a = model.prefill_logits(params, batch)
    b, _, _ = model.prefill(params, batch, 16)
    np.testing.assert_allclose(a, b, atol=1e-5)
