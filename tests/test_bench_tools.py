"""benchmarks/compare.py CLI contracts: the --accept baseline promotion
(staging .new.json → committed baseline, staging file removed) and the
--schema structural check the CI bench smoke gates on."""
import json
import os
import subprocess
import sys

REPO = os.path.join(os.path.dirname(__file__), "..")
COMPARE = os.path.join(REPO, "benchmarks", "compare.py")


def _run(*args):
    return subprocess.run([sys.executable, COMPARE, *args],
                          capture_output=True, text=True)


def _rec(schedule, us, **extra):
    return {"op": "serve", "shape": "s1", "schedule": schedule,
            "us_per_call": us, "tok_per_s": 1e6 / us, **extra}


def _write(path, recs):
    with open(path, "w") as f:
        json.dump(recs, f)


def test_accept_promotes_and_removes_staging(tmp_path):
    old = tmp_path / "BENCH_x.json"
    new = tmp_path / "BENCH_x.new.json"
    _write(old, [_rec("a", 100.0)])
    staged = [_rec("a", 250.0)]              # a >10% regression, on purpose
    _write(new, staged)
    r = _run("--pair", str(old), str(new), "--accept")
    # accepting is the operator's call: regressions are SHOWN, not fatal
    assert r.returncode == 0, r.stdout + r.stderr
    assert "REGRESSION" in r.stdout and "accepted" in r.stdout
    assert not new.exists()                  # staging file cleaned up
    assert json.load(open(old)) == staged    # baseline replaced


def test_accept_first_baseline_and_optional_missing(tmp_path):
    old = tmp_path / "BENCH_y.json"
    new = tmp_path / "BENCH_y.new.json"
    _write(new, [_rec("a", 10.0)])
    r = _run("--pair", str(old), str(new),
             "--optional-pair", str(tmp_path / "no.json"),
             str(tmp_path / "no.new.json"), "--accept")
    assert r.returncode == 0, r.stdout + r.stderr
    assert json.load(open(old)) == [_rec("a", 10.0)]
    assert not new.exists()
    assert "skipping accept" in r.stdout
    # a REQUIRED pair with no staging file still fails the accept run
    r = _run("--pair", str(old), str(new), "--accept")
    assert r.returncode == 1
    assert "MISSING staging" in r.stdout


def test_compare_without_accept_still_gates(tmp_path):
    old = tmp_path / "BENCH_z.json"
    new = tmp_path / "BENCH_z.new.json"
    _write(old, [_rec("a", 100.0)])
    _write(new, [_rec("a", 250.0)])
    r = _run("--pair", str(old), str(new))
    assert r.returncode == 1                 # the plain gate still fails
    assert new.exists() and "REGRESSION" in r.stdout


def test_schema_ok_and_violations(tmp_path):
    good = tmp_path / "good.json"
    _write(good, [_rec("a", 10.0, ttft_p50_ms=1.5, ttft_p95_ms=9.0),
                  _rec("b", 20.0)])
    r = _run("--schema", str(good))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK, 2 records" in r.stdout

    bad = tmp_path / "bad.json"
    _write(bad, [
        {"op": "serve", "shape": "s1"},                       # missing keys
        _rec("a", -5.0),                                      # bad number
        _rec("c", 10.0, ttft_p50_ms="fast"),                  # bad ttft type
        _rec("d", 10.0), _rec("d", 11.0),                     # duplicate row
    ])
    r = _run("--schema", str(bad))
    assert r.returncode == 1
    for frag in ("schedule", "us_per_call", "ttft_p50_ms", "duplicate"):
        assert frag in r.stdout, f"{frag} not reported:\n{r.stdout}"

    empty = tmp_path / "empty.json"
    _write(empty, [])
    assert _run("--schema", str(empty)).returncode == 1
    assert _run("--schema", str(tmp_path / "missing.json")).returncode == 1
