import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True, scope="module")
def _release_compiled_executables():
    """XLA's CPU backend keeps one mmap'd JIT-code region per compiled
    executable — including the tiny ones eager primitive dispatch compiles
    — and never unmaps them while referenced. A full tier-1 run compiles
    enough of them to exhaust ``vm.max_map_count`` (65530 default) and
    LLVM then SEGFAULTS on the failed mmap mid-compile. Dropping the
    compilation caches after every test module keeps the map count
    bounded; per-module caches are cold anyway (each module builds its own
    models)."""
    yield
    import gc
    import jax
    jax.clear_caches()
    gc.collect()


def make_packed(rng, lens, cap, feat=None, rows=None):
    """Helper: pack per-sequence arrays (built by `feat(n)` or token ids)
    into (rows, cap) buffers. Returns (packed_values, positions, seg_ids,
    per_seq_values, row_offsets)."""
    import numpy as np
    vals = [feat(n) if feat else
            rng.integers(1, 100, size=(n,)).astype(np.int32) for n in lens]
    rows_plan = []
    cur, used = [], 0
    for i, n in enumerate(lens):
        if used + n > cap:
            rows_plan.append(cur)
            cur, used = [], 0
        cur.append(i)
        used += n
    rows_plan.append(cur)
    R = rows if rows is not None else len(rows_plan)
    shape_tail = vals[0].shape[1:]
    packed = np.zeros((R, cap) + shape_tail, vals[0].dtype)
    pos = np.zeros((R, cap), np.int32)
    seg = np.zeros((R, cap), np.int32)
    offsets = {}
    for r, row in enumerate(rows_plan):
        off = 0
        for s_i, i in enumerate(row, start=1):
            n = lens[i]
            packed[r, off:off + n] = vals[i]
            pos[r, off:off + n] = np.arange(n)
            seg[r, off:off + n] = s_i
            offsets[i] = (r, off)
            off += n
    return packed, pos, seg, vals, offsets
