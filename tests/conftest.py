import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_packed(rng, lens, cap, feat=None, rows=None):
    """Helper: pack per-sequence arrays (built by `feat(n)` or token ids)
    into (rows, cap) buffers. Returns (packed_values, positions, seg_ids,
    per_seq_values, row_offsets)."""
    import numpy as np
    vals = [feat(n) if feat else
            rng.integers(1, 100, size=(n,)).astype(np.int32) for n in lens]
    rows_plan = []
    cur, used = [], 0
    for i, n in enumerate(lens):
        if used + n > cap:
            rows_plan.append(cur)
            cur, used = [], 0
        cur.append(i)
        used += n
    rows_plan.append(cur)
    R = rows if rows is not None else len(rows_plan)
    shape_tail = vals[0].shape[1:]
    packed = np.zeros((R, cap) + shape_tail, vals[0].dtype)
    pos = np.zeros((R, cap), np.int32)
    seg = np.zeros((R, cap), np.int32)
    offsets = {}
    for r, row in enumerate(rows_plan):
        off = 0
        for s_i, i in enumerate(row, start=1):
            n = lens[i]
            packed[r, off:off + n] = vals[i]
            pos[r, off:off + n] = np.arange(n)
            seg[r, off:off + n] = s_i
            offsets[i] = (r, off)
            off += n
    return packed, pos, seg, vals, offsets
