"""Prefix/state cache + speculative decode (the O(1)-state exploits).

The StateCache unit tests need no model: longest-prefix lookup over
distinct stored lengths, LRU eviction at the byte budget, generation-based
miss memoization, oversized-entry refusal.

The engine tests pin the PR's acceptance bar: a warm cache-hit stream
(declared shared prefix restored, only the suffix prefilled — or a
whole-prompt hit with NO forward at all) is bit-identical to a cold run;
speculative decode (n-gram draft + one verify forward + trajectory
rollback) is bit-identical to one-token-at-a-time greedy; both hold for
EVERY cached block kind (attn full + windowed, mamba, mamba2, rec,
mlstm/slstm). The fault seams ride along: a poisoned cached state must be
quarantined by the guard rails, never streamed from; a forced cache drop
must fall back to a cold prefill with identical output.
"""
import dataclasses

import numpy as np
import jax
import pytest

from repro.configs.base import get_config
from repro.faults import FaultPlan
from repro.checkpoint.checkpoint import CheckpointManager
from repro.launch.serve import ServeEngine
from repro.launch.state_cache import StateCache, state_row, cache_row
from repro.models.lm import build_model
from tests.test_serve import _reference_decode, tiny_engine_model  # noqa: F401


# --------------------------------------------------------------- unit level

def _fake_state(fill=0.0, units=2, width=8):
    return {"units": np.full((units, 1, width), fill, np.float32),
            "tail": np.full((1, 4), fill, np.float32)}


def _fake_logits(v=16):
    return np.zeros(v, np.float32)


def test_lookup_longest_prefix_wins():
    sc = StateCache(1 << 20)
    toks = np.arange(1, 40, dtype=np.int32)
    sc.insert(toks, 8, _fake_state(1.0), _fake_logits())
    sc.insert(toks, 24, _fake_state(2.0), _fake_logits())
    e = sc.lookup(toks)
    assert e.prefix_len == 24 and e.state["tail"][0, 0] == 2.0
    # a shorter query can only match the shorter stored prefix
    e = sc.lookup(toks[:10])
    assert e.prefix_len == 8
    # a diverging prompt misses entirely
    other = toks.copy()
    other[3] = 999
    assert sc.lookup(other) is None
    assert sc.hits == 2 and sc.misses == 1 and sc.lookups == 3


def test_lru_eviction_at_byte_budget():
    one = _fake_state()
    nbytes = sum(a.nbytes for a in one.values()) + _fake_logits().nbytes
    sc = StateCache(3 * nbytes)
    prompts = [np.arange(i, i + 10, dtype=np.int32) * 7 for i in range(4)]
    for p in prompts[:3]:
        assert sc.insert(p, 10, _fake_state(), _fake_logits()) is not None
    assert len(sc) == 3 and sc.nbytes == 3 * nbytes
    sc.lookup(prompts[0])            # refresh 0 → 1 is now LRU
    sc.insert(prompts[3], 10, _fake_state(), _fake_logits())
    assert len(sc) == 3 and sc.evictions == 1
    assert sc.lookup(prompts[1]) is None      # the LRU entry was evicted
    assert sc.lookup(prompts[0]) is not None  # the refreshed one survived
    # an entry bigger than the whole budget is refused, not thrashed
    tiny = StateCache(nbytes - 1)
    assert tiny.insert(prompts[0], 10, _fake_state(),
                       _fake_logits()) is None
    assert len(tiny) == 0 and tiny.evictions == 0


def test_generation_tracks_content_changes():
    sc = StateCache(1 << 20)
    g0 = sc.generation
    p = np.arange(1, 20, dtype=np.int32)
    sc.insert(p, 19, _fake_state(), _fake_logits())
    assert sc.generation != g0          # insert invalidates memoized misses
    g1 = sc.generation
    sc.lookup(p)                        # a pure lookup does not
    assert sc.generation == g1
    sc.clear()
    assert sc.generation != g1 and len(sc) == 0
    assert sc.evictions == 1            # clear() counts as eviction


def test_row_views_round_trip():
    """state_row / cache_row produce the documented single-row layout."""
    states = {"units": np.arange(2 * 3 * 2 * 4, dtype=np.float32)
              .reshape(2, 3, 2, 4), "tail": np.arange(3 * 2 * 5,
              dtype=np.float32).reshape(3, 2, 5)}     # (B=3, S=2, …)
    row = state_row(states, 1, 0)
    assert row["units"].shape == (2, 1, 4)
    assert row["tail"].shape == (1, 5)
    np.testing.assert_array_equal(np.asarray(row["tail"][0]),
                                  states["tail"][1, 0])
    cache = {"units": np.arange(2 * 3 * 4, dtype=np.float32)
             .reshape(2, 3, 4), "tail": np.arange(3 * 5, dtype=np.float32)
             .reshape(3, 5)}                          # (B=3, …)
    cr = cache_row(cache, 2)
    assert cr["units"].shape == (2, 1, 4)
    assert cr["tail"].shape == (1, 5)
    np.testing.assert_array_equal(np.asarray(cr["tail"][0]),
                                  cache["tail"][2])


# ------------------------------------------------------------- engine level

KW = dict(num_slots=4, max_len=96, prefill_rows=2, buckets=(16, 32),
          max_segments=2)


def _shared_prompts(rng, vocab, n=5, prefix=20, tail=5):
    shared = rng.integers(1, vocab, size=prefix)
    return [np.concatenate([shared,
                            rng.integers(1, vocab, size=tail)]).astype(
                np.int32) for _ in range(n)]


def test_warm_hit_bit_identical_and_cheaper(tiny_engine_model, rng):
    """Declared-prefix workload: the first request captures the prefix
    state, everyone behind restores it and prefills only the suffix; a
    full rerun is all whole-prompt hits with ZERO forwards. Streams match
    the cache-off engine bit for bit."""
    cfg, model, params = tiny_engine_model
    prompts = _shared_prompts(rng, cfg.vocab)
    cold = ServeEngine(model, params, **KW)
    for p in prompts:
        cold.submit(p, 6)
    ref = cold.run()

    sc = StateCache(32 << 20)
    warm = ServeEngine(model, params, state_cache=sc, **KW)
    for p in prompts:
        warm.submit(p, 6, prefix_len=20)
    assert warm.run() == ref
    assert sc.hits >= len(prompts) - 1       # everyone behind the first
    # suffix rounds consume ≤ tail tokens each once the prefix is cached
    assert warm.stats.chunk_tokens < sum(len(p) for p in prompts)

    rerun = ServeEngine(model, params, state_cache=sc, **KW)
    for p in prompts:
        rerun.submit(p, 6, prefix_len=20)
    assert rerun.run() == ref
    assert rerun.stats.prefills == 0 and rerun.stats.chunk_rounds == 0


def test_undeclared_full_prompt_hits(tiny_engine_model, rng):
    """No prefix_len declared: a landed prompt is itself a cached prefix,
    so resubmitting the same prompts is served entirely from the cache."""
    cfg, model, params = tiny_engine_model
    prompts = [rng.integers(1, cfg.vocab, size=9).astype(np.int32)
               for _ in range(4)]
    sc = StateCache(32 << 20)
    e1 = ServeEngine(model, params, state_cache=sc, **KW)
    for p in prompts:
        e1.submit(p, 6)
    ref = e1.run()
    assert sc.inserts == len(prompts)
    e2 = ServeEngine(model, params, state_cache=sc, **KW)
    for p in prompts:
        e2.submit(p, 6)
    assert e2.run() == ref
    assert e2.stats.prefills == 0 and e2.stats.chunk_rounds == 0


def test_hit_after_restore(tiny_engine_model, rng, tmp_path):
    """The StateCache lives on the host, OUTSIDE the engine's device
    state: after a snapshot → fresh-engine restore() the same cache keeps
    hitting — crash recovery does not cold-start the prefix cache."""
    cfg, model, params = tiny_engine_model
    prompts = _shared_prompts(rng, cfg.vocab, n=3)
    cold = ServeEngine(model, params, **KW)
    for p in prompts:
        cold.submit(p, 5)
    ref = cold.run()

    sc = StateCache(32 << 20)
    e1 = ServeEngine(model, params, state_cache=sc, **KW)
    for p in prompts:
        e1.submit(p, 5, prefix_len=20)
    assert e1.run() == ref
    mgr = CheckpointManager(str(tmp_path / "ckpt"), async_save=False)
    e1.snapshot(mgr, blocking=True)

    e2 = ServeEngine(model, params, state_cache=sc, **KW)
    e2.restore(mgr)
    hits0 = sc.hits
    for i, p in enumerate(prompts):
        e2.submit(p, 5, prefix_len=20, rid=100 + i)
    outs = e2.run()
    assert [outs[100 + i] for i in range(3)] == [ref[i] for i in range(3)]
    assert sc.hits > hits0
    assert e2.stats.prefills == 0 and e2.stats.chunk_rounds == 0


def test_poisoned_cached_state_quarantined(tiny_engine_model, rng):
    """A corrupted stored state must be quarantined by the guard rails —
    failed with a diagnostic, never streamed from — while every healthy
    request's stream stays bit-identical to the cold run."""
    cfg, model, params = tiny_engine_model
    prompts = _shared_prompts(rng, cfg.vocab, n=4)
    cold = ServeEngine(model, params, **KW)
    for p in prompts:
        cold.submit(p, 5)
    ref = cold.run()

    sc = StateCache(32 << 20)
    plan = FaultPlan(poison_cache_hit=[0])
    eng = ServeEngine(model, params, state_cache=sc, faults=plan, **KW)
    assert eng.guard                   # poison auto-enables the guard
    for p in prompts:
        eng.submit(p, 5, prefix_len=20)
    outs = eng.run()
    assert eng.stats.quarantined == 1
    failed = [r for r in outs if eng.status[r] == "failed"]
    assert len(failed) == 1
    assert "quarantined" in eng.errors[failed[0]]
    for r in outs:
        if eng.status[r] == "done":
            assert outs[r] == ref[r]


def test_drop_cache_falls_back_cold(tiny_engine_model, rng):
    """The forced-evict seam: clearing the cache under a would-be hit
    turns it into a cold chunked prefill with an identical stream."""
    cfg, model, params = tiny_engine_model
    prompts = _shared_prompts(rng, cfg.vocab, n=4)
    cold = ServeEngine(model, params, **KW)
    for p in prompts:
        cold.submit(p, 5)
    ref = cold.run()

    sc = StateCache(32 << 20)
    eng = ServeEngine(model, params, state_cache=sc,
                      faults=FaultPlan(drop_cache=1), **KW)
    for p in prompts:
        eng.submit(p, 5, prefix_len=20)
    assert eng.run() == ref
    assert sc.evictions >= 1


def test_spec_decode_bit_identical_with_metrics(tiny_engine_model, rng):
    """Speculative decode emits exactly the greedy stream (the verify IS
    the greedy step, scanned), and the spec.* metrics are observable."""
    cfg, model, params = tiny_engine_model
    prompts = [rng.integers(1, cfg.vocab, size=8).astype(np.int32)
               for _ in range(3)]
    plain = ServeEngine(model, params, **KW)
    for p in prompts:
        plain.submit(p, 24)
    ref = plain.run()

    spec = ServeEngine(model, params, spec_k=4, **KW)
    for p in prompts:
        spec.submit(p, 24)
    assert spec.run() == ref
    assert spec._spec_rounds.value > 0
    assert spec._spec_proposed.value > 0
    assert 0.0 <= spec.spec_accept_rate <= 1.0
    reg = spec.obs.metrics
    assert reg.counter("spec.rounds").value == spec._spec_rounds.value
    # a verify round advances every active slot ≥ 1 token, so total steps
    # can never exceed the plain engine's (and fewer means accepts landed)
    assert spec.stats.decode_steps <= plain.stats.decode_steps


def test_spec_respects_eos_and_budget(tiny_engine_model, rng):
    """A draft token beyond EOS or the slot budget must not be committed
    even when the verify accepted it."""
    cfg, model, params = tiny_engine_model
    prompt = rng.integers(1, cfg.vocab, size=8).astype(np.int32)
    ref = _reference_decode(model, params, prompt, 16, KW["max_len"])
    eos = ref[4]                       # force an early EOS mid-stream
    want = ref[:ref.index(eos) + 1]
    for k in (2, 5):
        eng = ServeEngine(model, params, spec_k=k, **KW)
        rid = eng.submit(prompt, 16, eos=int(eos))
        assert eng.run()[rid] == want, f"spec_k={k}"


CACHE_CASES = [("stablelm-1.6b", None, 8),
               ("stablelm-1.6b", {"attn_window": 5}, 4),
               ("mamba-110m", None, 8), ("mamba2-370m", None, 8),
               ("recurrentgemma-2b", None, 8), ("xlstm-125m", None, 8)]


@pytest.mark.slow
@pytest.mark.parametrize("arch,mod,chunk", CACHE_CASES)
def test_cache_hit_bit_identical_per_block_kind(arch, mod, chunk, rng):
    """TENTPOLE acceptance: for EVERY cached block kind, a warm cache-hit
    stream (prefix restored + suffix prefilled, then whole-prompt hits)
    and a speculative stream are bit-identical to the cold greedy
    reference."""
    cfg = get_config(arch).reduced()
    if mod:
        cfg = dataclasses.replace(cfg, **mod)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shared = rng.integers(1, cfg.vocab, size=11).astype(np.int32)
    prompts = [np.concatenate([shared, rng.integers(1, cfg.vocab, size=4)])
               .astype(np.int32) for _ in range(2)]
    refs = [_reference_decode(model, params, p, 4, 64) for p in prompts]

    sc = StateCache(64 << 20)
    warm = ServeEngine(model, params, num_slots=2, max_len=64,
                       prefill_rows=1, buckets=(8,), max_segments=1,
                       chunk_size=chunk, state_cache=sc)
    rids = [warm.submit(p, 4, prefix_len=11) for p in prompts]
    outs = warm.run()
    assert [outs[r] for r in rids] == refs, arch
    assert sc.hits >= 1                        # the second request hit

    rerun = ServeEngine(model, params, num_slots=2, max_len=64,
                        prefill_rows=1, buckets=(8,), max_segments=1,
                        chunk_size=chunk, state_cache=sc)
    rids = [rerun.submit(p, 4, prefix_len=11) for p in prompts]
    outs = rerun.run()
    assert [outs[r] for r in rids] == refs, arch
    assert rerun.stats.prefills == 0 and rerun.stats.chunk_rounds == 0

    spec = ServeEngine(model, params, num_slots=2, max_len=64,
                       prefill_rows=1, buckets=(8,), max_segments=1,
                       chunk_size=chunk, spec_k=3)
    rids = [spec.submit(p, 4) for p in prompts]
    outs = spec.run()
    assert [outs[r] for r in rids] == refs, arch
