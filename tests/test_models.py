"""Per-architecture smoke tests (assignment requirement): reduced config per
family, one forward/train step on CPU, output shapes + no NaNs; decode parity
for every stateful family."""
import dataclasses
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import all_names
from repro.configs.base import get_config
from repro.models.lm import build_model
from repro.optim.adamw import AdamW, constant_schedule
from repro.train.trainer import make_train_step

ARCHS = ["recurrentgemma-2b", "stablelm-1.6b", "deepseek-coder-33b",
         "gemma-7b", "deepseek-67b", "hubert-xlarge", "mixtral-8x22b",
         "moonshot-v1-16b-a3b", "qwen2-vl-2b", "xlstm-125m",
         "mamba-110m", "mamba-1.4b", "mamba-2.8b", "mamba2-370m"]


def _batch(rng, cfg, B=2, L=32):
    pos = np.tile(np.concatenate([np.arange(20), np.arange(12)]), (B, 1))
    seg = np.tile(np.concatenate([np.full(20, 1), np.full(12, 2)]), (B, 1))
    batch = {"tokens": jnp.asarray(
        rng.integers(1, cfg.vocab, (B, L)), jnp.int32),
        "positions": jnp.asarray(pos, jnp.int32),
        "segment_ids": jnp.asarray(seg, jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, L, cfg.d_model)), jnp.float32)
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, L)), jnp.int32)
    if cfg.family == "vlm":
        batch["mrope_positions"] = jnp.asarray(
            np.repeat(pos[..., None], 3, axis=-1), jnp.int32)
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(B, 4, cfg.d_model)), jnp.float32)
        batch["vision_positions"] = jnp.asarray(
            rng.integers(0, L, (B, 4)), jnp.int32)
    return batch


def test_registry_complete():
    assert set(ARCHS) <= set(all_names())


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(rng, cfg)
    logits = model.forward(params, batch)
    assert logits.shape == (2, 32, cfg.vocab)
    assert not bool(jnp.isnan(logits).any()), "NaN in logits"
    # one full train step (fwd+bwd+AdamW)
    opt = AdamW(constant_schedule(1e-3))
    step = jax.jit(make_train_step(model, opt))
    state = {"params": params, "opt": opt.init(params)}
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    diff = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                        state["params"], params)
    assert max(jax.tree.leaves(diff)) > 0


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "mamba-110m",
                                  "mamba2-370m",
                                  "recurrentgemma-2b", "xlstm-125m",
                                  "mixtral-8x22b", "qwen2-vl-2b"])
def test_decode_matches_forward(arch, rng):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    T = 12
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (1, T)), jnp.int32)
    batch = {"tokens": toks, "positions": jnp.arange(T)[None],
             "segment_ids": jnp.ones((1, T), jnp.int32)}
    if cfg.family == "vlm":
        batch["mrope_positions"] = jnp.repeat(
            jnp.arange(T)[None, :, None], 3, axis=-1)
    full = model.forward(params, batch)
    cache = model.init_cache(1, 16)
    step = jax.jit(model.decode_step)
    errs = []
    for t in range(T):
        kw = {}
        if cfg.mrope_sections is not None:
            kw["mrope_positions"] = jnp.full((1, 1, 3), t, jnp.int32)
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1],
                                      jnp.asarray([t]), **kw)
        errs.append(float(jnp.abs(lg[0] - full[0, t]).max()))
    assert max(errs) < 2e-2, f"{arch}: {errs}"


def test_decode_reset_isolates_sequences(rng):
    """Serving a second sequence after a reset matches a fresh cache — the
    decode-path analogue of PUI."""
    cfg = get_config("mamba-110m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    s1 = jnp.asarray(rng.integers(1, cfg.vocab, (1, 5)), jnp.int32)
    s2 = jnp.asarray(rng.integers(1, cfg.vocab, (1, 4)), jnp.int32)
    # run s1 then reset then s2 in one cache
    cache = model.init_cache(1, 16)
    for t in range(5):
        _, cache = model.decode_step(params, cache, s1[:, t:t + 1],
                                     jnp.asarray([t]))
    out_joint = []
    for t in range(4):
        lg, cache = model.decode_step(
            params, cache, s2[:, t:t + 1], jnp.asarray([t]),
            reset=jnp.asarray([t == 0]))
        out_joint.append(lg)
    # fresh cache for s2 alone
    cache2 = model.init_cache(1, 16)
    out_fresh = []
    for t in range(4):
        lg, cache2 = model.decode_step(params, cache2, s2[:, t:t + 1],
                                       jnp.asarray([t]))
        out_fresh.append(lg)
    for a, b in zip(out_joint, out_fresh):
        np.testing.assert_allclose(a, b, atol=1e-4)


def test_prefill_logits_matches_forward(rng):
    cfg = get_config("stablelm-1.6b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, L, n = 2, 16, 11
    toks = jnp.asarray(rng.integers(1, cfg.vocab, (B, L)), jnp.int32)
    seg = jnp.asarray((np.arange(L) < n)[None].repeat(B, 0).astype(np.int32))
    pos = jnp.asarray((np.arange(L) * (np.arange(L) < n))[None]
                      .repeat(B, 0).astype(np.int32))
    batch = {"tokens": toks, "positions": pos, "segment_ids": seg}
    pl = model.prefill_logits(params, batch)
    full = model.forward(params, batch)
    np.testing.assert_allclose(pl, full[:, n - 1], atol=1e-4)


def test_tied_embeddings_shape():
    cfg = get_config("gemma-7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    assert "head" not in params
    assert params["embed"].shape == (cfg.vocab, cfg.d_model)


def test_pattern_units():
    cfg = get_config("recurrentgemma-2b")
    from repro.models.lm import unit_layout
    names = [k for k, _ in unit_layout(cfg)]
    assert names == ["0_rec", "0_ffn", "1_rec", "1_ffn", "2_attn", "2_ffn"]
    model = build_model(cfg)
    assert model.n_units == 8 and model.n_tail == 2   # 26 = 8×3 + 2
    cfg2 = get_config("xlstm-125m")
    model2 = build_model(cfg2)
    assert model2.n_units == 2 and model2.n_tail == 0
