"""Unified telemetry subsystem (repro/obs): metrics registry, span tracer,
Chrome trace export, and the instrumentation contract.

The load-bearing claims:
  * ``percentiles()`` is the repo's ONE percentile implementation and
    matches ``np.percentile(..., "linear")`` on every degenerate case
    (empty, single sample, duplicates, weighted multisets);
  * the registry is thread-safe — concurrent increments never lose counts
    (a bare ``+=`` on a Python int would);
  * spans nest correctly under a scripted clock and the exported JSON
    satisfies the Chrome trace-event schema ``obs.check`` enforces (the
    same validator ``make obs-smoke`` runs on real launcher traces);
  * the OFF state is free of observable effect: a serve engine with
    ``Obs.off()`` emits token streams bit-identical to one with
    ``Obs.on()`` — tracing may never perturb scheduling or sampling;
  * ``ServeStats`` is a thin view over the registry (one source of
    numbers), and the Trainer/PrefetchLoader meter through it.
"""
import json
import threading

import numpy as np
import pytest

from repro.obs import (NULL_TRACER, Counter, Gauge, Histogram,
                       MetricsRegistry, NullTracer, Obs, Tracer,
                       percentiles)
from repro.obs.check import check_trace


# ---------------------------------------------------------------- percentiles

def test_percentiles_empty_and_single():
    assert percentiles([]) == {}
    out = percentiles([42.0], (50, 95, 99))
    assert out == {"p50": 42.0, "p95": 42.0, "p99": 42.0}


def test_percentiles_matches_numpy_linear(rng):
    for n in (2, 3, 7, 100):
        vals = rng.normal(size=n)
        got = percentiles(vals, (0, 10, 50, 90, 95, 100))
        for p in (0, 10, 50, 90, 95, 100):
            np.testing.assert_allclose(got[f"p{p:g}"],
                                       np.percentile(vals, p), rtol=1e-12)


def test_percentiles_duplicates_match_numpy():
    vals = [3.0, 1.0, 3.0, 3.0, 2.0, 1.0]
    got = percentiles(vals, (25, 50, 75))
    for p in (25, 50, 75):
        np.testing.assert_allclose(got[f"p{p:g}"], np.percentile(vals, p))


def test_percentiles_weighted_equals_expanded_multiset():
    vals = [1.0, 5.0, 10.0]
    weights = [3, 1, 2]
    expanded = [1.0, 1.0, 1.0, 5.0, 10.0, 10.0]
    got = percentiles(vals, (50, 90, 95), weights=weights)
    for p in (50, 90, 95):
        np.testing.assert_allclose(got[f"p{p:g}"],
                                   np.percentile(expanded, p), rtol=1e-12)


def test_percentiles_zero_weights_and_validation():
    assert percentiles([1.0, 2.0], weights=[0, 0]) == {}
    with pytest.raises(ValueError):
        percentiles([1.0, 2.0], weights=[1.0])       # shape mismatch
    with pytest.raises(ValueError):
        percentiles([1.0, 2.0], weights=[1.0, -1.0])


# ------------------------------------------------------------------- registry

def test_registry_idempotent_and_kind_checked():
    m = MetricsRegistry()
    c = m.counter("a.x", help="first")
    assert m.counter("a.x") is c                     # idempotent handle
    with pytest.raises(ValueError, match="already registered"):
        m.gauge("a.x")
    assert m.names() == ["a.x"]


def test_registry_concurrent_increments_lose_nothing():
    m = MetricsRegistry()
    c = m.counter("hot")
    g = m.gauge("warm")
    n_threads, n_inc = 8, 2000

    def work():
        for _ in range(n_inc):
            c.inc()
            g.add(2)

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == n_threads * n_inc
    assert g.value == 2 * n_threads * n_inc


def test_gauge_max_of_and_counter_set():
    g = Gauge("g")
    g.max_of(5)
    g.max_of(3)
    assert g.value == 5
    c = Counter("c")
    c.inc(7)
    c.set(0)
    assert c.value == 0


def test_histogram_summary_routes_through_percentiles():
    h = Histogram("h", buckets=(1, 2, 5, 10))
    assert h.summary() == {}                         # no observations
    for v in (0.5, 1.5, 1.5, 4.0, 20.0):             # 20 -> +inf tail
        h.observe(v)
    s = h.summary((50, 95))
    assert s["count"] == 5
    np.testing.assert_allclose(s["mean"], (0.5 + 1.5 + 1.5 + 4 + 20) / 5)
    # bucket upper bounds weighted by counts, tail reported at last bound
    expect = percentiles([1, 2, 5, 10, 10], (50, 95),
                         weights=[1, 2, 1, 0, 1])
    assert s["p50"] == expect["p50"] and s["p95"] == expect["p95"]
    with pytest.raises(ValueError):
        Histogram("bad", buckets=(5, 1))             # not ascending


def test_prometheus_text_exposition():
    m = MetricsRegistry()
    m.counter("serve.shed", help="requests shed").inc(3)
    h = m.histogram("serve.ttft_ms", (10, 100))
    h.observe(5)
    h.observe(500)
    txt = m.prometheus_text()
    assert "# TYPE serve_shed counter" in txt
    assert "serve_shed 3" in txt
    assert 'serve_ttft_ms_bucket{le="10"} 1' in txt
    assert 'serve_ttft_ms_bucket{le="+Inf"} 2' in txt
    assert "serve_ttft_ms_count 2" in txt


# --------------------------------------------------------------------- tracer

def _scripted_clock(start=100.0, step=0.25):
    t = {"now": start}

    def clock():
        t["now"] += step
        return t["now"]

    return clock


def test_tracer_nesting_under_scripted_clock():
    tr = Tracer(clock=_scripted_clock())
    a = tr.start("outer", track="t", k=1)
    b = tr.start("inner", track="t")
    tr.finish(b)
    tr.finish(a, done=True)
    tr.instant("mark", track="t")
    evs = [e for e in tr.chrome_events() if e["ph"] != "M"]
    assert [(e["ph"], e["name"]) for e in evs] == [
        ("B", "outer"), ("B", "inner"), ("E", "inner"), ("E", "outer"),
        ("i", "mark")]
    # scripted clock: timestamps strictly increase, args ride along
    ts = [e["ts"] for e in evs]
    assert ts == sorted(ts) and len(set(ts)) == len(ts)
    assert evs[0]["args"] == {"k": 1}
    assert evs[3]["args"] == {"done": True}
    assert evs[4]["s"] == "t"                        # thread-scoped instant


def test_tracer_finish_is_tolerant_and_clamped():
    tr = Tracer(clock=_scripted_clock())
    tr.finish(None)                                  # no-op, never raises
    tr.finish(12345)                                 # unknown id ignored
    assert [e for e in tr.chrome_events() if e["ph"] != "M"] == []
    tr.complete("back", t0=2.0, t1=1.0, track="t")   # end clamps to start
    b, e = [ev for ev in tr.chrome_events() if ev["ph"] in "BE"]
    assert e["ts"] >= b["ts"]


def test_tracer_span_ctx_and_tracks():
    tr = Tracer(clock=_scripted_clock())
    with tr.span("a", track="x"):
        with tr.span("b", track="y"):                # other track: no nest
            pass
    evs = tr.chrome_events()
    tids = {e["name"]: e["tid"] for e in evs if e["ph"] == "B"}
    assert tids["a"] != tids["b"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert {m["args"]["name"] for m in meta} == {"x", "y"}


def test_tracer_bounded_events():
    tr = Tracer(clock=_scripted_clock(), max_events=3)
    for i in range(5):
        tr.instant(f"e{i}")
    assert len(tr.chrome_events()) == 3              # incl. track metadata
    assert tr.dropped == 3
    assert tr.to_chrome()["otherData"]["dropped_events"] == 3


def test_chrome_export_schema_via_checker(tmp_path):
    obs = Obs.on(clock=_scripted_clock())
    with obs.tracer.span("serve.step", track="engine"):
        with obs.tracer.span("decode_step", track="engine", step=0):
            obs.metrics.counter("serve.decode_steps").inc()
    obs.tracer.instant("shed", track="engine")
    path = tmp_path / "trace.json"
    obs.export(str(path))
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    assert doc["metrics"]["serve.decode_steps"] == 1
    assert check_trace(str(path), require=["serve.decode_steps"]) == []
    # the checker flags real damage: drop an E and it reports imbalance
    doc["traceEvents"] = [e for e in doc["traceEvents"]
                          if not (e["ph"] == "E"
                                  and e["name"] == "decode_step")]
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(doc))
    errs = check_trace(str(bad))
    assert any("unclosed" in e or "unbalanced" in e for e in errs)


def test_checker_rejects_misnested_spans(tmp_path):
    evs = [{"ph": "B", "name": "a", "ts": 0, "pid": 1, "tid": 0},
           {"ph": "B", "name": "b", "ts": 1, "pid": 1, "tid": 0},
           {"ph": "E", "name": "a", "ts": 2, "pid": 1, "tid": 0},
           {"ph": "E", "name": "b", "ts": 3, "pid": 1, "tid": 0}]
    p = tmp_path / "cross.json"
    p.write_text(json.dumps({"traceEvents": evs}))
    assert any("innermost" in e for e in check_trace(str(p)))


def test_timeline_text_view():
    tr = Tracer(clock=_scripted_clock())
    with tr.span("outer", track="t"):
        with tr.span("inner", track="t"):
            pass
    txt = tr.timeline("t")
    assert "-- t" in txt and "outer" in txt and "/inner" in txt
    # inner is indented one level deeper than outer
    outer_line = next(ln for ln in txt.splitlines() if ln.endswith("outer"))
    inner_line = next(ln for ln in txt.splitlines() if ln.endswith("inner"))
    assert inner_line.index("inner") > outer_line.index("outer")


def test_null_tracer_is_inert():
    nt = NULL_TRACER
    assert isinstance(nt, NullTracer) and not nt.enabled
    assert nt.start("x") is None
    nt.finish(None)
    nt.complete("x", 0, 1)
    nt.instant("x")
    nt.sync(object())                                # no jax sync attempted
    with nt.span("x"):
        pass
    assert nt.chrome_events() == []
    assert nt.timeline() == "(tracing disabled)"
    with pytest.raises(RuntimeError):
        nt.export("/dev/null")


def test_obs_bundle_on_off():
    off = Obs.off()
    assert not off.enabled and off.tracer is NULL_TRACER
    on = Obs.on(clock=_scripted_clock())
    assert on.enabled and isinstance(on.tracer, Tracer)
    # one clock drives both the registry stamp and the span timestamps
    assert on.metrics.clock is on.tracer.clock


# ------------------------------------------------- instrumentation contracts

def test_serve_stats_is_registry_view():
    from repro.launch.serve import ServeStats
    m = MetricsRegistry()
    st = ServeStats(m)
    st.shed += 1
    st.prefills += 2
    st.queue_depth_max = max(st.queue_depth_max, 7)
    st.ttft_ms.append(12.0)
    assert m.counter("serve.shed").value == 1
    assert m.counter("serve.prefills").value == 2
    assert m.gauge("serve.queue_depth_max").value == 7
    assert m.histogram("serve.ttft_ms", (1,)).count == 1
    assert st.ttft_percentiles() == {"p50": 12.0, "p95": 12.0}
    fresh = ServeStats()                             # standalone registry
    assert fresh.shed == 0 and fresh.ttft_percentiles() == {}


@pytest.mark.slow
def test_serve_disabled_obs_token_streams_bit_identical(rng):
    """Tracing may never perturb the engine: the same engine config run
    with Obs.off(), Obs.on(), and no obs argument at all produces
    bit-identical per-request token streams."""
    from repro.configs.base import get_config
    from repro.launch.serve import ServeEngine
    from repro.models.lm import build_model

    cfg = get_config("mamba-110m").reduced()
    model = build_model(cfg)
    params = model.init(__import__("jax").random.PRNGKey(0))
    prompts = [rng.integers(1, cfg.vocab, size=int(n)).astype(np.int32)
               for n in rng.integers(4, 24, size=6)]
    budgets = [int(b) for b in rng.integers(3, 8, size=6)]
    kw = dict(num_slots=3, max_len=64, buckets=(16, 32), max_segments=2,
              overlap=True)

    def run(obs):
        eng = ServeEngine(model, params, **kw) if obs is None else \
            ServeEngine(model, params, obs=obs, **kw)
        rids = [eng.submit(p, b) for p, b in zip(prompts, budgets)]
        eng.run()
        return [eng.outputs[r] for r in rids], eng

    base, _ = run(None)
    off, eng_off = run(Obs.off())
    on, eng_on = run(Obs.on())
    assert base == off == on
    # and the traced engine actually recorded the lifecycle
    evs = eng_on.obs.tracer.chrome_events()
    names = {e["name"] for e in evs if e["ph"] == "B"}
    assert {"queued", "prefill", "decode", "serve.step"} <= names
    assert any(e["name"] == "first_token" for e in evs if e["ph"] == "i")
    assert eng_off.obs.tracer.chrome_events() == []


def test_trainer_metering_through_registry():
    from repro.data.dataset import SyntheticCorpus, CorpusConfig
    from repro.data.packing_loader import PackingLoader, LoaderConfig
    from repro.models.lm import build_model
    from repro.optim.adamw import AdamW, constant_schedule
    from repro.train.trainer import Trainer, TrainerConfig
    from repro.configs.base import get_config
    import jax

    cfg = get_config("mamba-110m").reduced()
    model = build_model(cfg)
    corpus = SyntheticCorpus(CorpusConfig(vocab=cfg.vocab, seed=0,
                                          len_min=4, len_max=48,
                                          mu=2.6, sigma=0.4))
    loader = PackingLoader(corpus, LoaderConfig(rows=2, seq_len=64,
                                                mode="pack"))
    obs = Obs.on()
    tr = Trainer(model, AdamW(constant_schedule(1e-3)), loader,
                 TrainerConfig(steps=3, log_every=10), obs=obs)
    _, hist = tr.train(jax.random.PRNGKey(0), verbose=False)
    assert len(hist) == 3
    m = obs.metrics
    assert m.counter("train.steps").value == 3
    assert m.counter("train.real_tokens").value == \
        sum(int(r["real_tokens"]) for r in hist)
    assert m.counter("train.compiles").value == 1    # one batch shape
    # per-step spans landed on the train track with the compile mark
    spans = [e for e in obs.tracer.chrome_events()
             if e["ph"] == "B" and e["name"] == "train.step"]
    assert len(spans) == 3
    assert spans[0]["args"]["compile"] is True
    assert spans[1]["args"]["compile"] is False
