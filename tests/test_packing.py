"""Packing: pack/unpack inverse, policies, paper §5 padding rates."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # tier-1 env has no hypothesis: fixed-seed fallback
    from _hypothesis_fallback import given, settings, st

from repro.core.packing import (pack, unpack, pad_to_max, plan_packing,
                                padding_rate, pack_with_split)
from repro.data.dataset import SyntheticCorpus, CorpusConfig


@given(st.lists(st.integers(1, 50), min_size=1, max_size=30),
       st.sampled_from(["sequential", "first_fit", "sorted_greedy",
                        "first_fit_decreasing"]))
@settings(max_examples=50, deadline=None)
def test_pack_unpack_roundtrip(lens, policy):
    rng = np.random.default_rng(0)
    seqs = [rng.integers(1, 1000, size=n).astype(np.int32) for n in lens]
    pb = pack(seqs, capacity=64, policy=policy)
    rec = unpack(pb.tokens, pb)
    assert len(rec) == len(seqs)
    for a, b in zip(rec, seqs):
        np.testing.assert_array_equal(a, b)


@given(st.lists(st.integers(1, 50), min_size=1, max_size=30))
@settings(max_examples=30, deadline=None)
def test_position_and_segment_invariants(lens):
    rng = np.random.default_rng(1)
    seqs = [rng.integers(1, 1000, size=n).astype(np.int32) for n in lens]
    pb = pack(seqs, capacity=64)
    pos = np.asarray(pb.positions)
    seg = np.asarray(pb.segment_ids)
    # padding has seg == 0 and pos == 0
    assert (pos[seg == 0] == 0).all()
    # each segment's positions are 0..n-1 in order
    for r in range(seg.shape[0]):
        for s in np.unique(seg[r]):
            if s == 0:
                continue
            p = pos[r][seg[r] == s]
            np.testing.assert_array_equal(p, np.arange(len(p)))
    # position 0 marks starts: count equals number of sequences
    assert int(((pos == 0) & (seg > 0)).sum()) == len(seqs)


def test_too_long_sequence_raises():
    with pytest.raises(ValueError):
        pack([np.ones(100, np.int32)], capacity=64)


def test_padding_rates_paper_discussion():
    """Paper §5: sequential ≈19.1% padding on InternLM lengths; sorted local
    greedy ≈0.41%. Our synthetic corpus matches the paper's length stats
    (57–2048, mean≈646); check same ordering and ballpark."""
    corpus = SyntheticCorpus(CorpusConfig(seed=3))
    lens = np.concatenate([corpus.lengths(s, 256) for s in range(8)]).tolist()
    seq_rate = padding_rate(lens, 4096, "sequential")
    sort_rate = padding_rate(lens, 4096, "sorted_greedy")
    ff_rate = padding_rate(lens, 4096, "first_fit")
    assert 0.05 < seq_rate < 0.30          # paper: 19.1%
    assert sort_rate < 0.02                # paper: 0.41%
    assert sort_rate < ff_rate <= seq_rate + 1e-9
    # pad-to-max baseline is far worse (paper: 66.3%)
    pad_rate = 1 - np.mean(lens) / 2048
    assert pad_rate > 0.5


def test_pack_with_split_zero_padding():
    rng = np.random.default_rng(2)
    seqs = [rng.integers(1, 100, size=n).astype(np.int32)
            for n in [10, 20, 30, 15]]
    sb = pack_with_split(seqs, capacity=16)
    # all but the final partial row have zero padding
    seg = np.asarray(sb.segment_ids)
    assert (seg[:-1] > 0).all()
    rec = unpack(sb.tokens, sb)
    whole = np.concatenate(seqs)
    got = np.concatenate([np.concatenate([p for p in rec])])
    # every token appears exactly once in order
    np.testing.assert_array_equal(
        np.concatenate(rec)[:whole.size], whole)
    # carry mask marks rows whose first token is mid-sequence
    pos = np.asarray(sb.positions)
    np.testing.assert_array_equal(np.asarray(sb.carry_mask),
                                  (pos[:, 0] > 0) & (seg[:, 0] > 0))


def test_plan_packing_capacity_respected():
    lens = [30, 40, 10, 64, 1, 63]
    for policy in ("sequential", "first_fit", "sorted_greedy",
                   "first_fit_decreasing"):
        plan = plan_packing(lens, 64, policy)
        for row in plan:
            assert sum(lens[i] for i in row) <= 64
        assert sorted(i for row in plan for i in row) == list(range(len(lens)))


@given(st.lists(st.integers(1, 64), min_size=1, max_size=40))
@settings(max_examples=40, deadline=None)
def test_first_fit_decreasing_never_more_rows(lens):
    """FFD (classic ≤ 11/9·OPT + 1 bound) never uses more rows — so never
    more padding — than arrival-order sequential packing."""
    ffd = plan_packing(lens, 64, "first_fit_decreasing")
    seq = plan_packing(lens, 64, "sequential")
    assert len(ffd) <= len(seq)
    # every sequence placed exactly once, capacity respected
    assert sorted(i for row in ffd for i in row) == list(range(len(lens)))
    for row in ffd:
        assert sum(lens[i] for i in row) <= 64


def test_first_fit_decreasing_padding_rate_improves():
    """On the paper's length distribution FFD lands near sorted_greedy,
    far below sequential."""
    corpus = SyntheticCorpus(CorpusConfig(seed=3))
    lens = np.concatenate([corpus.lengths(s, 256) for s in range(4)]).tolist()
    ffd_rate = padding_rate(lens, 4096, "first_fit_decreasing")
    seq_rate = padding_rate(lens, 4096, "sequential")
    assert ffd_rate < seq_rate
    assert ffd_rate < 0.02                 # near-optimal on lognormal draws


def test_pad_to_max_matches_paper_baseline():
    seqs = [np.arange(1, 5, dtype=np.int32), np.arange(1, 3, dtype=np.int32)]
    pb = pad_to_max(seqs, 8)
    assert pb.tokens.shape == (2, 8)
    assert pb.padding_rate() == pytest.approx(1 - 6 / 16)
