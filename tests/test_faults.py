"""Fault-tolerant serving: deterministic fault injection end to end.

Every failure mode the ISSUE names is exercised on CPU against a
fault-free reference run of the SAME engine configuration:

* prefill dispatch failure (mid-overlap): the round's requests fail with
  an explicit status, every other request's token stream is bit-identical
  to the reference;
* NaN/Inf poisoning (decode logits and harvested prefill states): the
  poisoned slot is quarantined, healthy slots bit-identical;
* chunked-prefill seams (scheduler v2): a failed slab round kills the
  chunk-lane request explicitly, a poisoned carried state is quarantined
  at handoff — decode slots never notice either;
* deadlines vs a scripted clock (queued, and mid-decode with tokens kept);
* overload shedding (queue depth and head-of-line age bounds);
* cancellation in every lifecycle stage;
* kill-at-step-K → ``snapshot()``/``restore()`` → token equality with an
  uninterrupted run (slow lane);
* a seeded chaos run (``FAULT_CHAOS_SEED``, the ``make verify-faults``
  lane): randomized plan, every request must terminate explicitly —
  no hangs, no silent garbage.
"""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.checkpoint.checkpoint import CheckpointManager
from repro.faults import (EngineKilled, FaultPlan, poison_cache_rows,
                          poison_states)
from repro.launch.serve import ServeEngine, ShedError
from repro.models.lm import build_model

KW = dict(num_slots=4, max_len=64, prefill_rows=2, buckets=(16, 32),
          max_segments=2)


@pytest.fixture(scope="module")
def tiny_engine_model():
    cfg = get_config("mamba-110m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompts(cfg, rng, lens=(5, 9, 7, 12)):
    return [rng.integers(1, cfg.vocab, size=n).tolist() for n in lens]


def _run(model, params, prompts, max_new=8, **kw):
    """Submit all prompts into a fresh engine and drain it."""
    eng = ServeEngine(model, params, **dict(KW, **kw))
    for i, p in enumerate(prompts):
        eng.submit(p, max_new[i] if isinstance(max_new, (list, tuple))
                   else max_new)
    out = eng.run()
    return eng, out


# ---------------------------------------------------------------------------
# FaultPlan unit behaviour
# ---------------------------------------------------------------------------

def test_fault_plan_queries():
    plan = FaultPlan(fail_prefill=2, delay_prefill={1: 3},
                     poison_decode={5: [0, 2]}, kill_at_step=9)
    assert plan.fails_prefill(2) and not plan.fails_prefill(1)
    # the delay holds for exactly the first N probes of the named prefill
    assert [plan.prefill_not_ready(1, k) for k in range(5)] == \
        [True, True, True, False, False]
    assert not plan.prefill_not_ready(0, 0)
    v = plan.decode_poison(5, 4)
    assert v.shape == (4,) and np.isnan(v[0]) and np.isnan(v[2])
    assert v[1] == 0.0 and v[3] == 0.0          # untouched slots add 0.0
    assert plan.decode_poison(4, 4) is None
    assert plan.kills(9) and not plan.kills(8)
    assert plan.needs_guard() and not plan.empty()
    assert FaultPlan().empty() and not FaultPlan().needs_guard()
    # delay/fail alone are visible without the guard
    assert not FaultPlan(fail_prefill=0).needs_guard()
    # chunk seams: indexed by chunk round; poison self-enables the guard
    cplan = FaultPlan(fail_chunk=2, poison_chunk={1: [0]})
    assert cplan.fails_chunk(2) and not cplan.fails_chunk(1)
    assert cplan.chunk_poison(1) == [0] and cplan.chunk_poison(0) is None
    assert cplan.needs_guard() and not cplan.empty()
    assert not FaultPlan(fail_chunk=0).needs_guard()


def test_fault_plan_random_deterministic():
    a = FaultPlan.random(7, allow_kill=True)
    b = FaultPlan.random(7, allow_kill=True)
    assert a == b                               # same seed, same plan
    plans = [FaultPlan.random(s, allow_kill=True) for s in range(16)]
    assert any(p != plans[0] for p in plans[1:])
    assert any(not p.empty() for p in plans)


def test_poison_states_targets_only_named_segments():
    states = {"layer": {"conv": jnp.ones((2, 3, 4)),
                        "units": jnp.ones((5, 2, 3, 6))},
              "len": jnp.ones((2, 3), jnp.int32)}
    out = poison_states(states, [(1, 2)], float("nan"))
    conv = np.asarray(out["layer"]["conv"])
    assert np.isnan(conv[1, 2]).all() and np.isfinite(conv[0]).all()
    assert np.isfinite(conv[1, :2]).all()
    stacked = np.asarray(out["layer"]["units"])  # (units, B, S, ...)
    assert np.isnan(stacked[:, 1, 2]).all()
    assert np.isfinite(stacked[:, 0]).all()
    # integer bookkeeping leaves cannot hold a NaN and must pass through
    np.testing.assert_array_equal(np.asarray(out["len"]),
                                  np.asarray(states["len"]))


def test_poison_cache_rows_targets_only_named_rows():
    """The chunk-lane analogue: whole rows of a decode-layout cache."""
    cache = {"layer": {"conv": jnp.ones((3, 4)),
                       "units": jnp.ones((5, 3, 6))},
             "len": jnp.ones((3,), jnp.int32)}
    out = poison_cache_rows(cache, [1], float("nan"))
    conv = np.asarray(out["layer"]["conv"])
    assert np.isnan(conv[1]).all()
    assert np.isfinite(conv[0]).all() and np.isfinite(conv[2]).all()
    stacked = np.asarray(out["layer"]["units"])   # (units, B, ...)
    assert np.isnan(stacked[:, 1]).all()
    assert np.isfinite(stacked[:, 0]).all()
    np.testing.assert_array_equal(np.asarray(out["len"]),
                                  np.asarray(cache["len"]))


# ---------------------------------------------------------------------------
# guard rails + quarantine
# ---------------------------------------------------------------------------

def test_guard_on_no_faults_is_bit_identical(tiny_engine_model, rng):
    """The finiteness probes and the all-zero poison seam must not perturb
    a single logit: guarded output == unguarded output, exactly."""
    cfg, model, params = tiny_engine_model
    prompts = _prompts(cfg, rng)
    _, ref = _run(model, params, prompts)
    eng, out = _run(model, params, prompts, guard=True)
    assert out == ref
    assert eng.stats.quarantined == 0
    assert all(eng.status[r] == "done" for r in out)


def test_decode_poison_quarantines_slot_only(tiny_engine_model, rng):
    cfg, model, params = tiny_engine_model
    prompts = _prompts(cfg, rng)
    _, ref = _run(model, params, prompts)
    plan = FaultPlan(poison_decode={2: [1]})
    eng, out = _run(model, params, prompts, faults=plan)
    assert eng.guard                       # poison plans self-enable it
    failed = [r for r, s in eng.status.items() if s == "failed"]
    assert len(failed) == 1 and eng.stats.quarantined == 1
    assert "non-finite decode logits" in eng.errors[failed[0]]
    # the poisoned token was never emitted, and the healthy slots'
    # streams are bit-identical to the fault-free run
    assert len(out[failed[0]]) < len(ref[failed[0]])
    for r in ref:
        if r not in failed:
            assert out[r] == ref[r]


def test_decode_poison_inf_also_caught(tiny_engine_model, rng):
    cfg, model, params = tiny_engine_model
    prompts = _prompts(cfg, rng)
    plan = FaultPlan(poison_decode={1: [0]}, poison_value=float("inf"))
    eng, _ = _run(model, params, prompts, faults=plan)
    assert eng.stats.quarantined == 1


def test_prefill_poison_quarantines_before_activation(tiny_engine_model,
                                                      rng):
    cfg, model, params = tiny_engine_model
    prompts = _prompts(cfg, rng)
    _, ref = _run(model, params, prompts)
    plan = FaultPlan(poison_prefill={0: [(0, 1)]})
    eng, out = _run(model, params, prompts, faults=plan)
    failed = [r for r, s in eng.status.items() if s == "failed"]
    assert len(failed) == 1 and eng.stats.quarantined == 1
    assert "non-finite prefill state" in eng.errors[failed[0]]
    assert out[failed[0]] == []            # never activated, zero tokens
    for r in ref:
        if r not in failed:
            assert out[r] == ref[r]


# ---------------------------------------------------------------------------
# chunked-prefill fault seams (scheduler v2)
# ---------------------------------------------------------------------------

def test_chunk_dispatch_failure_keeps_serving(tiny_engine_model, rng):
    """A failed slab round (injected stand-in for device OOM on the chunk
    forward) kills the chunk-lane request with an explicit status; the
    packed requests never notice and the engine drains."""
    cfg, model, params = tiny_engine_model
    prompts = _prompts(cfg, rng) + \
        [rng.integers(1, cfg.vocab, size=40).tolist()]   # > bucket 32
    long_rid = len(prompts) - 1
    _, ref = _run(model, params, prompts)
    assert ref[long_rid]                   # fault-free chunk lane works
    eng, out = _run(model, params, prompts,
                    faults=FaultPlan(fail_chunk=1))
    assert eng.status[long_rid] == "failed"
    assert "chunked-prefill round 1 failed" in eng.errors[long_rid]
    assert out[long_rid] == []             # never reached a decode slot
    assert eng.stats.prefill_faults == 1
    assert eng.stats.chunked_prefills == 0
    for r in range(long_rid):
        assert out[r] == ref[r]
    assert all(s in ("done", "failed") for s in eng.status.values())


def test_chunk_poison_quarantined_at_handoff(tiny_engine_model, rng):
    """A poisoned carried chunk state is caught by the handoff probe: the
    request is quarantined BEFORE its slot activates — no garbage token is
    ever emitted, healthy streams stay bit-identical."""
    cfg, model, params = tiny_engine_model
    prompts = _prompts(cfg, rng) + \
        [rng.integers(1, cfg.vocab, size=40).tolist()]
    long_rid = len(prompts) - 1
    _, ref = _run(model, params, prompts)
    plan = FaultPlan(poison_chunk={0: [0]})
    eng, out = _run(model, params, prompts, faults=plan)
    assert eng.guard                       # poison plans self-enable it
    assert eng.status[long_rid] == "failed"
    assert "non-finite chunked-prefill state" in eng.errors[long_rid]
    assert eng.stats.quarantined == 1
    assert out[long_rid] == []
    for r in range(long_rid):
        assert out[r] == ref[r]


# ---------------------------------------------------------------------------
# prefill dispatch failure + delay (overlap window)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_prefill_failure_mid_overlap(tiny_engine_model, rng):
    """Kill the SECOND prefill dispatch — issued mid-flight while the
    first round is still decoding. Its requests fail explicitly; the
    first round never notices."""
    cfg, model, params = tiny_engine_model
    prompts = _prompts(cfg, rng, lens=(5, 9, 7, 12, 6, 10))
    budgets = [4, 10, 6, 12, 5, 7]     # staggered so slots free gradually
    _, ref = _run(model, params, prompts, max_new=budgets)
    eng, out = _run(model, params, prompts, max_new=budgets,
                    faults=FaultPlan(fail_prefill=1))
    assert eng.stats.prefill_faults == 1
    failed = sorted(r for r, s in eng.status.items() if s == "failed")
    assert failed                           # the 2nd round had requests
    for r in failed:
        assert "prefill dispatch 1 failed" in eng.errors[r]
        assert out[r] == []
    for r in ref:
        if r not in failed:
            assert out[r] == ref[r]
    # the engine drained: every request reached a terminal status
    assert all(s in ("done", "failed") for s in eng.status.values())


def test_prefill_delay_stretches_overlap_benignly(tiny_engine_model, rng):
    """A delayed prefill (scripted slow device) lands late but lands
    right: outputs are bit-identical to the undelayed run."""
    cfg, model, params = tiny_engine_model
    prompts = _prompts(cfg, rng, lens=(5, 9, 7, 12, 6, 10))
    budgets = [4, 10, 6, 12, 5, 7]
    _, ref = _run(model, params, prompts, max_new=budgets)
    eng, out = _run(model, params, prompts, max_new=budgets,
                    faults=FaultPlan(delay_prefill={1: 3}))
    assert out == ref
    assert all(s == "done" for s in eng.status.values())


# ---------------------------------------------------------------------------
# deadlines, shedding, cancellation, submit validation
# ---------------------------------------------------------------------------

def test_deadline_expires_queued_request(tiny_engine_model, rng):
    cfg, model, params = tiny_engine_model
    prompts = _prompts(cfg, rng)
    t = {"now": 0.0}
    eng = ServeEngine(model, params, clock=lambda: t["now"], **KW)
    a = eng.submit(prompts[0], 8, deadline_ms=50)
    b = eng.submit(prompts[1], 8)
    t["now"] = 0.2                         # 200ms > 50ms budget
    out = eng.run()
    assert eng.status[a] == "expired" and "while queued" in eng.errors[a]
    assert eng.status[b] == "done" and len(out[b]) == 8
    assert eng.stats.expired == 1
    assert out[a] == []                    # never prefetched, no waste


def test_deadline_expires_mid_decode_keeps_tokens(tiny_engine_model, rng):
    cfg, model, params = tiny_engine_model
    prompts = _prompts(cfg, rng)
    t = {"now": 0.0}
    eng = ServeEngine(model, params, clock=lambda: t["now"], **KW)
    a = eng.submit(prompts[0], 16, deadline_ms=50)
    b = eng.submit(prompts[1], 16)
    for _ in range(4):                     # prefill lands + a few tokens
        eng.step()
    t["now"] = 0.2
    while eng.step():
        pass
    assert eng.status[a] == "expired" and "mid-decode" in eng.errors[a]
    assert 0 < len(eng.outputs[a]) < 16    # partial stream kept
    assert eng.status[b] == "done" and len(eng.outputs[b]) == 16


def test_shed_on_queue_depth_and_age(tiny_engine_model, rng):
    cfg, model, params = tiny_engine_model
    prompts = _prompts(cfg, rng)
    t = {"now": 0.0}
    eng = ServeEngine(model, params, clock=lambda: t["now"],
                      max_queue=2, max_queue_age_ms=100, **KW)
    eng.submit(prompts[0], 4)
    eng.submit(prompts[1], 4)
    with pytest.raises(ShedError, match="queue depth"):
        eng.submit(prompts[2], 4)          # depth bound
    eng2 = ServeEngine(model, params, clock=lambda: t["now"],
                       max_queue_age_ms=100, **KW)
    eng2.submit(prompts[0], 4)
    t["now"] = 0.5                         # head-of-line is 500ms old
    with pytest.raises(ShedError, match="max_queue_age_ms"):
        eng2.submit(prompts[1], 4)
    assert eng.stats.shed == 1 and eng2.stats.shed == 1
    # a shed request was never queued: both engines still drain cleanly
    assert all(len(v) == 4 for v in eng.run().values())


def test_cancel_in_every_stage(tiny_engine_model, rng):
    cfg, model, params = tiny_engine_model
    prompts = _prompts(cfg, rng)
    eng = ServeEngine(model, params, **KW)
    rids = [eng.submit(p, 8) for p in prompts]
    assert eng.cancel(rids[3])             # still queued
    assert eng.status[rids[3]] == "cancelled"
    assert eng.outputs[rids[3]] == []
    while not eng._active_slots():         # drive until decode starts
        eng.step()
    assert eng.cancel(rids[0])             # actively decoding
    assert eng.status[rids[0]] == "cancelled"
    eng.run()
    assert eng.status[rids[1]] == "done" and eng.status[rids[2]] == "done"
    assert not eng.cancel(rids[1])         # terminal: no-op
    assert not eng.cancel(9999)            # unknown rid: no-op
    assert eng.stats.cancelled == 2


def test_submit_rejects_duplicate_rid_and_oversize(tiny_engine_model, rng):
    cfg, model, params = tiny_engine_model
    eng = ServeEngine(model, params, **KW)
    eng.submit(_prompts(cfg, rng)[0], 4, rid=5)
    with pytest.raises(ValueError, match="duplicate request id 5"):
        eng.submit(_prompts(cfg, rng)[1], 4, rid=5)
    # over-bucket prompts go to the chunk lane now; the rejection survives
    # only where chunking is off (scheduler v2)
    nochunk = ServeEngine(model, params, chunk_rows=0, **KW)
    with pytest.raises(ValueError, match="largest prefill bucket"):
        nochunk.submit(list(range(1, 40)), 4)  # 39 > max bucket 32
    # auto rids keep advancing past pinned ones
    assert eng.submit(_prompts(cfg, rng)[1], 4) == 6


# ---------------------------------------------------------------------------
# crash recovery: kill at step K, restore, prove token equality
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("kill_at", [1, 3, 6])
def test_kill_and_restore_completes_identically(tiny_engine_model, rng,
                                                tmp_path, kill_at):
    cfg, model, params = tiny_engine_model
    prompts = _prompts(cfg, rng)
    _, ref = _run(model, params, prompts, max_new=8)

    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    eng = ServeEngine(model, params,
                      faults=FaultPlan(kill_at_step=kill_at), **KW)
    for p in prompts:
        eng.submit(p, 8)
    with pytest.raises(EngineKilled):
        snap = 0
        while True:
            eng.snapshot(mgr, step=snap)   # snapshot EVERY step boundary
            snap += 1
            if not eng.step():
                pytest.fail("fault plan never fired")

    # a fresh engine (fresh process stand-in) resumes from the last
    # published snapshot and must finish every stream bit-identically
    eng2 = ServeEngine(model, params, **KW)
    restored = eng2.restore(mgr)
    assert restored == mgr.latest_step()
    assert eng2.resumed == set(ref)        # every live request resumed
    out = eng2.run()
    assert out == ref
    assert all(eng2.status[r] == "done" for r in ref)


@pytest.mark.slow
def test_restore_refuses_mismatched_engine(tiny_engine_model, rng,
                                           tmp_path):
    cfg, model, params = tiny_engine_model
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    eng = ServeEngine(model, params, **KW)
    eng.submit(_prompts(cfg, rng)[0], 4)
    eng.snapshot(mgr, step=0)
    other = ServeEngine(model, params, **dict(KW, num_slots=2))
    with pytest.raises(ValueError, match="slot shapes"):
        other.restore(mgr)
    busy = ServeEngine(model, params, **KW)
    busy.submit(_prompts(cfg, rng)[1], 4)
    with pytest.raises(RuntimeError, match="idle engine"):
        busy.restore(mgr)
    empty = ServeEngine(model, params, **KW)
    with pytest.raises(FileNotFoundError):
        empty.restore(CheckpointManager(str(tmp_path / "nope"),
                                        async_save=False))


def test_snapshot_preserves_remaining_deadline_budget(tiny_engine_model,
                                                      rng, tmp_path):
    """Deadlines are persisted as REMAINING budget: downtime between
    crash and restore must not expire a request that had time left."""
    cfg, model, params = tiny_engine_model
    t = {"now": 0.0}
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    eng = ServeEngine(model, params, clock=lambda: t["now"], **KW)
    a = eng.submit(_prompts(cfg, rng)[0], 4, deadline_ms=1000)
    t["now"] = 0.4                         # 400ms gone, 600ms left
    eng.snapshot(mgr, step=0)
    t["now"] = 100.0                       # ~100s of downtime
    eng2 = ServeEngine(model, params, clock=lambda: t["now"], **KW)
    eng2.restore(mgr)
    out = eng2.run()                       # clock frozen: no time passes
    assert eng2.status[a] == "done" and len(out[a]) == 4


# ---------------------------------------------------------------------------
# chaos lane: randomized-but-seeded plan, every request terminates
# ---------------------------------------------------------------------------

def test_chaos_seeded_no_hangs_no_garbage(tiny_engine_model, rng):
    """``make verify-faults`` entry point. A seeded random FaultPlan is
    thrown at a full workload; the invariants are the ISSUE's acceptance
    bar: bounded steps (no hangs), every request terminates with an
    explicit status, failure counters match statuses, and — when the plan
    happens to be empty — outputs equal the reference exactly."""
    cfg, model, params = tiny_engine_model
    base_seed = int(os.environ.get("FAULT_CHAOS_SEED", "0"))
    # the last prompt is over-bucket (40 > 32): every seed also stresses
    # the chunk lane, and chunk faults are in the random plan's envelope
    prompts = _prompts(cfg, rng, lens=(5, 9, 7, 12, 6, 40))
    budgets = [4, 10, 6, 12, 5, 7]
    _, ref = _run(model, params, prompts, max_new=budgets)
    for seed in range(base_seed, base_seed + 4):
        plan = FaultPlan.random(seed, max_prefills=3, max_steps=20,
                                num_slots=KW["num_slots"],
                                prefill_rows=KW["prefill_rows"],
                                max_segments=KW["max_segments"],
                                chunk_rows=1)
        eng = ServeEngine(model, params, faults=plan, **KW)
        for p, m in zip(prompts, budgets):
            eng.submit(p, m)
        steps = 0
        while eng.step():
            steps += 1
            assert steps < 500, f"seed {seed}: engine failed to drain"
        statuses = {r: eng.status[r] for r in eng.outputs}
        assert all(s in ("done", "failed") for s in statuses.values()), \
            f"seed {seed}: non-terminal status in {statuses}"
        n_failed = sum(s == "failed" for s in statuses.values())
        # every failure is accounted for by an injected fault, with a
        # human-readable diagnostic — nothing fails silently
        assert n_failed == eng.stats.quarantined + sum(
            "prefill dispatch" in eng.errors.get(r, "") or
            "chunked-prefill round" in eng.errors.get(r, "")
            for r, s in statuses.items() if s == "failed")
        for r, s in statuses.items():
            if s == "failed":
                assert eng.errors[r]
            elif plan.empty():
                assert eng.outputs[r] == ref[r]
        if plan.empty():
            assert eng.outputs == ref


def test_chaos_cached_lane_terminates_accounted(tiny_engine_model, rng):
    """Chaos over the CACHED lane: a shared declared prefix routes every
    request through the StateCache (capture, partial-hit restore,
    full-hit restore), and the plan's envelope includes the cache seams —
    drop_cache (forced evict → cold fallback) and poison_cache_hit
    (corrupted stored state → the guard rails must quarantine). Same
    invariants as the plain chaos lane: bounded steps, terminal statuses,
    every failure accounted for, empty plan → exact reference outputs."""
    from repro.launch.state_cache import StateCache

    cfg, model, params = tiny_engine_model
    base_seed = int(os.environ.get("FAULT_CHAOS_SEED", "0"))
    shared = rng.integers(1, cfg.vocab, size=14).tolist()
    tails = _prompts(cfg, rng, lens=(4, 7, 5, 6, 4))
    prompts = [shared + t for t in tails]
    budgets = [4, 8, 5, 6, 4]
    _, ref = _run(model, params, prompts, max_new=budgets)
    for seed in range(base_seed, base_seed + 4):
        plan = FaultPlan.random(seed, max_prefills=3, max_steps=20,
                                num_slots=KW["num_slots"],
                                prefill_rows=KW["prefill_rows"],
                                max_segments=KW["max_segments"],
                                chunk_rows=1, cache_lookups=6)
        sc = StateCache(32 << 20)
        eng = ServeEngine(model, params, faults=plan, state_cache=sc,
                          **KW)
        for p, m in zip(prompts, budgets):
            eng.submit(p, m, prefix_len=14)
        steps = 0
        while eng.step():
            steps += 1
            assert steps < 500, f"seed {seed}: engine failed to drain"
        statuses = {r: eng.status[r] for r in eng.outputs}
        assert all(s in ("done", "failed") for s in statuses.values()), \
            f"seed {seed}: non-terminal status in {statuses}"
        n_failed = sum(s == "failed" for s in statuses.values())
        assert n_failed == eng.stats.quarantined + sum(
            "prefill dispatch" in eng.errors.get(r, "") or
            "chunked-prefill round" in eng.errors.get(r, "")
            for r, s in statuses.items() if s == "failed"), \
            f"seed {seed}: unaccounted failure"
        for r, s in statuses.items():
            if s == "failed":
                assert eng.errors[r]
            elif plan.empty():
                assert eng.outputs[r] == ref[r]
        if plan.empty():
            assert eng.outputs == ref
