"""Checkpoint: roundtrip (incl. bf16), atomic publish, keep-K GC, template
restore with dtype/shape checks, resume metadata."""
import os
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.checkpoint import CheckpointManager


def _tree(rng):
    return {"params": {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
                       "emb": jnp.asarray(rng.normal(size=(8, 2)),
                                          jnp.bfloat16)},
            "opt": {"m": jnp.zeros((4, 3)), "step": jnp.asarray(7)}}


def test_roundtrip(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    tree = _tree(rng)
    mgr.save(10, tree, meta={"step": 10, "note": "x"})
    assert mgr.latest_step() == 10
    got = mgr.restore(tree)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
    assert mgr.read_meta(10)["meta"]["note"] == "x"


def test_keep_k_gc(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    tree = _tree(rng)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]


def test_async_save(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    tree = _tree(rng)
    mgr.save(5, tree)
    mgr.wait()
    assert mgr.latest_step() == 5
    got = mgr.restore(tree)
    np.testing.assert_allclose(got["params"]["w"], tree["params"]["w"])


def test_no_partial_checkpoint_visible(tmp_path, rng):
    """tmp dirs are never listed as checkpoints."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    os.makedirs(tmp_path / "step_99.tmp")
    assert mgr.all_steps() == []
    mgr.save(1, _tree(rng))
    assert mgr.all_steps() == [1]


def test_restore_missing_leaf_raises(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, {"a": jnp.zeros(2)})
    with pytest.raises(KeyError):
        mgr.restore({"a": jnp.zeros(2), "b": jnp.zeros(3)})


def test_async_save_error_propagates(tmp_path, rng, monkeypatch):
    """A failed background write must surface at the next sync point —
    wait() or the following save() — not vanish with the daemon thread."""
    mgr = CheckpointManager(str(tmp_path), async_save=True)

    def boom(*a, **k):
        raise OSError("disk full (injected)")

    monkeypatch.setattr(np, "savez", boom)
    mgr.save(1, {"a": jnp.zeros(2)})
    with pytest.raises(RuntimeError, match="disk full"):
        mgr.wait()
    monkeypatch.undo()
    # the manager is usable again once the error has been delivered
    mgr.save(2, {"a": jnp.zeros(2)})
    mgr.wait()
    assert mgr.latest_step() == 2


def test_async_save_error_raises_on_next_save(tmp_path, rng, monkeypatch):
    mgr = CheckpointManager(str(tmp_path), async_save=True)

    def boom(*a, **k):
        raise OSError("quota exceeded (injected)")

    monkeypatch.setattr(np, "savez", boom)
    mgr.save(1, {"a": jnp.zeros(2)})
    with pytest.raises(RuntimeError, match="quota exceeded"):
        mgr.save(2, {"a": jnp.zeros(2)})   # save() syncs via wait() first


def test_restore_corrupt_arrays_clear_error(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(3, {"a": jnp.zeros(2)})
    with open(tmp_path / "step_3" / "arrays.npz", "wb") as f:
        f.write(b"this is not an npz archive")
    with pytest.raises(ValueError, match="corrupt"):
        mgr.restore({"a": jnp.zeros(2)}, step=3)


def test_restore_missing_arrays_file_clear_error(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(4, {"a": jnp.zeros(2)})
    os.remove(tmp_path / "step_4" / "arrays.npz")
    with pytest.raises(FileNotFoundError, match="no arrays.npz"):
        mgr.restore({"a": jnp.zeros(2)}, step=4)


def test_read_meta_unpublished_step_clear_error(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, {"a": jnp.zeros(2)})
    with pytest.raises(FileNotFoundError, match="never published"):
        mgr.read_meta(99)


def test_restore_template_by_shape_struct(tmp_path, rng):
    """Restore into eval_shape templates (how the trainer resumes) and cast
    dtype when the template asks for it (elastic precision change)."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    tree = _tree(rng)
    mgr.save(2, tree)
    tpl = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)
    got = mgr.restore(tpl)
    np.testing.assert_allclose(np.asarray(got["params"]["emb"], np.float32),
                               np.asarray(tree["params"]["emb"], np.float32))


def test_elastic_restore_across_mesh_sizes():
    """Save under a (4,2) mesh, restore under (2,4) — checkpoints are
    mesh-agnostic (host arrays) and device_put resharded on load."""
    import subprocess
    import sys
    src = r"""
import tempfile, numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.checkpoint import CheckpointManager
from repro.distributed.compat import make_mesh

d = tempfile.mkdtemp()
mesh1 = make_mesh((4, 2), ("data", "model"))
w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
w1 = jax.device_put(w, NamedSharding(mesh1, P("data", "model")))
mgr = CheckpointManager(d, async_save=False)
mgr.save(1, {"w": w1})

mesh2 = make_mesh((2, 4), ("data", "model"))
sh2 = {"w": NamedSharding(mesh2, P("data", "model"))}
got = mgr.restore({"w": w}, shardings=sh2)
assert got["w"].sharding == sh2["w"], got["w"].sharding
np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(w))
print("ELASTIC_OK")
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", src], env=env,
                         capture_output=True, text=True, timeout=300)
    assert "ELASTIC_OK" in out.stdout, out.stderr[-1500:]
