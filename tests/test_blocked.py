"""Blocked (SSD-style) scan schedule: parity vs the sequential reference.

Covers the acceptance surface of the block-parallel schedule:
  * generic ``scan_blocked`` (cumprod M construction) on random packed
    resets, chunk not dividing L, segments straddling chunk boundaries
  * ``core.ssm.selective_scan(method='blocked')`` fwd + grads, both
    in-chunk evaluators ('matmul' einsum and 'assoc' tree), h0 carry
  * the Pallas ``schedule='blocked'`` kernels (interpret mode) fwd + grads
  * structural memory claim: no (B, L, D, N) intermediate in the jaxpr
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.scan import segmented_scan
from repro.core import ssm as core_ssm
from repro.kernels.ops import selective_scan as kops_scan
from repro.kernels.ref import selective_scan_ref


def _packed_pos(rng, Bz, L, max_cuts=3):
    """Random packed position ids: segments deliberately straddle chunk
    boundaries (cuts are arbitrary, chunks are powers of two)."""
    pos = np.zeros((Bz, L), np.int32)
    for b in range(Bz):
        cuts = sorted(rng.choice(np.arange(1, L),
                                 size=min(max_cuts, L - 1),
                                 replace=False)) if L > 2 else []
        prev = 0
        for c in list(cuts) + [L]:
            pos[b, prev:c] = np.arange(c - prev)
            prev = c
    return jnp.asarray(pos)


def _ssm_inputs(rng, Bz, L, Dm, N):
    u = jnp.asarray(rng.normal(size=(Bz, L, Dm)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.5, (Bz, L, Dm)), jnp.float32)
    A = -jnp.exp(jnp.asarray(rng.normal(size=(Dm, N)), jnp.float32))
    Bm = jnp.asarray(rng.normal(size=(Bz, L, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(Bz, L, N)), jnp.float32)
    Dk = jnp.asarray(rng.normal(size=(Dm,)), jnp.float32)
    return u, dt, A, Bm, Cm, Dk, _packed_pos(rng, Bz, L)


# ---------------------------------------------------------------------------
# generic scan_blocked
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,L,D,chunk", [(2, 37, 5, 8), (1, 16, 3, 16),
                                         (3, 64, 4, 5), (1, 7, 2, 32)])
def test_scan_blocked_matches_sequential(rng, B, L, D, chunk):
    a = jnp.asarray(rng.uniform(0.1, 1.0, (B, L, D)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, L, D)), jnp.float32)
    reset = jnp.asarray(rng.random((B, L)) < 0.2)
    h0 = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)
    hs, hls = segmented_scan(a, b, reset, h0, method="sequential")
    hb, hlb = segmented_scan(a, b, reset, h0, method="blocked", chunk=chunk)
    np.testing.assert_allclose(hs, hb, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(hls, hlb, atol=1e-5, rtol=1e-5)


def test_scan_blocked_grads(rng):
    B, L, D, chunk = 2, 23, 4, 8
    a = jnp.asarray(rng.uniform(0.1, 1.0, (B, L, D)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, L, D)), jnp.float32)
    reset = jnp.zeros((B, L), bool).at[:, 9].set(True)

    def loss(m):
        def f(a_in, b_in):
            h, _ = segmented_scan(a_in, b_in, reset, method=m, chunk=chunk)
            return (h ** 2).sum()
        return jax.grad(f, argnums=(0, 1))(a, b)

    for ga, gb in zip(loss("sequential"), loss("blocked")):
        np.testing.assert_allclose(ga, gb, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# XLA selective scan, method='blocked'
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("intra", ["matmul", "assoc"])
@pytest.mark.parametrize("Bz,L,Dm,N,T", [(2, 24, 10, 4, 8),
                                         (1, 17, 5, 3, 8),
                                         (1, 64, 16, 16, 16)])
def test_blocked_ssm_fwd(rng, intra, Bz, L, Dm, N, T):
    u, dt, A, Bm, Cm, Dk, pos = _ssm_inputs(rng, Bz, L, Dm, N)
    y_seq = core_ssm.selective_scan(u, dt, A, Bm, Cm, Dk, pos,
                                    method="sequential")
    y_blk, h_blk = core_ssm.selective_scan(u, dt, A, Bm, Cm, Dk, pos,
                                           method="blocked", chunk=T,
                                           intra=intra, return_state=True)
    _, h_seq = core_ssm.selective_scan(u, dt, A, Bm, Cm, Dk, pos,
                                       method="sequential",
                                       return_state=True)
    np.testing.assert_allclose(np.asarray(y_blk), np.asarray(y_seq),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_blk), np.asarray(h_seq),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("intra", ["matmul", "assoc"])
def test_blocked_ssm_grads(rng, intra):
    Bz, L, Dm, N, T = 2, 24, 6, 4, 8
    u, dt, A, Bm, Cm, Dk, pos = _ssm_inputs(rng, Bz, L, Dm, N)

    def grads(method, **kw):
        def f(u, dt, A, Bm, Cm, Dk):
            y = core_ssm.selective_scan(u, dt, A, Bm, Cm, Dk, pos,
                                        method=method, chunk=T, **kw)
            return (y ** 2).sum()
        return jax.grad(f, argnums=tuple(range(6)))(u, dt, A, Bm, Cm, Dk)

    gs = grads("sequential")
    gb = grads("blocked", intra=intra)
    for name, a, b in zip("u dt A B C D".split(), gs, gb):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-3,
                                   err_msg=f"{intra} grad {name}")


def test_blocked_ssm_h0_carry(rng):
    """Split-pack state carry: scan [x1; x2] == scan x2 with h0 from x1."""
    Bz, L, Dm, N = 1, 20, 4, 3
    u, dt, A, Bm, Cm, Dk, _ = _ssm_inputs(rng, Bz, L, Dm, N)
    pos = jnp.tile(jnp.arange(1, L + 1, dtype=jnp.int32), (Bz, 1))  # no reset
    y_all, h_all = core_ssm.selective_scan(u, dt, A, Bm, Cm, Dk, pos,
                                           method="blocked", chunk=8,
                                           return_state=True)
    _, h_mid = core_ssm.selective_scan(
        u[:, :11], dt[:, :11], A, Bm[:, :11], Cm[:, :11], Dk, pos[:, :11],
        method="sequential", return_state=True)
    y_rest, h_end = core_ssm.selective_scan(
        u[:, 11:], dt[:, 11:], A, Bm[:, 11:], Cm[:, 11:], Dk, pos[:, 11:],
        h0=h_mid, method="blocked", chunk=4, return_state=True)
    np.testing.assert_allclose(np.asarray(y_rest), np.asarray(y_all[:, 11:]),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_end), np.asarray(h_all),
                               atol=1e-4, rtol=1e-4)


def test_blocked_grad_does_not_cross_boundary(rng):
    """Backward PUI on the blocked path (paper §3.4)."""
    Bz, L, Dm, N = 1, 16, 4, 3
    u, dt, A, Bm, Cm, Dk, _ = _ssm_inputs(rng, Bz, L, Dm, N)
    pos = jnp.concatenate([jnp.arange(8), jnp.arange(8)])[None]

    def loss(u_in):
        y = core_ssm.selective_scan(u_in, dt, A, Bm, Cm, Dk, pos,
                                    method="blocked", chunk=8)
        return (y[:, 8:] ** 2).sum()

    g = jax.grad(loss)(u)
    np.testing.assert_allclose(g[:, :8], 0.0, atol=1e-7)
    assert float(jnp.abs(g[:, 8:]).max()) > 0


def test_blocked_jaxpr_has_no_full_trajectory():
    """The structural memory claim: `blocked` never builds a (B, L, D, N)
    intermediate; `chunked` does (it materializes decay + input tensors)."""
    Bz, L, Dm, N = 1, 512, 32, 8
    args = (jnp.zeros((Bz, L, Dm)), jnp.full((Bz, L, Dm), 0.1),
            -jnp.ones((Dm, N)), jnp.zeros((Bz, L, N)),
            jnp.zeros((Bz, L, N)), jnp.zeros((Dm,)),
            jnp.zeros((Bz, L), jnp.int32))

    def has_full(method, **kw):
        jaxpr = jax.make_jaxpr(lambda *a: core_ssm.selective_scan(
            *a, method=method, chunk=64, **kw))(*args)
        want = (Bz, L, Dm, N)
        return any(getattr(v.aval, "shape", None) == want
                   for eqn in jaxpr.jaxpr.eqns for v in eqn.outvars)

    assert has_full("chunked")
    assert not has_full("blocked", intra="assoc")
    assert not has_full("blocked", intra="matmul")


# ---------------------------------------------------------------------------
# Pallas blocked kernels (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("schedule", ["step", "blocked"])
@pytest.mark.parametrize("Bz,L,Dm,N", [(2, 24, 10, 4), (1, 33, 8, 16)])
def test_pallas_schedules_fwd(rng, schedule, Bz, L, Dm, N):
    u, dt, A, Bm, Cm, Dk, pos = _ssm_inputs(rng, Bz, L, Dm, N)
    y_ref = selective_scan_ref(u, dt, A, Bm, Cm, Dk, pos)
    y = kops_scan(u, dt, A, Bm, Cm, Dk, pos, backend="pallas",
                  block_d=8, chunk=8, schedule=schedule)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)


def test_pallas_blocked_grads(rng):
    Bz, L, Dm, N = 2, 24, 10, 4
    u, dt, A, Bm, Cm, Dk, pos = _ssm_inputs(rng, Bz, L, Dm, N)

    def lp(*args):
        return (kops_scan(*args, pos, backend="pallas", block_d=8, chunk=8,
                          schedule="blocked") ** 2).sum()

    def lr(*args):
        return (selective_scan_ref(*args, pos) ** 2).sum()

    gp = jax.grad(lp, argnums=tuple(range(6)))(u, dt, A, Bm, Cm, Dk)
    gr = jax.grad(lr, argnums=tuple(range(6)))(u, dt, A, Bm, Cm, Dk)
    for name, a, b in zip("u dt A B C D".split(), gp, gr):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-3,
                                   err_msg=f"grad {name}")


def test_pallas_blocked_reset_blocks_grad(rng):
    u, dt, A, Bm, Cm, Dk, _ = _ssm_inputs(rng, 1, 16, 8, 4)
    pos = jnp.concatenate([jnp.arange(8), jnp.arange(8)])[None]

    def loss(u_in):
        y = kops_scan(u_in, dt, A, Bm, Cm, Dk, pos, backend="pallas",
                      block_d=8, chunk=8, schedule="blocked")
        return (y[:, 8:] ** 2).sum()

    g = jax.grad(loss)(u)
    np.testing.assert_allclose(g[:, :8], 0.0, atol=1e-7)
    assert float(jnp.abs(g[:, 8:]).max()) > 0
