"""End-to-end behaviour of the paper's system: the three training regimes
produce equivalent learning on identical data; packing processes ~the same
tokens with far fewer step-invocations; split-packing (paper §5 future work)
trains with zero padding."""
import dataclasses
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.core.packing import pack, pack_with_split, pad_to_max
from repro.data.dataset import SyntheticCorpus, CorpusConfig
from repro.data.packing_loader import PackingLoader, LoaderConfig
from repro.models.lm import build_model
from repro.optim.adamw import AdamW, constant_schedule
from repro.train.trainer import Trainer, TrainerConfig, make_train_step


def _tiny(vocab=128):
    cfg = get_config("mamba-110m").reduced()
    return dataclasses.replace(cfg, vocab=vocab, n_layers=2, d_model=32)


def _corpus():
    return SyntheticCorpus(CorpusConfig(vocab=128, seed=0, len_min=5,
                                        len_max=40, mu=3.0, sigma=0.5))


def test_pack_and_pad_learn_equivalently():
    """PUI at the training level: packed training and padded training on the
    SAME sequences produce near-identical losses step by step."""
    cfg = _tiny()
    model = build_model(cfg)
    corpus = _corpus()
    opt = AdamW(constant_schedule(2e-3))
    step = jax.jit(make_train_step(model, opt))
    losses = {}
    for mode in ("pack", "pad"):
        params = model.init(jax.random.PRNGKey(0))
        state = {"params": params, "opt": opt.init(params)}
        ls = []
        for s in range(8):
            seqs = corpus.batch_of_sequences(s, 6)
            if mode == "pack":
                pb = pack(seqs, 64, num_rows=6)
            else:
                pb = pad_to_max(seqs, 64)
            batch = {"tokens": pb.tokens, "positions": pb.positions,
                     "segment_ids": pb.segment_ids}
            state, m = step(state, batch)
            ls.append(float(m["ce"]))
        losses[mode] = ls
    # identical data + PUI ⇒ same per-token CE trajectory
    np.testing.assert_allclose(losses["pack"], losses["pad"], rtol=2e-2)


def test_packing_uses_fewer_rows():
    """The throughput mechanism: same tokens, ~4× fewer buffer rows than
    pad-to-max at the paper's length statistics."""
    corpus = SyntheticCorpus()
    seqs = corpus.batch_of_sequences(0, 64)
    pb = pack(seqs, 4096)
    rows_pack = pb.tokens.shape[0]
    rows_pad = len(seqs)
    dense_pack = 1 - pb.padding_rate()
    lens = [len(s) for s in seqs]
    dense_pad = np.sum(lens) / (rows_pad * 4096)
    assert rows_pack < rows_pad / 3
    assert dense_pack > 3 * dense_pad


def test_split_packing_trains_with_zero_padding():
    cfg = _tiny()
    model = build_model(cfg)
    corpus = _corpus()
    opt = AdamW(constant_schedule(2e-3))
    step = jax.jit(make_train_step(model, opt))
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": opt.init(params)}
    for s in range(4):
        seqs = corpus.batch_of_sequences(s, 8)
        total = sum(len(x) for x in seqs)
        rows = total // 48 + 1
        sb = pack_with_split(seqs, 48, num_rows=rows)
        assert sb.padding_rate() < 1 / 2          # only final-row padding
        batch = {"tokens": sb.tokens, "positions": sb.positions,
                 "segment_ids": sb.segment_ids}
        state, m = step(state, batch)
        assert np.isfinite(float(m["loss"]))


def test_full_pipeline_checkpoint_restart(tmp_path):
    """Train → stop → restart → identical continuation (the fault-tolerance
    story end to end)."""
    cfg = _tiny()
    model = build_model(cfg)
    opt = AdamW(constant_schedule(1e-3))
    corpus = _corpus()
    loader = PackingLoader(corpus, LoaderConfig(rows=4, seq_len=64))

    t1 = Trainer(model, opt, loader,
                 TrainerConfig(steps=6, log_every=100, ckpt_every=3,
                               ckpt_dir=str(tmp_path)))
    s1, h1 = t1.train(jax.random.PRNGKey(0), verbose=False)
    # "crash" after step 6 (ckpt at 6); restart a new trainer
    t2 = Trainer(model, opt, loader,
                 TrainerConfig(steps=9, log_every=100, ckpt_every=100,
                               ckpt_dir=str(tmp_path)))
    s2, h2 = t2.train(jax.random.PRNGKey(1), verbose=False)
    assert len(h2) == 3                      # resumed from 6, ran 3
    # direct 9-step run matches the restarted run
    t3 = Trainer(model, opt, loader,
                 TrainerConfig(steps=9, log_every=100))
    s3, _ = t3.train(jax.random.PRNGKey(0), verbose=False)
    for a, b in zip(jax.tree.leaves(s2["params"]),
                    jax.tree.leaves(s3["params"])):
        np.testing.assert_allclose(a, b, atol=1e-5)
