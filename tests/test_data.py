"""Data pipeline: determinism, paper length statistics, loader modes,
background prefetch."""
import numpy as np
import pytest

from repro.data.dataset import SyntheticCorpus, CorpusConfig
from repro.data.packing_loader import PackingLoader, LoaderConfig
from repro.data.prefetch import PrefetchLoader


def test_deterministic_replay():
    c1 = SyntheticCorpus(CorpusConfig(seed=5))
    c2 = SyntheticCorpus(CorpusConfig(seed=5))
    for step in (0, 3, 1000):
        np.testing.assert_array_equal(c1.lengths(step, 16),
                                      c2.lengths(step, 16))
        s1 = c1.batch_of_sequences(step, 4)
        s2 = c2.batch_of_sequences(step, 4)
        for a, b in zip(s1, s2):
            np.testing.assert_array_equal(a, b)
    # different seeds differ
    c3 = SyntheticCorpus(CorpusConfig(seed=6))
    assert not np.array_equal(c1.lengths(0, 16), c3.lengths(0, 16))


def test_paper_length_statistics():
    """Paper §4: lengths in [57, 2048], mean ≈ 646."""
    c = SyntheticCorpus()
    lens = np.concatenate([c.lengths(s, 512) for s in range(20)])
    assert lens.min() >= 57 and lens.max() <= 2048
    assert 560 < lens.mean() < 730


def test_tokens_in_vocab_and_nonzero():
    c = SyntheticCorpus(CorpusConfig(vocab=1000))
    for s in c.batch_of_sequences(0, 8):
        assert s.min() >= 1 and s.max() < 1000   # 0 reserved for padding


@pytest.mark.parametrize("mode,rows", [("pack", 4), ("pad", 4),
                                       ("single", 1)])
def test_loader_static_shapes(mode, rows):
    c = SyntheticCorpus(CorpusConfig(seed=1, len_min=5, len_max=40,
                                     mu=3.0, sigma=0.5))
    ld = PackingLoader(c, LoaderConfig(rows=rows, seq_len=64, mode=mode))
    shapes = set()
    for step in range(3):
        b = ld.batch(step)
        if mode != "single":          # single pads to per-step power of two
            shapes.add(b["tokens"].shape)
        assert b["tokens"].shape == b["positions"].shape == \
            b["segment_ids"].shape
        seg = np.asarray(b["segment_ids"])
        pos = np.asarray(b["positions"])
        assert (pos[seg == 0] == 0).all()
    if mode != "single":
        assert len(shapes) == 1       # static across steps


def test_single_mode_pads_to_power_of_two():
    """Paper Fig 2: the single-sequence baseline runs at seqlen = 2^n."""
    c = SyntheticCorpus(CorpusConfig(seed=2))
    ld = PackingLoader(c, LoaderConfig(rows=1, seq_len=2048, mode="single"))
    for step in range(3):
        L = ld.batch(step)["tokens"].shape[1]
        assert L & (L - 1) == 0       # power of two


def test_pack_padding_beats_pad_mode():
    c = SyntheticCorpus()
    ld = PackingLoader(c, LoaderConfig(rows=8, seq_len=4096, mode="pack"))
    st = ld.stats(0)
    assert st["padding_rate"] < 0.35
    # pad-to-max on the same distribution wastes far more
    lens = c.lengths(0, 64)
    pad_rate = 1 - lens.mean() / 2048
    assert pad_rate > 2 * st["padding_rate"]


def test_shard_load_balancing():
    """Straggler mitigation: with balance_shards=k, each contiguous row
    group (one DP shard's slice) carries near-equal real-token load."""
    c = SyntheticCorpus()
    for bal in (0, 4):
        ld = PackingLoader(c, LoaderConfig(rows=16, seq_len=4096,
                                           mode="pack", balance_shards=bal))
        b = ld.batch(0)
        seg = np.asarray(b["segment_ids"])
        loads = (seg > 0).sum(axis=1).reshape(4, 4).sum(axis=1)
        spread = loads.max() - loads.min()
        if bal:
            balanced_spread = spread
        else:
            unbalanced_spread = spread
    assert balanced_spread <= unbalanced_spread
    # balanced spread is within one buffer's capacity of perfectly even
    assert balanced_spread <= 4096


def test_balance_shards_indivisible_raises():
    """rows % balance_shards != 0 must fail loudly at construction (the
    old code silently returned the unbalanced batch)."""
    c = SyntheticCorpus()
    with pytest.raises(ValueError, match="balance_shards"):
        PackingLoader(c, LoaderConfig(rows=6, seq_len=2048, mode="pack",
                                      balance_shards=4))
    # _balance itself also raises for direct callers
    with pytest.raises(ValueError, match="not divisible"):
        PackingLoader._balance(
            {"segment_ids": np.ones((6, 8), np.int32)}, 4)


def test_stats_reports_balanced_flag():
    c = SyntheticCorpus()
    ld = PackingLoader(c, LoaderConfig(rows=8, seq_len=4096, mode="pack",
                                       balance_shards=2))
    assert ld.stats(0)["balanced"] is True
    ld0 = PackingLoader(c, LoaderConfig(rows=8, seq_len=4096, mode="pack"))
    assert ld0.stats(0)["balanced"] is False


def _small_loader(**kw):
    c = SyntheticCorpus(CorpusConfig(seed=1, len_min=5, len_max=40,
                                     mu=3.0, sigma=0.5))
    return PackingLoader(c, LoaderConfig(rows=4, seq_len=64, mode="pack",
                                         **kw))


def test_prefetch_bit_identity():
    """PrefetchLoader is a pure memoizer: every step's batch is
    bit-identical to the synchronous loader, in any access order."""
    sync = _small_loader()
    with PrefetchLoader(_small_loader(), depth=3) as pf:
        for step in (0, 1, 2, 3, 7, 4, 0):      # incl. replay + a jump back
            a, b = sync.batch(step), pf.batch(step)
            for k in ("tokens", "positions", "segment_ids"):
                np.testing.assert_array_equal(np.asarray(a[k]),
                                              np.asarray(b[k]))


def test_prefetch_hits_on_sequential_access():
    with PrefetchLoader(_small_loader(), depth=2) as pf:
        for step in range(6):
            pf.batch(step)
        st = pf.stats(5)
        # step 0 is a miss; the buffer then stays ahead
        assert st["prefetch_misses"] >= 1
        assert st["prefetch_hits"] >= 3
        assert "padding_rate" in st              # wrapped stats passthrough
    assert pf.cfg.rows == 4                      # attribute passthrough


def test_prefetch_rejects_bad_depth():
    with pytest.raises(ValueError):
        PrefetchLoader(_small_loader(), depth=0)


def test_prefetch_wait_time_metered():
    """Cumulative blocked time: a scripted slow loader must show up in
    ``data.prefetch_wait_ms`` — a miss blocks for the whole computation,
    and a hit whose future is still running blocks in result()."""
    import time

    class SlowLoader:
        def batch(self, step):
            time.sleep(0.02)
            return {"step": step}

    with PrefetchLoader(SlowLoader(), depth=1) as pf:
        assert pf.batch(0) == {"step": 0}            # miss: full 20ms wait
        st = pf.stats(0)
    assert st["prefetch_misses"] == 1
    assert st["prefetch_wait_ms"] >= 15.0            # sleep minus slack
    assert pf.wait_ms == st["prefetch_wait_ms"]
    # the wait is stored in the shared registry, not a shadow attribute
    assert pf.obs.metrics.gauge("data.prefetch_wait_ms").value == pf.wait_ms


def test_prefetch_fast_loader_waits_near_zero():
    """When the worker keeps up, hits barely block: the cumulative wait on
    a buffer-ahead access pattern stays far below the work it overlapped."""
    import time

    class SlowLoader:
        def batch(self, step):
            time.sleep(0.01)
            return {"step": step}

    with PrefetchLoader(SlowLoader(), depth=2) as pf:
        pf.batch(0)                                  # miss, primes 1..2
        time.sleep(0.05)                             # let the worker finish
        t0 = time.perf_counter()
        pf.batch(1)                                  # hit: already computed
        hit_wall = (time.perf_counter() - t0) * 1e3
        st = pf.stats(1)
    assert st["prefetch_hits"] >= 1
    assert hit_wall < 8.0                            # served from buffer


def test_first_fit_decreasing_loader_padding_not_worse():
    """FFD is the offline padding reducer: never more padding than the
    arrival-order sequential policy on the same draw."""
    c = SyntheticCorpus()
    seqr = PackingLoader(c, LoaderConfig(rows=8, seq_len=4096, mode="pack",
                                         policy="sequential"))
    ffd = PackingLoader(c, LoaderConfig(rows=8, seq_len=4096, mode="pack",
                                        policy="first_fit_decreasing"))
    for step in range(3):
        assert ffd.stats(step)["padding_rate"] <= \
            seqr.stats(step)["padding_rate"] + 1e-9


def test_balance_preserves_rows():
    c = SyntheticCorpus()
    ld0 = PackingLoader(c, LoaderConfig(rows=8, seq_len=2048, mode="pack"))
    ld1 = PackingLoader(c, LoaderConfig(rows=8, seq_len=2048, mode="pack",
                                        balance_shards=2))
    b0, b1 = ld0.batch(3), ld1.batch(3)
    # same multiset of rows, different order
    r0 = {tuple(np.asarray(b0["tokens"][i]).tolist()) for i in range(8)}
    r1 = {tuple(np.asarray(b1["tokens"][i]).tolist()) for i in range(8)}
    assert r0 == r1
