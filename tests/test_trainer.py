"""Trainer: loss decrease, grad-accum equivalence, resume determinism,
emergency checkpoint plumbing."""
import dataclasses
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config
from repro.models.lm import build_model
from repro.optim.adamw import AdamW, constant_schedule, cosine_schedule
from repro.train.trainer import Trainer, TrainerConfig, make_train_step
from repro.data.dataset import SyntheticCorpus, CorpusConfig
from repro.data.packing_loader import PackingLoader, LoaderConfig


def _tiny():
    cfg = get_config("mamba-110m").reduced()
    return dataclasses.replace(cfg, vocab=128, n_layers=2, d_model=32)


def _loader(rows=4, seq=64, mode="pack"):
    corpus = SyntheticCorpus(CorpusConfig(vocab=128, seed=0, len_min=5,
                                          len_max=40, mu=3.0, sigma=0.5))
    return PackingLoader(corpus, LoaderConfig(rows=rows, seq_len=seq,
                                              mode=mode))


def test_loss_decreases(tmp_path):
    model = build_model(_tiny())
    opt = AdamW(cosine_schedule(3e-3, warmup=5, total=40))
    tr = Trainer(model, opt, _loader(),
                 TrainerConfig(steps=25, log_every=100))
    _, hist = tr.train(jax.random.PRNGKey(0), verbose=False)
    assert np.mean([h["loss"] for h in hist[-5:]]) < \
        np.mean([h["loss"] for h in hist[:5]]) - 0.2


def test_grad_accum_equivalence():
    """accum=2 over the same global batch == accum=1 (up to fp assoc)."""
    model = build_model(_tiny())
    opt = AdamW(constant_schedule(1e-3))
    loader = _loader(rows=4)
    batch = loader.batch(0)
    params = model.init(jax.random.PRNGKey(0))
    s1 = {"params": params, "opt": opt.init(params)}
    s2 = jax.tree.map(lambda x: x, s1)
    f1 = jax.jit(make_train_step(model, opt, accum=1))
    f2 = jax.jit(make_train_step(model, opt, accum=2))
    n1, m1 = f1(s1, batch)
    n2, m2 = f2(s2, batch)
    # losses: accum averages microbatch means (token counts differ slightly
    # per row) — close but not identical; params should track closely
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 0.05
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                     n1["params"], n2["params"])
    assert max(jax.tree.leaves(d)) < 5e-2


def test_resume_is_deterministic(tmp_path):
    """train 10 straight == train 5, checkpoint, restart, train 5 more."""
    model = build_model(_tiny())

    def mk(dirname, steps, every):
        opt = AdamW(constant_schedule(1e-3))
        return Trainer(model, opt, _loader(),
                       TrainerConfig(steps=steps, log_every=100,
                                     ckpt_every=every, ckpt_dir=dirname,
                                     keep_ckpts=5))

    t_a = mk(str(tmp_path / "a"), 10, 100)
    state_a, _ = t_a.train(jax.random.PRNGKey(7), verbose=False)

    t_b1 = mk(str(tmp_path / "b"), 5, 5)
    t_b1.train(jax.random.PRNGKey(7), verbose=False)
    t_b2 = mk(str(tmp_path / "b"), 10, 100)
    state_b, hist_b = t_b2.train(jax.random.PRNGKey(999), verbose=False)
    assert len(hist_b) == 5                     # resumed at step 5
    for a, b in zip(jax.tree.leaves(state_a["params"]),
                    jax.tree.leaves(state_b["params"])):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_bf16_grad_accum_runs():
    model = build_model(_tiny())
    opt = AdamW(constant_schedule(1e-3))
    f = jax.jit(make_train_step(model, opt, accum=2,
                                grad_accum_dtype="bfloat16"))
    loader = _loader(rows=4)
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": opt.init(params)}
    state, metrics = f(state, loader.batch(0))
    assert np.isfinite(float(metrics["loss"]))


def test_history_reports_real_and_buffer_tokens():
    """Metering derives real tokens from segment_ids > 0 in the batch —
    never from an optional loss metric — and logs the buffer count too."""
    model = build_model(_tiny())
    opt = AdamW(constant_schedule(1e-3))
    loader = _loader(rows=4, seq=64)
    tr = Trainer(model, opt, loader, TrainerConfig(steps=3, log_every=100))
    _, hist = tr.train(jax.random.PRNGKey(0), verbose=False)
    assert len(hist) == 3
    for step, row in enumerate(hist):
        seg = np.asarray(loader.batch(step)["segment_ids"])
        assert row["real_tokens"] == float((seg > 0).sum())
        assert row["buffer_tokens"] == float(seg.size)
        assert 0 < row["real_tokens"] <= row["buffer_tokens"]


def test_resume_is_deterministic_under_prefetch(tmp_path):
    """Mid-stream checkpoint -> restore replays the exact stream even with
    the background prefetcher in the loop (batch(step) is memoized, never
    consumed)."""
    from repro.data.prefetch import PrefetchLoader
    model = build_model(_tiny())

    def mk(dirname, steps, every, prefetch):
        opt = AdamW(constant_schedule(1e-3))
        loader = _loader()
        if prefetch:
            loader = PrefetchLoader(loader, depth=2)
        return Trainer(model, opt, loader,
                       TrainerConfig(steps=steps, log_every=100,
                                     ckpt_every=every, ckpt_dir=dirname,
                                     keep_ckpts=5))

    t_a = mk(str(tmp_path / "a"), 10, 100, prefetch=False)
    state_a, _ = t_a.train(jax.random.PRNGKey(7), verbose=False)

    t_b1 = mk(str(tmp_path / "b"), 5, 5, prefetch=True)
    t_b1.train(jax.random.PRNGKey(7), verbose=False)
    t_b2 = mk(str(tmp_path / "b"), 10, 100, prefetch=True)
    state_b, hist_b = t_b2.train(jax.random.PRNGKey(999), verbose=False)
    assert len(hist_b) == 5                     # resumed at step 5
    for a, b in zip(jax.tree.leaves(state_a["params"]),
                    jax.tree.leaves(state_b["params"])):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_single_vs_padding_vs_pack_same_model():
    """All three paper regimes drive the same model/loss code."""
    model = build_model(_tiny())
    opt = AdamW(constant_schedule(1e-3))
    f = jax.jit(make_train_step(model, opt))
    for mode, rows in (("pack", 4), ("pad", 4), ("single", 1)):
        loader = _loader(rows=rows, mode=mode)
        params = model.init(jax.random.PRNGKey(0))
        state = {"params": params, "opt": opt.init(params)}
        batch = loader.batch(0)
        if mode == "single":
            f2 = jax.jit(make_train_step(model, opt))
            state, metrics = f2(state, batch)
        else:
            state, metrics = f(state, batch)
        assert np.isfinite(float(metrics["loss"])), mode
