"""Tests for the shape-keyed autotuning subsystem (repro/tune) and the
dual-form in-chunk evaluator it tunes over.

Covers (ISSUE 4): cache round-trip; fingerprint mismatch forcing a re-tune;
bucketed + nearest-key lookup; ``scan_tune="off"`` tracing identically to
the hard-coded defaults (and never consulting the tuner); dual-vs-quad
fwd/grad parity against the sequential reference on both the XLA path and
the Pallas (interpret) kernels; the shared timing helper's injectable
clock; the runner sweep; and the perf/config override mapping.
"""
import dataclasses
import json

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import ssm as core_ssm
from repro.kernels import ops as kops
from repro.models.lm import build_model
from repro.tune import (ShapeKey, TuneCache, shape_key, space_for, tuned,
                        tuned_config_overrides, l_bucket, reset_bucket)
from repro.tune import cache as tcache
from repro.tune import runner as trunner

FP_A = {"schema": 1, "device_kind": "cpu", "platform": "cpu", "jax": "1"}
FP_B = {"schema": 1, "device_kind": "v5e", "platform": "tpu", "jax": "1"}


@pytest.fixture(autouse=True)
def _fresh_cache_registry():
    tcache.reset_caches()
    yield
    tcache.reset_caches()


# ---------------------------------------------------------------------------
# shape keys + buckets
# ---------------------------------------------------------------------------

def test_l_bucket_and_reset_bands():
    assert l_bucket(1) == 16
    assert l_bucket(256) == 256
    assert l_bucket(300) == 512
    assert reset_bucket(0.0) == "none"
    assert reset_bucket(1 / 1000) == "sparse"
    assert reset_bucket(1 / 100) == "mid"
    assert reset_bucket(0.5) == "dense"
    assert reset_bucket(None) == "mid"      # packed, density unknown


def test_shape_key_encode_roundtrip():
    k = shape_key("selective_scan_heads", B=2, L=300, H=4, dh=64, N=16)
    assert k.Lb == 512
    assert ShapeKey.decode(k.encode()) == k


def test_shape_key_objective_roundtrip_and_legacy_decode():
    """fwdbwd keys append a 10th field; fwd keys encode byte-identically
    to the 9-field pre-objective format (committed caches stay valid) and
    9-field strings decode as objective='fwd'."""
    kf = shape_key("selective_scan", B=1, L=256, D=64, N=8)
    kb = shape_key("selective_scan", B=1, L=256, D=64, N=8,
                   objective="fwdbwd")
    assert kf.objective == "fwd"
    assert kf.encode().count("|") == 8            # legacy 9-field format
    assert kb.encode() == kf.encode() + "|fwdbwd"
    assert ShapeKey.decode(kb.encode()) == kb
    assert ShapeKey.decode(kf.encode()) == kf     # 9 fields -> fwd
    with pytest.raises(ValueError):
        shape_key("selective_scan", B=1, L=256, D=64, N=8,
                  objective="backward-only")


def test_nearest_lookup_never_crosses_objectives():
    """A forward-tuned winner must not be served to a training (fwdbwd)
    query, and vice versa — the schedules optimize different graphs."""
    c = TuneCache(fp=FP_A)
    kf = shape_key("selective_scan", B=1, L=512, D=256, N=16)
    c.put(kf, {"backend": "xla", "method": "associative"}, 10.0)
    near_fwd = shape_key("selective_scan", B=1, L=600, D=256, N=16)
    assert c.lookup(near_fwd)[1] == "nearest"
    near_bwd = shape_key("selective_scan", B=1, L=600, D=256, N=16,
                         objective="fwdbwd")
    assert c.lookup(near_bwd) == (None, None)
    # and a fwdbwd entry resolves for fwdbwd queries only
    kb = shape_key("selective_scan", B=1, L=512, D=256, N=16,
                   objective="fwdbwd")
    c.put(kb, {"backend": "xla", "method": "blocked", "chunk": 64}, 20.0)
    got, how = c.lookup(near_bwd)
    assert how == "nearest" and got["method"] == "blocked"
    assert c.lookup(near_fwd)[0]["method"] == "associative"


def test_runner_fwdbwd_objective_sweeps_and_caches(monkeypatch):
    """The fwdbwd thunk (jit value_and_grad over the candidate scan) runs,
    and ensure() keys the measurement under the objective-tagged entry."""
    monkeypatch.setattr(
        trunner, "space_for",
        lambda key, include_pallas=False: [
            {"backend": "xla", "method": "blocked", "chunk": 16,
             "intra": "quad"},
            {"backend": "xla", "method": "sequential"},
        ])
    c = TuneCache()
    assert trunner.ensure("selective_scan_heads", B=1, L=64, H=2, dh=8,
                          N=4, cache=c, rounds=1, objective="fwdbwd")
    kb = shape_key("selective_scan_heads", B=1, L=64, H=2, dh=8, N=4,
                   objective="fwdbwd")
    assert kb.encode() in c.entries
    # the forward entry is untouched -> a fwd ensure() measures separately
    kf = shape_key("selective_scan_heads", B=1, L=64, H=2, dh=8, N=4)
    assert kf.encode() not in c.entries
    assert trunner.ensure("selective_scan_heads", B=1, L=64, H=2, dh=8,
                          N=4, cache=c, rounds=1)
    assert kf.encode() in c.entries
    # cached -> no re-measure
    assert trunner.ensure("selective_scan_heads", B=1, L=64, H=2, dh=8,
                          N=4, cache=c, objective="fwdbwd") is False


def test_space_bounded_and_has_dual():
    k = shape_key("selective_scan_heads", B=1, L=1024, H=2, dh=128, N=16)
    cands = space_for(k)
    assert 0 < len(cands) <= 16
    intras = {c.get("intra") for c in cands}
    assert {"quad", "dual"} <= intras
    # xla-only unless pallas explicitly included
    assert all(c["backend"] == "xla" for c in cands)
    assert any(c["backend"] == "pallas"
               for c in space_for(k, include_pallas=True))


# ---------------------------------------------------------------------------
# cache: round-trip, fingerprint, nearest-key
# ---------------------------------------------------------------------------

def test_cache_roundtrip(tmp_path):
    c = TuneCache(fp=FP_A)
    k = shape_key("selective_scan", B=1, L=256, D=64, N=8)
    c.put(k, {"backend": "xla", "method": "blocked", "chunk": 32}, 123.4,
          candidates=5)
    p = c.save(str(tmp_path / "tc.json"))
    c2 = TuneCache.load(p, fp=FP_A)
    assert not c2.stale
    knobs, how = c2.lookup(k)
    assert how == "exact"
    assert knobs == {"backend": "xla", "method": "blocked", "chunk": 32}
    # bucketed: any L in the same power-of-two bucket hits the same entry
    same_bucket = shape_key("selective_scan", B=1, L=200, D=64, N=8)
    assert c2.lookup(same_bucket)[1] == "exact"


def test_fingerprint_mismatch_forces_retune(tmp_path):
    c = TuneCache(fp=FP_A)
    k = shape_key("selective_scan", B=1, L=256, D=64, N=8)
    c.put(k, {"backend": "xla", "method": "fused_seq"}, 50.0)
    p = c.save(str(tmp_path / "tc.json"))
    c2 = TuneCache.load(p, fp=FP_B)          # other device kind
    assert c2.stale and not c2.entries and c2.stale_entries
    assert c2.lookup(k) == (None, None)      # never serves stale knobs
    # tuned() therefore falls back to the caller's defaults
    kn = tuned("selective_scan", B=1, L=256, D=64, N=8, cache=c2,
               default={"method": "blocked"})
    assert kn == {"method": "blocked"}


def test_save_preserves_foreign_entries(tmp_path):
    """Round-tripping a shared cache file through a foreign machine must
    not destroy the original machine's measurements."""
    p = str(tmp_path / "tc.json")
    k_a = shape_key("selective_scan", B=1, L=256, D=64, N=8)
    k_b = shape_key("selective_scan", B=1, L=512, D=64, N=8)
    a = TuneCache(fp=FP_A)
    a.put(k_a, {"backend": "xla", "method": "blocked", "chunk": 32}, 10.0)
    a.save(p)
    # machine B: A's entries quarantined, B tunes its own and saves
    b = TuneCache.load(p, fp=FP_B)
    assert b.stale and b.lookup(k_a) == (None, None)
    b.put(k_b, {"backend": "xla", "method": "fused_seq"}, 20.0)
    b.save(p)
    # back on machine A: its entry is resurrected, B's is quarantined
    a2 = TuneCache.load(p, fp=FP_A)
    knobs, how = a2.lookup(k_a)
    assert how == "exact" and knobs["chunk"] == 32
    assert a2.stale_entries and a2.lookup(k_b, nearest=False) == (None, None)
    # and on machine B again, B's entry survives too
    b2 = TuneCache.load(p, fp=FP_B)
    assert b2.lookup(k_b)[1] == "exact"


def test_nearest_key_fallback_never_blocks():
    c = TuneCache(fp=FP_A)
    k512 = shape_key("selective_scan", B=1, L=512, D=256, N=16)
    k4k = shape_key("selective_scan", B=1, L=4096, D=256, N=16)
    c.put(k512, {"backend": "xla", "method": "associative"}, 10.0)
    c.put(k4k, {"backend": "xla", "method": "blocked", "chunk": 128}, 99.0)
    # unseen shape resolves to the closest key of the same op
    got, how = c.lookup(shape_key("selective_scan", B=1, L=3000, D=512,
                                  N=16))
    assert how == "nearest" and got["method"] == "blocked"
    got, how = c.lookup(shape_key("selective_scan", B=1, L=600, D=256,
                                  N=16))
    assert how == "nearest" and got["method"] == "associative"
    # but never across ops
    assert c.lookup(shape_key("selective_scan_heads", B=1, L=512, H=4,
                              dh=64, N=16)) == (None, None)
    # and never across the distance cutoff: regime-gated winners (here
    # 'associative', offered only at short L) must not be served to a
    # far-away shape — beyond max_distance the lookup misses cleanly
    assert c.lookup(shape_key("selective_scan", B=1, L=32768, D=256,
                              N=16)) == (None, None)


def test_tuned_merges_over_defaults():
    c = TuneCache(fp=FP_A)
    k = shape_key("selective_scan_heads", B=1, L=256, H=4, dh=16, N=8)
    c.put(k, {"backend": "xla", "method": "blocked", "intra": "dual"}, 5.0)
    kn = tuned("selective_scan_heads", B=1, L=256, H=4, dh=16, N=8,
               cache=c, default={"method": "blocked", "chunk": 64})
    assert kn == {"backend": "xla", "method": "blocked", "chunk": 64,
                  "intra": "dual"}


def test_cache_check_cli(tmp_path, capsys):
    p = str(tmp_path / "tc.json")
    c = TuneCache()                          # real current fingerprint
    c.put(shape_key("selective_scan", B=1, L=64, D=8, N=4),
          {"backend": "xla", "method": "fused_seq"}, 1.0)
    c.save(p)
    import sys
    argv = sys.argv
    try:
        sys.argv = ["cache.py", "--check", p]
        tcache._main()
        assert "OK" in capsys.readouterr().out
        # stale file: rewrite with a foreign fingerprint
        doc = json.load(open(p))
        doc["fingerprint"]["device_kind"] = "not-this-machine"
        json.dump(doc, open(p, "w"))
        tcache._main()
        assert "STALE" in capsys.readouterr().out
        sys.argv = ["cache.py", "--check", str(tmp_path / "absent.json")]
        with pytest.raises(SystemExit):
            tcache._main()
    finally:
        sys.argv = argv


# ---------------------------------------------------------------------------
# scan_tune="off" is bit-identical and never consults the tuner
# ---------------------------------------------------------------------------

def _tiny_cfg(**kw):
    return dataclasses.replace(get_config("mamba-110m").reduced(),
                               n_layers=2, **kw)


def _fwd_jaxpr(cfg, monkeypatch=None):
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    L = 32
    batch = {"tokens": jnp.asarray(rng.integers(1, cfg.vocab, (2, L)),
                                   jnp.int32),
             "positions": jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32),
                                           (2, L)) % 20,
             "segment_ids": jnp.ones((2, L), jnp.int32)}
    return str(jax.make_jaxpr(model.forward)(params, batch))


def test_scan_tune_off_never_consults_tuner(monkeypatch, tmp_path):
    import repro.tune
    def boom(*a, **k):
        raise AssertionError("tuner consulted with scan_tune='off'")
    monkeypatch.setattr(repro.tune, "tuned", boom)
    _fwd_jaxpr(_tiny_cfg(scan_tune="off"))          # must not raise


@pytest.mark.parametrize("variant", ["mamba1", "mamba2"])
def test_scan_tune_off_jaxpr_identical_to_defaults(variant, tmp_path,
                                                   monkeypatch):
    """off == auto-with-empty-cache (defaults served on miss) == the
    pre-tuner trace; a cache entry then actually changes the schedule."""
    monkeypatch.setenv(tcache.ENV_PATH, str(tmp_path / "tc.json"))
    tcache.reset_caches()
    kw = {} if variant == "mamba1" else {"ssm_variant": "mamba2",
                                         "ssm_head_dim": 16}
    off = _fwd_jaxpr(_tiny_cfg(scan_tune="off", **kw))
    auto_empty = _fwd_jaxpr(_tiny_cfg(scan_tune="auto", **kw))
    assert off == auto_empty
    # now cache a different winner for this op → the trace must change
    cfg = _tiny_cfg(scan_tune="auto", **kw)
    c = tcache.get_cache()
    if variant == "mamba1":
        c.put(shape_key("selective_scan", B=2, L=32, D=cfg.d_inner,
                        N=cfg.d_state),
              {"backend": "xla", "method": "fused_seq"}, 1.0)
    else:
        c.put(shape_key("selective_scan_heads", B=2, L=32,
                        H=cfg.n_ssm_heads, dh=cfg.ssm_hd, N=cfg.d_state),
              {"backend": "xla", "method": "blocked", "chunk": 16,
               "intra": "dual"}, 1.0)
    assert _fwd_jaxpr(cfg) != off


def test_heads_default_intra_is_quad_jaxpr():
    """intra=None must trace exactly as the historical (quad) path."""
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.normal(size=(1, 64, 4, 8)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.5, (1, 64, 4)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(1, 64, 8)), jnp.float32)
    A = -jnp.exp(jnp.asarray(rng.normal(size=(4,)), jnp.float32))
    f = lambda intra: str(jax.make_jaxpr(
        lambda u, dt, Bm: core_ssm.selective_scan_heads(
            u, dt, A, Bm, Bm, None, method="blocked", chunk=32,
            intra=intra))(u, dt, Bm))
    assert f(None) == f("quad")
    assert f(None) != f("dual")


# ---------------------------------------------------------------------------
# dual-form vs quad-form parity (XLA + Pallas interpret)
# ---------------------------------------------------------------------------

def _heads_inputs(B=2, L=96, H=3, P=16, N=8, seed=3):
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.normal(size=(B, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.4, (B, L, H)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, L, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, L, N)), jnp.float32)
    A = -jnp.exp(jnp.asarray(rng.normal(size=(H,)), jnp.float32))
    Dk = jnp.asarray(rng.normal(size=(H,)), jnp.float32)
    pos = jnp.asarray(np.concatenate(
        [np.arange(41), np.arange(30), np.arange(L - 71)])[None]
        .repeat(B, 0), jnp.int32)
    return u, dt, Bm, Cm, A, Dk, pos


@pytest.mark.parametrize("chunk", [16, 32, 64])
def test_dual_fwd_parity_xla(chunk):
    u, dt, Bm, Cm, A, Dk, pos = _heads_inputs()
    ref = core_ssm.selective_scan_heads(u, dt, A, Bm, Cm, Dk, pos,
                                        method="sequential")
    got = core_ssm.selective_scan_heads(u, dt, A, Bm, Cm, Dk, pos,
                                        method="blocked", chunk=chunk,
                                        intra="dual")
    np.testing.assert_allclose(got, ref, atol=2e-5, rtol=1e-5)


def test_dual_grad_parity_xla():
    u, dt, Bm, Cm, A, Dk, pos = _heads_inputs()

    def loss(intra):
        def f(u, dt, Bm, Cm):
            kw = dict(method="sequential") if intra == "seq" else \
                dict(method="blocked", chunk=32, intra=intra)
            y = core_ssm.selective_scan_heads(u, dt, A, Bm, Cm, Dk, pos,
                                              **kw)
            return (y ** 2).sum()
        return jax.grad(f, argnums=(0, 1, 2, 3))(u, dt, Bm, Cm)

    for g_d, g_r in zip(loss("dual"), loss("seq")):
        np.testing.assert_allclose(g_d, g_r, atol=2e-3, rtol=1e-4)


def test_dual_state_and_ends_parity():
    """h_last carry + collect_ends handoff match sequential under dual."""
    u, dt, Bm, Cm, A, Dk, pos = _heads_inputs()
    rng = np.random.default_rng(7)
    h0 = jnp.asarray(rng.normal(size=(2, 3, 16, 8)), jnp.float32)
    ends = jnp.asarray([[40, 70, 95, -1], [40, -1, 95, 70]], jnp.int32)
    ref = core_ssm.selective_scan_heads(
        u, dt, A, Bm, Cm, Dk, pos, h0=h0, method="sequential",
        return_state=True, collect_ends=ends)
    got = core_ssm.selective_scan_heads(
        u, dt, A, Bm, Cm, Dk, pos, h0=h0, method="blocked", chunk=32,
        intra="dual", return_state=True, collect_ends=ends)
    for a, b in zip(ref, got):
        np.testing.assert_allclose(b, a, atol=2e-5, rtol=1e-5)


def test_dual_pallas_fwd_and_grad_parity():
    u, dt, Bm, Cm, A, Dk, pos = _heads_inputs()
    ref = core_ssm.selective_scan_heads(u, dt, A, Bm, Cm, Dk, pos,
                                        method="sequential")
    y = kops.selective_scan_heads(u, dt, A, Bm, Cm, Dk, pos,
                                  backend="pallas", chunk=32,
                                  schedule="blocked_heads_dual")
    np.testing.assert_allclose(y, ref, atol=2e-5, rtol=1e-5)
    # tuned subtile override
    y8 = kops.selective_scan_heads(u, dt, A, Bm, Cm, Dk, pos,
                                   backend="pallas", chunk=32,
                                   schedule="blocked_heads_dual", sub_t=8)
    np.testing.assert_allclose(y8, ref, atol=2e-5, rtol=1e-5)

    def loss(fn):
        return jax.grad(lambda u, dt, Bm, Cm: (fn(u, dt, Bm, Cm) ** 2).sum(),
                        argnums=(0, 1, 2, 3))(u, dt, Bm, Cm)

    g_d = loss(lambda u, dt, Bm, Cm: kops.selective_scan_heads(
        u, dt, A, Bm, Cm, Dk, pos, backend="pallas", chunk=32,
        schedule="blocked_heads_dual"))
    g_r = loss(lambda u, dt, Bm, Cm: core_ssm.selective_scan_heads(
        u, dt, A, Bm, Cm, Dk, pos, method="sequential"))
    for a, b in zip(g_d, g_r):
        np.testing.assert_allclose(a, b, atol=2e-3, rtol=1e-4)


def test_pallas_non_dividing_sub_t_degrades_not_raises():
    """A tuned sub_t from another L bucket must degrade to a valid subtile
    (largest divisor ≤ request), never crash the trace."""
    from repro.kernels.selective_scan import _pick_subtile
    assert _pick_subtile(32, 7) == 4
    assert _pick_subtile(16, 32) == 16
    u, dt, Bm, Cm, A, Dk, pos = _heads_inputs()
    ref = core_ssm.selective_scan_heads(u, dt, A, Bm, Cm, Dk, pos,
                                        method="sequential")
    y = kops.selective_scan_heads(u, dt, A, Bm, Cm, Dk, pos,
                                  backend="pallas", chunk=32, sub_t=7)
    np.testing.assert_allclose(y, ref, atol=2e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# timing helper + runner
# ---------------------------------------------------------------------------

def test_interleaved_min_of_rounds_injectable_clock():
    from benchmarks.timing import interleaved_min_of_rounds
    t = [0.0]
    # fake clock: "a" costs 10us, "b" costs 5us, with a drifty round
    costs = iter([10e-6, 5e-6, 30e-6, 25e-6, 10e-6, 5e-6])

    def clock():
        return t[0]

    calls = {"a": 0, "b": 0}

    def mk(name):
        def thunk():
            calls[name] += 1
            t[0] += next(costs)
            return name
        return thunk

    best, last = interleaved_min_of_rounds(
        [("a", mk("a")), ("b", mk("b"))], rounds=3, warmup=0,
        clock=clock, sync=lambda x: x)
    assert calls == {"a": 3, "b": 3}
    assert best["a"] == pytest.approx(10.0)       # min over rounds, in us
    assert best["b"] == pytest.approx(5.0)
    assert last == {"a": "a", "b": "b"}


def test_runner_tune_key_and_ensure(monkeypatch):
    # shrink the space so the sweep is a smoke test (one real + one broken
    # candidate: the broken one must be dropped, not crash the sweep)
    monkeypatch.setattr(
        trunner, "space_for",
        lambda key, include_pallas=False: [
            {"backend": "xla", "method": "blocked", "chunk": 16,
             "intra": "quad" if key.op == "selective_scan_heads"
             else "assoc"},
            {"backend": "xla", "method": "sequential"},
            {"backend": "xla", "method": "not-a-method"},
        ])
    c = TuneCache()
    k = shape_key("selective_scan_heads", B=1, L=64, H=2, dh=8, N=4)
    knobs = trunner.tune_key(k, cache=c, rounds=1)
    assert knobs["method"] in ("blocked", "sequential")
    rec = c.entries[k.encode()]
    assert rec["candidates"] == 2                 # broken one dropped
    # ensure(): cached key → no re-measure
    assert trunner.ensure("selective_scan_heads", B=1, L=64, H=2, dh=8,
                          N=4, cache=c) is False


def test_synth_positions_density():
    p = trunner.synth_positions(np.random.default_rng(0), 2, 256, "mid")
    assert p.shape == (2, 256)
    assert int((p == 0).sum(axis=1)[0]) == 256 // 100 + 1
    flat = trunner.synth_positions(np.random.default_rng(0), 1, 64, "none")
    assert int((flat == 0).sum()) == 1


# ---------------------------------------------------------------------------
# config / perf integration
# ---------------------------------------------------------------------------

def test_tuned_config_overrides_mapping():
    c = TuneCache(fp=FP_A)
    cfg = _tiny_cfg(ssm_variant="mamba2", ssm_head_dim=16)
    c.put(shape_key("selective_scan_heads", B=8, L=512, H=cfg.n_ssm_heads,
                    dh=cfg.ssm_hd, N=cfg.d_state),
          {"backend": "xla", "method": "blocked", "chunk": 32,
           "intra": "dual"}, 4.2)
    ov = tuned_config_overrides(cfg, B=8, L=512, cache=c)
    assert ov == {"scan_impl": "blocked", "scan_chunk": 32,
                  "scan_intra": "dual"}
    # pallas winner maps to the kernel-path toggles
    cfg1 = _tiny_cfg()
    c.put(shape_key("selective_scan", B=8, L=512, D=cfg1.d_inner,
                    N=cfg1.d_state, dtype=cfg1.dtype),
          {"backend": "pallas", "schedule": "blocked", "pchunk": 128},
          3.0)
    ov = tuned_config_overrides(cfg1, B=8, L=512, cache=c)
    assert ov == {"use_pallas": True, "pallas_schedule": "blocked"}
    # no scan hot path → no overrides
    assert tuned_config_overrides(get_config("gemma-7b"), B=8, L=512,
                                  cache=c) == {}


def test_model_forward_with_dual_tuned_cache_matches_off(tmp_path,
                                                         monkeypatch):
    """Numerics stay put when the tuner picks a different (valid) schedule:
    a dual-form winner must produce the same logits as the default path."""
    monkeypatch.setenv(tcache.ENV_PATH, str(tmp_path / "tc.json"))
    tcache.reset_caches()
    kw = {"ssm_variant": "mamba2", "ssm_head_dim": 16}
    cfg_off = _tiny_cfg(scan_tune="off", **kw)
    cfg_auto = _tiny_cfg(scan_tune="auto", **kw)
    c = tcache.get_cache()
    c.put(shape_key("selective_scan_heads", B=2, L=32, H=cfg_off.n_ssm_heads,
                    dh=cfg_off.ssm_hd, N=cfg_off.d_state),
          {"backend": "xla", "method": "blocked", "chunk": 16,
           "intra": "dual"}, 1.0)
    model_off, model_auto = build_model(cfg_off), build_model(cfg_auto)
    params = model_off.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    L = 32
    batch = {"tokens": jnp.asarray(rng.integers(1, cfg_off.vocab, (2, L)),
                                   jnp.int32),
             "positions": jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32),
                                           (2, L)) % 20,
             "segment_ids": jnp.ones((2, L), jnp.int32)}
    y_off = model_off.forward(params, batch)
    y_auto = model_auto.forward(params, batch)
    np.testing.assert_allclose(np.asarray(y_auto), np.asarray(y_off),
                               atol=2e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# compare.py: all offenders in one run
# ---------------------------------------------------------------------------

def test_compare_reports_all_offenders(tmp_path):
    import benchmarks.compare as cmp
    old = [{"op": "s", "shape": "a", "schedule": "x", "us_per_call": 100.0,
            "tok_per_s": 1},
           {"op": "s", "shape": "a", "schedule": "y", "us_per_call": 100.0,
            "tok_per_s": 1},
           {"op": "s", "shape": "a", "schedule": "z", "us_per_call": 100.0,
            "tok_per_s": 1}]
    new = [dict(r, us_per_call=us) for r, us in
           zip(old, (150.0, 95.0, 200.0))]
    po, pn = str(tmp_path / "o.json"), str(tmp_path / "n.json")
    json.dump(old, open(po, "w"))
    json.dump(new, open(pn, "w"))
    lines, offenders = cmp.compare(po, pn, pct=10.0)
    # BOTH regressions reported in one pass, plus the header + ok row
    assert len(offenders) == 2
    assert {o[0] for o in offenders} == {"s/a/x", "s/a/z"}
    assert any("ok" in ln and "s/a/y" in ln for ln in lines)


def test_compare_missing_required_still_reports_other_pairs(tmp_path,
                                                            capsys,
                                                            monkeypatch):
    """A missing required pair fails the gate but must not hide offenders
    in the remaining pairs (one run surfaces everything)."""
    import benchmarks.compare as cmp
    row = {"op": "s", "shape": "a", "schedule": "x", "tok_per_s": 1}
    po, pn = str(tmp_path / "o.json"), str(tmp_path / "n.json")
    json.dump([dict(row, us_per_call=100.0)], open(po, "w"))
    json.dump([dict(row, us_per_call=200.0)], open(pn, "w"))
    absent = str(tmp_path / "absent.json")
    monkeypatch.setattr("sys.argv", ["compare.py", "--pair", absent, absent,
                                     "--pair", po, pn])
    with pytest.raises(SystemExit) as e:
        cmp.main()
    assert e.value.code == 1
    out = capsys.readouterr().out
    assert "MISSING required" in out
    assert "s/a/x" in out and "+100.0%" in out     # 2nd pair still compared
