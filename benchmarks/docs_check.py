"""Keep the documentation honest — the CI `docs-check` lane.

Two checks, one invocation (`make docs-check`):

1. **Bench table.** README.md carries a "current numbers" table between
   ``<!-- BENCH_TABLE_START -->`` / ``<!-- BENCH_TABLE_END -->`` markers.
   This script regenerates that table from the *committed* benchmark
   baselines (BENCH_scan.json / BENCH_serve.json / BENCH_train.json) and
   fails if the README text differs — stale numbers in the README are a
   CI failure, not a review nit. ``--write`` regenerates the block in
   place (run it after `make bench-accept` promotes new baselines).

2. **Path references.** Every repo path mentioned in README.md and
   docs/*.md (anything shaped like ``src/…``, ``docs/…``, ``examples/…``,
   ``benchmarks/…``, ``tests/…``, or ``Makefile``) must exist. Docs that
   point at renamed or deleted files fail CI the moment the rename lands.

The table renderer is deliberately lossy: scan rows collapse to
baseline-vs-best per shape, serve/train rows print throughput and TTFT.
The committed JSON stays the source of truth; the README is a view.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
README = os.path.join(ROOT, "README.md")
START, END = "<!-- BENCH_TABLE_START -->", "<!-- BENCH_TABLE_END -->"

SCAN_JSON = os.path.join(ROOT, "BENCH_scan.json")
SERVE_JSON = os.path.join(ROOT, "BENCH_serve.json")
TRAIN_JSON = os.path.join(ROOT, "BENCH_train.json")

# what counts as a repo-path reference inside the prose/code of the docs
PATH_RE = re.compile(
    r"(?<![\w/.-])((?:src|docs|examples|benchmarks|tests)/"
    r"[A-Za-z0-9_./-]+|Makefile)(?![\w-])")


# ------------------------------------------------------------- bench table
def _load(path):
    with open(path) as f:
        return json.load(f)


def render_table():
    """The canonical README bench block (list of lines, no markers)."""
    lines = [
        "Numbers are single-CPU-host JAX timings from the committed",
        "baselines (regenerate: `make bench-scan` / `make bench-serve` /",
        "`make bench-train`, then `make bench-accept`; refresh this table",
        "with `make docs-check WRITE=--write`).",
        "",
    ]

    scan = _load(SCAN_JSON)
    by_shape = {}
    for r in scan:
        by_shape.setdefault(r["shape"], []).append(r)
    lines += [
        "**Selective-scan schedules** (BENCH_scan.json — per shape, the "
        "best Mamba-1 schedule vs its `chunked` baseline; Mamba-2/SSD "
        "rows are a different operator so they get their own column):",
        "",
        "| shape | chunked us | best M1 schedule | best M1 us | speedup "
        "| best M2 schedule | best M2 us |",
        "|---|---|---|---|---|---|---|",
    ]
    for shape in sorted(by_shape, key=lambda s: (len(s), s)):
        rows = by_shape[shape]
        m1 = [r for r in rows if not r["schedule"].startswith("mamba2")]
        m2 = [r for r in rows if r["schedule"].startswith("mamba2")]
        base = next((r for r in m1 if r["schedule"] == "chunked"), None)
        if base is None or not m1:
            continue
        best = min(m1, key=lambda r: r["us_per_call"])
        speed = base["us_per_call"] / best["us_per_call"]
        cell = "| — | — |"
        if m2:
            b2 = min(m2, key=lambda r: r["us_per_call"])
            cell = f"| {b2['schedule']} | {b2['us_per_call']:.1f} |"
        lines.append(
            f"| {shape} | {base['us_per_call']:.1f} | {best['schedule']} "
            f"| {best['us_per_call']:.1f} | {speed:.2f}x {cell}")

    serve = _load(SERVE_JSON)
    lines += [
        "",
        "**Serving** (BENCH_serve.json):",
        "",
        "| op | schedule | tok/s | TTFT p50 ms | notes |",
        "|---|---|---|---|---|",
    ]
    for r in serve:
        ttft = f"{r['ttft_p50_ms']:.2f}" if "ttft_p50_ms" in r else "—"
        notes = []
        if "hit_rate" in r:
            notes.append(f"hit_rate {r['hit_rate']:.2f}")
        if "spec_accept_rate" in r:
            notes.append(f"spec_accept {r['spec_accept_rate']:.2f}")
        if "arrival_rate_rps" in r:
            notes.append(f"{r['arrival_rate_rps']:.1f} req/s offered")
        lines.append(
            f"| {r['op']} | {r['schedule']} | {r['tok_per_s']:.0f} "
            f"| {ttft} | {', '.join(notes) or '—'} |")

    train = _load(TRAIN_JSON)
    lines += [
        "",
        "**Training** (BENCH_train.json — full train steps, real tok/s):",
        "",
        "| schedule | tok/s | padding rate |",
        "|---|---|---|",
    ]
    for r in train:
        pad = f"{r['padding_rate']:.2f}" if "padding_rate" in r else "—"
        lines.append(
            f"| {r['schedule']} | {r['tok_per_s']:.0f} | {pad} |")
    return lines


def check_table(write: bool):
    errs = []
    if not os.path.exists(README):
        return [f"{README}: missing (docs-check needs the README)"]
    with open(README) as f:
        text = f.read()
    if START not in text or END not in text:
        return [f"README.md: missing {START} / {END} markers"]
    head, rest = text.split(START, 1)
    current, tail = rest.split(END, 1)
    want = "\n" + "\n".join(render_table()) + "\n"
    if current != want:
        if write:
            with open(README, "w") as f:
                f.write(head + START + want + END + tail)
            print("# docs-check: rewrote README bench table")
        else:
            errs.append(
                "README.md: bench table is stale vs the committed "
                "BENCH_*.json — run `make docs-check WRITE=--write`")
    return errs


# --------------------------------------------------------- path references
def doc_files():
    files = [README] if os.path.exists(README) else []
    docs = os.path.join(ROOT, "docs")
    if os.path.isdir(docs):
        files += sorted(os.path.join(docs, n) for n in os.listdir(docs)
                        if n.endswith(".md"))
    return files

def check_paths():
    errs = []
    for path in doc_files():
        rel = os.path.relpath(path, ROOT)
        with open(path) as f:
            text = f.read()
        seen = set()
        for m in PATH_RE.finditer(text):
            ref = m.group(1).rstrip(".")
            # globs and templates aren't checkable references
            if any(c in ref for c in "*<>{}$"):
                continue
            if ref in seen:
                continue
            seen.add(ref)
            if not os.path.exists(os.path.join(ROOT, ref)):
                errs.append(f"{rel}: references missing path {ref!r}")
    return errs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--write", action="store_true",
                    help="regenerate the README bench table in place")
    args = ap.parse_args()

    errs = []
    for f in (SCAN_JSON, SERVE_JSON, TRAIN_JSON):
        if not os.path.exists(f):
            errs.append(f"missing committed baseline {os.path.basename(f)}")
    if not errs:
        errs += check_table(args.write)
    errs += check_paths()

    for e in errs:
        print(f"# docs-check: {e}")
    if errs:
        sys.exit(1)
    print(f"# docs-check: OK ({len(doc_files())} doc file(s), bench table "
          f"in sync)")


if __name__ == "__main__":
    main()
