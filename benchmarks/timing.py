"""Shared wall-clock measurement discipline for benchmarks and the autotuner.

One helper, one protocol: *interleaved min-of-rounds*. All candidate cells
are warmed (compiled) first, then timed round-robin — each round times every
cell once — and each cell keeps its best round. Interleaving means a
machine-load drift hits every cell in the same round instead of biasing
whichever cell happened to own a contiguous timing block; min-of-rounds
discards the drifty rounds entirely. This is the protocol
``benchmarks/run.py`` fig2/serve always used; ``repro/tune/runner.py``
reuses it so tuner measurements are comparable with the benchmark matrix.

``clock`` and ``sync`` are injectable so tests can drive the loop with a
fake clock and no real device work.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Sequence, Tuple


def _default_sync(x):
    import jax
    return jax.block_until_ready(x)


def interleaved_min_of_rounds(
        cells: Sequence[Tuple[str, Callable[[], object]]],
        rounds: int = 7, warmup: int = 1,
        clock: Callable[[], float] = time.perf_counter,
        sync: Callable[[object], object] = _default_sync,
) -> Tuple[Dict[str, float], Dict[str, object]]:
    """Time ``cells`` — (name, thunk) pairs — under the shared protocol.

    Each thunk runs one full measurement unit (e.g. one jitted call, one
    serve wave); ``sync`` blocks on its result before the clock stops.
    Returns (best_us, last_result): per-cell best round in microseconds and
    the last synced thunk result (benchmarks that need a derived quantity,
    e.g. generated-token counts, read it from there).
    """
    best: Dict[str, float] = {}
    last: Dict[str, object] = {}
    for name, thunk in cells:               # compile / cache warm-up
        for _ in range(warmup):
            last[name] = sync(thunk())
        best[name] = float("inf")
    for _ in range(rounds):
        for name, thunk in cells:
            t0 = clock()
            r = thunk()
            sync(r)
            best[name] = min(best[name], (clock() - t0) * 1e6)
            last[name] = r
    return best, last
