"""Diff two benchmark JSON files (BENCH_scan.json / BENCH_serve.json) and
flag regressions.

    PYTHONPATH=src python benchmarks/compare.py OLD.json NEW.json [--pct 10]

Rows are joined on (op, shape, schedule). For every pair the us_per_call
delta is printed; rows slower by more than ``--pct`` percent are flagged as
REGRESSION and the exit code is nonzero (so `make bench-compare` can gate a
PR on the scan-schedule AND serve-throughput perf trajectories). Rows
present in only one file are listed as added/removed, never flagged — new
schedules (e.g. the mamba2 rows) must be able to land. ``--allow-missing``
turns an absent file into a no-op (exit 0) so one gate can cover benchmark
files that a given run didn't regenerate.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _key(rec):
    return (rec["op"], rec["shape"], rec["schedule"])


def load(path):
    with open(path) as f:
        recs = json.load(f)
    return {_key(r): r for r in recs}


def compare(old_path: str, new_path: str, pct: float = 10.0):
    """Returns (report lines, regression count)."""
    old, new = load(old_path), load(new_path)
    lines, regressions = [], 0
    for k in sorted(old.keys() | new.keys()):
        name = "/".join(k)
        if k not in new:
            lines.append(f"  removed   {name}")
            continue
        if k not in old:
            lines.append(f"  added     {name}  "
                         f"{new[k]['us_per_call']:.1f}us")
            continue
        o, n = old[k]["us_per_call"], new[k]["us_per_call"]
        delta = (n - o) / o * 100 if o else 0.0
        tag = "ok        "
        if delta > pct:
            tag = "REGRESSION"
            regressions += 1
        elif delta < -pct:
            tag = "improved  "
        lines.append(f"  {tag} {name}  {o:.1f} -> {n:.1f}us "
                     f"({delta:+.1f}%)")
    return lines, regressions


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("old")
    ap.add_argument("new")
    ap.add_argument("--pct", type=float, default=10.0,
                    help="regression threshold in percent (default 10)")
    ap.add_argument("--allow-missing", action="store_true",
                    help="exit 0 (no-op) if either file is absent")
    args = ap.parse_args()
    if args.allow_missing and not (os.path.exists(args.old) and
                                   os.path.exists(args.new)):
        missing = [p for p in (args.old, args.new) if not os.path.exists(p)]
        print(f"# skipping compare: missing {', '.join(missing)}")
        return
    lines, regressions = compare(args.old, args.new, args.pct)
    print(f"# {args.old} -> {args.new} (threshold {args.pct:.0f}%)")
    for ln in lines:
        print(ln)
    if regressions:
        print(f"# {regressions} regression(s) > {args.pct:.0f}%")
        sys.exit(1)
    print("# no regressions")


if __name__ == "__main__":
    main()
