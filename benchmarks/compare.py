"""Diff benchmark JSON files (BENCH_scan.json / BENCH_serve.json) and report
every regression in one run.

    PYTHONPATH=src python benchmarks/compare.py OLD.json NEW.json [--pct 10]
    PYTHONPATH=src python benchmarks/compare.py \
        --pair BENCH_scan.json BENCH_scan.new.json \
        --optional-pair BENCH_serve.json BENCH_serve.new.json

Rows are joined on (op, shape, schedule) and printed as an aligned delta
table — every pair, every row, never stopping at the first offender — then
a summary block lists ALL rows slower by more than ``--pct`` percent across
all pairs. The exit code is nonzero iff that list is non-empty (so
`make bench-compare` gates a PR on the scan-schedule AND serve-throughput
trajectories while still showing a multi-row regression in full).

Rows present in only one file are listed as added/removed, never flagged —
new schedules (e.g. the tuned/dual rows) must be able to land.
``--pair`` files are required (missing → nonzero exit: the primary gate
cannot pass vacuously); ``--optional-pair`` skips a pair whose files are
absent, so one gate can also cover benchmark files a given run didn't
regenerate.

``--accept`` promotes each pair's candidate over its baseline (copy NEW →
OLD, delete the staging file) after printing the delta table — the
human-in-the-loop step that keeps ``*.new.json`` staging files out of the
repo (`make bench-accept`). Accepting never fails on regressions: the
table shows them, the operator is choosing to take them.

``--schema FILE...`` is a structural check used by the CI bench smoke:
each file must be a JSON list of records with string op/shape/schedule,
positive numeric us_per_call/tok_per_s, numeric ttft_* fields when
present, and no duplicate (op, shape, schedule) keys. Timings are NOT
judged — CI machines are too noisy to gate on; the schema check catches a
benchmark that silently stopped emitting rows.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys


def _key(rec):
    return (rec["op"], rec["shape"], rec["schedule"])


def load(path):
    with open(path) as f:
        recs = json.load(f)
    return {_key(r): r for r in recs}


def compare(old_path: str, new_path: str, pct: float = 10.0):
    """One pair → (table lines, [(row, old_us, new_us, delta_pct), ...])."""
    old, new = load(old_path), load(new_path)
    keys = sorted(old.keys() | new.keys())
    width = max([len("/".join(k)) for k in keys] + [4])
    lines = [f"  {'status':<10} {'row':<{width}} {'old_us':>10} "
             f"{'new_us':>10} {'delta':>8}"]
    offenders = []
    for k in keys:
        name = "/".join(k)
        if k not in new:
            lines.append(f"  {'removed':<10} {name:<{width}}")
            continue
        n = new[k]["us_per_call"]
        if k not in old:
            lines.append(f"  {'added':<10} {name:<{width}} {'—':>10} "
                         f"{n:>10.1f}")
            continue
        o = old[k]["us_per_call"]
        delta = (n - o) / o * 100 if o else 0.0
        tag = "ok"
        if delta > pct:
            tag = "REGRESSION"
            offenders.append((name, o, n, delta))
        elif delta < -pct:
            tag = "improved"
        lines.append(f"  {tag:<10} {name:<{width}} {o:>10.1f} {n:>10.1f} "
                     f"{delta:>+7.1f}%")
    return lines, offenders


REQUIRED_STR = ("op", "shape", "schedule")
REQUIRED_NUM = ("us_per_call", "tok_per_s")
# scheduler-v2 serve rows carry arrival-process parameters (arrival_*),
# queue pressure (queue_*), and the engine-phase wall-time split
# (prefill_/chunk_/decode_/host_ms) next to the ttft percentiles; train
# rows split tok/s into real_/buffer_tok_per_s and carry the padding_rate
# connecting them (dtype lives in the schedule string, e.g. "pack_bf16") —
# all non-negative numbers when present
OPTIONAL_NUM_PREFIXES = ("ttft_", "arrival_", "queue_", "prefill_",
                         "chunk_", "decode_", "host_", "real_", "buffer_",
                         "padding_",
                         # serve_cached rows: StateCache hit ratio and
                         # insert/evict pressure (hit_/cache_), speculative
                         # decode accept rate and round counts (spec_)
                         "hit_", "cache_", "spec_")
# observability-cost fields (obs_overhead_pct on the serve packed_obs row)
# are deltas vs a baseline mode — legitimately negative under CPU timing
# noise, so they only need to be numeric
OPTIONAL_SIGNED_PREFIXES = ("obs_",)


def schema_errors(path):
    """Structural violations in one benchmark JSON file (see module doc)."""
    errs = []
    try:
        with open(path) as f:
            recs = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    if not isinstance(recs, list) or not recs:
        return [f"{path}: expected a non-empty JSON list of records"]
    seen = set()
    for i, r in enumerate(recs):
        if not isinstance(r, dict):
            errs.append(f"{path}[{i}]: not an object")
            continue
        for k in REQUIRED_STR:
            if not isinstance(r.get(k), str) or not r.get(k):
                errs.append(f"{path}[{i}]: missing/empty string field {k!r}")
        for k in REQUIRED_NUM:
            v = r.get(k)
            if not isinstance(v, (int, float)) or isinstance(v, bool) \
                    or v <= 0:
                errs.append(f"{path}[{i}]: field {k!r} must be a positive "
                            f"number, got {v!r}")
        for k, v in r.items():
            if any(k.startswith(p) for p in OPTIONAL_NUM_PREFIXES) and (
                    not isinstance(v, (int, float)) or isinstance(v, bool)
                    or v < 0):
                errs.append(f"{path}[{i}]: field {k!r} must be a "
                            f"non-negative number, got {v!r}")
            if any(k.startswith(p) for p in OPTIONAL_SIGNED_PREFIXES) and (
                    not isinstance(v, (int, float))
                    or isinstance(v, bool)):
                errs.append(f"{path}[{i}]: field {k!r} must be a number, "
                            f"got {v!r}")
        if all(isinstance(r.get(k), str) for k in REQUIRED_STR):
            key = _key(r)
            if key in seen:
                errs.append(f"{path}[{i}]: duplicate row {'/'.join(key)}")
            seen.add(key)
    return errs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("old", nargs="?")
    ap.add_argument("new", nargs="?")
    ap.add_argument("--pair", nargs=2, action="append", default=[],
                    metavar=("OLD", "NEW"),
                    help="a required baseline/candidate file pair "
                         "(repeatable; missing files fail the gate)")
    ap.add_argument("--optional-pair", nargs=2, action="append", default=[],
                    metavar=("OLD", "NEW"),
                    help="like --pair but skipped when a file is absent "
                         "(for benchmark files a run didn't regenerate)")
    ap.add_argument("--pct", type=float, default=10.0,
                    help="regression threshold in percent (default 10)")
    ap.add_argument("--allow-missing", action="store_true",
                    help="treat EVERY pair as optional")
    ap.add_argument("--accept", action="store_true",
                    help="promote each pair's NEW file over its baseline "
                         "(copy NEW -> OLD, delete the staging file) after "
                         "showing the delta table; never fails on "
                         "regressions")
    ap.add_argument("--schema", nargs="+", metavar="FILE", default=None,
                    help="structural check of benchmark JSON files "
                         "(required fields/types, no duplicate rows); "
                         "timings are not judged")
    args = ap.parse_args()

    if args.schema is not None:
        errs = []
        for path in args.schema:
            e = schema_errors(path)
            errs += e
            if e:
                print(f"# schema {path}: {len(e)} error(s)")
            else:
                with open(path) as f:
                    print(f"# schema {path}: OK, {len(json.load(f))} "
                          f"records")
        for msg in errs:
            print(f"#   {msg}")
        sys.exit(1 if errs else 0)

    pairs = [(o, n, False) for o, n in args.pair] + \
            [(o, n, True) for o, n in args.optional_pair]
    if args.old or args.new:
        if not (args.old and args.new):
            ap.error("positional usage needs both OLD and NEW")
        pairs.insert(0, (args.old, args.new, False))
    if not pairs:
        ap.error("nothing to compare: pass OLD NEW or --pair")

    all_offenders = []
    missing_required = []
    promoted = []
    for old, new, optional in pairs:
        if args.accept:
            # accepting only needs the candidate; a first-ever baseline is
            # a plain promotion (nothing to diff against)
            if not os.path.exists(new):
                if optional or args.allow_missing:
                    print(f"# skipping accept: no staging file {new}")
                else:
                    print(f"# MISSING staging file {new}")
                    missing_required.append(new)
                continue
            if os.path.exists(old):
                lines, _ = compare(old, new, args.pct)
                print(f"# {old} -> {new} (threshold {args.pct:.0f}%)")
                for ln in lines:
                    print(ln)
            shutil.copyfile(new, old)
            os.remove(new)
            promoted.append((new, old))
            continue
        missing = [p for p in (old, new) if not os.path.exists(p)]
        if missing:
            if optional or args.allow_missing:
                print(f"# skipping compare: missing {', '.join(missing)}")
            else:
                # fail the gate, but keep comparing the remaining pairs so
                # ONE run still surfaces every offender
                print(f"# MISSING required {', '.join(missing)}")
                missing_required += missing
            continue
        lines, offenders = compare(old, new, args.pct)
        print(f"# {old} -> {new} (threshold {args.pct:.0f}%)")
        for ln in lines:
            print(ln)
        all_offenders += [(f"{old}->{new}",) + o for o in offenders]
    for new, old in promoted:
        print(f"# accepted: {new} promoted to {old} (staging file removed)")
    if all_offenders:
        print(f"# {len(all_offenders)} regression(s) > {args.pct:.0f}%:")
        for pair, name, o, n, delta in all_offenders:
            print(f"#   {name}  {o:.1f} -> {n:.1f}us ({delta:+.1f}%)  "
                  f"[{pair}]")
    if missing_required:
        print(f"# missing required file(s): {', '.join(missing_required)}")
    if all_offenders or missing_required:
        sys.exit(1)
    if not promoted:
        print("# no regressions")


if __name__ == "__main__":
    main()
