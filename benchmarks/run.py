"""Benchmark harness — one function per paper table/figure.

  fig2   SSM operator duration vs seqlen (paper Fig 2: the 2^n staircase)
  fig5   training throughput: single-sequence vs padding vs pack (Fig 5)
  fig6   per-operator speedup, padding vs pack at matched tokens (Fig 6)
  disc   packing-policy padding rates + sort overhead (paper §5)
  roof   roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline)
  serve  serving throughput: padded-wave vs packed-continuous batching
         (launch/serve.py engine; emits BENCH_serve.json)
  train  gated training benchmark: single vs padded vs packed train steps
         in f32 and bf16, real-vs-buffer tok/s + padding_rate per row
         (emits BENCH_train.json)

Output: ``name,us_per_call,derived`` CSV rows (plus commented context lines).
CPU timings are for *ratios* (the paper's A100 wall-clock is not reproducible
here); the structural effects — padding-rate, token-density, step-count —
are hardware-independent and checked against the paper's numbers.

Run: PYTHONPATH=src python -m benchmarks.run [fig2 fig5 fig6 disc roof]
(add ``--obs-trace PATH`` to any selection to export a Chrome trace-event
JSON of the serve packed_obs engine + train timing rounds — repro/obs)
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.timing import interleaved_min_of_rounds


def _timeit(fn, *args, reps=3, warmup=1):
    best, _ = interleaved_min_of_rounds(
        [("cell", lambda: fn(*args))], rounds=reps, warmup=warmup)
    return best["cell"]         # us


def _row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}")


# ---------------------------------------------------------------------------
# Fig 2 — SSM operator profile vs seqlen × scan schedule
# ---------------------------------------------------------------------------

BENCH_RECORDS = []          # machine-readable mirror of the scan CSV rows
# output path override so `make bench-scan` can write a fresh file next to
# the committed baseline instead of clobbering it (see Makefile)
BENCH_JSON = os.environ.get("BENCH_SCAN_JSON", "BENCH_scan.json")
# BENCH_SMOKE=1 (the `make bench-smoke` / CI lane): tiny shapes and short
# workloads — the JSON structure is checked (compare.py --schema), timings
# are NOT gated, so the job stays minutes-bounded on a cold cache
SMOKE = os.environ.get("BENCH_SMOKE", "") not in ("", "0")

# --obs-trace PATH (stripped from argv in main()): record host span traces
# for the obs-instrumented serve mode and the train timing rounds, and
# export ONE Chrome trace-event JSON (Perfetto-loadable) at PATH
OBS_TRACE = None
_OBS = None


def _obs():
    """Process-wide Obs handle: recording iff --obs-trace was given."""
    global _OBS
    if _OBS is None:
        from repro.obs import Obs
        _OBS = Obs.on() if OBS_TRACE else Obs.off()
    return _OBS


def _bench(op, shape, schedule, us, tokens):
    BENCH_RECORDS.append({"op": op, "shape": shape, "schedule": schedule,
                          "us_per_call": round(us, 1),
                          "tok_per_s": round(tokens / (us / 1e6), 1)})


def _packed_positions(L, seg=100):
    """Packed position ids: ≥2 segments at every benchmarked L (smallest is
    256), with boundaries straddling the power-of-two scan chunks."""
    lens = [seg] * (L // seg) + ([L % seg] if L % seg else [])
    return jnp.asarray(np.concatenate([np.arange(n) for n in lens])[None],
                       jnp.int32)


def fig2_ssm_operator_profile():
    """Paper Fig 2 reframed for schedules: the SSM operator's duration vs
    seqlen under each scan schedule at matched shapes, with PACKED positions
    (multi-segment rows) so the reset handling is exercised in every cell.

      chunked         materialize (B,L,D,N), chunk-carried associative scan
                      (the pre-blocked default)
      blocked         SSD-style block-parallel schedule, backend-default
                      in-chunk evaluator (core/ssm.py::_blocked_ssm)
      blocked_matmul  same schedule, explicit M @ b einsum contraction
                      (the MXU form the Pallas kernel uses)
      fused_seq       single sequential scan, y fused
      mamba2_blocked  head-structured (scalar per-head decay) blocked
                      schedule at MATCHED channels (D = H·dh) — the decay
                      matrix is (T,T) per head and the chunk evaluates as
                      one (T,T)·(T,dh·N) matmul
                      (core/ssm.py::selective_scan_heads)
      mamba2_dual     same schedule, C·Bᵀ attention-like dual-form in-chunk
                      evaluator (intra="dual")
      mamba2w_*       wide-head family (H=2, dh=128) at matched channels
                      with a small chunk (T=16) — the dh ≫ T regime where
                      the dual form's T²·(dh+N) beats quad's T²·dh·N
      *tuned*         knobs resolved from the shape-keyed tuning cache
                      (repro/tune; fig2 warms TUNE_CACHE.json for its own
                      shapes, so tuned rows are the measured winners)

    The blocked_noreset row repeats `blocked` with reset-free positions:
    its delta vs `blocked` is the cost of PackMamba reset-correctness
    (paper's claim: ~zero). A final comment row greps the compiled HLO for
    a (B, L, D, N)-shaped buffer — the peak-memory evidence that `blocked`
    (unlike `chunked`) never materializes the full decay/state trajectory
    (and likewise no (B, L, H, dh, N) buffer for mamba2_blocked).
    """
    print("# fig2: selective_scan duration vs seqlen x schedule "
          "(B=1, D=256, N=16, packed segments ~300; mamba2 rows: H=4 "
          "dh=64, mamba2w rows: H=2 dh=128, both at matched channels)")
    from repro.core.ssm import selective_scan, selective_scan_heads
    from repro.tune import get_cache
    from repro.tune import runner as tune_runner
    rng = np.random.default_rng(0)
    D, N = 256, 16
    H2 = 4
    P2 = D // H2
    H2w, P2w = 2, D // 2            # wide heads: dh = 128 ≫ T = 16
    A = -jnp.exp(jnp.asarray(rng.normal(size=(D, N)), jnp.float32))
    A2 = -jnp.exp(jnp.asarray(rng.normal(size=(H2,)), jnp.float32))
    A2w = -jnp.exp(jnp.asarray(rng.normal(size=(H2w,)), jnp.float32))
    Dk = jnp.ones((D,), jnp.float32)
    D2k = jnp.ones((H2,), jnp.float32)
    D2wk = jnp.ones((H2w,), jnp.float32)
    cache = get_cache()             # TUNE_CACHE.json when present
    warmed = False
    scheds = [
        ("chunked", dict(method="chunked", chunk=256)),
        ("blocked", dict(method="blocked", chunk=128)),
        ("blocked_matmul", dict(method="blocked", chunk=16,
                                intra="matmul")),
        ("fused_seq", dict(method="fused_seq")),
    ]

    for L in ([256] if SMOKE else [256, 512, 1024, 2048, 4096]):
        u = jnp.asarray(rng.normal(size=(1, L, D)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.1, 0.5, (1, L, D)), jnp.float32)
        Bm = jnp.asarray(rng.normal(size=(1, L, N)), jnp.float32)
        Cm = jnp.asarray(rng.normal(size=(1, L, N)), jnp.float32)
        u2 = u.reshape(1, L, H2, P2)
        dt2 = jnp.asarray(rng.uniform(0.1, 0.5, (1, L, H2)), jnp.float32)
        u2w = u.reshape(1, L, H2w, P2w)
        dt2w = jnp.asarray(rng.uniform(0.1, 0.5, (1, L, H2w)), jnp.float32)
        pos = _packed_positions(L)
        pos_flat = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (1, L))
        shape = f"B1_L{L}_D{D}_N{N}"

        def mk1(kw):
            return jax.jit(lambda u, dt, Bm, Cm, pos, kw=tuple(kw.items()):
                           selective_scan(u, dt, A, Bm, Cm, Dk, pos,
                                          **dict(kw)))

        def mk2(kw, A2=A2, D2k=D2k):
            return jax.jit(lambda u, dt, Bm, Cm, pos, kw=tuple(kw.items()):
                           selective_scan_heads(u, dt, A2, Bm, Cm, D2k, pos,
                                                **dict(kw)))

        # warm the tuning cache for this L's three shape families (no-op
        # when `make bench-tune` already measured them)
        warmed |= tune_runner.ensure("selective_scan", B=1, L=L, D=D, N=N,
                                     cache=cache)
        warmed |= tune_runner.ensure("selective_scan_heads", B=1, L=L,
                                     H=H2, dh=P2, N=N, cache=cache)
        warmed |= tune_runner.ensure("selective_scan_heads", B=1, L=L,
                                     H=H2w, dh=P2w, N=N, cache=cache)

        # each cell: (name, jitted fn, args)
        cells = [(name, mk1(kw), (u, dt, Bm, Cm, pos))
                 for name, kw in scheds]
        cells.append(("blocked_noreset",
                      mk1(dict(method="blocked", chunk=128)),
                      (u, dt, Bm, Cm, pos_flat)))
        # tuned rows resolve through the SAME trace-time resolver models
        # use (tune= → core/ssm.py; xla winners only on this cell's core
        # path, explicit args the miss fallback) — no parallel re-mapping
        cells.append(("tuned",
                      mk1(dict(method="blocked", chunk=128, tune=cache)),
                      (u, dt, Bm, Cm, pos)))
        cells.append(("mamba2_blocked",
                      mk2(dict(method="blocked", chunk=64)),
                      (u2, dt2, Bm, Cm, pos)))
        cells.append(("mamba2_dual",
                      mk2(dict(method="blocked", chunk=64, intra="dual")),
                      (u2, dt2, Bm, Cm, pos)))
        cells.append(("mamba2_noreset",
                      mk2(dict(method="blocked", chunk=64)),
                      (u2, dt2, Bm, Cm, pos_flat)))
        cells.append(("mamba2_tuned",
                      mk2(dict(method="blocked", chunk=64, tune=cache)),
                      (u2, dt2, Bm, Cm, pos)))
        # the dh ≫ T regime: quad must pay T²·dh·N, dual only T²·(dh+N)
        cells.append(("mamba2w_quad",
                      mk2(dict(method="blocked", chunk=16, intra="quad"),
                          A2w, D2wk),
                      (u2w, dt2w, Bm, Cm, pos)))
        cells.append(("mamba2w_dual",
                      mk2(dict(method="blocked", chunk=16, intra="dual"),
                          A2w, D2wk),
                      (u2w, dt2w, Bm, Cm, pos)))
        cells.append(("mamba2w_tuned",
                      mk2(dict(method="blocked", chunk=16, intra="quad",
                               tune=cache), A2w, D2wk),
                      (u2w, dt2w, Bm, Cm, pos)))
        # interleave schedules round-robin: min-of-rounds is robust to the
        # machine-load drift that would bias per-schedule timing blocks
        # (shared protocol: benchmarks/timing.py, also used by the tuner)
        best, _ = interleaved_min_of_rounds(
            [(name, (lambda fn=fn, args=args: fn(*args)))
             for name, fn, args in cells], rounds=7)
        for name, fn, args in cells:
            us = best[name]
            tag = " (reset-free baseline)" if name.endswith("noreset") \
                else ""
            _row(f"fig2/ssm_{name}_L{L}", us,
                 f"{L / (us / 1e6):.0f} tok/s{tag}")
            _bench("selective_scan", shape, name, us, L)
    if warmed:
        print(f"# fig2 tune: warmed {cache.save()} "
              f"({len(cache.entries)} entries)")
    if SMOKE:       # the HLO evidence below is compile-heavy; smoke skips it
        return
    # ---- peak-memory evidence: no (B, L, D, N) buffer in the blocked HLO
    L = 2048
    u = jnp.asarray(rng.normal(size=(1, L, D)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.5, (1, L, D)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(1, L, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(1, L, N)), jnp.float32)
    pos = _packed_positions(L)
    full = f"f32[1,{L},{D},{N}]"
    for name, kw in (("chunked", dict(method="chunked", chunk=256)),
                     ("blocked", dict(method="blocked", chunk=128)),
                     ("blocked_matmul", dict(method="blocked", chunk=16,
                                             intra="matmul"))):
        hlo = jax.jit(lambda u, dt, Bm, Cm, pos, kw=tuple(kw.items()):
                      selective_scan(u, dt, A, Bm, Cm, Dk, pos,
                                     **dict(kw))).lower(
            u, dt, Bm, Cm, pos).compile().as_text()
        print(f"# fig2 memory: {name} HLO contains (B,L,D,N)={full} "
              f"buffer: {full in hlo}")
    u2 = u.reshape(1, L, H2, P2)
    dt2 = jnp.asarray(rng.uniform(0.1, 0.5, (1, L, H2)), jnp.float32)
    full2 = f"f32[1,{L},{H2},{P2},{N}]"
    hlo2 = jax.jit(lambda u, dt, Bm, Cm, pos:
                   selective_scan_heads(u, dt, A2, Bm, Cm, D2k, pos,
                                        method="blocked", chunk=64)).lower(
        u2, dt2, Bm, Cm, pos).compile().as_text()
    print(f"# fig2 memory: mamba2_blocked HLO contains (B,L,H,dh,N)="
          f"{full2} buffer: {full2 in hlo2}")


# ---------------------------------------------------------------------------
# Fig 5 — training throughput: single vs padding vs pack
# ---------------------------------------------------------------------------

def _tiny_mamba(vocab=256, d_model=128, n_layers=4):
    from repro.configs.base import get_config
    cfg = get_config("mamba-110m")
    return dataclasses.replace(cfg, vocab=vocab, d_model=d_model,
                               n_layers=n_layers, dtype="float32",
                               scan_chunk=128)


def fig5_training_throughput(seq_len=512, n_stream=48):
    """Paper Fig 5 protocol: same sequence stream through the three
    regimes; throughput = corpus tokens / wall time. Paper (A100, bf16):
    pack/single = 3.06× (1.4B), 5.05× (110m); pack always beats padding.
    Derived: tok/s and speedup vs single-sequence."""
    print(f"# fig5: training throughput, tiny-mamba, seq_len={seq_len}, "
          f"{n_stream} sequences per batch")
    from repro.core.packing import pack, pad_to_max
    from repro.models.lm import build_model
    from repro.optim.adamw import AdamW, constant_schedule
    from repro.train.trainer import make_train_step
    from repro.data.dataset import SyntheticCorpus, CorpusConfig

    cfg = _tiny_mamba()
    model = build_model(cfg)
    opt = AdamW(constant_schedule(1e-3))
    step = jax.jit(make_train_step(model, opt))
    corpus = SyntheticCorpus(CorpusConfig(
        vocab=cfg.vocab, seed=0, len_min=seq_len // 8, len_max=seq_len,
        mu=float(np.log(seq_len / 3.0)), sigma=0.6))
    seqs = corpus.batch_of_sequences(0, n_stream)
    total_tokens = sum(len(s) for s in seqs)

    def regime_batches(mode):
        if mode == "pack":
            pb = pack(seqs, seq_len)
            return [{"tokens": pb.tokens, "positions": pb.positions,
                     "segment_ids": pb.segment_ids}]
        if mode == "pad":
            pb = pad_to_max(seqs, seq_len)
            return [{"tokens": pb.tokens, "positions": pb.positions,
                     "segment_ids": pb.segment_ids}]
        out = []
        for s in seqs:                      # single: one sequence per step
            cap = 1 << (len(s) - 1).bit_length()
            pb = pad_to_max([s], cap)
            out.append({"tokens": pb.tokens, "positions": pb.positions,
                        "segment_ids": pb.segment_ids})
        return out

    results = {}
    for mode in ("single", "pad", "pack"):
        batches = regime_batches(mode)
        params = model.init(jax.random.PRNGKey(0))
        state = {"params": params, "opt": opt.init(params)}
        # warmup compile for every distinct shape
        for b in {bb["tokens"].shape: bb for bb in batches}.values():
            state, _ = step(state, b)
        jax.block_until_ready(jax.tree.leaves(state["params"])[0])
        t0 = time.perf_counter()
        for b in batches:
            state, m = step(state, b)
        jax.block_until_ready(jax.tree.leaves(state["params"])[0])
        dt = time.perf_counter() - t0
        results[mode] = dt
        _row(f"fig5/{mode}", dt * 1e6,
             f"{total_tokens / dt:.0f} tok/s over {len(batches)} step(s)")
    _row("fig5/speedup_pack_vs_single",
         results["single"] / results["pack"] * 100,
         f"{results['single'] / results['pack']:.2f}x (paper: 3.06x@1.4B "
         f"5.05x@110m bf16)")
    _row("fig5/speedup_pack_vs_pad", results["pad"] / results["pack"] * 100,
         f"{results['pad'] / results['pack']:.2f}x")


# ---------------------------------------------------------------------------
# train — the paper's experiment as a gated benchmark: full train steps,
# single vs padded vs packed, f32 vs bf16 (emits BENCH_train.json)
# ---------------------------------------------------------------------------

TRAIN_RECORDS = []
TRAIN_JSON = os.environ.get("BENCH_TRAIN_JSON", "BENCH_train.json")


def train_throughput(seq_len=512, rows=4, steps=4):
    """PackMamba's headline experiment, gated: the SAME lognormal sequence
    stream through three training regimes as full train steps (fwd+bwd+
    AdamW), in f32 and in the bf16 mixed-precision lane (activations bf16,
    scan carries and loss reduction f32 — models/lm.py). Paper (A100,
    bf16): pack/single 3.06× (1.4B) / 5.05× (110m); pack > pad always.

    The three regimes are the paper's three pipelines under jit's static-
    shape discipline (every shape is warmed before timing):

      single  batch-1 fixed-context pipeline: one sequence per step,
              padded to the compiled (1, seq_len) buffer. (The pow2-
              bucketed batch-1 variant is fig5 / loader mode="single" —
              published there; on a CPU box it under-represents the
              paper's GPU underutilization cost.)
      pad     standard dynamic batch padding: `rows` sequences per step,
              one per row, padded to the longest in the batch rounded up
              to a power of two (bounded compiled-shape count).
      pack    PackingLoader first_fit_decreasing packed (rows, seq_len)
              buffers.

    tok/s = stream (real) tokens / wall; every row also reports the
    buffer-token rate and the padding_rate connecting them — the packed
    regime wins precisely because its buffer work is ~all real."""
    if SMOKE:
        seq_len, rows, steps = 256, 2, 2
    print(f"# train: single vs pad vs pack train steps x f32/bf16, "
          f"tiny-mamba, rows={rows}, seq_len={seq_len}, {steps} stream "
          f"draws, policy=first_fit_decreasing")
    from repro.core.packing import pad_to_max
    from repro.data.dataset import SyntheticCorpus, CorpusConfig
    from repro.data.packing_loader import PackingLoader, LoaderConfig
    from repro.models.lm import build_model
    from repro.optim.adamw import AdamW, constant_schedule
    from repro.train.trainer import make_train_step

    # lognormal with mass well below seq_len (paper Fig 1: mean ~646 at a
    # 4096 capacity) — the regime where packing pays and fixed-context
    # padding hurts
    corpus = SyntheticCorpus(CorpusConfig(
        vocab=256, seed=0, len_min=seq_len // 16, len_max=seq_len,
        mu=float(np.log(seq_len / 4.5)), sigma=0.45))
    loader = PackingLoader(corpus, LoaderConfig(
        rows=rows, seq_len=seq_len, mode="pack",
        policy="first_fit_decreasing"))
    n_draw = loader._n_draw()
    streams = [corpus.batch_of_sequences(s, n_draw) for s in range(steps)]

    def as_batch(pb):
        return {"tokens": pb.tokens, "positions": pb.positions,
                "segment_ids": pb.segment_ids}

    def batches_for(mode):
        if mode == "pack":
            return [loader.batch(s) for s in range(steps)]
        out = []
        for seqs in streams:
            if mode == "single":
                out += [as_batch(pad_to_max([s], seq_len)) for s in seqs]
            else:
                for i in range(0, len(seqs), rows):
                    group = seqs[i:i + rows]
                    cap = 1 << (max(len(s) for s in group) - 1).bit_length()
                    out.append(as_batch(pad_to_max(group, cap)))
        return out

    shape = f"tiny-mamba_rows{rows}x{seq_len}"
    real_tps = {}
    tr = _obs().tracer          # records per-round spans iff --obs-trace
    for mode in ("single", "pad", "pack"):
        bs = batches_for(mode)
        real = sum(int((b["segment_ids"] > 0).sum()) for b in bs)
        buf = sum(int(b["tokens"].size) for b in bs)
        pad_rate = 1.0 - real / buf
        for dtag, dname in (("f32", "float32"), ("bf16", "bfloat16")):
            cfg = dataclasses.replace(_tiny_mamba(), dtype=dname)
            model = build_model(cfg)
            opt = AdamW(constant_schedule(1e-3))
            step = jax.jit(make_train_step(model, opt))
            params = model.init(jax.random.PRNGKey(0))
            state = {"params": params, "opt": opt.init(params)}
            # warmup compile for every distinct shape (pad's remainder
            # group adds at most one)
            for b in {bb["tokens"].shape: bb for bb in bs}.values():
                state, _ = step(state, b)
            jax.block_until_ready(jax.tree.leaves(state["params"])[0])
            best_dt = np.inf
            sched = f"{mode}_{dtag}"
            for rnd in range(2):            # min-of-rounds vs load spikes
                t0 = time.perf_counter()
                for b in bs:
                    state, m = step(state, b)
                jax.block_until_ready(jax.tree.leaves(state["params"])[0])
                t1 = time.perf_counter()
                tr.complete(f"bench.train.{sched}", t0, t1, track="bench",
                            round=rnd, steps=len(bs), real_tokens=real)
                best_dt = min(best_dt, t1 - t0)
            real_tps[sched] = real / best_dt
            TRAIN_RECORDS.append({
                "op": "train", "shape": shape, "schedule": sched,
                "us_per_call": round(best_dt / len(bs) * 1e6, 1),
                "tok_per_s": round(real / best_dt, 1),
                "real_tok_per_s": round(real / best_dt, 1),
                "buffer_tok_per_s": round(buf / best_dt, 1),
                "padding_rate": round(pad_rate, 4)})
            _row(f"train/{sched}", best_dt / len(bs) * 1e6,
                 f"{real / best_dt:.0f} real tok/s "
                 f"({buf / best_dt:.0f} buffer, "
                 f"padding {pad_rate * 100:.1f}%, "
                 f"{len(bs)} step(s))")
    for dtag in ("f32", "bf16"):
        s, p, k = (real_tps[f"{m}_{dtag}"] for m in ("single", "pad",
                                                     "pack"))
        _row(f"train/speedup_pack_vs_single_{dtag}", k / s * 100,
             f"{k / s:.2f}x (paper bf16: 3.06x@1.4B 5.05x@110m); "
             f"pack/pad {k / p:.2f}x")


# ---------------------------------------------------------------------------
# Fig 6 — kernel-level speedup, padding vs pack
# ---------------------------------------------------------------------------

def fig6_kernel_speedup(seq_len=512):
    """Paper Fig 6: with padding as baseline, packing shrinks GEMM + SSM
    time by the token-density ratio; conv1d (memory-bound) gains less.
    We time each operator fwd+bwd at 'padding' shapes (many mostly-empty
    rows) vs 'pack' shapes (few dense rows) for the SAME real tokens."""
    print(f"# fig6: per-operator fwd+bwd time, padding vs pack "
          f"(matched real tokens, seq_len={seq_len})")
    from repro.core.packing import pack, pad_to_max
    from repro.data.dataset import SyntheticCorpus, CorpusConfig
    from repro.kernels.ops import selective_scan, conv1d_pack
    rng = np.random.default_rng(0)
    corpus = SyntheticCorpus(CorpusConfig(
        vocab=256, seed=0, len_min=seq_len // 8, len_max=seq_len,
        mu=float(np.log(seq_len / 3.0)), sigma=0.6))
    seqs = corpus.batch_of_sequences(0, 24)
    pb_pack = pack(seqs, seq_len)
    pb_pad = pad_to_max(seqs, seq_len)
    D, N, W = 256, 16, 4
    A = -jnp.exp(jnp.asarray(rng.normal(size=(D, N)), jnp.float32))
    Dk = jnp.ones((D,), jnp.float32)
    wconv = jnp.asarray(rng.normal(size=(W, D)), jnp.float32)
    wproj = jnp.asarray(rng.normal(size=(D, 2 * D)) / 16, jnp.float32)

    def mk(pb):
        Bz, L = pb.tokens.shape
        return dict(
            x=jnp.asarray(rng.normal(size=(Bz, L, D)), jnp.float32),
            dt=jnp.asarray(rng.uniform(0.1, 0.5, (Bz, L, D)), jnp.float32),
            Bm=jnp.asarray(rng.normal(size=(Bz, L, N)), jnp.float32),
            Cm=jnp.asarray(rng.normal(size=(Bz, L, N)), jnp.float32),
            pos=pb.positions)

    ssm = jax.jit(jax.grad(lambda x, d: (selective_scan(
        x, d["dt"], A, d["Bm"], d["Cm"], Dk, d["pos"],
        backend="xla", xla_chunk=128) ** 2).sum()))
    conv = jax.jit(jax.grad(lambda x, d: (conv1d_pack(
        x, wconv, None, d["pos"], backend="xla") ** 2).sum()))
    gemm = jax.jit(jax.grad(lambda x: ((x @ wproj) ** 2).sum()))

    speed = {}
    for op_name, fn, needs in (("ssm", ssm, True), ("conv1d", conv, True),
                               ("gemm", gemm, False)):
        times = {}
        for mode, pb in (("pad", pb_pad), ("pack", pb_pack)):
            d = mk(pb)
            args = (d["x"], d) if needs else (d["x"],)
            times[mode] = _timeit(fn, *args)
            _row(f"fig6/{op_name}_{mode}", times[mode],
                 f"rows={pb.tokens.shape[0]}")
        speed[op_name] = times["pad"] / times["pack"]
        _row(f"fig6/{op_name}_speedup", speed[op_name] * 100,
             f"{speed[op_name]:.2f}x (pad/pack)")
    print(f"# fig6 note: paper fwd+bwd 3.91x overall; GEMM+SSM gain ~= "
          f"token-density ratio, conv1d (memory-bound) gains less — here "
          f"ssm {speed['ssm']:.2f}x gemm {speed['gemm']:.2f}x "
          f"conv {speed['conv1d']:.2f}x")


# ---------------------------------------------------------------------------
# serve — padded-wave vs packed-continuous serving throughput
# ---------------------------------------------------------------------------

SERVE_RECORDS = []
SERVE_JSON = os.environ.get("BENCH_SERVE_JSON", "BENCH_serve.json")


def serve_throughput(n_requests=32, max_new=16, slots=8):
    """Serving throughput at paper-like prompt-length spreads: the padded
    synchronous-wave baseline (every prompt left-padded to the wave max,
    decode drains before the next wave admits) vs the packed continuous
    engine (prompts packed into shape-bucketed prefill buffers, per-segment
    state handoff, mid-flight slot refill), with and without prefill/decode
    OVERLAP (async prefill dispatch + TTFT-bounded admission). All modes
    greedy-decode the same requests on the same tiny mamba; tok/s =
    generated tokens / wall time after a full warm-up pass (compiles
    excluded from all sides — the bucket evidence line shows the packed
    side's compile count is bounded by the bucket list, not the number of
    distinct prompt lengths). Packed rows also emit p50/p95 TTFT
    (submit→first token, measured at host observability) accumulated over
    the timed rounds. The packed_obs row repeats packed_overlap with the
    host span tracer RECORDING (Obs.on()) — its delta vs packed_overlap,
    serve/obs_overhead_pct, is the measured cost of enabled observability
    (< 3% expected: two host timestamps per engine phase)."""
    rounds = 3
    if SMOKE:
        n_requests, max_new, slots, rounds = 10, 6, 4, 2
    print(f"# serve: padded-wave vs packed-continuous vs packed-overlap "
          f"vs packed-guarded vs packed-obs, tiny-mamba, {n_requests} "
          f"requests, {slots} slots, max_new={max_new}")
    from repro.models.lm import build_model
    from repro.launch.serve import ServeEngine
    from repro.obs import Obs

    cfg = _tiny_mamba()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    # lognormal-ish spread of prompt lengths (the paper's variable-length
    # serving regime), clipped to the bucket range; output budgets vary
    # too — padded waves drain to the slowest row, continuous refills
    lens = np.clip(np.exp(rng.normal(np.log(24), 0.7, n_requests)),
                   4, 96).astype(int)
    budgets = rng.integers(max(2, max_new // 4), 2 * max_new,
                           size=n_requests).tolist()
    prompts = [rng.integers(1, cfg.vocab, size=int(n)).astype(np.int32)
               for n in lens]
    max_len = 160
    shape = f"tiny-mamba_reqs{n_requests}_slots{slots}_new{max_new}"

    def run_padded(eng):
        gen = 0
        for i in range(0, len(prompts), slots):
            outs = eng.decode_batch(prompts[i:i + slots],
                                    budgets[i:i + slots])
            gen += sum(len(o) for o in outs)
        return gen

    def run_packed(eng):
        rids = [eng.submit(p, b) for p, b in zip(prompts, budgets)]
        outs = eng.run()
        return sum(len(outs[r]) for r in rids)

    # the overlap row isolates ASYNC PREFILL at a matched admission policy
    # (no TTFT target): this closed-loop workload submits everything up
    # front, so a TTFT override only converts batched prefills into many
    # small ones — the latency policy pays off on open-loop traffic with
    # arrival gaps, and is covered by the scripted-clock tests instead
    kw = dict(buckets=(32, 64, 128), max_segments=4)
    modes = [("padded_wave", run_padded,
              ServeEngine(model, params, slots, max_len)),
             ("packed_continuous", run_packed,       # the PR-3 reference
              ServeEngine(model, params, slots, max_len, overlap=False,
                          **kw)),
             ("packed_overlap", run_packed,          # async prefill dispatch
              ServeEngine(model, params, slots, max_len, overlap=True,
                          **kw)),
             ("packed_guarded", run_packed,          # + numerical guard
              # rails: per-step finiteness probes on decode logits and
              # harvested prefill states (the fault-tolerance layer's
              # quarantine path); the probe is fused into the jitted step,
              # so the expected cost is <2% of decode throughput
              ServeEngine(model, params, slots, max_len, overlap=True,
                          guard=True, **kw)),
             ("packed_obs", run_packed,              # + host span tracer ON
              # the observability cost row: same engine as packed_overlap
              # but with per-request lifecycle + engine-phase spans being
              # RECORDED; exported when --obs-trace is given
              ServeEngine(model, params, slots, max_len, overlap=True,
                          obs=_obs() if OBS_TRACE else Obs.on(), **kw))]
    for name, runner, eng in modes:            # warm-up: compile all shapes
        runner(eng)
        eng.stats = type(eng.stats)()          # count the timed rounds only
    # interleave timed rounds (min-of-rounds, same protocol as fig2 — CPU
    # wall clock is noisy and the modes must not sit in different load
    # regimes); warm-up already happened above so stats stay clean. TTFT
    # percentiles aggregate over every timed round (latency needs the
    # distribution, not the best round).
    best, gens = interleaved_min_of_rounds(
        [(name, (lambda runner=runner, eng=eng: runner(eng)))
         for name, runner, eng in modes], rounds=rounds, warmup=0)
    results = {name: best[name] / 1e6 for name, _, _ in modes}
    for name, runner, eng in modes:
        dt = results[name]
        gen = gens[name]
        rec = {"op": "serve", "shape": shape, "schedule": name,
               "us_per_call": round(dt * 1e6, 1),
               "tok_per_s": round(gen / dt, 1)}
        st = eng.stats
        pct = st.ttft_percentiles()
        extra = f"{gen / dt:.0f} tok/s"
        if pct:
            rec["ttft_p50_ms"] = round(pct["p50"], 2)
            rec["ttft_p95_ms"] = round(pct["p95"], 2)
            extra += (f" ttft p50 {pct['p50']:.1f}ms p95 "
                      f"{pct['p95']:.1f}ms")
        # engine-phase wall-time split (averaged per timed round) — makes a
        # packed-vs-padded throughput gap attributable: is the continuous
        # engine losing time in prefill sync, fused decode, or host-side
        # scheduling? padded_wave bypasses step(), so its split is zero.
        rec["prefill_ms"] = round(st.prefill_ms / rounds, 2)
        rec["chunk_ms"] = round(st.chunk_ms / rounds, 2)
        rec["decode_ms"] = round(st.decode_ms / rounds, 2)
        rec["host_ms"] = round(st.host_ms / rounds, 2)
        if name == "packed_obs":
            rec["obs_overhead_pct"] = round(
                (results["packed_obs"] / results["packed_overlap"] - 1.0)
                * 100, 2)
        _row(f"serve/{name}", dt * 1e6, extra)
        SERVE_RECORDS.append(rec)
        if name == "packed_overlap":
            print(f"# serve overlap evidence: "
                  f"{st.overlapped_prefills // rounds} of "
                  f"{st.prefills // rounds} prefills/run stayed in flight "
                  f"across ≥1 decode step")
        if name == "packed_continuous":
            print(f"# serve compile evidence: {len(st.buckets)} prefill "
                  f"shape(s) for {len(set(map(int, lens)))} distinct prompt "
                  f"lengths; {st.prefills // rounds} prefills "
                  f"({st.midflight_refills // rounds} mid-flight), "
                  f"{st.decode_steps // rounds} decode steps per run")
            print(f"# serve time split (per round): prefill "
                  f"{st.prefill_ms / rounds:.0f}ms decode "
                  f"{st.decode_ms / rounds:.0f}ms host "
                  f"{st.host_ms / rounds:.0f}ms — where a padded-wave gap "
                  f"lives")
    _row("serve/speedup_packed_vs_padded",
         results["padded_wave"] / results["packed_continuous"] * 100,
         f"{results['padded_wave'] / results['packed_continuous']:.2f}x")
    _row("serve/speedup_overlap_vs_continuous",
         results["packed_continuous"] / results["packed_overlap"] * 100,
         f"{results['packed_continuous'] / results['packed_overlap']:.2f}x "
         f"(>= 1.0 expected: overlap must not lose throughput)")
    guard_pct = (results["packed_guarded"] / results["packed_overlap"]
                 - 1.0) * 100
    _row("serve/guard_overhead_pct", guard_pct,
         f"{guard_pct:+.1f}% decode throughput for the finiteness probes "
         f"(< 2% expected: the probe is a fused all-reduce per step)")
    obs_pct = (results["packed_obs"] / results["packed_overlap"]
               - 1.0) * 100
    _row("serve/obs_overhead_pct", obs_pct,
         f"{obs_pct:+.1f}% decode throughput with the host span tracer "
         f"recording (< 3% expected: two perf_counter stamps per engine "
         f"phase + per-request lifecycle spans)")


def serve_open_loop(n_requests=48, max_new=16, slots=8):
    """Open-loop (Poisson-arrival) serving: requests arrive on a seeded
    exponential-gap schedule instead of all-at-once, which is where
    scheduler POLICY (early admission, bucket choice, prefill pipelining)
    actually shows up — a closed-loop run hides TTFT behind an always-full
    queue. Offered load is calibrated to ~1.5× the engine's closed-loop
    service rate (measured on this box during warm-up) — far enough past
    saturation that the admission queue stays non-empty, which is the
    regime where bucket CHOICE exists at all — then the SAME
    arrival schedule is replayed for the v1-equivalent scheduler
    (``open_packed_overlap``: one in-flight prefill, smallest-fit buckets,
    no TTFT policy) and the v2 scheduler (``open_scheduler_v2``: prefill
    pool of 2, TTFT-aware bucket upgrades, early admission) — matched
    offered load, so tok/s and ttft_p95 are directly comparable and a
    scheduler cannot buy throughput by silently queueing latency."""
    rounds = 4                 # timed rounds are ~1s each; the p95 needs
    #                            rounds*n_requests samples to sit still
    if SMOKE:
        n_requests, max_new, slots, rounds = 10, 6, 4, 1
    print(f"# serve_open: Poisson arrivals, v1 vs v2 scheduler, "
          f"tiny-mamba, {n_requests} requests, {slots} slots, "
          f"max_new={max_new}")
    from repro.models.lm import build_model
    from repro.launch.serve import ServeEngine

    cfg = _tiny_mamba()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    lens = np.clip(np.exp(rng.normal(np.log(24), 0.7, n_requests)),
                   4, 96).astype(int)
    budgets = rng.integers(max(2, max_new // 4), 2 * max_new,
                           size=n_requests).tolist()
    prompts = [rng.integers(1, cfg.vocab, size=int(n)).astype(np.int32)
               for n in lens]
    max_len = 160
    shape = f"tiny-mamba_open_reqs{n_requests}_slots{slots}_new{max_new}"
    kw = dict(buckets=(32, 64, 128), max_segments=4, overlap=True)
    modes = [("open_packed_overlap",          # the pre-v2 scheduler
              ServeEngine(model, params, slots, max_len, **kw)),
             ("open_scheduler_v2",            # pipelined + TTFT-aware
              ServeEngine(model, params, slots, max_len,
                          max_inflight_prefills=2, bucket_policy="ttft",
                          **kw))]
    # v2 carries NO fixed target_ttft_ms: the bucket policy self-calibrates
    # on its own measured TTFT p50 (_choose_bucket's fallback), and the
    # PR-5 early-admit override stays off — a fixed target below what the
    # box can deliver would force panic admissions of tiny fragmented
    # batches, which is a misconfiguration, not a scheduler property

    def run_closed(eng):
        rids = [eng.submit(p, b) for p, b in zip(prompts, budgets)]
        eng.run()
        return sum(len(eng.outputs[r]) for r in rids)

    for _, eng in modes:                  # warm-up: compile every shape
        run_closed(eng)
    # capacity measurement on WARM engines calibrates the offered load —
    # folding compiles in would understate capacity and leave the arrival
    # process too sparse to ever stress the scheduler
    t0 = time.perf_counter()
    for _, eng in modes:
        run_closed(eng)
    closed_dt = (time.perf_counter() - t0) / len(modes)
    rate = 1.5 * n_requests / closed_dt           # offered requests/sec
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests))
    print(f"# serve_open offered load: {rate:.1f} req/s "
          f"(1.5x measured closed-loop capacity), last arrival "
          f"{arrivals[-1] * 1e3:.0f}ms")
    for _, eng in modes:
        eng.stats = type(eng.stats)()             # timed rounds only

    def run_open(eng):
        t0 = time.perf_counter()
        i = 0
        rids = []
        while True:
            now = time.perf_counter() - t0
            while i < len(prompts) and arrivals[i] <= now:
                rids.append(eng.submit(prompts[i], budgets[i]))
                i += 1
            busy = eng.step()
            if not busy:
                if i >= len(prompts):
                    break
                gap = arrivals[i] - (time.perf_counter() - t0)
                if gap > 0:                       # idle until next arrival
                    time.sleep(min(gap, 2e-3))
        return sum(len(eng.outputs[r]) for r in rids)

    best, gens = interleaved_min_of_rounds(
        [(name, (lambda eng=eng: run_open(eng))) for name, eng in modes],
        rounds=rounds, warmup=0)
    out = {}
    for name, eng in modes:
        dt = best[name] / 1e6
        gen = gens[name]
        st = eng.stats
        pct = st.ttft_percentiles()
        rec = {"op": "serve_open", "shape": shape, "schedule": name,
               "us_per_call": round(dt * 1e6, 1),
               "tok_per_s": round(gen / dt, 1),
               "arrival_rate_rps": round(float(rate), 2),
               "queue_depth_max": int(st.queue_depth_max),
               "ttft_p50_ms": round(pct.get("p50", 0.0), 2),
               "ttft_p95_ms": round(pct.get("p95", 0.0), 2),
               "prefill_ms": round(st.prefill_ms / rounds, 2),
               "chunk_ms": round(st.chunk_ms / rounds, 2),
               "decode_ms": round(st.decode_ms / rounds, 2),
               "host_ms": round(st.host_ms / rounds, 2)}
        out[name] = rec
        SERVE_RECORDS.append(rec)
        _row(f"serve_open/{name}", dt * 1e6,
             f"{gen / dt:.0f} tok/s ttft p95 {pct.get('p95', 0):.1f}ms "
             f"queue≤{st.queue_depth_max}")
        if name == "open_scheduler_v2":
            print(f"# serve_open v2 evidence: {st.early_admits} early "
                  f"admits, {st.bucket_upgrades} bucket upgrades "
                  f"({st.deferred_upgrades} deferred), "
                  f"{st.overlapped_prefills} overlapped of {st.prefills} "
                  f"prefills")
    v1, v2 = out["open_packed_overlap"], out["open_scheduler_v2"]
    _row("serve_open/v2_vs_v1_tokps",
         v2["tok_per_s"] / max(v1["tok_per_s"], 1e-9) * 100,
         f"{v2['tok_per_s'] / max(v1['tok_per_s'], 1e-9):.2f}x tok/s, "
         f"ttft_p95 {v1['ttft_p95_ms']:.1f} -> {v2['ttft_p95_ms']:.1f}ms "
         f"at {rate:.1f} req/s offered (>=1.0x tok/s and a lower p95 "
         f"expected; on a single-core host the tok/s leg is parity — "
         f"pipelined prefills only buy throughput with cores to overlap)")


def serve_cached(n_requests=24, max_new=16, slots=8):
    """Prefix/state caching on a shared-system-prompt workload: every
    request carries the same long declared prefix plus a short unique
    tail (fresh tails every round — each timed request is a real
    partial hit, not a replay). ``cache_off`` chunk-prefills the whole
    prompt cold per request; ``cache_on`` restores the prefix's O(1)
    state from the StateCache and prefills only the tail in a
    smallest-bucket slab — the headline is the TTFT p50 reduction at
    matched-or-better tok/s (the cache can only REMOVE prefill work).
    ``cache_spec`` adds speculative decode (k=4 n-gram drafts, one verify
    forward, trajectory rollback) on top: streams stay bit-identical
    (asserted against cache_on inside the run) and spec_accept_rate is
    the observable — near zero on this random-token tiny model, which is
    the honest number; the draft source only pays off on repetitive
    text."""
    rounds = 3
    prefix_len, tail = 192, 8
    if SMOKE:
        n_requests, max_new, slots, rounds = 8, 6, 4, 2
        prefix_len = 48
    print(f"# serve_cached: shared {prefix_len}-token system prompt + "
          f"{tail}-token tails, cache off vs on vs on+spec, tiny-mamba, "
          f"{n_requests} requests, {slots} slots, max_new={max_new}")
    from repro.models.lm import build_model
    from repro.launch.serve import ServeEngine
    from repro.launch.state_cache import StateCache

    cfg = _tiny_mamba()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    shared = rng.integers(1, cfg.vocab, size=prefix_len).astype(np.int32)
    max_len = prefix_len + tail + 2 * max_new + 8
    shape = (f"tiny-mamba_prefix{prefix_len}_reqs{n_requests}_"
             f"slots{slots}_new{max_new}")
    kw = dict(buckets=(32, 64, 128), max_segments=4, overlap=True,
              chunk_rows=2)
    caches = {"cache_on": StateCache(64 << 20),
              "cache_spec": StateCache(64 << 20)}
    modes = [("cache_off",
              ServeEngine(model, params, slots, max_len, **kw)),
             ("cache_on",
              ServeEngine(model, params, slots, max_len,
                          state_cache=caches["cache_on"], **kw)),
             ("cache_spec",
              ServeEngine(model, params, slots, max_len,
                          state_cache=caches["cache_spec"], spec_k=4,
                          **kw))]

    def make_tails(r):
        g = np.random.default_rng(1000 + r)
        return [g.integers(1, cfg.vocab, size=tail).astype(np.int32)
                for _ in range(n_requests)]

    outs_by_mode = {}
    rounds_seen = {name: 0 for name, _ in modes}

    def run(eng, name, declare):
        r = rounds_seen[name]
        rounds_seen[name] += 1
        prompts = [np.concatenate([shared, t]) for t in make_tails(r)]
        rids = [eng.submit(p, max_new,
                           prefix_len=prefix_len if declare else None)
                for p in prompts]
        eng.run()
        outs_by_mode.setdefault(name, {})[r] = \
            [eng.outputs[i] for i in rids]
        return sum(len(eng.outputs[i]) for i in rids)

    for name, eng in modes:          # warm-up: compiles + first capture
        run(eng, name, declare=name != "cache_off")
        eng.stats = type(eng.stats)()
        if eng.state_cache is not None:
            # keep the stored prefix but zero the hit/miss counters so the
            # recorded hit_rate covers the timed rounds only
            eng.state_cache._hits.set(0)
            eng.state_cache._misses.set(0)
    best, gens = interleaved_min_of_rounds(
        [(name, (lambda name=name, eng=eng,
                 d=(name != "cache_off"): run(eng, name, d)))
         for name, eng in modes], rounds=rounds, warmup=0)
    out = {}
    for name, eng in modes:
        dt = best[name] / 1e6
        gen = gens[name]
        st = eng.stats
        pct = st.ttft_percentiles()
        rec = {"op": "serve_cached", "shape": shape, "schedule": name,
               "us_per_call": round(dt * 1e6, 1),
               "tok_per_s": round(gen / dt, 1),
               "ttft_p50_ms": round(pct.get("p50", 0.0), 2),
               "ttft_p95_ms": round(pct.get("p95", 0.0), 2),
               "prefill_ms": round(st.prefill_ms / rounds, 2),
               "chunk_ms": round(st.chunk_ms / rounds, 2),
               "decode_ms": round(st.decode_ms / rounds, 2),
               "host_ms": round(st.host_ms / rounds, 2)}
        sc = eng.state_cache
        if sc is not None:
            rec["hit_rate"] = round(sc.hits / max(sc.lookups, 1), 3)
            rec["cache_entries"] = len(sc)
            rec["cache_mb"] = round(sc.nbytes / 2**20, 2)
        if name == "cache_spec":
            rec["spec_accept_rate"] = round(eng.spec_accept_rate, 4)
            rec["spec_rounds"] = int(eng._spec_rounds.value)
        _row(f"serve_cached/{name}", dt * 1e6,
             f"{gen / dt:.0f} tok/s ttft p50 {pct.get('p50', 0):.2f}ms"
             + (f" hit_rate {rec['hit_rate']:.2f}" if sc else ""))
        out[name] = rec
        SERVE_RECORDS.append(rec)
    # bit-identity evidence: greedy streams must not depend on the cache
    # or on speculation — same tails, same tokens, every timed round
    for r in range(rounds):
        assert outs_by_mode["cache_on"][r + 1] == \
            outs_by_mode["cache_off"][r + 1], "cache changed tokens"
        assert outs_by_mode["cache_spec"][r + 1] == \
            outs_by_mode["cache_off"][r + 1], "spec changed tokens"
    print("# serve_cached identity evidence: cache_on and cache_spec "
          "streams are token-identical to cache_off in every timed round")
    off, on = out["cache_off"], out["cache_on"]
    red = off["ttft_p50_ms"] / max(on["ttft_p50_ms"], 1e-9)
    _row("serve_cached/ttft_p50_reduction", red * 100,
         f"{red:.2f}x lower TTFT p50 with the prefix cache "
         f"({off['ttft_p50_ms']:.2f} -> {on['ttft_p50_ms']:.2f}ms) at "
         f"{on['tok_per_s'] / max(off['tok_per_s'], 1e-9):.2f}x tok/s "
         f"(>= 2x TTFT expected: the {prefix_len}-token prefix restore "
         f"replaces its chunked prefill)")


# ---------------------------------------------------------------------------
# §5 discussion — packing policies
# ---------------------------------------------------------------------------

def discussion_packing_policies():
    """Paper §5: sequential 19.1% padding, local-greedy sorted 0.41% (plus
    sort-time overhead); splitting (future work, implemented here) → ~0."""
    print("# disc: packing policies on the paper's length distribution "
          "(57..2048, mean~646), capacity 4096")
    from repro.core.packing import padding_rate, pack_with_split
    from repro.data.dataset import SyntheticCorpus
    corpus = SyntheticCorpus()
    lens = np.concatenate([corpus.lengths(s, 512)
                           for s in range(8)]).tolist()
    for policy in ("sequential", "first_fit", "sorted_greedy"):
        t0 = time.perf_counter()
        rate = padding_rate(lens, 4096, policy)
        us = (time.perf_counter() - t0) * 1e6
        ref = {"sequential": "paper 19.1%", "sorted_greedy": "paper 0.41%",
               "first_fit": "n/a"}[policy]
        _row(f"disc/{policy}", us, f"padding {rate * 100:.2f}% ({ref})")
    seqs = corpus.batch_of_sequences(0, 512)
    t0 = time.perf_counter()
    sb = pack_with_split(seqs, 4096)
    us = (time.perf_counter() - t0) * 1e6
    _row("disc/split_pack", us,
         f"padding {sb.padding_rate() * 100:.3f}% (paper future work -> 0)")
    pad_rate = 1 - np.mean(lens) / 2048
    _row("disc/pad_to_max_baseline", 0.0,
         f"padding {pad_rate * 100:.1f}% (paper 66.3%)")


# ---------------------------------------------------------------------------
# Roofline table from dry-run artifacts
# ---------------------------------------------------------------------------

def roofline_table(out_dir="experiments/dryrun"):
    print("# roof: per-cell roofline terms from the compiled dry-run "
          "(v5e: 197TF bf16, 819GB/s HBM, 50GB/s ICI)")
    if not os.path.isdir(out_dir):
        print(f"# (no {out_dir}; run `python -m repro.launch.dryrun` first)")
        return
    for fn in sorted(os.listdir(out_dir)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(out_dir, fn)) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        rl = rec["roofline"]
        t_bound = max(rl["t_compute_s"], rl["t_memory_s"],
                      rl["t_collective_s"])
        _row(f"roof/{rec['arch']}/{rec['shape']}/{rec['mesh']}",
             t_bound * 1e6,
             f"dom={rl['dominant']} comp={rl['t_compute_s'] * 1e3:.2f}ms "
             f"mem={rl['t_memory_s'] * 1e3:.2f}ms "
             f"coll={rl['t_collective_s'] * 1e3:.2f}ms "
             f"frac={rl['roofline_fraction']:.3f}")


ALL = {"fig2": fig2_ssm_operator_profile,
       "fig5": fig5_training_throughput,
       "fig6": fig6_kernel_speedup,
       "disc": discussion_packing_policies,
       "roof": roofline_table,
       "serve": serve_throughput,
       "serve_open": serve_open_loop,
       "serve_cached": serve_cached,
       "train": train_throughput}


def main() -> None:
    global OBS_TRACE
    argv = list(sys.argv[1:])
    if "--obs-trace" in argv:
        i = argv.index("--obs-trace")
        if i + 1 >= len(argv):
            raise SystemExit("--obs-trace needs a PATH argument")
        OBS_TRACE = argv[i + 1]
        del argv[i:i + 2]
    which = argv or list(ALL)
    print("name,us_per_call,derived")
    for k in which:
        ALL[k]()
    if BENCH_RECORDS:
        # machine-readable perf trajectory, trackable across PRs
        with open(BENCH_JSON, "w") as f:
            json.dump(BENCH_RECORDS, f, indent=1)
        print(f"# wrote {len(BENCH_RECORDS)} scan records to {BENCH_JSON}")
    if SERVE_RECORDS:
        with open(SERVE_JSON, "w") as f:
            json.dump(SERVE_RECORDS, f, indent=1)
        print(f"# wrote {len(SERVE_RECORDS)} serve records to {SERVE_JSON}")
    if TRAIN_RECORDS:
        with open(TRAIN_JSON, "w") as f:
            json.dump(TRAIN_RECORDS, f, indent=1)
        print(f"# wrote {len(TRAIN_RECORDS)} train records to {TRAIN_JSON}")
    if OBS_TRACE and _OBS is not None and _OBS.enabled:
        _OBS.export(OBS_TRACE)
        print(f"# obs: wrote {len(_OBS.tracer.chrome_events())} trace "
              f"events to {OBS_TRACE} (chrome://tracing / ui.perfetto.dev)")


if __name__ == "__main__":
    main()
