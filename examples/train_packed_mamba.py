"""End-to-end training driver: PackMamba variable-length training with
checkpointing, resume, and the three paper regimes.

Demo (CPU, ~2 min):
    PYTHONPATH=src python examples/train_packed_mamba.py --preset tiny \
        --steps 200 --ckpt-dir /tmp/packmamba_ckpt

Paper-scale (the models evaluated in §4; needs accelerators):
    PYTHONPATH=src python examples/train_packed_mamba.py --arch mamba-110m \
        --rows 8 --seq-len 4096 --steps 300
Interrupt with Ctrl-C / SIGTERM → emergency checkpoint → rerun resumes.
"""
import argparse
import dataclasses
import sys

import jax

sys.path.insert(0, "src")

from repro.configs.base import get_config
from repro.data.dataset import SyntheticCorpus, CorpusConfig
from repro.data.packing_loader import PackingLoader, LoaderConfig
from repro.models.lm import build_model
from repro.optim.adamw import AdamW, AdamWConfig, cosine_schedule
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba-110m")
    ap.add_argument("--preset", choices=["tiny", "full"], default="full")
    ap.add_argument("--mode", choices=["pack", "pad", "single"],
                    default="pack")
    ap.add_argument("--policy", default="sequential",
                    choices=["sequential", "first_fit", "sorted_greedy"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--rows", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=4096)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.preset == "tiny":
        cfg = dataclasses.replace(cfg, d_model=128, n_layers=4, vocab=512,
                                  dtype="float32", scan_chunk=64)
        args.rows, args.seq_len = 4, 256
        corpus_cfg = CorpusConfig(vocab=cfg.vocab, seed=0, len_min=16,
                                  len_max=256, mu=4.4, sigma=0.6)
    else:
        corpus_cfg = CorpusConfig(vocab=cfg.vocab, seed=0)

    model = build_model(cfg)
    corpus = SyntheticCorpus(corpus_cfg)
    loader = PackingLoader(corpus, LoaderConfig(
        rows=args.rows, seq_len=args.seq_len, mode=args.mode,
        policy=args.policy))
    opt = AdamW(cosine_schedule(args.lr, warmup=min(50, args.steps // 10),
                                total=args.steps),
                AdamWConfig(weight_decay=0.1, clip_norm=1.0))
    trainer = Trainer(model, opt, loader, TrainerConfig(
        steps=args.steps, accum=args.accum, log_every=10,
        ckpt_every=args.ckpt_every if args.ckpt_dir else 0,
        ckpt_dir=args.ckpt_dir))
    print(f"arch={cfg.name} mode={args.mode} policy={args.policy} "
          f"rows={args.rows} seq_len={args.seq_len} "
          f"padding={loader.stats(0)['padding_rate']:.1%}")
    state, hist = trainer.train(jax.random.PRNGKey(0))
    print(f"final loss {hist[-1]['loss']:.4f} over {len(hist)} steps")


if __name__ == "__main__":
    main()
