"""Packing-policy comparison on the paper's length distribution (§5):
padding rates, buffers used, sort overhead, and the split-packing
(future-work) upper bound.

    PYTHONPATH=src python examples/packing_strategies.py
"""
import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.core.packing import (plan_packing, padding_rate, pack_with_split)
from repro.data.dataset import SyntheticCorpus


def main():
    corpus = SyntheticCorpus()
    lens = np.concatenate([corpus.lengths(s, 512)
                           for s in range(8)]).tolist()
    cap = 4096
    total = sum(lens)
    print(f"{len(lens)} sequences, {total} tokens, lengths "
          f"[{min(lens)}, {max(lens)}] mean {np.mean(lens):.0f}, "
          f"buffer capacity {cap}\n")
    print(f"{'policy':<16}{'buffers':>8}{'padding':>10}{'plan time':>12}")
    print("-" * 46)
    for policy in ("sequential", "first_fit", "sorted_greedy"):
        t0 = time.perf_counter()
        plan = plan_packing(lens, cap, policy)
        dt = time.perf_counter() - t0
        rate = 1 - total / (len(plan) * cap)
        note = {"sequential": "  <- paper default (19.1%)",
                "sorted_greedy": "  <- paper local greedy (0.41%)",
                "first_fit": ""}[policy]
        print(f"{policy:<16}{len(plan):>8}{rate:>9.2%}{dt * 1e3:>10.1f}ms"
              f"{note}")
    seqs = corpus.batch_of_sequences(0, 512)
    t0 = time.perf_counter()
    sb = pack_with_split(seqs, cap)
    dt = time.perf_counter() - t0
    print(f"{'split (ours)':<16}{sb.tokens.shape[0]:>8}"
          f"{sb.padding_rate():>9.2%}{dt * 1e3:>10.1f}ms"
          f"  <- paper future work (-> 0%)")
    print(f"\npad-to-max baseline would waste "
          f"{1 - np.mean(lens) / 2048:.1%} (paper: 66.3%)")


if __name__ == "__main__":
    main()
