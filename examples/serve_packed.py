"""Batched serving demo: prefill a batch of variable-length prompts
(token-wise replay into per-layer caches), then greedy-decode continuations
— with reset-based cache reuse across requests (the decode-side analogue of
the paper's state isolation).

    PYTHONPATH=src python examples/serve_packed.py
"""
import dataclasses
import sys

import numpy as np
import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.configs.base import get_config
from repro.models.lm import build_model


def main():
    cfg = dataclasses.replace(get_config("mamba-110m"),
                              d_model=128, n_layers=4, vocab=512,
                              dtype="float32", scan_chunk=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    B, max_new = 4, 16
    prompts = [rng.integers(1, cfg.vocab, size=n).astype(np.int32)
               for n in (9, 17, 5, 12)]
    max_prompt = max(len(p) for p in prompts)
    # left-align prompts into a (B, max_prompt) grid; step the batch jointly
    grid = np.zeros((B, max_prompt), np.int32)
    for b, p in enumerate(prompts):
        grid[b, :len(p)] = p
    lens = jnp.asarray([len(p) for p in prompts])

    step = jax.jit(model.decode_step)
    cache = model.init_cache(B, max_prompt + max_new)

    # --- prefill by replay: feed each prompt token; rows past their prompt
    # length replay their last token but never advance their cursor (the
    # cache write lands on the same slot, attention masks by cache_len).
    last_logits = None
    for t in range(max_prompt):
        tok = jnp.asarray(grid[:, min(t, max_prompt - 1)][:, None])
        cur = jnp.minimum(jnp.full((B,), t), lens - 1)
        logits, cache = step(params, cache, tok, cur)
        last_logits = logits

    # --- greedy decode
    outs = [[] for _ in range(B)]
    tok = jnp.argmax(last_logits, -1)[:, None].astype(jnp.int32)
    for i in range(max_new):
        for b in range(B):
            outs[b].append(int(tok[b, 0]))
        logits, cache = step(params, cache, tok, lens + i)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)

    for b, (p, o) in enumerate(zip(prompts, outs)):
        print(f"req{b}: prompt[{len(p)}] -> {o}")

    # --- reset isolation: reuse row 0's cache for a fresh request; output
    # must equal a fresh-cache run (PUI for serving)
    new_prompt = prompts[2]
    cache_fresh = model.init_cache(B, max_prompt + max_new)
    seqs = {}
    for name, c in (("reused", cache), ("fresh", cache_fresh)):
        toks = []
        cc = c
        for t, tk in enumerate(new_prompt):
            lg, cc = step(params, cc, jnp.full((B, 1), int(tk), jnp.int32),
                          jnp.full((B,), t),
                          jnp.asarray([t == 0] * B) if name == "reused"
                          else None)
        seqs[name] = int(jnp.argmax(lg[0]))
    print(f"reset isolation: reused-cache next-token {seqs['reused']} == "
          f"fresh-cache {seqs['fresh']}: {seqs['reused'] == seqs['fresh']}")


if __name__ == "__main__":
    main()
