"""Continuous-batching serving demo: packed prefill → per-slot decode.

The serving-side application of the paper's packing: variable-length
prompts are packed back-to-back into shape-bucketed prefill buffers, ONE
forward harvests every prompt's decode state at its segment end
(`model.prefill_packed`), and the states are scattered into per-request
decode slots (`model.scatter_into_cache`). Slots that finish (EOS or token
budget) are refilled from the queue mid-flight — no synchronous waves, no
per-length recompiles.

    PYTHONPATH=src python examples/serve_packed.py
"""
import dataclasses
import sys

import numpy as np
import jax

sys.path.insert(0, "src")

from repro.configs.base import get_config
from repro.launch.serve import ServeEngine
from repro.models.lm import build_model


def main():
    cfg = dataclasses.replace(get_config("mamba-110m"),
                              d_model=128, n_layers=4, vocab=512,
                              dtype="float32", scan_chunk=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # --- continuous engine: 4 slots, 12 requests with mixed prompt sizes
    # AND mixed token budgets — the regime where padded waves waste steps
    engine = ServeEngine(model, params, num_slots=4, max_len=128,
                         prefill_rows=2, buckets=(32, 64), max_segments=3)
    lens = rng.integers(5, 40, size=12)
    budgets = rng.integers(4, 16, size=12)
    rids = [engine.submit(rng.integers(1, cfg.vocab, size=int(n)), int(b))
            for n, b in zip(lens, budgets)]
    outs = engine.run()
    for rid in rids[:5]:
        print(f"req{rid}: prompt[{lens[rid]}] budget {budgets[rid]} "
              f"-> {outs[rid]}")
    st = engine.stats
    print(f"stats: {st.generated} tokens, {st.prefills} packed prefills "
          f"({st.midflight_refills} mid-flight), {st.decode_steps} decode "
          f"steps, {len(st.buckets)} prefill shape(s) compiled for "
          f"{len(set(map(int, lens)))} distinct prompt lengths")

    # --- EOS termination: pick a token greedy decode emits and serve with
    # it as EOS — the slot frees early and the queue takes over
    probe = rng.integers(1, cfg.vocab, size=9)
    probe_rid = engine.submit(probe, 8)
    full = engine.run()[probe_rid]
    eos = full[len(full) // 2]
    rid2 = engine.submit(probe, 8, eos=eos)
    cut = engine.run()[rid2]
    print(f"eos={eos}: free-run {full} -> terminated {cut} "
          f"(stopped early: {len(cut) < len(full)})")

    # --- the padded-wave baseline on the same engine class, for contrast
    wave = ServeEngine(model, params, num_slots=4, max_len=128)
    prompts = [rng.integers(1, cfg.vocab, size=int(n)) for n in lens[:4]]
    wave_outs = wave.decode_batch(prompts, 8)
    print(f"padded-wave baseline decoded {sum(map(len, wave_outs))} tokens "
          f"in one synchronous wave (compare: the engine above never "
          f"drains)")


if __name__ == "__main__":
    main()
