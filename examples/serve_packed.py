"""Continuous-batching serving demo: overlapped packed prefill → per-slot
decode with batched sampling.

The serving-side application of the paper's packing: variable-length
prompts are packed back-to-back into shape-bucketed prefill buffers, ONE
forward harvests every prompt's decode state at its segment end
(`model.prefill_packed`), and the states are scattered into per-request
decode slots (`model.scatter_into_cache`). Slots that finish (EOS or token
budget) are refilled from the queue mid-flight — no synchronous waves, no
per-length recompiles. The refill prefill is dispatched ASYNCHRONOUSLY and
lands while other slots keep decoding (`overlap=True`), admission is
TTFT-aware (`target_ttft_ms`), and each request carries its own
temperature/top-k/top-p knobs sampled in the fused decode step.

Scheduler v2 (demonstrated below): prompts longer than the largest
bucket are served by CHUNKED PREFILL — fixed-size slabs resuming from the
carried SSM/conv state in a side cache (`chunk_size` / `chunk_rows`), so a
huge prompt can't head-of-line-block short requests; up to
`max_inflight_prefills` packed prefills pipeline through the overlap
window; and `bucket_policy="ttft"` chooses between admitting small early
and waiting to fill a bigger bucket using the engine's own measured TTFT.
`max_prompt_len` is the explicit admission bound that replaced the old
over-bucket rejection.

The engine is also FAULT-TOLERANT (demonstrated below): requests carry
deadlines (`deadline_ms`) and can be cancelled (`cancel(rid)`); overload
is shed at submit (`max_queue` / `max_queue_age_ms` → `ShedError`);
`guard=True` turns on per-step finiteness probes that quarantine a slot
whose numerics go NaN/Inf instead of emitting garbage; and because every
request's session is one fixed-size SSM state, `snapshot()`/`restore()`
persist the WHOLE engine through the checkpoint subsystem — a killed
engine resumes mid-request with bit-identical remaining tokens. Failures
are injectable deterministically via `repro.faults.FaultPlan`.

Finally, the PREFIX CACHE (demonstrated below): the same O(1) state is a
cacheable artifact — a shared system prompt's post-prefill state is
stored once and restored by every later request, which then prefills only
its own suffix (`cache_bytes=` / `submit(..., prefix_len=N)`), with
token streams bit-identical to cold prefills. The full request lifecycle
— admission, packed/chunked prefill, StateCache hit paths, speculative
decode — is walked through in docs/serving.md.

    PYTHONPATH=src python examples/serve_packed.py
"""
import dataclasses
import sys
import tempfile

import numpy as np
import jax

sys.path.insert(0, "src")

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs.base import get_config
from repro.faults import EngineKilled, FaultPlan
from repro.launch.serve import ServeEngine, ShedError
from repro.models.lm import build_model


def main():
    cfg = dataclasses.replace(get_config("mamba-110m"),
                              d_model=128, n_layers=4, vocab=512,
                              dtype="float32", scan_chunk=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # --- continuous engine: 4 slots, 12 requests with mixed prompt sizes
    # AND mixed token budgets — the regime where padded waves waste steps.
    # overlap=True keeps decode stepping while each refill prefill is in
    # flight; target_ttft_ms bounds how long a queued request can wait
    # before admission stops batching for throughput and refills anyway.
    engine = ServeEngine(model, params, num_slots=4, max_len=128,
                         prefill_rows=2, buckets=(32, 64), max_segments=3,
                         overlap=True, target_ttft_ms=100.0)
    lens = rng.integers(5, 40, size=12)
    budgets = rng.integers(4, 16, size=12)
    rids = [engine.submit(rng.integers(1, cfg.vocab, size=int(n)), int(b))
            for n, b in zip(lens, budgets)]
    outs = engine.run()
    for rid in rids[:5]:
        print(f"req{rid}: prompt[{lens[rid]}] budget {budgets[rid]} "
              f"-> {outs[rid]}")
    st = engine.stats
    pct = st.ttft_percentiles()
    print(f"stats: {st.generated} tokens, {st.prefills} packed prefills "
          f"({st.midflight_refills} mid-flight, {st.overlapped_prefills} "
          f"overlapped, {st.early_admits} TTFT-forced), {st.decode_steps} "
          f"decode steps, {len(st.buckets)} prefill shape(s) compiled for "
          f"{len(set(map(int, lens)))} distinct prompt lengths")
    print(f"latency: TTFT p50 {pct['p50']:.0f}ms p95 {pct['p95']:.0f}ms "
          f"(incl. compiles), {len(st.itl_ms)} inter-token intervals "
          f"tracked")

    # --- batched sampling: per-request temperature/top-k/top-p, sampled
    # inside the fused decode step with a (seed, rid)-keyed stream — the
    # same request sampled twice gives the same tokens, and greedy
    # (temperature=0, the default) is exactly argmax
    probe2 = rng.integers(1, cfg.vocab, size=12)
    r_greedy = engine.submit(probe2, 6)
    r_hot = engine.submit(probe2, 6, temperature=0.9, top_k=8)
    r_nuc = engine.submit(probe2, 6, temperature=0.9, top_p=0.7)
    souts = engine.run()
    print(f"sampling: greedy {souts[r_greedy]} | top-k8 {souts[r_hot]} | "
          f"top-p0.7 {souts[r_nuc]}")

    # --- EOS termination: pick a token greedy decode emits and serve with
    # it as EOS — the slot frees early and the queue takes over
    probe = rng.integers(1, cfg.vocab, size=9)
    probe_rid = engine.submit(probe, 8)
    full = engine.run()[probe_rid]
    eos = full[len(full) // 2]
    rid2 = engine.submit(probe, 8, eos=eos)
    cut = engine.run()[rid2]
    print(f"eos={eos}: free-run {full} -> terminated {cut} "
          f"(stopped early: {len(cut) < len(full)})")

    # --- scheduler v2: a prompt 2× the largest bucket rides the chunk
    # lane (fixed 64-token slabs resuming from carried state) while short
    # requests keep decoding; the prefill pool keeps up to 2 packed
    # prefills in flight; bucket_policy="ttft" upgrades to the 64-bucket
    # only when that admits more AND the head still has latency slack
    v2 = ServeEngine(model, params, num_slots=4, max_len=256,
                     prefill_rows=2, buckets=(32, 64), max_segments=3,
                     overlap=True, target_ttft_ms=100.0,
                     max_inflight_prefills=2, bucket_policy="ttft",
                     chunk_size=64, max_prompt_len=192)
    giant = rng.integers(1, cfg.vocab, size=130)     # 130 > bucket 64
    rg2 = v2.submit(giant, 6)
    rsmall = [v2.submit(rng.integers(1, cfg.vocab, size=int(n)), int(b))
              for n, b in zip(lens[:6], budgets[:6])]
    v2outs = v2.run()
    s2 = v2.stats
    print(f"scheduler v2: {len(giant)}-token prompt chunked over "
          f"{s2.chunk_rounds} slab rounds ({s2.chunk_tokens} tokens) -> "
          f"{len(v2outs[rg2])} tokens decoded; {len(rsmall)} short "
          f"requests served alongside ({s2.bucket_upgrades} bucket "
          f"upgrades, {s2.deferred_upgrades} deferred, queue depth max "
          f"{s2.queue_depth_max})")
    print(f"time split: prefill {s2.prefill_ms:.0f}ms chunk "
          f"{s2.chunk_ms:.0f}ms decode {s2.decode_ms:.0f}ms host "
          f"{s2.host_ms:.1f}ms")

    # --- the padded-wave baseline on the same engine class, for contrast
    wave = ServeEngine(model, params, num_slots=4, max_len=128)
    prompts = [rng.integers(1, cfg.vocab, size=int(n)) for n in lens[:4]]
    wave_outs = wave.decode_batch(prompts, 8)
    print(f"padded-wave baseline decoded {sum(map(len, wave_outs))} tokens "
          f"in one synchronous wave (compare: the engine above never "
          f"drains)")

    # =================================================================
    # fault tolerance
    # =================================================================

    # --- deadlines + cancellation + load shedding: requests carry a
    # submit→completion budget; overdue requests expire (tokens so far are
    # kept), cancel() revokes a request in any stage, and a bounded queue
    # sheds at submit instead of queueing forever under overload
    ft = ServeEngine(model, params, num_slots=4, max_len=128,
                     prefill_rows=2, buckets=(32, 64), max_segments=3,
                     max_queue=8)
    ok_rid = ft.submit(rng.integers(1, cfg.vocab, size=12), 6)
    tight = ft.submit(rng.integers(1, cfg.vocab, size=12), 6,
                      deadline_ms=0.001)     # expires before admission
    victim = ft.submit(rng.integers(1, cfg.vocab, size=12), 6)
    ft.cancel(victim)
    fouts = ft.run()
    print(f"lifecycle: req{ok_rid} {ft.status[ok_rid]} "
          f"({len(fouts[ok_rid])} tokens) | req{tight} {ft.status[tight]} "
          f"| req{victim} {ft.status[victim]} | stats: "
          f"{ft.stats.expired} expired, {ft.stats.cancelled} cancelled")
    try:
        for _ in range(20):
            ft.submit(rng.integers(1, cfg.vocab, size=8), 4)
    except ShedError as e:
        print(f"overload shed at submit: {e.reason} "
              f"(shed={ft.stats.shed})")
    ft.run()

    # --- numerical guard rails + fault injection: poison one slot's
    # logits at decode step 2 (FaultPlan makes it deterministic); the
    # engine quarantines that slot with a diagnostic, every other stream
    # is bit-identical to a fault-free run
    plan = FaultPlan(poison_decode={2: [1]})
    gd = ServeEngine(model, params, num_slots=4, max_len=128,
                     prefill_rows=2, buckets=(32, 64), max_segments=3,
                     faults=plan)            # guard auto-enables
    grids = [gd.submit(rng.integers(1, cfg.vocab, size=int(n)), 8)
             for n in lens[:4]]
    gouts = gd.run()
    bad = [r for r in grids if gd.status[r] == "failed"]
    print(f"guard rails: {gd.stats.quarantined} slot quarantined "
          f"({gd.errors[bad[0]][:60]}…), "
          f"{sum(gd.status[r] == 'done' for r in grids)} requests "
          f"unaffected")

    # --- crash recovery: kill the engine mid-decode, restore a FRESH
    # engine from the last snapshot, finish every stream identically —
    # O(1) per-request state makes the whole-engine snapshot tiny
    ckdir = tempfile.mkdtemp(prefix="serve_snap_")
    mgr = CheckpointManager(ckdir, keep=2, async_save=False)
    doomed = ServeEngine(model, params, num_slots=4, max_len=128,
                         prefill_rows=2, buckets=(32, 64), max_segments=3,
                         faults=FaultPlan(kill_at_step=3))
    dr = [doomed.submit(rng.integers(1, cfg.vocab, size=int(n)), 8)
          for n in lens[:4]]
    try:
        snap = 0
        while True:
            doomed.snapshot(mgr, step=snap)
            snap += 1
            if not doomed.step():
                break
    except EngineKilled as e:
        print(f"crash: {e}")
    fresh = ServeEngine(model, params, num_slots=4, max_len=128,
                        prefill_rows=2, buckets=(32, 64), max_segments=3)
    fresh.restore(mgr)
    routs = fresh.run()
    print(f"recovery: restored step {mgr.latest_step()}, resumed "
          f"{sorted(fresh.resumed)}, all done="
          f"{all(fresh.status[r] == 'done' for r in dr)}, "
          f"{sum(len(routs[r]) for r in dr)} total tokens delivered")

    # =================================================================
    # prefix caching on the O(1) state (docs/serving.md §4)
    # =================================================================

    # a shared 48-token "system prompt": the first request with it cuts
    # its chunked prefill at the declared boundary and stores that state;
    # every request behind it restores the state and prefills only its
    # 8-token tail. Streams are bit-identical to cache-off runs.
    system = rng.integers(1, cfg.vocab, size=48).tolist()
    tails = [rng.integers(1, cfg.vocab, size=8).tolist() for _ in range(6)]
    cache_kw = dict(num_slots=4, max_len=128, prefill_rows=2,
                    buckets=(32, 64), max_segments=3,
                    chunk_rows=1, chunk_size=64)
    cold = ServeEngine(model, params, **cache_kw)
    crids = [cold.submit(system + t, 6) for t in tails]
    couts = cold.run()
    warm = ServeEngine(model, params, cache_bytes=64 << 20, **cache_kw)
    wrids = [warm.submit(system + t, 6, prefix_len=len(system))
             for t in tails]
    wouts = warm.run()
    assert [wouts[r] for r in wrids] == [couts[r] for r in crids]
    print(f"prefix cache: {warm.state_cache.hits} hits, "
          f"{warm.stats.chunk_tokens + warm.stats.prefill_tokens} prompt "
          f"tokens forwarded warm vs "
          f"{cold.stats.chunk_tokens + cold.stats.prefill_tokens} cold — "
          f"streams bit-identical ({warm.state_cache!r})")


if __name__ == "__main__":
    main()
