"""Packed-training lane end to end: PackingLoader (mode/policy knobs) +
background prefetch + bf16 mixed precision with f32 scan carries + the
fwd+bwd tuner objective.

    PYTHONPATH=src python examples/train_packed.py
    PYTHONPATH=src python examples/train_packed.py --mode pad --dtype float32
    PYTHONPATH=src python examples/train_packed.py --policy sequential \
        --scan-tune auto

This is the example-sized version of `python -m repro.launch.train`; the
launcher adds checkpoint/resume, SIGTERM safety, and mesh sharding on top
of exactly this wiring. The gated full-size numbers (single vs pad vs pack
x f32 vs bf16) live in BENCH_train.json (`make bench-train`).
"""
import argparse
import dataclasses
import sys

import jax

sys.path.insert(0, "src")

from repro.configs.base import get_config
from repro.data.dataset import SyntheticCorpus, CorpusConfig
from repro.data.packing_loader import PackingLoader, LoaderConfig
from repro.data.prefetch import PrefetchLoader
from repro.models.lm import build_model
from repro.optim.adamw import AdamW, AdamWConfig, cosine_schedule
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--rows", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--mode", default="pack",
                    choices=["pack", "pad", "single"])
    ap.add_argument("--policy", default="first_fit_decreasing",
                    choices=["sequential", "sorted_greedy", "first_fit",
                             "first_fit_decreasing"])
    ap.add_argument("--dtype", default="bfloat16",
                    help="activation/compute dtype; scan carries and the "
                         "loss reduction stay f32 regardless")
    ap.add_argument("--param-dtype", default="float32",
                    help="parameter storage dtype (bfloat16 keeps f32 "
                         "master weights inside AdamW)")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="batches packed ahead on a background thread "
                         "(0 = synchronous)")
    ap.add_argument("--scan-tune", default="off",
                    help="off | auto | <cache path>: resolve scan "
                         "schedules from the shape-keyed cache, warmed "
                         "here with the fwdbwd (training) objective")
    args = ap.parse_args()

    # a small model from the paper's family; dtype knobs are config fields
    cfg = dataclasses.replace(
        get_config("mamba-110m"), d_model=128, n_layers=4, vocab=512,
        scan_chunk=64, dtype=args.dtype, param_dtype=args.param_dtype)
    if args.scan_tune != "off":
        # training shapes want schedules timed on forward+backward, not
        # inference's forward-only sweep — the objective tags the cache key
        cfg = dataclasses.replace(cfg, scan_tune=args.scan_tune,
                                  tune_objective="fwdbwd")
        from repro.tune import warm_for_config
        warm_for_config(cfg, [(args.rows, args.seq_len)],
                        objective="fwdbwd")
    model = build_model(cfg)

    # lognormal variable-length stream -> packed (rows, seq_len) buffers;
    # batch(step) is a pure function of step, so the prefetch wrapper is a
    # memoizer and restart replay stays exact
    corpus = SyntheticCorpus(CorpusConfig(
        vocab=cfg.vocab, seed=0, len_min=16, len_max=args.seq_len,
        mu=float(__import__("math").log(args.seq_len / 4.0)), sigma=0.6))
    loader = PackingLoader(corpus, LoaderConfig(
        rows=args.rows, seq_len=args.seq_len, mode=args.mode,
        policy=args.policy))
    print(f"loader: mode={args.mode}, policy={args.policy}, "
          f"padding_rate={loader.stats(0)['padding_rate']:.1%}")
    if args.prefetch > 0:
        loader = PrefetchLoader(loader, depth=args.prefetch)

    opt = AdamW(cosine_schedule(1e-3, warmup=5, total=args.steps),
                AdamWConfig(weight_decay=0.1, clip_norm=1.0))
    trainer = Trainer(model, opt, loader,
                      TrainerConfig(steps=args.steps, log_every=10))
    state, hist = trainer.train(jax.random.PRNGKey(0))

    real = sum(h["real_tokens"] for h in hist)
    buf = sum(h["buffer_tokens"] for h in hist)
    print(f"done: loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"over {len(hist)} steps; {real:.0f} real / {buf:.0f} buffer "
          f"tokens ({real / buf:.0%} real)")
    if args.prefetch > 0:
        st = loader.stats(args.steps - 1)
        print(f"prefetch: {st['prefetch_hits']} hits / "
              f"{st['prefetch_misses']} misses")
        loader.close()


if __name__ == "__main__":
    main()
