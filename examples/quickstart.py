"""Quickstart: pack variable-length sequences, train a small Mamba, verify
Packing–Unpacking Invariance end to end.

    PYTHONPATH=src python examples/quickstart.py

CI: `.github/workflows/ci.yml` runs `make ci` on every push — the fast
tier-1 lane (`pytest -m "not slow"`; the slow-marked engine round-trips
and grid sweeps stay in the full local `make verify`), the fault-injection
chaos lane (`make verify-faults`, a randomized-but-seeded FaultPlan —
same FAULT_CHAOS_SEED, same faults, any machine), the tune-cache
audit (`make tune-check`), a tiny-shape benchmark smoke whose JSON
structure is schema-checked while its timings are never gated
(`make bench-smoke`), and the observability smoke (`make obs-smoke`:
tiny traced serve+train launcher runs, Chrome-trace structure validated
by `python -m repro.obs.check`). Benchmark baselines are refreshed with
`make bench-scan` / `make bench-serve` and promoted via
`make bench-accept` (the *.new.json staging files never get committed).
"""
import dataclasses
import sys

import numpy as np
import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.configs.base import get_config
from repro.core.packing import pack, unpack
from repro.models.lm import build_model
from repro.optim.adamw import AdamW, cosine_schedule
from repro.train.trainer import make_train_step


def main():
    # 1. a tiny Mamba (the paper's architecture family)
    #    (ssm_variant="mamba2" — or get_config("mamba2-370m") — selects the
    #    head-structured Mamba-2/SSD core instead: scalar per-head decay,
    #    whose blocked schedule runs one (T,T)·(T,dh·N) matmul per head.
    #    Everything below, packing included, works identically for both.)
    cfg = dataclasses.replace(get_config("mamba-110m"),
                              d_model=128, n_layers=4, vocab=512,
                              dtype="float32", scan_chunk=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # 2. pack variable-length sequences into one fixed buffer
    rng = np.random.default_rng(0)
    seqs = [rng.integers(1, cfg.vocab, size=n).astype(np.int32)
            for n in (57, 130, 75, 98, 160)]
    pb = pack(seqs, capacity=256)
    print(f"packed {len(seqs)} seqs (lens {[len(s) for s in seqs]}) into "
          f"{pb.tokens.shape[0]} buffer(s) of 256; "
          f"padding rate {pb.padding_rate():.1%}")

    # 3. PUI check: packed forward == per-sequence forward
    batch = {"tokens": pb.tokens, "positions": pb.positions,
             "segment_ids": pb.segment_ids}
    packed_logits = model.forward(params, batch)
    per_seq = unpack(packed_logits, pb)
    worst = 0.0
    for s, lg in zip(seqs, per_seq):
        single = {"tokens": jnp.asarray(s)[None],
                  "positions": jnp.arange(len(s))[None],
                  "segment_ids": jnp.ones((1, len(s)), jnp.int32)}
        ref = model.forward(params, single)[0]
        worst = max(worst, float(jnp.abs(ref - lg).max()))
    print(f"PUI: max |packed - per-seq| logit diff = {worst:.2e}")

    # 4. a few train steps on the packed batch
    opt = AdamW(cosine_schedule(1e-3, warmup=2, total=20))
    step = jax.jit(make_train_step(model, opt))
    state = {"params": params, "opt": opt.init(params)}
    for i in range(10):
        state, metrics = step(state, batch)
        if i == 0:
            f32_first_loss = float(metrics["loss"])
        if i % 3 == 0:
            print(f"step {i}: loss {float(metrics['loss']):.4f}")

    # 4b. the production training lane (examples/train_packed.py runs all
    #     of this end to end; `python -m repro.launch.train` is the real
    #     entry):
    #     * PackingLoader streams packed (rows, seq_len) buffers as a pure
    #       function of `step` (restart replay is exact); its
    #       policy="first_fit_decreasing" cuts padding_rate vs arrival
    #       order, and data/prefetch.PrefetchLoader packs the next batches
    #       on a background thread while the device trains — memoized, so
    #       every batch stays bit-identical to the synchronous loader.
    #     * dtype="bfloat16" turns on carry-aware mixed precision: the
    #       forward/backward runs bf16 while the scan/rglru/mLSTM
    #       recurrence carries and the loss reduction stay f32 (Mamba keeps
    #       SSM carries f32 — a blanket cast diverges);
    #       param_dtype="bfloat16" additionally stores params in bf16 with
    #       f32 master weights inside AdamW, so tiny updates are never
    #       lost to bf16's 8-bit mantissa.
    #     * the Trainer logs real tok/s (segment_ids > 0) next to buffer
    #       tok/s, so padding overhead is visible per step; the gated
    #       single-vs-pad-vs-pack × f32/bf16 numbers live in
    #       BENCH_train.json (`make bench-train`).
    bf16_cfg = dataclasses.replace(cfg, dtype="bfloat16")
    bf16_model = build_model(bf16_cfg)
    bf16_step = jax.jit(make_train_step(bf16_model, opt))
    p16 = bf16_model.init(jax.random.PRNGKey(0))
    s16 = {"params": p16, "opt": opt.init(p16)}
    s16, m16 = bf16_step(s16, batch)
    print(f"bf16 lane: loss {float(m16['loss']):.4f} "
          f"(f32 step 0 was {f32_first_loss:.4f}; carries stay f32)")

    # 5. serving: the same packing trick on the inference path. The
    #    ServeEngine packs queued prompts into ONE prefill forward, hands
    #    each prompt's final recurrent state off to a decode slot
    #    (model.prefill_packed -> model.scatter_into_cache), and refills
    #    slots mid-flight as requests finish — continuous batching with a
    #    bucket-bounded number of compiled prefill shapes. Refill prefills
    #    are dispatched ASYNCHRONOUSLY (overlap=True: decode keeps stepping
    #    while the packed forward is in flight), admission is latency-aware
    #    (target_ttft_ms bounds the head-of-line wait; stats.ttft_ms /
    #    itl_ms / ttft_percentiles() expose the resulting latencies), and
    #    submit() takes per-request temperature / top_k / top_p sampled in
    #    the fused decode step (temperature=0 → exact greedy).
    #    The engine is fault-tolerant: per-request deadlines
    #    (submit(..., deadline_ms=...)), cancel(rid), overload shedding
    #    (max_queue / max_queue_age_ms → ShedError), guard=True finiteness
    #    probes that quarantine NaN/Inf slots, and snapshot()/restore()
    #    through checkpoint.CheckpointManager — each request's session is
    #    one O(1) SSM state, so a killed engine resumes every in-flight
    #    request with bit-identical remaining tokens. Failure modes are
    #    deterministically injectable via repro.faults.FaultPlan.
    #    Scheduler v2: prompts longer than the largest bucket are accepted
    #    and chunk-prefilled — fixed (chunk_rows, chunk_size) slabs resume
    #    from the carried SSM state, so long prompts never head-of-line
    #    block (max_prompt_len is the explicit bound); up to
    #    max_inflight_prefills packed prefills pipeline through the
    #    overlap window; bucket_policy="ttft" trades admit-small-early vs
    #    wait-to-fill-big on the measured TTFT; and ServeStats splits
    #    wall time into prefill_ms/chunk_ms/decode_ms/host_ms.
    #    (see examples/serve_packed.py and `python -m repro.launch.serve`)
    from repro.launch.serve import ServeEngine
    engine = ServeEngine(model, state["params"], num_slots=4, max_len=64,
                         buckets=(32,), max_segments=2,
                         overlap=True, target_ttft_ms=100.0,
                         max_inflight_prefills=2)
    for i, s in enumerate(seqs[:6]):
        engine.submit(s[:20], max_new=8,
                      temperature=0.0 if i < 3 else 0.8, top_k=16)
    outs = engine.run()
    pct = engine.stats.ttft_percentiles()
    print(f"served {len(outs)} requests "
          f"({engine.stats.generated} tokens, "
          f"{engine.stats.prefills} packed prefills, "
          f"{engine.stats.overlapped_prefills} overlapped, "
          f"{len(engine.stats.buckets)} prefill shape(s) compiled; "
          f"TTFT p50 {pct['p50']:.0f}ms incl. compiles)")

    # 6. autotuning: every scan-schedule knob above (blocked chunk, in-chunk
    #    evaluator, Pallas subtile, backend) is a measured, shape-keyed
    #    decision when scan_tune != "off". `make bench-tune` sweeps the
    #    candidate spaces once per machine into TUNE_CACHE.json
    #    (fingerprinted by device/jax version; `make tune-check` audits it);
    #    a model with scan_tune="auto" then resolves its knobs from the
    #    cache at trace time, and launch/train.py / launch/serve.py warm the
    #    cache for their exact shape buckets at startup (--scan-tune auto).
    #    The default scan_tune="off" keeps the hard-coded paths bit-for-bit.
    from repro.tune import TuneCache, tuned
    demo = TuneCache()     # normally loaded from TUNE_CACHE.json
    from repro.tune import shape_key
    demo.put(shape_key("selective_scan", B=1, L=256, D=cfg.d_inner,
                       N=cfg.d_state),
             {"backend": "xla", "method": "blocked", "chunk": 32,
              "intra": "assoc"}, us=1234.0)
    knobs = tuned("selective_scan", B=1, L=256, D=cfg.d_inner,
                  N=cfg.d_state, cache=demo)
    print(f"tuned scan knobs for (B=1, L=256): {knobs} "
          f"(cfg: scan_tune='auto' applies these at trace time)")

    # 7. observability (repro.obs): every engine/trainer above metered
    #    through ONE MetricsRegistry — stats objects are thin views over it
    #    — and, when you pass Obs.on(), a span tracer records per-request
    #    lifecycles (queued → prefill → decode → done, one Perfetto row per
    #    request) and per-step train spans (data wait / fused step / compile
    #    marks). Off by default and provably cheap: the disabled tracer is
    #    a no-op object, and BENCH_serve.json's obs_overhead_pct row
    #    measures the ENABLED cost (< 3% expected). From the CLIs:
    #      python -m repro.launch.serve --tiny --obs-trace trace.json
    #      python -m repro.launch.train --tiny --seq-len 2048 \
    #          --obs-trace trace.json   [--profile-dir d  # + XLA profile]
    #      python -m benchmarks.run serve train --obs-trace trace.json
    #    then open trace.json in chrome://tracing or https://ui.perfetto.dev
    #    (`make obs-smoke` runs tiny traced launcher runs and validates the
    #    trace structure via python -m repro.obs.check).
    from repro.obs import Obs
    obs = Obs.on()
    engine2 = ServeEngine(model, state["params"], num_slots=4, max_len=64,
                          buckets=(32,), max_segments=2, overlap=True,
                          obs=obs)
    for s in seqs[:3]:
        engine2.submit(s[:16], max_new=4)
    engine2.run()
    print(f"obs: {len(obs.tracer.chrome_events())} trace events, "
          f"metrics serve.generated="
          f"{obs.metrics.counter('serve.generated').value}; "
          f"timeline of req0:")
    print(obs.tracer.timeline("req0"))

    # 8. prefix caching on the O(1) SSM state (docs/serving.md §4): a
    #    session's whole inference state is a few KB regardless of prompt
    #    length, so the post-prefill state of a shared system prompt can be
    #    stored ONCE in a host-side LRU (launch/state_cache.py) and every
    #    later request restores it and prefills only its own suffix — or
    #    skips the forward entirely on a whole-prompt hit. Declaring
    #    submit(..., prefix_len=N) marks the shared boundary; streams stay
    #    bit-identical to cold prefills (tests/test_state_cache.py).
    #    From the CLI: --cache-mb 64 --shared-prefix 48 [--spec-k 4].
    from repro.launch.state_cache import StateCache
    sc = StateCache(32 << 20)      # 32 MB byte budget, LRU
    system = seqs[0][:24].tolist()  # a shared "system prompt"
    warm_kw = dict(num_slots=4, max_len=96, buckets=(16, 32),
                   max_segments=2, overlap=True, chunk_rows=1,
                   chunk_size=32, state_cache=sc)
    eng_a = ServeEngine(model, state["params"], **warm_kw)
    tails8 = [rng.integers(1, cfg.vocab, size=8).tolist() for _ in range(4)]
    for t in tails8:
        eng_a.submit(system + t, max_new=4, prefix_len=len(system))
    outs_a = eng_a.run()
    # a SECOND engine reuses the same cache: every request is a warm hit
    eng_b = ServeEngine(model, state["params"], **warm_kw)
    for i, t in enumerate(tails8):
        eng_b.submit(system + t, max_new=4,
                     prefix_len=len(system), rid=100 + i)
    outs_b = eng_b.run()
    assert [outs_b[100 + i] for i in range(4)] == \
           [outs_a[i] for i in range(4)], "warm streams must equal cold"
    print(f"prefix cache: {sc!r}")
    print(f"  warm engine: {sc.hits} hits, "
          f"{eng_b.stats.prefill_tokens + eng_b.stats.chunk_tokens} prompt "
          f"tokens forwarded vs {eng_a.stats.prefill_tokens + eng_a.stats.chunk_tokens} cold "
          f"(streams bit-identical)")
    print("done.")


if __name__ == "__main__":
    main()
