"""Version-compat shims for the mesh/sharding API surface we use.

Mirror of ``kernels/compat.py`` (the CompilerParams shim), for the device
side: newer JAX grows ``jax.sharding.AxisType`` and a matching
``axis_types=`` kwarg on ``jax.make_mesh`` (explicit vs auto sharding
modes); the pinned 0.4.x has neither. Every mesh construction site —
``launch/mesh.py`` and the subprocess sources in
``tests/test_{roofline,sharding,checkpoint}.py`` — resolves mesh creation
through this shim so a version bump is a one-line change here instead of an
``AttributeError`` at mesh-build time in each call site.
"""
from __future__ import annotations

import jax

AxisType = getattr(jax.sharding, "AxisType", None)


def auto_axis_types(n: int):
    """``axis_types`` kwargs for an n-axis mesh: Auto on every axis where
    the running JAX supports axis types, {} otherwise."""
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n}


def make_mesh(axis_shapes, axis_names, **kwargs):
    """``jax.make_mesh`` with Auto axis types whenever supported."""
    return jax.make_mesh(axis_shapes, axis_names,
                         **auto_axis_types(len(axis_names)), **kwargs)
