"""Sharding rules: param/optimizer/batch/cache PartitionSpecs for the
production mesh.

Scheme (DESIGN.md §5): mesh axes ("data", "model") per pod, optional leading
"pod". Batch shards over ("pod","data") — the pod axis is pure DP. Params
shard Megatron-TP over "model" on the heads/ffn/vocab/d_inner dim and
FSDP/ZeRO-3 over "data" on a second dim; XLA inserts the all-gathers.
Optimizer moments inherit the param spec (sharded Adam). Decode KV caches
shard batch over DP axes and *sequence over "model"* (decode-time sequence
parallelism: partial-softmax reductions become model-axis all-reduces).

Every rule is guarded by divisibility — an axis that does not divide the dim
is dropped (falls back to replication), which is what makes the same rules
serve the 1-device smoke tests, the 16×16 pod and the 2×16×16 multi-pod
mesh, and any elastic restart size.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([_axis_size(mesh, n) for n in name]))
    return mesh.shape[name] if name in mesh.shape else 0


def _fit(mesh: Mesh, dim: int, axis):
    """axis if it exists in mesh and divides dim, else None."""
    s = _axis_size(mesh, axis)
    return axis if s and dim % s == 0 else None


def dp_axes(mesh: Mesh):
    """Data-parallel axes: ("pod","data") when pod exists, else ("data",)."""
    names = [n for n in ("pod", "data") if n in mesh.shape]
    return tuple(names)


def batch_axis(mesh: Mesh, batch_size: int):
    """Largest DP prefix whose product divides the batch."""
    axes = dp_axes(mesh)
    if not axes:
        return None
    prod = int(np.prod([mesh.shape[a] for a in axes]))
    if batch_size % prod == 0:
        return axes if len(axes) > 1 else axes[0]
    # try data only
    if "data" in mesh.shape and batch_size % mesh.shape["data"] == 0:
        return "data"
    return None


# ---------------------------------------------------------------------------
# parameter rules — keyed by leaf name (last path component)
# ---------------------------------------------------------------------------

def _param_rule(name: str, shape: Tuple[int, ...], mesh: Mesh,
                in_mlstm: bool = False) -> P:
    d = len(shape)
    m, dta = "model", "data"

    def spec2(row, col):                     # helper with divisibility guard
        return (_fit(mesh, shape[-2], row), _fit(mesh, shape[-1], col))

    if in_mlstm and name in ("wq", "wk", "wv"):
        # mlstm square projections consume the model-sharded conv output:
        # row-TP (contraction sharded, output replicated)
        return P(*spec2(m, None))
    if name == "embed":                       # (V, d): vocab-TP + FSDP
        body = spec2(m, dta)
    elif name == "head":                      # (d, V)
        body = spec2(dta, m)
    elif name in ("wq", "w_gate", "w_up", "w_upx", "w_upz", "in_proj",
                  "dt_w", "w_x", "w_y", "w_pre", "shared_gate", "shared_up"):
        body = spec2(dta, m)                  # column-TP (output sharded)
    elif name in ("wo", "w_down", "out_proj", "x_proj", "wo_rec",
                  "shared_down", "w_out", "bc_proj"):
        body = spec2(m, dta)                  # row-TP (contraction sharded)
    elif name == "dt_proj":                   # (d_inner, H): H heads are few
        body = spec2(m, None)                 # — shard the contraction only
    elif name == "wkv":                       # GQA KV: small — replicate cols
        body = spec2(dta, None)
    elif name == "router":                    # (d, E)
        body = spec2(dta, None)
    elif name.startswith("experts_"):         # (E, d_in, d_out)
        ep = _fit(mesh, shape[0], m)
        if ep:                                # expert parallelism
            body = (ep, _fit(mesh, shape[1], dta), None)
        elif name.endswith("down"):           # TP inside expert: (E, ff, d)
            body = (None, _fit(mesh, shape[1], m),
                    _fit(mesh, shape[2], dta))
        else:                                 # (E, d, ff)
            body = (None, _fit(mesh, shape[1], dta),
                    _fit(mesh, shape[2], m))
    elif name in ("conv_w",):                 # (W, channels)
        body = (None, _fit(mesh, shape[-1], m))
    elif name in ("conv_b", "dt_b", "D", "a_param",
                  "ssm_norm_w"):                       # (channels,)
        body = (_fit(mesh, shape[-1], m),)
    elif name == "A_log":
        if d == 1:                            # mamba2: (H,) per-head decay
            body = (_fit(mesh, shape[-1], m),)
        else:                                 # mamba1: (d_inner, N)
            body = (_fit(mesh, shape[-2], m), None)
    elif name in ("w_r", "w_i"):              # (nb, c, c) block-diag gates
        body = (_fit(mesh, shape[-3], m), None, None)
    elif name in ("w_if",):                   # (pf, 2H)
        body = (_fit(mesh, shape[-2], dta), None)
    elif name == "input_proj":
        body = spec2(dta, None)
    elif name == "R":                         # slstm (4, H, dh, dh)
        body = (None, None, None, None)
    else:                                     # norms, biases, scales
        body = tuple(None for _ in shape)
    body = tuple(body[-d:]) if d <= len(body) else \
        (None,) * (d - len(body)) + tuple(body)
    return P(*body)


def param_pspecs(params_shape, mesh: Mesh):
    """Pytree of PartitionSpecs matching a params (shape) tree. Stacked-unit
    leading dims (path contains 'units') get a leading None."""
    def one(path, leaf):
        name = None
        stacked = False
        in_mlstm = False
        for pth in path:
            k = getattr(pth, "key", None)
            if k == "units":
                stacked = True
            if k is not None:
                if "mlstm" in str(k):
                    in_mlstm = True
                name = k
        shape = leaf.shape
        if stacked:
            spec = _param_rule(name, shape[1:], mesh, in_mlstm)
            return P(None, *spec)
        return _param_rule(name, shape, mesh, in_mlstm)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def shardings_for(tree_shape, pspecs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)


# ---------------------------------------------------------------------------
# batch / cache rules
# ---------------------------------------------------------------------------

def batch_pspecs(batch_shape: Dict[str, Any], mesh: Mesh):
    def one(leaf):
        b = batch_axis(mesh, leaf.shape[0])
        return P(b, *([None] * (len(leaf.shape) - 1)))
    return jax.tree.map(one, batch_shape)


def _cache_leaf_spec(name, core, mesh: Mesh):
    """Slot-major cache-leaf body spec: (B, …) → axis tuple (no P)."""
    if name in ("k", "v") and len(core) == 4:      # (B, S, Hkv, hd)
        return (batch_axis(mesh, core[0]), _fit(mesh, core[1], "model"),
                None, None)
    if name == "conv" and len(core) == 3:          # (B, W-1, ch)
        return (batch_axis(mesh, core[0]), None,
                _fit(mesh, core[2], "model"))
    if name == "ssm" and len(core) == 3:           # (B, d_inner, N)
        return (batch_axis(mesh, core[0]),
                _fit(mesh, core[1], "model"), None)
    if name == "ssm" and len(core) == 4:     # (B, H, dh, N) head-struct.
        return (batch_axis(mesh, core[0]),
                _fit(mesh, core[1], "model"), None, None)
    if name == "h" and len(core) == 2:             # (B, lru)
        return (batch_axis(mesh, core[0]), _fit(mesh, core[1], "model"))
    return (batch_axis(mesh, core[0]),) + (None,) * (len(core) - 1)


_CACHE_LEAF_NAMES = ("k", "v", "conv", "ssm", "h", "C", "n", "m", "c")


def _cache_path_info(path):
    name, stacked = None, False
    for pth in path:
        k = getattr(pth, "key", None)
        if k == "units":
            stacked = True
        if k in _CACHE_LEAF_NAMES:
            name = k
    return name, stacked


def cache_pspecs(cache_shape, mesh: Mesh, batch_size: int):
    """Decode caches: batch over DP axes (when divisible), attention K/V
    sequence dim over 'model' (decode sequence parallelism); recurrent
    channel states over 'model'."""
    del batch_size

    def one(path, leaf):
        name, stacked = _cache_path_info(path)
        shp = leaf.shape
        # stacked over units: leading n_units dim
        lead = (None,) if stacked else ()
        core = shp[1:] if stacked else shp
        return P(*(lead + _cache_leaf_spec(name, core, mesh)))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def packed_state_pspecs(state_shape, mesh: Mesh):
    """PartitionSpecs for the packed-prefill handoff states
    (``model.prefill_packed``): same layout as the decode cache but every
    leaf carries a (B, S) leading pair — prefill rows shard like cache
    batch, the per-row segment axis is replicated (segments are scattered
    to arbitrary slots right after harvest, so sharding it would only buy
    an all-to-all). Unit-stacked leaves keep their leading None."""
    def one(path, leaf):
        name, stacked = _cache_path_info(path)
        shp = leaf.shape
        lead = (None,) if stacked else ()
        core = shp[1:] if stacked else shp          # (B, S, …)
        body = _cache_leaf_spec(name, (core[0],) + core[2:], mesh)
        spec = (body[0], None) + body[1:]           # reinsert segment axis
        return P(*(lead + spec))

    return jax.tree_util.tree_map_with_path(one, state_shape)
