"""hubert-xlarge [audio] — encoder-only (w2v2 arch). 48L d_model=1280 16H
(kv=16) d_ff=5120 vocab=504 [arXiv:2106.07447; unverified].

The modality frontend (CNN feature extractor) is a STUB per assignment:
input_specs() supplies precomputed frame embeddings (B, L, d_model).
Encoder-only ⇒ bidirectional segment-masked attention, no decode shapes."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    act="geglu",
    encoder_only=True,
    notes="decode_32k / long_500k skipped: no autoregressive step exists.",
))
