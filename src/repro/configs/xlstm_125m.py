"""xlstm-125m [ssm] — sLSTM + mLSTM blocks. 12L d_model=768 4H d_ff=0
vocab=50304 [arXiv:2405.04517; unverified].

xLSTM[7:1]-style mix expressed as a 6-layer pattern unit (5 mLSTM + 1 sLSTM,
repeated twice ⇒ sLSTM at depths 5 and 11). d_ff=0: mLSTM blocks carry their
own ×2 up-projection, no separate FFN. O(1) decode state ⇒ runs long_500k."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="xlstm-125m",
    family="xlstm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    pattern=("mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "slstm"),
    proj_factor=2.0,
    conv_width=4,
    notes="sLSTM is inherently sequential (DESIGN.md §Arch-applicability); "
          "segment resets still give exact PUI.",
))
