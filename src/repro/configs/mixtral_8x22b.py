"""mixtral-8x22b [moe] — 8 experts top-2, SWA. 56L d_model=6144 48H (GQA kv=8)
d_ff=16384 vocab=32768 [arXiv:2401.04088; hf]. Sliding window 4096 ⇒
bounded decode cache ⇒ runs long_500k. Experts (8) < model-axis (16) ⇒
sharding rules fall back to TP-inside-expert (see distributed/sharding.py)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    act="swiglu",
    attn_window=4096,
    n_experts=8,
    top_k=2,
))
