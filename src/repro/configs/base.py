"""ArchConfig: one dataclass describing every supported architecture family.

Families:
  dense   — llama-style decoder (GQA + SwiGLU/GeGLU)
  moe     — dense backbone with MoE FFN (top-k routing, optional shared experts)
  mamba   — the paper's architecture (conv1d_pack + selective_scan blocks)
  hybrid  — RecurrentGemma/Griffin: RG-LRU recurrent blocks + local attention
  xlstm   — mLSTM blocks with interspersed sLSTM
  audio   — encoder-only transformer over precomputed frame embeddings (stub
            frontend per assignment), bidirectional attention
  vlm     — decoder with M-RoPE + vision-embedding injection (stub frontend)

Heterogeneous layer stacks are expressed as a repeating *pattern unit*
(e.g. ("rec", "rec", "attn") for RecurrentGemma): the model stacks whole
units and lax.scan's over them, with any remainder layers applied unstacked.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

REGISTRY = {}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense|moe|mamba|hybrid|xlstm|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None    # default d_model // n_heads
    act: str = "swiglu"               # swiglu | geglu
    attn_window: Optional[int] = None  # sliding-window size (None = full)
    rope_theta: float = 10000.0
    mrope_sections: Optional[Tuple[int, ...]] = None   # vlm only
    encoder_only: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    moe_token_chunk: int = 0          # >0: lax.map the MoE over token chunks
                                      # (bounds dispatch-buffer memory)
    # Mamba
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None     # default ceil(d_model / 16)
    ssm_variant: str = "mamba1"       # mamba1 (per-channel decay, dh=1) |
                                      # mamba2 (SSD: scalar per-head decay,
                                      # single-matmul blocked schedule)
    ssm_heads: Optional[int] = None   # mamba2: #heads (default d_inner/hd)
    ssm_head_dim: Optional[int] = None  # mamba2: head dim dh (default 64)
    ssm_norm: str = "none"            # mamba2 output gate: "none" (plain
                                      # y·silu(z)) | "rms_gate" (RMSNorm the
                                      # gated product before out_proj, with
                                      # a learned (d_inner,) scale — the
                                      # Mamba-2 `rmsnorm` variant)
    # hybrid / xlstm layer pattern: one entry per layer in the unit
    pattern: Tuple[str, ...] = ()     # e.g. ("rec","rec","attn"); () = homogeneous
    lru_width: Optional[int] = None   # hybrid recurrent width (default d_model)
    lru_gate_blocks: int = 16         # block-diagonal RG-LRU gates (Griffin);
                                      # blocks shard over the model axis
    conv_width: int = 4               # hybrid/xlstm temporal conv width
    proj_factor: float = 2.0          # xlstm mLSTM up-projection factor
    # execution
    dtype: str = "bfloat16"           # activation/compute dtype
    param_dtype: str = "float32"
    use_pallas: bool = False          # flip on real TPU for kernel hot paths
    pallas_schedule: str = "blocked"  # step | blocked (Pallas scan kernel;
                                      # blocked = SSD-style subtile matmuls,
                                      # step = per-step reference walk)
    scan_chunk: int = 256             # chunk length for XLA-path scans
    scan_impl: str = "blocked"        # blocked | chunked | fused_seq (XLA
                                      # ssm path; blocked = SSD-style
                                      # block-parallel schedule, the default
                                      # hot path — see core/scan.py)
    scan_intra: Optional[str] = None  # blocked in-chunk evaluator: None =
                                      # auto (mamba1: matmul on TPU, assoc
                                      # on CPU; mamba2: quad). Force
                                      # "matmul" | "assoc" (mamba1) or
                                      # "quad" | "dual" (mamba2; dual = the
                                      # C·Bᵀ attention-like form, wins when
                                      # head dim ≫ chunk)
    scan_tune: str = "off"            # shape-keyed autotuning (repro/tune):
                                      # "off" = the knobs above stand as-is
                                      # (bit-identical HLO); "auto" = resolve
                                      # measured winners from the process-
                                      # default TUNE_CACHE.json; a path =
                                      # resolve from that cache file.
                                      # launch/train.py + launch/serve.py
                                      # warm the cache for their shapes.
    tune_objective: str = "fwd"       # which sweep's winners scan_tune
                                      # resolves: "fwd" (forward-only —
                                      # serving) | "fwdbwd" (forward+backward
                                      # — what launch/train.py sets so the
                                      # training step gets schedules tuned
                                      # for its own gradient shapes)
    scan_dtype: str = "float32"       # recurrence compute dtype (bf16 halves
                                      # the scan's HBM traffic on the XLA path)
    act_pspec: Optional[Tuple] = None  # sharding constraint on the residual
    #   carry between layer units, e.g. (("pod","data"), "model", None) —
    #   Megatron-SP-style sequence sharding of saved activations
    attn_chunk: Optional[int] = None  # online-softmax KV chunk (None=auto)
    remat: str = "unit"               # none | unit (checkpoint each unit)
    # sub-quadratic? (drives the long_500k skip rule)
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else \
            self.d_model // self.n_heads

    @property
    def dtr(self) -> int:
        if self.dt_rank is not None:
            return self.dt_rank
        return -(-self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def ssm_hd(self) -> int:
        """Mamba-2 head dim dh; enforces d_inner = ssm_heads · ssm_hd."""
        hd = self.ssm_head_dim
        if hd is None:
            hd = (self.d_inner // self.ssm_heads) if self.ssm_heads else 64
        if self.ssm_heads:
            if self.ssm_heads * hd != self.d_inner:
                raise ValueError(
                    f"ssm_heads ({self.ssm_heads}) × head dim ({hd}) != "
                    f"d_inner ({self.d_inner})")
        elif self.d_inner % hd:
            raise ValueError(
                f"d_inner {self.d_inner} not divisible by ssm_head_dim {hd}")
        return hd

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_hd

    @property
    def unit(self) -> Tuple[str, ...]:
        """The repeating layer-pattern unit."""
        if self.pattern:
            return self.pattern
        if self.family == "mamba":
            return ("mamba2",) if self.ssm_variant == "mamba2" else ("mamba",)
        if self.family == "moe":
            return ("moe_attn",)
        return ("attn",)

    @property
    def sub_quadratic(self) -> bool:
        """True if per-token decode state is O(1) w.r.t. context length."""
        kinds = set(self.unit)
        if kinds <= {"mamba", "mamba2", "rec", "mlstm", "slstm"}:
            return True
        # attention present: sub-quadratic iff windowed
        return self.attn_window is not None

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        k = {}
        if self.n_experts:
            k["n_experts"] = min(self.n_experts, 4)
            k["top_k"] = min(self.top_k, 2)
            k["n_shared_experts"] = min(self.n_shared_experts, 1)
            # no capacity drops at smoke scale (keeps decode parity exact)
            k["capacity_factor"] = 4.0
        if self.mrope_sections is not None:
            k["mrope_sections"] = (2, 3, 3)    # sums to reduced head_dim/2
        if self.family == "hybrid":
            k["lru_gate_blocks"] = 4
        if self.ssm_variant == "mamba2":
            k["ssm_head_dim"] = 16             # 8 heads at d_inner = 128
            k["ssm_heads"] = None
        return dataclasses.replace(
            self, name=self.name + "-smoke",
            n_layers=max(len(self.unit) * 2, 2),
            d_model=64,
            n_heads=4, n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=128,
            lru_width=64 if self.lru_width else None,
            dtype="float32", scan_chunk=8, attn_chunk=None, **k)


def register(cfg: ArchConfig) -> ArchConfig:
    REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    # import the config modules for their registration side effects
    from repro import configs as _c  # noqa: F401
    _c.load_all()
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]
