"""mamba2-370m — Mamba-2 (SSD) evaluation size (Dao & Gu: 48 layers,
d_model=1024, d_state=64, head dim 64 → 32 heads at expand=2).

Same PackMamba packing rules as mamba-110m, but the scalar per-head decay
turns the blocked schedule's in-chunk step into a single (T,T)·(T,dh·N)
matmul per head (core/scan.py taxonomy; kernels schedule='blocked_heads').
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-370m",
    family="mamba",
    n_layers=48,
    d_model=1024,
    n_heads=1, n_kv_heads=1,   # unused by mamba blocks
    d_ff=0,
    vocab=50280,
    d_state=64, d_conv=4, expand=2,
    ssm_variant="mamba2", ssm_head_dim=64,
))
