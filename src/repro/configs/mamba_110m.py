"""mamba-110m — the paper's smallest evaluation model (§4: 16 layers,
d_model=1024). The PackMamba technique applies in full."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba-110m",
    family="mamba",
    n_layers=16,
    d_model=1024,
    n_heads=1, n_kv_heads=1,   # unused by mamba blocks
    d_ff=0,
    vocab=50280,
    d_state=16, d_conv=4, expand=2,
))
