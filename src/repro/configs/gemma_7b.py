"""gemma-7b [dense] — GeGLU, head_dim=256. 28L d_model=3072 16H (kv=16)
d_ff=24576 vocab=256000 [arXiv:2403.08295; hf]. Tied embeddings + sqrt(d)
embedding scaling (Gemma convention)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab=256000,
    act="geglu",
    tie_embeddings=True,
    notes="pure full attention ⇒ long_500k cell skipped (quadratic).",
))
