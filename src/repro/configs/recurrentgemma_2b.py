"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 attn:recurrent.

26L d_model=2560 10H (GQA kv=1 ⇒ MQA) d_ff=7680 vocab=256000
[arXiv:2402.19427; hf]. Pattern unit (rec, rec, attn); 26 = 8×3 + 2, the two
remainder layers are recurrent. Local attention window 2048, head_dim 256
(Griffin convention). Sub-quadratic ⇒ runs long_500k.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    act="geglu",
    attn_window=2048,
    pattern=("rec", "rec", "attn"),
    lru_width=2560,
    conv_width=4,
    tie_embeddings=True,
    notes="RG-LRU diagonal recurrence gets the paper's Ā→0 segment reset; "
          "local attention gets the block-diagonal segment mask.",
))
