"""deepseek-coder-33b [dense] — llama-arch. 62L d_model=7168 56H (GQA kv=8)
d_ff=19200 vocab=32256 [arXiv:2401.14196; hf]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19200,
    vocab=32256,
    act="swiglu",
    notes="pure full attention ⇒ long_500k cell skipped (quadratic).",
))
