"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution. 28L d_model=1536 12H
(GQA kv=2) d_ff=8960 vocab=151936 [arXiv:2409.12191; hf].

Backbone only per assignment: the ViT frontend is a STUB — input_specs()
supplies precomputed patch embeddings injected at vision_positions. M-RoPE
sections (temporal, height, width) split the rotary half-dim 16/24/24;
under packing the 3-channel positions are per-sequence (PUI holds because
positions are inputs)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    act="swiglu",
    mrope_sections=(16, 24, 24),
    notes="pure full attention ⇒ long_500k cell skipped (quadratic).",
))
