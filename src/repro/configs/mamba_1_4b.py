"""mamba-1.4b — paper §4: 48 layers, d_model=2048. Packed seq_len 4096."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba-1.4b",
    family="mamba",
    n_layers=48,
    d_model=2048,
    n_heads=1, n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    d_state=16, d_conv=4, expand=2,
))
