"""moonshot-v1-16b-a3b [moe] — Moonlight (kimi) 64e top-6. 48L d_model=2048
16H (kv=16) d_ff=1408 (per expert) vocab=163840
[hf:moonshotai/Moonlight-16B-A3B; hf]. DeepSeek-V3-style fine-grained
experts with 2 shared experts (Moonlight convention); 64 experts shard
cleanly over the 16-way model axis (EP)."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=163840,
    act="swiglu",
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    notes="pure full attention ⇒ long_500k cell skipped (quadratic).",
))
