"""Architecture config registry. Import load_all() for side-effect
registration of every assigned architecture + the paper's own Mamba sizes."""
import importlib

_MODULES = [
    "recurrentgemma_2b", "stablelm_1_6b", "deepseek_coder_33b", "gemma_7b",
    "deepseek_67b", "hubert_xlarge", "mixtral_8x22b", "moonshot_v1_16b_a3b",
    "qwen2_vl_2b", "xlstm_125m",
    "mamba_110m", "mamba_1_4b", "mamba_2_8b", "mamba2_370m",
]

_loaded = False


def load_all():
    global _loaded
    if _loaded:
        return
    for m in _MODULES:
        importlib.import_module(f"repro.configs.{m}")
    _loaded = True


def all_names():
    load_all()
    from repro.configs.base import REGISTRY
    return sorted(REGISTRY)
