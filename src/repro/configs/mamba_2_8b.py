"""mamba-2.8b — paper §4: 64 layers, d_model=2560."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba-2.8b",
    family="mamba",
    n_layers=64,
    d_model=2560,
    n_heads=1, n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    d_state=16, d_conv=4, expand=2,
))
