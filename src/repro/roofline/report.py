"""Render EXPERIMENTS.md tables from the dry-run / perf JSON artifacts.

  PYTHONPATH=src python -m repro.roofline.report baseline
  PYTHONPATH=src python -m repro.roofline.report opt
  PYTHONPATH=src python -m repro.roofline.report multipod
  PYTHONPATH=src python -m repro.roofline.report kernel
"""
import json
import os
import sys

from repro.roofline.analysis import V5E


def _load(d):
    out = {}
    if not os.path.isdir(d):
        return out
    for fn in sorted(os.listdir(d)):
        if fn.endswith(".json"):
            rec = json.load(open(os.path.join(d, fn)))
            key = (rec["arch"], rec["shape"], rec.get("mesh", ""),
                   rec.get("variant", ""))
            out[key] = rec
    return out


def _fmt_row(rec, show_variant=False):
    rl = rec["roofline"]
    mem = rec["memory"].get("temp_size_in_bytes", 0) / 2 ** 30
    cols = [rec["arch"], rec["shape"]]
    if show_variant:
        cols.append(rec.get("variant", "baseline"))
    cols += [rl["dominant"],
             f"{rl['t_compute_s'] * 1e3:.1f}",
             f"{rl['t_memory_s'] * 1e3:.1f}",
             f"{rl['t_collective_s'] * 1e3:.1f}",
             f"{(rl['useful_flops_ratio'] or 0):.2f}",
             f"{rl['roofline_fraction']:.4f}",
             f"{mem:.1f}", "yes" if mem < 16 else "**NO**"]
    return "| " + " | ".join(str(c) for c in cols) + " |"


def baseline(mesh="pod16x16"):
    recs = _load("experiments/dryrun")
    print("| arch | shape | dominant | comp ms | mem ms | coll ms | "
          "useful | frac | tempGiB | fits |")
    print("|---|---|---|---:|---:|---:|---:|---:|---:|---|")
    for key in sorted(recs):
        rec = recs[key]
        if rec.get("status") != "ok" or key[2] != mesh:
            continue
        print(_fmt_row(rec))


def skips():
    recs = _load("experiments/dryrun")
    for key in sorted(recs):
        rec = recs[key]
        if rec.get("status") == "skip" and key[2] == "pod16x16":
            print(f"| {rec['arch']} | {rec['shape']} | {rec['reason']} |")


def opt():
    from repro.launch.perf import opt_variant
    recs = _load("experiments/dryrun_opt")
    base = _load("experiments/dryrun")
    print("| arch | shape | variant | dominant | comp ms | mem ms | "
          "coll ms | useful | frac | tempGiB | fits | frac vs baseline |")
    print("|---|---|---|---|---:|---:|---:|---:|---:|---:|---|---|")
    seen = set()
    for key in sorted(recs):
        arch, shape, mesh, variant = key
        want = opt_variant(arch, shape)
        if variant != want or (arch, shape) in seen:
            continue
        rec = recs[key]
        if rec.get("status") != "ok":
            continue
        seen.add((arch, shape))
        b = base.get((arch, shape, "pod16x16", ""))
        delta = ""
        if b and b.get("status") == "ok":
            f0 = b["roofline"]["roofline_fraction"]
            f1 = rec["roofline"]["roofline_fraction"]
            delta = f"{f0:.4f} → {f1:.4f} ({f1 / max(f0, 1e-9):.1f}×)"
        row = _fmt_row(rec, show_variant=True)
        print(row[:-1] + f" {delta} |")


def multipod():
    recs = _load("experiments/dryrun")
    print("| arch | shape | mesh | chips | comp ms | mem ms | coll ms | "
          "compile s |")
    print("|---|---|---|---:|---:|---:|---:|---:|")
    for key in sorted(recs):
        rec = recs[key]
        if rec.get("status") != "ok":
            continue
        rl = rec["roofline"]
        print(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
              f"{rec['chips']} | {rl['t_compute_s'] * 1e3:.1f} | "
              f"{rl['t_memory_s'] * 1e3:.1f} | "
              f"{rl['t_collective_s'] * 1e3:.1f} | {rec['compile_s']} |")


def kernel():
    """Pallas kernel-path projection for the mamba cells."""
    from repro.configs.base import get_config
    from repro.roofline.kernel_model import compare_scan_paths
    recs = _load("experiments/dryrun_opt")
    for arch in ("mamba-110m", "mamba-1.4b", "mamba-2.8b"):
        cfg = get_config(arch)
        key = None
        for k in recs:
            if k[0] == arch and k[1] == "train_4k":
                key = k
        if key is None:
            continue
        rec = recs[key]
        if rec.get("status") != "ok":
            continue
        t_mem = rec["roofline"]["t_memory_s"]
        # scan share of measured traffic: everything except dot+collectives
        by = rec.get("traffic_by_op", {})
        tot = sum(by.values()) or 1.0
        scan_share = 1.0 - (by.get("dot", 0.0) / tot)
        proj = compare_scan_paths(cfg, 256, 4096,
                                  measured_xla_scan_share=scan_share,
                                  measured_t_memory_s=t_mem)
        print(f"| {arch} | {t_mem * 1e3:.0f} | {scan_share:.2f} | "
              f"{proj['t_memory_s'] * 1e3:.1f} | "
              f"{proj['projected_t_memory_s'] * 1e3:.0f} | "
              f"{proj['speedup_vs_xla']:.0f}× |")


if __name__ == "__main__":
    {"baseline": baseline, "opt": opt, "multipod": multipod,
     "kernel": kernel, "skips": skips}[sys.argv[1] if len(sys.argv) > 1
                                       else "baseline"]()
