"""Analytic HBM-traffic model for the Pallas selective-scan kernel path.

The XLA-lowered chunked associative scan is traffic-bound: it materializes
the (B, L, D, N) decay/state trajectories (×log-levels, ×backward-saved
residuals). The Pallas kernel (kernels/selective_scan.py) keeps h in VMEM
scratch and recomputes the trajectory per chunk in the backward from L/T
checkpoints, so its HBM traffic is just the kernel I/O:

  fwd : read u, Δ (2·B·L·D·s) + B, C (2·B·L·N·s) + pos (B·L·4)
        write y (B·L·D·s) + checkpoints (B·(L/T)·N·D·4)
  bwd : read everything fwd reads + dy (B·L·D·s) + checkpoints
        write du, dΔ (2·B·L·D·4) + dB, dC partials (2·B·nD·L·N·4)
        + dA, dD partials (small)

(s = activation byte width, 2 for bf16.) This module sizes those terms per
device for a given (cfg, shape, mesh) so EXPERIMENTS.md §Perf can report the
deployed kernel path next to the measured XLA path. The conv1d_pack kernel
is modeled the same way (I/O-only; the halo re-read is L/T-fractional).
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ArchConfig
from repro.roofline.analysis import V5E


def mamba_scan_traffic_per_device(cfg: ArchConfig, batch: int, seq: int,
                                  data_shards: int, model_shards: int,
                                  act_bytes: int = 2, chunk: int = 256,
                                  block_d: int = 128) -> Dict[str, float]:
    """Per-device bytes for ALL mamba blocks of one train step (fwd+bwd)."""
    B = batch / data_shards                 # rows per device
    L = seq
    D = cfg.d_inner / model_shards          # channels per device
    N = cfg.d_state
    nD = max(1, D // block_d)
    s = act_bytes
    fwd = (2 * B * L * D * s          # u, Δ in
           + 2 * B * L * N * s        # B, C in
           + B * L * 4                # positions
           + B * L * D * s            # y out
           + B * (L / chunk) * N * D * 4)   # checkpoints
    bwd = (fwd                        # recompute reads ≈ fwd reads
           + B * L * D * s            # dy in
           + 2 * B * L * D * 4        # du, dΔ out (f32)
           + 2 * B * nD * L * N * 4)  # dB, dC partials
    conv = 3 * (2 * B * L * D * s + B * L * 4)   # fwd + dx + dw passes
    per_layer = fwd + bwd + conv
    total = per_layer * cfg.n_layers
    return {"per_layer_bytes": per_layer, "total_bytes": total,
            "t_memory_s": total / V5E["hbm_bw"]}


def compare_scan_paths(cfg: ArchConfig, batch: int, seq: int,
                       data_shards: int = 16, model_shards: int = 16,
                       measured_xla_scan_share: float = 0.9,
                       measured_t_memory_s: float = None) -> Dict[str, float]:
    """Kernel-path projection: replace ~`measured_xla_scan_share` of the
    measured XLA memory term (the scan's share, from traffic_by_op) with the
    analytic kernel traffic."""
    k = mamba_scan_traffic_per_device(cfg, batch, seq, data_shards,
                                      model_shards)
    out = dict(k)
    if measured_t_memory_s is not None:
        rest = measured_t_memory_s * (1 - measured_xla_scan_share)
        out["projected_t_memory_s"] = rest + k["t_memory_s"]
        out["speedup_vs_xla"] = measured_t_memory_s / \
            out["projected_t_memory_s"]
    return out
