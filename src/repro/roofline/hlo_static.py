"""While-aware static analysis of post-SPMD HLO text.

``compiled.cost_analysis()`` counts a while-loop body ONCE, not × trip-count
(verified empirically: a scan of 8 matmuls reports 1/8 the flops of the
unrolled loop). Every model here scans over layer-units, so naive
cost_analysis under-reports flops, bytes and collectives by ~n_layers. This
module re-derives the three roofline inputs from the HLO text itself:

  * **flops** — 2·prod(result_dims)·prod(contracting_dims) per ``dot``
    (matmuls dominate; elementwise flops are ignored — methodology noted in
    EXPERIMENTS.md §Roofline).
  * **HBM traffic** — per top-level instruction: operand bytes + result
    bytes (operand shapes resolved through a per-computation symbol table —
    HLO text does not inline operand types). Post-fusion this is a faithful
    model: a ``fusion`` op's boundary operands/results are exactly what the
    fused kernel reads/writes from HBM.
  * **collective bytes** — operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute.

Each ``while`` multiplies its body+condition totals by the trip count
recovered from the condition computation (scan lowers to a counted loop:
``compare(iv, constant(N)), direction=LT`` — we take the largest integer
constant in the condition). Nested loops recurse; ``fusion``/``call``
subcomputations contribute their internal dot flops; ``conditional`` takes
the most expensive branch.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_WHILE_ARGS = re.compile(r"condition=%?([\w\.\-]+),?\s*body=%?([\w\.\-]+)")
_CALLS_ARGS = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_BRANCH_ARGS = re.compile(r"branch_computations={([^}]*)}")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims={([0-9,]*)}")

_SKIP_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "after-all", "iota", "while", "call",
                 "conditional", "get-dimension-size", "partition-id",
                 "replica-id", "copy-start", "copy-done", "custom-call",
                 "opt-barrier", "rng-bit-generator", "domain"}
_COLLS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
          "collective-permute")


def _bytes_of(shapes: List[Tuple[str, str]]) -> float:
    total = 0.0
    for dt, dims in shapes:
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _prod(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    traffic: float = 0.0
    coll: float = 0.0
    coll_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)
    traffic_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, o: "Totals", k: float = 1.0):
        self.flops += o.flops * k
        self.traffic += o.traffic * k
        self.coll += o.coll * k
        for op, v in o.coll_by_op.items():
            self.coll_by_op[op] = self.coll_by_op.get(op, 0.0) + v * k
        for op, v in o.traffic_by_op.items():
            self.traffic_by_op[op] = \
                self.traffic_by_op.get(op, 0.0) + v * k

    def bump(self, op: str, b: float):
        self.traffic += b
        self.traffic_by_op[op] = self.traffic_by_op.get(op, 0.0) + b


@dataclasses.dataclass
class _Instr:
    name: str
    opcode: str
    result_shapes: List[Tuple[str, str]]
    line: str


class HloStaticAnalysis:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[_Instr]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[str, Totals] = {}

    # ------------------------------------------------------------------ parse
    def _parse(self, text: str):
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if cur is None:
                if line.endswith("{") and ("->" in line) and "(" in line:
                    m = _COMP_HDR.match(line)
                    if m:
                        cur = m.group(1)
                        self.comps[cur] = []
                        if line.lstrip().startswith("ENTRY"):
                            self.entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            m = _INSTR_RE.match(line)
            if m:
                name, result_ty, opcode = m.groups()
                self.comps[cur].append(
                    _Instr(name, opcode, _SHAPE_RE.findall(result_ty), line))

    # ------------------------------------------------------------- trip count
    def _trip_count(self, cond: str) -> float:
        consts = [int(c) for i in self.comps.get(cond, [])
                  for c in _CONST_INT.findall(i.line)]
        return float(max(consts)) if consts else 1.0

    # ------------------------------------------------------------- dot flops
    @staticmethod
    def _dot_flops(instr: _Instr, sym: Dict[str, List[Tuple[str, str]]]
                   ) -> float:
        if not instr.result_shapes:
            return 0.0
        contract = 1
        cm = _CONTRACT.search(instr.line)
        if cm:
            # first operand name after the opcode paren
            tail = instr.line.split(instr.opcode + "(", 1)[-1]
            names = _OPERAND_RE.findall(tail.split(")", 1)[0])
            if names and names[0] in sym and sym[names[0]]:
                lhs_dims = sym[names[0]][0][1].split(",")
                for ci in cm.group(1).split(","):
                    if ci and int(ci) < len(lhs_dims):
                        contract *= int(lhs_dims[int(ci)])
        return 2.0 * _prod(instr.result_shapes[0][1]) * contract

    def _comp_dot_flops(self, name: str, seen=None) -> float:
        seen = seen or set()
        if name in seen:
            return 0.0
        seen.add(name)
        sym = {i.name: i.result_shapes for i in self.comps.get(name, [])}
        total = 0.0
        for i in self.comps.get(name, []):
            if i.opcode == "dot":
                total += self._dot_flops(i, sym)
            elif i.opcode in ("fusion", "call"):
                cm = _CALLS_ARGS.search(i.line)
                if cm:
                    total += self._comp_dot_flops(cm.group(1), seen)
        return total

    # ------------------------------------------------------- computation cost
    def _comp(self, name: str) -> Totals:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Totals()                    # cycle guard
        sym = {i.name: i.result_shapes for i in self.comps.get(name, [])}
        total = Totals()
        for i in self.comps.get(name, []):
            if i.opcode == "while":
                wm = _WHILE_ARGS.search(i.line)
                if wm:
                    cond, body = wm.groups()
                    trips = self._trip_count(cond)
                    total.add(self._comp(body), trips)
                    total.add(self._comp(cond), trips)
                continue
            if i.opcode == "call":
                cm = _CALLS_ARGS.search(i.line)
                if cm:
                    total.add(self._comp(cm.group(1)))
                continue
            if i.opcode == "conditional":
                bm = _BRANCH_ARGS.search(i.line)
                if bm:
                    branches = [self._comp(b.strip().lstrip("%"))
                                for b in bm.group(1).split(",") if b.strip()]
                    if branches:
                        total.add(max(branches,
                                      key=lambda t: t.flops + t.traffic))
                continue
            # operand bytes via symbol table
            tail = i.line.split(i.opcode + "(", 1)[-1]
            op_names = _OPERAND_RE.findall(tail.split(")", 1)[0])
            op_bytes = sum(_bytes_of(sym.get(n, [])) for n in op_names)
            res_bytes = _bytes_of(i.result_shapes)
            base = i.opcode[:-6] if i.opcode.endswith("-start") else i.opcode
            if base in _COLLS:
                total.coll += op_bytes
                total.coll_by_op[base] = \
                    total.coll_by_op.get(base, 0.0) + op_bytes
                total.bump(base, op_bytes + res_bytes)
                continue
            if i.opcode == "dot":
                total.flops += self._dot_flops(i, sym)
                total.bump("dot", op_bytes + res_bytes)
                continue
            if i.opcode == "fusion":
                cm = _CALLS_ARGS.search(i.line)
                if cm:
                    total.flops += self._comp_dot_flops(cm.group(1))
                total.bump("fusion", op_bytes + res_bytes)
                continue
            if i.opcode in _SKIP_TRAFFIC or i.opcode.endswith("-done"):
                continue
            total.bump(i.opcode, op_bytes + res_bytes)
        self._memo[name] = total
        return total

    def totals(self) -> Totals:
        if self.entry is not None:
            return self._comp(self.entry)
        best = Totals()
        for name in self.comps:
            t = self._comp(name)
            if t.flops + t.traffic > best.flops + best.traffic:
                best = t
        return best


def analyze(hlo_text: str) -> Dict[str, object]:
    t = HloStaticAnalysis(hlo_text).totals()
    top = dict(sorted(t.traffic_by_op.items(), key=lambda kv: -kv[1])[:12])
    return {"flops": t.flops, "traffic_bytes": t.traffic,
            "collective_bytes": t.coll, "collectives_by_op": t.coll_by_op,
            "traffic_by_op": top}
