"""Three-term roofline from a compiled dry-run artifact.

  compute    = HLO_FLOPs      / (chips × 197e12  bf16 FLOP/s)      [v5e]
  memory     = HLO_bytes      / (chips × 819e9   B/s HBM)
  collective = collective_B   / (chips × 50e9    B/s per ICI link)

FLOPs/bytes come from ``compiled.cost_analysis()``. Collective bytes are NOT
in cost_analysis — we parse the post-SPMD HLO text and sum *operand* sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction (operand types are inlined on the defining
line in HLO text, e.g. ``all-reduce(f32[16,1024]{1,0} %add.5)``).

MODEL_FLOPS = 6·N·D (dense; N_active for MoE) ratioed against HLO FLOPs
exposes remat/redundancy overhead.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

V5E = dict(peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

# a defining line: "%name = TYPE[dims] opcode(OPERANDS...)"
_DEF_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9_]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum operand bytes per collective type over the (post-SPMD) HLO.

    Delegates to the while-aware static analyzer (roofline/hlo_static.py):
    HLO text does not inline operand types, and collectives inside scan
    bodies must be multiplied by the loop trip count."""
    from repro.roofline.hlo_static import analyze
    r = analyze(hlo_text)
    out: Dict[str, float] = {op: 0.0 for op in _COLL_OPS}
    out.update(r["collectives_by_op"])
    out["total"] = r["collective_bytes"]
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # whole-program HLO flops
    hbm_bytes: float             # whole-program bytes accessed
    coll_bytes: float            # whole-program collective operand bytes
    chips: int
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    model_flops: Optional[float] = None

    def __post_init__(self):
        self.t_compute = self.flops / (self.chips * V5E["peak_flops"])
        self.t_memory = self.hbm_bytes / (self.chips * V5E["hbm_bw"])
        self.t_collective = self.coll_bytes / (self.chips * V5E["ici_bw"])

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        if not self.model_flops or not self.flops:
            return None
        return self.model_flops / self.flops

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the bound time that is useful compute — how close the
        cell sits to the compute roofline if the dominant term were the
        only cost."""
        if not self.model_flops:
            return 0.0
        t_useful = self.model_flops / (self.chips * V5E["peak_flops"])
        return t_useful / max(self.bound_time, 1e-30)

    def to_dict(self):
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_per_step(cfg, kind: str, batch: int, seq: int) -> float:
    """6·N·D (train) / 2·N·D (fwd-only) with N = active params (MoE-aware)."""
    n_active = active_params(cfg)
    tokens = batch * seq if kind != "decode" else batch * 1
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * tokens


def active_params(cfg) -> float:
    """Parameter count with MoE experts scaled to the top-k active set."""
    from repro.models.lm import build_model
    import jax
    import jax.numpy as jnp
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    total = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        name = ""
        for p in path:
            k = getattr(p, "key", None)
            if k:
                name = k
        n = 1.0
        for s in leaf.shape:
            n *= s
        if name.startswith("experts_") and cfg.n_experts:
            n *= (cfg.top_k / cfg.n_experts)
        total += n
    return total
