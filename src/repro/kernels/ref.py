"""Pure-jnp oracles for the Pallas kernels.

Deliberately written as the most naive sequential formulation (python-level
math, lax.scan over single timesteps, no chunking) so they are independent of
both the Pallas kernels and the optimized XLA path in core/ — every test
triangulates kernel ↔ oracle ↔ core path.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def selective_scan_ref(u: jnp.ndarray, delta: jnp.ndarray, A: jnp.ndarray,
                       B: jnp.ndarray, C: jnp.ndarray,
                       D: Optional[jnp.ndarray] = None,
                       positions: Optional[jnp.ndarray] = None,
                       ) -> jnp.ndarray:
    """u, delta: (Bz, L, Dm) | A: (Dm, N) | B, C: (Bz, L, N) | D: (Dm,).

    h_t = exp(Δ_t A)·h_{t-1} + (Δ_t B_t)·u_t ;  y_t = C_t·h_t + D·u_t
    with Ā→0 where positions == 0 (PackMamba reset). All math f32.
    """
    Bz, L, Dm = u.shape
    N = A.shape[-1]
    f = jnp.float32
    u32, d32 = u.astype(f), delta.astype(f)
    A32, B32, C32 = A.astype(f), B.astype(f), C.astype(f)
    reset = (positions == 0) if positions is not None else \
        jnp.zeros((Bz, L), bool)

    def step(h, xs):
        u_t, d_t, B_t, C_t, r_t = xs
        a_t = jnp.exp(d_t[..., None] * A32)              # (Bz, Dm, N)
        a_t = jnp.where(r_t[:, None, None], 0.0, a_t)
        b_t = (d_t * u_t)[..., None] * B_t[:, None, :]   # (Bz, Dm, N)
        h = a_t * h + b_t
        y_t = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y_t

    h0 = jnp.zeros((Bz, Dm, N), f)
    xs = (jnp.moveaxis(u32, 1, 0), jnp.moveaxis(d32, 1, 0),
          jnp.moveaxis(B32, 1, 0), jnp.moveaxis(C32, 1, 0),
          jnp.moveaxis(reset, 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)
    if D is not None:
        y = y + D.astype(f) * u32
    return y.astype(u.dtype)


def conv1d_pack_ref(x: jnp.ndarray, weight: jnp.ndarray,
                    bias: Optional[jnp.ndarray] = None,
                    positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """x: (Bz, L, Dm) | weight: (W, Dm) | bias: (Dm,) | positions: (Bz, L).

    Causal depthwise conv; tap reaching back k is dropped when
    k > positions[t] (Algorithm 1)."""
    Bz, L, Dm = x.shape
    W = weight.shape[0]
    f = jnp.float32
    x32 = x.astype(f)
    y = jnp.zeros((Bz, L, Dm), f)
    for t in range(L):
        acc = jnp.zeros((Bz, Dm), f)
        for k in range(W):
            src = t - k
            if src < 0:
                continue
            tap = x32[:, src] * weight[W - 1 - k].astype(f)
            if positions is not None:
                ok = positions[:, t] >= k
                tap = jnp.where(ok[:, None], tap, 0.0)
            acc = acc + tap
        y = y.at[:, t].set(acc)
    if bias is not None:
        y = y + bias.astype(f)
    return y.astype(x.dtype)
