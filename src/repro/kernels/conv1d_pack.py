"""Pallas TPU kernel for conv1d_pack (paper Algorithm 1, fwd + dx bwd).

Causal depthwise conv, width W (Mamba uses 4), with PackMamba boundary
truncation: the tap reaching back k positions is dropped when
k > position_indices[t].

Halo handling: Pallas BlockSpecs don't express halos, so the kernel receives
the *previous* L-chunk as a second view of x (index map ``l-1`` clamped at 0)
and stitches the W-1 halo columns. Tokens that would reach before the packed
buffer are always masked by the position test (positions[t] ≤ t for any
packed layout — a sequence's start can never precede buffer start), so the
clamped duplicate block at l = 0 is never actually read through.

The dx backward needs the *next* chunk of dy (reverse-index halo — the
paper's "reverse indices" of §3.3/§3.5); at the last chunk the halo is
explicitly zeroed. dweight/dbias are cheap O(W·D) reductions left to XLA in
ops.py (documented split: the sequence-structured, bandwidth-bound work is
in the kernel; the tiny parameter reductions are not).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

DEF_BLOCK_D = 128
DEF_CHUNK_T = 256
INTERPRET = True


def _fwd_kernel(pos_ref, xc_ref, xp_ref, w_ref, b_ref, y_ref):
    """pos (1,T) | x cur/prev (1,T,bd) | w (W,bd) | b (1,bd) | y (1,T,bd)."""
    T = xc_ref.shape[1]
    W = w_ref.shape[0]
    x_cur = xc_ref[0].astype(jnp.float32)          # (T, bd)
    halo = xp_ref[0, T - (W - 1):, :].astype(jnp.float32)   # (W-1, bd)
    halo = jnp.where(pl.program_id(2) == 0, 0.0, halo)
    full = jnp.concatenate([halo, x_cur], axis=0)  # (T+W-1, bd)
    pos = pos_ref[0]                               # (T,) i32
    acc = jnp.broadcast_to(b_ref[0].astype(jnp.float32), x_cur.shape)
    for k in range(W):                             # static unroll
        seg = jax.lax.slice_in_dim(full, W - 1 - k, W - 1 - k + T, axis=0)
        if k > 0:
            seg = jnp.where((pos >= k)[:, None], seg, 0.0)
        acc = acc + w_ref[W - 1 - k].astype(jnp.float32)[None, :] * seg
    y_ref[0] = acc.astype(y_ref.dtype)


def conv1d_pack_fwd_pallas(x, weight, bias, positions,
                           block_d: int = DEF_BLOCK_D,
                           chunk: int = DEF_CHUNK_T,
                           interpret: Optional[bool] = None):
    """x (B, L, Dm) | weight (W, Dm) | bias (1, Dm) | positions (B, L) i32.
    All pre-padded to multiples of (chunk, block_d). Returns y (B, L, Dm)."""
    Bz, L, Dm = x.shape
    T, bd = chunk, block_d
    grid = (Bz, Dm // bd, L // T)
    W = weight.shape[0]
    prev = lambda l: jnp.maximum(l - 1, 0)
    return pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T), lambda b, d, l: (b, l)),
            pl.BlockSpec((1, T, bd), lambda b, d, l: (b, l, d)),
            pl.BlockSpec((1, T, bd), lambda b, d, l: (b, prev(l), d)),
            pl.BlockSpec((W, bd), lambda b, d, l: (0, d)),
            pl.BlockSpec((1, bd), lambda b, d, l: (0, d)),
        ],
        out_specs=pl.BlockSpec((1, T, bd), lambda b, d, l: (b, l, d)),
        out_shape=jax.ShapeDtypeStruct((Bz, L, Dm), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=INTERPRET if interpret is None else interpret,
    )(positions, x, x, weight, bias)


def _bwd_dx_kernel(posc_ref, posn_ref, dyc_ref, dyn_ref, w_ref, dx_ref):
    """dx[t] = Σ_k w[W-1-k]·dy[t+k]·(pos[t+k] ≥ k) — reverse-index halo."""
    T = dyc_ref.shape[1]
    W = w_ref.shape[0]
    is_last = pl.program_id(2) == pl.num_programs(2) - 1
    dy_halo = jnp.where(is_last, 0.0,
                        dyn_ref[0, :W - 1, :].astype(jnp.float32))
    full_dy = jnp.concatenate(
        [dyc_ref[0].astype(jnp.float32), dy_halo], axis=0)   # (T+W-1, bd)
    pos_halo = jnp.where(is_last, -1, posn_ref[0, :W - 1])
    full_pos = jnp.concatenate([posc_ref[0], pos_halo], axis=0)
    acc = jnp.zeros((T, dyc_ref.shape[2]), jnp.float32)
    for k in range(W):
        seg = jax.lax.slice_in_dim(full_dy, k, k + T, axis=0)
        p = jax.lax.slice_in_dim(full_pos, k, k + T, axis=0)
        seg = jnp.where((p >= k)[:, None], seg, 0.0)
        acc = acc + w_ref[W - 1 - k].astype(jnp.float32)[None, :] * seg
    dx_ref[0] = acc.astype(dx_ref.dtype)


def conv1d_pack_bwd_dx_pallas(dy, weight, positions,
                              block_d: int = DEF_BLOCK_D,
                              chunk: int = DEF_CHUNK_T,
                              interpret: Optional[bool] = None):
    Bz, L, Dm = dy.shape
    T, bd = chunk, block_d
    grid = (Bz, Dm // bd, L // T)
    W = weight.shape[0]
    nxt = lambda l: jnp.minimum(l + 1, (L // T) - 1)
    return pl.pallas_call(
        _bwd_dx_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T), lambda b, d, l: (b, l)),
            pl.BlockSpec((1, T), lambda b, d, l: (b, nxt(l))),
            pl.BlockSpec((1, T, bd), lambda b, d, l: (b, l, d)),
            pl.BlockSpec((1, T, bd), lambda b, d, l: (b, nxt(l), d)),
            pl.BlockSpec((W, bd), lambda b, d, l: (0, d)),
        ],
        out_specs=pl.BlockSpec((1, T, bd), lambda b, d, l: (b, l, d)),
        out_shape=jax.ShapeDtypeStruct((Bz, L, Dm), jnp.float32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=INTERPRET if interpret is None else interpret,
    )(positions, positions, dy, dy, weight)
