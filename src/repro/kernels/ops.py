"""jit'd public wrappers for the Pallas kernels: padding to tile multiples,
layout transposes, custom_vjp wiring, and backend dispatch.

``backend='xla'`` routes to the chunked pure-JAX implementations in
repro.core (the dry-run / roofline path — SPMD-partitionable and visible to
cost_analysis); ``backend='pallas'`` routes to the TPU kernels (validated in
interpret mode on CPU; the path you flip on real v5e).

Structure note: the custom_vjp is defined over *already padded, fully
normalized* operands (no Nones, tile-multiple shapes); the public wrappers
pad/transpose outside it, so cotangent padding/slicing falls out of autodiff
instead of hand-written bookkeeping.
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import conv as core_conv
from repro.core import ssm as core_ssm
from repro.kernels import conv1d_pack as conv_k
from repro.kernels import selective_scan as scan_k

_F0 = jax.dtypes.float0


def _pad_to(x: jnp.ndarray, axis: int, mult: int, value=0):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------
# selective scan
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9, 10))
def _scan_padded(u, delta, At, B, C, Dp, pos, block_d, chunk, schedule,
                 sub_t):
    y, _ = _scan_fwd_rule(u, delta, At, B, C, Dp, pos, block_d, chunk,
                          schedule, sub_t)
    return y


def _scan_fwd_rule(u, delta, At, B, C, Dp, pos, block_d, chunk, schedule,
                   sub_t):
    y, ckpts = scan_k.selective_scan_fwd_pallas(
        u, delta, At, B, C, Dp, pos, block_d=block_d, chunk=chunk,
        schedule=schedule, sub_t=sub_t)
    return y, (u, delta, At, B, C, Dp, pos, ckpts)


def _scan_bwd_rule(block_d, chunk, schedule, sub_t, res, dy):
    u, delta, At, B, C, Dp, pos, ckpts = res
    du, ddelta, dB_p, dC_p, dA_p, dD_p = scan_k.selective_scan_bwd_pallas(
        u, delta, At, B, C, Dp, pos, ckpts, dy, block_d=block_d, chunk=chunk,
        schedule=schedule, sub_t=sub_t)
    return (du.astype(u.dtype), ddelta.astype(delta.dtype),
            dA_p.sum(0).astype(At.dtype), dB_p.sum(1).astype(B.dtype),
            dC_p.sum(1).astype(C.dtype), dD_p.sum(0).astype(Dp.dtype),
            np.zeros(pos.shape, _F0))


_scan_padded.defvjp(_scan_fwd_rule, _scan_bwd_rule)


def _resolve_tune(op, tune, *, B, L, D=0, N=0, H=0, dh=0, dtype, positions,
                  objective="fwd"):
    """Resolve the measured winner for one call site from the tuning cache.

    Unlike the xla-only resolver in core/ssm.py, this level owns the
    backend decision too: a pallas winner flips ``backend`` and carries
    (schedule, pchunk, sub_t); an xla winner carries (method, chunk, intra).
    ``objective`` picks which sweep's winner ("fwd" | "fwdbwd") is served.
    Returns {} on miss (→ the caller's explicit arguments stand).
    """
    from repro.tune import tuned       # lazy: repro.tune imports this module
    return tuned(op, cache=None if tune == "auto" else tune,
                 B=B, L=L, D=D, N=N, H=H, dh=dh, dtype=dtype,
                 reset_density=None if positions is not None else 0.0,
                 objective=objective) or {}


def selective_scan(u, delta, A, B, C, D=None, positions=None, *,
                   backend: str = "xla", block_d: int = scan_k.DEF_BLOCK_D,
                   chunk: int = scan_k.DEF_CHUNK_T, xla_chunk: int = 256,
                   xla_method: str = "blocked", xla_dtype=None,
                   xla_intra=None, schedule: str = "blocked",
                   sub_t=None, tune=None, tune_objective: str = "fwd"):
    """Fused segmented selective scan. See kernels/ref.py for semantics.

    u, delta: (B, L, Dm) | A: (Dm, N) | B, C: (B, L, N) | D: (Dm,) |
    positions: (B, L) i32 (reset where == 0) → y (B, L, Dm).

    ``schedule`` (pallas backend): 'blocked' (SSD-style subtile contraction,
    the default hot path; ``sub_t`` overrides its subtile) | 'step'
    (per-step reference walk). Both wire the same custom_vjp;
    ``xla_method='blocked'`` (+ optional ``xla_intra``) is the XLA twin.

    ``tune``: None (off) | "auto" | cache path | TuneCache — resolve every
    knob above (backend included) from the shape-keyed tuning cache; the
    explicit arguments are the miss fallback (repro/tune).
    """
    if tune is not None:
        kn = _resolve_tune("selective_scan", tune, B=u.shape[0],
                           L=u.shape[1], D=u.shape[2], N=A.shape[-1],
                           dtype=u.dtype, positions=positions,
                           objective=tune_objective)
        if kn:
            backend = kn.get("backend", backend)
            if backend == "pallas":
                schedule = kn.get("schedule", schedule)
                chunk = kn.get("pchunk", chunk)
                sub_t = kn.get("sub_t", sub_t)
            else:
                xla_method = kn.get("method", xla_method)
                xla_chunk = kn.get("chunk", xla_chunk)
                xla_intra = kn.get("intra", xla_intra)
    if backend == "xla":
        return core_ssm.selective_scan(u, delta, A, B, C, D,
                                       positions=positions,
                                       method=xla_method, chunk=xla_chunk,
                                       compute_dtype=xla_dtype,
                                       intra=xla_intra)
    if backend != "pallas":
        raise ValueError(f"unknown backend {backend!r}")
    Bz, L, Dm = u.shape
    bd = min(block_d, max(Dm, 8))
    T = min(chunk, L)
    # channel padding: A=0 ⇒ a=1 but b=0 keeps padded h = 0; y sliced off
    up, dtp = _pad_to(u, 2, bd), _pad_to(delta, 2, bd)
    At = _pad_to(A.T, 1, bd)
    Dp = _pad_to((D if D is not None else jnp.zeros(Dm, u.dtype))[None, :],
                 1, bd)
    # L padding: pos=1 (no reset), delta=0 ⇒ a=1 carry; y sliced off
    up, dtp = _pad_to(up, 1, T), _pad_to(dtp, 1, T)
    Bp, Cp = _pad_to(B, 1, T), _pad_to(C, 1, T)
    pos = positions if positions is not None else \
        jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (Bz, L))
    posp = _pad_to(pos.astype(jnp.int32), 1, T, value=1)
    y = _scan_padded(up, dtp, At, Bp, Cp, Dp, posp, bd, T, schedule, sub_t)
    return y[:, :L, :Dm]


# ---------------------------------------------------------------------------
# head-structured selective scan (Mamba-2 / SSD, scalar per-head decay)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9))
def _scan_heads_padded(u, delta, Ah, B, C, Dp, pos, chunk, schedule, sub_t):
    y, _ = _scan_heads_fwd_rule(u, delta, Ah, B, C, Dp, pos, chunk,
                                schedule, sub_t)
    return y


def _scan_heads_fwd_rule(u, delta, Ah, B, C, Dp, pos, chunk, schedule,
                         sub_t):
    y, ckpts = scan_k.selective_scan_heads_fwd_pallas(
        u, delta, Ah, B, C, Dp, pos, chunk=chunk, schedule=schedule,
        sub_t=sub_t)
    return y, (u, delta, Ah, B, C, Dp, pos, ckpts)


def _scan_heads_bwd_rule(chunk, schedule, sub_t, res, dy):
    # one backward serves both forward schedules: the ckpt contract is
    # identical and the adjoint math is schedule-independent
    u, delta, Ah, B, C, Dp, pos, ckpts = res
    du, ddelta, dB_p, dC_p, dA_p, dD_p = \
        scan_k.selective_scan_heads_bwd_pallas(
            u, delta, Ah, B, C, Dp, pos, ckpts, dy, chunk=chunk,
            sub_t=sub_t)
    return (du.astype(u.dtype), ddelta.astype(delta.dtype),
            dA_p.sum(0).astype(Ah.dtype), dB_p.sum(1).astype(B.dtype),
            dC_p.sum(1).astype(C.dtype), dD_p.sum(0).astype(Dp.dtype),
            np.zeros(pos.shape, _F0))


_scan_heads_padded.defvjp(_scan_heads_fwd_rule, _scan_heads_bwd_rule)


def selective_scan_heads(u, delta, A, B, C, D=None, positions=None, *,
                         backend: str = "xla",
                         chunk: int = scan_k.DEF_CHUNK_T,
                         xla_chunk: int = 64, xla_method: str = "blocked",
                         xla_dtype=None, xla_intra=None,
                         schedule: str = "blocked_heads",
                         sub_t=None, tune=None, tune_objective: str = "fwd"):
    """Fused head-structured segmented selective scan (scalar per-head
    decay — Mamba-2/SSD). See core/ssm.py::selective_scan_heads for
    semantics; this wrapper adds backend dispatch.

    u: (B, L, H, dh) | delta: (B, L, H) | A: (H,) | B, C: (B, L, N) |
    D: (H,) | positions: (B, L) i32 (reset where == 0) → y (B, L, H, dh).

    ``backend='xla'`` routes to the core evaluators (``xla_intra``:
    'quad' | 'dual' in-chunk form); ``backend='pallas'`` transposes to the
    head-major kernel layout ((B, H, L, dh)), pads L to the chunk, and runs
    the ``schedule`` kernels ('blocked_heads' | 'blocked_heads_dual', with
    optional subtile ``sub_t``) through a custom_vjp (the shared
    transpose-contraction backward). ``tune`` resolves every knob —
    backend included — from the shape-keyed tuning cache (repro/tune).
    """
    if tune is not None:
        kn = _resolve_tune("selective_scan_heads", tune, B=u.shape[0],
                           L=u.shape[1], N=B.shape[-1], H=u.shape[2],
                           dh=u.shape[3], dtype=u.dtype, positions=positions,
                           objective=tune_objective)
        if kn:
            backend = kn.get("backend", backend)
            if backend == "pallas":
                schedule = kn.get("schedule", schedule)
                chunk = kn.get("pchunk", chunk)
                sub_t = kn.get("sub_t", sub_t)
            else:
                xla_method = kn.get("method", xla_method)
                xla_chunk = kn.get("chunk", xla_chunk)
                xla_intra = kn.get("intra", xla_intra)
    if backend == "xla":
        return core_ssm.selective_scan_heads(u, delta, A, B, C, D,
                                             positions=positions,
                                             method=xla_method,
                                             chunk=xla_chunk,
                                             compute_dtype=xla_dtype,
                                             intra=xla_intra)
    if backend != "pallas":
        raise ValueError(f"unknown backend {backend!r}")
    if schedule not in ("blocked_heads", "blocked_heads_dual"):
        raise ValueError(f"unknown heads schedule {schedule!r}")
    Bz, L, H, P = u.shape
    T = min(chunk, L)
    uh = jnp.moveaxis(u, 2, 1)                       # (B, H, L, P)
    dth = jnp.moveaxis(delta, 2, 1)                  # (B, H, L)
    Ah = A.astype(jnp.float32)[:, None]              # (H, 1)
    Dp = (D if D is not None else
          jnp.zeros(H, u.dtype)).astype(jnp.float32)[:, None]
    # L padding: pos=1 (no reset), delta=0 ⇒ decay 1 / b-term 0 (carry)
    uh, dth = _pad_to(uh, 2, T), _pad_to(dth, 2, T)
    Bp, Cp = _pad_to(B, 1, T), _pad_to(C, 1, T)
    pos = positions if positions is not None else \
        jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (Bz, L))
    posp = _pad_to(pos.astype(jnp.int32), 1, T, value=1)
    y = _scan_heads_padded(uh, dth, Ah, Bp, Cp, Dp, posp, T, schedule, sub_t)
    return jnp.moveaxis(y, 1, 2)[:, :L]


# ---------------------------------------------------------------------------
# conv1d pack
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _conv_padded(x, weight, bias, pos, block_d, chunk):
    y, _ = _conv_fwd_rule(x, weight, bias, pos, block_d, chunk)
    return y


def _conv_fwd_rule(x, weight, bias, pos, block_d, chunk):
    y = conv_k.conv1d_pack_fwd_pallas(x, weight, bias, pos,
                                      block_d=block_d, chunk=chunk)
    return y, (x, weight, bias, pos)


def _conv_bwd_rule(block_d, chunk, res, dy):
    x, weight, bias, pos = res
    W = weight.shape[0]
    dx = conv_k.conv1d_pack_bwd_dx_pallas(dy, weight, pos,
                                          block_d=block_d, chunk=chunk)
    # dweight / dbias: tiny O(W·D) reductions — XLA einsum (see kernel doc)
    Lp = x.shape[1]
    dy32, x32 = dy.astype(jnp.float32), x.astype(jnp.float32)
    dws = []
    for k in range(W):                    # weight row j = W-1-k ↔ back-off k
        shifted = jnp.pad(x32, ((0, 0), (k, 0), (0, 0)))[:, :Lp]
        masked = jnp.where((pos >= k)[..., None], shifted, 0.0)
        dws.append(jnp.einsum("bld,bld->d", dy32, masked))
    dw = jnp.stack(dws[::-1], axis=0).astype(weight.dtype)
    dbias = dy32.sum((0, 1))[None, :].astype(bias.dtype)
    return (dx.astype(x.dtype), dw, dbias, np.zeros(pos.shape, _F0))


_conv_padded.defvjp(_conv_fwd_rule, _conv_bwd_rule)


def conv1d_pack(x, weight, bias=None, positions=None, *,
                backend: str = "xla", block_d: int = conv_k.DEF_BLOCK_D,
                chunk: int = conv_k.DEF_CHUNK_T):
    """Segmented causal depthwise conv. x (B,L,D) | weight (W,D) | bias (D,)."""
    if backend == "xla":
        return core_conv.conv1d_pack(x, weight, bias, positions)
    if backend != "pallas":
        raise ValueError(f"unknown backend {backend!r}")
    Bz, L, Dm = x.shape
    bd = min(block_d, max(Dm, 8))
    T = min(chunk, L)
    xp = _pad_to(_pad_to(x, 2, bd), 1, T)
    wp = _pad_to(weight, 1, bd)
    bp = _pad_to((bias if bias is not None else
                  jnp.zeros(Dm, x.dtype))[None, :], 1, bd)
    pos = positions if positions is not None else \
        jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (Bz, L))
    posp = _pad_to(pos.astype(jnp.int32), 1, T, value=1)
    y = _conv_padded(xp, wp, bp, posp, bd, T)
    return y[:, :L, :Dm]
