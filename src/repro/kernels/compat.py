"""Version-compat shims for the Pallas TPU API surface we use.

The pinned JAX renamed/renames ``pltpu.TPUCompilerParams`` ↔
``pltpu.CompilerParams`` across releases (0.4.x exposes only
``TPUCompilerParams``; newer releases deprecate it in favour of
``CompilerParams``). Every kernel module resolves the class through this
shim so a version bump is a one-line change here instead of an
``AttributeError`` at kernel-build time in each call site.
"""
from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """Build compiler params (e.g. dimension_semantics=...) portably."""
    return CompilerParams(**kwargs)
