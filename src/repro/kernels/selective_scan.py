"""Pallas TPU kernel for the PackMamba segmented selective scan (fwd + bwd).

TPU adaptation of the paper's modified `ScanOp_pack` (Algorithm 2) + §3.5
co-optimization. The CUDA version modifies a Blelloch tree scan
(scanMul/scanAdd) and stages position_indices HBM→SRAM→registers with
coalesced loads. The TPU-native reformulation:

  * Grid ``(B, D/bd, L/T)`` with semantics ("parallel", "parallel",
    "arbitrary"): batch and channel blocks are embarrassingly parallel; the
    sequence-chunk dimension is sequential and the recurrent state ``h``
    lives in a VMEM scratch that persists across grid steps along it —
    the TPU analogue of the chunk-carried scan.
  * The ``(N=16, bd=128)`` state layout matches the (sublane, lane) native
    tile exactly — one f32 VREG pair per state tile, so the per-step update
    ``h = a⊙h + b`` is pure VPU work with no relayout.
  * ``position_indices`` ride the same BlockSpec pipeline as the
    activations: one (1, T) int32 VMEM block per grid step — a single DMA
    amortized over the whole (T × bd) tile, the VMEM counterpart of the
    paper's coalesced-HBM/SRAM staging. Inside the loop the reset test
    ``pos[t] == 0`` folds into the decay computation (Ā→0), costing zero
    extra memory passes — their "no extra kernel overhead" property.
  * The backward pass (paper §3.4: "modifications only require setting
    Ā_{pos==0}→0" in the reverse scans) is a second kernel that walks the
    L-grid in *reverse*, recomputes h within each chunk from a per-chunk
    checkpoint saved by the forward (flash-style recompute: checkpoints are
    L/T× smaller than the full state trajectory), and carries the adjoint
    dh in VMEM scratch.

VMEM budget per grid step (T=256, bd=128, N=16, f32):
  in/out blocks: u, Δ, y (3 × T·bd·4 = 384 KiB) + B, C (2 × T·N·4 = 32 KiB)
  + A (8 KiB) + pos (1 KiB); scratch h (8 KiB); bwd adds h_buf
  ((T+1)·N·bd·4 ≈ 2.06 MiB) + dh/dA (16 KiB) — comfortably inside the
  ~16 MiB/core VMEM with room for double buffering.

Three schedules share this grid/BlockSpec structure (`schedule=` knob):
  * ``step``    — the kernels above: a per-step fori_loop VPU walk. The
                  reference path; matches the paper's ScanOp_pack closely.
  * ``blocked`` — SSD-style (Gu & Dao duality): each in-chunk subtile of
                  length Tt is evaluated at once as a masked
                  cumulative-decay contraction dec @ b (see
                  core/ssm.py::_blocked_ssm for the math). The sequential
                  chain shrinks T→T/Tt and the (Tt, Tt, N, bd) contraction
                  is dense matmul-shaped work the MXU can absorb, instead
                  of T dependent (N, bd) VPU updates that leave it idle —
                  the Baruah et al. bottleneck this PR attacks. Backward
                  blocks the same way (transpose contraction for the
                  adjoint scan; elementwise grads fully vectorized).
                  Extra VMEM: ~4 MiB (gbuf + subtile dec) at defaults.
  * ``blocked_heads`` — head-structured (Mamba-2/SSD proper): grid
                  ``(B, H, L/T)``, per-head SCALAR decay, state (dh, N) per
                  head in VMEM scratch. The masked cumulative-decay matrix
                  is one (Tt, Tt) f32 tile per head (vs (Tt, Tt, N, bd) for
                  ``blocked``), and the entire subtile evaluates as ONE
                  dense (Tt, Tt) @ (Tt, dh·N) matmul — the widest MXU shape
                  of the three, with ~N·bd/Tt× less decay-matrix traffic.
                  Backward mirrors ``blocked``: transpose contraction
                  (Tt, Tt)ᵀ @ (Tt, dh·N) for the adjoint scan, elementwise
                  grads vectorized over the chunk, per-head dA/dD scalar
                  accumulators. Operands arrive head-major ((B, H, L, dh) /
                  (B, H, L)); ops.py does the layout transpose.
  * ``blocked_heads_dual`` — the attention-like dual form of
                  ``blocked_heads`` (structured-state-space duality): the
                  (Tt, Tt) decay folds into a C·Bᵀ Gram matrix and outputs
                  come straight from (Tt, Tt) @ (Tt, dh) matmuls without
                  forming the in-chunk (Tt, dh, N) states — Tt²·(dh + N)
                  FLOPs vs the quad form's Tt²·dh·N, the measured winner at
                  dh ≫ Tt. Shares the ``blocked_heads`` backward kernel
                  (identical ckpt contract; adjoint math is schedule-free).
                  The quad-vs-dual pick, chunk, and subtile are shape-keyed
                  autotuner decisions (repro/tune), not constants.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

DEF_BLOCK_D = 128
DEF_CHUNK_T = 256
DEF_SUB_T = 16     # blocked schedule: in-chunk subtile for the M contraction
#   (the *default* — every kernel entry takes an explicit ``sub_t`` so the
#   shape-keyed autotuner (repro/tune) can sweep measured subtiles instead)
INTERPRET = True   # flipped by ops.configure_for_tpu() on real hardware


def _pick_subtile(T: int, sub_t=None) -> int:
    """Subtile length for a chunk of length T: the explicit (tuned) request
    when given, else the largest supported default dividing the chunk.

    A requested ``sub_t`` that does not divide T degrades to the largest
    divisor ≤ the request instead of raising: tuned knobs resolve through
    bucketed/nearest-key cache lookups, so a winner measured at one L can
    legally arrive at a chunk it does not divide — the tuner must never
    turn a working call into a trace-time error."""
    if sub_t:
        st = min(int(sub_t), T)
        while T % st:
            st -= 1
        return st
    for tt in (DEF_SUB_T, 8, 4, 2, 1):
        if T % tt == 0:
            return tt
    return 1


# ---------------------------------------------------------------------------
# forward kernel
# ---------------------------------------------------------------------------

def _fwd_kernel(pos_ref, u_ref, dt_ref, At_ref, Bm_ref, Cm_ref, Dp_ref,
                y_ref, ckpt_ref, h_ref):
    """One (b, d-block, l-chunk) grid step.

    pos (1,T) i32 | u, dt (1,T,bd) | At (N,bd) | Bm, Cm (1,T,N) | Dp (1,bd)
    y (1,T,bd) | ckpt (1,1,N,bd) — chunk-entry state | h scratch (N,bd) f32.
    """
    T = u_ref.shape[1]

    @pl.when(pl.program_id(2) == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    ckpt_ref[0, 0] = h_ref[...]          # h at chunk entry (for backward)
    At = At_ref[...].astype(jnp.float32)          # (N, bd)
    Dp = Dp_ref[0, :].astype(jnp.float32)         # (bd,)

    def step(t, _):
        dt = dt_ref[0, t, :].astype(jnp.float32)              # (bd,)
        u_t = u_ref[0, t, :].astype(jnp.float32)
        a = jnp.exp(dt[None, :] * At)                         # (N, bd)
        a = jnp.where(pos_ref[0, t] == 0, 0.0, a)             # PackMamba reset
        b = Bm_ref[0, t, :].astype(jnp.float32)[:, None] * \
            (dt * u_t)[None, :]                               # (N, bd)
        h = a * h_ref[...] + b
        h_ref[...] = h
        y = jnp.sum(h * Cm_ref[0, t, :].astype(jnp.float32)[:, None], axis=0)
        y_ref[0, t, :] = (y + Dp * u_t).astype(y_ref.dtype)
        return ()

    jax.lax.fori_loop(0, T, step, ())


# ---------------------------------------------------------------------------
# forward kernel — blocked (SSD-style) schedule
# ---------------------------------------------------------------------------

def _fwd_kernel_blocked(pos_ref, u_ref, dt_ref, At_ref, Bm_ref, Cm_ref,
                        Dp_ref, y_ref, ckpt_ref, h_ref, *, sub_t):
    """Same block shapes and carry semantics as ``_fwd_kernel``, but instead
    of T dependent per-step VPU updates, each in-chunk subtile of length Tt
    is evaluated at once via the masked cumulative-decay contraction
    (core/ssm.py 'blocked'/'matmul' formulation):

        dec[i,j] = exp(s_i − s_j)·[j ≤ i]·[no reset in (j, i]]
        h_i      = Σ_j dec[i,j]·b_j + 1[no reset ≤ i]·exp(s_i)·h_carry

    The sequential chain shrinks from T steps to T/Tt subtile steps; the
    (Tt, Tt, N, bd) contraction is dense matmul-shaped work for the MXU.
    Peak extra VMEM: Tt²·N·bd f32 (2 MiB at Tt=16, bd=128, N=16).
    """
    T = u_ref.shape[1]
    nsub = T // sub_t

    @pl.when(pl.program_id(2) == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    ckpt_ref[0, 0] = h_ref[...]
    At = At_ref[...].astype(jnp.float32)              # (N, bd)
    Dp = Dp_ref[0, :].astype(jnp.float32)             # (bd,)
    ii = jax.lax.broadcasted_iota(jnp.int32, (sub_t, sub_t), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (sub_t, sub_t), 1)
    causal = ii >= jj

    def sub(si, _):
        t0 = si * sub_t
        dt = dt_ref[0, pl.ds(t0, sub_t), :].astype(jnp.float32)   # (Tt, bd)
        u_t = u_ref[0, pl.ds(t0, sub_t), :].astype(jnp.float32)
        Bv = Bm_ref[0, pl.ds(t0, sub_t), :].astype(jnp.float32)   # (Tt, N)
        Cv = Cm_ref[0, pl.ds(t0, sub_t), :].astype(jnp.float32)
        r = pos_ref[0, pl.ds(t0, sub_t)] == 0                     # (Tt,)
        la = dt[:, None, :] * At[None]                            # (Tt, N, bd)
        s = jnp.cumsum(la, axis=0)
        rid = jnp.cumsum(r.astype(jnp.int32))
        m = (rid[:, None] == rid[None, :]) & causal               # (Tt, Tt)
        mm = m[..., None, None]
        diff = s[:, None] - s[None, :]                     # (Tt, Tt, N, bd)
        dec = jnp.where(mm, jnp.exp(jnp.where(mm, diff, 0.0)), 0.0)
        bt = Bv[..., None] * (dt * u_t)[:, None, :]               # (Tt, N, bd)
        h = jnp.sum(dec * bt[None], axis=1)                       # Σ_j
        cin = jnp.where((rid == 0)[:, None, None], jnp.exp(s), 0.0)
        h = h + cin * h_ref[...][None]
        y = jnp.sum(h * Cv[..., None], axis=1)                    # (Tt, bd)
        y_ref[0, pl.ds(t0, sub_t), :] = (y + Dp[None] * u_t).astype(
            y_ref.dtype)
        h_ref[...] = h[-1]
        return ()

    jax.lax.fori_loop(0, nsub, sub, ())


# ---------------------------------------------------------------------------
# forward kernel — blocked_heads (scalar per-head decay) schedule
# ---------------------------------------------------------------------------

def _fwd_kernel_blocked_heads(pos_ref, u_ref, dt_ref, A_ref, Bm_ref, Cm_ref,
                              Dp_ref, y_ref, ckpt_ref, h_ref, *, sub_t):
    """One (b, head, l-chunk) grid step, scalar per-head decay.

    pos (1,T) i32 | u (1,1,T,P) | dt (1,1,T) | A, Dp (1,1) scalars |
    Bm, Cm (1,T,N) | y (1,1,T,P) | ckpt (1,1,1,P,N) | h scratch (P,N) f32.

    Per subtile of length Tt the masked cumulative-decay matrix is a single
    (Tt, Tt) tile and all states evaluate as ONE matmul:

        dec[i,j] = exp(s_i − s_j)·[j ≤ i]·[no reset in (j, i]]
        h        = dec @ bterm.reshape(Tt, P·N)   + carry·exp(s)
        y_i      = Σ_n h[i,·,n]·C[i,n] + D·u_i
    """
    T = u_ref.shape[2]
    P = u_ref.shape[3]
    N = Bm_ref.shape[2]
    nsub = T // sub_t

    @pl.when(pl.program_id(2) == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    ckpt_ref[0, 0, 0] = h_ref[...]
    A = A_ref[0, 0]                                    # per-head scalar
    Dp = Dp_ref[0, 0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (sub_t, sub_t), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (sub_t, sub_t), 1)
    causal = ii >= jj

    def sub(si, _):
        t0 = si * sub_t
        dt = dt_ref[0, 0, pl.ds(t0, sub_t)].astype(jnp.float32)   # (Tt,)
        u_t = u_ref[0, 0, pl.ds(t0, sub_t), :].astype(jnp.float32)  # (Tt,P)
        Bv = Bm_ref[0, pl.ds(t0, sub_t), :].astype(jnp.float32)   # (Tt, N)
        Cv = Cm_ref[0, pl.ds(t0, sub_t), :].astype(jnp.float32)
        r = pos_ref[0, pl.ds(t0, sub_t)] == 0                     # (Tt,)
        s = jnp.cumsum(dt * A)                                    # (Tt,)
        rid = jnp.cumsum(r.astype(jnp.int32))
        m = (rid[:, None] == rid[None, :]) & causal               # (Tt, Tt)
        diff = s[:, None] - s[None, :]
        dec = jnp.where(m, jnp.exp(jnp.where(m, diff, 0.0)), 0.0)
        bt = Bv[:, None, :] * (dt[:, None] * u_t)[:, :, None]     # (Tt,P,N)
        h = jnp.dot(dec, bt.reshape(sub_t, P * N),
                    preferred_element_type=jnp.float32).reshape(sub_t, P, N)
        cin = jnp.where(rid == 0, jnp.exp(s), 0.0)                # (Tt,)
        h = h + cin[:, None, None] * h_ref[...][None]
        y = jnp.sum(h * Cv[:, None, :], axis=2)                   # (Tt, P)
        y_ref[0, 0, pl.ds(t0, sub_t), :] = (y + Dp * u_t).astype(
            y_ref.dtype)
        h_ref[...] = h[-1]
        return ()

    jax.lax.fori_loop(0, nsub, sub, ())


# ---------------------------------------------------------------------------
# forward kernel — blocked_heads_dual (C·Bᵀ attention-like) schedule
# ---------------------------------------------------------------------------

def _fwd_kernel_blocked_heads_dual(pos_ref, u_ref, dt_ref, A_ref, Bm_ref,
                                   Cm_ref, Dp_ref, y_ref, ckpt_ref, h_ref, *,
                                   sub_t):
    """Dual-form twin of ``_fwd_kernel_blocked_heads`` (same grid, block
    shapes, carry semantics, and ckpt output — so the quad backward kernel
    serves both). Per subtile the masked decay folds into the (Tt, Tt)
    C·Bᵀ Gram matrix and the outputs come straight from two matmuls,
    without forming the (Tt, P, N) in-chunk states:

        G        = dec ⊙ (C @ Bᵀ)                 (Tt, Tt)
        y        = G @ (Δ·u)  +  cin·(C @ h_inᵀ)  (Tt,Tt)@(Tt,P)
        h_new    = dec[last,:] @ bterm  +  cin[last]·h_in

    FLOPs Tt²·(N + P) + Tt·P·N vs the quad form's Tt²·P·N — the measured
    winner when dh ≫ Tt (see repro/tune; core/ssm.py has the XLA math).
    """
    T = u_ref.shape[2]
    P = u_ref.shape[3]
    N = Bm_ref.shape[2]
    nsub = T // sub_t

    @pl.when(pl.program_id(2) == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    ckpt_ref[0, 0, 0] = h_ref[...]
    A = A_ref[0, 0]                                    # per-head scalar
    Dp = Dp_ref[0, 0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (sub_t, sub_t), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (sub_t, sub_t), 1)
    causal = ii >= jj

    def sub(si, _):
        t0 = si * sub_t
        dt = dt_ref[0, 0, pl.ds(t0, sub_t)].astype(jnp.float32)   # (Tt,)
        u_t = u_ref[0, 0, pl.ds(t0, sub_t), :].astype(jnp.float32)  # (Tt,P)
        Bv = Bm_ref[0, pl.ds(t0, sub_t), :].astype(jnp.float32)   # (Tt, N)
        Cv = Cm_ref[0, pl.ds(t0, sub_t), :].astype(jnp.float32)
        r = pos_ref[0, pl.ds(t0, sub_t)] == 0                     # (Tt,)
        s = jnp.cumsum(dt * A)                                    # (Tt,)
        rid = jnp.cumsum(r.astype(jnp.int32))
        m = (rid[:, None] == rid[None, :]) & causal               # (Tt, Tt)
        diff = s[:, None] - s[None, :]
        dec = jnp.where(m, jnp.exp(jnp.where(m, diff, 0.0)), 0.0)
        du = dt[:, None] * u_t                                    # (Tt, P)
        h_in = h_ref[...]                                         # (P, N)
        G = dec * jnp.dot(Cv, Bv.T, preferred_element_type=jnp.float32)
        cin = jnp.where(rid == 0, jnp.exp(s), 0.0)                # (Tt,)
        y = jnp.dot(G, du, preferred_element_type=jnp.float32)
        y = y + cin[:, None] * jnp.dot(Cv, h_in.T,
                                       preferred_element_type=jnp.float32)
        bt = Bv[:, None, :] * du[:, :, None]                      # (Tt,P,N)
        h_new = jnp.dot(dec[-1][None, :], bt.reshape(sub_t, P * N),
                        preferred_element_type=jnp.float32).reshape(P, N)
        h_ref[...] = h_new + cin[-1] * h_in
        y_ref[0, 0, pl.ds(t0, sub_t), :] = (y + Dp * u_t).astype(
            y_ref.dtype)
        return ()

    jax.lax.fori_loop(0, nsub, sub, ())


_HEADS_FWD_KERNELS = {"blocked_heads": _fwd_kernel_blocked_heads,
                      "blocked_heads_dual": _fwd_kernel_blocked_heads_dual}


def selective_scan_heads_fwd_pallas(u, delta, Ah, Bm, Cm, Dp, positions,
                                    chunk: int = DEF_CHUNK_T,
                                    schedule: str = "blocked_heads",
                                    sub_t: Optional[int] = None,
                                    interpret: Optional[bool] = None):
    """Head-major shapes (already padded/transposed by ops.py):
    u (B, H, L, P); delta (B, H, L); Ah, Dp (H, 1); Bm, Cm (B, L, N);
    positions (B, L) i32. ``schedule``: 'blocked_heads' (quad/state form) |
    'blocked_heads_dual' (C·Bᵀ attention-like form; same ckpt contract).
    Returns (y (B, H, L, P), ckpts (B, H, L/T, P, N))."""
    Bz, H, L, P = u.shape
    N = Bm.shape[-1]
    T = chunk
    nL = L // T
    grid = (Bz, H, nL)
    if schedule not in _HEADS_FWD_KERNELS:
        raise ValueError(f"unknown heads schedule {schedule!r}")
    kernel = functools.partial(_HEADS_FWD_KERNELS[schedule],
                               sub_t=_pick_subtile(T, sub_t))
    out_shape = (
        jax.ShapeDtypeStruct((Bz, H, L, P), u.dtype),
        jax.ShapeDtypeStruct((Bz, H, nL, P, N), jnp.float32),
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T), lambda b, h, l: (b, l)),            # pos
            pl.BlockSpec((1, 1, T, P), lambda b, h, l: (b, h, l, 0)),  # u
            pl.BlockSpec((1, 1, T), lambda b, h, l: (b, h, l)),      # dt
            pl.BlockSpec((1, 1), lambda b, h, l: (h, 0)),            # A
            pl.BlockSpec((1, T, N), lambda b, h, l: (b, l, 0)),      # Bm
            pl.BlockSpec((1, T, N), lambda b, h, l: (b, l, 0)),      # Cm
            pl.BlockSpec((1, 1), lambda b, h, l: (h, 0)),            # Dp
        ],
        out_specs=[
            pl.BlockSpec((1, 1, T, P), lambda b, h, l: (b, h, l, 0)),  # y
            pl.BlockSpec((1, 1, 1, P, N),
                         lambda b, h, l: (b, h, l, 0, 0)),             # ckpt
        ],
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=INTERPRET if interpret is None else interpret,
    )(positions, u, delta, Ah, Bm, Cm, Dp)


def selective_scan_fwd_pallas(u, delta, At, Bm, Cm, Dp, positions,
                              block_d: int = DEF_BLOCK_D,
                              chunk: int = DEF_CHUNK_T,
                              schedule: str = "step",
                              sub_t: Optional[int] = None,
                              interpret: Optional[bool] = None):
    """Shapes (already padded by ops.py): u, delta (B, L, Dm); At (N, Dm);
    Bm, Cm (B, L, N); Dp (1, Dm); positions (B, L) i32.
    ``schedule``: 'step' (per-step VPU walk) | 'blocked' (SSD-style subtile
    contraction; ``sub_t`` overrides the default subtile).
    Returns (y (B, L, Dm), ckpts (B, L/T, N, Dm))."""
    Bz, L, Dm = u.shape
    N = At.shape[0]
    T, bd = chunk, block_d
    nL, nD = L // T, Dm // bd
    grid = (Bz, nD, nL)
    if schedule == "blocked":
        kernel = functools.partial(_fwd_kernel_blocked,
                                   sub_t=_pick_subtile(T, sub_t))
    elif schedule == "step":
        kernel = _fwd_kernel
    else:
        raise ValueError(f"unknown schedule {schedule!r}")
    out_shape = (
        jax.ShapeDtypeStruct((Bz, L, Dm), u.dtype),
        jax.ShapeDtypeStruct((Bz, nL, N, Dm), jnp.float32),
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T), lambda b, d, l: (b, l)),          # pos
            pl.BlockSpec((1, T, bd), lambda b, d, l: (b, l, d)),   # u
            pl.BlockSpec((1, T, bd), lambda b, d, l: (b, l, d)),   # dt
            pl.BlockSpec((N, bd), lambda b, d, l: (0, d)),         # At
            pl.BlockSpec((1, T, N), lambda b, d, l: (b, l, 0)),    # Bm
            pl.BlockSpec((1, T, N), lambda b, d, l: (b, l, 0)),    # Cm
            pl.BlockSpec((1, bd), lambda b, d, l: (0, d)),         # Dp
        ],
        out_specs=[
            pl.BlockSpec((1, T, bd), lambda b, d, l: (b, l, d)),       # y
            pl.BlockSpec((1, 1, N, bd), lambda b, d, l: (b, l, 0, d)),  # ckpt
        ],
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((N, bd), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=INTERPRET if interpret is None else interpret,
    )(positions, u, delta, At, Bm, Cm, Dp)


# ---------------------------------------------------------------------------
# backward kernel — reverse L-grid walk, per-chunk recompute
# ---------------------------------------------------------------------------

def _bwd_kernel(pos_ref, u_ref, dt_ref, At_ref, Bm_ref, Cm_ref, Dp_ref,
                ckpt_ref, dy_ref,
                du_ref, ddt_ref, dB_ref, dC_ref, dA_ref, dD_ref,
                hbuf_ref, g_ref, dA_acc, dD_acc):
    """Adjoint of one chunk. Same block shapes as forward plus:
    dy (1,T,bd) | du, ddt (1,T,bd) | dB, dC (1,1,T,N) per-(b,dblk) partials |
    dA (1,N,bd), dD (1,1,bd) per-b partials |
    scratch: hbuf (T+1, N, bd) recomputed states, g (N,bd) adjoint carry,
    dA_acc (N,bd), dD_acc (1,bd).

    Reverse recurrence (paper §3.4 bwd: same Ā→0 rule):
      g_t ≡ dL/dh_t = C_t ⊗ dy_t + a_{t+1} · g_{t+1}
      da_t = g_t ⊙ h_{t-1}  →  dΔ += Σ_n da·a·A ;  dA += Σ_t da·a·Δ
      db_t = g_t            →  dB_t = Σ_d g·Δu ;  du += Δ·Σ_n g·B ; dΔ += u·Σ_n g·B
      dC_t = Σ_d dy_t ⊙ h_t ;  du += D·dy ;  dD += Σ_t dy·u
    """
    T = u_ref.shape[1]
    N = At_ref.shape[0]

    @pl.when(pl.program_id(2) == 0)          # first step of the REVERSE walk
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        dA_acc[...] = jnp.zeros_like(dA_acc)
        dD_acc[...] = jnp.zeros_like(dD_acc)

    At = At_ref[...].astype(jnp.float32)
    Dp = Dp_ref[0, :].astype(jnp.float32)

    # ---- recompute h trajectory within the chunk from the checkpoint ----
    hbuf_ref[0] = ckpt_ref[0, 0]

    def fstep(t, _):
        dt = dt_ref[0, t, :].astype(jnp.float32)
        u_t = u_ref[0, t, :].astype(jnp.float32)
        a = jnp.exp(dt[None, :] * At)
        a = jnp.where(pos_ref[0, t] == 0, 0.0, a)
        b = Bm_ref[0, t, :].astype(jnp.float32)[:, None] * (dt * u_t)[None, :]
        hbuf_ref[t + 1] = a * hbuf_ref[t] + b
        return ()

    jax.lax.fori_loop(0, T, fstep, ())

    # ---- reverse adjoint walk ----
    def rstep(i, _):
        t = T - 1 - i
        dt = dt_ref[0, t, :].astype(jnp.float32)              # (bd,)
        u_t = u_ref[0, t, :].astype(jnp.float32)
        dy = dy_ref[0, t, :].astype(jnp.float32)
        Bv = Bm_ref[0, t, :].astype(jnp.float32)              # (N,)
        Cv = Cm_ref[0, t, :].astype(jnp.float32)
        a = jnp.exp(dt[None, :] * At)
        a = jnp.where(pos_ref[0, t] == 0, 0.0, a)
        h_t = hbuf_ref[t + 1]
        h_prev = hbuf_ref[t]
        g = Cv[:, None] * dy[None, :] + g_ref[...]            # dL/dh_t
        # parameter/input adjoints
        da = g * h_prev
        ddt_a = jnp.sum(da * a * At, axis=0)                  # (bd,)
        gB = jnp.sum(g * Bv[:, None], axis=0)                 # (bd,)
        du = dt * gB + Dp * dy
        ddt_b = u_t * gB
        dB_t = jnp.sum(g * (dt * u_t)[None, :], axis=1)       # (N,)
        dC_t = jnp.sum(h_t * dy[None, :], axis=1)             # (N,)
        du_ref[0, t, :] = du.astype(du_ref.dtype)
        ddt_ref[0, t, :] = (ddt_a + ddt_b).astype(ddt_ref.dtype)
        dB_ref[0, 0, t, :] = dB_t.astype(dB_ref.dtype)
        dC_ref[0, 0, t, :] = dC_t.astype(dC_ref.dtype)
        dA_acc[...] += da * a * dt[None, :]
        dD_acc[0, :] += dy * u_t
        g_ref[...] = a * g                                    # carry to t-1
        return ()

    jax.lax.fori_loop(0, T, rstep, ())

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        dA_ref[0] = dA_acc[...]
        dD_ref[0, 0] = dD_acc[0, :]


# ---------------------------------------------------------------------------
# backward kernel — blocked (SSD-style) schedule
# ---------------------------------------------------------------------------

def _bwd_kernel_blocked(pos_ref, u_ref, dt_ref, At_ref, Bm_ref, Cm_ref,
                        Dp_ref, ckpt_ref, dy_ref,
                        du_ref, ddt_ref, dB_ref, dC_ref, dA_ref, dD_ref,
                        hbuf_ref, gbuf_ref, g_ref, dA_acc, dD_acc, *, sub_t):
    """Adjoint of one chunk under the blocked formulation. Outputs and carry
    semantics identical to ``_bwd_kernel``; the two inner walks are blocked:

      * h recompute: the forward subtile contraction refilled into hbuf.
      * adjoint g: the reverse recurrence g_t = C_t⊗dy_t + a_{t+1}·g_{t+1}
        is itself a segmented scan running backwards, so per subtile
        g_j = Σ_{i≥j} dec[i,j]·(C⊗dy)_i + dec[last,j]·G_in — the transpose
        contraction of the same masked decay matrix, with the VMEM carry
        G = a_first·g_first handed to the previous subtile/chunk.

    The per-position parameter/input adjoints are then pure elementwise
    (T, N, bd) tensor work — no sequential walk at all.
    Extra VMEM vs step bwd: gbuf (T, N, bd) ≈ 2 MiB at T=256, bd=128.
    """
    T = u_ref.shape[1]
    nsub = T // sub_t

    @pl.when(pl.program_id(2) == 0)          # first step of the REVERSE walk
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        dA_acc[...] = jnp.zeros_like(dA_acc)
        dD_acc[...] = jnp.zeros_like(dD_acc)

    At = At_ref[...].astype(jnp.float32)
    Dp = Dp_ref[0, :].astype(jnp.float32)
    ii = jax.lax.broadcasted_iota(jnp.int32, (sub_t, sub_t), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (sub_t, sub_t), 1)
    causal = ii >= jj

    def _tile(si):
        """Masked decay matrix + shared per-subtile tensors."""
        t0 = si * sub_t
        dt = dt_ref[0, pl.ds(t0, sub_t), :].astype(jnp.float32)
        u_t = u_ref[0, pl.ds(t0, sub_t), :].astype(jnp.float32)
        r = pos_ref[0, pl.ds(t0, sub_t)] == 0
        la = dt[:, None, :] * At[None]                  # (Tt, N, bd)
        s = jnp.cumsum(la, axis=0)
        rid = jnp.cumsum(r.astype(jnp.int32))
        m = (rid[:, None] == rid[None, :]) & causal
        mm = m[..., None, None]
        diff = s[:, None] - s[None, :]
        dec = jnp.where(mm, jnp.exp(jnp.where(mm, diff, 0.0)), 0.0)
        return t0, dt, u_t, r, la, s, rid, dec

    # ---- recompute h within the chunk, blocked per subtile ----
    hbuf_ref[0] = ckpt_ref[0, 0]

    def fsub(si, _):
        t0, dt, u_t, r, la, s, rid, dec = _tile(si)
        Bv = Bm_ref[0, pl.ds(t0, sub_t), :].astype(jnp.float32)
        bt = Bv[..., None] * (dt * u_t)[:, None, :]
        h = jnp.sum(dec * bt[None], axis=1)
        cin = jnp.where((rid == 0)[:, None, None], jnp.exp(s), 0.0)
        h = h + cin * hbuf_ref[t0][None]
        hbuf_ref[pl.ds(t0 + 1, sub_t)] = h
        return ()

    jax.lax.fori_loop(0, nsub, fsub, ())

    # ---- reverse adjoint walk, blocked per subtile ----
    def rsub(si, _):
        t0, dt, u_t, r, la, s, rid, dec = _tile(nsub - 1 - si)
        Cv = Cm_ref[0, pl.ds(t0, sub_t), :].astype(jnp.float32)
        dy = dy_ref[0, pl.ds(t0, sub_t), :].astype(jnp.float32)
        c = Cv[..., None] * dy[:, None, :]              # (Tt, N, bd)
        g = jnp.sum(dec * c[:, None], axis=0)           # Σ_{i≥j} decᵀ·c
        g = g + dec[-1] * g_ref[...][None]              # carry through M[last,j]
        gbuf_ref[pl.ds(t0, sub_t)] = g
        a0 = jnp.where(r[0], 0.0, jnp.exp(la[0]))
        g_ref[...] = a0 * g[0]                          # hand to t0 − 1
        return ()

    jax.lax.fori_loop(0, nsub, rsub, ())

    # ---- elementwise adjoints, vectorized over the whole chunk ----
    dt = dt_ref[0].astype(jnp.float32)                  # (T, bd)
    u_t = u_ref[0].astype(jnp.float32)
    dy = dy_ref[0].astype(jnp.float32)
    Bv = Bm_ref[0].astype(jnp.float32)                  # (T, N)
    a = jnp.exp(dt[:, None, :] * At[None])              # (T, N, bd)
    a = jnp.where((pos_ref[0] == 0)[:, None, None], 0.0, a)
    hb = hbuf_ref[...]
    h_prev, h_t = hb[:-1], hb[1:]
    g = gbuf_ref[...]
    da = g * h_prev
    gB = jnp.sum(g * Bv[..., None], axis=1)             # (T, bd)
    du_ref[0] = (dt * gB + Dp[None] * dy).astype(du_ref.dtype)
    ddt_ref[0] = (jnp.sum(da * a * At[None], axis=1) +
                  u_t * gB).astype(ddt_ref.dtype)
    dB_ref[0, 0] = jnp.sum(g * (dt * u_t)[:, None, :],
                           axis=2).astype(dB_ref.dtype)
    dC_ref[0, 0] = jnp.sum(h_t * dy[:, None, :], axis=2).astype(dC_ref.dtype)
    dA_acc[...] += jnp.sum(da * a * dt[:, None, :], axis=0)
    dD_acc[0, :] += jnp.sum(dy * u_t, axis=0)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        dA_ref[0] = dA_acc[...]
        dD_ref[0, 0] = dD_acc[0, :]


# ---------------------------------------------------------------------------
# backward kernel — blocked_heads schedule
# ---------------------------------------------------------------------------

def _bwd_kernel_blocked_heads(pos_ref, u_ref, dt_ref, A_ref, Bm_ref, Cm_ref,
                              Dp_ref, ckpt_ref, dy_ref,
                              du_ref, ddt_ref, dB_ref, dC_ref, dA_ref,
                              dD_ref,
                              hbuf_ref, gbuf_ref, g_ref, dA_acc, dD_acc, *,
                              sub_t):
    """Adjoint of one (b, head, l-chunk), scalar per-head decay. Mirrors
    ``_bwd_kernel_blocked``: h recomputed per subtile from the chunk-entry
    checkpoint via the forward matmul, the adjoint scan

        g_t = C_t ⊗ dy_t + a_{t+1}·g_{t+1}

    evaluated per subtile as the TRANSPOSE contraction decᵀ @ (C⊗dy) (one
    (Tt, Tt) @ (Tt, P·N) matmul) with the VMEM carry G = a_first·g_first,
    then all per-position parameter/input adjoints as elementwise chunk-wide
    tensor work. Per-head dA/dD reduce into (1, 1) scalar accumulators
    flushed on the last reverse grid step.
    """
    T = u_ref.shape[2]
    P = u_ref.shape[3]
    N = Bm_ref.shape[2]
    nsub = T // sub_t

    @pl.when(pl.program_id(2) == 0)          # first step of the REVERSE walk
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        dA_acc[...] = jnp.zeros_like(dA_acc)
        dD_acc[...] = jnp.zeros_like(dD_acc)

    A = A_ref[0, 0]
    Dp = Dp_ref[0, 0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (sub_t, sub_t), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (sub_t, sub_t), 1)
    causal = ii >= jj

    def _tile(si):
        """Masked (Tt, Tt) decay matrix + shared per-subtile tensors."""
        t0 = si * sub_t
        dt = dt_ref[0, 0, pl.ds(t0, sub_t)].astype(jnp.float32)   # (Tt,)
        u_t = u_ref[0, 0, pl.ds(t0, sub_t), :].astype(jnp.float32)
        r = pos_ref[0, pl.ds(t0, sub_t)] == 0
        la = dt * A
        s = jnp.cumsum(la)
        rid = jnp.cumsum(r.astype(jnp.int32))
        m = (rid[:, None] == rid[None, :]) & causal
        diff = s[:, None] - s[None, :]
        dec = jnp.where(m, jnp.exp(jnp.where(m, diff, 0.0)), 0.0)
        return t0, dt, u_t, r, la, s, rid, dec

    # ---- recompute h within the chunk, one matmul per subtile ----
    hbuf_ref[0] = ckpt_ref[0, 0, 0]

    def fsub(si, _):
        t0, dt, u_t, r, la, s, rid, dec = _tile(si)
        Bv = Bm_ref[0, pl.ds(t0, sub_t), :].astype(jnp.float32)
        bt = Bv[:, None, :] * (dt[:, None] * u_t)[:, :, None]     # (Tt,P,N)
        h = jnp.dot(dec, bt.reshape(sub_t, P * N),
                    preferred_element_type=jnp.float32).reshape(sub_t, P, N)
        cin = jnp.where(rid == 0, jnp.exp(s), 0.0)
        h = h + cin[:, None, None] * hbuf_ref[t0][None]
        hbuf_ref[pl.ds(t0 + 1, sub_t)] = h
        return ()

    jax.lax.fori_loop(0, nsub, fsub, ())

    # ---- reverse adjoint walk, transpose contraction per subtile ----
    def rsub(si, _):
        t0, dt, u_t, r, la, s, rid, dec = _tile(nsub - 1 - si)
        Cv = Cm_ref[0, pl.ds(t0, sub_t), :].astype(jnp.float32)
        dy = dy_ref[0, 0, pl.ds(t0, sub_t), :].astype(jnp.float32)
        c = dy[:, :, None] * Cv[:, None, :]                       # (Tt,P,N)
        g = jnp.dot(dec.T, c.reshape(sub_t, P * N),
                    preferred_element_type=jnp.float32).reshape(sub_t, P, N)
        g = g + dec[-1][:, None, None] * g_ref[...][None]   # carry M[last,j]
        gbuf_ref[pl.ds(t0, sub_t)] = g
        a0 = jnp.where(r[0], 0.0, jnp.exp(la[0]))
        g_ref[...] = a0 * g[0]                              # hand to t0 − 1
        return ()

    jax.lax.fori_loop(0, nsub, rsub, ())

    # ---- elementwise adjoints, vectorized over the whole chunk ----
    dt = dt_ref[0, 0].astype(jnp.float32)                   # (T,)
    u_t = u_ref[0, 0].astype(jnp.float32)                   # (T, P)
    dy = dy_ref[0, 0].astype(jnp.float32)
    Bv = Bm_ref[0].astype(jnp.float32)                      # (T, N)
    a = jnp.exp(dt * A)                                     # (T,)
    a = jnp.where(pos_ref[0] == 0, 0.0, a)
    hb = hbuf_ref[...]
    h_prev, h_t = hb[:-1], hb[1:]                           # (T, P, N)
    g = gbuf_ref[...]
    da = jnp.sum(g * h_prev, axis=(1, 2))                   # (T,) scalar/step
    gB = jnp.sum(g * Bv[:, None, :], axis=2)                # (T, P)
    du_ref[0, 0] = (dt[:, None] * gB + Dp * dy).astype(du_ref.dtype)
    ddt_ref[0, 0] = (da * a * A +
                     jnp.sum(u_t * gB, axis=1)).astype(ddt_ref.dtype)
    dB_ref[0, 0] = jnp.sum(g * (dt[:, None] * u_t)[:, :, None],
                           axis=1).astype(dB_ref.dtype)
    dC_ref[0, 0] = jnp.sum(h_t * dy[:, :, None], axis=1).astype(dC_ref.dtype)
    dA_acc[0, 0] += jnp.sum(da * a * dt)
    dD_acc[0, 0] += jnp.sum(dy * u_t)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        dA_ref[0, 0] = dA_acc[0]
        dD_ref[0, 0] = dD_acc[0]


def selective_scan_heads_bwd_pallas(u, delta, Ah, Bm, Cm, Dp, positions,
                                    ckpts, dy,
                                    chunk: int = DEF_CHUNK_T,
                                    sub_t: Optional[int] = None,
                                    interpret: Optional[bool] = None):
    """Head-major shapes as in the forward. Returns (du (B,H,L,P),
    ddelta (B,H,L), dB_partial (B,H,L,N), dC_partial (B,H,L,N),
    dA_partial (B,H,1), dD_partial (B,H,1)).

    Serves BOTH forward schedules: the adjoint math is schedule-independent
    and the dual forward writes the same chunk-entry ckpts."""
    Bz, H, L, P = u.shape
    N = Bm.shape[-1]
    T = chunk
    nL = L // T
    grid = (Bz, H, nL)
    rev = lambda l: nL - 1 - l                 # walk the L dimension backwards
    f32 = jnp.float32
    kernel = functools.partial(_bwd_kernel_blocked_heads,
                               sub_t=_pick_subtile(T, sub_t))
    scratch = [
        pltpu.VMEM((T + 1, P, N), f32),        # recomputed h trajectory
        pltpu.VMEM((T, P, N), f32),            # adjoint trajectory g
        pltpu.VMEM((P, N), f32),               # adjoint carry G
        pltpu.VMEM((1, 1), f32),               # per-head dA accumulator
        pltpu.VMEM((1, 1), f32),               # per-head dD accumulator
    ]
    out_shape = (
        jax.ShapeDtypeStruct((Bz, H, L, P), f32),     # du
        jax.ShapeDtypeStruct((Bz, H, L), f32),        # ddelta
        jax.ShapeDtypeStruct((Bz, H, L, N), f32),     # dB partials
        jax.ShapeDtypeStruct((Bz, H, L, N), f32),     # dC partials
        jax.ShapeDtypeStruct((Bz, H, 1), f32),        # dA partials
        jax.ShapeDtypeStruct((Bz, H, 1), f32),        # dD partials
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T), lambda b, h, l: (b, rev(l))),       # pos
            pl.BlockSpec((1, 1, T, P), lambda b, h, l: (b, h, rev(l), 0)),
            pl.BlockSpec((1, 1, T), lambda b, h, l: (b, h, rev(l))),  # dt
            pl.BlockSpec((1, 1), lambda b, h, l: (h, 0)),            # A
            pl.BlockSpec((1, T, N), lambda b, h, l: (b, rev(l), 0)),  # Bm
            pl.BlockSpec((1, T, N), lambda b, h, l: (b, rev(l), 0)),  # Cm
            pl.BlockSpec((1, 1), lambda b, h, l: (h, 0)),            # Dp
            pl.BlockSpec((1, 1, 1, P, N),
                         lambda b, h, l: (b, h, rev(l), 0, 0)),      # ckpt
            pl.BlockSpec((1, 1, T, P), lambda b, h, l: (b, h, rev(l), 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, T, P), lambda b, h, l: (b, h, rev(l), 0)),
            pl.BlockSpec((1, 1, T), lambda b, h, l: (b, h, rev(l))),
            pl.BlockSpec((1, 1, T, N), lambda b, h, l: (b, h, rev(l), 0)),
            pl.BlockSpec((1, 1, T, N), lambda b, h, l: (b, h, rev(l), 0)),
            pl.BlockSpec((1, 1, 1), lambda b, h, l: (b, h, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, h, l: (b, h, 0)),
        ],
        out_shape=out_shape,
        scratch_shapes=scratch,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=INTERPRET if interpret is None else interpret,
    )(positions, u, delta, Ah, Bm, Cm, Dp, ckpts, dy)


def selective_scan_bwd_pallas(u, delta, At, Bm, Cm, Dp, positions, ckpts, dy,
                              block_d: int = DEF_BLOCK_D,
                              chunk: int = DEF_CHUNK_T,
                              schedule: str = "step",
                              sub_t: Optional[int] = None,
                              interpret: Optional[bool] = None):
    """Returns (du, ddelta, dB_partial (B,nD,L,N), dC_partial (B,nD,L,N),
    dA_partial (B,N,Dm), dD_partial (B,1,Dm))."""
    Bz, L, Dm = u.shape
    N = At.shape[0]
    T, bd = chunk, block_d
    nL, nD = L // T, Dm // bd
    grid = (Bz, nD, nL)
    rev = lambda l: nL - 1 - l                 # walk the L dimension backwards
    f32 = jnp.float32
    if schedule == "blocked":
        kernel = functools.partial(_bwd_kernel_blocked,
                                   sub_t=_pick_subtile(T, sub_t))
        scratch = [
            pltpu.VMEM((T + 1, N, bd), f32),   # recomputed h trajectory
            pltpu.VMEM((T, N, bd), f32),       # adjoint trajectory g
            pltpu.VMEM((N, bd), f32),          # adjoint carry G
            pltpu.VMEM((N, bd), f32),          # dA accumulator
            pltpu.VMEM((1, bd), f32),          # dD accumulator
        ]
    elif schedule == "step":
        kernel = _bwd_kernel
        scratch = [
            pltpu.VMEM((T + 1, N, bd), f32),   # recomputed h trajectory
            pltpu.VMEM((N, bd), f32),          # adjoint carry g
            pltpu.VMEM((N, bd), f32),          # dA accumulator
            pltpu.VMEM((1, bd), f32),          # dD accumulator
        ]
    else:
        raise ValueError(f"unknown schedule {schedule!r}")
    out_shape = (
        jax.ShapeDtypeStruct((Bz, L, Dm), f32),       # du
        jax.ShapeDtypeStruct((Bz, L, Dm), f32),       # ddelta
        jax.ShapeDtypeStruct((Bz, nD, L, N), f32),    # dB partials
        jax.ShapeDtypeStruct((Bz, nD, L, N), f32),    # dC partials
        jax.ShapeDtypeStruct((Bz, N, Dm), f32),       # dA partials
        jax.ShapeDtypeStruct((Bz, 1, Dm), f32),       # dD partials
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T), lambda b, d, l: (b, rev(l))),
            pl.BlockSpec((1, T, bd), lambda b, d, l: (b, rev(l), d)),   # u
            pl.BlockSpec((1, T, bd), lambda b, d, l: (b, rev(l), d)),   # dt
            pl.BlockSpec((N, bd), lambda b, d, l: (0, d)),              # At
            pl.BlockSpec((1, T, N), lambda b, d, l: (b, rev(l), 0)),    # Bm
            pl.BlockSpec((1, T, N), lambda b, d, l: (b, rev(l), 0)),    # Cm
            pl.BlockSpec((1, bd), lambda b, d, l: (0, d)),              # Dp
            pl.BlockSpec((1, 1, N, bd), lambda b, d, l: (b, rev(l), 0, d)),
            pl.BlockSpec((1, T, bd), lambda b, d, l: (b, rev(l), d)),   # dy
        ],
        out_specs=[
            pl.BlockSpec((1, T, bd), lambda b, d, l: (b, rev(l), d)),
            pl.BlockSpec((1, T, bd), lambda b, d, l: (b, rev(l), d)),
            pl.BlockSpec((1, 1, T, N), lambda b, d, l: (b, d, rev(l), 0)),
            pl.BlockSpec((1, 1, T, N), lambda b, d, l: (b, d, rev(l), 0)),
            pl.BlockSpec((1, N, bd), lambda b, d, l: (b, 0, d)),
            pl.BlockSpec((1, 1, bd), lambda b, d, l: (b, 0, d)),
        ],
        out_shape=out_shape,
        scratch_shapes=scratch,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=INTERPRET if interpret is None else interpret,
    )(positions, u, delta, At, Bm, Cm, Dp, ckpts, dy)
