"""Pallas TPU kernel for the PackMamba segmented selective scan (fwd + bwd).

TPU adaptation of the paper's modified `ScanOp_pack` (Algorithm 2) + §3.5
co-optimization. The CUDA version modifies a Blelloch tree scan
(scanMul/scanAdd) and stages position_indices HBM→SRAM→registers with
coalesced loads. The TPU-native reformulation:

  * Grid ``(B, D/bd, L/T)`` with semantics ("parallel", "parallel",
    "arbitrary"): batch and channel blocks are embarrassingly parallel; the
    sequence-chunk dimension is sequential and the recurrent state ``h``
    lives in a VMEM scratch that persists across grid steps along it —
    the TPU analogue of the chunk-carried scan.
  * The ``(N=16, bd=128)`` state layout matches the (sublane, lane) native
    tile exactly — one f32 VREG pair per state tile, so the per-step update
    ``h = a⊙h + b`` is pure VPU work with no relayout.
  * ``position_indices`` ride the same BlockSpec pipeline as the
    activations: one (1, T) int32 VMEM block per grid step — a single DMA
    amortized over the whole (T × bd) tile, the VMEM counterpart of the
    paper's coalesced-HBM/SRAM staging. Inside the loop the reset test
    ``pos[t] == 0`` folds into the decay computation (Ā→0), costing zero
    extra memory passes — their "no extra kernel overhead" property.
  * The backward pass (paper §3.4: "modifications only require setting
    Ā_{pos==0}→0" in the reverse scans) is a second kernel that walks the
    L-grid in *reverse*, recomputes h within each chunk from a per-chunk
    checkpoint saved by the forward (flash-style recompute: checkpoints are
    L/T× smaller than the full state trajectory), and carries the adjoint
    dh in VMEM scratch.

VMEM budget per grid step (T=256, bd=128, N=16, f32):
  in/out blocks: u, Δ, y (3 × T·bd·4 = 384 KiB) + B, C (2 × T·N·4 = 32 KiB)
  + A (8 KiB) + pos (1 KiB); scratch h (8 KiB); bwd adds h_buf
  ((T+1)·N·bd·4 ≈ 2.06 MiB) + dh/dA (16 KiB) — comfortably inside the
  ~16 MiB/core VMEM with room for double buffering.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

DEF_BLOCK_D = 128
DEF_CHUNK_T = 256
INTERPRET = True   # flipped by ops.configure_for_tpu() on real hardware


# ---------------------------------------------------------------------------
# forward kernel
# ---------------------------------------------------------------------------

def _fwd_kernel(pos_ref, u_ref, dt_ref, At_ref, Bm_ref, Cm_ref, Dp_ref,
                y_ref, ckpt_ref, h_ref):
    """One (b, d-block, l-chunk) grid step.

    pos (1,T) i32 | u, dt (1,T,bd) | At (N,bd) | Bm, Cm (1,T,N) | Dp (1,bd)
    y (1,T,bd) | ckpt (1,1,N,bd) — chunk-entry state | h scratch (N,bd) f32.
    """
    T = u_ref.shape[1]

    @pl.when(pl.program_id(2) == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    ckpt_ref[0, 0] = h_ref[...]          # h at chunk entry (for backward)
    At = At_ref[...].astype(jnp.float32)          # (N, bd)
    Dp = Dp_ref[0, :].astype(jnp.float32)         # (bd,)

    def step(t, _):
        dt = dt_ref[0, t, :].astype(jnp.float32)              # (bd,)
        u_t = u_ref[0, t, :].astype(jnp.float32)
        a = jnp.exp(dt[None, :] * At)                         # (N, bd)
        a = jnp.where(pos_ref[0, t] == 0, 0.0, a)             # PackMamba reset
        b = Bm_ref[0, t, :].astype(jnp.float32)[:, None] * \
            (dt * u_t)[None, :]                               # (N, bd)
        h = a * h_ref[...] + b
        h_ref[...] = h
        y = jnp.sum(h * Cm_ref[0, t, :].astype(jnp.float32)[:, None], axis=0)
        y_ref[0, t, :] = (y + Dp * u_t).astype(y_ref.dtype)
        return ()

    jax.lax.fori_loop(0, T, step, ())


def selective_scan_fwd_pallas(u, delta, At, Bm, Cm, Dp, positions,
                              block_d: int = DEF_BLOCK_D,
                              chunk: int = DEF_CHUNK_T,
                              interpret: Optional[bool] = None):
    """Shapes (already padded by ops.py): u, delta (B, L, Dm); At (N, Dm);
    Bm, Cm (B, L, N); Dp (1, Dm); positions (B, L) i32.
    Returns (y (B, L, Dm), ckpts (B, L/T, N, Dm))."""
    Bz, L, Dm = u.shape
    N = At.shape[0]
    T, bd = chunk, block_d
    nL, nD = L // T, Dm // bd
    grid = (Bz, nD, nL)
    out_shape = (
        jax.ShapeDtypeStruct((Bz, L, Dm), u.dtype),
        jax.ShapeDtypeStruct((Bz, nL, N, Dm), jnp.float32),
    )
    return pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T), lambda b, d, l: (b, l)),          # pos
            pl.BlockSpec((1, T, bd), lambda b, d, l: (b, l, d)),   # u
            pl.BlockSpec((1, T, bd), lambda b, d, l: (b, l, d)),   # dt
            pl.BlockSpec((N, bd), lambda b, d, l: (0, d)),         # At
            pl.BlockSpec((1, T, N), lambda b, d, l: (b, l, 0)),    # Bm
            pl.BlockSpec((1, T, N), lambda b, d, l: (b, l, 0)),    # Cm
            pl.BlockSpec((1, bd), lambda b, d, l: (0, d)),         # Dp
        ],
        out_specs=[
            pl.BlockSpec((1, T, bd), lambda b, d, l: (b, l, d)),       # y
            pl.BlockSpec((1, 1, N, bd), lambda b, d, l: (b, l, 0, d)),  # ckpt
        ],
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((N, bd), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=INTERPRET if interpret is None else interpret,
    )(positions, u, delta, At, Bm, Cm, Dp)


# ---------------------------------------------------------------------------
# backward kernel — reverse L-grid walk, per-chunk recompute
# ---------------------------------------------------------------------------

def _bwd_kernel(pos_ref, u_ref, dt_ref, At_ref, Bm_ref, Cm_ref, Dp_ref,
                ckpt_ref, dy_ref,
                du_ref, ddt_ref, dB_ref, dC_ref, dA_ref, dD_ref,
                hbuf_ref, g_ref, dA_acc, dD_acc):
    """Adjoint of one chunk. Same block shapes as forward plus:
    dy (1,T,bd) | du, ddt (1,T,bd) | dB, dC (1,1,T,N) per-(b,dblk) partials |
    dA (1,N,bd), dD (1,1,bd) per-b partials |
    scratch: hbuf (T+1, N, bd) recomputed states, g (N,bd) adjoint carry,
    dA_acc (N,bd), dD_acc (1,bd).

    Reverse recurrence (paper §3.4 bwd: same Ā→0 rule):
      g_t ≡ dL/dh_t = C_t ⊗ dy_t + a_{t+1} · g_{t+1}
      da_t = g_t ⊙ h_{t-1}  →  dΔ += Σ_n da·a·A ;  dA += Σ_t da·a·Δ
      db_t = g_t            →  dB_t = Σ_d g·Δu ;  du += Δ·Σ_n g·B ; dΔ += u·Σ_n g·B
      dC_t = Σ_d dy_t ⊙ h_t ;  du += D·dy ;  dD += Σ_t dy·u
    """
    T = u_ref.shape[1]
    N = At_ref.shape[0]

    @pl.when(pl.program_id(2) == 0)          # first step of the REVERSE walk
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        dA_acc[...] = jnp.zeros_like(dA_acc)
        dD_acc[...] = jnp.zeros_like(dD_acc)

    At = At_ref[...].astype(jnp.float32)
    Dp = Dp_ref[0, :].astype(jnp.float32)

    # ---- recompute h trajectory within the chunk from the checkpoint ----
    hbuf_ref[0] = ckpt_ref[0, 0]

    def fstep(t, _):
        dt = dt_ref[0, t, :].astype(jnp.float32)
        u_t = u_ref[0, t, :].astype(jnp.float32)
        a = jnp.exp(dt[None, :] * At)
        a = jnp.where(pos_ref[0, t] == 0, 0.0, a)
        b = Bm_ref[0, t, :].astype(jnp.float32)[:, None] * (dt * u_t)[None, :]
        hbuf_ref[t + 1] = a * hbuf_ref[t] + b
        return ()

    jax.lax.fori_loop(0, T, fstep, ())

    # ---- reverse adjoint walk ----
    def rstep(i, _):
        t = T - 1 - i
        dt = dt_ref[0, t, :].astype(jnp.float32)              # (bd,)
        u_t = u_ref[0, t, :].astype(jnp.float32)
        dy = dy_ref[0, t, :].astype(jnp.float32)
        Bv = Bm_ref[0, t, :].astype(jnp.float32)              # (N,)
        Cv = Cm_ref[0, t, :].astype(jnp.float32)
        a = jnp.exp(dt[None, :] * At)
        a = jnp.where(pos_ref[0, t] == 0, 0.0, a)
        h_t = hbuf_ref[t + 1]
        h_prev = hbuf_ref[t]
        g = Cv[:, None] * dy[None, :] + g_ref[...]            # dL/dh_t
        # parameter/input adjoints
        da = g * h_prev
        ddt_a = jnp.sum(da * a * At, axis=0)                  # (bd,)
        gB = jnp.sum(g * Bv[:, None], axis=0)                 # (bd,)
        du = dt * gB + Dp * dy
        ddt_b = u_t * gB
        dB_t = jnp.sum(g * (dt * u_t)[None, :], axis=1)       # (N,)
        dC_t = jnp.sum(h_t * dy[None, :], axis=1)             # (N,)
        du_ref[0, t, :] = du.astype(du_ref.dtype)
        ddt_ref[0, t, :] = (ddt_a + ddt_b).astype(ddt_ref.dtype)
        dB_ref[0, 0, t, :] = dB_t.astype(dB_ref.dtype)
        dC_ref[0, 0, t, :] = dC_t.astype(dC_ref.dtype)
        dA_acc[...] += da * a * dt[None, :]
        dD_acc[0, :] += dy * u_t
        g_ref[...] = a * g                                    # carry to t-1
        return ()

    jax.lax.fori_loop(0, T, rstep, ())

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        dA_ref[0] = dA_acc[...]
        dD_ref[0, 0] = dD_acc[0, :]


def selective_scan_bwd_pallas(u, delta, At, Bm, Cm, Dp, positions, ckpts, dy,
                              block_d: int = DEF_BLOCK_D,
                              chunk: int = DEF_CHUNK_T,
                              interpret: Optional[bool] = None):
    """Returns (du, ddelta, dB_partial (B,nD,L,N), dC_partial (B,nD,L,N),
    dA_partial (B,N,Dm), dD_partial (B,1,Dm))."""
    Bz, L, Dm = u.shape
    N = At.shape[0]
    T, bd = chunk, block_d
    nL, nD = L // T, Dm // bd
    grid = (Bz, nD, nL)
    rev = lambda l: nL - 1 - l                 # walk the L dimension backwards
    f32 = jnp.float32
    out_shape = (
        jax.ShapeDtypeStruct((Bz, L, Dm), f32),       # du
        jax.ShapeDtypeStruct((Bz, L, Dm), f32),       # ddelta
        jax.ShapeDtypeStruct((Bz, nD, L, N), f32),    # dB partials
        jax.ShapeDtypeStruct((Bz, nD, L, N), f32),    # dC partials
        jax.ShapeDtypeStruct((Bz, N, Dm), f32),       # dA partials
        jax.ShapeDtypeStruct((Bz, 1, Dm), f32),       # dD partials
    )
    return pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T), lambda b, d, l: (b, rev(l))),
            pl.BlockSpec((1, T, bd), lambda b, d, l: (b, rev(l), d)),   # u
            pl.BlockSpec((1, T, bd), lambda b, d, l: (b, rev(l), d)),   # dt
            pl.BlockSpec((N, bd), lambda b, d, l: (0, d)),              # At
            pl.BlockSpec((1, T, N), lambda b, d, l: (b, rev(l), 0)),    # Bm
            pl.BlockSpec((1, T, N), lambda b, d, l: (b, rev(l), 0)),    # Cm
            pl.BlockSpec((1, bd), lambda b, d, l: (0, d)),              # Dp
            pl.BlockSpec((1, 1, N, bd), lambda b, d, l: (b, rev(l), 0, d)),
            pl.BlockSpec((1, T, bd), lambda b, d, l: (b, rev(l), d)),   # dy
        ],
        out_specs=[
            pl.BlockSpec((1, T, bd), lambda b, d, l: (b, rev(l), d)),
            pl.BlockSpec((1, T, bd), lambda b, d, l: (b, rev(l), d)),
            pl.BlockSpec((1, 1, T, N), lambda b, d, l: (b, d, rev(l), 0)),
            pl.BlockSpec((1, 1, T, N), lambda b, d, l: (b, d, rev(l), 0)),
            pl.BlockSpec((1, N, bd), lambda b, d, l: (b, 0, d)),
            pl.BlockSpec((1, 1, bd), lambda b, d, l: (b, 0, d)),
        ],
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((T + 1, N, bd), f32),   # recomputed h trajectory
            pltpu.VMEM((N, bd), f32),          # adjoint carry g
            pltpu.VMEM((N, bd), f32),          # dA accumulator
            pltpu.VMEM((1, bd), f32),          # dD accumulator
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=INTERPRET if interpret is None else interpret,
    )(positions, u, delta, At, Bm, Cm, Dp, ckpts, dy)
