"""Production training launcher: sharded train step on the production mesh
(or whatever devices exist), checkpoint/resume, SIGTERM-safe.

On a real TPU pod slice this is the entry each host runs (jax.distributed
initializes from the TPU environment; the mesh axes map onto the physical
topology). On CPU it runs the same code path on a local mesh.

  PYTHONPATH=src python -m repro.launch.train --arch mamba-110m \
      --rows 8 --seq-len 4096 --steps 100 --ckpt-dir /tmp/ckpt
  # dry-run the full production mesh instead of executing:
  PYTHONPATH=src python -m repro.launch.train --arch mamba-2.8b --dry-run

Recommended real-TPU XLA flags (latency-hiding overlap of the FSDP
all-gathers / grad reduce-scatters with compute; bf16 collective payload):
  --xla_tpu_enable_latency_hiding_scheduler=true
  --xla_tpu_megacore_fusion_allow_ags=true
  --xla_enable_async_collective_permute=true
  --xla_tpu_enable_async_collective_fusion_fuse_all_gather=true
"""
import argparse
import dataclasses
import os

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import get_config
from repro.data.dataset import SyntheticCorpus, CorpusConfig
from repro.data.packing_loader import PackingLoader, LoaderConfig
from repro.distributed import sharding as shd
from repro.models.lm import build_model
from repro.obs import Obs, profiler_session
from repro.optim.adamw import AdamW, AdamWConfig, cosine_schedule
from repro.train.trainer import Trainer, TrainerConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba-110m")
    ap.add_argument("--tiny", action="store_true",
                    help="shrink the model for a CPU demo / smoke run")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--rows", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=4096)
    ap.add_argument("--mode", default="pack",
                    choices=["pack", "pad", "single"])
    ap.add_argument("--policy", default="sequential",
                    choices=["sequential", "sorted_greedy", "first_fit",
                             "first_fit_decreasing"])
    ap.add_argument("--dtype", default=None,
                    help="activation/compute dtype override (e.g. bfloat16 "
                         "for the mixed-precision lane; scan carries and "
                         "the loss reduction stay f32 regardless)")
    ap.add_argument("--param-dtype", default=None,
                    help="parameter storage dtype (bfloat16 keeps f32 "
                         "master weights in the optimizer)")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="host-side batches packed ahead of the device "
                         "step (0 = synchronous loader)")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--model-axis", type=int, default=1,
                    help="TP size on the local mesh")
    ap.add_argument("--scan-tune", default="off",
                    help="off | auto | <cache path>: shape-keyed scan "
                         "autotuning (repro/tune); the cache is warmed for "
                         "the training shape before the first step")
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile on the 16x16 production mesh")
    ap.add_argument("--obs-trace", default=None, metavar="PATH",
                    help="record per-step train spans (data wait / fused "
                         "step / compile marks) and export a Chrome "
                         "trace-event JSON here")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="also capture an XLA profile (jax.profiler, "
                         "TensorBoard format) into this directory")
    args = ap.parse_args()

    if args.dry_run:
        from repro.launch.dryrun import run_cell     # sets 512 devices? no —
        # dryrun sets XLA_FLAGS at import; for a clean dry-run use the
        # dedicated module entry instead:
        raise SystemExit(
            "use: python -m repro.launch.dryrun --arch "
            f"{args.arch} --shape train_4k --mesh both")

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = dataclasses.replace(cfg, d_model=128, n_layers=4, vocab=512,
                                  dtype="float32", scan_chunk=64)
    if args.dtype or args.param_dtype:
        cfg = dataclasses.replace(
            cfg, dtype=args.dtype or cfg.dtype,
            param_dtype=args.param_dtype or cfg.param_dtype)
    if args.scan_tune != "off":
        # measure-or-load the scan schedule winners for THIS run's shape
        # bucket before any step compiles — the model then resolves its
        # scan knobs from the cache at trace time (configs/base.py).
        # objective="fwdbwd": this is a training launcher, so the sweep
        # times forward+backward and the step resolves those winners.
        cfg = dataclasses.replace(cfg, scan_tune=args.scan_tune,
                                  tune_objective="fwdbwd")
        from repro.tune import warm_for_config
        warm_for_config(cfg, [(args.rows, args.seq_len)],
                        objective="fwdbwd")
    model = build_model(cfg)
    obs = Obs.on() if args.obs_trace else Obs.off()
    corpus = SyntheticCorpus(CorpusConfig(vocab=cfg.vocab, seed=0))
    loader = PackingLoader(corpus, LoaderConfig(
        rows=args.rows, seq_len=args.seq_len, mode=args.mode,
        policy=args.policy))
    if args.prefetch > 0:
        from repro.data.prefetch import PrefetchLoader
        loader = PrefetchLoader(loader, depth=args.prefetch, obs=obs)
    opt = AdamW(cosine_schedule(args.lr, warmup=max(1, args.steps // 20),
                                total=args.steps),
                AdamWConfig(weight_decay=0.1, clip_norm=1.0))

    n_dev = len(jax.devices())
    step_fn = make_train_step(model, opt, accum=args.accum)
    if n_dev > 1:
        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh(model_axis=args.model_axis)
        pspec = shd.param_pspecs(
            jax.eval_shape(model.init, jax.random.PRNGKey(0)), mesh)
        ns = lambda t: jax.tree.map(
            lambda s: NamedSharding(mesh, s), t,
            is_leaf=lambda x: isinstance(x, P))
        state_spec = ns({"params": pspec})
        print(f"mesh {dict(mesh.shape)}; sharded train step")
        # jit with param shardings; batch follows data axis
        step_fn = jax.jit(step_fn, donate_argnums=(0,))
    trainer = Trainer(model, opt, loader, TrainerConfig(
        steps=args.steps, accum=args.accum, log_every=10,
        ckpt_every=args.ckpt_every if args.ckpt_dir else 0,
        ckpt_dir=args.ckpt_dir), step_fn=None if n_dev == 1 else step_fn,
        jit=(n_dev == 1), obs=obs)
    print(f"training {cfg.name}: {args.steps} steps, mode={args.mode}, "
          f"rows={args.rows}x{args.seq_len}, devices={n_dev}")
    with profiler_session(args.profile_dir) as profiling:
        state, hist = trainer.train(jax.random.PRNGKey(0))
    print(f"done; final loss {hist[-1]['loss']:.4f}")
    if args.obs_trace:
        obs.export(args.obs_trace)
        print(f"obs: wrote {len(obs.tracer.chrome_events())} trace events "
              f"to {args.obs_trace} (open in chrome://tracing or "
              f"ui.perfetto.dev)")
    if args.profile_dir and profiling:
        print(f"obs: XLA profile captured under {args.profile_dir}")


if __name__ == "__main__":
    main()
