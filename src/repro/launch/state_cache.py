"""Prefix/state cache: the serving-side payoff of the O(1) SSM state.

An attention server's prompt cache is a paged KV region that grows with the
prefix length; an SSM's entire context after ``P`` tokens is a fixed-size
(conv-tail, recurrent/KV-ring) state — a few KB per layer regardless of
``P``. That collapse makes prefix caching almost free: after a prefill
consumes a prompt prefix, ONE decode-cache row (every layer's state, in the
``model.init_cache`` leaf layout that ``snapshot()`` already persists) plus
the end-of-prefix logits is the whole artifact. A later request whose
prompt starts with the same tokens restores that row and prefills only its
suffix — a shared system prompt costs one stored state instead of
recompute, for every request that carries it.

``StateCache`` is a host-side LRU keyed by a content hash of the prefix
tokens, bounded by ``max_bytes``. It is deliberately engine-agnostic: the
ServeEngine passes single-row cache trees in and out (see ``cache_row`` /
``load_cache_row`` below and the cached-lane plumbing in launch/serve.py),
and because the object lives on the host it survives engine crash-recovery
— a fresh engine ``restore()``d from a snapshot keeps hitting the same
cache.

Metrics (``cache.*`` in the obs registry — catalogue in obs/README.md):
hits / misses / inserts / evictions counters, bytes / entries gauges.

Leaf layout of one stored row (mirrors init_cache with B == 1):
  * unit-stacked leaves:  ``(n_units, 1, …)``  (under the "units" key)
  * tail leaves:          ``(1, …)``
"""
from __future__ import annotations

import collections
import hashlib
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.obs import MetricsRegistry


def _stacked(path) -> bool:
    return any(getattr(p, "key", None) == "units" for p in path)


# ---------------------------------------------------------------------------
# single-row views of the engine's state trees
# ---------------------------------------------------------------------------

def state_row(states, r: int, s: int):
    """One packed segment's harvested state as a single-row cache tree.

    ``states`` is the pytree from ``model.prefill_packed`` — leaves carry
    (B, S, …) leading dims, (n_units, B, S, …) for unit-stacked layers.
    Returns the (r, s) segment's state with the row layout documented in
    the module docstring."""
    def one(path, leaf):
        if _stacked(path):                      # (n_units, B, S, …)
            return leaf[:, r, s][:, None]       # → (n_units, 1, …)
        return leaf[r, s][None]                 # (B, S, …) → (1, …)

    return jax.tree_util.tree_map_with_path(one, states)


def cache_row(cache, r: int):
    """One row of a decode-layout cache (``model.init_cache`` leaves) as a
    single-row cache tree — what the chunk lane's carried state looks like
    at a prefix boundary."""
    def one(path, leaf):
        if _stacked(path):                      # (n_units, B, …)
            return leaf[:, r:r + 1]
        return leaf[r:r + 1]                    # (B, …)

    return jax.tree_util.tree_map_with_path(one, cache)


def load_cache_row(cache, row, idx):
    """Write a stored single-row tree into row ``idx`` of a decode-layout
    cache. jit-friendly (``idx`` may be a traced scalar): the engine jits
    this once and reuses it for both the decode-slot cache and the chunk
    side cache."""
    def one(path, c, s):
        if _stacked(path):
            return c.at[:, idx].set(s[:, 0].astype(c.dtype))
        return c.at[idx].set(s[0].astype(c.dtype))

    return jax.tree_util.tree_map_with_path(one, cache, row)


def row_finite(row, logits) -> bool:
    """Host-side finiteness probe over a single-row tree + its logits —
    the insert-side guard: a poisoned state must never be cached."""
    if not np.all(np.isfinite(np.asarray(logits))):
        return False
    for leaf in jax.tree_util.tree_leaves(jax.device_get(row)):
        if np.issubdtype(leaf.dtype, np.floating) and \
                not np.all(np.isfinite(leaf)):
            return False
    return True


# ---------------------------------------------------------------------------
# the cache
# ---------------------------------------------------------------------------

class CacheEntry:
    """One stored prefix: its length, the single-row state tree (host
    numpy), the end-of-prefix logits (V,) f32, and its byte cost."""

    __slots__ = ("key", "prefix_len", "state", "logits", "nbytes")

    def __init__(self, key, prefix_len, state, logits, nbytes):
        self.key = key
        self.prefix_len = prefix_len
        self.state = state
        self.logits = logits
        self.nbytes = nbytes


class StateCache:
    """LRU prefix→state cache with a byte budget.

    ``lookup(tokens)`` returns the entry for the LONGEST stored prefix of
    ``tokens`` (checking distinct stored lengths longest-first), bumping it
    to most-recently-used; ``insert`` evicts from the LRU end until the new
    entry fits. ``generation`` increments on any content change so callers
    can memoize misses ("this prompt missed at generation G" stays valid
    until G changes).

    Pass ``registry`` (e.g. the engine's ``obs.metrics``) to surface the
    ``cache.*`` metrics next to the ``serve.*`` ones."""

    def __init__(self, max_bytes: int = 64 << 20,
                 registry: Optional[MetricsRegistry] = None):
        if max_bytes < 1:
            raise ValueError(f"max_bytes must be >= 1, got {max_bytes}")
        self.max_bytes = int(max_bytes)
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self._hits = self.registry.counter(
            "cache.hits", help="prefix lookups that found a stored state")
        self._misses = self.registry.counter(
            "cache.misses", help="prefix lookups with no stored prefix")
        self._inserts = self.registry.counter(
            "cache.inserts", help="prefix states stored")
        self._evictions = self.registry.counter(
            "cache.evictions", help="entries evicted (LRU byte budget)")
        self._bytes_g = self.registry.gauge(
            "cache.bytes", help="resident bytes of stored states")
        self._entries_g = self.registry.gauge(
            "cache.entries", help="resident entries")
        self._entries: "collections.OrderedDict[str, CacheEntry]" = \
            collections.OrderedDict()
        self._lens: collections.Counter = collections.Counter()
        self._bytes = 0
        self.generation = 0

    # ------------------------------------------------------------- queries
    @property
    def hits(self) -> int:
        return self._hits.value

    @property
    def misses(self) -> int:
        return self._misses.value

    @property
    def lookups(self) -> int:
        return self._hits.value + self._misses.value

    @property
    def inserts(self) -> int:
        return self._inserts.value

    @property
    def evictions(self) -> int:
        return self._evictions.value

    @property
    def nbytes(self) -> int:
        return self._bytes

    def __len__(self) -> int:
        return len(self._entries)

    @staticmethod
    def _key(tokens, n: int) -> str:
        t = np.ascontiguousarray(np.asarray(tokens[:n], np.int32))
        return hashlib.blake2b(t.tobytes(), digest_size=16).hexdigest()

    def lookup(self, tokens) -> Optional[CacheEntry]:
        """Longest stored prefix of ``tokens``, or None. One hash per
        DISTINCT stored prefix length ≤ len(tokens) — not per entry."""
        n = len(tokens)
        for P in sorted(self._lens, reverse=True):
            if P > n:
                continue
            e = self._entries.get(self._key(tokens, P))
            if e is not None:
                self._entries.move_to_end(e.key)
                self._hits.inc()
                return e
        self._misses.inc()
        return None

    # ------------------------------------------------------------ mutation
    def insert(self, tokens, prefix_len: int, state,
               logits) -> Optional[CacheEntry]:
        """Store ``tokens[:prefix_len]`` → (single-row state tree, (V,)
        logits). Device leaves are pulled to host numpy; an entry larger
        than the whole budget is refused (returns None); otherwise LRU
        entries are evicted until it fits. Re-inserting a stored prefix
        just refreshes its recency."""
        key = self._key(tokens, prefix_len)
        if key in self._entries:
            self._entries.move_to_end(key)
            return self._entries[key]
        state = jax.device_get(state)
        logits = np.asarray(logits, np.float32).reshape(-1)
        nbytes = logits.nbytes + sum(
            leaf.nbytes for leaf in jax.tree_util.tree_leaves(state))
        if nbytes > self.max_bytes:
            return None
        while self._bytes + nbytes > self.max_bytes and self._entries:
            self._evict_lru()
        e = CacheEntry(key, int(prefix_len), state, logits, nbytes)
        self._entries[key] = e
        self._lens[e.prefix_len] += 1
        self._bytes += nbytes
        self._inserts.inc()
        self.generation += 1
        self._sync_gauges()
        return e

    def _evict_lru(self):
        _, e = self._entries.popitem(last=False)
        self._lens[e.prefix_len] -= 1
        if not self._lens[e.prefix_len]:
            del self._lens[e.prefix_len]
        self._bytes -= e.nbytes
        self._evictions.inc()
        self.generation += 1

    def clear(self):
        """Drop every entry (counted as evictions) — the forced-evict
        fault seam and a manual invalidation hook."""
        while self._entries:
            self._evict_lru()
        self._sync_gauges()

    def _sync_gauges(self):
        self._bytes_g.set(self._bytes)
        self._entries_g.set(len(self._entries))

    @staticmethod
    def device_state(entry: CacheEntry):
        """The entry's row tree as device arrays (what ``load_cache_row``
        consumes)."""
        return jax.tree.map(jnp.asarray, entry.state)

    def __repr__(self):
        return (f"StateCache(entries={len(self._entries)}, "
                f"bytes={self._bytes}/{self.max_bytes}, "
                f"hits={self.hits}, misses={self.misses}, "
                f"evictions={self.evictions})")
