"""Assigned input shapes × architectures: ShapeDtypeStruct stand-ins,
sharding specs, and jit-able step functions for every dry-run cell.

Shapes (per assignment):
  train_4k     seq 4,096   global_batch 256   → train_step
  prefill_32k  seq 32,768  global_batch 32    → prefill (packed fwd → logits)
  decode_32k   seq 32,768  global_batch 128   → serve_step (1 token, KV cache)
  long_500k    seq 524,288 global_batch 1     → serve_step (sub-quadratic only)

Skip rules (DESIGN.md §4): encoder-only archs have no decode; long_500k
runs only for sub-quadratic archs (SSM/hybrid/windowed attention).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.lm import build_model
from repro.optim.adamw import AdamW, constant_schedule
from repro.train.trainer import make_train_step
from repro.distributed import sharding as shd

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

N_VISION_TOKENS = 256      # vlm stub: patch embeddings per packed buffer


def cell_supported(cfg: ArchConfig, shape_name: str) -> Tuple[bool, str]:
    s = SHAPES[shape_name]
    if s["kind"] == "decode":
        if cfg.encoder_only:
            return False, "encoder-only: no autoregressive step"
        if shape_name == "long_500k" and not cfg.sub_quadratic:
            return False, "full attention: 500k decode is quadratic-regime"
    return True, ""


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs — never allocated)
# ---------------------------------------------------------------------------

def train_batch_specs(cfg: ArchConfig, batch: int, seq: int) -> Dict[str, Any]:
    i32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
    bspec: Dict[str, Any] = {
        "tokens": i32((batch, seq)),
        "positions": i32((batch, seq)),
        "segment_ids": i32((batch, seq)),
    }
    if cfg.family == "audio":
        bspec["frames"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                               jnp.dtype(cfg.dtype))
        bspec["labels"] = i32((batch, seq))
    if cfg.family == "vlm":
        bspec["mrope_positions"] = i32((batch, seq, len(cfg.mrope_sections)))
        bspec["vision_embeds"] = jax.ShapeDtypeStruct(
            (batch, N_VISION_TOKENS, cfg.d_model), jnp.dtype(cfg.dtype))
        bspec["vision_positions"] = i32((batch, N_VISION_TOKENS))
    return bspec


def input_specs(cfg: ArchConfig, shape_name: str) -> Dict[str, Any]:
    """Public entry: ShapeDtypeStruct stand-ins for every model input of the
    given cell (weak-type-correct, shardable, no device allocation)."""
    s = SHAPES[shape_name]
    if s["kind"] in ("train", "prefill"):
        return train_batch_specs(cfg, s["batch"], s["seq"])
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(s["batch"], s["seq"]))
    return {
        "cache": cache,
        "tokens_t": jax.ShapeDtypeStruct((s["batch"], 1), jnp.int32),
        "cache_len": jax.ShapeDtypeStruct((s["batch"],), jnp.int32),
    }


# ---------------------------------------------------------------------------
# cell builders: (fn, example_args, in_shardings, out_shardings)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Cell:
    fn: Any
    args: Tuple
    in_shardings: Tuple
    out_shardings: Any
    meta: Dict[str, Any]


def _ns(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _resolve_act_pspec(cfg: ArchConfig, mesh, batch: int) -> ArchConfig:
    """act_pspec=("auto",) → sequence-shard the residual carry over 'model'
    (Megatron-SP; right for attention-only stacks). ("auto_d",) → shard the
    d_model dim instead (right for recurrent stacks whose scans are
    channel-parallel but sequential in L)."""
    if cfg.act_pspec == ("auto",):
        cfg = dataclasses.replace(
            cfg, act_pspec=(shd.batch_axis(mesh, batch), "model", None))
    elif cfg.act_pspec == ("auto_d",):
        dspec = shd._fit(mesh, cfg.d_model, "model")
        cfg = dataclasses.replace(
            cfg, act_pspec=(shd.batch_axis(mesh, batch), None, dspec))
    return cfg


def build_train_cell(cfg: ArchConfig, mesh, shape_name: str = "train_4k",
                     accum: int = 1,
                     opt: Optional[AdamW] = None) -> Cell:
    s = SHAPES[shape_name]
    cfg = dataclasses.replace(cfg, dtype="bfloat16")
    cfg = _resolve_act_pspec(cfg, mesh, s["batch"])
    model = build_model(cfg)
    opt = opt or AdamW(constant_schedule(1e-4))
    step_fn = make_train_step(model, opt, accum=accum)

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    opt_shape = jax.eval_shape(opt.init, params_shape)
    state_shape = {"params": params_shape, "opt": opt_shape}
    pspec = shd.param_pspecs(params_shape, mesh)
    state_spec = {"params": pspec,
                  "opt": type(opt_shape)(step=P(), m=pspec, v=pspec)}
    batch_shape = train_batch_specs(cfg, s["batch"], s["seq"])
    batch_spec = shd.batch_pspecs(batch_shape, mesh)
    metrics_spec = jax.tree.map(
        lambda _: P(), jax.eval_shape(step_fn, state_shape, batch_shape)[1])
    return Cell(
        fn=step_fn,
        args=(state_shape, batch_shape),
        in_shardings=(_ns(mesh, state_spec), _ns(mesh, batch_spec)),
        out_shardings=(_ns(mesh, state_spec), _ns(mesh, metrics_spec)),
        meta={"kind": "train", "batch": s["batch"], "seq": s["seq"],
              "fn_name": "train_step"},
    )


def build_prefill_cell(cfg: ArchConfig, mesh,
                       shape_name: str = "prefill_32k") -> Cell:
    s = SHAPES[shape_name]
    cfg = dataclasses.replace(cfg, dtype="bfloat16")
    cfg = _resolve_act_pspec(cfg, mesh, s["batch"])
    model = build_model(cfg)
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    # serving: bf16 weights
    params_shape = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16)
        if l.dtype == jnp.float32 else l, params_shape)
    pspec = shd.param_pspecs(params_shape, mesh)
    batch_shape = train_batch_specs(cfg, s["batch"], s["seq"])
    batch_spec = shd.batch_pspecs(batch_shape, mesh)

    def prefill(params, batch):
        return model.prefill_logits(params, batch)

    vshard = shd._fit(mesh, cfg.vocab, "model")
    return Cell(
        fn=prefill,
        args=(params_shape, batch_shape),
        in_shardings=(_ns(mesh, pspec), _ns(mesh, batch_spec)),
        out_shardings=_ns(mesh, P(shd.batch_axis(mesh, s["batch"]), vshard)),
        meta={"kind": "prefill", "batch": s["batch"], "seq": s["seq"],
              "fn_name": "prefill"},
    )


def build_decode_cell(cfg: ArchConfig, mesh, shape_name: str) -> Cell:
    s = SHAPES[shape_name]
    cfg = dataclasses.replace(cfg, dtype="bfloat16")
    model = build_model(cfg)
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params_shape = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16)
        if l.dtype == jnp.float32 else l, params_shape)
    pspec = shd.param_pspecs(params_shape, mesh)
    ins = input_specs(cfg, shape_name)
    cache_spec = shd.cache_pspecs(ins["cache"], mesh, s["batch"])
    b = shd.batch_axis(mesh, s["batch"])

    def serve_step(params, cache, tokens_t, cache_len):
        return model.decode_step(params, cache, tokens_t, cache_len)

    vshard = shd._fit(mesh, cfg.vocab, "model")
    return Cell(
        fn=serve_step,
        args=(params_shape, ins["cache"], ins["tokens_t"], ins["cache_len"]),
        in_shardings=(_ns(mesh, pspec), _ns(mesh, cache_spec),
                      NamedSharding(mesh, P(b, None)),
                      NamedSharding(mesh, P(b))),
        out_shardings=(NamedSharding(mesh, P(b, vshard)),
                       _ns(mesh, cache_spec)),
        meta={"kind": "decode", "batch": s["batch"], "seq": s["seq"],
              "fn_name": "serve_step"},
    )


def build_cell(cfg: ArchConfig, mesh, shape_name: str, **kw) -> Cell:
    kind = SHAPES[shape_name]["kind"]
    if kind == "train":
        return build_train_cell(cfg, mesh, shape_name, **kw)
    if kind == "prefill":
        return build_prefill_cell(cfg, mesh, shape_name)
    return build_decode_cell(cfg, mesh, shape_name)
