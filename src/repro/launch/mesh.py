"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — smoke tests keep their 1 CPU device; only
launch/dryrun.py (which sets XLA_FLAGS first) materializes 512 devices.

Mesh creation goes through ``distributed/compat.py``: the pinned JAX has no
``jax.sharding.AxisType`` / ``axis_types=`` kwarg; newer releases do.
"""
from __future__ import annotations

from repro.distributed.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Whatever devices exist, as ("data", "model") — for sharding unit
    tests run in subprocesses with --xla_force_host_platform_device_count."""
    import jax
    n = len(jax.devices())
    if n % model_axis:
        raise ValueError(f"{n} devices not divisible by model={model_axis}")
    return make_mesh((n // model_axis, model_axis), ("data", "model"))
