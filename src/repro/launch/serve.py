"""Serving launcher: batched greedy decoding with per-layer caches, request
slots with reset-based reuse (no cache reallocation between requests), and
continuous-batching-style slot refill.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba-110m --tiny \
      --batch 4 --new-tokens 16
"""
import argparse
import functools
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models.lm import build_model


class ServeEngine:
    """Slot-based batch decoder: B slots; prompts enter through a single
    O(L) prefill forward that hands off every layer's cache (model.prefill);
    finished slots are reset in place (PackMamba's state-isolation rule on
    the decode path) and refilled from the pending queue."""

    def __init__(self, model, params, batch_slots: int, max_len: int):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.cache = model.init_cache(batch_slots, max_len)
        self.step = jax.jit(model.decode_step)
        self.prefill = jax.jit(functools.partial(model.prefill,
                                                 max_len=max_len))

    def decode_batch(self, prompts, max_new: int, eos: int = -1):
        """prompts: list of ≤B int32 arrays. Returns list of outputs."""
        B = self.B
        lens = [len(p) for p in prompts] + [1] * (B - len(prompts))
        maxp = max(lens)
        grid = np.zeros((B, maxp), np.int32)
        seg = np.zeros((B, maxp), np.int32)
        pos = np.zeros((B, maxp), np.int32)
        for b, p in enumerate(prompts):
            grid[b, :len(p)] = p
            seg[b, :len(p)] = 1
            pos[b, :len(p)] = np.arange(len(p))
        seg[len(prompts):, 0] = 1              # idle slots: 1-token dummy
        batch = {"tokens": jnp.asarray(grid), "positions": jnp.asarray(pos),
                 "segment_ids": jnp.asarray(seg)}
        logits, self.cache, lens_j = self.prefill(self.params, batch)
        outs = [[] for _ in range(B)]
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for i in range(max_new):
            for b in range(len(prompts)):
                outs[b].append(int(tok[b, 0]))
            logits, self.cache = self.step(self.params, self.cache, tok,
                                           lens_j + i, None)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        return outs[:len(prompts)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba-110m")
    ap.add_argument("--tiny", action="store_true",
                    help="shrink the model for a CPU demo")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = dataclasses.replace(cfg, d_model=128, n_layers=4, vocab=512,
                                  dtype="float32", scan_chunk=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, args.batch, args.max_len)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    n_reqs, n_toks = 0, 0
    for round_i in range(2):                       # two waves of requests
        prompts = [rng.integers(1, cfg.vocab, size=int(n)).astype(np.int32)
                   for n in rng.integers(5, 20, size=args.batch)]
        outs = engine.decode_batch(prompts, args.new_tokens)
        for b, o in enumerate(outs):
            print(f"wave{round_i} req{b}: prompt[{len(prompts[b])}] "
                  f"-> {o[:8]}…")
        n_reqs += len(prompts)
        n_toks += sum(len(o) for o in outs)
    dt = time.perf_counter() - t0
    print(f"{n_reqs} requests, {n_toks} tokens in {dt:.2f}s "
          f"({n_toks / dt:.1f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
