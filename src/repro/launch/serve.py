"""Overlapped continuous-batching serve engine: async packed prefill →
per-slot decode with batched sampling.

PackMamba's packing is applied to the SERVING path: instead of left-padding
every prompt to the batch max and decoding in synchronous waves (the padded
baseline the paper shows wasting 2-3×), prompts are packed back-to-back into
shape-bucketed prefill buffers (core/packing.py policies), ONE forward
harvests every segment's final (conv-tail, recurrent/KV) state at its
segment end (``model.prefill_packed``), and the states are scattered into
per-request decode slots (``model.scatter_into_cache``). Decode then runs
one fused step per token over all slots; a slot that hits EOS or its token
budget is released and refilled from the admission queue *mid-flight* —
the decode batch stays full without draining a wave.

Three serving-loop mechanisms on top of the PR-3 engine:

* **Prefill/decode overlap** (``overlap=True``): the packed prefill is
  dispatched asynchronously (JAX async dispatch; the decode-step jit donates
  its cache buffers) and the engine keeps issuing decode steps on the live
  slots while the prefill result is in flight. The target slots are merely
  *reserved* while pending; only when the device signals completion
  (``jax.Array.is_ready``) are the harvested states scattered into the
  decode cache — so the decode dependency chain never stalls on the packed
  forward. Per-slot token streams are identical either way: the engines
  differ only in *when* independent computations are enqueued.
* **Latency-aware admission** (``target_ttft_ms``): the fixed
  ``refill_threshold`` batches admissions for throughput (a decode step
  costs the same idle or full, so single-slot refills waste prefills). The
  TTFT policy overrides it: when the queue's *oldest* request has waited
  longer than the target, a prefill is issued even for a single free slot.
  ``ServeStats`` tracks per-request submit→first-token (TTFT) and
  inter-token latencies so the trade is measurable.
* **Batched sampling** (per-request ``temperature`` / ``top_k`` /
  ``top_p``): one fixed-shape jitted step (``model.decode_step_sample``)
  decodes AND samples every slot, with per-slot ``jax.random`` key streams
  derived from (engine seed, request id) — a request samples identically
  wherever its slot lands. ``temperature=0`` (the default) is exact greedy.

Compile discipline: decode is one fixed shape; prefill shapes are bounded
by the bucket list (rows × bucket-capacity), NOT by the number of distinct
prompt lengths — ``stats.buckets`` counts the shapes actually compiled.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba-110m --tiny \
      --slots 8 --requests 24 --new-tokens 16 --temperature 0.8 --top-k 40
"""
import argparse
import collections
import dataclasses
import functools
import time
from typing import Callable, Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import packing
from repro.models import blocks as B
from repro.models.lm import build_model


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray         # 1-D int32 prompt
    max_new: int
    eos: int = -1              # -1 = never matches (runs to budget)
    temperature: float = 0.0   # 0 = greedy
    top_k: int = 0             # 0 = full vocab
    top_p: float = 1.0         # 1 = full mass
    submit_t: float = 0.0      # engine clock at submit()


@dataclasses.dataclass
class ServeStats:
    prefills: int = 0              # packed prefill rounds issued
    prefill_tokens: int = 0        # real prompt tokens prefilled
    decode_steps: int = 0          # fused all-slot decode steps
    generated: int = 0             # tokens handed back to requests
    midflight_refills: int = 0     # prefills issued while slots were decoding
    overlapped_prefills: int = 0   # prefills that stayed in flight across
    #                                ≥1 decode step before landing
    early_admits: int = 0          # admissions forced by the TTFT policy
    #                                below the refill threshold
    buckets: Optional[set] = None  # distinct (rows, L) prefill shapes used
    ttft_ms: Optional[List[float]] = None   # per request: submit→first token
    itl_ms: Optional[List[float]] = None    # per decode token: inter-token

    def __post_init__(self):
        if self.buckets is None:
            self.buckets = set()
        if self.ttft_ms is None:
            self.ttft_ms = []
        if self.itl_ms is None:
            self.itl_ms = []

    def ttft_percentiles(self) -> Dict[str, float]:
        """{'p50': ms, 'p95': ms} over recorded TTFTs ({} when none)."""
        if not self.ttft_ms:
            return {}
        return {"p50": float(np.percentile(self.ttft_ms, 50)),
                "p95": float(np.percentile(self.ttft_ms, 95))}


# back-compat alias (pre-overlap name)
EngineStats = ServeStats


class ServeEngine:
    """Slot-based continuous batching with an async packed-prefill admission
    path and batched per-slot sampling.

    * ``submit()`` enqueues requests (each with its own budget, EOS and
      sampling knobs); ``run()`` drives admission + decode until everything
      drains (``step()`` exposes one iteration for custom loops).
    * Admission packs queued prompts (FIFO, ``policy``) into a
      (prefill_rows, bucket) buffer — the smallest bucket that fits the
      head-of-line prompt — capped by free slots and ``max_segments`` per
      row. The prefill is DISPATCHED and, with ``overlap=True``, left in
      flight while decode keeps stepping; its states land in the reserved
      slots once ready. Requests never wait for a wave boundary.
    * The decode batch is one jitted ``decode_step_sample`` over ALL slots
      (forward + temperature/top-k/top-p sampling fused; idle slots ride
      along — their state is fully overwritten at refill, so the garbage
      they accumulate is harmless and the shape never changes).
    * Per-slot termination: a slot is released the moment its request emits
      ``eos`` or exhausts ``max_new`` — the EOS token itself is kept.
    """

    def __init__(self, model, params, num_slots: int, max_len: int, *,
                 prefill_rows: int = 2, buckets=(64, 128, 256),
                 max_segments: int = 4, policy: str = "first_fit",
                 eos: int = -1, refill_threshold: Optional[int] = None,
                 overlap: bool = True,
                 target_ttft_ms: Optional[float] = None,
                 sample_seed: int = 0,
                 clock: Callable[[], float] = time.monotonic):
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.prefill_rows = prefill_rows
        self.buckets = tuple(sorted(buckets))
        self.max_segments = max_segments
        self.policy = policy
        self.eos = eos
        self.overlap = overlap
        self.target_ttft_ms = target_ttft_ms
        self.sample_seed = sample_seed
        self._clock = clock
        # A decode step costs the same whether a slot is active or idle
        # (fixed batch), so single-slot refills waste a whole prefill
        # forward to activate one slot. Batch admissions: only refill once
        # this many slots are free (or nothing is decoding at all) — unless
        # the head-of-line wait blows the TTFT target (see _admission_due).
        self.refill_threshold = max(1, num_slots // 2) \
            if refill_threshold is None else refill_threshold

        cfg = getattr(model, "cfg", None)
        if cfg is not None and getattr(cfg, "scan_tune", "off") != "off":
            # warm the scan autotuning cache for every prefill shape this
            # engine can compile — (prefill_rows, bucket) — so the packed
            # forwards resolve measured schedule winners at trace time
            from repro.tune import warm_for_config
            warm_for_config(cfg, [(prefill_rows, b) for b in self.buckets])

        self.cache = model.init_cache(num_slots, max_len)
        self.cache_len = jnp.zeros((num_slots,), jnp.int32)
        self.cur_tok = jnp.zeros((num_slots, 1), jnp.int32)
        # per-slot sampling state, scattered at refill like the cache
        self.slot_keys = jnp.zeros((num_slots, 2), jnp.uint32)
        self.slot_temp = jnp.zeros((num_slots,), jnp.float32)
        self.slot_topk = jnp.zeros((num_slots,), jnp.int32)
        self.slot_topp = jnp.ones((num_slots,), jnp.float32)
        # the decode chain and the scatter both rewrite the whole slot
        # cache every call — donate it so the engine holds ONE cache's
        # worth of device memory (and XLA can update in place), which is
        # what lets an overlapped prefill allocate its activations beside
        # the live decode loop instead of on top of two cache copies
        self._step = jax.jit(model.decode_step_sample, donate_argnums=(1,))

        # all-greedy steps skip the sampling tail (full-vocab sort + gumbel
        # per slot) — with temperature=0 the default, the common serving
        # regime decodes on the plain argmax step; slots only pay for
        # sampling on steps where some ACTIVE request actually samples
        # (key streams stay aligned: a sampling request keeps every one of
        # its steps on the sampled path)
        def greedy_step(params, cache, toks, clen):
            logits, cache = model.decode_step(params, cache, toks, clen,
                                              None)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache

        self._step_greedy = jax.jit(greedy_step, donate_argnums=(1,))
        self._scatter = jax.jit(model.scatter_into_cache,
                                donate_argnums=(0,))
        self._sample_flat = jax.jit(model.sample_tokens)
        self._prefill = jax.jit(
            functools.partial(model.prefill_packed, max_len=max_len))
        self._wave_prefill = jax.jit(
            functools.partial(model.prefill, max_len=max_len))

        self.queue: collections.deque = collections.deque()
        self.slot_req: List[Optional[Request]] = [None] * num_slots
        self.slot_remaining = [0] * num_slots
        self.slot_pending = [False] * num_slots   # reserved by in-flight
        self.slot_last_t = [0.0] * num_slots      # last token host-observed
        self._inflight: Optional[dict] = None     # one pending prefill
        self.outputs: Dict[int, List[int]] = {}
        self.stats = ServeStats()
        self._next_rid = 0

    # ------------------------------------------------------------ admission
    def submit(self, tokens, max_new: int, eos: Optional[int] = None,
               temperature: float = 0.0, top_k: int = 0,
               top_p: float = 1.0) -> int:
        tokens = np.asarray(tokens, np.int32)
        if tokens.ndim != 1 or len(tokens) == 0:
            raise ValueError(
                f"prompt must be a non-empty 1-D token array, got shape "
                f"{tokens.shape} — every request needs ≥ 1 prompt token")
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new} — a "
                             f"request must generate at least one token")
        if len(tokens) > self.buckets[-1]:
            raise ValueError(f"prompt length {len(tokens)} exceeds largest "
                             f"prefill bucket {self.buckets[-1]}")
        if len(tokens) + max_new > self.max_len:
            raise ValueError(f"prompt {len(tokens)} + max_new {max_new} "
                             f"exceeds slot capacity {self.max_len}")
        if temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got {temperature}")
        if top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 = full vocab), "
                             f"got {top_k}")
        if not 0.0 < top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {top_p}")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, tokens, max_new,
                                  self.eos if eos is None else eos,
                                  temperature, int(top_k), top_p,
                                  self._clock()))
        self.outputs[rid] = []
        return rid

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req)
                if r is None and not self.slot_pending[i]]

    def _active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    def _finish_token(self, slot: int, tok: int):
        """Record one generated token; release the slot on EOS / budget."""
        req = self.slot_req[slot]
        self.outputs[req.rid].append(tok)
        self.stats.generated += 1
        self.slot_remaining[slot] -= 1
        if tok == req.eos or self.slot_remaining[slot] <= 0:
            self.slot_req[slot] = None

    def _admission_due(self, free: List[int]) -> bool:
        """Throughput rule (enough free slots, or nothing decoding) with a
        latency override: admit below the threshold when the head-of-line
        request's wait already exceeds ``target_ttft_ms``."""
        if not free or not self.queue or self._inflight is not None:
            return False
        if not self._active_slots():
            return True
        if len(free) >= self.refill_threshold:
            return True
        if self.target_ttft_ms is not None:
            wait_ms = (self._clock() - self.queue[0].submit_t) * 1e3
            if wait_ms >= self.target_ttft_ms:
                self.stats.early_admits += 1
                return True
        return False

    def _try_refill(self) -> bool:
        """Admit queued prompts into free slots via one packed prefill.

        Bucket choice is head-of-line: the smallest bucket holding the
        oldest prompt; younger prompts join only if they fit the same
        bucket (FIFO within a round, no starvation across rounds). The
        prefill is dispatched asynchronously; with ``overlap`` on and other
        slots decoding, it is left in flight (see _land_prefill)."""
        free = self._free_slots()
        if not self._admission_due(free):
            return False
        head = self.queue[0]
        L = min(b for b in self.buckets if b >= len(head.tokens))
        admitted: List[Request] = []
        lens: List[int] = []
        for req in list(self.queue):
            if len(req.tokens) > L or len(admitted) == len(free):
                break
            plan = packing.plan_packing(lens + [len(req.tokens)], L,
                                        self.policy)
            if len(plan) > self.prefill_rows or \
                    any(len(row) > self.max_segments for row in plan):
                break
            admitted.append(req)
            lens.append(len(req.tokens))
        if not admitted:
            return False
        if self._active_slots():
            self.stats.midflight_refills += 1
        for _ in admitted:          # admitted is always a queue prefix
            self.queue.popleft()
        pb = packing.pack([r.tokens for r in admitted], L,
                          policy=self.policy, num_rows=self.prefill_rows)
        ends = packing.segment_ends(pb, self.max_segments)
        batch = {"tokens": pb.tokens, "positions": pb.positions,
                 "segment_ids": pb.segment_ids}
        logits, states, seg_lens = self._prefill(self.params, batch,
                                                 ends=jnp.asarray(ends))
        # (row, seg) → admitted request → slot; fixed-size scatter with the
        # num_slots sentinel dropping unused entries (one compile per bucket)
        K = self.prefill_rows * self.max_segments
        src = np.zeros(K, np.int32)
        dst = np.full(K, self.num_slots, np.int32)
        rids = np.zeros(K, np.int32)
        temp = np.zeros(K, np.float32)
        topk = np.zeros(K, np.int32)
        topp = np.ones(K, np.float32)
        slot_of = {}
        for r, ids in enumerate(pb.seq_ids):
            for s, qi in enumerate(ids):
                slot = free[qi]
                k = len(slot_of)
                src[k] = r * self.max_segments + s
                dst[k] = slot
                slot_of[qi] = (slot, r, s)
                req = admitted[qi]
                fk = r * self.max_segments + s
                rids[fk] = req.rid
                temp[fk] = req.temperature
                topk[fk] = req.top_k
                topp[fk] = req.top_p
        # the prefill's own first token, sampled per segment with each
        # request's (seed, rid)-derived key stream — flat (K, V) so the
        # sample jit compiles once, independent of the bucket
        keys0 = B.request_keys(self.sample_seed, rids)
        flat_lg = logits.reshape(K, -1)
        flat_tok, keys1 = self._sample_flat(flat_lg, keys0,
                                            jnp.asarray(temp),
                                            jnp.asarray(topk),
                                            jnp.asarray(topp))
        for qi in slot_of:                       # reserve target slots
            self.slot_pending[slot_of[qi][0]] = True
        self._inflight = {
            "tok": flat_tok, "keys": keys1, "states": states,
            "seg_lens": seg_lens, "src": jnp.asarray(src),
            "dst": jnp.asarray(dst), "admitted": admitted,
            "slot_of": slot_of, "temp": temp, "topk": topk, "topp": topp,
            "steps_waited": 0}
        self.stats.prefills += 1
        self.stats.prefill_tokens += sum(lens)
        self.stats.buckets.add((self.prefill_rows, L))
        if not self.overlap or not self._active_slots():
            self._land_prefill(block=True)
        return True

    def _prefill_ready(self, inflight: dict) -> bool:
        """Device-side completion probe for an in-flight prefill (split out
        so tests can script the overlap window)."""
        tok = inflight["tok"]
        ready = getattr(tok, "is_ready", None)
        return ready() if ready is not None else True

    def _land_prefill(self, block: bool = False) -> bool:
        """Scatter a completed prefill's states into the reserved slots and
        activate them. With ``block=False`` this is a no-op while the
        prefill is still in flight — decode keeps the device busy and the
        states land on a later engine step."""
        inf = self._inflight
        if inf is None:
            return False
        if not block and not self._prefill_ready(inf):
            return False
        src_j, dst_j = inf["src"], inf["dst"]
        self.cache = self._scatter(self.cache, inf["states"], src_j, dst_j)
        flat_lens = inf["seg_lens"].reshape(-1)
        self.cache_len = self.cache_len.at[dst_j].set(
            flat_lens[src_j], mode="drop")
        self.cur_tok = self.cur_tok.at[dst_j].set(
            inf["tok"][src_j][:, None], mode="drop")
        self.slot_keys = self.slot_keys.at[dst_j].set(
            inf["keys"][src_j], mode="drop")
        self.slot_temp = self.slot_temp.at[dst_j].set(
            jnp.asarray(inf["temp"])[src_j], mode="drop")
        self.slot_topk = self.slot_topk.at[dst_j].set(
            jnp.asarray(inf["topk"])[src_j], mode="drop")
        self.slot_topp = self.slot_topp.at[dst_j].set(
            jnp.asarray(inf["topp"])[src_j], mode="drop")
        # host bookkeeping + the prefill's own first token (the np.asarray
        # is the host sync point — TTFT is measured where the token becomes
        # observable, not where the prefill was dispatched)
        first = np.asarray(inf["tok"])
        now = self._clock()
        for qi, req in enumerate(inf["admitted"]):
            slot, r, s = inf["slot_of"][qi]
            self.slot_pending[slot] = False
            self.slot_req[slot] = req
            self.slot_remaining[slot] = req.max_new
            self.slot_last_t[slot] = now
            self.stats.ttft_ms.append((now - req.submit_t) * 1e3)
            self._finish_token(slot, int(first[r * self.max_segments + s]))
        if inf["steps_waited"] > 0:
            self.stats.overlapped_prefills += 1
        self._inflight = None
        return True

    # --------------------------------------------------------------- decode
    def _decode_step(self):
        """One fused decode+sample step over every slot; per-slot
        termination and inter-token latency accounting."""
        active = self._active_slots()
        if not active:
            return
        if any(self.slot_req[i].temperature > 0.0 for i in active):
            tok, _, self.cache, self.slot_keys = self._step(
                self.params, self.cache, self.cur_tok, self.cache_len,
                self.slot_keys, self.slot_temp, self.slot_topk,
                self.slot_topp, None)
        else:
            tok, self.cache = self._step_greedy(
                self.params, self.cache, self.cur_tok, self.cache_len)
        act = np.zeros(self.num_slots, bool)
        act[active] = True
        self.cache_len = self.cache_len + jnp.asarray(act, jnp.int32)
        self.cur_tok = tok[:, None]
        self.stats.decode_steps += 1
        if self._inflight is not None:
            self._inflight["steps_waited"] += 1
        toks = np.asarray(tok)
        now = self._clock()
        for i in active:
            self.stats.itl_ms.append((now - self.slot_last_t[i]) * 1e3)
            self.slot_last_t[i] = now
            self._finish_token(i, int(toks[i]))

    # ----------------------------------------------------------------- loop
    def step(self) -> bool:
        """One engine iteration: land a finished prefill, refill free slots,
        then one decode step. Returns True while work remains."""
        self._land_prefill(block=False)
        self._try_refill()
        if self._inflight is not None and not self._active_slots():
            self._land_prefill(block=True)    # nothing else to overlap with
        self._decode_step()
        return bool(self.queue or self._active_slots()
                    or self._inflight is not None)

    def run(self) -> Dict[int, List[int]]:
        """Drive until the queue and all slots drain; returns rid → tokens."""
        while self.step():
            pass
        return self.outputs

    # ------------------------------------------------- padded-wave baseline
    def decode_batch(self, prompts, max_new, eos: int = -1,
                     temperature: float = 0.0, top_k: int = 0,
                     top_p: float = 1.0):
        """Padded-wave BASELINE (the paper's padding regime on the serving
        path): ≤num_slots prompts left-padded to the batch max, one prefill,
        synchronous decode. Kept for benchmarking against the continuous
        path — it shares the fused decode+sample step (uniform sampling
        knobs across the wave), so the two modes stay comparable under any
        sampling regime. ``max_new`` is an int or a per-prompt list; slots
        stop accumulating tokens at ``eos`` or their budget (the EOS token
        itself is kept) — but the WAVE only ends when every row is done,
        which is exactly the drain cost continuous batching removes."""
        Bz = self.num_slots
        if len(prompts) > Bz:
            raise ValueError(f"{len(prompts)} prompts > {Bz} slots")
        if self._active_slots() or self.queue or self._inflight is not None:
            raise RuntimeError("decode_batch would clobber the live slot "
                               "cache; drain the continuous engine first "
                               "(or use a separate ServeEngine)")
        budgets = [max_new] * len(prompts) if isinstance(max_new, int) \
            else list(max_new)
        lens = [len(p) for p in prompts] + [1] * (Bz - len(prompts))
        maxp = max(lens)
        grid = np.zeros((Bz, maxp), np.int32)
        seg = np.zeros((Bz, maxp), np.int32)
        pos = np.zeros((Bz, maxp), np.int32)
        for b, p in enumerate(prompts):
            grid[b, :len(p)] = p
            seg[b, :len(p)] = 1
            pos[b, :len(p)] = np.arange(len(p))
        seg[len(prompts):, 0] = 1              # idle slots: 1-token dummy
        batch = {"tokens": jnp.asarray(grid), "positions": jnp.asarray(pos),
                 "segment_ids": jnp.asarray(seg)}
        logits, self.cache, lens_j = self._wave_prefill(self.params, batch)
        sampling = temperature > 0.0
        temp = jnp.full((Bz,), temperature, jnp.float32)
        topk = jnp.full((Bz,), int(top_k), jnp.int32)
        topp = jnp.full((Bz,), top_p, jnp.float32)
        keys = B.request_keys(self.sample_seed, np.arange(Bz))
        outs = [[] for _ in range(Bz)]
        done = [b >= len(prompts) for b in range(Bz)]
        if sampling:
            tok, keys = self._sample_flat(logits, keys, temp, topk, topp)
        else:
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        tok = tok[:, None]
        for i in range(max(budgets, default=0)):
            toks = np.asarray(tok[:, 0])
            for b in range(len(prompts)):
                if done[b]:
                    continue
                outs[b].append(int(toks[b]))
                if int(toks[b]) == eos or len(outs[b]) >= budgets[b]:
                    done[b] = True
            if all(done):
                break
            if sampling:
                tok, _, self.cache, keys = self._step(
                    self.params, self.cache, tok, lens_j + i, keys, temp,
                    topk, topp, None)
            else:
                tok, self.cache = self._step_greedy(
                    self.params, self.cache, tok, lens_j + i)
            tok = tok[:, None]
        return outs[:len(prompts)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba-110m")
    ap.add_argument("--tiny", action="store_true",
                    help="shrink the model for a CPU demo")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--policy", default="first_fit",
                    choices=["first_fit", "sequential", "sorted_greedy"])
    ap.add_argument("--no-overlap", action="store_true",
                    help="block on each packed prefill instead of decoding "
                         "through it")
    ap.add_argument("--target-ttft-ms", type=float, default=None,
                    help="admit below the refill threshold once the oldest "
                         "queued request has waited this long")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature for every request (0=greedy)")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--scan-tune", default="off",
                    help="off | auto | <cache path>: shape-keyed scan "
                         "autotuning (the engine warms the cache for its "
                         "prefill buckets at startup)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = dataclasses.replace(cfg, d_model=128, n_layers=4, vocab=512,
                                  dtype="float32", scan_chunk=64)
    if args.scan_tune != "off":
        cfg = dataclasses.replace(cfg, scan_tune=args.scan_tune)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, args.slots, args.max_len,
                         policy=args.policy, overlap=not args.no_overlap,
                         target_ttft_ms=args.target_ttft_ms)

    rng = np.random.default_rng(0)
    lens = rng.integers(5, 40, size=args.requests)
    t0 = time.perf_counter()
    for n in lens:
        engine.submit(rng.integers(1, cfg.vocab, size=int(n)),
                      args.new_tokens, temperature=args.temperature,
                      top_k=args.top_k, top_p=args.top_p)
    outs = engine.run()
    dt = time.perf_counter() - t0
    st = engine.stats
    for rid in sorted(outs)[:4]:
        print(f"req{rid}: prompt[{lens[rid]}] -> {outs[rid][:8]}…")
    pct = st.ttft_percentiles()
    print(f"{len(outs)} requests, {st.generated} tokens in {dt:.2f}s "
          f"({st.generated / dt:.1f} tok/s incl. compile) — "
          f"{st.prefills} prefills ({st.midflight_refills} mid-flight, "
          f"{st.overlapped_prefills} overlapped, {st.early_admits} early), "
          f"{st.decode_steps} decode steps, "
          f"{len(st.buckets)} prefill shape(s) compiled")
    itl = f"{np.percentile(st.itl_ms, 50):.2f}ms" if st.itl_ms else "n/a"
    print(f"TTFT p50 {pct.get('p50', 0):.1f}ms p95 {pct.get('p95', 0):.1f}ms "
          f"over {len(st.ttft_ms)} requests; "
          f"ITL p50 {itl} over {len(st.itl_ms)} decode tokens")


if __name__ == "__main__":
    main()
