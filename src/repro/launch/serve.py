"""Overlapped continuous-batching serve engine: async packed prefill →
per-slot decode with batched sampling.

PackMamba's packing is applied to the SERVING path: instead of left-padding
every prompt to the batch max and decoding in synchronous waves (the padded
baseline the paper shows wasting 2-3×), prompts are packed back-to-back into
shape-bucketed prefill buffers (core/packing.py policies), ONE forward
harvests every segment's final (conv-tail, recurrent/KV) state at its
segment end (``model.prefill_packed``), and the states are scattered into
per-request decode slots (``model.scatter_into_cache``). Decode then runs
one fused step per token over all slots; a slot that hits EOS or its token
budget is released and refilled from the admission queue *mid-flight* —
the decode batch stays full without draining a wave.

Three serving-loop mechanisms on top of the PR-3 engine:

* **Prefill/decode overlap** (``overlap=True``): the packed prefill is
  dispatched asynchronously (JAX async dispatch; the decode-step jit donates
  its cache buffers) and the engine keeps issuing decode steps on the live
  slots while the prefill result is in flight. The target slots are merely
  *reserved* while pending; only when the device signals completion
  (``jax.Array.is_ready``) are the harvested states scattered into the
  decode cache — so the decode dependency chain never stalls on the packed
  forward. Per-slot token streams are identical either way: the engines
  differ only in *when* independent computations are enqueued.
* **Latency-aware admission** (``target_ttft_ms``): the fixed
  ``refill_threshold`` batches admissions for throughput (a decode step
  costs the same idle or full, so single-slot refills waste prefills). The
  TTFT policy overrides it: when the queue's *oldest* request has waited
  longer than the target, a prefill is issued even for a single free slot.
  ``ServeStats`` tracks per-request submit→first-token (TTFT) and
  inter-token latencies so the trade is measurable.
* **Batched sampling** (per-request ``temperature`` / ``top_k`` /
  ``top_p``): one fixed-shape jitted step (``model.decode_step_sample``)
  decodes AND samples every slot, with per-slot ``jax.random`` key streams
  derived from (engine seed, request id) — a request samples identically
  wherever its slot lands. ``temperature=0`` (the default) is exact greedy.

Scheduler v2 adds three levers on the admission path:

* **Chunked prefill** (``chunk_rows`` / ``chunk_size``): a prompt longer
  than the largest bucket is consumed in fixed-shape (chunk_rows,
  chunk_size) slabs that resume from the carried O(1) SSM/conv/KV state
  (``model.prefill_chunk``) on a side cache, then hand off to a decode
  slot through the same ``scatter_into_cache`` path — a 32k prompt can no
  longer head-of-line-block the queue, and short requests keep decoding
  through every chunk round. The old over-bucket ``ValueError`` in
  ``submit()`` is gone (``max_prompt_len`` is the explicit bound now).
* **Multi-prefill pipelining** (``max_inflight_prefills``): the single
  in-flight prefill generalizes to a bounded pool; each entry lands
  independently when its device result is ready. Token streams stay
  bit-identical — per-request sampling keys make them slot- and
  schedule-independent.
* **TTFT-aware bucket choice** (``bucket_policy="ttft"``): instead of
  always taking the smallest bucket that fits the head-of-line prompt,
  the engine upgrades to a larger bucket when that admits strictly more
  queued requests AND the head's wait still has slack against the TTFT
  allowance (``target_ttft_ms``, else the measured p50) — admit small
  early under latency pressure, wait to fill big when there is headroom.

``ServeStats`` additionally splits engine wall time into prefill / chunk /
decode / host phases (``*_ms``) so a throughput regression is attributable
to the scheduler vs the kernels.

Compile discipline: decode is one fixed shape; prefill shapes are bounded
by the bucket list (rows × bucket-capacity), NOT by the number of distinct
prompt lengths — ``stats.buckets`` counts the shapes actually compiled.
Chunked prefill adds ONE more shape, (chunk_rows, chunk_size).

On top of the overlap/latency/sampling engine sits a FAULT-TOLERANCE
layer (PackMamba's O(1) per-request state is what makes it cheap — a
session *is* a few KB of SSM/conv/KV state, not a paged KV region):

* **Request lifecycle**: ``submit(..., deadline_ms=)`` enforces a
  deadline at admission, at prefill landing, and per decode step;
  ``cancel(rid)`` revokes a request wherever it is (queued, reserved by
  an in-flight prefill, or decoding); when the admission queue exceeds
  ``max_queue`` entries or its head is older than ``max_queue_age_ms``,
  ``submit`` sheds the request (``ShedError`` with a reason) instead of
  queueing forever. ``engine.status[rid]`` is the explicit outcome:
  queued → active → done | failed | expired | cancelled (``errors[rid]``
  carries the diagnostic for failures).
* **Numerical guard rails** (``guard=True``): a per-step finiteness probe
  on decode logits (``model.decode_step_sample_guarded``) and a
  per-segment probe on harvested prefill states (``model.prefill_probe``).
  A non-finite slot is QUARANTINED — request failed with a diagnostic,
  slot freed for reuse — instead of silently streaming garbage; healthy
  slots' token streams are bit-identical to an unguarded run (the probe
  only reads the logits; the poison seam adds 0.0).
* **Fault injection** (``faults=FaultPlan(...)``, repro/faults.py): fail
  or delay the Nth prefill dispatch, poison decode logits or prefill
  states, kill the engine at step K — every failure mode above is
  deterministically testable on CPU (``make verify-faults``).
* **Crash recovery**: ``snapshot(manager)`` persists the whole engine —
  per-slot SSM/conv/KV states, sampling keys, generated-token tails,
  queue contents, statuses — through checkpoint.CheckpointManager;
  ``restore(manager)`` on a fresh engine resumes every in-flight request
  and completes it with exactly the tokens an uninterrupted run would
  have produced (decode is deterministic given the restored state, and
  per-request sampling keys make streams slot-independent).

Finally, two O(1)-state exploits ride on the same snapshot leaf layout
(full lifecycle walkthrough in docs/serving.md):

* **Prefix caching** (``state_cache=`` / ``cache_bytes=``,
  launch/state_cache.py): every landed prompt's post-prefill state (ONE
  cache row + end logits) is stored in a host-side LRU keyed by a prefix
  hash; ``submit(..., prefix_len=N)`` declares a shared system prompt so
  the capture boundary sits mid-prompt. A later request restores the
  longest cached prefix and prefills only its suffix (chunk-lane slabs,
  bucket-quantized widths) — or, on a whole-prompt hit, starts decoding
  with NO forward at all. Token streams are bit-identical to cold
  prefills (chunked ≡ unchunked + per-request key streams).
* **Speculative decode** (``spec_k=``): n-gram prompt-copy drafts are
  verified k-at-a-time by one scan-of-decode-steps forward
  (``model.decode_verify``); rejected suffixes roll back via the verify's
  own state trajectory (``model.spec_rollback``). Greedy streams are
  bit-identical to one-token-at-a-time decoding by construction;
  ``spec.accept_rate`` is the observable payoff.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba-110m --tiny \
      --slots 8 --requests 24 --new-tokens 16 --temperature 0.8 --top-k 40
"""
import argparse
import collections
import dataclasses
import functools
import time
from typing import Callable, Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import packing
from repro.faults import (EngineKilled, FaultPlan, poison_cache_rows,
                          poison_states)
from repro.launch.state_cache import (StateCache, cache_row, load_cache_row,
                                      row_finite, state_row)
from repro.models import blocks as B
from repro.models.lm import build_model
from repro.obs import (MetricsRegistry, Obs, percentiles, profiler_session)


class ShedError(RuntimeError):
    """Request rejected at admission (overload shedding). ``reason`` says
    which bound tripped; the request was never queued and has no rid."""

    def __init__(self, reason: str):
        super().__init__(reason)
        self.reason = reason


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray         # 1-D int32 prompt
    max_new: int
    eos: int = -1              # -1 = never matches (runs to budget)
    temperature: float = 0.0   # 0 = greedy
    top_k: int = 0             # 0 = full vocab
    top_p: float = 1.0         # 1 = full mass
    submit_t: float = 0.0      # engine clock at submit()
    deadline_ms: Optional[float] = None   # total budget from submit_t
    prefix_len: Optional[int] = None      # declared shared-prefix boundary
    #                                       (a StateCache capture/reuse hint)


class _HistList(list):
    """Per-sample latency list that ALSO feeds a registry histogram on
    append — ``stats.ttft_ms`` keeps its list API (indexing, len,
    ``np.percentile``-ability) while the obs registry sees every sample."""

    def __init__(self, hist):
        super().__init__()
        self.hist = hist

    def append(self, v):
        super().append(v)
        self.hist.observe(v)


# fixed histogram bounds (ms) for the registry view of per-request TTFT and
# per-token ITL — wide enough for CPU-compile-included demo runs
_TTFT_BUCKETS = (1, 2, 5, 10, 20, 50, 100, 200, 500, 1000, 2000, 5000)
_ITL_BUCKETS = (0.5, 1, 2, 5, 10, 20, 50, 100, 200, 500)


class ServeStats:
    """Engine counters/latencies as a thin view over a ``MetricsRegistry``
    (repro.obs): every attribute below is backed by a ``serve.*`` metric,
    so ``engine.stats.shed`` and the registry's ``serve.shed`` are the SAME
    number — one source for the CLI summary, the benchmark JSON, a
    Prometheus scrape, and the trace's embedded snapshot.

    ``ServeStats()`` stands alone (its own registry);
    ``ServeStats(registry)`` binds to an existing one (the engine passes
    its ``obs.metrics``). The attribute API is unchanged from the old
    dataclass: ``st.shed += 1`` works, ``st.buckets`` is a plain set,
    ``st.ttft_ms`` / ``st.itl_ms`` are lists (that also feed histograms).

    Counters:
      prefills            packed prefill rounds issued
      prefill_tokens      real prompt tokens prefilled
      decode_steps        fused all-slot decode steps
      generated           tokens handed back to requests
      midflight_refills   prefills issued while slots were decoding
      overlapped_prefills prefills in flight across ≥1 decode step
      early_admits        admissions forced by the TTFT policy
      shed                submits rejected by overload shedding
      expired             requests terminated by their deadline
      cancelled           requests revoked via cancel()
      quarantined         slots failed by the finiteness probes
      prefill_faults      prefill dispatches that raised
      chunk_rounds        chunked-prefill forwards issued
      chunk_tokens        prompt tokens consumed via chunk rounds
      chunked_prefills    requests whose prompt landed via chunks
      bucket_upgrades     TTFT policy took a bigger-than-fit bucket
      deferred_upgrades   upgrade declined: head wait too long
    Gauges:
      queue_depth_max     deepest the admission queue ever got
      prefill_ms / chunk_ms / decode_ms / host_ms
                          host wall time per engine phase (the satellite
                          diagnosis for packed_continuous vs padded_wave:
                          WHERE does a step spend time?)
    """

    _counters = ("prefills", "prefill_tokens", "decode_steps", "generated",
                 "midflight_refills", "overlapped_prefills", "early_admits",
                 "shed", "expired", "cancelled", "quarantined",
                 "prefill_faults", "chunk_rounds", "chunk_tokens",
                 "chunked_prefills", "bucket_upgrades", "deferred_upgrades")
    _gauges = ("queue_depth_max", "prefill_ms", "chunk_ms", "decode_ms",
               "host_ms")

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        # bypass our __setattr__ until the metric map exists
        d = self.__dict__
        d["registry"] = registry if registry is not None \
            else MetricsRegistry()
        d["_m"] = {n: d["registry"].counter(f"serve.{n}")
                   for n in self._counters}
        d["_m"].update({n: d["registry"].gauge(f"serve.{n}")
                        for n in self._gauges})
        d["buckets"] = set()   # distinct (rows, L) prefill shapes used
        d["ttft_ms"] = _HistList(
            d["registry"].histogram("serve.ttft_ms", _TTFT_BUCKETS,
                                    help="submit to first token, ms"))
        d["itl_ms"] = _HistList(
            d["registry"].histogram("serve.itl_ms", _ITL_BUCKETS,
                                    help="inter-token latency, ms"))

    def __getattr__(self, name):
        m = self.__dict__.get("_m", {})
        if name in m:
            return m[name].value
        raise AttributeError(name)

    def __setattr__(self, name, value):
        m = self.__dict__.get("_m", {})
        if name in m:
            m[name].set(value)
        else:
            object.__setattr__(self, name, value)

    def __repr__(self):
        fields = ", ".join(f"{n}={self._m[n].value}"
                           for n in self._counters + self._gauges)
        return (f"ServeStats({fields}, buckets={self.buckets}, "
                f"ttft_n={len(self.ttft_ms)}, itl_n={len(self.itl_ms)})")

    def ttft_percentiles(self) -> Dict[str, float]:
        """{'p50': ms, 'p95': ms} over recorded TTFTs ({} when none)."""
        return percentiles(self.ttft_ms, (50, 95))

    def itl_percentiles(self) -> Dict[str, float]:
        """{'p50': ms, 'p95': ms} over inter-token latencies ({} = none)."""
        return percentiles(self.itl_ms, (50, 95))


# back-compat alias (pre-overlap name)
EngineStats = ServeStats


class ServeEngine:
    """Slot-based continuous batching with an async packed-prefill admission
    path and batched per-slot sampling.

    * ``submit()`` enqueues requests (each with its own budget, EOS and
      sampling knobs); ``run()`` drives admission + decode until everything
      drains (``step()`` exposes one iteration for custom loops).
    * Admission packs queued prompts (FIFO, ``policy``) into a
      (prefill_rows, bucket) buffer — the smallest bucket that fits the
      head-of-line prompt — capped by free slots and ``max_segments`` per
      row. The prefill is DISPATCHED and, with ``overlap=True``, left in
      flight while decode keeps stepping; its states land in the reserved
      slots once ready. Requests never wait for a wave boundary.
    * The decode batch is one jitted ``decode_step_sample`` over ALL slots
      (forward + temperature/top-k/top-p sampling fused; idle slots ride
      along — their state is fully overwritten at refill, so the garbage
      they accumulate is harmless and the shape never changes).
    * Per-slot termination: a slot is released the moment its request emits
      ``eos`` or exhausts ``max_new`` — the EOS token itself is kept.
    """

    def __init__(self, model, params, num_slots: int, max_len: int, *,
                 prefill_rows: int = 2, buckets=(64, 128, 256),
                 max_segments: int = 4, policy: str = "first_fit",
                 eos: int = -1, refill_threshold: Optional[int] = None,
                 overlap: bool = True,
                 target_ttft_ms: Optional[float] = None,
                 sample_seed: int = 0,
                 clock: Callable[[], float] = time.monotonic,
                 max_queue: Optional[int] = None,
                 max_queue_age_ms: Optional[float] = None,
                 guard: bool = False,
                 faults: Optional[FaultPlan] = None,
                 max_inflight_prefills: int = 1,
                 bucket_policy: str = "smallest_fit",
                 chunk_rows: int = 1,
                 chunk_size: Optional[int] = None,
                 max_prompt_len: Optional[int] = None,
                 obs: Optional[Obs] = None,
                 state_cache: Optional[StateCache] = None,
                 cache_bytes: Optional[int] = None,
                 spec_k: int = 0, spec_ngram: int = 3):
        self.model = model
        self.params = params
        # telemetry: metrics are always on (ServeStats is a view over
        # obs.metrics); span tracing records only when the caller passes
        # Obs.on() — the default NULL_TRACER makes every tracer call below
        # a no-op, so token streams and schedules are bit-identical
        self.obs = obs if obs is not None else Obs.off()
        self._tr = self.obs.tracer
        self._req_spans: Dict[int, Optional[int]] = {}
        self.num_slots = num_slots
        self.max_len = max_len
        self.prefill_rows = prefill_rows
        self.buckets = tuple(sorted(buckets))
        self.max_segments = max_segments
        self.policy = policy
        self.eos = eos
        self.overlap = overlap
        self.target_ttft_ms = target_ttft_ms
        self.sample_seed = sample_seed
        self._clock = clock
        self.max_queue = max_queue
        self.max_queue_age_ms = max_queue_age_ms
        self.faults = faults
        if bucket_policy not in ("smallest_fit", "ttft"):
            raise ValueError(f"bucket_policy must be 'smallest_fit' or "
                             f"'ttft', got {bucket_policy!r}")
        self.max_inflight_prefills = max(1, int(max_inflight_prefills))
        self.bucket_policy = bucket_policy
        self.max_prompt_len = max_prompt_len
        # prefix/state caching (launch/state_cache.py): a host-side LRU of
        # single-row post-prefix states. Pass a StateCache to share one
        # across engines (it survives crash-recovery), or just a byte
        # budget (``cache_bytes``) to have the engine build its own on the
        # obs metrics registry.
        if state_cache is None and cache_bytes is not None:
            state_cache = StateCache(cache_bytes, registry=self.obs.metrics)
        self.state_cache = state_cache
        self._cache_memo: Dict[int, int] = {}   # rid → miss generation
        # speculative decode: k-token n-gram/prompt-copy drafts verified by
        # ONE scan-of-decode-steps forward; rejects roll the per-slot state
        # back via the verify's own trajectory (greedy slots only)
        self.spec_k = max(0, int(spec_k))
        self.spec_ngram = max(1, int(spec_ngram))
        # chunked prefill: prompts longer than the largest bucket are fed
        # through a SIDE cache in fixed (chunk_rows, chunk_size) slabs —
        # the main decode cache can't host a partial prompt because the
        # fused all-slot decode step would advance (and corrupt) it
        self.chunk_rows = max(1, int(chunk_rows))
        self.chunk_size = int(chunk_size) if chunk_size is not None \
            else self.buckets[-1]
        if self.chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
        self.chunk_enabled = chunk_rows > 0 and \
            getattr(model, "supports_chunked_prefill", False)
        # poison faults are only observable through the finiteness probes,
        # so a plan that injects them turns the guard on by itself
        self.guard = guard or (faults is not None and faults.needs_guard())
        # A decode step costs the same whether a slot is active or idle
        # (fixed batch), so single-slot refills waste a whole prefill
        # forward to activate one slot. Batch admissions: only refill once
        # this many slots are free (or nothing is decoding at all) — unless
        # the head-of-line wait blows the TTFT target (see _admission_due).
        self.refill_threshold = max(1, num_slots // 2) \
            if refill_threshold is None else refill_threshold

        cfg = getattr(model, "cfg", None)
        if cfg is not None and getattr(cfg, "scan_tune", "off") != "off":
            # warm the scan autotuning cache for every prefill shape this
            # engine can compile — (prefill_rows, bucket) plus the chunk
            # slab — so the packed forwards resolve measured schedule
            # winners at trace time
            from repro.tune import warm_for_config
            shapes = [(prefill_rows, b) for b in self.buckets]
            if self.chunk_enabled:
                # the chunk lane's slab widths are dynamic now: any bucket
                # ≤ chunk_size (packing.slab_width), not just the full slab
                for w in sorted({b for b in self.buckets
                                 if b <= self.chunk_size}
                                | {self.chunk_size}):
                    shapes.append((self.chunk_rows, w))
            warm_for_config(cfg, shapes)

        self.cache = model.init_cache(num_slots, max_len)
        self.cache_len = jnp.zeros((num_slots,), jnp.int32)
        self.cur_tok = jnp.zeros((num_slots, 1), jnp.int32)
        # per-slot sampling state, scattered at refill like the cache
        self.slot_keys = jnp.zeros((num_slots, 2), jnp.uint32)
        self.slot_temp = jnp.zeros((num_slots,), jnp.float32)
        self.slot_topk = jnp.zeros((num_slots,), jnp.int32)
        self.slot_topp = jnp.ones((num_slots,), jnp.float32)
        # the decode chain and the scatter both rewrite the whole slot
        # cache every call — donate it so the engine holds ONE cache's
        # worth of device memory (and XLA can update in place), which is
        # what lets an overlapped prefill allocate its activations beside
        # the live decode loop instead of on top of two cache copies
        self._step = jax.jit(model.decode_step_sample, donate_argnums=(1,))

        # all-greedy steps skip the sampling tail (full-vocab sort + gumbel
        # per slot) — with temperature=0 the default, the common serving
        # regime decodes on the plain argmax step; slots only pay for
        # sampling on steps where some ACTIVE request actually samples
        # (key streams stay aligned: a sampling request keeps every one of
        # its steps on the sampled path)
        def greedy_step(params, cache, toks, clen):
            logits, cache = model.decode_step(params, cache, toks, clen,
                                              None)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache

        self._step_greedy = jax.jit(greedy_step, donate_argnums=(1,))

        # guard-rail variants: same forward, plus the fused finiteness
        # probe and the additive poison seam (all-zero poison is a bitwise
        # no-op on the logits, so guarded streams match unguarded ones)
        def greedy_step_guarded(params, cache, toks, clen, poison):
            logits, cache = model.decode_step(params, cache, toks, clen,
                                              None)
            logits = logits + poison[:, None]
            return (jnp.argmax(logits, -1).astype(jnp.int32), cache,
                    jnp.all(jnp.isfinite(logits), axis=-1))

        self._step_greedy_guarded = jax.jit(greedy_step_guarded,
                                            donate_argnums=(1,))
        self._step_guarded = jax.jit(model.decode_step_sample_guarded,
                                     donate_argnums=(1,))
        self._probe = jax.jit(model.prefill_probe)
        self._poison0 = jnp.zeros((num_slots,), jnp.float32)
        self._scatter = jax.jit(model.scatter_into_cache,
                                donate_argnums=(0,))
        self._sample_flat = jax.jit(model.sample_tokens)
        # cached-lane row restore: ONE jitted writer shared by the decode
        # cache and the chunk cache (idx is traced; two cache shapes → two
        # compiles, independent of how many prefixes get restored)
        self._load_row = jax.jit(load_cache_row, donate_argnums=(0,))
        if self.spec_k:
            # no cache donation here: the verify's trajectory output keeps
            # K+1 cache copies alive, so in-place reuse is impossible
            self._spec_verify = jax.jit(model.decode_verify)
            self._spec_rollback = jax.jit(model.spec_rollback)
        m = self.obs.metrics
        self._spec_rounds = m.counter(
            "spec.rounds", help="speculative verify rounds issued")
        self._spec_proposed = m.counter(
            "spec.proposed", help="draft tokens proposed")
        self._spec_accepted = m.counter(
            "spec.accepted", help="draft tokens accepted by verify")
        self._spec_rate = m.gauge(
            "spec.accept_rate", help="accepted/proposed, cumulative")
        self._prefill = jax.jit(
            functools.partial(model.prefill_packed, max_len=max_len))
        self._wave_prefill = jax.jit(
            functools.partial(model.prefill, max_len=max_len))

        # chunked-prefill lane: a side cache of chunk_rows long prompts
        # being consumed slab by slab; handoff to a decode slot reuses the
        # packed scatter by viewing each row as a 1-segment harvest
        if self.chunk_enabled:
            self.chunk_cache = model.init_cache(self.chunk_rows, max_len)
            self.chunk_clen = jnp.zeros((self.chunk_rows,), jnp.int32)
            self._chunk_fn = jax.jit(model.prefill_chunk,
                                     donate_argnums=(1,))
            self._reset_rows = jax.jit(model.reset_cache_rows,
                                       donate_argnums=(0,))

            def chunk_handoff(cache, chunk_cache, src, dst):
                states = model.expand_chunk_states(chunk_cache)
                return model.scatter_into_cache(cache, states, src, dst)

            self._chunk_scatter = jax.jit(chunk_handoff, donate_argnums=(0,))

            def chunk_probe(chunk_cache, logits):
                states = model.expand_chunk_states(chunk_cache)
                return model.prefill_probe(states, logits[:, None])

            self._chunk_probe = jax.jit(chunk_probe)
        self.chunk_req: List[Optional[Request]] = [None] * self.chunk_rows
        self.chunk_off = [0] * self.chunk_rows    # prompt tokens consumed
        self.chunk_slot = [-1] * self.chunk_rows  # reserved decode slot
        self.chunk_capture = [-1] * self.chunk_rows  # StateCache boundary

        self.queue: collections.deque = collections.deque()
        self.slot_req: List[Optional[Request]] = [None] * num_slots
        self.slot_remaining = [0] * num_slots
        self.slot_pending = [False] * num_slots   # reserved by in-flight
        self.slot_last_t = [0.0] * num_slots      # last token host-observed
        self._prefill_pool: List[dict] = []       # pending packed prefills
        self.outputs: Dict[int, List[int]] = {}
        # explicit per-request lifecycle: queued → active → done | failed |
        # expired | cancelled; errors[rid] holds the failure diagnostic
        self.status: Dict[int, str] = {}
        self.errors: Dict[int, str] = {}
        self.resumed: set = set()     # rids restored from a snapshot
        self.stats = ServeStats(self.obs.metrics)
        self._next_rid = 0

    @property
    def spec_accept_rate(self) -> float:
        """Cumulative accepted/proposed draft-token ratio (0.0 before any
        speculative round) — also exported as the ``spec.accept_rate``
        gauge."""
        p = self._spec_proposed.value
        return self._spec_accepted.value / p if p else 0.0

    @property
    def _inflight(self) -> Optional[dict]:
        """Oldest pending prefill (None when the pool is empty) — the
        pre-pool engine exposed exactly one; tests and callers keep that
        view while the pool holds up to ``max_inflight_prefills``."""
        return self._prefill_pool[0] if self._prefill_pool else None

    # ------------------------------------------------------------ admission
    def submit(self, tokens, max_new: int, eos: Optional[int] = None,
               temperature: float = 0.0, top_k: int = 0,
               top_p: float = 1.0, deadline_ms: Optional[float] = None,
               prefix_len: Optional[int] = None,
               rid: Optional[int] = None) -> int:
        """Enqueue one request; returns its rid.

        ``prefix_len`` declares that ``tokens[:prefix_len]`` is a SHARED
        prefix (a system prompt): with a ``state_cache`` configured, the
        first such request's post-prefix state is captured at that exact
        boundary and every later request carrying the same prefix restores
        it and prefills only its suffix. Undeclared prompts still profit —
        any full prompt already decoded is itself a cached prefix — but
        only a declaration puts the capture boundary mid-prompt.

        ``deadline_ms`` bounds submit→completion: a request still queued,
        still in a prefill, or still decoding when its budget runs out is
        terminated with status "expired" (tokens generated so far are
        kept). ``rid`` lets a client pin its own id (e.g. resubmission
        with stable ids); duplicates of ANY known rid are rejected here
        rather than corrupting that request's output stream later.
        Prompts longer than the largest prefill bucket are accepted and
        served via chunked prefill (``max_prompt_len`` is the explicit
        length bound when configured).
        Raises ``ShedError`` — without queueing — when the admission queue
        is over its depth (``max_queue``) or age (``max_queue_age_ms``)
        bound: under overload a fast explicit reject beats an unbounded
        queue every client has already given up on."""
        tokens = np.asarray(tokens, np.int32)
        if tokens.ndim != 1 or len(tokens) == 0:
            raise ValueError(
                f"prompt must be a non-empty 1-D token array, got shape "
                f"{tokens.shape} — every request needs ≥ 1 prompt token")
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new} — a "
                             f"request must generate at least one token")
        if self.max_prompt_len is not None and \
                len(tokens) > self.max_prompt_len:
            raise ValueError(
                f"prompt length {len(tokens)} exceeds max_prompt_len "
                f"{self.max_prompt_len} — raise the engine's bound or "
                f"truncate the prompt")
        if len(tokens) > self.buckets[-1] and not self.chunk_enabled:
            raise ValueError(
                f"prompt length {len(tokens)} exceeds largest prefill "
                f"bucket {self.buckets[-1]} and chunked prefill is "
                f"unavailable (chunk_rows=0, or the model has no "
                f"chunk-resume step) — enable chunking, split the prompt, "
                f"or configure a larger bucket")
        if len(tokens) + max_new > self.max_len:
            raise ValueError(f"prompt {len(tokens)} + max_new {max_new} "
                             f"exceeds slot capacity {self.max_len}")
        if temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got {temperature}")
        if top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 = full vocab), "
                             f"got {top_k}")
        if not 0.0 < top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {top_p}")
        if deadline_ms is not None and deadline_ms <= 0:
            raise ValueError(f"deadline_ms must be > 0, got {deadline_ms}")
        if prefix_len is not None and not 1 <= prefix_len <= len(tokens):
            raise ValueError(
                f"prefix_len {prefix_len} outside [1, {len(tokens)}] — it "
                f"marks how many LEADING prompt tokens form the shareable "
                f"prefix, so it must cover at least one token and at most "
                f"the whole prompt")
        if rid is not None:
            if rid < 0:
                raise ValueError(f"rid must be >= 0, got {rid}")
            if rid in self.outputs:
                raise ValueError(
                    f"duplicate request id {rid} (status "
                    f"{self.status.get(rid)!r}) — rids identify output "
                    f"streams and may never be reused")
        now = self._clock()
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            self.stats.shed += 1
            self._tr.instant("shed", track="engine", reason="max_queue")
            raise ShedError(f"shed: admission queue depth {len(self.queue)} "
                            f">= max_queue {self.max_queue}")
        if self.max_queue_age_ms is not None and self.queue:
            age_ms = (now - self.queue[0].submit_t) * 1e3
            if age_ms > self.max_queue_age_ms:
                self.stats.shed += 1
                self._tr.instant("shed", track="engine",
                                 reason="max_queue_age_ms")
                raise ShedError(
                    f"shed: head-of-line request has waited {age_ms:.0f}ms "
                    f"> max_queue_age_ms {self.max_queue_age_ms} — the "
                    f"engine is not keeping up")
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid + 1)
        self.queue.append(Request(rid, tokens, max_new,
                                  self.eos if eos is None else eos,
                                  temperature, int(top_k), top_p,
                                  now, deadline_ms, prefix_len))
        self.outputs[rid] = []
        self.status[rid] = "queued"
        self._span_to(rid, "queued", prompt=len(tokens), max_new=max_new)
        self.stats.queue_depth_max = max(self.stats.queue_depth_max,
                                         len(self.queue))
        return rid

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req)
                if r is None and not self.slot_pending[i]]

    def _active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    def _finish_token(self, slot: int, tok: int):
        """Record one generated token; release the slot on EOS / budget."""
        req = self.slot_req[slot]
        self.outputs[req.rid].append(tok)
        self.stats.generated += 1
        self.slot_remaining[slot] -= 1
        if tok == req.eos or self.slot_remaining[slot] <= 0:
            self.slot_req[slot] = None
            self.status[req.rid] = "done"
            self._span_end(req.rid, "done",
                           tokens=len(self.outputs[req.rid]))

    # ------------------------------------------------------------ lifecycle
    def _span_to(self, rid: int, name: str, **attrs):
        """Advance a request's lifecycle span (queued → prefill/chunk →
        decode) on its own trace track — one Perfetto row per request."""
        self._tr.finish(self._req_spans.pop(rid, None))
        self._req_spans[rid] = self._tr.start(name, track=f"req{rid}",
                                              rid=rid, **attrs)

    def _span_end(self, rid: int, status: str, **attrs):
        """Close a request's lifecycle span at a terminal status and mark
        the terminal as an instant event on its track."""
        self._tr.finish(self._req_spans.pop(rid, None))
        self._tr.instant(status, track=f"req{rid}", rid=rid, **attrs)

    def _terminate(self, rid: int, status: str, reason: str):
        """Move a request to a terminal status with its diagnostic."""
        self.status[rid] = status
        self.errors[rid] = reason
        if status == "expired":
            self.stats.expired += 1
        elif status == "cancelled":
            self.stats.cancelled += 1
        self._span_end(rid, status, reason=reason)

    def _deadline_over(self, req: Request, now: float) -> bool:
        return req.deadline_ms is not None and \
            (now - req.submit_t) * 1e3 >= req.deadline_ms

    def _expire_queued(self):
        """Admission-side deadline enforcement: drop queued requests whose
        budget already ran out — prefilling them would waste a forward on
        an answer nobody is waiting for."""
        if not any(r.deadline_ms is not None for r in self.queue):
            return
        now = self._clock()
        kept = collections.deque()
        for r in self.queue:
            if self._deadline_over(r, now):
                self._terminate(r.rid, "expired",
                                f"deadline {r.deadline_ms:.0f}ms exceeded "
                                f"while queued")
            else:
                kept.append(r)
        self.queue = kept

    def cancel(self, rid: int) -> bool:
        """Revoke a request wherever it is: queued (dequeued now), reserved
        by an in-flight prefill (its slot is released when the prefill
        lands), or actively decoding (slot freed now). Tokens generated so
        far stay in ``outputs[rid]``. Returns False for unknown rids and
        requests already in a terminal state — cancelling twice, or after
        completion, is a harmless no-op."""
        st = self.status.get(rid)
        if st == "queued":
            self.queue = collections.deque(
                r for r in self.queue if r.rid != rid)
            self._terminate(rid, "cancelled", "cancelled while queued")
            return True
        if st == "active":
            for i, r in enumerate(self.slot_req):
                if r is not None and r.rid == rid:
                    self.slot_req[i] = None
                    self._terminate(rid, "cancelled", "cancelled mid-decode")
                    return True
            # reserved by an in-flight prefill (_land_prefill skips it) or
            # mid-chunked-prefill (_chunk_step frees the row next round)
            self._terminate(rid, "cancelled", "cancelled during prefill")
            return True
        return False

    def _packable(self) -> List[Request]:
        """Queued requests the PACKED prefill path serves, FIFO. Longer
        prompts stay queued for the chunk lane and never block these.
        Declared-prefix requests belong to the cached lane when a
        StateCache and the chunk lane are both available — only the chunk
        lane can cut the slab stream at the declared boundary to capture
        (or resume from) the prefix state."""
        Lmax = self.buckets[-1]
        cached_lane = self.state_cache is not None and self.chunk_enabled
        return [r for r in self.queue if len(r.tokens) <= Lmax
                and not (cached_lane and r.prefix_len)]

    def _admission_due(self, free: List[int],
                       head: Optional[Request]) -> bool:
        """Throughput rule (enough free slots, or nothing decoding) with a
        latency override: admit below the threshold when the head-of-line
        request's wait already exceeds ``target_ttft_ms``. ``head`` is the
        oldest PACKABLE request (chunk-lane prompts are admitted by
        ``_chunk_step`` and don't gate the packed path)."""
        if not free or head is None or \
                len(self._prefill_pool) >= self.max_inflight_prefills:
            return False
        if not self._active_slots():
            return True
        if len(free) >= self.refill_threshold:
            return True
        if self.target_ttft_ms is not None:
            wait_ms = (self._clock() - head.submit_t) * 1e3
            if wait_ms >= self.target_ttft_ms:
                self.stats.early_admits += 1
                return True
        return False

    def _admit_count(self, packq: List[Request], L: int,
                     nfree: int) -> int:
        """How many head-of-queue packable requests one (prefill_rows, L)
        round would admit — the dry-run of ``_try_refill``'s loop."""
        lens: List[int] = []
        for req in packq:
            if len(req.tokens) > L or len(lens) == nfree:
                break
            plan = packing.plan_packing(lens + [len(req.tokens)], L,
                                        self.policy)
            if len(plan) > self.prefill_rows or \
                    any(len(row) > self.max_segments for row in plan):
                break
            lens.append(len(req.tokens))
        return len(lens)

    def _choose_bucket(self, head: Request, packq: List[Request],
                       free: List[int]) -> int:
        """Pick the prefill bucket for this round. ``smallest_fit`` (the
        default, and the pre-v2 behaviour) takes the smallest bucket that
        holds the head-of-line prompt. ``ttft`` upgrades to a larger
        bucket when that admits strictly more queued requests AND the
        head's wait is still inside the TTFT allowance
        (``target_ttft_ms``, else the measured p50): wait to fill big
        while the head has slack, admit small immediately once the head
        is already late — a bigger forward would only make a blown
        deadline worse, while everyone behind the head still benefits
        from upgrades on later rounds."""
        fits = [b for b in self.buckets if b >= len(head.tokens)]
        L = fits[0]
        if self.bucket_policy != "ttft" or len(fits) == 1:
            return L
        allowance = self.target_ttft_ms
        if allowance is None:
            allowance = self.stats.ttft_percentiles().get("p50")
        if allowance is None or allowance <= 0:
            return L                 # no latency signal yet — stay small
        best_n, best_L = self._admit_count(packq, L, len(free)), L
        if best_n >= min(len(packq), len(free)):
            return L     # smallest fit already admits every admissible
            #              request — no bucket can admit strictly more,
            #              skip the bigger buckets' dry-runs entirely
        for b in fits[1:]:
            n = self._admit_count(packq, b, len(free))
            if n > best_n:
                best_n, best_L = n, b
        if best_L == L:
            return L
        wait_ms = (self._clock() - head.submit_t) * 1e3
        if wait_ms < allowance:
            self.stats.bucket_upgrades += 1
            return best_L
        self.stats.deferred_upgrades += 1
        return L

    def _try_refill(self) -> bool:
        """Admit queued prompts into free slots via one packed prefill.

        Bucket choice starts from the oldest packable prompt
        (``_choose_bucket``); younger prompts join only if they fit the
        chosen bucket (FIFO within a round, no starvation across rounds).
        The prefill is dispatched asynchronously; with ``overlap`` on and
        other slots decoding, it joins the in-flight pool (see
        _land_prefill)."""
        packq = self._packable()
        head = packq[0] if packq else None
        free = self._free_slots()
        if not self._admission_due(free, head):
            return False
        L = self._choose_bucket(head, packq, free)
        admitted: List[Request] = []
        lens: List[int] = []
        for req in packq:
            if len(req.tokens) > L or len(admitted) == len(free):
                break
            plan = packing.plan_packing(lens + [len(req.tokens)], L,
                                        self.policy)
            if len(plan) > self.prefill_rows or \
                    any(len(row) > self.max_segments for row in plan):
                break
            admitted.append(req)
            lens.append(len(req.tokens))
        if not admitted:
            return False
        if self._active_slots():
            self.stats.midflight_refills += 1
        adm = {r.rid for r in admitted}   # a prefix of packq, but possibly
        #                                   interleaved with chunk prompts
        self.queue = collections.deque(
            r for r in self.queue if r.rid not in adm)
        for req in admitted:
            self.status[req.rid] = "active"
            self._span_to(req.rid, "prefill", bucket=L)
        pidx = self.stats.prefills      # this dispatch's fault-plan index
        dsid = self._tr.start("prefill_dispatch", track="engine", bucket=L,
                              rows=self.prefill_rows, admitted=len(admitted),
                              pidx=pidx)
        if self.faults is not None and self.faults.fails_prefill(pidx):
            # the packed forward died (injected stand-in for device OOM /
            # preemption): fail this round's requests with an explicit
            # status and keep serving — no slot was reserved, no state
            # landed, the live slots never notice
            self.stats.prefills += 1
            self.stats.prefill_faults += 1
            for req in admitted:
                self._terminate(req.rid, "failed",
                                f"prefill dispatch {pidx} failed "
                                f"(injected fault)")
            self._tr.finish(dsid, fault=True)
            return False
        pb = packing.pack([r.tokens for r in admitted], L,
                          policy=self.policy, num_rows=self.prefill_rows)
        ends = packing.segment_ends(pb, self.max_segments)
        batch = {"tokens": pb.tokens, "positions": pb.positions,
                 "segment_ids": pb.segment_ids}
        logits, states, seg_lens = self._prefill(self.params, batch,
                                                 ends=jnp.asarray(ends))
        if self.faults is not None:
            rs = self.faults.prefill_poison(pidx)
            if rs:
                states = poison_states(states, rs,
                                       self.faults.poison_value)
        # (row, seg) → admitted request → slot; fixed-size scatter with the
        # num_slots sentinel dropping unused entries (one compile per bucket)
        K = self.prefill_rows * self.max_segments
        src = np.zeros(K, np.int32)
        dst = np.full(K, self.num_slots, np.int32)
        rids = np.zeros(K, np.int32)
        temp = np.zeros(K, np.float32)
        topk = np.zeros(K, np.int32)
        topp = np.ones(K, np.float32)
        slot_of = {}
        for r, ids in enumerate(pb.seq_ids):
            for s, qi in enumerate(ids):
                slot = free[qi]
                k = len(slot_of)
                src[k] = r * self.max_segments + s
                dst[k] = slot
                slot_of[qi] = (slot, r, s)
                req = admitted[qi]
                fk = r * self.max_segments + s
                rids[fk] = req.rid
                temp[fk] = req.temperature
                topk[fk] = req.top_k
                topp[fk] = req.top_p
        # the prefill's own first token, sampled per segment with each
        # request's (seed, rid)-derived key stream — flat (K, V) so the
        # sample jit compiles once, independent of the bucket
        keys0 = B.request_keys(self.sample_seed, rids)
        flat_lg = logits.reshape(K, -1)
        flat_tok, keys1 = self._sample_flat(flat_lg, keys0,
                                            jnp.asarray(temp),
                                            jnp.asarray(topk),
                                            jnp.asarray(topp))
        for qi in slot_of:                       # reserve target slots
            self.slot_pending[slot_of[qi][0]] = True
        inf = {
            "tok": flat_tok, "keys": keys1, "states": states,
            "logits": flat_lg, "seg_lens": seg_lens, "src": jnp.asarray(src),
            "dst": jnp.asarray(dst), "admitted": admitted,
            "slot_of": slot_of, "temp": temp, "topk": topk, "topp": topp,
            "steps_waited": 0, "pidx": pidx, "probes": 0}
        if self.guard:
            # per-segment finiteness of the harvested states + end logits;
            # probed asynchronously with the prefill, read at land time
            inf["ok"] = self._probe(states, logits)
        self._prefill_pool.append(inf)
        self.stats.prefills += 1
        self.stats.prefill_tokens += sum(lens)
        self.stats.buckets.add((self.prefill_rows, L))
        self._tr.finish(dsid, tokens=sum(lens))
        if not self.overlap or not self._active_slots():
            self._land_prefill(block=True)
        return True

    def _prefill_ready(self, inflight: dict) -> bool:
        """Device-side completion probe for an in-flight prefill (split out
        so tests can script the overlap window). A fault plan can hold the
        answer at not-ready for the first N probes — a deterministic slow
        device stretching the overlap window."""
        if self.faults is not None and self.faults.prefill_not_ready(
                inflight.get("pidx", 0), inflight.get("probes", 0)):
            inflight["probes"] = inflight.get("probes", 0) + 1
            return False
        tok = inflight["tok"]
        ready = getattr(tok, "is_ready", None)
        return ready() if ready is not None else True

    def _land_prefill(self, block: bool = False) -> bool:
        """Scatter completed prefills' states into their reserved slots and
        activate them. With ``block=False`` only pool entries whose device
        result is ready land (a no-op while everything is still in flight —
        decode keeps the device busy and the states land on a later engine
        step); ``block=True`` drains the whole pool. Entries land in any
        order: they target disjoint reserved slots and per-request sampling
        keys keep token streams schedule-independent."""
        landed = False
        for inf in list(self._prefill_pool):
            if not block and not self._prefill_ready(inf):
                continue
            self._prefill_pool.remove(inf)
            self._land_one(inf)
            landed = True
        return landed

    def _land_one(self, inf: dict):
        """Land one dispatched prefill: scatter states, activate slots."""
        lsid = self._tr.start("prefill_land", track="engine",
                              pidx=inf["pidx"],
                              steps_waited=inf["steps_waited"])
        src_j, dst_j = inf["src"], inf["dst"]
        self.cache = self._scatter(self.cache, inf["states"], src_j, dst_j)
        flat_lens = inf["seg_lens"].reshape(-1)
        self.cache_len = self.cache_len.at[dst_j].set(
            flat_lens[src_j], mode="drop")
        self.cur_tok = self.cur_tok.at[dst_j].set(
            inf["tok"][src_j][:, None], mode="drop")
        self.slot_keys = self.slot_keys.at[dst_j].set(
            inf["keys"][src_j], mode="drop")
        self.slot_temp = self.slot_temp.at[dst_j].set(
            jnp.asarray(inf["temp"])[src_j], mode="drop")
        self.slot_topk = self.slot_topk.at[dst_j].set(
            jnp.asarray(inf["topk"])[src_j], mode="drop")
        self.slot_topp = self.slot_topp.at[dst_j].set(
            jnp.asarray(inf["topp"])[src_j], mode="drop")
        # host bookkeeping + the prefill's own first token (the np.asarray
        # is the host sync point — TTFT is measured where the token becomes
        # observable, not where the prefill was dispatched)
        first = np.asarray(inf["tok"])
        ok = np.asarray(inf["ok"]).reshape(-1) if "ok" in inf else None
        now = self._clock()
        for qi, req in enumerate(inf["admitted"]):
            slot, r, s = inf["slot_of"][qi]
            self.slot_pending[slot] = False
            if self.status.get(req.rid) == "cancelled":
                continue            # revoked while the prefill was in flight
            if self._deadline_over(req, now):
                self._terminate(req.rid, "expired",
                                f"deadline {req.deadline_ms:.0f}ms exceeded "
                                f"during prefill")
                continue
            k = r * self.max_segments + s
            if ok is not None and not ok[k]:
                # quarantine: the harvested state (or its end logits) went
                # non-finite — fail the request with a diagnostic and leave
                # the slot free (its cache row is fully overwritten at the
                # next refill, so the poison never propagates)
                self.stats.quarantined += 1
                self._tr.instant("quarantined", track=f"req{req.rid}",
                                 rid=req.rid)
                self._terminate(req.rid, "failed",
                                f"non-finite prefill state for request "
                                f"{req.rid} (prefill {inf['pidx']}, row "
                                f"{r}, segment {s}) — quarantined")
                continue
            if self.state_cache is not None:
                # every landed prompt doubles as a cached prefix — the
                # packed path's contribution to the StateCache
                self._insert_cache(req.tokens, len(req.tokens),
                                   state_row(inf["states"], r, s),
                                   inf["logits"][k])
            self.slot_req[slot] = req
            self.slot_remaining[slot] = req.max_new
            self.slot_last_t[slot] = now
            self.stats.ttft_ms.append((now - req.submit_t) * 1e3)
            self._span_to(req.rid, "decode", slot=slot)
            self._tr.instant("first_token", track=f"req{req.rid}",
                             rid=req.rid)
            self._finish_token(slot, int(first[k]))
        if inf["steps_waited"] > 0:
            self.stats.overlapped_prefills += 1
        self._tr.finish(lsid)

    # ------------------------------------------------------- chunked prefill
    def _chunk_active(self) -> bool:
        return any(r is not None for r in self.chunk_req)

    def _free_chunk_row(self, row: int):
        """Release a chunk row and its reserved decode slot."""
        slot = self.chunk_slot[row]
        if slot >= 0:
            self.slot_pending[slot] = False
        self.chunk_req[row] = None
        self.chunk_slot[row] = -1
        self.chunk_capture[row] = -1

    def _chunk_claims(self):
        """Assign queued over-bucket prompts to free chunk rows (each also
        reserves the decode slot it will land in, so packed admission can't
        take it out from under a half-consumed prompt)."""
        claimed = np.zeros(self.chunk_rows, bool)
        Lmax = self.buckets[-1]
        cached_lane = self.state_cache is not None
        for row in range(self.chunk_rows):
            if self.chunk_req[row] is not None:
                continue
            # declared-prefix prompts are claimed by _cache_admit (which
            # also decides the capture boundary / restored offset)
            nxt = next((r for r in self.queue if len(r.tokens) > Lmax
                        and not (cached_lane and r.prefix_len)),
                       None)
            if nxt is None:
                break
            free = self._free_slots()
            if not free:
                break
            self.queue = collections.deque(
                r for r in self.queue if r.rid != nxt.rid)
            self.status[nxt.rid] = "active"
            self.slot_pending[free[0]] = True
            self.chunk_req[row] = nxt
            self.chunk_off[row] = 0
            self.chunk_slot[row] = free[0]
            claimed[row] = True
            self._span_to(nxt.rid, "chunk", row=row, slot=free[0],
                          prompt=len(nxt.tokens))
        if claimed.any():
            # wipe the claimed rows back to init_cache values — no stale
            # conv tail / attention ring / stabilizer state across tenants
            fr = jnp.asarray(claimed)
            self.chunk_cache = self._reset_rows(self.chunk_cache, fr)
            self.chunk_clen = jnp.where(fr, 0, self.chunk_clen)

    # --------------------------------------------------------- prefix cache
    def _insert_cache(self, tokens, prefix_len: int, row, logits):
        """Store one single-row state tree in the StateCache, with the
        insert-side guard: a non-finite state is never cached — a poisoned
        entry would turn one fault into a failure for every request that
        shares the prefix."""
        lg = np.asarray(logits, np.float32)
        row = jax.device_get(row)
        if not row_finite(row, lg):
            return
        e = self.state_cache.insert(tokens, prefix_len, row, lg)
        if e is not None:
            self._tr.instant("cache_insert", track="engine",
                             prefix=int(prefix_len), bytes=e.nbytes)

    def _claim_row(self, req: Request, row: int, slot: int, off: int,
                   capture: int = -1, state=None):
        """Claim a chunk row (and its reserved decode slot) for ``req``
        starting at prompt offset ``off`` — either cold (``state=None``:
        the row is wiped to init_cache values) or resuming from a restored
        cache entry (``state``: a single-row tree; the carried length
        starts at the prefix length). ``capture > off`` marks a declared
        prefix boundary: _chunk_step cuts the slab stream there and
        inserts the post-boundary state into the StateCache as it goes
        by."""
        self.queue = collections.deque(
            r for r in self.queue if r.rid != req.rid)
        self.status[req.rid] = "active"
        self.slot_pending[slot] = True
        self.chunk_req[row] = req
        self.chunk_off[row] = off
        self.chunk_slot[row] = slot
        self.chunk_capture[row] = capture if capture > off else -1
        mask = np.zeros(self.chunk_rows, bool)
        mask[row] = True
        mj = jnp.asarray(mask)
        if state is None:
            self.chunk_cache = self._reset_rows(self.chunk_cache, mj)
            self.chunk_clen = jnp.where(mj, 0, self.chunk_clen)
        else:
            self.chunk_cache = self._load_row(self.chunk_cache, state, row)
            self.chunk_clen = jnp.where(mj, off, self.chunk_clen)
        self._span_to(req.rid, "chunk", row=row, slot=slot,
                      prompt=len(req.tokens), cached_prefix=off)

    def _activate_full_hit(self, req: Request, slot: int, state, entry):
        """Zero-forward admission on a whole-prompt cache hit: restore the
        stored post-prompt state straight into a free decode slot and
        sample the first token from the STORED end-of-prompt logits with
        the request's own (seed, rid) key stream — bit-identical to what a
        cold prefill of the same prompt would emit, without running one."""
        self.queue = collections.deque(
            r for r in self.queue if r.rid != req.rid)
        now = self._clock()
        if self._deadline_over(req, now):
            self._terminate(req.rid, "expired",
                            f"deadline {req.deadline_ms:.0f}ms exceeded "
                            f"while queued")
            return
        self.cache = self._load_row(self.cache, state, slot)
        self.cache_len = self.cache_len.at[slot].set(entry.prefix_len)
        keys0 = B.request_keys(self.sample_seed,
                               np.asarray([req.rid], np.int32))
        tok, keys1 = self._sample_flat(
            jnp.asarray(entry.logits)[None], keys0,
            jnp.asarray([req.temperature], jnp.float32),
            jnp.asarray([req.top_k], jnp.int32),
            jnp.asarray([req.top_p], jnp.float32))
        self.cur_tok = self.cur_tok.at[slot].set(tok)
        self.slot_keys = self.slot_keys.at[slot].set(keys1[0])
        self.slot_temp = self.slot_temp.at[slot].set(req.temperature)
        self.slot_topk = self.slot_topk.at[slot].set(req.top_k)
        self.slot_topp = self.slot_topp.at[slot].set(req.top_p)
        self.status[req.rid] = "active"
        self.slot_req[slot] = req
        self.slot_remaining[slot] = req.max_new
        self.slot_last_t[slot] = now
        self.stats.ttft_ms.append((now - req.submit_t) * 1e3)
        self._span_to(req.rid, "decode", slot=slot)
        self._tr.instant("first_token", track=f"req{req.rid}", rid=req.rid)
        self._finish_token(slot, int(np.asarray(tok)[0]))

    def _cache_admit(self):
        """Cached-lane admission, run before packed refill each step.

        For every queued request (FIFO) the StateCache is consulted for
        its longest stored prefix:

        * FULL-prompt hit → ``_activate_full_hit`` (no forward at all);
        * partial hit → a chunk row is claimed seeded with the restored
          state at offset P, so only the suffix is prefilled;
        * declared-prefix miss → a chunk row is claimed cold with the
          capture boundary set, so the first request with a new system
          prompt populates the cache for everyone behind it;
        * undeclared miss → memoized against the cache's generation (no
          re-hashing until the cache changes) and left for the packed /
          chunk lanes.

        The fault seams live here too: ``drop_cache`` clears the cache
        before the indexed lookup, ``poison_cache_hit`` corrupts the
        restored state of the indexed hit (which the guard rails must
        quarantine downstream)."""
        sc = self.state_cache
        if sc is None or not self.queue:
            return
        for req in list(self.queue):
            free = self._free_slots()
            if not free:
                return
            if self.chunk_enabled:
                rows = [i for i in range(self.chunk_rows)
                        if self.chunk_req[i] is None]
                if not rows:
                    return
            else:
                rows = []
            declared = int(req.prefix_len or 0)
            if not declared and \
                    self._cache_memo.get(req.rid) == sc.generation:
                continue               # known miss and the cache unchanged
            if self.faults is not None and \
                    self.faults.drops_cache(sc.lookups):
                sc.clear()
            entry = sc.lookup(req.tokens)
            if entry is None:
                self._cache_memo[req.rid] = sc.generation
                if not declared or not self.chunk_enabled:
                    continue           # packed / chunk lanes serve it cold
                self._claim_row(req, rows[0], free[0], off=0,
                                capture=declared)
                continue
            hidx = sc.hits - 1         # the lookup above counted this hit
            state = sc.device_state(entry)
            if self.faults is not None and self.faults.cache_hit_poison(hidx):
                state = poison_cache_rows(state, [0],
                                          self.faults.poison_value)
            P = entry.prefix_len
            self._tr.instant("cache_hit", track=f"req{req.rid}",
                             rid=req.rid, prefix=P)
            if P == len(req.tokens):
                self._activate_full_hit(req, free[0], state, entry)
                continue
            if not self.chunk_enabled:
                # a suffix prefill needs the chunk lane's carried state;
                # without it the packed lane serves the request cold
                self._cache_memo[req.rid] = sc.generation
                continue
            self._claim_row(req, rows[0], free[0], off=P,
                            capture=declared if declared > P else -1,
                            state=state)

    def _chunk_step(self):
        """One chunked-prefill round: claim rows for queued over-bucket
        prompts, advance every occupied row by one fixed-shape
        (chunk_rows, chunk_size) slab resuming from the carried state, and
        hand finished prompts off to their reserved decode slots (first
        token sampled with the request's own key stream, guard probe on the
        carried state) — all while the decode slots keep stepping."""
        if not self.chunk_enabled:
            return
        self._chunk_claims()
        rows = [i for i, r in enumerate(self.chunk_req) if r is not None]
        if not rows:
            return
        # lifecycle sweep before spending a forward on a dead request
        now = self._clock()
        for i in rows:
            req = self.chunk_req[i]
            if self.status.get(req.rid) == "cancelled":
                self._free_chunk_row(i)
            elif self._deadline_over(req, now):
                self._terminate(req.rid, "expired",
                                f"deadline {req.deadline_ms:.0f}ms exceeded "
                                f"during chunked prefill")
                self._free_chunk_row(i)
        rows = [i for i, r in enumerate(self.chunk_req) if r is not None]
        if not rows:
            return
        cidx = self.stats.chunk_rounds
        if self.faults is not None and self.faults.fails_chunk(cidx):
            # the chunk forward died (injected stand-in for device OOM on
            # the slab): fail the rows' requests, keep serving — the decode
            # slots never notice
            self.stats.chunk_rounds += 1
            self.stats.prefill_faults += 1
            for i in rows:
                self._terminate(self.chunk_req[i].rid, "failed",
                                f"chunked-prefill round {cidx} failed "
                                f"(injected fault)")
                self._free_chunk_row(i)
            return
        # per-row consumption stop: a declared capture boundary CUTS the
        # slab stream so the carried state (and the slab's end logits) at
        # the cut are exactly the post-prefix artifacts the cache stores
        stops = {}
        for i in rows:
            cap = self.chunk_capture[i]
            stops[i] = cap if cap > self.chunk_off[i] \
                else len(self.chunk_req[i].tokens)
        # slab width is bucket-quantized to the round's real need (a warm
        # suffix round compiles/runs a small slab, not the full chunk_size
        # one) — compile shapes stay bounded by the bucket list
        need = max(min(self.chunk_size, stops[i] - self.chunk_off[i])
                   for i in rows)
        T = packing.slab_width(need, self.buckets, self.chunk_size)
        entries = {}
        took = {}
        for i in rows:
            off = self.chunk_off[i]
            n = min(T, stops[i] - off)
            entries[i] = (self.chunk_req[i].tokens, off, n)
            took[i] = n
        batch = packing.suffix_slab(entries, self.chunk_rows, T)
        csid = self._tr.start("chunk_slab", track="engine", round=cidx,
                              rows=len(rows), tokens=sum(took.values()))
        logits, self.chunk_cache, self.chunk_clen = self._chunk_fn(
            self.params, self.chunk_cache, batch, self.chunk_clen)
        self.stats.chunk_rounds += 1
        self.stats.chunk_tokens += sum(took.values())
        self._tr.finish(csid)
        if self.faults is not None:
            prs = self.faults.chunk_poison(cidx)
            if prs:
                self.chunk_cache = poison_cache_rows(
                    self.chunk_cache, prs, self.faults.poison_value)
        if self.state_cache is not None:
            for i in rows:
                cap = self.chunk_capture[i]
                if cap >= 0 and self.chunk_off[i] + took[i] >= cap:
                    # the slab stream was cut at the boundary, so row i's
                    # carried state IS the post-prefix state and logits[i]
                    # are the end-of-prefix logits — capture both
                    self._insert_cache(self.chunk_req[i].tokens, cap,
                                       cache_row(self.chunk_cache, i),
                                       logits[i])
                    self.chunk_capture[i] = -1
        finishing = []
        for i in rows:
            self.chunk_off[i] += took[i]
            if self.chunk_off[i] >= len(self.chunk_req[i].tokens):
                finishing.append(i)
        if not finishing:
            return
        # handoff: sample each finished prompt's first token with its own
        # (seed, rid)-derived key stream, then scatter the carried state
        # into the reserved decode slot — fixed chunk_rows shapes, the
        # num_slots sentinel dropping the still-chunking rows
        rids = np.zeros(self.chunk_rows, np.int32)
        temp = np.zeros(self.chunk_rows, np.float32)
        topk = np.zeros(self.chunk_rows, np.int32)
        topp = np.ones(self.chunk_rows, np.float32)
        dst = np.full(self.chunk_rows, self.num_slots, np.int32)
        for i in finishing:
            req = self.chunk_req[i]
            rids[i] = req.rid
            temp[i] = req.temperature
            topk[i] = req.top_k
            topp[i] = req.top_p
            dst[i] = self.chunk_slot[i]
        keys0 = B.request_keys(self.sample_seed, rids)
        tok, keys1 = self._sample_flat(logits, keys0, jnp.asarray(temp),
                                       jnp.asarray(topk), jnp.asarray(topp))
        ok = None
        if self.guard:
            ok = np.asarray(self._chunk_probe(self.chunk_cache,
                                              logits)).reshape(-1)
        src_j = jnp.arange(self.chunk_rows, dtype=jnp.int32)
        dst_j = jnp.asarray(dst)
        self.cache = self._chunk_scatter(self.cache, self.chunk_cache,
                                         src_j, dst_j)
        self.cache_len = self.cache_len.at[dst_j].set(
            self.chunk_clen, mode="drop")
        self.cur_tok = self.cur_tok.at[dst_j].set(
            tok[:, None], mode="drop")
        self.slot_keys = self.slot_keys.at[dst_j].set(keys1, mode="drop")
        self.slot_temp = self.slot_temp.at[dst_j].set(
            jnp.asarray(temp), mode="drop")
        self.slot_topk = self.slot_topk.at[dst_j].set(
            jnp.asarray(topk), mode="drop")
        self.slot_topp = self.slot_topp.at[dst_j].set(
            jnp.asarray(topp), mode="drop")
        first = np.asarray(tok)         # host sync — TTFT observed here
        now = self._clock()
        for i in finishing:
            req = self.chunk_req[i]
            slot = self.chunk_slot[i]
            self._free_chunk_row(i)
            if self._deadline_over(req, now):
                self._terminate(req.rid, "expired",
                                f"deadline {req.deadline_ms:.0f}ms exceeded "
                                f"during chunked prefill")
                continue
            if ok is not None and not ok[i]:
                # quarantine: the carried state (or its end logits) went
                # non-finite — the slot stays free; its cache row is fully
                # overwritten at the next refill, so the poison never
                # reaches a live stream
                self.stats.quarantined += 1
                self._tr.instant("quarantined", track=f"req{req.rid}",
                                 rid=req.rid)
                self._terminate(req.rid, "failed",
                                f"non-finite chunked-prefill state for "
                                f"request {req.rid} (chunk round {cidx}, "
                                f"row {i}) — quarantined")
                continue
            if self.state_cache is not None:
                # the finished prompt is itself a cached prefix: a later
                # identical prompt becomes a zero-forward full hit
                self._insert_cache(req.tokens, len(req.tokens),
                                   cache_row(self.chunk_cache, i),
                                   logits[i])
            self.slot_req[slot] = req
            self.slot_remaining[slot] = req.max_new
            self.slot_last_t[slot] = now
            self.stats.ttft_ms.append((now - req.submit_t) * 1e3)
            self.stats.chunked_prefills += 1
            self._span_to(req.rid, "decode", slot=slot)
            self._tr.instant("first_token", track=f"req{req.rid}",
                             rid=req.rid)
            self._finish_token(slot, int(first[i]))

    # --------------------------------------------------- speculative decode
    def _spec_draft(self):
        """Propose up to ``spec_k`` draft tokens per active slot by n-gram
        prompt copy: find the most recent earlier occurrence of the
        context's trailing g-gram (g from ``spec_ngram`` down to 1, search
        capped at the last 512 context tokens) and copy the tokens that
        followed it. Free — no model call — and strong exactly where
        speculation pays: prompts that quote, template, or repeat.
        Returns ((num_slots, spec_k) int32 drafts, (num_slots,) bool
        have-a-draft)."""
        K = self.spec_k
        draft = np.zeros((self.num_slots, K), np.int32)
        have = np.zeros(self.num_slots, bool)
        for i in self._active_slots():
            req = self.slot_req[i]
            ctx = [int(t) for t in req.tokens] + self.outputs[req.rid]
            n = len(ctx)
            for g in range(min(self.spec_ngram, n - 1), 0, -1):
                pat = ctx[n - g:]
                hit = -1
                for e in range(n - 2, max(g - 2, n - 2 - 512), -1):
                    if ctx[e - g + 1:e + 1] == pat:
                        hit = e
                        break
                if hit >= 0:        # hit ≤ n-2, so ≥ 1 token follows it
                    cont = ctx[hit + 1:hit + 1 + K]
                    draft[i, :len(cont)] = cont
                    have[i] = True
                    break
        return draft, have

    def _spec_round(self, active: List[int], step_idx: int) -> bool:
        """One speculative round: draft ``spec_k`` tokens per slot, verify
        EVERY slot with one scan-of-decode-steps forward
        (``model.decode_verify`` — the same per-token computation as the
        plain greedy step, so token streams are bit-identical by
        construction), commit each slot's accepted draft prefix plus the
        verify's own next token, and roll each slot's state back to its
        post-commit trajectory entry (``model.spec_rollback``). Greedy
        slots only — the caller falls back to the plain step when any
        active slot samples, or when no slot has a draft (returns False:
        a verify round would then be pure overhead)."""
        draft, have = self._spec_draft()
        if not have.any():
            return False
        K = self.spec_k
        rsid = self._tr.start("spec_round", track="engine", step=step_idx,
                              active=len(active), k=K)
        toks, fins, traj = self._spec_verify(
            self.params, self.cache, self.cur_tok, self.cache_len,
            jnp.asarray(draft))
        toks_np = np.asarray(toks)
        fin_np = np.asarray(fins) if self.guard else None
        cur_np = np.asarray(self.cur_tok[:, 0]).copy()
        idx = np.zeros(self.num_slots, np.int32)
        adv = np.zeros(self.num_slots, np.int32)
        commits: Dict[int, List[int]] = {}
        bad: Dict[int, bool] = {}
        proposed = accepted = 0
        for i in active:
            req = self.slot_req[i]
            a = 0
            while a < K and draft[i, a] == toks_np[i, a]:
                a += 1
            if have[i]:
                proposed += K
                accepted += a
            # commit t_1..t_{a+1}: the a verified draft tokens plus the
            # verify's own next token — truncated at EOS / the slot's
            # remaining budget / (guard on) the first non-finite step
            emit: List[int] = []
            bad[i] = False
            for t in toks_np[i, :a + 1]:
                if fin_np is not None and not fin_np[i, len(emit)]:
                    bad[i] = True
                    break
                emit.append(int(t))
                if int(t) == req.eos or len(emit) >= self.slot_remaining[i]:
                    break
            commits[i] = emit
            if emit:
                idx[i] = len(emit) - 1
                adv[i] = len(emit)
                cur_np[i] = emit[-1]
        # rollback: select each row's post-commit state from the verify's
        # cache trajectory — rejected draft suffixes never touch the cache
        self.cache = self._spec_rollback(traj, jnp.asarray(idx))
        self.cache_len = self.cache_len + jnp.asarray(adv)
        self.cur_tok = jnp.asarray(cur_np)[:, None]
        self.stats.decode_steps += 1
        for inf in self._prefill_pool:
            inf["steps_waited"] += 1
        self._spec_rounds.inc()
        self._spec_proposed.inc(proposed)
        self._spec_accepted.inc(accepted)
        if self._spec_proposed.value:
            self._spec_rate.set(self._spec_accepted.value
                                / self._spec_proposed.value)
        now = self._clock()
        for i in active:
            emit = commits[i]
            if emit:
                # one verify forward produced len(emit) tokens — one ITL
                # sample per slot per round (the latency the client saw)
                self.stats.itl_ms.append((now - self.slot_last_t[i]) * 1e3)
                self.slot_last_t[i] = now
                for t in emit:
                    if self.slot_req[i] is None:
                        break
                    self._finish_token(i, t)
            if bad[i] and self.slot_req[i] is not None:
                rid = self.slot_req[i].rid
                self.slot_req[i] = None
                self.stats.quarantined += 1
                self._tr.instant("quarantined", track=f"req{rid}", rid=rid)
                self._terminate(rid, "failed",
                                f"non-finite verify logits for request "
                                f"{rid} at spec round {step_idx} (slot {i})"
                                f" — quarantined")
        self._expire_active(now)
        self._tr.finish(rsid)
        return True

    # --------------------------------------------------------------- decode
    def _expire_active(self, now: float):
        """Per-step deadline enforcement over the live decode slots."""
        for i in self._active_slots():
            req = self.slot_req[i]
            if self._deadline_over(req, now):
                self.slot_req[i] = None
                self._terminate(req.rid, "expired",
                                f"deadline {req.deadline_ms:.0f}ms exceeded "
                                f"mid-decode (kept "
                                f"{len(self.outputs[req.rid])} tokens)")

    def _decode_step(self):
        """One fused decode+sample step over every slot; per-slot
        termination, inter-token latency accounting, and (guard on) the
        finiteness probe + quarantine + per-step deadline enforcement."""
        active = self._active_slots()
        if not active:
            return
        step_idx = self.stats.decode_steps
        if self.faults is not None and self.faults.kills(step_idx):
            # simulated process death at a step boundary: everything not
            # persisted by the last snapshot() is gone
            raise EngineKilled(f"fault plan killed the engine before "
                               f"decode step {step_idx}")
        sampling = any(self.slot_req[i].temperature > 0.0 for i in active)
        if self.spec_k and not sampling and \
                self._spec_round(active, step_idx):
            return
        dsid = self._tr.start("decode_step", track="engine", step=step_idx,
                              active=len(active))
        fin = None
        if self.guard:
            pv = None if self.faults is None else \
                self.faults.decode_poison(step_idx, self.num_slots)
            poison = self._poison0 if pv is None else \
                jnp.asarray(pv, jnp.float32)
            if sampling:
                tok, _, self.cache, self.slot_keys, finite = \
                    self._step_guarded(
                        self.params, self.cache, self.cur_tok,
                        self.cache_len, self.slot_keys, self.slot_temp,
                        self.slot_topk, self.slot_topp, poison, None)
            else:
                tok, self.cache, finite = self._step_greedy_guarded(
                    self.params, self.cache, self.cur_tok, self.cache_len,
                    poison)
            fin = np.asarray(finite)
        elif sampling:
            tok, _, self.cache, self.slot_keys = self._step(
                self.params, self.cache, self.cur_tok, self.cache_len,
                self.slot_keys, self.slot_temp, self.slot_topk,
                self.slot_topp, None)
        else:
            tok, self.cache = self._step_greedy(
                self.params, self.cache, self.cur_tok, self.cache_len)
        act = np.zeros(self.num_slots, bool)
        act[active] = True
        self.cache_len = self.cache_len + jnp.asarray(act, jnp.int32)
        self.cur_tok = tok[:, None]
        self.stats.decode_steps += 1
        for inf in self._prefill_pool:
            inf["steps_waited"] += 1
        toks = np.asarray(tok)
        now = self._clock()
        for i in active:
            if fin is not None and not fin[i]:
                # quarantine: fail the request with a diagnostic, free the
                # slot (fully overwritten at its next refill), never emit
                # the garbage token — the other slots' rows are untouched
                # by this row's values, so their streams stay bit-identical
                rid = self.slot_req[i].rid
                self.slot_req[i] = None
                self.stats.quarantined += 1
                self._tr.instant("quarantined", track=f"req{rid}", rid=rid)
                self._terminate(rid, "failed",
                                f"non-finite decode logits for request "
                                f"{rid} at step {step_idx} (slot {i}) — "
                                f"quarantined")
                continue
            self.stats.itl_ms.append((now - self.slot_last_t[i]) * 1e3)
            self.slot_last_t[i] = now
            self._finish_token(i, int(toks[i]))
        self._expire_active(now)             # per-step deadline enforcement
        self._tr.finish(dsid)

    # ----------------------------------------------------------------- loop
    def step(self) -> bool:
        """One engine iteration: expire overdue queued requests, land
        finished prefills, refill free slots (up to the in-flight pool
        bound), advance one chunked-prefill round, then one decode step.
        Wall time is split per phase into ``stats.*_ms``. Returns True
        while work remains."""
        ssid = self._tr.start("serve.step", track="engine")
        t0 = time.perf_counter()
        self._expire_queued()
        t1 = time.perf_counter()
        self._land_prefill(block=False)
        self._cache_admit()           # cached lane first: hits skip prefill
        while self._try_refill():     # bounded by max_inflight_prefills
            pass                      # (and by the queue/slots draining)
        if self._prefill_pool and not self._active_slots() \
                and not self._chunk_active():
            self._land_prefill(block=True)    # nothing to overlap with
        t2 = time.perf_counter()
        self._chunk_step()
        t3 = time.perf_counter()
        self._decode_step()
        t4 = time.perf_counter()
        st = self.stats
        st.host_ms += (t1 - t0) * 1e3
        st.prefill_ms += (t2 - t1) * 1e3
        st.chunk_ms += (t3 - t2) * 1e3
        st.decode_ms += (t4 - t3) * 1e3
        self._tr.finish(ssid)
        return bool(self.queue or self._active_slots()
                    or self._prefill_pool or self._chunk_active())

    def run(self) -> Dict[int, List[int]]:
        """Drive until the queue and all slots drain; returns rid → tokens."""
        while self.step():
            pass
        return self.outputs

    # ------------------------------------------------------ crash recovery
    def _device_state(self) -> Dict[str, object]:
        """The engine's complete device-side state as one pytree. For an
        SSM serve engine this is TINY — each slot is a fixed-size
        (conv-tail, recurrent/KV) state plus a few per-slot scalars — which
        is exactly why snapshot/restore is almost free here where an
        attention server would checkpoint a paged KV region."""
        state = {"cache": self.cache, "cache_len": self.cache_len,
                 "cur_tok": self.cur_tok, "slot_keys": self.slot_keys,
                 "slot_temp": self.slot_temp, "slot_topk": self.slot_topk,
                 "slot_topp": self.slot_topp}
        if self.chunk_enabled:
            # a half-consumed long prompt is just chunk_rows more O(1)
            # states — snapshotting mid-chunked-prefill costs nothing extra
            state["chunk_cache"] = self.chunk_cache
            state["chunk_clen"] = self.chunk_clen
        return state

    def _engine_meta(self) -> Dict[str, object]:
        return {"num_slots": self.num_slots, "max_len": self.max_len,
                "prefill_rows": self.prefill_rows,
                "buckets": list(self.buckets),
                "max_segments": self.max_segments,
                "sample_seed": self.sample_seed,
                "chunk_rows": self.chunk_rows if self.chunk_enabled else 0,
                "chunk_size": self.chunk_size}

    @staticmethod
    def _req_meta(req: Request, now: float) -> Dict[str, object]:
        left = None if req.deadline_ms is None else \
            req.deadline_ms - (now - req.submit_t) * 1e3
        return {"rid": int(req.rid),
                "tokens": [int(t) for t in req.tokens],
                "max_new": int(req.max_new), "eos": int(req.eos),
                "temperature": float(req.temperature),
                "top_k": int(req.top_k), "top_p": float(req.top_p),
                "deadline_left_ms": left,
                "prefix_len": None if req.prefix_len is None
                else int(req.prefix_len)}

    @staticmethod
    def _meta_req(m: Dict, now: float) -> Request:
        return Request(m["rid"], np.asarray(m["tokens"], np.int32),
                       m["max_new"], m["eos"], m["temperature"],
                       m["top_k"], m["top_p"], now, m["deadline_left_ms"],
                       m.get("prefix_len"))

    def snapshot(self, manager, step: int = 0,
                 blocking: bool = False) -> int:
        """Persist the whole engine through ``CheckpointManager``: per-slot
        SSM/conv/KV states and sampling keys (device tree), plus queue
        contents, generated-token tails, statuses, and remaining deadline
        budgets (manifest metadata). An in-flight prefill is landed first
        so the snapshot sits at a clean step boundary; deadlines are stored
        as *remaining* budget so wall-clock downtime between crash and
        restore does not silently expire requests. The host copy is taken
        synchronously (the engine may keep stepping immediately); with
        ``blocking=False`` the disk write happens on the manager's
        background thread. Returns the checkpoint step."""
        self._land_prefill(block=True)
        now = self._clock()
        meta = {
            "engine": self._engine_meta(),
            "slots": [None if r is None else
                      dict(self._req_meta(r, now),
                           remaining=int(self.slot_remaining[i]))
                      for i, r in enumerate(self.slot_req)],
            "chunks": [None if r is None else
                       dict(self._req_meta(r, now),
                            off=int(self.chunk_off[i]),
                            slot=int(self.chunk_slot[i]),
                            capture=int(self.chunk_capture[i]))
                       for i, r in enumerate(self.chunk_req)],
            "queue": [self._req_meta(r, now) for r in self.queue],
            "outputs": {str(rid): [int(t) for t in toks]
                        for rid, toks in self.outputs.items()},
            "status": {str(rid): st for rid, st in self.status.items()},
            "errors": {str(rid): e for rid, e in self.errors.items()},
            "next_rid": int(self._next_rid),
        }
        manager.save(step, self._device_state(), meta=meta,
                     blocking=blocking)
        return step

    def restore(self, manager, step: Optional[int] = None) -> int:
        """Load a ``snapshot()`` into this (freshly constructed, idle)
        engine: every request that was decoding resumes from its exact
        per-slot state and completes with the same remaining tokens an
        uninterrupted run would have produced; queued requests are
        re-admitted in order. Restored rids are recorded in
        ``self.resumed`` (their terminal status is still "done" — resumed
        and completed). Returns the checkpoint step restored."""
        if self.queue or self._active_slots() or any(self.slot_pending) \
                or self._prefill_pool or self._chunk_active():
            raise RuntimeError("restore() requires an idle engine — it "
                               "overwrites every slot; use a freshly "
                               "constructed ServeEngine")
        step = step if step is not None else manager.latest_step()
        if step is None:
            raise FileNotFoundError(f"no snapshot to restore in "
                                    f"{manager.dir}")
        meta = manager.read_meta(step)["meta"]
        if meta.get("engine") != self._engine_meta():
            raise ValueError(
                f"snapshot step {step} was taken by an engine configured "
                f"as {meta.get('engine')} but this engine is "
                f"{self._engine_meta()} — slot shapes would not line up")
        got = manager.restore(self._device_state(), step=step)
        self.cache = got["cache"]
        self.cache_len = got["cache_len"]
        self.cur_tok = got["cur_tok"]
        self.slot_keys = got["slot_keys"]
        self.slot_temp = got["slot_temp"]
        self.slot_topk = got["slot_topk"]
        self.slot_topp = got["slot_topp"]
        now = self._clock()
        self.slot_req = [None if m is None else self._meta_req(m, now)
                         for m in meta["slots"]]
        self.slot_remaining = [0 if m is None else int(m["remaining"])
                               for m in meta["slots"]]
        self.slot_pending = [False] * self.num_slots
        self.slot_last_t = [now] * self.num_slots
        if self.chunk_enabled:
            self.chunk_cache = got["chunk_cache"]
            self.chunk_clen = got["chunk_clen"]
        for i, m in enumerate(meta.get("chunks", [])):
            if m is None:
                continue
            # a request mid-chunked-prefill resumes exactly where the slab
            # stream left off; its decode slot is re-reserved so packed
            # admission can't steal it before the handoff
            self.chunk_req[i] = self._meta_req(m, now)
            self.chunk_off[i] = int(m["off"])
            self.chunk_slot[i] = int(m["slot"])
            self.chunk_capture[i] = int(m.get("capture", -1))
            self.slot_pending[int(m["slot"])] = True
        self.queue = collections.deque(
            self._meta_req(m, now) for m in meta["queue"])
        self.outputs = {int(rid): list(toks)
                        for rid, toks in meta["outputs"].items()}
        self.status = {int(rid): st for rid, st in meta["status"].items()}
        self.errors = {int(rid): e for rid, e in meta["errors"].items()}
        self._next_rid = int(meta["next_rid"])
        self.resumed |= {r.rid for r in self.slot_req if r is not None}
        self.resumed |= {r.rid for r in self.chunk_req if r is not None}
        self.resumed |= {r.rid for r in self.queue}
        return step

    # ------------------------------------------------- padded-wave baseline
    def decode_batch(self, prompts, max_new, eos: int = -1,
                     temperature: float = 0.0, top_k: int = 0,
                     top_p: float = 1.0):
        """Padded-wave BASELINE (the paper's padding regime on the serving
        path): ≤num_slots prompts left-padded to the batch max, one prefill,
        synchronous decode. Kept for benchmarking against the continuous
        path — it shares the fused decode+sample step (uniform sampling
        knobs across the wave), so the two modes stay comparable under any
        sampling regime. ``max_new`` is an int or a per-prompt list; slots
        stop accumulating tokens at ``eos`` or their budget (the EOS token
        itself is kept) — but the WAVE only ends when every row is done,
        which is exactly the drain cost continuous batching removes."""
        Bz = self.num_slots
        if len(prompts) > Bz:
            raise ValueError(f"{len(prompts)} prompts > {Bz} slots")
        if self._active_slots() or self.queue or self._prefill_pool \
                or self._chunk_active():
            raise RuntimeError("decode_batch would clobber the live slot "
                               "cache; drain the continuous engine first "
                               "(or use a separate ServeEngine)")
        budgets = [max_new] * len(prompts) if isinstance(max_new, int) \
            else list(max_new)
        lens = [len(p) for p in prompts] + [1] * (Bz - len(prompts))
        maxp = max(lens)
        grid = np.zeros((Bz, maxp), np.int32)
        seg = np.zeros((Bz, maxp), np.int32)
        pos = np.zeros((Bz, maxp), np.int32)
        for b, p in enumerate(prompts):
            grid[b, :len(p)] = p
            seg[b, :len(p)] = 1
            pos[b, :len(p)] = np.arange(len(p))
        seg[len(prompts):, 0] = 1              # idle slots: 1-token dummy
        batch = {"tokens": jnp.asarray(grid), "positions": jnp.asarray(pos),
                 "segment_ids": jnp.asarray(seg)}
        logits, self.cache, lens_j = self._wave_prefill(self.params, batch)
        sampling = temperature > 0.0
        temp = jnp.full((Bz,), temperature, jnp.float32)
        topk = jnp.full((Bz,), int(top_k), jnp.int32)
        topp = jnp.full((Bz,), top_p, jnp.float32)
        keys = B.request_keys(self.sample_seed, np.arange(Bz))
        outs = [[] for _ in range(Bz)]
        done = [b >= len(prompts) for b in range(Bz)]
        if sampling:
            tok, keys = self._sample_flat(logits, keys, temp, topk, topp)
        else:
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        tok = tok[:, None]
        for i in range(max(budgets, default=0)):
            toks = np.asarray(tok[:, 0])
            for b in range(len(prompts)):
                if done[b]:
                    continue
                outs[b].append(int(toks[b]))
                if int(toks[b]) == eos or len(outs[b]) >= budgets[b]:
                    done[b] = True
            if all(done):
                break
            if sampling:
                tok, _, self.cache, keys = self._step(
                    self.params, self.cache, tok, lens_j + i, keys, temp,
                    topk, topp, None)
            else:
                tok, self.cache = self._step_greedy(
                    self.params, self.cache, tok, lens_j + i)
            tok = tok[:, None]
        return outs[:len(prompts)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba-110m")
    ap.add_argument("--tiny", action="store_true",
                    help="shrink the model for a CPU demo")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--policy", default="first_fit",
                    choices=["first_fit", "sequential", "sorted_greedy"])
    ap.add_argument("--no-overlap", action="store_true",
                    help="block on each packed prefill instead of decoding "
                         "through it")
    ap.add_argument("--target-ttft-ms", type=float, default=None,
                    help="admit below the refill threshold once the oldest "
                         "queued request has waited this long")
    ap.add_argument("--max-inflight-prefills", type=int, default=1,
                    help="packed prefills allowed in flight at once "
                         "(the v2 prefill pipeline; 1 = pre-v2 behaviour)")
    ap.add_argument("--bucket-policy", default="smallest_fit",
                    choices=["smallest_fit", "ttft"],
                    help="ttft: upgrade to a bigger prefill bucket when it "
                         "admits more requests and TTFT has slack")
    ap.add_argument("--chunk-size", type=int, default=None,
                    help="chunked-prefill slab length (default: largest "
                         "bucket); prompts beyond the largest bucket are "
                         "consumed in slabs of this size")
    ap.add_argument("--chunk-rows", type=int, default=1,
                    help="long prompts chunk-prefilling concurrently "
                         "(0 disables chunked prefill)")
    ap.add_argument("--max-prompt-len", type=int, default=None,
                    help="hard bound on accepted prompt length "
                         "(default: unbounded — chunked prefill handles "
                         "any length that fits a slot)")
    ap.add_argument("--cache-mb", type=float, default=None,
                    help="enable the prefix StateCache with this byte "
                         "budget (MB); repeated prefixes restore an O(1) "
                         "state instead of re-prefilling")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="give every request the same N-token system "
                         "prefix, declared via submit(prefix_len=N) — the "
                         "prefix-cache demo workload")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decode: draft k tokens per round via "
                         "n-gram prompt copy, verify in one forward "
                         "(greedy slots only; 0 = off)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request submit→completion deadline; overdue "
                         "requests are expired, not served late")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="shed submits once this many requests are queued")
    ap.add_argument("--guard", action="store_true",
                    help="numerical guard rails: per-step finiteness "
                         "probes; non-finite slots are quarantined")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature for every request (0=greedy)")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--scan-tune", default="off",
                    help="off | auto | <cache path>: shape-keyed scan "
                         "autotuning (the engine warms the cache for its "
                         "prefill buckets at startup)")
    ap.add_argument("--obs-trace", default=None, metavar="PATH",
                    help="record request-lifecycle spans and export a "
                         "Chrome trace-event JSON here (open in "
                         "chrome://tracing or ui.perfetto.dev)")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="also capture an XLA profile (jax.profiler, "
                         "TensorBoard format) into this directory")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = dataclasses.replace(cfg, d_model=128, n_layers=4, vocab=512,
                                  dtype="float32", scan_chunk=64)
    if args.scan_tune != "off":
        cfg = dataclasses.replace(cfg, scan_tune=args.scan_tune)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    obs = Obs.on() if args.obs_trace else Obs.off()
    engine = ServeEngine(model, params, args.slots, args.max_len,
                         policy=args.policy, overlap=not args.no_overlap,
                         target_ttft_ms=args.target_ttft_ms,
                         max_queue=args.max_queue, guard=args.guard,
                         max_inflight_prefills=args.max_inflight_prefills,
                         bucket_policy=args.bucket_policy,
                         chunk_size=args.chunk_size,
                         chunk_rows=args.chunk_rows,
                         max_prompt_len=args.max_prompt_len,
                         obs=obs,
                         cache_bytes=None if args.cache_mb is None
                         else int(args.cache_mb * 2**20),
                         spec_k=args.spec_k)

    rng = np.random.default_rng(0)
    lens = rng.integers(5, 40, size=args.requests)
    shared = rng.integers(1, cfg.vocab, size=args.shared_prefix) \
        if args.shared_prefix else None
    t0 = time.perf_counter()
    shed = 0
    with profiler_session(args.profile_dir) as profiling:
        for n in lens:
            toks = rng.integers(1, cfg.vocab, size=int(n))
            if shared is not None:
                toks = np.concatenate([shared, toks])
            try:
                engine.submit(toks, args.new_tokens,
                              temperature=args.temperature,
                              top_k=args.top_k, top_p=args.top_p,
                              deadline_ms=args.deadline_ms,
                              prefix_len=args.shared_prefix or None)
            except ShedError:
                shed += 1
        outs = engine.run()
    dt = time.perf_counter() - t0
    st = engine.stats
    if shed or st.expired or st.quarantined:
        print(f"fault-tolerance: {shed} shed at submit, {st.expired} "
              f"expired, {st.quarantined} quarantined")
    for rid in sorted(outs)[:4]:
        print(f"req{rid}: prompt[{lens[rid]}] -> {outs[rid][:8]}…")
    pct = st.ttft_percentiles()
    print(f"{len(outs)} requests, {st.generated} tokens in {dt:.2f}s "
          f"({st.generated / dt:.1f} tok/s incl. compile) — "
          f"{st.prefills} prefills ({st.midflight_refills} mid-flight, "
          f"{st.overlapped_prefills} overlapped, {st.early_admits} early), "
          f"{st.decode_steps} decode steps, "
          f"{len(st.buckets)} prefill shape(s) compiled")
    if st.chunk_rounds:
        print(f"chunked prefill: {st.chunked_prefills} request(s) over "
              f"{st.chunk_rounds} rounds ({st.chunk_tokens} tokens)")
    if engine.state_cache is not None:
        print(f"prefix cache: {engine.state_cache!r}")
    if args.spec_k:
        print(f"speculative decode: accept rate "
              f"{engine.spec_accept_rate:.2f} over "
              f"{engine._spec_rounds.value} verify rounds")
    print(f"time split: prefill {st.prefill_ms:.0f}ms, chunk "
          f"{st.chunk_ms:.0f}ms, decode {st.decode_ms:.0f}ms, host "
          f"{st.host_ms:.0f}ms")
    ipct = st.itl_percentiles()
    itl = f"{ipct['p50']:.2f}ms" if ipct else "n/a"
    print(f"TTFT p50 {pct.get('p50', 0):.1f}ms p95 {pct.get('p95', 0):.1f}ms "
          f"over {len(st.ttft_ms)} requests; "
          f"ITL p50 {itl} over {len(st.itl_ms)} decode tokens")
    if args.obs_trace:
        obs.export(args.obs_trace)
        print(f"obs: wrote {len(obs.tracer.chrome_events())} trace events "
              f"to {args.obs_trace} (open in chrome://tracing or "
              f"ui.perfetto.dev)")
    if args.profile_dir and profiling:
        print(f"obs: XLA profile captured under {args.profile_dir}")


if __name__ == "__main__":
    main()
