"""Continuous-batching serve engine: packed prefill → per-slot decode.

PackMamba's packing is applied to the SERVING path: instead of left-padding
every prompt to the batch max and decoding in synchronous waves (the padded
baseline the paper shows wasting 2-3×), prompts are packed back-to-back into
shape-bucketed prefill buffers (core/packing.py policies), ONE forward
harvests every segment's final (conv-tail, recurrent/KV) state at its
segment end (``model.prefill_packed``), and the states are scattered into
per-request decode slots (``model.scatter_into_cache``). Decode then runs
one fused step per token over all slots; a slot that hits EOS or its token
budget is released and refilled from the admission queue *mid-flight* —
the decode batch stays full without draining a wave.

Compile discipline: decode is one fixed shape; prefill shapes are bounded
by the bucket list (rows × bucket-capacity), NOT by the number of distinct
prompt lengths — ``stats.buckets`` counts the shapes actually compiled.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba-110m --tiny \
      --slots 8 --requests 24 --new-tokens 16
"""
import argparse
import collections
import dataclasses
import functools
import time
from typing import Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import packing
from repro.models.lm import build_model


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray         # 1-D int32 prompt
    max_new: int
    eos: int = -1              # -1 = never matches (greedy runs to budget)


@dataclasses.dataclass
class EngineStats:
    prefills: int = 0              # packed prefill rounds issued
    prefill_tokens: int = 0        # real prompt tokens prefilled
    decode_steps: int = 0          # fused all-slot decode steps
    generated: int = 0             # tokens handed back to requests
    midflight_refills: int = 0     # prefills issued while slots were decoding
    buckets: Optional[set] = None  # distinct (rows, L) prefill shapes used

    def __post_init__(self):
        if self.buckets is None:
            self.buckets = set()


class ServeEngine:
    """Slot-based continuous batching with a packed-prefill admission path.

    * ``submit()`` enqueues requests; ``run()`` drives admission + decode
      until everything drains (``step()`` exposes one iteration for custom
      loops).
    * Admission packs queued prompts (FIFO, ``policy``) into a
      (prefill_rows, bucket) buffer — the smallest bucket that fits the
      head-of-line prompt — capped by free slots and ``max_segments`` per
      row, then scatters the harvested per-segment states into the free
      slots. Requests never wait for a wave boundary.
    * The decode batch is one jitted ``decode_step`` over ALL slots; idle
      slots ride along (their state is fully overwritten at refill, so the
      garbage they accumulate is harmless and the shape never changes).
    * Per-slot termination: a slot is released the moment its request emits
      ``eos`` or exhausts ``max_new`` — the EOS token itself is kept.
    """

    def __init__(self, model, params, num_slots: int, max_len: int, *,
                 prefill_rows: int = 2, buckets=(64, 128, 256),
                 max_segments: int = 4, policy: str = "first_fit",
                 eos: int = -1, refill_threshold: Optional[int] = None):
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.prefill_rows = prefill_rows
        self.buckets = tuple(sorted(buckets))
        self.max_segments = max_segments
        self.policy = policy
        self.eos = eos
        # A decode step costs the same whether a slot is active or idle
        # (fixed batch), so single-slot refills waste a whole prefill
        # forward to activate one slot. Batch admissions: only refill once
        # this many slots are free (or nothing is decoding at all).
        self.refill_threshold = max(1, num_slots // 2) \
            if refill_threshold is None else refill_threshold

        cfg = getattr(model, "cfg", None)
        if cfg is not None and getattr(cfg, "scan_tune", "off") != "off":
            # warm the scan autotuning cache for every prefill shape this
            # engine can compile — (prefill_rows, bucket) — so the packed
            # forwards resolve measured schedule winners at trace time
            from repro.tune import warm_for_config
            warm_for_config(cfg, [(prefill_rows, b) for b in self.buckets])

        self.cache = model.init_cache(num_slots, max_len)
        self.cache_len = jnp.zeros((num_slots,), jnp.int32)
        self.cur_tok = jnp.zeros((num_slots, 1), jnp.int32)
        self._step = jax.jit(model.decode_step)
        self._scatter = jax.jit(model.scatter_into_cache)
        self._prefill = jax.jit(
            functools.partial(model.prefill_packed, max_len=max_len))
        self._wave_prefill = jax.jit(
            functools.partial(model.prefill, max_len=max_len))

        self.queue: collections.deque = collections.deque()
        self.slot_req: List[Optional[Request]] = [None] * num_slots
        self.slot_remaining = [0] * num_slots
        self.outputs: Dict[int, List[int]] = {}
        self.stats = EngineStats()
        self._next_rid = 0

    # ------------------------------------------------------------ admission
    def submit(self, tokens, max_new: int, eos: Optional[int] = None) -> int:
        tokens = np.asarray(tokens, np.int32)
        if len(tokens) == 0:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        if len(tokens) > self.buckets[-1]:
            raise ValueError(f"prompt length {len(tokens)} exceeds largest "
                             f"prefill bucket {self.buckets[-1]}")
        if len(tokens) + max_new > self.max_len:
            raise ValueError(f"prompt {len(tokens)} + max_new {max_new} "
                             f"exceeds slot capacity {self.max_len}")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, tokens, max_new,
                                  self.eos if eos is None else eos))
        self.outputs[rid] = []
        return rid

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    def _finish_token(self, slot: int, tok: int):
        """Record one generated token; release the slot on EOS / budget."""
        req = self.slot_req[slot]
        self.outputs[req.rid].append(tok)
        self.stats.generated += 1
        self.slot_remaining[slot] -= 1
        if tok == req.eos or self.slot_remaining[slot] <= 0:
            self.slot_req[slot] = None

    def _try_refill(self) -> bool:
        """Admit queued prompts into free slots via one packed prefill.

        Bucket choice is head-of-line: the smallest bucket holding the
        oldest prompt; younger prompts join only if they fit the same
        bucket (FIFO within a round, no starvation across rounds)."""
        free = self._free_slots()
        if not free or not self.queue:
            return False
        if len(free) < self.refill_threshold and self._active_slots():
            return False
        head = self.queue[0]
        L = min(b for b in self.buckets if b >= len(head.tokens))
        admitted: List[Request] = []
        lens: List[int] = []
        for req in list(self.queue):
            if len(req.tokens) > L or len(admitted) == len(free):
                break
            plan = packing.plan_packing(lens + [len(req.tokens)], L,
                                        self.policy)
            if len(plan) > self.prefill_rows or \
                    any(len(row) > self.max_segments for row in plan):
                break
            admitted.append(req)
            lens.append(len(req.tokens))
        if not admitted:
            return False
        if self._active_slots():
            self.stats.midflight_refills += 1
        for _ in admitted:          # admitted is always a queue prefix
            self.queue.popleft()
        pb = packing.pack([r.tokens for r in admitted], L,
                          policy=self.policy, num_rows=self.prefill_rows)
        ends = packing.segment_ends(pb, self.max_segments)
        batch = {"tokens": pb.tokens, "positions": pb.positions,
                 "segment_ids": pb.segment_ids}
        logits, states, seg_lens = self._prefill(self.params, batch,
                                                 ends=jnp.asarray(ends))
        # (row, seg) → admitted request → slot; fixed-size scatter with the
        # num_slots sentinel dropping unused entries (one compile per bucket)
        K = self.prefill_rows * self.max_segments
        src = np.zeros(K, np.int32)
        dst = np.full(K, self.num_slots, np.int32)
        slot_of = {}
        for r, ids in enumerate(pb.seq_ids):
            for s, qi in enumerate(ids):
                slot = free[qi]
                k = len(slot_of)
                src[k] = r * self.max_segments + s
                dst[k] = slot
                slot_of[qi] = (slot, r, s)
        src_j, dst_j = jnp.asarray(src), jnp.asarray(dst)
        self.cache = self._scatter(self.cache, states, src_j, dst_j)
        flat_lens = seg_lens.reshape(-1)
        flat_tok = jnp.argmax(logits, -1).reshape(-1).astype(jnp.int32)
        self.cache_len = self.cache_len.at[dst_j].set(
            flat_lens[src_j], mode="drop")
        self.cur_tok = self.cur_tok.at[dst_j].set(
            flat_tok[src_j][:, None], mode="drop")
        # host bookkeeping + the prefill's own greedy token
        first = np.asarray(flat_tok)
        for qi, req in enumerate(admitted):
            slot, r, s = slot_of[qi]
            self.slot_req[slot] = req
            self.slot_remaining[slot] = req.max_new
            self._finish_token(slot, int(first[r * self.max_segments + s]))
        self.stats.prefills += 1
        self.stats.prefill_tokens += sum(lens)
        self.stats.buckets.add((self.prefill_rows, L))
        return True

    # --------------------------------------------------------------- decode
    def _decode_step(self):
        """One fused greedy step over every slot; per-slot termination."""
        active = self._active_slots()
        if not active:
            return
        logits, self.cache = self._step(self.params, self.cache,
                                        self.cur_tok, self.cache_len, None)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)       # (num_slots,)
        act = np.zeros(self.num_slots, bool)
        act[active] = True
        self.cache_len = self.cache_len + jnp.asarray(act, jnp.int32)
        self.cur_tok = nxt[:, None]
        self.stats.decode_steps += 1
        toks = np.asarray(nxt)
        for i in active:
            self._finish_token(i, int(toks[i]))

    # ----------------------------------------------------------------- loop
    def step(self) -> bool:
        """One engine iteration: refill free slots, then one decode step.
        Returns True while work remains."""
        self._try_refill()
        self._decode_step()
        return bool(self.queue or self._active_slots())

    def run(self) -> Dict[int, List[int]]:
        """Drive until the queue and all slots drain; returns rid → tokens."""
        while self.step():
            pass
        return self.outputs

    # ------------------------------------------------- padded-wave baseline
    def decode_batch(self, prompts, max_new, eos: int = -1):
        """Padded-wave BASELINE (the paper's padding regime on the serving
        path): ≤num_slots prompts left-padded to the batch max, one prefill,
        synchronous decode. Kept for benchmarking against the continuous
        path. ``max_new`` is an int or a per-prompt list; slots stop
        accumulating tokens at ``eos`` or their budget (the EOS token itself
        is kept) — but the WAVE only ends when every row is done, which is
        exactly the drain cost continuous batching removes."""
        B = self.num_slots
        if len(prompts) > B:
            raise ValueError(f"{len(prompts)} prompts > {B} slots")
        if self._active_slots() or self.queue:
            raise RuntimeError("decode_batch would clobber the live slot "
                               "cache; drain the continuous engine first "
                               "(or use a separate ServeEngine)")
        budgets = [max_new] * len(prompts) if isinstance(max_new, int) \
            else list(max_new)
        lens = [len(p) for p in prompts] + [1] * (B - len(prompts))
        maxp = max(lens)
        grid = np.zeros((B, maxp), np.int32)
        seg = np.zeros((B, maxp), np.int32)
        pos = np.zeros((B, maxp), np.int32)
        for b, p in enumerate(prompts):
            grid[b, :len(p)] = p
            seg[b, :len(p)] = 1
            pos[b, :len(p)] = np.arange(len(p))
        seg[len(prompts):, 0] = 1              # idle slots: 1-token dummy
        batch = {"tokens": jnp.asarray(grid), "positions": jnp.asarray(pos),
                 "segment_ids": jnp.asarray(seg)}
        logits, self.cache, lens_j = self._wave_prefill(self.params, batch)
        outs = [[] for _ in range(B)]
        done = [b >= len(prompts) for b in range(B)]
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for i in range(max(budgets, default=0)):
            toks = np.asarray(tok[:, 0])
            for b in range(len(prompts)):
                if done[b]:
                    continue
                outs[b].append(int(toks[b]))
                if int(toks[b]) == eos or len(outs[b]) >= budgets[b]:
                    done[b] = True
            if all(done):
                break
            logits, self.cache = self._step(self.params, self.cache, tok,
                                            lens_j + i, None)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        return outs[:len(prompts)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba-110m")
    ap.add_argument("--tiny", action="store_true",
                    help="shrink the model for a CPU demo")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--policy", default="first_fit",
                    choices=["first_fit", "sequential", "sorted_greedy"])
    ap.add_argument("--scan-tune", default="off",
                    help="off | auto | <cache path>: shape-keyed scan "
                         "autotuning (the engine warms the cache for its "
                         "prefill buckets at startup)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = dataclasses.replace(cfg, d_model=128, n_layers=4, vocab=512,
                                  dtype="float32", scan_chunk=64)
    if args.scan_tune != "off":
        cfg = dataclasses.replace(cfg, scan_tune=args.scan_tune)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, args.slots, args.max_len,
                         policy=args.policy)

    rng = np.random.default_rng(0)
    lens = rng.integers(5, 40, size=args.requests)
    t0 = time.perf_counter()
    for n in lens:
        engine.submit(rng.integers(1, cfg.vocab, size=int(n)), # noqa: E501
                      args.new_tokens)
    outs = engine.run()
    dt = time.perf_counter() - t0
    st = engine.stats
    for rid in sorted(outs)[:4]:
        print(f"req{rid}: prompt[{lens[rid]}] -> {outs[rid][:8]}…")
    print(f"{len(outs)} requests, {st.generated} tokens in {dt:.2f}s "
          f"({st.generated / dt:.1f} tok/s incl. compile) — "
          f"{st.prefills} prefills ({st.midflight_refills} mid-flight), "
          f"{st.decode_steps} decode steps, "
          f"{len(st.buckets)} prefill shape(s) compiled")


if __name__ == "__main__":
    main()
