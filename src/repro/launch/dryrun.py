"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell and extract memory/cost/collective analysis for the roofline report.

MUST set XLA_FLAGS before any other import (jax locks the device count on
first init); smoke tests and benches must NOT import this module.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch xlstm-125m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --list
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import all_names
from repro.configs.base import get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, build_cell, cell_supported
from repro.roofline.analysis import (Roofline, collective_bytes,
                                     model_flops_per_step)

ASSIGNED = [
    "recurrentgemma-2b", "stablelm-1.6b", "deepseek-coder-33b", "gemma-7b",
    "deepseek-67b", "hubert-xlarge", "mixtral-8x22b", "moonshot-v1-16b-a3b",
    "qwen2-vl-2b", "xlstm-125m",
]
PAPER = ["mamba-110m", "mamba-1.4b", "mamba-2.8b", "mamba2-370m"]


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             overrides=None) -> dict:
    cfg = get_config(arch)
    accum = 1
    if overrides:
        overrides = dict(overrides)
        accum = overrides.pop("__accum__", 1)
        cfg = dataclasses.replace(cfg, **overrides)
    ok, why = cell_supported(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
           "status": "skip", "reason": why}
    if not ok:
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = mesh.devices.size
        with mesh:
            kw = {"accum": accum} if SHAPES[shape]["kind"] == "train" else {}
            cell = build_cell(cfg, mesh, shape, **kw)
            jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                             out_shardings=cell.out_shardings)
            lowered = jitted.lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, list):   # pinned JAX: one dict per device
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        # while-aware static analysis: cost_analysis counts scan bodies
        # once, not × trip count (see roofline/hlo_static.py)
        from repro.roofline.hlo_static import analyze as hlo_analyze
        stat = hlo_analyze(hlo)
        coll = dict(stat["collectives_by_op"], total=stat["collective_bytes"])
        flops_dev = float(stat["flops"])
        bytes_dev = float(stat["traffic_bytes"])
        s = SHAPES[shape]
        mf = model_flops_per_step(cfg, s["kind"], s["batch"], s["seq"])
        rl = Roofline(flops=flops_dev * chips, hbm_bytes=bytes_dev * chips,
                      coll_bytes=coll["total"] * chips, chips=chips,
                      model_flops=mf)
        mem_rec = {}
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                mem_rec[attr] = int(v)
        rec.update(
            status="ok",
            fn=cell.meta["fn_name"],
            chips=chips,
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            flops_per_device=flops_dev,
            bytes_per_device=bytes_dev,
            collectives={k: v for k, v in coll.items()},
            traffic_by_op=stat["traffic_by_op"],
            cost_analysis_raw={"flops": float(cost.get("flops", 0.0)),
                               "bytes": float(cost.get("bytes accessed",
                                                       0.0))},
            memory=mem_rec,
            roofline=rl.to_dict(),
            hlo_lines=hlo.count("\n"),
        )
    except Exception as e:                     # noqa: BLE001 — sweep robust
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:],
                   elapsed_s=round(time.time() - t0, 1))
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fn = f"{arch}__{shape}__{mesh_name}.json"
        with open(os.path.join(out_dir, fn), "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=None)
    ap.add_argument("--shape", nargs="*", default=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="all assigned archs (+ paper mamba sizes)")
    ap.add_argument("--paper", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = args.arch or (ASSIGNED + (PAPER if args.paper else [])
                          if args.all or args.arch is None else [])
    if args.list:
        for a in archs:
            for s in args.shape:
                ok, why = cell_supported(get_config(a), s)
                print(f"{a:24s} {s:12s} {'RUN' if ok else 'SKIP: ' + why}")
        return
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for a in archs:
        for s in args.shape:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                path = os.path.join(args.out, f"{a}__{s}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip-existing] {a} {s} {mesh_name}")
                    continue
                rec = run_cell(a, s, mp, args.out)
                if rec["status"] == "ok":
                    rl = rec["roofline"]
                    print(f"[ok] {a} {s} {mesh_name}: "
                          f"compile {rec['compile_s']}s "
                          f"dom={rl['dominant']} "
                          f"frac={rl['roofline_fraction']:.3f} "
                          f"mem={rec['memory'].get('temp_size_in_bytes', 0) / 2**30:.2f}GiB")
                elif rec["status"] == "skip":
                    print(f"[skip] {a} {s} {mesh_name}: {rec['reason']}")
                else:
                    print(f"[ERR] {a} {s} {mesh_name}: {rec['error']}")


if __name__ == "__main__":
    main()
