"""Performance hillclimbing driver (§Perf): re-lower + re-analyze chosen
cells under named optimization variants; print before/after per roofline
term.

Usage:
  PYTHONPATH=src python -m repro.launch.perf --cell recurrentgemma-2b:train_4k \
      --variants baseline act_sp
  PYTHONPATH=src python -m repro.launch.perf --all-hillclimb
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse
import json

from repro.launch.dryrun import run_cell

# named optimization variants → ArchConfig overrides
# (__accum__ is a builder knob: gradient-accumulation microbatches)
VARIANTS = {
    "baseline": {},
    # It-1: Megatron-SP-style sequence sharding of the residual carried (and
    # saved-for-backward) between layer units
    "act_sp": {"act_pspec": ("auto",)},
    # It-1b: channel (d_model) sharding of the carry — for recurrent stacks
    # whose scans are channel-parallel but sequential in L
    "act_dp": {"act_pspec": ("auto_d",)},
    # It-2: fold y=C·h into a single sequential scan — never materialize the
    # (B,L,D,N) trajectories (pure-XLA analogue of the Pallas kernel).
    # REFUTED: scan autodiff stores per-step residuals (see EXPERIMENTS.md)
    "fused_scan": {"scan_impl": "fused_seq"},
    "act_sp+fused_scan": {"act_pspec": ("auto",), "scan_impl": "fused_seq"},
    # It-8: scan schedules. The SSD-style block-parallel schedule
    # (scan_impl="blocked", see core/scan.py) is now the BASELINE hot path:
    # no (B,L,D,N) materialization, y=C·h fused per chunk, checkpointed
    # chunk bodies. "scan_chunked" re-lowers the pre-It-8 default for
    # before/after regression tracking.
    "scan_chunked": {"scan_impl": "chunked"},
    "act_dp+scan_chunked": {"act_pspec": ("auto_d",), "scan_impl": "chunked"},
    "scan_blocked+bf16": {"scan_impl": "blocked", "scan_dtype": "bfloat16"},
    # It-3: bf16 recurrence compute — halves the scan's HBM traffic
    "scan_bf16": {"scan_dtype": "bfloat16"},
    "act_dp+scan_bf16": {"act_pspec": ("auto_d",),
                         "scan_dtype": "bfloat16"},
    # It-4: smaller scan chunks — fewer associative-scan levels in flight
    "chunk128": {"scan_chunk": 128},
    "act_sp+chunk128": {"act_pspec": ("auto",), "scan_chunk": 128},
    # It-5: gradient-accumulation microbatching — divides live activations
    "act_sp+accum4": {"act_pspec": ("auto",), "__accum__": 4},
    "act_sp+accum8": {"act_pspec": ("auto",), "__accum__": 8},
    # It-7: save dot outputs in remat — spend reclaimed HBM on less
    # recompute traffic
    "act_sp+accum4+remat_dots": {"act_pspec": ("auto",), "__accum__": 4,
                                 "remat": "dots"},
    "act_dp+accum2+remat_dots": {"act_pspec": ("auto_d",), "__accum__": 2,
                                 "remat": "dots"},
    # It-6: token-chunked MoE dispatch — bounds (E, C, d) buffer memory
    "act_sp+accum4+moe8k": {"act_pspec": ("auto",), "__accum__": 4,
                            "moe_token_chunk": 8192},
    "act_sp+accum2+moe8k": {"act_pspec": ("auto",), "__accum__": 2,
                            "moe_token_chunk": 8192},
    "act_sp+moe8k": {"act_pspec": ("auto",), "moe_token_chunk": 8192},
    # It-10: scan knobs read from the measured tuning cache
    # (TUNE_CACHE.json, see repro/tune) instead of hand-derived combos —
    # resolved per (arch, shape) at run time by tuned_overrides(); an empty
    # or stale cache degrades to baseline
    "tuned": {},
}


def tuned_overrides(arch: str, shape: str) -> dict:
    """ArchConfig overrides for the ``tuned`` variant: the tuning cache's
    measured winner for this arch's scan op at this cell's (batch, seq)."""
    from repro.configs.base import get_config
    from repro.launch.shapes import SHAPES as _S
    from repro.tune import tuned_config_overrides
    s = _S[shape]
    ov = tuned_config_overrides(get_config(arch), B=s["batch"], L=s["seq"])
    if not ov:
        print(f"  (tuned: no cache entry for {arch}:{shape} — baseline)")
    return ov

# the three hillclimbed cells (DESIGN.md §Perf) + the paper-faithful extra
HILLCLIMB = [
    ("recurrentgemma-2b", "train_4k",
     ["act_dp", "act_dp+scan_bf16"]),
    ("deepseek-67b", "train_4k", ["act_sp+accum4", "act_sp+accum8"]),
    ("gemma-7b", "prefill_32k", ["act_sp"]),
    ("mamba-2.8b", "train_4k",
     ["act_dp", "scan_bf16", "act_dp+scan_bf16", "scan_chunked",
      "scan_blocked+bf16", "tuned"]),
    # It-9: head-structured (Mamba-2/SSD) variant at matched packed shapes —
    # tracks the per-head vs per-channel schedule gap across PRs
    ("mamba2-370m", "train_4k",
     ["baseline", "act_dp", "scan_bf16", "act_dp+scan_bf16", "tuned"]),
]


def run_variant(arch, shape, variant, out="experiments/perf",
                multi_pod=False):
    label = variant
    if variant == "tuned":
        overrides = tuned_overrides(arch, shape)
        if not overrides:
            # don't let a baseline-identical row masquerade as tuned in the
            # persisted perf series — the miss is visible in the label
            label = "tuned:miss(baseline)"
    else:
        overrides = VARIANTS[variant]
    rec = run_cell(arch, shape, multi_pod, out_dir=None, overrides=overrides)
    rec["variant"] = label
    rec["overrides"] = {k: v for k, v in overrides.items()
                        if k != "__accum__"}      # audit what was applied
    os.makedirs(out, exist_ok=True)
    fn = f"{arch}__{shape}__{variant.replace('+', '_')}.json"
    with open(os.path.join(out, fn), "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def _report(rec):
    if rec["status"] != "ok":
        print(f"  {rec.get('variant')}: {rec['status']} "
              f"{rec.get('error', '')[:160]}")
        return
    rl = rec["roofline"]
    mem = rec["memory"].get("temp_size_in_bytes", 0) / 2 ** 30
    print(f"  {rec['variant']:>20}: comp {rl['t_compute_s'] * 1e3:9.1f}ms | "
          f"mem {rl['t_memory_s'] * 1e3:9.1f}ms | "
          f"coll {rl['t_collective_s'] * 1e3:9.1f}ms | "
          f"dom={rl['dominant']:<10} frac={rl['roofline_fraction']:.4f} | "
          f"tempHBM {mem:6.2f}GiB")


RECURRENT = {"mamba-110m", "mamba-1.4b", "mamba-2.8b", "mamba2-370m",
             "recurrentgemma-2b", "xlstm-125m"}
BIG = {"deepseek-67b", "deepseek-coder-33b", "mixtral-8x22b"}


def opt_variant(arch: str, shape: str) -> str:
    """Per-family best-known settings (EXPERIMENTS.md §Perf iterations)."""
    from repro.launch.shapes import SHAPES as _S
    kind = _S[shape]["kind"]
    rec = arch in RECURRENT
    moe = arch in ("mixtral-8x22b", "moonshot-v1-16b-a3b")
    if kind == "decode":
        return "baseline"                      # no carries; caches dominate
    act = "act_dp" if rec else "act_sp"
    if kind == "train":
        if arch == "mixtral-8x22b":
            return "act_sp+accum8"             # fits w/o expert re-reads
        if arch == "moonshot-v1-16b-a3b":
            return "act_sp+accum2+moe8k"       # 64-expert dispatch chunked
        if arch in BIG:
            return f"{act}+accum4"
        return f"{act}+accum2"
    if moe:
        return "act_sp+moe8k"                  # prefill
    if arch == "hubert-xlarge":
        return "baseline"       # encoder prefill: act_sp measured slightly
        # worse (0.0079→0.0067) and baseline already fits — keep baseline
    return act                                 # prefill


def opt_sweep(out="experiments/dryrun_opt", multi_pod=False):
    from repro.launch.dryrun import ASSIGNED, PAPER
    from repro.launch.shapes import SHAPES as _S
    for arch in ASSIGNED + PAPER:
        for shape in _S:
            v = opt_variant(arch, shape)
            if v not in VARIANTS:
                VARIANTS[v] = {}
                base = "act_dp" if "act_dp" in v else "act_sp"
                VARIANTS[v].update(VARIANTS[base])
                if "accum4" in v:
                    VARIANTS[v]["__accum__"] = 4
                elif "accum2" in v:
                    VARIANTS[v]["__accum__"] = 2
            rec = run_variant(arch, shape, v, out=out, multi_pod=multi_pod)
            print(f"{arch} {shape} [{v}]", end=" ")
            _report(rec)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", help="arch:shape")
    ap.add_argument("--variants", nargs="*", default=["baseline", "act_sp"])
    ap.add_argument("--all-hillclimb", action="store_true")
    ap.add_argument("--opt-sweep", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    if args.opt_sweep:
        opt_sweep(multi_pod=args.multi_pod)
        return
    plan = []
    if args.all_hillclimb:
        plan = HILLCLIMB
    elif args.cell:
        arch, shape = args.cell.split(":")
        plan = [(arch, shape, args.variants)]
    for arch, shape, variants in plan:
        print(f"== {arch} × {shape} ==")
        for v in variants:
            rec = run_variant(arch, shape, v, out=args.out)
            _report(rec)


if __name__ == "__main__":
    main()
