"""Background-prefetching wrapper for step-keyed dataloaders.

Host-side packing (lognormal draws -> plan_packing -> PackedBatch) costs
real milliseconds per step; PrefetchLoader overlaps it with the device
step by computing the next ``depth`` batches on a worker thread while the
current one trains.

Determinism contract: the wrapped loader's ``batch(step)`` must be a pure
function of ``step`` (PackingLoader's is — every batch derives from
(seed, step) alone). The wrapper only *memoizes* those calls; it never
reorders or consumes a stream, so ``batch(step)`` is bit-identical to the
synchronous loader at every step and restart replay (checkpoint at step k,
re-create the loader, resume at k) is preserved by construction.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, Optional


class PrefetchLoader:
    """Wrap any loader with ``batch(step)`` (and optionally ``stats``).

    ``batch(step)`` returns the wrapped loader's result for that step,
    served from the prefetch buffer when the background thread got there
    first, computed synchronously otherwise — then schedules steps
    ``step+1 .. step+depth`` so the buffer stays ahead of a sequentially
    advancing training loop.
    """

    def __init__(self, loader: Any, depth: int = 2, obs=None):
        if depth < 1:
            raise ValueError(f"prefetch depth must be >= 1, got {depth}")
        self.loader = loader
        self.depth = depth
        self._lock = threading.Lock()
        self._futures: Dict[int, Future] = {}
        # one worker: the wrapped loader is not assumed thread-safe, and a
        # single thread already fully overlaps host packing with the device
        self._pool = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="prefetch")
        # hit/miss/wait metering lives in data.* registry metrics (the
        # instance attributes below are views over them); pass the
        # Trainer's Obs to share one registry, or let it stand alone
        if obs is None:
            from repro.obs import Obs
            obs = Obs.off()
        self.obs = obs
        m = obs.metrics
        self._c_hits = m.counter(
            "data.prefetch_hits",
            help="batches served from the prefetch buffer")
        self._c_misses = m.counter(
            "data.prefetch_misses",
            help="batches computed on the caller's thread")
        self._g_wait = m.gauge(
            "data.prefetch_wait_ms",
            help="cumulative ms the consumer blocked waiting for a batch")

    # consumer-visible counters (data.* registry metrics are the storage)
    @property
    def hits(self) -> int:
        return self._c_hits.value

    @property
    def misses(self) -> int:
        return self._c_misses.value

    @property
    def wait_ms(self) -> float:
        """Cumulative time ``batch()`` spent blocked — on a future still
        being computed (hit, but the worker wasn't done) or on synchronous
        computation (miss). Near-zero waits mean the worker keeps up;
        growing waits mean the loop is data-starved."""
        return self._g_wait.value

    def _schedule(self, step: int) -> None:
        with self._lock:
            if step not in self._futures:
                self._futures[step] = self._pool.submit(
                    self.loader.batch, step)

    def batch(self, step: int):
        with self._lock:
            fut = self._futures.pop(step, None)
        # keep the buffer ahead before blocking on the current step
        for k in range(step + 1, step + 1 + self.depth):
            self._schedule(k)
        t0 = time.perf_counter()
        if fut is not None:
            self._c_hits.inc()
            out = fut.result()
        else:
            self._c_misses.inc()
            out = self.loader.batch(step)
        # blocked time either way: a hit whose future is still running
        # blocks in result(), a miss blocks for the whole computation
        self._g_wait.add((time.perf_counter() - t0) * 1e3)
        # drop stale entries (restarts / non-monotonic access): anything
        # at or before `step` can never be requested by a forward-moving
        # loop again, and re-scheduling is cheap if it is
        with self._lock:
            stale = [k for k in self._futures if k <= step]
            for k in stale:
                self._futures.pop(k)
        return out

    def stats(self, step: int) -> Dict[str, Any]:
        out = dict(self.loader.stats(step)) if hasattr(self.loader, "stats") \
            else {}
        out["prefetch_hits"] = self.hits
        out["prefetch_misses"] = self.misses
        out["prefetch_wait_ms"] = self.wait_ms
        return out

    def __getattr__(self, name):
        # transparent passthrough (cfg, corpus, ...) for drop-in use
        return getattr(self.loader, name)

    def close(self) -> None:
        self._pool.shutdown(wait=False, cancel_futures=True)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
