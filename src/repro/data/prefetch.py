"""Background-prefetching wrapper for step-keyed dataloaders.

Host-side packing (lognormal draws -> plan_packing -> PackedBatch) costs
real milliseconds per step; PrefetchLoader overlaps it with the device
step by computing the next ``depth`` batches on a worker thread while the
current one trains.

Determinism contract: the wrapped loader's ``batch(step)`` must be a pure
function of ``step`` (PackingLoader's is — every batch derives from
(seed, step) alone). The wrapper only *memoizes* those calls; it never
reorders or consumes a stream, so ``batch(step)`` is bit-identical to the
synchronous loader at every step and restart replay (checkpoint at step k,
re-create the loader, resume at k) is preserved by construction.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict


class PrefetchLoader:
    """Wrap any loader with ``batch(step)`` (and optionally ``stats``).

    ``batch(step)`` returns the wrapped loader's result for that step,
    served from the prefetch buffer when the background thread got there
    first, computed synchronously otherwise — then schedules steps
    ``step+1 .. step+depth`` so the buffer stays ahead of a sequentially
    advancing training loop.
    """

    def __init__(self, loader: Any, depth: int = 2):
        if depth < 1:
            raise ValueError(f"prefetch depth must be >= 1, got {depth}")
        self.loader = loader
        self.depth = depth
        self._lock = threading.Lock()
        self._futures: Dict[int, Future] = {}
        # one worker: the wrapped loader is not assumed thread-safe, and a
        # single thread already fully overlaps host packing with the device
        self._pool = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="prefetch")
        self.hits = 0      # batches served from the prefetch buffer
        self.misses = 0    # batches computed on the caller's thread

    def _schedule(self, step: int) -> None:
        with self._lock:
            if step not in self._futures:
                self._futures[step] = self._pool.submit(
                    self.loader.batch, step)

    def batch(self, step: int):
        with self._lock:
            fut = self._futures.pop(step, None)
        # keep the buffer ahead before blocking on the current step
        for k in range(step + 1, step + 1 + self.depth):
            self._schedule(k)
        if fut is not None:
            self.hits += 1
            out = fut.result()
        else:
            self.misses += 1
            out = self.loader.batch(step)
        # drop stale entries (restarts / non-monotonic access): anything
        # at or before `step` can never be requested by a forward-moving
        # loop again, and re-scheduling is cheap if it is
        with self._lock:
            stale = [k for k in self._futures if k <= step]
            for k in stale:
                self._futures.pop(k)
        return out

    def stats(self, step: int) -> Dict[str, Any]:
        out = dict(self.loader.stats(step)) if hasattr(self.loader, "stats") \
            else {}
        out["prefetch_hits"] = self.hits
        out["prefetch_misses"] = self.misses
        return out

    def __getattr__(self, name):
        # transparent passthrough (cfg, corpus, ...) for drop-in use
        return getattr(self.loader, name)

    def close(self) -> None:
        self._pool.shutdown(wait=False, cancel_futures=True)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
