"""Synthetic variable-length corpus matching the paper's data statistics.

Paper §4: "sequences ranging in length from 57 to 2048, with an average
length of 646" (InternLM-derived). We sample lengths from a clipped
lognormal calibrated to that mean and range, and fill tokens with a
learnable per-sequence process (affine stride mod vocab) so integration
tests can assert loss decrease.

Everything is *stateless and step-indexed*: ``batch_lengths(step)`` and
``sequence(seq_id)`` are pure functions of (seed, step/seq_id), which is
what makes checkpoint-resume deterministic (the trainer just stores the
step; the pipeline replays identically, including after elastic restarts).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

PAPER_LEN_MIN = 57
PAPER_LEN_MAX = 2048
PAPER_LEN_MEAN = 646


@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    vocab: int = 50280
    seed: int = 0
    len_min: int = PAPER_LEN_MIN
    len_max: int = PAPER_LEN_MAX
    # lognormal(mu, sigma) clipped to [len_min, len_max]; defaults calibrated
    # so the clipped mean ≈ 646 (paper's InternLM statistics)
    mu: float = 6.17
    sigma: float = 0.75


class SyntheticCorpus:
    def __init__(self, cfg: CorpusConfig = CorpusConfig()):
        self.cfg = cfg

    def _rng(self, *salt: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, *salt]))

    def lengths(self, step: int, n: int) -> np.ndarray:
        r = self._rng(0xB0B, step)
        ln = np.exp(r.normal(self.cfg.mu, self.cfg.sigma, size=n))
        return np.clip(ln, self.cfg.len_min, self.cfg.len_max).astype(np.int64)

    def sequence(self, step: int, idx: int, length: int) -> np.ndarray:
        """Learnable structure: token_{t+1} = (token_t + stride) % (vocab-1) + 1
        (0 is reserved for padding)."""
        r = self._rng(0x5E9, step, idx)
        start = int(r.integers(1, self.cfg.vocab))
        stride = int(r.integers(1, 64))
        toks = (start + stride * np.arange(length, dtype=np.int64)) % \
            (self.cfg.vocab - 1) + 1
        return toks.astype(np.int32)

    def batch_of_sequences(self, step: int, n: int) -> List[np.ndarray]:
        lens = self.lengths(step, n)
        return [self.sequence(step, i, int(L)) for i, L in enumerate(lens)]

    def mean_length(self, probe_steps: int = 50, per_step: int = 64) -> float:
        tot = [self.lengths(s, per_step) for s in range(probe_steps)]
        return float(np.mean(np.concatenate(tot)))
