"""Batch producers for the paper's three training regimes.

  * ``pack``   — PackMamba: variable-length sequences packed into fixed
                 (rows, seq_len) buffers with position/segment side tensors.
  * ``pad``    — baseline 2: one sequence per row, zero-padded to seq_len.
  * ``single`` — baseline 1: one sequence per step (padded up to the next
                 power of two, the shape the paper's Fig 2 analysis favors).

Static shapes always: (rows, seq_len) — required for jit/pjit. Every batch is
a pure function of ``step`` (see data/dataset.py), so restart/elastic resume
replays the stream exactly.

Straggler note (DESIGN.md §5): packing itself is the straggler mitigation
for variable-length data — every data shard gets identical (rows, seq_len)
dense work regardless of the raw length draw; the loader additionally
assigns packed rows to shards round-robin by descending row load so
token-imbalance across shards stays <1 row.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np
import jax.numpy as jnp

from repro.core.packing import pack, pad_to_max, plan_packing
from repro.data.dataset import SyntheticCorpus


@dataclasses.dataclass
class LoaderConfig:
    rows: int                   # global batch rows (packed buffers per step)
    seq_len: int                # packed buffer capacity (paper: 4096 = 2^12)
    mode: str = "pack"          # pack | pad | single
    policy: str = "sequential"  # packing policy (paper default)
    oversample: float = 1.15    # draw margin so `rows` buffers always fill
    balance_shards: int = 0     # >0: reorder rows so each contiguous group
                                # of rows/balance_shards (one DP shard's
                                # slice) carries ~equal real-token load


class PackingLoader:
    def __init__(self, corpus: SyntheticCorpus, cfg: LoaderConfig):
        if cfg.balance_shards > 1 and cfg.rows % cfg.balance_shards:
            raise ValueError(
                f"balance_shards={cfg.balance_shards} must divide "
                f"rows={cfg.rows}: shard balancing permutes rows into "
                f"contiguous per-shard slices of rows/balance_shards, which "
                f"is ill-defined on a remainder. Pick rows as a multiple of "
                f"balance_shards (e.g. rows="
                f"{cfg.rows + (-cfg.rows) % cfg.balance_shards}) or set "
                f"balance_shards=0.")
        self.corpus = corpus
        self.cfg = cfg
        self._mean = corpus.mean_length(probe_steps=20, per_step=64)

    def _n_draw(self) -> int:
        c = self.cfg
        if c.mode == "pad":
            return c.rows
        if c.mode == "single":
            return 1
        return max(1, int(c.rows * c.seq_len / self._mean / c.oversample))

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        c = self.cfg
        seqs = self.corpus.batch_of_sequences(step, self._n_draw())
        if c.mode == "pad":
            pb = pad_to_max(seqs, c.seq_len)
        elif c.mode == "single":
            n = len(seqs[0])
            cap = 1 << (n - 1).bit_length()          # next power of two
            pb = pad_to_max(seqs[:1], cap)
        else:
            # drop sequences that would need a row beyond `rows` (counted)
            plan = plan_packing([len(s) for s in seqs], c.seq_len, c.policy)
            keep_ids = [i for row in plan[:c.rows] for i in row]
            pb = pack([seqs[i] for i in keep_ids], c.seq_len,
                      policy=c.policy, num_rows=c.rows)
        out = {"tokens": pb.tokens, "positions": pb.positions,
               "segment_ids": pb.segment_ids}
        if c.balance_shards > 1 and c.mode == "pack":
            out = self._balance(out, c.balance_shards)
        return out

    @staticmethod
    def _balance(batch, n_shards):
        """Straggler mitigation across DP shards: snake-order rows by real
        token count so each shard's contiguous row-slice carries ~equal
        load (matters when padding differs across rows; with packing the
        residual imbalance is < one sequence)."""
        seg = np.asarray(batch["segment_ids"])
        rows = seg.shape[0]
        if rows % n_shards:
            # unreachable through PackingLoader (validated in __init__);
            # loud here too for direct callers
            raise ValueError(f"_balance: {rows} rows not divisible by "
                             f"{n_shards} shards")
        load = (seg > 0).sum(axis=1)
        order = np.argsort(-load, kind="stable")
        fill = [[] for _ in range(n_shards)]
        for i, row in enumerate(order):
            rnd, pos = divmod(i, n_shards)
            shard = pos if rnd % 2 == 0 else n_shards - 1 - pos  # snake
            fill[shard].append(int(row))
        perm = np.concatenate([np.asarray(f, np.int64) for f in fill])
        return {k: v[jnp.asarray(perm)] for k, v in batch.items()}

    def stats(self, step: int) -> Dict[str, float]:
        c = self.cfg
        seqs = self.corpus.batch_of_sequences(step, self._n_draw())
        lens = [len(s) for s in seqs]
        plan = plan_packing(lens, c.seq_len, c.policy)
        used = sum(lens[i] for row in plan[:c.rows] for i in row)
        return {"padding_rate": 1.0 - used / (c.rows * c.seq_len),
                "n_seqs": float(len(lens)),
                "dropped_rows": float(max(0, len(plan) - c.rows)),
                "balanced": bool(c.balance_shards > 1 and c.mode == "pack")}
