"""repro: PackMamba (variable-length sequence packing for Mamba training)
as a production JAX/TPU framework. See README.md and DESIGN.md."""
__version__ = "1.0.0"
