"""Fault-tolerant checkpointing, from scratch (no orbax in environment).

Design for 1000+ node operation:
  * **Atomic publish** — arrays + manifest are written to ``step_N.tmp`` and
    os.rename'd to ``step_N`` (rename is atomic on POSIX); a crashed writer
    can never leave a half-readable "latest" checkpoint.
  * **Async save** — serialization happens on a background thread after the
    train loop has snapshotted host copies (jax.device_get), so step time is
    not blocked by disk. ``wait()`` joins before exit / next save.
  * **Keep-K GC** — oldest checkpoints pruned after each successful publish.
  * **Mesh-elastic restore** — arrays are stored unsharded (host view). On
    restore the caller passes a template (from jax.eval_shape) + optional
    NamedShardings: leaves are matched *by tree path*, then device_put with
    the *current* mesh's sharding — so restarts may change pod/data/model
    sizes freely (ZeRO-style resharding falls out of device_put). On a real
    multi-host pod each host would write its addressable shards
    (`arrays-of-shards` layout) — single-process here, noted in DESIGN.md.
  * **Self-describing** — manifest (msgpack) records step, tree paths,
    shapes, dtypes, plus user metadata (data step, RNG, mesh shape) for
    deterministic data replay after restart.
"""
from __future__ import annotations

import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional

import msgpack
import numpy as np
import jax
import ml_dtypes

# numpy's npz format round-trips only standard dtypes; ml_dtypes (bfloat16,
# fp8) are stored as same-width uint views and re-viewed on load using the
# logical dtype recorded in the manifest.
_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
           "float8_e5m2": np.uint8, "float16": None}


def _to_storable(a: np.ndarray):
    if a.dtype.name in _EXOTIC and _EXOTIC[a.dtype.name] is not None:
        return a.view(_EXOTIC[a.dtype.name])
    return a


def _from_storable(a: np.ndarray, logical: str):
    if logical in _EXOTIC and _EXOTIC[logical] is not None:
        return a.view(np.dtype(logical))
    return a


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_pkey(p) for p in path)
        flat[key] = leaf
    return flat


def _pkey(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


class CheckpointManager:
    STEP_RE = re.compile(r"^step_(\d+)$")

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, meta: Optional[Dict] = None,
             blocking: bool = False):
        """Snapshot to host memory now; write to disk (a)synchronously."""
        self.wait()
        host_flat = {k: np.asarray(jax.device_get(v))
                     for k, v in _flatten(tree).items()}
        meta = dict(meta or {}, step=int(step))

        def _write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{f"k{i}": _to_storable(a)
                        for i, a in enumerate(host_flat.values())})
            manifest = {
                "step": int(step),
                "keys": list(host_flat.keys()),
                "shapes": [list(a.shape) for a in host_flat.values()],
                "dtypes": [str(a.dtype) for a in host_flat.values()],
                "meta": meta,
            }
            with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
                f.write(msgpack.packb(manifest))
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)                      # atomic publish
            self._gc()

        def _write_capturing():
            # a daemon thread's exception is otherwise printed and dropped —
            # a checkpoint that silently failed to publish is the one
            # failure mode a fault-tolerant trainer can't afford, so the
            # error is held and re-raised on wait()/the next save()
            try:
                _write()
            except BaseException as e:
                self._error = e

        if blocking or not self.async_save:
            _write()
        else:
            self._thread = threading.Thread(target=_write_capturing,
                                            daemon=True)
            self._thread.start()

    def wait(self):
        """Join the in-flight async save. Raises if that save failed — the
        caller finds out at the first synchronization point (here or the
        next ``save()``), not after the restore it was counting on."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(
                f"async checkpoint save to {self.dir} failed: "
                f"{err!r}") from err

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = self.STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "manifest.msgpack")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def read_meta(self, step: int) -> Dict:
        path = os.path.join(self.dir, f"step_{step}", "manifest.msgpack")
        if not os.path.exists(path):
            raise FileNotFoundError(
                f"no checkpoint manifest at {path} — step {step} was never "
                f"published (available steps: {self.all_steps()})")
        with open(path, "rb") as f:
            raw = f.read()
        try:
            return msgpack.unpackb(raw)
        except Exception as e:
            raise ValueError(f"checkpoint manifest {path} is corrupt and "
                             f"cannot be unpacked: {e!r}") from e

    def restore(self, template, step: Optional[int] = None,
                shardings=None) -> Any:
        """Fill ``template``'s leaves (any pytree of arrays/ShapeDtypeStructs)
        by tree path. ``shardings``: optional matching pytree of
        jax.sharding.Sharding — leaves are device_put onto the *current*
        mesh (elastic restart)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        manifest = self.read_meta(step)
        arrays_path = os.path.join(d, "arrays.npz")
        if not os.path.exists(arrays_path):
            raise FileNotFoundError(
                f"checkpoint step {step} has a manifest but no arrays.npz "
                f"at {arrays_path} — the checkpoint directory was "
                f"partially deleted")
        try:
            npz = np.load(arrays_path)
            stored = set(npz.files)
        except Exception as e:
            raise ValueError(f"checkpoint leaf file {arrays_path} is "
                             f"corrupt and cannot be read: {e!r}") from e
        by_path = {}
        for i, k in enumerate(manifest["keys"]):
            if f"k{i}" not in stored:
                raise ValueError(
                    f"checkpoint step {step} is corrupt: the manifest "
                    f"records leaf '{k}' but {arrays_path} has no entry "
                    f"'k{i}' ({len(stored)} of {len(manifest['keys'])} "
                    f"leaves present)")
            try:
                arr = npz[f"k{i}"]
            except Exception as e:
                raise ValueError(f"checkpoint leaf '{k}' in {arrays_path} "
                                 f"is corrupt: {e!r}") from e
            by_path[k] = _from_storable(arr, manifest["dtypes"][i])

        tpl_flat = _flatten(template)
        missing = set(tpl_flat) - set(by_path)
        if missing:
            raise KeyError(f"checkpoint step {step} missing leaves: "
                           f"{sorted(missing)[:5]}…")
        shard_flat = _flatten(shardings) if shardings is not None else {}

        def fill(path_leaf):
            path, leaf = path_leaf
            key = "/".join(_pkey(p) for p in path)
            arr = by_path[key]
            want = np.dtype(leaf.dtype)
            if arr.dtype != want:
                arr = arr.astype(want)
            if key in shard_flat:
                return jax.device_put(arr, shard_flat[key])
            return jax.device_put(arr)

        leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
        return jax.tree_util.tree_unflatten(treedef,
                                            [fill(pl) for pl in leaves])
