"""AdamW with decoupled weight decay, global-norm clipping and LR schedules.

Built from scratch (no optax in the environment). State is a pytree pair
(m, v) mirroring params, kept in f32 regardless of param dtype, plus a
scalar step. Weight decay applies only to rank≥2 weights (norm scales,
biases, per-channel gains like Mamba's D are excluded), the standard LLM
recipe.

Mixed precision: when any param leaf is low-precision (bf16/f16), ``init``
also stores an f32 **master** copy. ``update`` then accumulates into the
master and re-rounds to the param dtype each step, so tiny updates are
never lost to bf16's 8-bit mantissa. For all-f32 params ``master`` is
None and the state tree is unchanged from earlier revisions (checkpoints
stay compatible).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray     # () int32
    m: Any                # pytree like params (f32)
    v: Any                # pytree like params (f32)
    master: Any = None    # f32 param copy when params are low-precision


def _needs_master(params) -> bool:
    return any(jnp.issubdtype(x.dtype, jnp.floating)
               and jnp.dtype(x.dtype).itemsize < 4
               for x in jax.tree.leaves(params))


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else \
            jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


def constant_schedule(base_lr: float) -> Callable:
    return lambda step: jnp.asarray(base_lr, jnp.float32)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale), tree), norm


class AdamW:
    def __init__(self, lr_fn: Callable, cfg: AdamWConfig = AdamWConfig()):
        self.lr_fn = lr_fn
        self.cfg = cfg

    def init(self, params) -> AdamWState:
        zeros = lambda p: jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), p)
        master = (jax.tree.map(lambda x: x.astype(jnp.float32), params)
                  if _needs_master(params) else None)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=zeros(params), v=zeros(params), master=master)

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
        c = self.cfg
        if c.clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, c.clip_norm)
        else:
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            gnorm = global_norm(grads)
        step = state.step + 1
        lr = self.lr_fn(step)
        b1, b2 = c.b1, c.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        # mixed precision: step from the f32 master (when kept), so bf16
        # rounding never swallows a small update; weight decay also reads
        # the master, not the rounded copy
        masters = state.master if state.master is not None else params

        def upd(g, m, v, p, w):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mh = m / bc1
            vh = v / bc2
            delta = mh / (jnp.sqrt(vh) + c.eps)
            w32 = w.astype(jnp.float32)
            if c.weight_decay and p.ndim >= 2:
                delta = delta + c.weight_decay * w32
            new_w = w32 - lr * delta
            return new_w.astype(p.dtype), m, v, new_w

        out = jax.tree.map(upd, grads, state.m, state.v, params, masters)
        pick = lambda i: jax.tree.map(
            lambda t: t[i], out, is_leaf=lambda t: isinstance(t, tuple))
        new_params, new_m, new_v = pick(0), pick(1), pick(2)
        new_master = pick(3) if state.master is not None else None
        stats = {"grad_norm": gnorm, "lr": lr}
        return new_params, AdamWState(step, new_m, new_v, new_master), stats
