"""Mamba-1 selective scan with PackMamba segment resets — XLA path.

Discretization (paper eq. 2a/2b, Mamba's ZOH-for-A / Euler-for-B):

    Ā[b,l,d,n] = exp(Δ[b,l,d] · A[d,n])          A = -exp(A_log)  (real < 0)
    B̄x[b,l,d,n] = Δ[b,l,d] · B[b,l,n] · u[b,l,d]

    h_t = Ā_t ⊙ h_{t-1} + B̄x_t                    (per (b, d, n))
    y[b,l,d] = Σ_n C[b,l,n] · h[b,l,d,n] + D[d] · u[b,l,d]

PackMamba (§3.4): wherever position_indices == 0, Ā → 0 — state reset at the
start of each packed sequence. In serial form this equals Δ→∞ state
forgetting that selective SSMs already support (paper eq. 2a remark); in
parallel form the reset composes with the associative combine (see
core/scan.py docstring).

This module is the default (dry-run / roofline) path; the Pallas TPU kernel
lives in kernels/selective_scan.py and matches this to numerical tolerance.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.scan import segmented_scan, scan_step


def selective_scan(u: jnp.ndarray, delta: jnp.ndarray, A: jnp.ndarray,
                   B: jnp.ndarray, C: jnp.ndarray,
                   D: Optional[jnp.ndarray] = None,
                   positions: Optional[jnp.ndarray] = None,
                   h0: Optional[jnp.ndarray] = None,
                   method: str = "chunked", chunk: int = 256,
                   return_state: bool = False,
                   compute_dtype=None):
    """u,delta: (B,L,D); A: (D,N); B,C: (B,L,N); D: (D,).

    positions: (B,L) int32 — PackMamba position indices (reset where == 0).
    h0: (B, D, N) initial state (for split-pack state carry / decode chunking).
    compute_dtype: recurrence dtype (default f32; bf16 halves scan traffic).
    Returns y (B, L, D) [, h_last (B, D, N)].
    """
    Bsz, L, Dm = u.shape
    N = A.shape[-1]
    cdt = jnp.dtype(compute_dtype) if compute_dtype is not None else \
        jnp.promote_types(u.dtype, jnp.float32)     # scan state dtype
    if method == "fused_seq":
        # §Perf iteration: fold y = C·h into a single sequential scan so the
        # (B, L, D, N) decay/h trajectories are NEVER materialized — HBM
        # traffic drops from O(L·D·N·log chunk) to O(L·D·N) carry round-trips
        # + O(L·D) outputs. (The Pallas kernel is the real TPU answer; this
        # is its closest pure-XLA analogue.)
        return _fused_seq_scan(u, delta, A, B, C, D, positions, h0,
                               return_state, cdt)
    delta_f = delta.astype(cdt)
    # decay a = exp(Δ·A): (B, L, D, N)
    a = jnp.exp(delta_f[..., None] * A.astype(cdt))
    # b-term = Δ·B·u: (B, L, D, N)
    bterm = (delta_f * u.astype(cdt))[..., None] * B.astype(cdt)[:, :, None, :]
    reset = (positions == 0) if positions is not None else None
    h, h_last = segmented_scan(a, bterm, reset=reset, h0=h0,
                               method=method, chunk=chunk)
    y = jnp.einsum("bldn,bln->bld", h, C.astype(cdt))
    if D is not None:
        y = y + D.astype(cdt) * u.astype(cdt)
    y = y.astype(u.dtype)
    if return_state:
        return y, h_last
    return y


def _fused_seq_scan(u, delta, A, B, C, D, positions, h0, return_state, cdt):
    Bsz, L, Dm = u.shape
    N = A.shape[-1]
    A32 = A.astype(cdt)
    reset = (positions == 0) if positions is not None else \
        jnp.zeros((Bsz, L), bool)
    if h0 is None:
        h0 = jnp.zeros((Bsz, Dm, N), cdt)

    def step(h, xs):
        u_t, d_t, B_t, C_t, r_t = xs
        d32 = d_t.astype(cdt)
        a_t = jnp.exp(d32[..., None] * A32)               # (B, Dm, N)
        a_t = jnp.where(r_t[:, None, None], 0.0, a_t)
        h = a_t * h + (d32 * u_t.astype(cdt))[..., None] * \
            B_t.astype(cdt)[:, None, :]
        y_t = jnp.einsum("bdn,bn->bd", h, C_t.astype(cdt))
        return h, y_t

    xs = (jnp.moveaxis(u, 1, 0), jnp.moveaxis(delta, 1, 0),
          jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0),
          jnp.moveaxis(reset, 1, 0))
    h_last, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)
    if D is not None:
        y = y + D.astype(cdt) * u.astype(cdt)
    y = y.astype(u.dtype)
    if return_state:
        return y, h_last
    return y


def selective_scan_step(h: jnp.ndarray, u_t: jnp.ndarray, delta_t: jnp.ndarray,
                        A: jnp.ndarray, B_t: jnp.ndarray, C_t: jnp.ndarray,
                        D: Optional[jnp.ndarray] = None,
                        reset_t: Optional[jnp.ndarray] = None
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One decode step. h: (B, D, N); u_t, delta_t: (B, D); B_t, C_t: (B, N).

    Returns (y_t (B, D), h_new (B, D, N)).
    """
    cdt = h.dtype
    a_t = jnp.exp(delta_t.astype(cdt)[..., None] * A.astype(cdt))      # (B,D,N)
    b_t = (delta_t.astype(cdt) * u_t.astype(cdt))[..., None] * \
        B_t.astype(cdt)[:, None, :]
    h_new = scan_step(h, a_t, b_t, reset_t)
    y_t = jnp.einsum("bdn,bn->bd", h_new, C_t.astype(cdt))
    if D is not None:
        y_t = y_t + D.astype(cdt) * u_t.astype(cdt)
    return y_t.astype(u_t.dtype), h_new
