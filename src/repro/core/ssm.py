"""Selective scan with PackMamba segment resets — XLA path.

One *head-structured* state-space interface serves both Mamba generations.
The general layout is ``(B, L, H, dh)`` inputs with state ``(B, H, dh, N)``:

  * **Mamba-2 / SSD** — per-head *scalar* decay ``A: (H,)``:

        Ā[b,l,h]      = exp(Δ[b,l,h] · A[h])     A = -exp(A_log)  (real < 0)
        B̄x[b,l,h,p,n] = Δ[b,l,h] · B[b,l,n] · u[b,l,h,p]
        h_t = Ā_t · h_{t-1} + B̄x_t               (per (b, h); scalar decay)
        y[b,l,h,p] = Σ_n C[b,l,n] · h[b,l,h,p,n] + D[h] · u[b,l,h,p]

    With scalar decay the blocked schedule's cumulative-decay matrix is one
    (T, T) matrix per head, so a whole chunk evaluates as a single
    (T, T) · (T, dh·N) matmul — see ``selective_scan_heads``.

  * **Mamba-1** — the degenerate case ``H = d_inner, dh = 1`` with
    *per-channel* decay ``A: (D, N)`` (paper eq. 2a/2b, ZOH-for-A /
    Euler-for-B):

        Ā[b,l,d,n] = exp(Δ[b,l,d] · A[d,n])
        B̄x[b,l,d,n] = Δ[b,l,d] · B[b,l,n] · u[b,l,d]
        y[b,l,d] = Σ_n C[b,l,n] · h[b,l,d,n] + D[d] · u[b,l,d]

    ``selective_scan`` keeps the historical (B, L, D) surface and routes
    through ``selective_scan_heads`` with dh = 1.

PackMamba (§3.4): wherever position_indices == 0, Ā → 0 — state reset at the
start of each packed sequence. In serial form this equals Δ→∞ state
forgetting that selective SSMs already support (paper eq. 2a remark); in
parallel form the reset composes with the associative combine (see
core/scan.py docstring).

Serving handoff (``collect_ends``): because resets make the state at index
``e`` depend only on tokens of ``e``'s own segment, the final state of EVERY
packed segment is just the state trajectory sampled at that segment's last
token. ``collect_ends (B, S)`` asks the evaluators to also return those
samples ``h_ends (B, S, …)`` (−1 entries = absent segment → zeros). The
blocked schedules gather them from the in-chunk state slice they already
compute — one O(B·S·state) gather per chunk, no extra scan passes and still
no (B, L, …, N) materialization.

This module is the default (dry-run / roofline) path; the Pallas TPU kernels
live in kernels/selective_scan.py and match this to numerical tolerance
(``schedule='blocked'``/``'step'`` for per-channel, ``'blocked_heads'`` for
per-head scalar decay).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.scan import segmented_scan, scan_step, gather_state_ends
from repro.core.scan import _combine as _scan_combine

_MATMUL_CHUNK_CAP = 32    # blocked/matmul intra: bounds the T²·D·N operand
_HEADS_CHUNK_CAP = 64     # blocked heads (quad): bounds the (T, T, H) decay
#   matrix and the T× FLOP multiplier of the single-matmul step (SSD picks
#   T ≈ dh so the (T,T)·(T,dh·N) matmul stays square-ish)
_HEADS_DUAL_CHUNK_CAP = 128  # dual form: the T² term is only (dh + N) wide,
#   so a larger T pays off — but the (B, T, T, H) Gram/decay matrices still
#   grow as T², hence a cap of their own
# The caps bound worst-case memory whatever the tuner asks for; WITHIN them
# the chunk is a measured per-shape decision of repro/tune, not a constant.


def _tuned_knobs(op, tune, *, B, L, D=0, N=0, H=0, dh=0, dtype,
                 positions, objective="fwd"):
    """Resolve measured xla-path knobs for one call site (or {} on miss).

    ``tune``: "auto" (process-default cache), a cache path, or a TuneCache.
    ``objective``: "fwd" | "fwdbwd" — which sweep's winner to serve (a
    training step resolves against forward+backward timings). Resolution is
    trace-time Python over static shapes — nothing here ever blocks a
    traced computation; a cache miss falls through to the caller's explicit
    arguments. Winners recorded for the pallas backend are ignored at this
    (xla-only) level — kernels/ops.py resolves those.
    """
    from repro.tune import tuned       # lazy: repro.tune imports this module
    kn = tuned(op, cache=None if tune == "auto" else tune,
               B=B, L=L, D=D, N=N, H=H, dh=dh, dtype=dtype,
               reset_density=None if positions is not None else 0.0,
               objective=objective)
    if not kn or kn.get("backend", "xla") != "xla":
        return {}
    return kn


def selective_scan(u: jnp.ndarray, delta: jnp.ndarray, A: jnp.ndarray,
                   B: jnp.ndarray, C: jnp.ndarray,
                   D: Optional[jnp.ndarray] = None,
                   positions: Optional[jnp.ndarray] = None,
                   h0: Optional[jnp.ndarray] = None,
                   method: str = "chunked", chunk: int = 256,
                   return_state: bool = False,
                   compute_dtype=None, intra: Optional[str] = None,
                   collect_ends: Optional[jnp.ndarray] = None,
                   tune=None, tune_objective: str = "fwd"):
    """Mamba-1 surface: u,delta: (B,L,D); A: (D,N); B,C: (B,L,N); D: (D,).

    The degenerate head-structured case H = D, dh = 1 — dispatches through
    ``selective_scan_heads`` (the unified state-space interface).

    positions: (B,L) int32 — PackMamba position indices (reset where == 0).
    h0: (B, D, N) initial state (for split-pack state carry / decode chunking).
    compute_dtype: recurrence dtype (default f32; bf16 halves scan traffic).
    intra: method='blocked' only — in-chunk evaluator ('matmul' | 'assoc';
    default picks 'matmul' on TPU, 'assoc' elsewhere — see _blocked_ssm).
    collect_ends: (B, S) int32 segment-end indices (−1 = absent) — per-
    segment serving handoff (module docstring).
    tune: None (off — use the explicit arguments as-is) | "auto" | cache
    path | TuneCache: resolve (method, chunk, intra) from the shape-keyed
    tuning cache, explicit arguments serving as the miss fallback.
    Returns y (B, L, D) [, h_last (B, D, N)] [, h_ends (B, S, D, N)].
    """
    out = selective_scan_heads(
        u[..., None], delta, A, B, C, D, positions=positions,
        h0=None if h0 is None else h0[:, :, None, :],
        method=method, chunk=chunk, return_state=return_state,
        compute_dtype=compute_dtype, intra=intra,
        collect_ends=collect_ends, tune=tune, tune_objective=tune_objective)
    if not (return_state or collect_ends is not None):
        return out[..., 0]
    out = list(out)
    out[0] = out[0][..., 0]                          # y: drop dh = 1
    for i in range(1, len(out)):
        out[i] = out[i][..., 0, :]                   # states: (…, dh=1, N)
    return tuple(out)


def selective_scan_heads(u: jnp.ndarray, delta: jnp.ndarray, A: jnp.ndarray,
                         B: jnp.ndarray, C: jnp.ndarray,
                         D: Optional[jnp.ndarray] = None,
                         positions: Optional[jnp.ndarray] = None,
                         h0: Optional[jnp.ndarray] = None,
                         method: str = "blocked", chunk: int = 64,
                         return_state: bool = False,
                         compute_dtype=None, intra: Optional[str] = None,
                         collect_ends: Optional[jnp.ndarray] = None,
                         tune=None, tune_objective: str = "fwd"):
    """Unified head-structured state-space interface (module docstring).

    u: (B, L, H, dh); delta: (B, L, H); B, C: (B, L, N) (shared across the
    heads of a group); D: (H,) skip; positions: (B, L) int32 (reset where
    == 0); h0: (B, H, dh, N); collect_ends: (B, S) int32 segment-end
    indices (−1 = absent) for the per-segment serving handoff.

    ``A`` selects the variant:
      * (H,)   — Mamba-2/SSD scalar per-head decay. ``method``:
                 'blocked' (single-matmul chunk evaluation — the hot path)
                 | 'sequential' (reference / short L). ``intra`` picks the
                 blocked in-chunk form: 'quad' (state-form dec @ b, the
                 default) | 'dual' (C·Bᵀ attention-like contraction straight
                 to outputs — wins when dh ≫ T; see _blocked_ssm_heads).
      * (H, N) — Mamba-1 per-(channel, state) decay; requires dh == 1 and
                 accepts every per-channel ``method`` ('blocked' | 'chunked'
                 | 'fused_seq' | 'sequential' | 'associative', plus
                 ``intra`` ∈ ('matmul', 'assoc') for 'blocked').

    ``tune``: None (off) | "auto" | cache path | TuneCache — resolve
    (method, chunk, intra) from the shape-keyed tuning cache at trace time;
    the explicit arguments are the miss fallback (repro/tune).

    Returns y (B, L, H, dh) [, h_last (B, H, dh, N)]
    [, h_ends (B, S, H, dh, N)].
    """
    Bsz, L, H, P = u.shape
    if tune is not None:
        kn = _tuned_knobs(
            "selective_scan" if A.ndim == 2 else "selective_scan_heads",
            tune, B=Bsz, L=L, D=(H if A.ndim == 2 else 0),
            N=B.shape[-1], H=(0 if A.ndim == 2 else H),
            dh=(0 if A.ndim == 2 else P), dtype=u.dtype,
            positions=positions, objective=tune_objective)
        if kn:
            method = kn.get("method", method)
            chunk = kn.get("chunk", chunk)
            intra = kn.get("intra", intra)
    if A.ndim == 2:
        # Mamba-1 degenerate case: fold dh into the channel axis and run the
        # per-channel evaluators.
        if P != 1:
            raise ValueError(
                f"per-channel decay A{A.shape} requires dh == 1, got {P}")
        y, h_last, h_ends = _selective_scan_channels(
            u[..., 0], delta, A, B, C, D, positions,
            None if h0 is None else h0[:, :, 0, :],
            method, chunk, compute_dtype, intra, collect_ends)
        return _pack_scan_out(
            y[..., None], h_last[:, :, None, :],
            None if h_ends is None else h_ends[:, :, :, None, :],
            return_state, collect_ends)
    cdt = jnp.dtype(compute_dtype) if compute_dtype is not None else \
        jnp.promote_types(u.dtype, jnp.float32)
    if method == "blocked":
        y, h_last, h_ends = _blocked_ssm_heads(
            u, delta, A, B, C, D, positions, h0, cdt, chunk, collect_ends,
            intra=intra)
    elif method == "sequential":
        y, h_last, h_ends = _seq_scan_heads(
            u, delta, A, B, C, D, positions, h0, cdt, collect_ends)
    else:
        raise ValueError(f"unknown scalar-decay scan method {method!r}")
    return _pack_scan_out(y, h_last, h_ends, return_state, collect_ends)


def _pack_scan_out(y, h_last, h_ends, return_state, collect_ends):
    out = (y,)
    if return_state:
        out += (h_last,)
    if collect_ends is not None:
        out += (h_ends,)
    return out[0] if len(out) == 1 else out


_gather_ends = gather_state_ends


def _selective_scan_channels(u, delta, A, B, C, D, positions, h0,
                             method, chunk, compute_dtype, intra,
                             collect_ends=None):
    """Per-channel (Mamba-1) evaluator family. u,delta: (B,L,D); A: (D,N).

    Returns (y, h_last, h_ends|None) — packed by the dispatcher."""
    Bsz, L, Dm = u.shape
    N = A.shape[-1]
    cdt = jnp.dtype(compute_dtype) if compute_dtype is not None else \
        jnp.promote_types(u.dtype, jnp.float32)     # scan state dtype
    if method == "fused_seq":
        # §Perf iteration: fold y = C·h into a single sequential scan so the
        # (B, L, D, N) decay/h trajectories are NEVER materialized — HBM
        # traffic drops from O(L·D·N·log chunk) to O(L·D·N) carry round-trips
        # + O(L·D) outputs. (The Pallas kernel is the real TPU answer; this
        # is its closest pure-XLA analogue.)
        return _fused_seq_scan(u, delta, A, B, C, D, positions, h0,
                               cdt, collect_ends)
    if method == "blocked":
        # SSD-style block-parallel schedule: also never materializes
        # (B, L, D, N), and replaces the elementwise recurrence with
        # matmul-shaped contractions (see core/scan.py docstring).
        return _blocked_ssm(u, delta, A, B, C, D, positions, h0,
                            cdt, chunk, intra, collect_ends)
    delta_f = delta.astype(cdt)
    # decay a = exp(Δ·A): (B, L, D, N)
    a = jnp.exp(delta_f[..., None] * A.astype(cdt))
    # b-term = Δ·B·u: (B, L, D, N)
    bterm = (delta_f * u.astype(cdt))[..., None] * B.astype(cdt)[:, :, None, :]
    reset = (positions == 0) if positions is not None else None
    h, h_last = segmented_scan(a, bterm, reset=reset, h0=h0,
                               method=method, chunk=chunk)
    y = jnp.einsum("bldn,bln->bld", h, C.astype(cdt))
    if D is not None:
        y = y + D.astype(cdt) * u.astype(cdt)
    y = y.astype(u.dtype)
    h_ends = _gather_ends(h, collect_ends) if collect_ends is not None \
        else None
    return y, h_last, h_ends


def _blocked_ssm(u, delta, A, B, C, D, positions, h0, cdt,
                 chunk, intra=None, collect_ends=None):
    """Block-parallel (SSD-style) selective scan — the fused hot path.

    The schedule: partition L into chunks of length T, evaluate the whole
    reset-masked in-chunk operator at once, and carry only the (B, D, N)
    state across the O(L/T) chunk boundary recurrence. Per chunk
    (structured-state-space duality, Gu & Dao, specialized to Mamba-1's
    (D, N) diagonal decay and PackMamba resets):

        M[i,j]   = Π_{j<k≤i} Ā_k  = exp(s_i − s_j)   masked to j ≤ i AND no
                   reset in (j, i]    (s = in-chunk cumsum of Δ·A)
        h_i      = Σ_j M[i,j]·(Δ·B·u)_j  +  1[no reset ≤ i]·exp(s_i)·h_in
        y_i      = C_i · h_i  (+ D·u)

    Only the current chunk's tensors are ever live — never the (B, L, D, N)
    decay/input trajectory the ``chunked`` method materializes up front —
    and y = C·h is fused into the chunk body, so HBM sees only the
    (B, L, D) output plus O(B·L·(D+N)) raw inputs (the chunk body is
    checkpointed, so backward residuals stay at the raw inputs too).

    ``intra`` selects how the in-chunk operator is evaluated:
      * ``"matmul"`` — build M explicitly and contract h = M @ b as an
        einsum: T× the FLOPs of the recurrence but matmul-shaped, so the
        MXU absorbs them while the carry chain shrinks by T. The form the
        Pallas ``blocked`` kernel implements; default when running on TPU.
        Peak per-chunk intermediate is the (B, T, T, D, N) masked decay
        (s_i − s_j ≤ 0 for unmasked pairs since A < 0, Δ ≥ 0; masked pairs
        are clamped before the exp, so no overflow anywhere).
      * ``"assoc"`` — evaluate the same masked operator with an in-chunk
        associative tree (log₂T passes of elementwise combines). No matrix
        units to feed on CPU, so this is the default there; it keeps the
        schedule's fusion/memory wins (≈2-3× faster than ``chunked`` at
        L ≥ 1024 on CPU — see benchmarks/run.py fig2) without the T×
        element-op blowup that only an MXU makes free.
    Both evaluate the identical operator: results match ``sequential`` to
    f32 tolerance either way.
    """
    if intra is None:
        intra = "matmul" if jax.default_backend() == "tpu" else "assoc"
    if intra not in ("matmul", "assoc"):
        raise ValueError(f"unknown blocked intra mode {intra!r}")
    Bsz, L, Dm = u.shape
    N = A.shape[-1]
    T = min(chunk, L)
    if intra == "matmul":
        # the (B, T, T, D, N) contraction operand grows as T²·D·N: an
        # uncapped scan_chunk (256) would dwarf the (B, L, D, N) buffer
        # this schedule exists to avoid. Matches the Pallas kernel's
        # DEF_SUB_T-scale subtiling.
        T = min(T, _MATMUL_CHUNK_CAP)
    A32 = A.astype(cdt)
    reset = (positions == 0) if positions is not None else \
        jnp.zeros((Bsz, L), bool)
    pad = (-L) % T
    if pad:
        # Δ=0 ⇒ decay 1 / b-term 0 (state carried), no reset: identity steps
        padw = [(0, 0), (0, pad)]
        u = jnp.pad(u, padw + [(0, 0)])
        delta = jnp.pad(delta, padw + [(0, 0)])
        B = jnp.pad(B, padw + [(0, 0)])
        C = jnp.pad(C, padw + [(0, 0)])
        reset = jnp.pad(reset, padw)
    Lp = u.shape[1]
    nc = Lp // T
    if h0 is None:
        h0 = jnp.zeros((Bsz, Dm, N), cdt)
    h0 = h0.astype(cdt)
    tril = jnp.tril(jnp.ones((T, T), bool))
    collect = collect_ends is not None
    nseg = collect_ends.shape[1] if collect else 0

    @jax.checkpoint
    def chunk_step(carry, xs):
        h_in, acc = carry
        uc, dc, Bc, Cc, rc, ci = xs      # (B,T,Dm) ×2, (B,T,N) ×2, (B,T), ()
        d32 = dc.astype(cdt)
        bterm = (d32 * uc.astype(cdt))[..., None] * \
            Bc.astype(cdt)[:, :, None, :]               # (B,T,Dm,N)
        if intra == "matmul":
            la = d32[..., None] * A32                   # (B,T,Dm,N) log decay
            s = jnp.cumsum(la, axis=1)
            rid = jnp.cumsum(rc.astype(jnp.int32), axis=1)   # resets ≤ i
            m = (rid[:, :, None] == rid[:, None, :]) & tril[None]  # (B,T,T)
            mm = m[..., None, None]
            diff = s[:, :, None] - s[:, None, :]        # (B,T,T,Dm,N)
            dec = jnp.where(mm, jnp.exp(jnp.where(mm, diff, 0.0)), 0.0)
            h = jnp.einsum("bijdn,bjdn->bidn", dec, bterm)
            cin = jnp.where((rid == 0)[..., None, None], jnp.exp(s), 0.0)
            h = h + cin * h_in[:, None]
        else:
            a = jnp.exp(d32[..., None] * A32)           # (B,T,Dm,N)
            a = jnp.where(rc[..., None, None], 0.0, a)  # PackMamba reset
            Acum, Bcum = jax.lax.associative_scan(_scan_combine, (a, bterm),
                                                  axis=1)
            h = Acum * h_in[:, None] + Bcum             # Acum: carry decay,
            #   zeroed past an in-chunk reset since a→0 poisons its products
        if collect:
            # serving handoff: sample the in-chunk states (already live in
            # both intra modes) at the segment ends that fall in this chunk
            local = collect_ends - ci * T               # (B, S)
            ok = (local >= 0) & (local < T)
            lcl = jnp.clip(local, 0, T - 1)[..., None, None]
            sel = jnp.take_along_axis(
                h, jnp.broadcast_to(lcl, (Bsz, nseg, Dm, N)), axis=1)
            acc = acc + jnp.where(ok[..., None, None], sel, 0)
        y = jnp.einsum("bidn,bin->bid", h, Cc.astype(cdt))
        return (h[:, -1], acc), y

    xs = tuple(jnp.moveaxis(x.reshape((Bsz, nc, T) + x.shape[2:]), 1, 0)
               for x in (u, delta, B, C, reset))
    acc0 = jnp.zeros((Bsz, nseg, Dm, N), cdt) if collect else \
        jnp.zeros((), cdt)
    (h_last, h_ends), ys = jax.lax.scan(chunk_step, (h0, acc0),
                                        xs + (jnp.arange(nc),))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, Lp, Dm)[:, :L]
    if D is not None:
        y = y + D.astype(cdt) * u[:, :L].astype(cdt)
    y = y.astype(u.dtype)
    return y, h_last, (h_ends if collect else None)


def _fused_seq_scan(u, delta, A, B, C, D, positions, h0, cdt,
                    collect_ends=None):
    Bsz, L, Dm = u.shape
    N = A.shape[-1]
    A32 = A.astype(cdt)
    reset = (positions == 0) if positions is not None else \
        jnp.zeros((Bsz, L), bool)
    if h0 is None:
        h0 = jnp.zeros((Bsz, Dm, N), cdt)
    collect = collect_ends is not None
    nseg = collect_ends.shape[1] if collect else 0

    def step(carry, xs):
        h, acc = carry
        u_t, d_t, B_t, C_t, r_t, t = xs
        d32 = d_t.astype(cdt)
        a_t = jnp.exp(d32[..., None] * A32)               # (B, Dm, N)
        a_t = jnp.where(r_t[:, None, None], 0.0, a_t)
        h = a_t * h + (d32 * u_t.astype(cdt))[..., None] * \
            B_t.astype(cdt)[:, None, :]
        if collect:
            ok = (collect_ends == t)[..., None, None]     # (B, S, 1, 1)
            acc = acc + jnp.where(ok, h[:, None], 0)
        y_t = jnp.einsum("bdn,bn->bd", h, C_t.astype(cdt))
        return (h, acc), y_t

    xs = (jnp.moveaxis(u, 1, 0), jnp.moveaxis(delta, 1, 0),
          jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0),
          jnp.moveaxis(reset, 1, 0), jnp.arange(L))
    acc0 = jnp.zeros((Bsz, nseg, Dm, N), cdt) if collect else \
        jnp.zeros((), cdt)
    (h_last, h_ends), ys = jax.lax.scan(step, (h0, acc0), xs)
    y = jnp.moveaxis(ys, 0, 1)
    if D is not None:
        y = y + D.astype(cdt) * u.astype(cdt)
    y = y.astype(u.dtype)
    return y, h_last, (h_ends if collect else None)


# ---------------------------------------------------------------------------
# head-structured (scalar per-head decay) evaluators — Mamba-2 / SSD
# ---------------------------------------------------------------------------

def _blocked_ssm_heads(u, delta, A, B, C, D, positions, h0,
                       cdt, chunk, collect_ends=None, intra=None):
    """Block-parallel schedule, per-head scalar decay — the SSD hot path.

    The same schedule as ``_blocked_ssm`` but the decay depends only on
    (b, l, h), so per chunk of length T the masked cumulative-decay matrix

        dec[i,j] = exp(s_i − s_j)·[j ≤ i]·[no reset in (j, i]]   (s = cumsum Δ·A)

    is a single (T, T) matrix per (b, h) — NOT (T, T, D, N). ``intra``
    selects how the in-chunk operator is evaluated against it:

      * ``"quad"`` (default, ``None``) — the state form: every in-chunk
        state is produced by ONE matmul-shaped contraction

          h[i, p, n] = Σ_j dec[i,j] · (Δ·u ⊗ B)[j, p, n]    ((T,T)·(T,dh·N))

        per head, with y = C·h fused in the chunk body. T²·dh·N FLOPs per
        head per chunk; the in-chunk (T, dh, N) states are live (and are
        what ``collect_ends`` samples).
      * ``"dual"`` — the attention-like form (structured-state-space
        duality, the 'quadratic mode' of SSD): contract straight to outputs
        through the (T, T) Gram matrix

          G[i,j]    = dec[i,j] · (C_i · B_j)
          y[i,p]    = Σ_j G[i,j] · (Δ·u)[j,p]  +  cin_i · (C_i · h_in)[p]

        plus one decay-weighted reduction for the chunk-final carry state.
        T²·(dh + N) + T·dh·N FLOPs — beats quad when dh ≫ T (the in-chunk
        states are never formed, so their T·dh·N cost disappears from the
        T² term). Segment-end samples for ``collect_ends`` are rebuilt only
        at the (B, S) sampled rows.

    Both forms evaluate the identical operator (parity to f32 tolerance).
    The (B, L, H, dh, N) state trajectory is never materialized either way,
    and the chunk body is checkpointed so backward residuals stay at the
    raw inputs.

    ``intra="quad"`` is an exact pin of the default path (same
    ``_HEADS_CHUNK_CAP`` clamp, same trace); ``"dual"`` clamps at its own
    ``_HEADS_DUAL_CHUNK_CAP``. Within those bounds the chunk is the
    autotuner's (repro/tune) measured decision.
    """
    if intra not in (None, "quad", "dual"):
        raise ValueError(f"unknown heads blocked intra mode {intra!r}")
    Bsz, L, H, P = u.shape
    N = B.shape[-1]
    T = min(chunk, L, _HEADS_DUAL_CHUNK_CAP if intra == "dual"
            else _HEADS_CHUNK_CAP)
    A32 = A.astype(cdt)
    reset = (positions == 0) if positions is not None else \
        jnp.zeros((Bsz, L), bool)
    pad = (-L) % T
    if pad:
        # Δ=0 ⇒ decay 1 / b-term 0 (state carried), no reset: identity steps
        u = jnp.pad(u, [(0, 0), (0, pad), (0, 0), (0, 0)])
        delta = jnp.pad(delta, [(0, 0), (0, pad), (0, 0)])
        B = jnp.pad(B, [(0, 0), (0, pad), (0, 0)])
        C = jnp.pad(C, [(0, 0), (0, pad), (0, 0)])
        reset = jnp.pad(reset, [(0, 0), (0, pad)])
    Lp = u.shape[1]
    nc = Lp // T
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), cdt)
    h0 = h0.astype(cdt)
    tril = jnp.tril(jnp.ones((T, T), bool))
    collect = collect_ends is not None
    nseg = collect_ends.shape[1] if collect else 0

    @jax.checkpoint
    def chunk_step(carry, xs):
        h_in, acc = carry
        uc, dc, Bc, Cc, rc, ci = xs  # (B,T,H,P), (B,T,H), (B,T,N)×2, (B,T)
        d32 = dc.astype(cdt)
        la = d32 * A32                                   # (B,T,H) log decay
        s = jnp.cumsum(la, axis=1)
        rid = jnp.cumsum(rc.astype(jnp.int32), axis=1)   # resets ≤ i
        m = (rid[:, :, None] == rid[:, None, :]) & tril[None]    # (B,T,T)
        mm = m[..., None]
        diff = s[:, :, None] - s[:, None, :]             # (B,T,T,H)
        dec = jnp.where(mm, jnp.exp(jnp.where(mm, diff, 0.0)), 0.0)
        bterm = (d32[..., None] * uc.astype(cdt))[..., None] * \
            Bc.astype(cdt)[:, :, None, None, :]          # (B,T,H,P,N)
        # the single-matmul step: (T,T)·(T, dh·N) batched only over (b, h)
        h = jnp.einsum("bijh,bjhpn->bihpn", dec, bterm)
        cin = jnp.where((rid == 0)[..., None], jnp.exp(s), 0.0)  # (B,T,H)
        h = h + cin[..., None, None] * h_in[:, None]
        if collect:
            # serving handoff: sample in-chunk states at segment ends
            local = collect_ends - ci * T                # (B, S)
            ok = (local >= 0) & (local < T)
            lcl = jnp.clip(local, 0, T - 1)[..., None, None, None]
            sel = jnp.take_along_axis(
                h, jnp.broadcast_to(lcl, (Bsz, nseg, H, P, N)), axis=1)
            acc = acc + jnp.where(ok[..., None, None, None], sel, 0)
        y = jnp.einsum("bihpn,bin->bihp", h, Cc.astype(cdt))
        return (h[:, -1], acc), y

    @jax.checkpoint
    def chunk_step_dual(carry, xs):
        h_in, acc = carry
        uc, dc, Bc, Cc, rc, ci = xs  # (B,T,H,P), (B,T,H), (B,T,N)×2, (B,T)
        d32 = dc.astype(cdt)
        la = d32 * A32                                   # (B,T,H) log decay
        s = jnp.cumsum(la, axis=1)
        rid = jnp.cumsum(rc.astype(jnp.int32), axis=1)   # resets ≤ i
        m = (rid[:, :, None] == rid[:, None, :]) & tril[None]    # (B,T,T)
        mm = m[..., None]
        diff = s[:, :, None] - s[:, None, :]             # (B,T,T,H)
        dec = jnp.where(mm, jnp.exp(jnp.where(mm, diff, 0.0)), 0.0)
        B32 = Bc.astype(cdt)
        C32 = Cc.astype(cdt)
        du = d32[..., None] * uc.astype(cdt)             # (B,T,H,P)  Δ·u
        # dual form: fold the (C_i · B_j) Gram matrix into the decay and
        # contract straight to outputs — the (B,T,H,dh,N) in-chunk states
        # are never formed
        G = dec * jnp.einsum("bin,bjn->bij", C32, B32)[..., None]  # (B,T,T,H)
        y = jnp.einsum("bijh,bjhp->bihp", G, du)
        cin = jnp.where((rid == 0)[..., None], jnp.exp(s), 0.0)    # (B,T,H)
        y = y + cin[..., None] * jnp.einsum("bhpn,bin->bihp", h_in, C32)
        # chunk-final carry state: one decay-weighted reduction per head
        h_out = jnp.einsum("bjh,bjhp,bjn->bhpn", dec[:, -1], du, B32) + \
            cin[:, -1][..., None, None] * h_in
        if collect:
            # rebuild states only at the sampled segment-end rows: gather
            # the (B, S) rows of dec/cin and redo the (S, T) contraction
            local = collect_ends - ci * T                # (B, S)
            ok = (local >= 0) & (local < T)
            lcl = jnp.clip(local, 0, T - 1)
            dec_e = jnp.take_along_axis(
                dec, jnp.broadcast_to(lcl[:, :, None, None],
                                      (Bsz, nseg, T, H)), axis=1)
            cin_e = jnp.take_along_axis(
                cin, jnp.broadcast_to(lcl[:, :, None], (Bsz, nseg, H)),
                axis=1)
            sel = jnp.einsum("bsjh,bjhp,bjn->bshpn", dec_e, du, B32) + \
                cin_e[..., None, None] * h_in[:, None]
            acc = acc + jnp.where(ok[..., None, None, None], sel, 0)
        return (h_out, acc), y

    xs = tuple(jnp.moveaxis(x.reshape((Bsz, nc, T) + x.shape[2:]), 1, 0)
               for x in (u, delta, B, C, reset))
    acc0 = jnp.zeros((Bsz, nseg, H, P, N), cdt) if collect else \
        jnp.zeros((), cdt)
    body = chunk_step_dual if intra == "dual" else chunk_step
    (h_last, h_ends), ys = jax.lax.scan(body, (h0, acc0),
                                        xs + (jnp.arange(nc),))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, Lp, H, P)[:, :L]
    if D is not None:
        y = y + (D.astype(cdt)[:, None] * u[:, :L].astype(cdt))
    y = y.astype(u.dtype)
    return y, h_last, (h_ends if collect else None)


def _seq_scan_heads(u, delta, A, B, C, D, positions, h0, cdt,
                    collect_ends=None):
    """Sequential per-head reference (y = C·h fused, scalar decay)."""
    Bsz, L, H, P = u.shape
    N = B.shape[-1]
    A32 = A.astype(cdt)
    reset = (positions == 0) if positions is not None else \
        jnp.zeros((Bsz, L), bool)
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), cdt)
    h0 = h0.astype(cdt)
    collect = collect_ends is not None
    nseg = collect_ends.shape[1] if collect else 0

    def step(carry, xs):
        h, acc = carry
        u_t, d_t, B_t, C_t, r_t, t = xs    # (B,H,P), (B,H), (B,N)×2, (B,)
        d32 = d_t.astype(cdt)
        a_t = jnp.exp(d32 * A32)                          # (B, H)
        a_t = jnp.where(r_t[:, None], 0.0, a_t)
        b_t = (d32[..., None] * u_t.astype(cdt))[..., None] * \
            B_t.astype(cdt)[:, None, None, :]             # (B, H, P, N)
        h = a_t[..., None, None] * h + b_t
        if collect:
            ok = (collect_ends == t)[..., None, None, None]
            acc = acc + jnp.where(ok, h[:, None], 0)
        y_t = jnp.einsum("bhpn,bn->bhp", h, C_t.astype(cdt))
        return (h, acc), y_t

    xs = (jnp.moveaxis(u, 1, 0), jnp.moveaxis(delta, 1, 0),
          jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0),
          jnp.moveaxis(reset, 1, 0), jnp.arange(L))
    acc0 = jnp.zeros((Bsz, nseg, H, P, N), cdt) if collect else \
        jnp.zeros((), cdt)
    (h_last, h_ends), ys = jax.lax.scan(step, (h0, acc0), xs)
    y = jnp.moveaxis(ys, 0, 1)
    if D is not None:
        y = y + (D.astype(cdt)[:, None] * u.astype(cdt))
    y = y.astype(u.dtype)
    return y, h_last, (h_ends if collect else None)


def selective_scan_heads_step(h: jnp.ndarray, u_t: jnp.ndarray,
                              delta_t: jnp.ndarray, A: jnp.ndarray,
                              B_t: jnp.ndarray, C_t: jnp.ndarray,
                              D: Optional[jnp.ndarray] = None,
                              reset_t: Optional[jnp.ndarray] = None
                              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One head-structured decode step. h: (B, H, dh, N); u_t: (B, H, dh);
    delta_t: (B, H); A: (H,) scalar decay or (H, N) per-state (dh == 1);
    B_t, C_t: (B, N); D: (H,); reset_t: (B,) bool.

    Returns (y_t (B, H, dh), h_new (B, H, dh, N)).
    """
    cdt = h.dtype
    d32 = delta_t.astype(cdt)
    if A.ndim == 2:                       # Mamba-1 degenerate: (H, N), dh = 1
        a_t = jnp.exp(d32[..., None] * A.astype(cdt))[:, :, None, :]
    else:
        a_t = jnp.exp(d32 * A.astype(cdt))[..., None, None]   # (B,H,1,1)
    b_t = (d32[..., None] * u_t.astype(cdt))[..., None] * \
        B_t.astype(cdt)[:, None, None, :]                     # (B,H,dh,N)
    h_new = scan_step(h, jnp.broadcast_to(a_t, h.shape), b_t, reset_t)
    y_t = jnp.einsum("bhpn,bn->bhp", h_new, C_t.astype(cdt))
    if D is not None:
        y_t = y_t + D.astype(cdt)[:, None] * u_t.astype(cdt)
    return y_t.astype(u_t.dtype), h_new


def selective_scan_step(h: jnp.ndarray, u_t: jnp.ndarray, delta_t: jnp.ndarray,
                        A: jnp.ndarray, B_t: jnp.ndarray, C_t: jnp.ndarray,
                        D: Optional[jnp.ndarray] = None,
                        reset_t: Optional[jnp.ndarray] = None
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One Mamba-1 decode step — the dh = 1 case of
    ``selective_scan_heads_step``. h: (B, D, N); u_t, delta_t: (B, D);
    B_t, C_t: (B, N). Returns (y_t (B, D), h_new (B, D, N))."""
    y_t, h_new = selective_scan_heads_step(
        h[:, :, None, :], u_t[..., None], delta_t, A, B_t, C_t, D, reset_t)
    return y_t[..., 0], h_new[:, :, 0, :]
