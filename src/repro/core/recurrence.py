"""Segmented recurrences beyond Mamba: RG-LRU (RecurrentGemma), mLSTM and
sLSTM (xLSTM). All share the PackMamba boundary rule — the multiplicative
term of the recurrence is forced to zero at ``position_indices == 0`` — which
core/scan.py implements once for every diagonal recurrence.

* RG-LRU is literally a diagonal recurrence (state (D,)): a_t = exp(-c·softplus(Λ)·r_t),
  h_t = a_t h_{t-1} + sqrt(1-a_t²)·(i_t ⊙ x_t). One segmented_scan call.
* mLSTM has a matrix state C (dk×dv) per head with *scalar* per-head decay.
  Materializing per-step outer products k vᵀ is O(L·dk·dv) — prohibitive —
  so we use the chunkwise-parallel form (inter-chunk state + intra-chunk
  masked attention matrix), the linear-attention analogue of the chunked
  selective scan. Stabilized with the max-plus scan m_t = max(f̃_t+m_{t-1}, ĩ_t)
  (itself an associative segmented scan in the (max,+) semiring).
* sLSTM is *inherently sequential* (h_{t-1} feeds the gate preactivations
  through recurrent weights) — lax.scan over time, with h/c/n zeroed at
  segment starts. Documented in DESIGN.md as the one op where the paper's
  parallel-scan machinery cannot apply; resets still give exact PUI.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.scan import segmented_scan, scan_step, gather_state_ends


# ---------------------------------------------------------------------------
# RG-LRU (Griffin / RecurrentGemma)
# ---------------------------------------------------------------------------

RGLRU_C = 8.0


def rglru(x: jnp.ndarray, r_gate: jnp.ndarray, i_gate: jnp.ndarray,
          a_param: jnp.ndarray, positions: Optional[jnp.ndarray] = None,
          h0: Optional[jnp.ndarray] = None, method: str = "chunked",
          chunk: int = 256, compute_dtype=None,
          collect_ends: Optional[jnp.ndarray] = None
          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x, r_gate, i_gate: (B, L, D) (gates already sigmoided); a_param: (D,).

    collect_ends: (B, S) int32 segment-end indices (−1 = absent) — the
    RG-LRU state trajectory IS its output, so the per-segment serving
    handoff is a free gather (in the f32 compute dtype, pre-cast).

    Returns (h (B, L, D), h_last (B, D)) [+ h_ends (B, S, D) appended]."""
    cdt = jnp.dtype(compute_dtype) if compute_dtype is not None else \
        jnp.float32
    log_a = -RGLRU_C * jax.nn.softplus(a_param.astype(cdt)) * \
        r_gate.astype(cdt)                                   # (B, L, D) ≤ 0
    a = jnp.exp(log_a)
    gated = i_gate.astype(cdt) * x.astype(cdt)
    # NOTE (PUI): the sqrt(1-a²) input normalizer uses the *gate-computed* a
    # — exactly what an unpacked sequence sees at its own step 0 with
    # h_{-1}=0. The PackMamba reset only zeroes the multiplicative use of a
    # inside the recurrence (segmented_scan applies it), never the b-term.
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0)) * gated
    reset = (positions == 0) if positions is not None else None
    h, h_last = segmented_scan(a, b, reset=reset, h0=h0,
                               method=method, chunk=chunk)
    if collect_ends is not None:
        return h.astype(x.dtype), h_last, gather_state_ends(h, collect_ends)
    return h.astype(x.dtype), h_last


def rglru_step(h: jnp.ndarray, x_t: jnp.ndarray, r_t: jnp.ndarray,
               i_t: jnp.ndarray, a_param: jnp.ndarray,
               reset_t: Optional[jnp.ndarray] = None):
    """Decode step. h: (B, D) f32. Returns (y_t (B, D), h_new)."""
    cdt = jnp.float32
    log_a = -RGLRU_C * jax.nn.softplus(a_param.astype(cdt)) * r_t.astype(cdt)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0)) * \
        (i_t.astype(cdt) * x_t.astype(cdt))
    a_rec = a if reset_t is None else \
        jnp.where(reset_t[:, None], 0.0, a)     # reset kills recurrence only
    h_new = a_rec * h + b
    return h_new.astype(x_t.dtype), h_new


# ---------------------------------------------------------------------------
# mLSTM (xLSTM) — chunkwise-parallel matrix-state recurrence
# ---------------------------------------------------------------------------

def _maxplus_scan(logf: jnp.ndarray, logi: jnp.ndarray) -> jnp.ndarray:
    """m_t = max(logf_t + m_{t-1}, logi_t), m_{-1} = -inf  →  (B, L, H).

    Associative combine on pairs (f, i): (f1,i1)⊕(f2,i2) = (f1+f2, max(i1+f2, i2)).
    Segment resets are encoded upstream as logf = -inf."""
    def comb(c1, c2):
        f1, i1 = c1
        f2, i2 = c2
        return f1 + f2, jnp.maximum(i1 + f2, i2)
    _, m = jax.lax.associative_scan(comb, (logf, logi), axis=1)
    return m


def mlstm(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
          logf: jnp.ndarray, logi: jnp.ndarray,
          positions: Optional[jnp.ndarray] = None,
          chunk: int = 256,
          state: Optional[Tuple] = None,
          return_state: bool = False):
    """Chunked mLSTM. q,k: (B,L,H,dk); v: (B,L,H,dv); logf,logi: (B,L,H).

    logf is the *log* forget gate (≤0 for sigmoid, any real for exp gate);
    logi the log input gate. Returns h̃ (B,L,H,dv) [, (C,n,m) final state].
    """
    B, L, H, dk = k.shape
    dv = v.shape[-1]
    cdt = jnp.float32
    NEG = jnp.asarray(-1e30, cdt)
    logf = logf.astype(cdt)
    logi = logi.astype(cdt)
    reset = (positions == 0) if positions is not None else None
    if reset is not None:
        logf = jnp.where(reset[..., None], NEG, logf)

    # global stabilizer (cheap: scalar state per (B, H))
    if state is not None:
        C_in0, n_in0, m_in0 = state
        # m_{-1} = m_in0: the composite over [0..t] is (F_t, I_t) with
        # m_t = max(F_t + m_{-1}, I_t)
        def comb(c1, c2):
            f1, i1 = c1
            f2, i2 = c2
            return f1 + f2, jnp.maximum(i1 + f2, i2)
        F, I = jax.lax.associative_scan(comb, (logf, logi), axis=1)
        m = jnp.maximum(F + m_in0[:, None], I)
    else:
        C_in0 = jnp.zeros((B, H, dk, dv), cdt)
        n_in0 = jnp.zeros((B, H, dk), cdt)
        m_in0 = jnp.full((B, H), NEG, cdt)
        m = _maxplus_scan(logf, logi)
    m = jnp.maximum(m, -1e30)  # keep finite

    # stabilized per-step gates
    m_prev = jnp.concatenate([m_in0[:, None], m[:, :-1]], axis=1)
    logfp = jnp.clip(logf + m_prev - m, -60.0, 0.0)   # log f' ≤ 0
    logip = jnp.clip(logi - m, -60.0, 30.0)           # log i'
    ip = jnp.exp(logip)

    # chunking — pad with IDENTITY steps (f'=1, i'=0, no reset) so the
    # chunk-end state (return_state) is exactly the state after step L-1
    pad = (-L) % chunk
    if pad:
        padc = lambda t, fill=0.0: jnp.pad(
            t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2),
            constant_values=fill)
        q, k, v = padc(q), padc(k), padc(v)
        logfp, ip = padc(logfp), padc(ip, 0.0)
        if reset is not None:
            reset = jnp.pad(reset, [(0, 0), (0, pad)],
                            constant_values=False)
    Lp = q.shape[1]
    nc = Lp // chunk
    scale = dk ** -0.5
    q = q.astype(cdt) * scale                       # fold the 1/√dk into q
    rs = lambda t: jnp.moveaxis(
        t.reshape((B, nc, chunk) + t.shape[2:]), 1, 0)
    qc, kc, vc, fc, ic = map(rs, (q, k.astype(cdt), v.astype(cdt), logfp, ip))
    rc = rs(reset) if reset is not None else jnp.zeros((nc, B, chunk), bool)

    def body(carry, inp):
        C_in, n_in = carry
        qb, kb, vb, lfb, ib, rb = inp               # (B, chunk, ...)
        cumF = jnp.cumsum(lfb, axis=1)              # (B, chunk, H) ≤ 0
        # carry validity: no reset so far in this chunk (inclusive of t)
        seg = jnp.cumsum(rb.astype(jnp.int32), axis=1)   # intra-chunk seg id
        Pt = jnp.exp(cumF) * (seg == 0)[..., None]  # decay from chunk entry
        # intra-chunk decay matrix D[t,s] = exp(cumF_t - cumF_s) for s ≤ t in
        # the same segment; else 0. True diffs are ≤ 0 (f' ≤ 1); clamp before
        # exp so masked entries cannot overflow to inf·0 = NaN.
        diff = cumF[:, :, None] - cumF[:, None]     # (B, t, s, H)
        ok = (seg[:, :, None] == seg[:, None]) & \
            (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None])
        D = jnp.exp(jnp.minimum(diff, 0.0)) * ok[..., None]
        w = jnp.einsum("bthd,bshd->btsh", qb, kb) * D * ib[:, None]
        y_intra = jnp.einsum("btsh,bshd->bthd", w, vb)
        # normalizer accumulates k WITHOUT q: n_t = Σ_s D[t,s]·i'_s·k_s
        n_intra = jnp.einsum("btsh,bshd->bthd", D * ib[:, None], kb)
        y_carry = jnp.einsum("bthd,bhde->bthe", qb, C_in) * Pt[..., None]
        n_carry = jnp.einsum("bhd,bth->bthd", n_in, Pt)
        y = y_intra + y_carry
        n = n_intra + n_carry
        # state update to end of chunk
        PT = Pt[:, -1]                               # (B, H)
        decay_to_end = jnp.exp(jnp.minimum(cumF[:, -1:] - cumF, 0.0)) * \
            (seg == seg[:, -1:])[..., None]          # (B, chunk, H)
        wk = decay_to_end * ib                       # (B, chunk, H)
        C_out = C_in * PT[..., None, None] + jnp.einsum(
            "bthd,bthe->bhde", kb * wk[..., None], vb)
        n_out = n_in * PT[..., None] + jnp.einsum(
            "bthd,bth->bhd", kb, wk)
        return (C_out, n_out), (y, n)

    (C_f, n_f), (ys, ns) = jax.lax.scan(
        body, (C_in0, n_in0), (qc, kc, vc, fc, ic, rc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, Lp, H, dv)[:, :L]
    n = jnp.moveaxis(ns, 0, 1).reshape(B, Lp, H, dk)[:, :L]
    qn = jnp.einsum("blhd,blhd->blh", n, q[:, :L])
    den = jnp.maximum(jnp.abs(qn), jnp.exp(-jnp.clip(m, -30.0, 30.0)))
    out = (y / jnp.maximum(den, 1e-20)[..., None]).astype(v.dtype)
    if return_state:
        return out, (C_f, n_f, m[:, -1])
    return out


def mlstm_step(state: Tuple, q_t, k_t, v_t, logf_t, logi_t,
               reset_t: Optional[jnp.ndarray] = None):
    """Decode step. state=(C (B,H,dk,dv), n (B,H,dk), m (B,H));
    q_t,k_t: (B,H,dk); v_t: (B,H,dv); gates (B,H)."""
    C, n, m = state
    cdt = jnp.float32
    logf_t = logf_t.astype(cdt)
    logi_t = logi_t.astype(cdt)
    if reset_t is not None:
        logf_t = jnp.where(reset_t[:, None], -1e30, logf_t)
    m_new = jnp.maximum(logf_t + m, logi_t)
    fp = jnp.exp(jnp.clip(logf_t + m - m_new, -60.0, 0.0))
    ip = jnp.exp(jnp.clip(logi_t - m_new, -60.0, 30.0))
    C_new = C * fp[..., None, None] + ip[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", k_t.astype(cdt), v_t.astype(cdt))
    n_new = n * fp[..., None] + ip[..., None] * k_t.astype(cdt)
    scale = k_t.shape[-1] ** -0.5
    y = jnp.einsum("bhd,bhde->bhe", q_t.astype(cdt) * scale, C_new)
    qn = jnp.einsum("bhd,bhd->bh", n_new, q_t.astype(cdt) * scale)
    den = jnp.maximum(jnp.abs(qn), jnp.exp(-jnp.clip(m_new, -30.0, 30.0)))
    y = (y / jnp.maximum(den, 1e-20)[..., None]).astype(v_t.dtype)
    return y, (C_new, n_new, m_new)


# ---------------------------------------------------------------------------
# sLSTM (xLSTM) — sequential scalar-memory recurrence
# ---------------------------------------------------------------------------

def slstm(x_preact: jnp.ndarray, R: jnp.ndarray,
          positions: Optional[jnp.ndarray] = None,
          state: Optional[Tuple] = None, return_state: bool = False,
          valid: Optional[jnp.ndarray] = None):
    """Sequential sLSTM. x_preact: (B, L, 4, H, dh) input-driven
    preactivations for gates (i, f, z, o); R: (4, H, dh, dh) per-head
    recurrent weights applied to h_{t-1}.

    Cannot be parallelized across time (true nonlinearity between steps) —
    runs as lax.scan; segment resets zero (h, c, n) and m at starts.
    ``valid`` (B, L): state is frozen across invalid (padding) steps —
    used by prefill to stop right-padding from corrupting the handed-off
    state. Returns h (B, L, H, dh) [, final (c, n, m, h)]."""
    B, L, _, H, dh = x_preact.shape
    cdt = jnp.float32
    if state is None:
        z0 = jnp.zeros((B, H, dh), cdt)
        state = (z0, z0, jnp.full((B, H, dh), -1e30, cdt), z0)
    reset = (positions == 0) if positions is not None else \
        jnp.zeros((B, L), bool)
    ok = valid if valid is not None else jnp.ones((B, L), bool)

    def step(carry, inp):
        c, n, m, h = carry
        xp, r_t, v_t = inp                            # (B,4,H,dh), (B,), (B,)
        keep = (~r_t).astype(cdt)[:, None, None]
        c1, n1, h1 = c * keep, n * keep, h * keep
        m1 = jnp.where(r_t[:, None, None], -1e30, m)
        rec = jnp.einsum("bhd,ghde->bghe", h1, R)     # (B,4,H,dh)
        pre = xp.astype(cdt) + rec
        it, ft, zt, ot = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
        logi = it
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + m1, logi)
        fp = jnp.exp(jnp.clip(logf + m1 - m_new, -60.0, 0.0))
        ip = jnp.exp(jnp.clip(logi - m_new, -60.0, 30.0))
        c_new = fp * c1 + ip * jnp.tanh(zt)
        n_new = fp * n1 + ip
        h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)
        vm = v_t[:, None, None]
        out = (jnp.where(vm, c_new, c), jnp.where(vm, n_new, n),
               jnp.where(vm, m_new, m), jnp.where(vm, h_new, h))
        return out, h_new

    xT = jnp.moveaxis(x_preact, 1, 0)
    rT = jnp.moveaxis(reset, 1, 0)
    vT = jnp.moveaxis(ok, 1, 0)
    final, hs = jax.lax.scan(step, state, (xT, rT, vT))
    h = jnp.moveaxis(hs, 0, 1).astype(x_preact.dtype)
    if return_state:
        return h, final
    return h
