"""Generic *segmented* diagonal linear recurrence.

    h_t = a_t ⊙ h_{t-1} + b_t ,      y = all h_t            (inclusive scan)

with the PackMamba reset rule: wherever ``reset[t]`` is set (a packed-sequence
start, ``position_indices == 0``), force ``a_t → 0`` so no state crosses the
boundary. The paper's §3.4 correctness argument is algebraic — the combine
operator

    (a₁, b₁) ⊕ (a₂, b₂) = (a₂·a₁, a₂·b₁ + b₂)

is associative, and once some aₖ = 0 every composite multiplicative term that
spans k is 0, so no additive term from before k survives — hence it holds for
*any* schedule: the sequential scan, the Blelloch tree the paper modifies on
GPU, XLA's associative_scan, and our chunked TPU scan. ``test_pui.py`` checks
this property directly.

This one primitive backs: Mamba-1 selective scan (state (D, N)), RG-LRU
(state (D,)), and mLSTM (matrix state (H, dk, dv) with scalar per-head decay).

Schedule taxonomy (who wins when):
  * ``sequential``   — lax.scan over time. Reference & decode-step building
                       block. O(L) chain of tiny VPU ops; wins only at L
                       small enough that per-chunk setup overhead dominates.
  * ``associative``  — jax.lax.associative_scan over the full L (materializes
                       (B, L, *S) twice; fine for small state / short L).
  * ``chunked``      — lax.scan over L/T chunks carrying h, with an
                       intra-chunk associative scan. Peak memory O(B·T·S)
                       instead of O(B·L·S) for the scan internals; this is
                       the direct XLA analogue of the Pallas ``step``
                       kernel's grid-sequential VMEM-resident carry. Still
                       elementwise (VPU) work end to end.
  * ``blocked``      — SSD-style block-parallel schedule (Gu & Dao's
                       structured-state-space duality, adapted to segmented
                       scans): per chunk of length T, build the
                       lower-triangular cumulative-decay matrix
                       M[i,j] = Π_{j<k≤i} a_k (reset-masked: a→0 at segment
                       starts, so no product spans a boundary) and compute
                       all in-chunk states as one contraction h = M @ b,
                       plus an O(L/T) inter-chunk carry. Turns the O(L)
                       dependent elementwise chain into L/T matmul-shaped
                       contractions (MXU-friendly); costs O(T²·S) per-chunk
                       intermediates and ~T× the FLOPs, so it wins when the
                       hardware has idle matrix units and L ≫ T (see
                       benchmarks/run.py fig2). The selective-scan
                       specialization (exp-of-cumsum log decays, y = C·h
                       folded in, (B, L, D, N) never materialized) is
                       core/ssm.py::method='blocked'; its TPU-kernel twin is
                       kernels/selective_scan.py::schedule='blocked'.
  * ``blocked`` with *per-head scalar decay* (Mamba-2 / SSD proper) — the
                       head-structured specialization: with state (H, dh, N)
                       and one scalar decay a_t per head (instead of one per
                       (d, n) element), the decay matrix M collapses from
                       (T, T, D, N) to a single (T, T) matrix per head, and
                       the whole in-chunk evaluation becomes ONE
                       (T, T)·(T, dh·N) matmul per head — the pure-MXU form
                       PackMamba's "bottleneck operator under diverse tensor
                       shapes" analysis calls for. Mamba-1 is the degenerate
                       case H = d_inner, dh = 1 with per-channel decay; both
                       variants dispatch through
                       core/ssm.py::selective_scan_heads. The TPU-kernel
                       twin is kernels/selective_scan.py::
                       schedule='blocked_heads'.

The Pallas kernels mirror the last two: ``schedule='step'`` walks time with
a per-step VPU update (chunk carry in VMEM scratch), ``schedule='blocked'``
applies the same masked-triangular-decay contraction per in-chunk subtile,
and ``schedule='blocked_heads'`` applies the per-head scalar-decay form as
one dense (Tt, Tt) @ (Tt, dh·N) matmul per subtile.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _bcast_reset(reset: jnp.ndarray, like: jnp.ndarray) -> jnp.ndarray:
    """Broadcast (B, L) reset mask to the rank of ``like`` ((B, L, *S))."""
    extra = like.ndim - reset.ndim
    return reset.reshape(reset.shape + (1,) * extra)


def apply_reset(a: jnp.ndarray, reset: Optional[jnp.ndarray]) -> jnp.ndarray:
    """PackMamba boundary rule: Ā→0 at sequence starts."""
    if reset is None:
        return a
    return jnp.where(_bcast_reset(reset, a), jnp.zeros_like(a), a)


def _combine(c1, c2):
    a1, b1 = c1
    a2, b2 = c2
    return a2 * a1, a2 * b1 + b2


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def scan_sequential(a: jnp.ndarray, b: jnp.ndarray,
                    reset: Optional[jnp.ndarray] = None,
                    h0: Optional[jnp.ndarray] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Time axis = 1. Returns (h_all (B,L,*S), h_last (B,*S))."""
    a = apply_reset(a, reset)
    if h0 is None:
        h0 = jnp.zeros(a.shape[:1] + a.shape[2:], a.dtype)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    # scan over time: move axis 1 to front
    aT = jnp.moveaxis(a, 1, 0)
    bT = jnp.moveaxis(b, 1, 0)
    h_last, hs = jax.lax.scan(step, h0, (aT, bT))
    return jnp.moveaxis(hs, 0, 1), h_last


def scan_associative(a: jnp.ndarray, b: jnp.ndarray,
                     reset: Optional[jnp.ndarray] = None,
                     h0: Optional[jnp.ndarray] = None
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    a = apply_reset(a, reset)
    if h0 is not None:
        # fold h0 in as an extra b-term on step 0
        b = b.at[:, 0].add(a[:, 0] * h0)
    A, B = jax.lax.associative_scan(_combine, (a, b), axis=1)
    del A
    return B, B[:, -1]


def _chunk_scan(a, b, h0, chunk, chunk_body):
    """Shared scaffold for the chunk-carried schedules: pad L to a multiple
    of the chunk with identity steps (a=1, b=0 carry h unchanged), run
    ``chunk_body(h_in, (ac, bc)) -> (h_out, h_chunk)`` under lax.scan over
    the chunks, and stitch/slice the result back to (B, L, *S)."""
    Bsz, L = a.shape[0], a.shape[1]
    T = min(chunk, L)
    pad = (-L) % T
    if pad:
        a = jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2),
                    constant_values=1)
        b = jnp.pad(b, [(0, 0), (0, pad)] + [(0, 0)] * (b.ndim - 2))
    Lp = a.shape[1]
    nc = Lp // T
    S = a.shape[2:]
    if h0 is None:
        h0 = jnp.zeros((Bsz,) + S, a.dtype)
    aC = jnp.moveaxis(a.reshape((Bsz, nc, T) + S), 1, 0)   # (nc, B, T, *S)
    bC = jnp.moveaxis(b.reshape((Bsz, nc, T) + S), 1, 0)
    h_last, hs = jax.lax.scan(chunk_body, h0, (aC, bC))
    h_all = jnp.moveaxis(hs, 0, 1).reshape((Bsz, Lp) + S)[:, :L]
    return h_all, h_last


def scan_chunked(a: jnp.ndarray, b: jnp.ndarray,
                 reset: Optional[jnp.ndarray] = None,
                 h0: Optional[jnp.ndarray] = None,
                 chunk: int = 256) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked scan: sequential across L/chunk, associative inside a chunk.

    Inside a chunk the pair scan yields, per position t (chunk-local),
    the composite (A_t, B_t) of steps [0..t]; then h_t = A_t·h_in + B_t.
    """
    a = apply_reset(a, reset)

    def step(h_in, ab):
        ac, bc = ab                      # (B, chunk, *S)
        A, Bc = jax.lax.associative_scan(_combine, (ac, bc), axis=1)
        h = A * h_in[:, None] + Bc       # (B, chunk, *S)
        return h[:, -1], h

    return _chunk_scan(a, b, h0, chunk, step)


def scan_blocked(a: jnp.ndarray, b: jnp.ndarray,
                 reset: Optional[jnp.ndarray] = None,
                 h0: Optional[jnp.ndarray] = None,
                 chunk: int = 32) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Block-parallel (SSD-style) schedule. See module docstring.

    Per chunk of length T the in-chunk recurrence is evaluated closed-form:

        h_i = cp_i · h_in + Σ_{j≤i} M[i,j] · b_j
        M[i,j] = Π_{j<k≤i} a_k        cp_i = Π_{k≤i} a_k

    M is built with a cumprod along i of the broadcast decay (exact for any
    real a, no log-space needed), so the PackMamba reset (a→0) zeroes every
    boundary-spanning product automatically — including cp, which kills the
    inter-chunk carry past a reset. Peak intermediate is O(B·T²·*S) per
    chunk (the chunk body is rematerialized in the backward pass, so
    residuals stay O(B·L·*S)).
    """
    a = apply_reset(a, reset)

    @jax.checkpoint
    def chunk_step(h_in, ab):
        ac, bc = ab                                     # (B, T, *S)
        T = ac.shape[1]
        S = ac.shape[2:]
        ii = jnp.arange(T)[:, None]
        jj = jnp.arange(T)[None, :]
        strict = (ii > jj).reshape((1, T, T) + (1,) * len(S))
        lower = (ii >= jj).reshape((1, T, T) + (1,) * len(S))
        # Amat[b,i,j] = a_i for i > j else 1; cumprod over i gives M[i,j]
        amat = jnp.where(strict, ac[:, :, None],
                         jnp.ones_like(ac)[:, :1, None])
        M = jnp.where(lower, jnp.cumprod(amat, axis=1), 0)
        h = jnp.einsum("bij...,bj...->bi...", M, bc)
        cp = jnp.cumprod(ac, axis=1)                    # carry decay
        h = h + cp * h_in[:, None]
        return h[:, -1], h

    return _chunk_scan(a, b, h0, chunk, chunk_step)


_METHODS = {
    "sequential": scan_sequential,
    "associative": scan_associative,
    "chunked": scan_chunked,
    "blocked": scan_blocked,
}


def segmented_scan(a: jnp.ndarray, b: jnp.ndarray,
                   reset: Optional[jnp.ndarray] = None,
                   h0: Optional[jnp.ndarray] = None,
                   method: str = "chunked",
                   chunk: int = 256) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dispatch. a, b: (B, L, *S); reset: (B, L) bool; h0: (B, *S).

    Returns (h_all (B, L, *S), h_last (B, *S)).
    """
    if a.shape != b.shape:
        raise ValueError(f"a/b shape mismatch {a.shape} vs {b.shape}")
    fn = _METHODS[method]
    if method in ("chunked", "blocked"):
        return fn(a, b, reset, h0, chunk=chunk)
    return fn(a, b, reset, h0)


def scan_step(h: jnp.ndarray, a_t: jnp.ndarray, b_t: jnp.ndarray,
              reset_t: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Single decode step of the recurrence (used by serve paths)."""
    if reset_t is not None:
        a_t = jnp.where(_bcast_reset(reset_t, a_t), jnp.zeros_like(a_t), a_t)
    return a_t * h + b_t


def gather_state_ends(h_traj: jnp.ndarray, ends: jnp.ndarray) -> jnp.ndarray:
    """Sample a (B, L, *S) state trajectory at per-segment end indices.

    Because segment resets stop state from crossing boundaries, the state at
    a segment's last token IS that segment's final state — this is the
    packed-prefill serving handoff. ``ends``: (B, S) int32, −1 = absent
    segment (→ zeros). Returns (B, S, *S)."""
    Bsz = h_traj.shape[0]
    S = ends.shape[1]
    tail = h_traj.shape[2:]
    idx = jnp.clip(ends, 0, h_traj.shape[1] - 1)
    idx = idx.reshape((Bsz, S) + (1,) * len(tail))
    g = jnp.take_along_axis(h_traj, jnp.broadcast_to(idx, (Bsz, S) + tail),
                            axis=1)
    ok = (ends >= 0).reshape((Bsz, S) + (1,) * len(tail))
    return jnp.where(ok, g, 0)
