"""Segmented causal depthwise conv1d — the paper's conv1d_pack (Algorithm 1).

Standard Mamba short conv: width-W (W=4) depthwise causal convolution along
the sequence. In a packed buffer the window slides across sequence boundaries
(the red line in paper Fig. 3b); Algorithm 1 truncates it: the tap that
reaches back ``k`` positions contributes iff ``k <= position_indices[t]`` —
i.e. the source token lies inside the same original sequence.

Layout: x (B, L, D); weight (W, D); bias (D,). The op is expressed as W
shifted masked adds, which XLA fuses into a single elementwise pass — and
which is exactly the structure the Pallas kernel (kernels/conv1d_pack.py)
tiles into VMEM.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def conv1d_pack(x: jnp.ndarray, weight: jnp.ndarray,
                bias: Optional[jnp.ndarray],
                positions: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Causal depthwise conv with boundary truncation.

    x: (B, L, D); weight: (W, D); bias: (D,) or None;
    positions: (B, L) int32 intra-sequence positions, or None (= one segment).
    Returns (B, L, D).
    """
    B, L, D = x.shape
    W = weight.shape[0]
    y = x * weight[W - 1]                        # k = 0 tap (current token)
    for k in range(1, W):                        # tap reaching back k positions
        shifted = jnp.pad(x, ((0, 0), (k, 0), (0, 0)))[:, :L]
        if positions is not None:
            valid = (positions >= k)[..., None]
            shifted = jnp.where(valid, shifted, jnp.zeros_like(shifted))
        y = y + shifted * weight[W - 1 - k]
    if bias is not None:
        y = y + bias
    return y


def conv1d_pack_update(x_t: jnp.ndarray, conv_state: jnp.ndarray,
                       weight: jnp.ndarray, bias: Optional[jnp.ndarray],
                       reset_t: Optional[jnp.ndarray] = None):
    """Single decode step. conv_state: (B, W-1, D) trailing inputs.

    reset_t: (B,) bool — start of a new sequence (clear the window).
    Returns (y_t (B, D), new_state (B, W-1, D)).
    """
    Bsz, Wm1, D = conv_state.shape
    W = Wm1 + 1
    if reset_t is not None:
        conv_state = jnp.where(reset_t[:, None, None],
                               jnp.zeros_like(conv_state), conv_state)
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,W,D)
    y_t = jnp.einsum("bwd,wd->bd", window, weight)
    if bias is not None:
        y_t = y_t + bias
    return y_t, window[:, 1:]
