"""Sequence packing: the paper's pack()/unpack() and packing policies.

A *packed batch* is a fixed-shape (B, L) buffer holding several variable-length
sequences laid back-to-back, plus two side tensors generated at pack() time:

  * ``positions``    (B, L) int32 — offset of each token inside its original
    sequence. ``positions == 0`` marks a sequence start; this is the paper's
    ``position_indices`` and is what the modified sequence-wise operators
    consume (conv tap truncation, scan Ā→0 reset).
  * ``segment_ids``  (B, L) int32 — 1-based id of the original sequence, 0 for
    padding. Used for attention block-diagonal masks and loss masking.

Packing policies (paper §5 + classics):
  * ``sequential``  — paper's default: fill in arrival order, seal the buffer
    when the next sequence does not fit (19.1% padding on InternLM lengths).
  * ``sorted_greedy`` — paper's local-greedy: sort a window of sequences by
    length descending, then first-fit (0.41% padding, extra sort cost).
  * ``first_fit``   — first-fit over all open buffers (no sort).
  * ``split``       — paper §5 *future work*, implemented here: a sequence may
    be cut at a buffer boundary and continue in the next buffer with state
    carried over (padding → 0). See ``pack_with_split``.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Sequence

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass
class PackedBatch:
    """One packed training batch. All arrays shaped (B, L) unless noted."""

    tokens: jnp.ndarray        # int32 token ids (0 in padding)
    positions: jnp.ndarray     # int32 intra-sequence positions (0 at starts & padding)
    segment_ids: jnp.ndarray   # int32, 1-based per sequence, 0 = padding
    # Bookkeeping for unpack():
    seq_lens: Optional[List[List[int]]] = None   # per row: original lengths in order
    seq_ids: Optional[List[List[int]]] = None    # per row: original corpus indices

    @property
    def shape(self):
        return self.tokens.shape

    def padding_rate(self) -> float:
        return float(jnp.mean((self.segment_ids == 0).astype(jnp.float32)))


# ---------------------------------------------------------------------------
# pack() / unpack()
# ---------------------------------------------------------------------------

def _plan_sequential(lengths: Sequence[int], capacity: int) -> List[List[int]]:
    """Paper default: arrival order, seal buffer when next seq does not fit."""
    rows: List[List[int]] = []
    cur: List[int] = []
    used = 0
    for i, n in enumerate(lengths):
        if n > capacity:
            raise ValueError(f"sequence {i} length {n} exceeds capacity {capacity}")
        if used + n > capacity:
            rows.append(cur)
            cur, used = [], 0
        cur.append(i)
        used += n
    if cur:
        rows.append(cur)
    return rows


def _plan_sorted_greedy(lengths: Sequence[int], capacity: int,
                        window: int = 0) -> List[List[int]]:
    """Paper §5 local greedy: sort (a window of) sequences desc, best-fit."""
    order = list(range(len(lengths)))
    if window and window < len(order):
        # locality-preserving: sort inside consecutive windows only
        chunks = [order[i:i + window] for i in range(0, len(order), window)]
        order = [j for ch in chunks
                 for j in sorted(ch, key=lambda k: -lengths[k])]
    else:
        order.sort(key=lambda k: -lengths[k])
    return _plan_first_fit(lengths, capacity, order)


def _plan_first_fit(lengths: Sequence[int], capacity: int,
                    order: Optional[Sequence[int]] = None) -> List[List[int]]:
    rows: List[List[int]] = []
    space: List[int] = []
    for i in (order if order is not None else range(len(lengths))):
        n = lengths[i]
        if n > capacity:
            raise ValueError(f"sequence {i} length {n} exceeds capacity {capacity}")
        for r, s in enumerate(space):
            if s >= n:
                rows[r].append(i)
                space[r] -= n
                break
        else:
            rows.append([i])
            space.append(capacity - n)
    return rows


def _plan_first_fit_decreasing(lengths: Sequence[int],
                               capacity: int) -> List[List[int]]:
    """Classic FFD bin packing: first-fit over lengths sorted descending.

    Guaranteed ≤ (11/9)·OPT + 1 rows, and never worse than ``sequential``
    on row count — the padding_rate reducer for offline/oversampled pools
    where arrival order doesn't matter.
    """
    order = sorted(range(len(lengths)), key=lambda k: -lengths[k])
    return _plan_first_fit(lengths, capacity, order)


_POLICIES = {
    "sequential": _plan_sequential,
    "sorted_greedy": _plan_sorted_greedy,
    "first_fit": _plan_first_fit,
    "first_fit_decreasing": _plan_first_fit_decreasing,
}


def plan_packing(lengths: Sequence[int], capacity: int,
                 policy: str = "sequential", **kw) -> List[List[int]]:
    """Return list of rows; each row is a list of sequence indices."""
    if policy not in _POLICIES:
        raise ValueError(f"unknown packing policy {policy!r}; have {list(_POLICIES)}")
    return _POLICIES[policy](lengths, capacity, **kw)


def pack(sequences: Sequence[np.ndarray], capacity: int,
         policy: str = "sequential", num_rows: Optional[int] = None,
         **kw) -> PackedBatch:
    """Pack 1-D int token sequences into a (B, L=capacity) PackedBatch.

    ``num_rows`` pads/limits the batch dimension to a fixed B (for static
    shapes in jit); extra rows are all-padding.
    """
    lengths = [int(s.shape[0]) for s in sequences]
    rows = plan_packing(lengths, capacity, policy, **kw)
    B = num_rows if num_rows is not None else len(rows)
    if len(rows) > B:
        raise ValueError(f"packing plan needs {len(rows)} rows > num_rows={B}")
    tokens = np.zeros((B, capacity), dtype=np.int32)
    positions = np.zeros((B, capacity), dtype=np.int32)
    segment_ids = np.zeros((B, capacity), dtype=np.int32)
    seq_lens: List[List[int]] = [[] for _ in range(B)]
    seq_ids: List[List[int]] = [[] for _ in range(B)]
    for r, row in enumerate(rows):
        off = 0
        for seg, i in enumerate(row, start=1):
            n = lengths[i]
            tokens[r, off:off + n] = np.asarray(sequences[i], dtype=np.int32)
            positions[r, off:off + n] = np.arange(n, dtype=np.int32)
            segment_ids[r, off:off + n] = seg
            seq_lens[r].append(n)
            seq_ids[r].append(i)
            off += n
    return PackedBatch(jnp.asarray(tokens), jnp.asarray(positions),
                       jnp.asarray(segment_ids), seq_lens, seq_ids)


def unpack(batch_values: jnp.ndarray, packed: PackedBatch) -> List[np.ndarray]:
    """Inverse of pack(): split a (B, L, ...) value tensor back into per-
    original-sequence arrays, in original corpus order."""
    if packed.seq_lens is None or packed.seq_ids is None:
        raise ValueError("PackedBatch lacks unpack bookkeeping")
    vals = np.asarray(batch_values)
    pieces: dict[int, list] = {}
    for r, (lens, ids) in enumerate(zip(packed.seq_lens, packed.seq_ids)):
        off = 0
        for n, i in zip(lens, ids):
            # rows are visited in order, so split pieces concatenate in order
            pieces.setdefault(i, []).append(vals[r, off:off + n])
            off += n
    return [np.concatenate(pieces[i], axis=0) for i in sorted(pieces)]


def segment_ends(packed: PackedBatch, max_segments: int) -> np.ndarray:
    """Last-token index of each packed segment, −1-padded to
    (B, max_segments) — the ``ends`` input of ``model.prefill_packed``
    (serving: one decode-cache handoff per entry)."""
    if packed.seq_lens is None:
        raise ValueError("PackedBatch lacks seq_lens bookkeeping")
    B = packed.tokens.shape[0]
    ends = np.full((B, max_segments), -1, np.int32)
    for r, lens in enumerate(packed.seq_lens):
        if len(lens) > max_segments:
            raise ValueError(f"row {r} holds {len(lens)} segments "
                             f"> max_segments={max_segments}")
        off = 0
        for s, n in enumerate(lens):
            off += n
            ends[r, s] = off - 1
    return ends


# ---------------------------------------------------------------------------
# chunk-aware planning (serving: chunked prefill of over-bucket prompts)
# ---------------------------------------------------------------------------

def chunk_spans(length: int, chunk: int) -> List[tuple]:
    """Fixed-size chunk plan for one long sequence: [(offset, n), …] with
    n == chunk everywhere except a possibly-short final span. The serving
    engine feeds each span to ``model.prefill_chunk``, resuming from the
    carried state — the §5 split idea applied to prefill instead of
    training rows."""
    if length <= 0:
        raise ValueError(f"length must be positive, got {length}")
    if chunk <= 0:
        raise ValueError(f"chunk must be positive, got {chunk}")
    return [(off, min(chunk, length - off))
            for off in range(0, length, chunk)]


def needs_chunking(length: int, buckets: Sequence[int]) -> bool:
    """True when a prompt cannot ride the packed-prefill bucket lane and
    must be consumed by the chunked-prefill lane instead."""
    return length > max(buckets)


def slab_width(need: int, buckets: Sequence[int], chunk_size: int) -> int:
    """Width of the next chunked-prefill slab: the smallest bucket-aligned
    candidate ≥ ``need`` (tokens the hungriest chunk row wants this round),
    capped at ``chunk_size``. Candidates are the prefill buckets ≤
    chunk_size plus chunk_size itself, so compile count stays bounded by
    the bucket list — a 16-token cached-prefix SUFFIX prefills in a
    smallest-bucket slab instead of paying a full chunk_size forward,
    which is where the prefix cache's TTFT win comes from."""
    cands = sorted({b for b in buckets if b <= chunk_size} | {chunk_size})
    for c in cands:
        if c >= need:
            return c
    return chunk_size


def suffix_slab(entries, num_rows: int, width: int):
    """Build one fixed-shape (num_rows, width) chunk-lane slab batch.

    ``entries`` maps row → (tokens, offset, take): the slab carries
    ``tokens[offset : offset + take]`` for that row with GLOBAL positions
    (``prefill_chunk`` resumes mid-prompt — for a cached prefix the first
    slab starts at offset = prefix length, so only the suffix is ever
    prefilled). Unoccupied rows and the tail beyond ``take`` are
    segment_ids-0 padding — exact state no-ops in every sequence-wise
    operator. Returns the tokens/positions/segment_ids batch dict."""
    toks = np.zeros((num_rows, width), np.int32)
    pos = np.zeros((num_rows, width), np.int32)
    seg = np.zeros((num_rows, width), np.int32)
    for i, (tokens, off, take) in entries.items():
        if not 0 <= take <= width:
            raise ValueError(f"row {i}: take {take} outside slab width "
                             f"{width}")
        toks[i, :take] = tokens[off:off + take]
        pos[i, :take] = np.arange(off, off + take)
        seg[i, :take] = 1
    return {"tokens": jnp.asarray(toks), "positions": jnp.asarray(pos),
            "segment_ids": jnp.asarray(seg)}


# ---------------------------------------------------------------------------
# pack_with_split — paper §5 future work (beyond-paper feature)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SplitPackedBatch(PackedBatch):
    """Packing with boundary splitting: padding → 0 (modulo final buffer).

    A sequence may be cut at a row boundary; ``carry_mask`` (B,) marks rows
    whose *first* segment continues a sequence cut in the previous row — the
    trainer threads recurrent state across those rows (state carry), which is
    what the paper sketches for "parallel strategies for infinitely long
    sequences".
    """
    carry_mask: Optional[jnp.ndarray] = None   # (B,) bool


def pack_with_split(sequences: Sequence[np.ndarray], capacity: int,
                    num_rows: Optional[int] = None) -> SplitPackedBatch:
    stream = np.concatenate([np.asarray(s, np.int32) for s in sequences])
    # per-token position + segment id over the flat stream
    lengths = [int(s.shape[0]) for s in sequences]
    pos = np.concatenate([np.arange(n, dtype=np.int32) for n in lengths])
    seg = np.concatenate([np.full(n, i + 1, dtype=np.int32)
                          for i, n in enumerate(lengths)])
    total = stream.shape[0]
    B = int(np.ceil(total / capacity)) if num_rows is None else num_rows
    pad = B * capacity - total
    if pad < 0:
        raise ValueError(f"num_rows={num_rows} too small for {total} tokens")
    stream = np.pad(stream, (0, pad))
    pos = np.pad(pos, (0, pad))
    seg = np.pad(seg, (0, pad))
    tokens = stream.reshape(B, capacity)
    positions = pos.reshape(B, capacity)
    segment_ids = seg.reshape(B, capacity)
    # Row r continues the previous row iff its first token is mid-sequence.
    carry = (positions[:, 0] > 0) & (segment_ids[:, 0] > 0)
    # positions stay *global within the original sequence* so operators know
    # token 0 of a carried row is NOT a reset point.
    seq_lens: List[List[int]] = []
    seq_ids: List[List[int]] = []
    for r in range(B):
        row_ids, row_lens = [], []
        for s in np.unique(segment_ids[r]):
            if s == 0:
                continue
            row_ids.append(int(s) - 1)
            row_lens.append(int((segment_ids[r] == s).sum()))
        seq_lens.append(row_lens)
        seq_ids.append(row_ids)
    return SplitPackedBatch(jnp.asarray(tokens), jnp.asarray(positions),
                            jnp.asarray(segment_ids), seq_lens, seq_ids,
                            carry_mask=jnp.asarray(carry))


# ---------------------------------------------------------------------------
# padding-mode batch (the paper's baseline) + single-sequence mode
# ---------------------------------------------------------------------------

def pad_to_max(sequences: Sequence[np.ndarray], max_len: int) -> PackedBatch:
    """Paper baseline 2: one sequence per row, zero-padded to max_len."""
    B = len(sequences)
    tokens = np.zeros((B, max_len), dtype=np.int32)
    positions = np.zeros((B, max_len), dtype=np.int32)
    segment_ids = np.zeros((B, max_len), dtype=np.int32)
    seq_lens, seq_ids = [], []
    for r, s in enumerate(sequences):
        n = min(int(s.shape[0]), max_len)
        tokens[r, :n] = np.asarray(s[:n], np.int32)
        positions[r, :n] = np.arange(n, dtype=np.int32)
        segment_ids[r, :n] = 1
        seq_lens.append([n])
        seq_ids.append([r])
    return PackedBatch(jnp.asarray(tokens), jnp.asarray(positions),
                       jnp.asarray(segment_ids), seq_lens, seq_ids)


def padding_rate(lengths: Sequence[int], capacity: int,
                 policy: str = "sequential", **kw) -> float:
    """Fraction of buffer slots wasted by a packing plan (paper §5 metric)."""
    rows = plan_packing(lengths, capacity, policy, **kw)
    used = sum(lengths)
    alloc = len(rows) * capacity
    return 1.0 - used / alloc
