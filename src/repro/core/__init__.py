"""PackMamba core: packing + segment-aware sequence-wise operators.

Public surface:
  packing    — pack / unpack / pack_with_split / policies / padding_rate
  scan       — segmented_scan (the Ā→0 reset algebra, 3 schedules)
  ssm        — selective_scan (Mamba-1, XLA path) + decode step
  conv       — conv1d_pack (Algorithm 1) + decode update
  attention  — segment-masked attention (GQA/SWA/M-RoPE, online-softmax)
  recurrence — RG-LRU, mLSTM, sLSTM with segment resets
"""
from repro.core.packing import (pack, unpack, pack_with_split, pad_to_max,
                                plan_packing, padding_rate, PackedBatch)
from repro.core.scan import segmented_scan, scan_step
from repro.core.ssm import selective_scan, selective_scan_step
from repro.core.conv import conv1d_pack, conv1d_pack_update
from repro.core.attention import attention, decode_attention, rope, mrope
from repro.core.recurrence import rglru, mlstm, slstm

__all__ = [
    "pack", "unpack", "pack_with_split", "pad_to_max", "plan_packing",
    "padding_rate", "PackedBatch", "segmented_scan", "scan_step",
    "selective_scan", "selective_scan_step", "conv1d_pack",
    "conv1d_pack_update", "attention", "decode_attention", "rope", "mrope",
    "rglru", "mlstm", "slstm",
]
