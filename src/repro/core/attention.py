"""Packed (segment-masked) attention — the transformer face of PackMamba.

For attention-family architectures the paper's technique degenerates to the
ByteTransformer precedent it cites: pack sequences back-to-back and replace
the causal mask with ``causal ∧ same-segment`` (block-diagonal). This module
provides:

  * ``attention``       — GQA/MQA/MHA, causal or bidirectional, optional
                          sliding window, segment mask; either materialized
                          scores (short L) or an online-softmax scan over KV
                          chunks (32k+ prefill: peak memory O(Lq·chunk), the
                          Rabe–Staats/Flash recurrence).
  * ``decode_attention`` — one query token against a (possibly sharded) KV
                          cache with validity-length masking.
  * ``rope`` / ``mrope`` — rotary embeddings over *intra-sequence* positions
                          (using packed-buffer-global positions would violate
                          PUI; tests check this), plus Qwen2-VL multi-section
                          M-RoPE.

Layouts: q (B, Lq, H, Dh); k, v (B, Lkv, Hkv, Dh) with H % Hkv == 0.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # large-negative instead of -inf: keeps online-softmax NaN-free


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def rope(x: jnp.ndarray, positions: jnp.ndarray,
         theta: float = 10000.0) -> jnp.ndarray:
    """x: (B, L, H, Dh); positions: (B, L) int — intra-sequence positions."""
    freqs = rope_freqs(x.shape[-1], theta)                    # (Dh/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs    # (B, L, Dh/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def mrope(x: jnp.ndarray, positions: jnp.ndarray,
          sections: Sequence[int], theta: float = 10000.0) -> jnp.ndarray:
    """Qwen2-VL M-RoPE. positions: (B, L, S) — one channel per section
    (temporal / height / width); sections sum to Dh/2."""
    Dh = x.shape[-1]
    if sum(sections) != Dh // 2:
        raise ValueError(f"M-RoPE sections {sections} must sum to {Dh // 2}")
    freqs = rope_freqs(Dh, theta)                             # (Dh/2,)
    # pick the position channel per rotary dim
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.asarray(sections), total_repeat_length=Dh // 2)
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),                         # (B, L, S)
        jnp.broadcast_to(sec_id, positions.shape[:2] + (Dh // 2,)), axis=-1)
    ang = pos * freqs                                          # (B, L, Dh/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------

def _pair_mask(q_idx, kv_idx, seg_q, seg_kv, causal, window):
    """Boolean (…, Lq, Lkv) allow-mask from index/segment tensors."""
    m = jnp.ones(q_idx.shape[:-1] + (q_idx.shape[-1], kv_idx.shape[-1]),
                 dtype=bool)
    qi = q_idx[..., :, None]
    ki = kv_idx[..., None, :]
    if causal:
        m &= ki <= qi
    if window is not None:
        m &= (qi - ki) < window
        if not causal:
            m &= (ki - qi) < window
    if seg_q is not None:
        sq = seg_q[..., :, None]
        sk = seg_kv[..., None, :]
        m &= (sq == sk) & (sq != 0)
    return m


# ---------------------------------------------------------------------------
# core attention
# ---------------------------------------------------------------------------

def _gqa_scores(q, k, scale):
    """q: (B,Lq,Hkv,G,Dh); k: (B,T,Hkv,Dh) → (B,Hkv,G,Lq,T) f32."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                      preferred_element_type=jnp.float32) * scale


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              segment_ids_q: Optional[jnp.ndarray] = None,
              segment_ids_kv: Optional[jnp.ndarray] = None,
              causal: bool = True,
              window: Optional[int] = None,
              chunk_kv: Optional[int] = None,
              scale: Optional[float] = None) -> jnp.ndarray:
    """Segment-masked attention. Returns (B, Lq, H, Dh).

    ``chunk_kv``: if set, run the online-softmax recurrence over KV chunks of
    this size (required for 32k+ prefill where Lq·Lkv scores cannot be
    materialized).
    """
    B, Lq, H, Dh = q.shape
    _, Lkv, Hkv, _ = k.shape
    if H % Hkv:
        raise ValueError(f"H={H} not divisible by Hkv={Hkv}")
    G = H // Hkv
    scale = scale if scale is not None else Dh ** -0.5
    qg = q.reshape(B, Lq, Hkv, G, Dh)
    q_idx = jnp.broadcast_to(jnp.arange(Lq), (B, Lq))
    if segment_ids_q is None or segment_ids_kv is None:
        segment_ids_q = segment_ids_kv = None
    if chunk_kv is None or Lkv <= chunk_kv:
        kv_idx = jnp.broadcast_to(jnp.arange(Lkv), (B, Lkv))
        mask = _pair_mask(q_idx, kv_idx, segment_ids_q, segment_ids_kv,
                          causal, window)                    # (B, Lq, Lkv)
        s = _gqa_scores(qg, k, scale)                        # (B,Hkv,G,Lq,Lkv)
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        # guard all-masked rows (padding queries)
        p = jax.nn.softmax(s, axis=-1)
        p = jnp.where(mask[:, None, None].any(-1, keepdims=True), p, 0.0)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
        return o.reshape(B, Lq, H, Dh)

    # ---- online-softmax over KV chunks (flash recurrence, pure XLA) ----
    if Lkv % chunk_kv:
        raise ValueError(f"Lkv={Lkv} not divisible by chunk_kv={chunk_kv}")
    nk = Lkv // chunk_kv
    kc = jnp.moveaxis(k.reshape(B, nk, chunk_kv, Hkv, Dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nk, chunk_kv, Hkv, Dh), 1, 0)
    if segment_ids_kv is not None:
        segc = jnp.moveaxis(segment_ids_kv.reshape(B, nk, chunk_kv), 1, 0)
    else:
        segc = jnp.zeros((nk, B, 0), jnp.int32)

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, sb, c0 = inp                     # chunk kv, base index c0
        kv_idx = c0 + jnp.broadcast_to(jnp.arange(chunk_kv), (B, chunk_kv))
        use_seg = segment_ids_q is not None and segment_ids_kv is not None
        mask = _pair_mask(q_idx, kv_idx,
                          segment_ids_q if use_seg else None,
                          sb if use_seg else None,
                          causal, window)        # (B, Lq, chunk)
        s = _gqa_scores(qg, kb, scale)           # (B,Hkv,G,Lq,chunk)
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))        # (B,Hkv,G,Lq)
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[:, None, None], p, 0.0)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vb.astype(p.dtype))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G, Lq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Lq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Lq, Dh), jnp.float32)
    bases = jnp.arange(nk) * chunk_kv
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, segc, bases))
    o = jnp.where(l[..., None] > 0, acc / jnp.maximum(l[..., None], 1e-20), 0.0)
    o = jnp.moveaxis(o, -2, 1)                   # (B, Lq, Hkv, G, Dh)
    return o.reshape(B, Lq, H, Dh).astype(q.dtype)


def decode_attention(q_t: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, cache_len: jnp.ndarray, *,
                     window: Optional[int] = None,
                     scale: Optional[float] = None) -> jnp.ndarray:
    """One-token decode. q_t: (B, H, Dh); caches: (B, S, Hkv, Dh);
    cache_len: (B,) number of valid cache entries (the new token's index).
    Returns (B, H, Dh)."""
    B, S, Hkv, Dh = k_cache.shape
    H = q_t.shape[1]
    G = H // Hkv
    scale = scale if scale is not None else Dh ** -0.5
    qg = q_t.reshape(B, Hkv, G, Dh)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    idx = jnp.arange(S)[None, :]                              # (1, S)
    valid = idx <= cache_len[:, None]
    if window is not None:
        valid &= (cache_len[:, None] - idx) < window
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, H, Dh)
