"""Shape-keyed autotuning for the scan hot path.

PackMamba's core move is picking the best parallelization per tensor shape
(paper §4); this package replaces the repo's frozen guesses (``DEF_SUB_T``,
the matmul-intra chunk cap, ``_HEADS_CHUNK_CAP``, the CPU-vs-MXU intra
auto-pick) with *measured, cached* decisions:

  space.py   the declarative tunable space per operator + shape-key buckets
  runner.py  interleaved min-of-rounds measurement sweeps per shape key
  cache.py   persistent ``TUNE_CACHE.json`` — fingerprinted by device kind /
             platform / jax version, bucketed lookup, nearest-key fallback

``tuned()`` below is the one resolver every call site threads through
(core/ssm.py, kernels/ops.py via their ``tune=`` argument; model configs
via ``ArchConfig.scan_tune``). It is trace-time Python over static shapes:
a cache miss falls back to the caller's defaults and *never* blocks —
measurement happens only in explicit ``warm_for_config`` / runner sweeps.

    cfg = dataclasses.replace(cfg, scan_tune="auto")   # or a cache path
    # launch/train.py and launch/serve.py warm the cache for their shape
    # buckets at startup; `make bench-tune` runs the standalone sweep.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.tune.space import (OPS, OBJECTIVES, ShapeKey, shape_key,  # noqa: F401
                              space_for, candidate_name, l_bucket,
                              reset_bucket)
from repro.tune.cache import (TuneCache, fingerprint, get_cache,  # noqa: F401
                              set_cache, reset_caches, default_path)


def tuned(op: str, *, B: int, L: int, D: int = 0, N: int = 0, H: int = 0,
          dh: int = 0, dtype="float32", reset_density: Optional[float] = None,
          objective: str = "fwd", cache=None,
          default: Optional[Dict] = None) -> Dict:
    """Measured knobs for one operator invocation, or the defaults on miss.

    ``cache``: a TuneCache, a path, or None (process-default cache —
    $REPRO_TUNE_CACHE or ./TUNE_CACHE.json). Lookup is exact on the
    bucketed key, then nearest-key within the op *and objective*
    ("fwd"-swept winners are never served to "fwdbwd" queries), then
    ``default`` (or {}); a stale cache (fingerprint mismatch) always
    misses.
    """
    c = cache if isinstance(cache, TuneCache) else get_cache(cache)
    key = shape_key(op, dtype=dtype, B=B, L=L, D=D, N=N, H=H, dh=dh,
                    reset_density=reset_density, objective=objective)
    knobs, _how = c.lookup(key)
    if knobs is None:
        return dict(default) if default else {}
    return {**(default or {}), **knobs}


def config_shape_args(cfg, B: int, L: int) -> Optional[Dict]:
    """Map an ArchConfig's scan operator to ``tuned()`` shape kwargs.

    Returns None for families without a selective-scan hot path."""
    kinds = set(cfg.unit)
    if "mamba2" in kinds:
        return dict(op="selective_scan_heads", B=B, L=L, N=cfg.d_state,
                    H=cfg.n_ssm_heads, dh=cfg.ssm_hd, dtype=cfg.dtype)
    if "mamba" in kinds:
        return dict(op="selective_scan", B=B, L=L, D=cfg.d_inner,
                    N=cfg.d_state, dtype=cfg.dtype)
    return None


def tuned_config_overrides(cfg, B: int, L: int, cache=None) -> Dict:
    """Cache winner for ``cfg``'s scan op at (B, L) as ArchConfig override
    fields — what launch/perf.py's ``tuned`` hillclimb variant applies
    instead of hand-picked knob combinations. {} when nothing is cached."""
    args = config_shape_args(cfg, B, L)
    if args is None:
        return {}
    op = args.pop("op")
    kn = tuned(op, cache=cache, **args)
    if not kn:
        return {}
    out: Dict = {}
    if kn.get("backend") == "pallas":
        out["use_pallas"] = True
        if op == "selective_scan" and "schedule" in kn:
            out["pallas_schedule"] = kn["schedule"]
    else:
        if "method" in kn:
            out["scan_impl"] = kn["method"]
        if "chunk" in kn:
            out["scan_chunk"] = kn["chunk"]
        if "intra" in kn:
            out["scan_intra"] = kn["intra"]
    return out


def warm_for_config(cfg, shapes, cache: Optional[TuneCache] = None,
                    rounds: int = 3, save: bool = True, verbose: bool = True,
                    objective: str = "fwd"):
    """Warm the tuning cache for a config's scan shapes at launcher startup.

    ``shapes``: iterable of (rows, seq_len) the launcher will actually run
    (training batch shape, serve prefill buckets, …). Shapes whose bucketed
    key is already cached are skipped; new winners are measured with the
    runner and saved back to the cache file. ``objective="fwdbwd"`` makes
    the sweep time forward+backward — what launch/train.py warms so the
    training step gets schedules tuned for its own gradient shapes instead
    of inference's. Returns the cache (None when the config has no scan
    hot path or tuning is off)."""
    if getattr(cfg, "scan_tune", "off") == "off":
        return None
    from repro.tune import runner
    path = None if cfg.scan_tune == "auto" else cfg.scan_tune
    c = cache if cache is not None else get_cache(path)
    touched = False
    for rows, L in shapes:
        args = config_shape_args(cfg, rows, L)
        if args is None:
            return None
        op = args.pop("op")
        touched |= runner.ensure(op, cache=c, rounds=rounds,
                                 verbose=verbose, objective=objective,
                                 **args)
    if touched and save:
        c.save()
    return c
