"""``python -m repro.tune --check [TUNE_CACHE.json]`` — cache health check
(delegates to cache._main; a dedicated entry avoids runpy re-executing the
already-imported cache module)."""
from repro.tune.cache import _main

if __name__ == "__main__":
    _main()
