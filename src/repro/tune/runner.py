"""Measurement sweeps: time every candidate in an operator's tunable space
at one shape key, cache the winner.

The timing discipline is the benchmark harness's own (benchmarks/timing.py:
interleaved min-of-rounds) so tuner numbers and fig2 numbers are directly
comparable. What a sweep measures is the key's ``objective``: "fwd" times
the forward operator (the serving regime); "fwdbwd" times forward + full
VJP of a scalar loss (the training regime — the backward recomputes the
chunk bodies, so its cost structure, and therefore the winning schedule,
can differ from the forward's). Winners are cached under objective-tagged
keys and never served across objectives.

Pallas candidates are included only where their timings mean something:
real TPU kernels, not interpret mode (`INTERPRET` in
kernels/selective_scan.py) — interpret-mode wall clock would "tune" the
emulator.

CLI — the bounded default sweep behind ``make bench-tune``:

    PYTHONPATH=src python -m repro.tune.runner --out TUNE_CACHE.json \
        [--rounds 3] [--grid small|fig2] [--force]

The ``fig2`` grid covers the benchmark matrix's shapes (both scan ops at
L ∈ {256…4096}, plus the wide-head dh ≫ T cell where the dual form wins);
``small`` is a seconds-scale smoke grid.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.tune.space import (ShapeKey, shape_key, space_for, candidate_name)
from repro.tune.cache import TuneCache, get_cache


def _timing():
    """Import the shared benchmark timing helper (repo-root package)."""
    try:
        from benchmarks.timing import interleaved_min_of_rounds
    except ImportError:    # src-only sys.path (e.g. installed layout)
        root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
        if root not in sys.path:
            sys.path.insert(0, root)
        from benchmarks.timing import interleaved_min_of_rounds
    return interleaved_min_of_rounds


def _pallas_usable() -> bool:
    import jax
    from repro.kernels import selective_scan as scan_k
    return jax.default_backend() == "tpu" and not scan_k.INTERPRET


# ---------------------------------------------------------------------------
# synthetic operands per shape key
# ---------------------------------------------------------------------------

def synth_positions(rng, B: int, L: int, resets: str):
    """Packed position ids matching a reset-density band (space.RESET_BANDS):
    segment length ≈ 1/density, boundaries straddling power-of-two chunks."""
    import jax.numpy as jnp
    if resets == "none":
        return jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32), (B, L))
    seg = {"sparse": 400, "mid": 100, "dense": 12}.get(resets, 100)
    seg = min(seg, L)
    lens = [seg] * (L // seg) + ([L % seg] if L % seg else [])
    row = np.concatenate([np.arange(n) for n in lens])
    return jnp.asarray(np.broadcast_to(row, (B, L)).copy(), jnp.int32)


def synth_args(key: ShapeKey, seed: int = 0) -> Tuple:
    """Operator inputs for one shape key (at the bucketed L)."""
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    B, L, N = key.B, key.Lb, key.N
    dt_ = jnp.dtype(key.dtype)
    pos = synth_positions(rng, B, L, key.resets)
    Bm = jnp.asarray(rng.normal(size=(B, L, N)), dt_)
    Cm = jnp.asarray(rng.normal(size=(B, L, N)), dt_)
    if key.op == "selective_scan_heads":
        H, P = key.H, key.dh
        u = jnp.asarray(rng.normal(size=(B, L, H, P)), dt_)
        delta = jnp.asarray(rng.uniform(0.1, 0.5, (B, L, H)), dt_)
        A = -jnp.exp(jnp.asarray(rng.normal(size=(H,)), jnp.float32))
        Dk = jnp.ones((H,), jnp.float32)
    else:
        D = key.D
        u = jnp.asarray(rng.normal(size=(B, L, D)), dt_)
        delta = jnp.asarray(rng.uniform(0.1, 0.5, (B, L, D)), dt_)
        A = -jnp.exp(jnp.asarray(rng.normal(size=(D, key.N)), jnp.float32))
        Dk = jnp.ones((D,), jnp.float32)
    return u, delta, A, Bm, Cm, Dk, pos


def make_thunk(key: ShapeKey, knobs: Dict, args: Tuple):
    """A zero-arg jitted callable evaluating one candidate at this shape.

    ``key.objective == "fwdbwd"`` wraps the candidate in a value_and_grad
    of a scalar loss over every differentiable operand, so the sweep times
    the full training-step cost of the schedule (forward + VJP recompute),
    not just the forward."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops as kops
    u, delta, A, Bm, Cm, Dk, pos = args
    heads = key.op == "selective_scan_heads"
    if knobs.get("backend") == "pallas":
        kw = dict(backend="pallas", chunk=knobs["pchunk"],
                  sub_t=knobs.get("sub_t"))
        if heads:
            kw["schedule"] = knobs.get("schedule", "blocked_heads")
            raw = lambda u, d, Bm, Cm, p: kops.selective_scan_heads(
                u, d, A, Bm, Cm, Dk, p, **kw)
        else:
            kw["schedule"] = knobs.get("schedule", "blocked")
            raw = lambda u, d, Bm, Cm, p: kops.selective_scan(
                u, d, A, Bm, Cm, Dk, p, **kw)
    else:
        from repro.core import ssm as core_ssm
        kw = dict(method=knobs.get("method", "blocked"))
        if "chunk" in knobs:
            kw["chunk"] = knobs["chunk"]
        if "intra" in knobs:
            kw["intra"] = knobs["intra"]
        f = core_ssm.selective_scan_heads if heads else core_ssm.selective_scan
        raw = lambda u, d, Bm, Cm, p, f=f: f(u, d, A, Bm, Cm, Dk, p, **kw)
    if key.objective == "fwdbwd":
        def scalar_loss(u, d, Bm, Cm, p):
            y = raw(u, d, Bm, Cm, p)
            return (y.astype(jnp.float32) ** 2).mean()
        fn = jax.jit(jax.value_and_grad(scalar_loss, argnums=(0, 1, 2, 3)))
    else:
        fn = jax.jit(raw)
    return lambda: fn(u, delta, Bm, Cm, pos)


# ---------------------------------------------------------------------------
# the sweep
# ---------------------------------------------------------------------------

def tune_key(key: ShapeKey, cache: Optional[TuneCache] = None,
             rounds: int = 3, include_pallas: Optional[bool] = None,
             verbose: bool = False, obs=None) -> Dict:
    """Measure the candidate space at ``key``, cache and return the winner.

    Candidates that fail to build/compile are dropped (a knob combination
    can be invalid for a shape); at least the default-equivalent candidates
    always survive. ``obs`` (repro.obs.Obs) records one ``tune.sweep`` span
    per key with nested ``tune.candidate`` compile probes, plus
    ``tune.sweeps`` / ``tune.candidates`` counters."""
    if obs is None:
        from repro.obs import Obs
        obs = Obs.off()
    tr = obs.tracer
    if include_pallas is None:
        include_pallas = _pallas_usable()
    cands = space_for(key, include_pallas=include_pallas)
    args = synth_args(key)
    cells: List[Tuple[str, object]] = []
    by_name: Dict[str, Dict] = {}
    ssid = tr.start("tune.sweep", track="tune", key=key.encode(),
                    candidates=len(cands))
    for c in cands:
        name = candidate_name(c)
        try:
            with tr.span("tune.candidate", track="tune", cand=name):
                thunk = make_thunk(key, c, args)
                thunk()       # build + compile probe outside the timed loop
        except Exception as e:
            if verbose:
                print(f"#   tune drop {name}: {type(e).__name__}: {e}")
            continue
        cells.append((name, thunk))
        by_name[name] = c
    if not cells:
        tr.finish(ssid, viable=0)
        raise RuntimeError(f"no viable candidates for {key.encode()}")
    best_us, _ = _timing()(cells, rounds=rounds, warmup=1)
    win = min(best_us, key=best_us.get)
    obs.metrics.counter("tune.sweeps").inc()
    obs.metrics.counter("tune.candidates").inc(len(cells))
    tr.finish(ssid, viable=len(cells), winner=win,
              winner_us=best_us[win])
    if verbose:
        ranked = sorted(best_us.items(), key=lambda kv: kv[1])
        print(f"# tune {key.encode()}: " +
              "  ".join(f"{n}={us:.0f}us" for n, us in ranked[:4]) +
              (f"  (+{len(ranked) - 4} more)" if len(ranked) > 4 else ""))
    knobs = by_name[win]
    if cache is not None:
        cache.put(key, knobs, best_us[win], candidates=len(cells))
    return knobs


def ensure(op: str, *, B: int, L: int, D: int = 0, N: int = 0, H: int = 0,
           dh: int = 0, dtype="float32", reset_density=None,
           objective: str = "fwd", cache: Optional[TuneCache] = None,
           rounds: int = 3, include_pallas: Optional[bool] = None,
           force: bool = False, verbose: bool = False, obs=None) -> bool:
    """Tune ``op`` at this shape unless its exact bucketed key is already
    cached. Returns True iff a new measurement was taken."""
    c = cache if cache is not None else get_cache()
    key = shape_key(op, dtype=dtype, B=B, L=L, D=D, N=N, H=H, dh=dh,
                    reset_density=reset_density, objective=objective)
    if not force and c.get(key) is not None:
        return False
    tune_key(key, cache=c, rounds=rounds, include_pallas=include_pallas,
             verbose=verbose, obs=obs)
    return True


# ---------------------------------------------------------------------------
# bounded default sweeps (make bench-tune)
# ---------------------------------------------------------------------------

def sweep_grid(grid: str) -> List[ShapeKey]:
    """The named bounded sweeps. ``fig2`` mirrors the benchmark matrix —
    including the wide-head (dh ≫ T) cell that gives the dual-form
    evaluator a real shot at winning."""
    keys = []
    if grid == "small":
        keys.append(shape_key("selective_scan", B=1, L=128, D=64, N=8))
        keys.append(shape_key("selective_scan_heads", B=1, L=128, H=4,
                              dh=16, N=8))
        return keys
    if grid != "fig2":
        raise ValueError(f"unknown grid {grid!r}")
    for L in (256, 512, 1024, 2048, 4096):
        keys.append(shape_key("selective_scan", B=1, L=L, D=256, N=16))
        keys.append(shape_key("selective_scan_heads", B=1, L=L, H=4,
                              dh=64, N=16))
        # wide heads at matched channels: dh ≫ the small blocked chunks —
        # the shape family where the C·Bᵀ dual form beats the quad form
        keys.append(shape_key("selective_scan_heads", B=1, L=L, H=2,
                              dh=128, N=16))
    return keys


def main(argv=None):
    ap = argparse.ArgumentParser(description="scan-schedule autotune sweep")
    ap.add_argument("--out", default=None,
                    help="cache path (default: $REPRO_TUNE_CACHE or "
                         "TUNE_CACHE.json)")
    ap.add_argument("--grid", default="fig2", choices=["small", "fig2"])
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--force", action="store_true",
                    help="re-measure keys already in the cache")
    ap.add_argument("--include-pallas", action="store_true",
                    help="force pallas candidates into the space (default: "
                         "only on real TPU)")
    ap.add_argument("--objective", default="fwd",
                    choices=["fwd", "fwdbwd", "both"],
                    help="time forward only (serving), forward+backward "
                         "(training), or sweep both")
    args = ap.parse_args(argv)
    cache = get_cache(args.out)
    objectives = ("fwd", "fwdbwd") if args.objective == "both" \
        else (args.objective,)
    n_new = 0
    for base in sweep_grid(args.grid):
        for obj in objectives:
            key = dataclasses.replace(base, objective=obj)
            if not args.force and cache.get(key) is not None:
                continue
            tune_key(key, cache=cache, rounds=args.rounds,
                     include_pallas=True if args.include_pallas else None,
                     verbose=True)
            n_new += 1
    path = cache.save(args.out)
    print(f"# tuned {n_new} new key(s); {len(cache.entries)} total -> {path}")


if __name__ == "__main__":
    main()
