"""Persistent shape-keyed tuning cache (``TUNE_CACHE.json``).

The cache maps ``ShapeKey`` → measured-best knob dict. It is fingerprinted
by (device kind, platform, jax version): measurements from a v5e are
meaningless on a CPU host, so a fingerprint mismatch marks the cache
*stale* — entries are kept for reporting but never served, which forces a
re-tune (``lookup`` misses, ``tuned()`` falls back to defaults).

Lookup never blocks on an unseen shape: exact bucketed hit first, then the
nearest key for the same operator (log-distance over the shape axes), then
``None`` — the caller's hard-coded defaults. Tuning is something launchers
do at startup (``warm``), not something the hot path ever waits on.

CLI (the ``make tune-check`` gate):

    PYTHONPATH=src python -m repro.tune.cache --check [TUNE_CACHE.json]

exits 0 with an OK/STALE report (stale is a clean, expected state on any
machine other than the one that tuned), 1 only when the file is missing or
unreadable.
"""
from __future__ import annotations

import json
import math
import os
from typing import Dict, Optional, Tuple

from repro.tune.space import ShapeKey

SCHEMA = 1
DEFAULT_PATH = "TUNE_CACHE.json"
ENV_PATH = "REPRO_TUNE_CACHE"


def fingerprint() -> Dict[str, str]:
    import jax
    dev = jax.devices()[0]
    return {"schema": SCHEMA, "device_kind": str(dev.device_kind),
            "platform": str(dev.platform), "jax": jax.__version__}


class TuneCache:
    """In-memory view of one tuning-cache file."""

    def __init__(self, fp: Optional[Dict] = None):
        self.fp = dict(fp) if fp is not None else fingerprint()
        self.entries: Dict[str, Dict] = {}     # key.encode() -> record
        self.stale_entries: Dict[str, Dict] = {}
        self.stale_fp: Optional[Dict] = None   # fingerprint of the above
        self.path: Optional[str] = None

    # ------------------------------------------------------------ mutation
    def put(self, key: ShapeKey, knobs: Dict, us: float,
            candidates: int = 0) -> None:
        self.entries[key.encode()] = {
            "knobs": dict(knobs), "us": round(float(us), 1),
            "candidates": int(candidates)}

    # ------------------------------------------------------------- lookup
    def get(self, key: ShapeKey) -> Optional[Dict]:
        rec = self.entries.get(key.encode())
        return dict(rec["knobs"]) if rec else None

    def lookup(self, key: ShapeKey, nearest: bool = True,
               max_distance: float = 4.0
               ) -> Tuple[Optional[Dict], Optional[str]]:
        """Returns (knobs, how) — how ∈ {"exact", "nearest", None}.

        The nearest-key fallback is bounded by ``max_distance``: knob
        winners are regime-specific (e.g. the whole-trajectory
        'associative' method is only offered at short L because it
        materializes (B, L, D, N)), so serving them to an arbitrarily
        distant shape could trade a miss for an OOM. Beyond the cutoff the
        lookup misses and the caller's defaults stand — the documented
        never-blocks contract. At the default weights, 4.0 ≈ two octaves
        of L or four octaves of a secondary axis."""
        hit = self.get(key)
        if hit is not None:
            return hit, "exact"
        if not nearest:
            return None, None
        best, best_d = None, math.inf
        for ks, rec in self.entries.items():
            k = ShapeKey.decode(ks)
            # objective isolation: a fwd winner must never be served to a
            # fwdbwd query (recompute structure flips winners) — same hard
            # boundary as the operator itself
            if k.op != key.op or k.objective != key.objective:
                continue
            d = _distance(key, k)
            if d < best_d:
                best, best_d = rec, d
        if best is None or best_d > max_distance:
            return None, None
        return dict(best["knobs"]), "nearest"

    # ---------------------------------------------------------- persistence
    def save(self, path: Optional[str] = None) -> str:
        """Write the cache. Quarantined foreign-fingerprint entries are
        written back under a ``stale`` section — saving on machine B must
        not destroy machine A's measurements in a shared/committed file
        (A's ``load`` resurrects them from the stale section)."""
        path = path or self.path or DEFAULT_PATH
        doc = {"fingerprint": self.fp,
               "entries": {k: self.entries[k] for k in sorted(self.entries)}}
        if self.stale_entries:
            doc["stale"] = {"fingerprint": self.stale_fp,
                            "entries": {k: self.stale_entries[k]
                                        for k in sorted(self.stale_entries)}}
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        self.path = path
        return path

    @property
    def stale(self) -> bool:
        return bool(self.stale_entries)

    @classmethod
    def load(cls, path: str, fp: Optional[Dict] = None) -> "TuneCache":
        """Load ``path``; entries measured under a different fingerprint are
        quarantined in ``stale_entries`` (lookup never serves them, save
        preserves them). A ``stale`` section whose fingerprint matches the
        CURRENT machine is resurrected as live entries — round-tripping a
        shared cache file through a foreign machine loses nothing."""
        with open(path) as f:
            doc = json.load(f)
        current = dict(fp) if fp is not None else fingerprint()
        cache = cls(fp=current)
        cache.path = path
        buckets = [(doc.get("fingerprint"), dict(doc.get("entries", {})))]
        st = doc.get("stale")
        if st:
            buckets.append((st.get("fingerprint"),
                            dict(st.get("entries", {}))))
        for bfp, entries in buckets:
            if bfp == current:
                cache.entries.update(entries)
            elif entries:
                cache.stale_entries.update(entries)
                cache.stale_fp = bfp
        return cache


def _distance(a: ShapeKey, b: ShapeKey) -> float:
    """Log-scale shape distance for the nearest-key fallback."""
    def lg(x, y):
        return abs(math.log2(max(x, 1)) - math.log2(max(y, 1)))
    d = 2.0 * lg(a.Lb, b.Lb)            # schedule winners flip fastest in L
    d += lg(a.D, b.D) + lg(a.N, b.N) + lg(a.H, b.H) + lg(a.dh, b.dh)
    d += 0.5 * lg(a.B, b.B)
    if a.resets != b.resets:
        d += 0.5
    if a.dtype != b.dtype:
        d += 0.25
    return d


# ---------------------------------------------------------------------------
# process-wide cache registry (what ``tuned()`` resolves against)
# ---------------------------------------------------------------------------

_CACHES: Dict[str, TuneCache] = {}


def default_path() -> str:
    return os.environ.get(ENV_PATH, DEFAULT_PATH)


def get_cache(path: Optional[str] = None) -> TuneCache:
    """Memoized cache handle for ``path`` (default: $REPRO_TUNE_CACHE or
    ./TUNE_CACHE.json). A missing file yields an empty, writable cache."""
    path = path or default_path()
    if path not in _CACHES:
        if os.path.exists(path):
            _CACHES[path] = TuneCache.load(path)
        else:
            c = TuneCache()
            c.path = path
            _CACHES[path] = c
    return _CACHES[path]


def set_cache(cache: TuneCache, path: Optional[str] = None) -> None:
    _CACHES[path or cache.path or default_path()] = cache


def reset_caches() -> None:
    """Drop all memoized handles (tests)."""
    _CACHES.clear()


def _main():
    import argparse
    ap = argparse.ArgumentParser(description="tune-cache health check")
    ap.add_argument("path", nargs="?", default=None)
    ap.add_argument("--check", action="store_true")
    args = ap.parse_args()
    path = args.path or default_path()
    if not os.path.exists(path):
        print(f"# tune-check: MISSING {path} (run `make bench-tune`)")
        raise SystemExit(1)
    try:
        cache = TuneCache.load(path)
    except Exception as e:  # corrupt file
        print(f"# tune-check: UNREADABLE {path}: {e}")
        raise SystemExit(1)
    fp = cache.fp
    if cache.stale:
        print(f"# tune-check: STALE {path} — {len(cache.stale_entries)} "
              f"entry(ies) measured under a different fingerprint; current "
              f"{fp['platform']}/{fp['device_kind']}/jax-{fp['jax']}. "
              f"Re-tune with `make bench-tune` to use them here.")
    else:
        print(f"# tune-check: OK {path} — {len(cache.entries)} entry(ies) "
              f"valid for {fp['platform']}/{fp['device_kind']}/"
              f"jax-{fp['jax']}")


if __name__ == "__main__":
    _main()
