"""Declarative tunable space per scan operator + shape-key bucketing.

PackMamba's method is shape analysis: the winning parallelization for the
bottleneck operators flips with (L, D, N, H, dh) and device, so every knob
the repo used to hard-code (``DEF_SUB_T``, the matmul-intra chunk cap, the
heads chunk cap, the CPU-vs-MXU ``intra`` auto-pick) is expressed here as a
*candidate list* the runner can measure. A candidate is a plain JSON-able
dict of knobs:

  backend   "xla" | "pallas"
  method    xla scan schedule ("blocked" | "chunked" | "fused_seq" |
            "sequential" | "associative")
  chunk     xla chunk length T
  intra     blocked in-chunk evaluator — per-channel op: "matmul" | "assoc";
            heads op: "quad" (state-form dec @ b) | "dual" (C·Bᵀ
            attention-like form, wins when dh ≫ T)
  schedule  pallas kernel ("step" | "blocked" | "blocked_heads" |
            "blocked_heads_dual")
  pchunk    pallas chunk length
  sub_t     pallas in-chunk subtile (None = kernel default)

Shape keys bucket the continuous axes so one measurement serves a
neighborhood: L to the next power of two, reset density to four named
bands. Everything else (B, D, N, H, dh, dtype) is kept exact — the
nearest-key fallback in cache.py absorbs the remaining variation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

OPS = ("selective_scan", "selective_scan_heads")

# what a sweep measures: "fwd" times the forward evaluation only (the
# inference/serving regime); "fwdbwd" times forward + full VJP (the
# training-step regime, where checkpoint/recompute structure can flip the
# winner). Cached winners are objective-tagged and never cross-served.
OBJECTIVES = ("fwd", "fwdbwd")

# reset-density bands: resets per token. "none" is the unpacked case; packed
# training with paper-like segment lengths (~100-600 tokens) lands in "mid".
RESET_BANDS = (("none", 0.0), ("sparse", 1 / 256), ("mid", 1 / 32),
               ("dense", 1.0))


def l_bucket(L: int) -> int:
    """Next power of two ≥ L (floor 16) — the sequence-length bucket."""
    L = max(int(L), 16)
    return 1 << (L - 1).bit_length()


def reset_bucket(density: Optional[float]) -> str:
    """Map a resets-per-token density to its named band.

    ``None`` means "packed, density unknown at trace time" → "mid" (the
    typical training regime); pass 0.0 explicitly for reset-free inputs.
    """
    if density is None:
        return "mid"
    for name, hi in RESET_BANDS:
        if density <= hi:
            return name
    return "dense"


@dataclasses.dataclass(frozen=True)
class ShapeKey:
    """Bucketed shape identity of one operator invocation."""
    op: str
    dtype: str
    B: int
    Lb: int          # l_bucket(L)
    D: int           # per-channel width (0 for the heads op)
    N: int           # state size
    H: int           # heads (0 for the per-channel op)
    dh: int          # head dim (0 for the per-channel op)
    resets: str      # reset-density band
    objective: str = "fwd"   # "fwd" | "fwdbwd" — what the sweep timed

    def encode(self) -> str:
        base = (f"{self.op}|{self.dtype}|B{self.B}|L{self.Lb}|D{self.D}|"
                f"N{self.N}|H{self.H}|dh{self.dh}|{self.resets}")
        # 10th field only for non-default objectives: committed fwd caches
        # keep their pre-objective key strings byte-identical
        return base if self.objective == "fwd" else \
            base + f"|{self.objective}"

    @classmethod
    def decode(cls, s: str) -> "ShapeKey":
        parts = s.split("|")
        if len(parts) == 9:
            parts = parts + ["fwd"]
        op, dtype, B, Lb, D, N, H, dh, resets, objective = parts
        return cls(op, dtype, int(B[1:]), int(Lb[1:]), int(D[1:]),
                   int(N[1:]), int(H[1:]), int(dh[2:]), resets, objective)


def shape_key(op: str, *, dtype="float32", B: int, L: int, D: int = 0,
              N: int = 0, H: int = 0, dh: int = 0,
              reset_density: Optional[float] = None,
              objective: str = "fwd") -> ShapeKey:
    if op not in OPS:
        raise ValueError(f"unknown op {op!r}; have {OPS}")
    if objective not in OBJECTIVES:
        raise ValueError(
            f"unknown objective {objective!r}; have {OBJECTIVES}")
    import numpy as np
    dt = np.dtype(dtype).name if dtype is not None else "float32"
    return ShapeKey(op, dt, int(B), l_bucket(L), int(D), int(N), int(H),
                    int(dh), reset_bucket(reset_density), objective)


# ---------------------------------------------------------------------------
# candidate spaces
# ---------------------------------------------------------------------------

def _xla(method, chunk=None, intra=None) -> Dict:
    c = {"backend": "xla", "method": method}
    if chunk is not None:
        c["chunk"] = int(chunk)
    if intra is not None:
        c["intra"] = intra
    return c


def _pallas(schedule, pchunk, sub_t=None) -> Dict:
    c = {"backend": "pallas", "schedule": schedule, "pchunk": int(pchunk)}
    if sub_t is not None:
        c["sub_t"] = int(sub_t)
    return c


def space_for(key: ShapeKey, include_pallas: bool = False) -> List[Dict]:
    """Bounded candidate list for one shape key.

    ``include_pallas`` should be True only where pallas timings mean
    something (real TPU, kernels not in interpret mode) — the runner decides.
    """
    L = key.Lb
    out: List[Dict] = []
    if key.op == "selective_scan_heads":
        # the heads chunk (frozen at cap 64 pre-tuner) and the quad-vs-dual
        # in-chunk evaluator are the two real discrete decisions here.
        # Candidate chunks stop at each form's safety cap (core/ssm.py
        # _HEADS_CHUNK_CAP / _HEADS_DUAL_CHUNK_CAP): anything larger would
        # silently clamp and mislabel the cached winner.
        for chunk in (16, 32, 64, 128):
            if chunk > max(16, 2 * L):
                continue
            if chunk <= 64:
                out.append(_xla("blocked", chunk, "quad"))
            out.append(_xla("blocked", chunk, "dual"))
        if L <= 128:
            out.append(_xla("sequential"))
        if include_pallas:
            for sched in ("blocked_heads", "blocked_heads_dual"):
                for pchunk in (128, 256):
                    for sub_t in (16, 32):
                        out.append(_pallas(sched, min(pchunk, L), sub_t))
    else:
        # per-channel: the matmul-intra chunk cap (frozen at 32) vs the
        # assoc-tree chunk, plus the legacy whole-trajectory schedules
        for chunk in (8, 16, 32):
            out.append(_xla("blocked", chunk, "matmul"))
        for chunk in (64, 128, 256):
            out.append(_xla("blocked", min(chunk, L), "assoc"))
        out.append(_xla("chunked", min(256, L)))
        out.append(_xla("fused_seq"))
        if L <= 1024:      # materializes (B, L, D, N): only viable when small
            out.append(_xla("associative"))
        if include_pallas:
            for sched in ("step", "blocked"):
                for pchunk in (128, 256):
                    c = _pallas(sched, min(pchunk, L))
                    if sched == "blocked":
                        for sub_t in (8, 16):
                            out.append({**c, "sub_t": sub_t})
                    else:
                        out.append(c)
    # dedup (chunk clamping can collide candidates at small L)
    seen, uniq = set(), []
    for c in out:
        k = tuple(sorted(c.items()))
        if k not in seen:
            seen.add(k)
            uniq.append(c)
    return uniq


def candidate_name(c: Dict) -> str:
    if c.get("backend") == "pallas":
        st = c.get("sub_t")
        return f"pallas/{c['schedule']}/T{c['pchunk']}" + \
            (f"/t{st}" if st else "")
    parts = [c["method"]]
    if "chunk" in c:
        parts.append(f"T{c['chunk']}")
    if c.get("intra"):
        parts.append(c["intra"])
    return "xla/" + "/".join(parts)
