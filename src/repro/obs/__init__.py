"""repro.obs — unified telemetry: metrics registry + span tracing.

The ``Obs`` bundle is what instrumented code receives: a (always-on,
cheap) ``MetricsRegistry`` plus a ``Tracer`` that is either recording or
the no-op ``NULL_TRACER``. Construct with:

    obs = Obs.off()                  # default: metrics only, no tracing
    obs = Obs.on()                   # record spans too
    obs = Obs.on(clock=fake_clock)   # deterministic tests

and at the end of a traced run:

    obs.export("trace.json")         # Chrome trace + metric snapshot
    print(obs.tracer.timeline())     # plain-text per-track view
    print(obs.metrics.prometheus_text())

Metric names are dotted (``serve.prefills``, ``train.real_tokens``,
``data.prefetch_wait_ms``) — the catalogue lives in obs/README.md.
See obs/metrics.py and obs/trace.py for the pieces; obs/profile.py for
the optional jax.profiler bridge; obs/check.py for the trace validator
that ``make obs-smoke`` runs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Union

from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      percentiles)
from .trace import NULL_TRACER, NullTracer, Tracer
from .profile import profile_region, profiler_session, step_region

__all__ = [
    "Obs", "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "percentiles", "Tracer", "NullTracer", "NULL_TRACER",
    "profile_region", "step_region", "profiler_session",
]


@dataclasses.dataclass
class Obs:
    """Telemetry bundle handed to ServeEngine / Trainer / loaders."""

    metrics: MetricsRegistry
    tracer: Union[Tracer, NullTracer]

    @classmethod
    def off(cls, clock: Callable[[], float] = time.time) -> "Obs":
        """Metrics only (tracing disabled — the default everywhere)."""
        return cls(metrics=MetricsRegistry(clock=clock), tracer=NULL_TRACER)

    @classmethod
    def on(cls, clock: Optional[Callable[[], float]] = None,
           span_clock: Optional[Callable[[], float]] = None,
           max_events: int = 1_000_000) -> "Obs":
        """Metrics + recording tracer. ``clock`` overrides both the
        registry stamp clock and the span clock (scripted-clock tests);
        ``span_clock`` overrides just the tracer's."""
        reg = MetricsRegistry(clock=clock or time.time)
        tr = Tracer(clock=span_clock or clock or time.perf_counter,
                    max_events=max_events)
        return cls(metrics=reg, tracer=tr)

    @property
    def enabled(self) -> bool:
        return self.tracer.enabled

    def export(self, path: str) -> str:
        """Dump the Chrome trace (with the metric snapshot embedded)."""
        return self.tracer.export(path, metrics=self.metrics.to_dict())
