"""Metrics registry: counters, gauges, fixed-bucket histograms.

One registry is ONE source of numbers for a process: ``ServeStats`` and the
``Trainer``'s token metering are thin views over counters registered here,
so the CLI summary line, the benchmark JSON, and a Prometheus scrape can
never disagree. Everything is host-side and cheap — a counter increment is
a lock + an int add — so the registry is always on; only *tracing*
(obs/trace.py) has an explicit off switch.

Thread-safety: the serve engine's prefill pool lands from the main thread,
but the prefetch loader's worker thread and the checkpoint manager's async
saver may observe metrics concurrently — every metric mutation takes the
metric's own lock (a bare ``+=`` on a Python int is NOT atomic: the
read-add-write interleaves under the GIL).

``percentiles()`` is THE percentile implementation for the repo: TTFT,
ITL, and histogram summaries all route through it, so the degenerate cases
(no samples → {}, a single sample → every percentile equals it, duplicate
values) behave identically everywhere.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


def percentiles(values: Sequence[float],
                pcts: Sequence[float] = (50, 95),
                weights: Optional[Sequence[float]] = None) -> Dict[str, float]:
    """``{"p50": v, ...}`` over ``values`` — the repo's one percentile
    implementation (ServeStats TTFT/ITL, histogram summaries, benchmark
    reports).

    Degenerate cases, uniformly: no samples (or all-zero weights) → ``{}``;
    a single sample → every requested percentile equals it; duplicate
    values interpolate exactly like ``np.percentile(..., "linear")``.

    ``weights`` generalizes to weighted samples (a histogram's bucket
    bounds weighted by bucket counts): the result is exactly
    ``np.percentile`` of the multiset where each value appears ``weight``
    times, computed without materializing it.
    """
    vals = np.asarray(values, np.float64)
    if vals.size == 0:
        return {}
    if weights is None:
        w = np.ones(vals.size)
    else:
        w = np.asarray(weights, np.float64)
        if w.shape != vals.shape:
            raise ValueError(f"weights shape {w.shape} != values shape "
                             f"{vals.shape}")
        if (w < 0).any():
            raise ValueError("weights must be non-negative")
    order = np.argsort(vals, kind="stable")
    vals, w = vals[order], w[order]
    keep = w > 0
    vals, w = vals[keep], w[keep]
    total = w.sum()
    if total == 0:
        return {}
    # rank space of the expanded multiset: value i occupies integer ranks
    # [cum_{i-1}, cum_i); np.percentile's "linear" method sits percentile p
    # at fractional rank p/100 * (n - 1)
    cum = np.cumsum(w)
    out = {}
    for p in pcts:
        r = p / 100.0 * (total - 1)
        lo = float(vals[np.searchsorted(cum, np.floor(r), side="right")])
        hi = float(vals[np.searchsorted(cum, np.ceil(r), side="right")])
        frac = r - np.floor(r)
        out[f"p{p:g}"] = lo + (hi - lo) * float(frac)
    return out


class Counter:
    """Monotonic-by-convention integer/float counter. ``set()`` exists so
    stats views can alias it as a plain attribute (``st.shed += 1`` reads
    then writes) and benchmarks can reset between rounds."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n=1):
        with self._lock:
            self._value += n

    def set(self, v):
        with self._lock:
            self._value = v

    @property
    def value(self):
        with self._lock:
            return self._value


class Gauge(Counter):
    """A value that goes up and down (queue depth, cumulative phase ms)."""

    kind = "gauge"

    def add(self, v):
        self.inc(v)

    def max_of(self, v):
        with self._lock:
            self._value = max(self._value, v)


class Histogram:
    """Fixed-bucket histogram: ``buckets`` are inclusive upper bounds (an
    implicit +inf bucket catches the tail). ``observe()`` is O(#buckets);
    ``summary()`` estimates percentiles from the bucket counts through the
    shared ``percentiles()`` helper (each bucket contributes its upper
    bound weighted by its count — an upper-bound estimate, exact when
    observations sit on bucket bounds)."""

    kind = "histogram"

    def __init__(self, name: str, buckets: Sequence[float], help: str = ""):
        if not buckets or list(buckets) != sorted(buckets):
            raise ValueError(f"histogram buckets must be a non-empty "
                             f"ascending sequence, got {buckets!r}")
        self.name = name
        self.help = help
        self.bounds: Tuple[float, ...] = tuple(float(b) for b in buckets)
        self._lock = threading.Lock()
        self.counts = [0] * (len(self.bounds) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float):
        i = int(np.searchsorted(self.bounds, v, side="left"))
        with self._lock:
            self.counts[i] += 1
            self.sum += v
            self.count += 1

    def summary(self, pcts: Sequence[float] = (50, 95)) -> Dict[str, float]:
        with self._lock:
            counts = list(self.counts)
            total, s = self.count, self.sum
        if not total:
            return {}
        # the +inf tail bucket reports as the largest finite bound (there
        # is no upper estimate for it); values/weights feed the shared
        # percentile implementation
        vals = list(self.bounds) + [self.bounds[-1]]
        out = percentiles(vals, pcts, weights=counts)
        out["count"] = total
        out["mean"] = s / total
        return out


class MetricsRegistry:
    """Named metrics with idempotent registration and an injectable clock
    (`clock` stamps the Prometheus export and lets tests freeze time)."""

    def __init__(self, clock: Callable[[], float] = time.time):
        self._lock = threading.Lock()
        self._metrics: Dict[str, object] = {}
        self.clock = clock

    def _get(self, name: str, factory, kind):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = factory()
            elif m.kind != kind:
                raise ValueError(f"metric {name!r} already registered as "
                                 f"{m.kind}, not {kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, lambda: Counter(name, help), "counter")

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, lambda: Gauge(name, help), "gauge")

    def histogram(self, name: str, buckets: Sequence[float],
                  help: str = "") -> Histogram:
        return self._get(name, lambda: Histogram(name, buckets, help),
                         "histogram")

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def to_dict(self) -> Dict[str, object]:
        """Plain scalars for JSON export: counters/gauges as numbers,
        histograms as {count, mean, p50, p95} summaries."""
        out = {}
        with self._lock:
            items = list(self._metrics.items())
        for name, m in sorted(items):
            if m.kind == "histogram":
                out[name] = m.summary()
            else:
                out[name] = m.value
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition (counters/gauges as-is, histograms as
        cumulative ``_bucket``/``_sum``/``_count`` series). Metric names
        swap "." for "_" — the registry's dotted names are the catalogue
        (obs/README.md), Prometheus wants underscores."""
        lines = []
        with self._lock:
            items = list(self._metrics.items())
        for name, m in sorted(items):
            pn = name.replace(".", "_").replace("-", "_")
            if m.help:
                lines.append(f"# HELP {pn} {m.help}")
            lines.append(f"# TYPE {pn} {m.kind}")
            if m.kind == "histogram":
                acc = 0
                for b, c in zip(m.bounds, m.counts):
                    acc += c
                    lines.append(f'{pn}_bucket{{le="{b:g}"}} {acc}')
                acc += m.counts[-1]
                lines.append(f'{pn}_bucket{{le="+Inf"}} {acc}')
                lines.append(f"{pn}_sum {m.sum:g}")
                lines.append(f"{pn}_count {m.count}")
            else:
                lines.append(f"{pn} {m.value:g}")
        return "\n".join(lines) + "\n"
