"""Validate exported Chrome traces — the ``make obs-smoke`` checker.

    PYTHONPATH=src python -m repro.obs.check trace.json \
        --require serve.prefills --require serve.generated

Checks, per file:
  * the JSON parses and has a ``traceEvents`` list;
  * every event carries the Chrome trace-event schema fields
    (``ph``/``ts``/``pid``/``tid`` and, for B/E/i/M, ``name``);
  * begin/end events are balanced AND well-nested per (pid, tid) track
    (an "E" must close the innermost open "B" with the same name — the
    contract chrome://tracing and Perfetto assume);
  * timestamps are non-negative and non-decreasing within each span;
  * each ``--require NAME`` metric is present in the embedded ``metrics``
    snapshot (and, for plain numbers, > 0 unless --allow-zero).

Exit code 0 when every file passes; 1 with a per-file error report
otherwise.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List


def check_trace(path: str, require: List[str] = (),
                allow_zero: bool = False) -> List[str]:
    """Return a list of problems (empty == valid)."""
    errs: List[str] = []
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return [f"unreadable: {e}"]
    if isinstance(doc, list):          # bare-array variant is legal Chrome
        events, metrics = doc, {}
    elif isinstance(doc, dict):
        events = doc.get("traceEvents")
        metrics = doc.get("metrics", {})
        if not isinstance(events, list):
            return ["no traceEvents list"]
    else:
        return [f"top level must be object or array, got {type(doc)}"]

    stacks = {}                        # (pid, tid) -> [open B names]
    n_b = n_e = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errs.append(f"event[{i}] not an object")
            continue
        for field in ("ph", "ts", "pid", "tid"):
            if field not in ev:
                errs.append(f"event[{i}] missing {field!r}")
        ph = ev.get("ph")
        if ph in ("B", "E", "i", "I", "M", "X") and "name" not in ev:
            errs.append(f"event[{i}] ph={ph!r} missing 'name'")
        ts = ev.get("ts")
        if isinstance(ts, (int, float)) and ts < 0:
            errs.append(f"event[{i}] negative ts {ts}")
        key = (ev.get("pid"), ev.get("tid"))
        if ph == "B":
            n_b += 1
            stacks.setdefault(key, []).append((ev.get("name"), ts))
        elif ph == "E":
            n_e += 1
            stack = stacks.get(key)
            if not stack:
                errs.append(f"event[{i}] 'E' {ev.get('name')!r} on track "
                            f"{key} with no open span")
                continue
            name, t0 = stack.pop()
            if ev.get("name") != name:
                errs.append(f"event[{i}] 'E' {ev.get('name')!r} does not "
                            f"close innermost 'B' {name!r} on track {key}")
            if (isinstance(ts, (int, float))
                    and isinstance(t0, (int, float)) and ts < t0):
                errs.append(f"event[{i}] span {name!r} ends ({ts}) before "
                            f"it starts ({t0})")
    for key, stack in stacks.items():
        if stack:
            errs.append(f"track {key}: {len(stack)} unclosed span(s): "
                        f"{[n for n, _ in stack]}")
    if n_b != n_e:
        errs.append(f"unbalanced: {n_b} 'B' events vs {n_e} 'E' events")

    for name in require:
        if name not in metrics:
            errs.append(f"required metric {name!r} missing from snapshot "
                        f"(have {len(metrics)} metrics)")
        elif (not allow_zero and isinstance(metrics[name], (int, float))
                and metrics[name] <= 0):
            errs.append(f"required metric {name!r} is {metrics[name]} "
                        f"(expected > 0)")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("traces", nargs="+", help="Chrome trace JSON files")
    ap.add_argument("--require", action="append", default=[],
                    metavar="METRIC",
                    help="metric that must be present (and > 0) in the "
                         "embedded snapshot; repeatable")
    ap.add_argument("--allow-zero", action="store_true",
                    help="required metrics may be 0")
    args = ap.parse_args(argv)
    bad = 0
    for path in args.traces:
        errs = check_trace(path, args.require, args.allow_zero)
        if errs:
            bad += 1
            print(f"FAIL {path}")
            for e in errs[:20]:
                print(f"  - {e}")
            if len(errs) > 20:
                print(f"  ... and {len(errs) - 20} more")
        else:
            with open(path) as f:
                doc = json.load(f)
            n = len(doc["traceEvents"] if isinstance(doc, dict) else doc)
            print(f"OK   {path} ({n} events)")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
