"""Span tracing with Chrome trace-event export.

A ``Tracer`` records begin/end ("B"/"E") span events and instant ("i")
annotations onto named *tracks* (Chrome tids): the serve engine puts its
phases on an ``engine`` track and gives every request its own ``req<rid>``
track, so the exported JSON opens directly in ``chrome://tracing`` or
https://ui.perfetto.dev as one row per request — queued → prefill →
decode, with shed/expired/quarantined markers where they happened.

The OFF state is ``NULL_TRACER`` — a no-op object with the full API, so
instrumented code never branches on "is tracing on?" and the disabled cost
is one attribute lookup + an empty method call per site. Token streams,
schedules, and compiled HLO are untouched either way: the tracer only ever
*reads* host-observable time.

Design points:
  * explicit timestamps — ``start()/finish()`` stamp from the injectable
    ``clock``; ``complete(name, t0, t1)`` records a span from timestamps
    the caller already took (the serve engine's phase split measures with
    ``time.perf_counter`` whether or not tracing is on).
  * spans may cross call boundaries: ``start()`` returns a span id that
    ``finish()`` closes later (a request's "queued" span starts in
    ``submit()`` and ends at admission, many engine steps later). Within
    one track spans must nest (Chrome's B/E contract); separate tracks are
    independent.
  * thread-safe appends — the prefetch worker and the main thread may
    both emit.
  * bounded: past ``max_events`` new events are dropped and counted
    (``dropped``) instead of growing without bound.
"""
from __future__ import annotations

import contextlib
import json
import threading
import time
from typing import Callable, Dict, List, Optional

# Chrome trace-event constants
_B, _E, _I, _META = "B", "E", "i", "M"


class _SpanCtx:
    """Context manager for ``Tracer.span`` (reused for with-statements)."""

    __slots__ = ("tracer", "name", "track", "args", "sid")

    def __init__(self, tracer, name, track, args):
        self.tracer = tracer
        self.name = name
        self.track = track
        self.args = args
        self.sid = None

    def __enter__(self):
        self.sid = self.tracer.start(self.name, track=self.track,
                                     **self.args)
        return self

    def __exit__(self, *exc):
        self.tracer.finish(self.sid)
        return False


class Tracer:
    """Records spans/instants; exports Chrome trace JSON + text timelines."""

    enabled = True

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 pid: int = 1, process_name: str = "repro",
                 max_events: int = 1_000_000):
        self.clock = clock
        self.pid = pid
        self.process_name = process_name
        self.max_events = max_events
        self.dropped = 0
        self._lock = threading.Lock()
        self._events: List[dict] = []
        self._tracks: Dict[str, int] = {}      # track name -> tid
        self._spans: Dict[int, dict] = {}      # open span id -> B event
        self._next_sid = 0

    # ------------------------------------------------------------- plumbing
    def _tid(self, track: Optional[str]) -> int:
        if track is None:
            track = "main"
        tid = self._tracks.get(track)
        if tid is None:
            tid = self._tracks[track] = len(self._tracks)
            self._emit({"ph": _META, "name": "thread_name", "ts": 0,
                        "pid": self.pid, "tid": tid,
                        "args": {"name": track}})
        return tid

    def _emit(self, ev: dict):
        if len(self._events) >= self.max_events:
            self.dropped += 1
            return
        self._events.append(ev)

    # ------------------------------------------------------------ recording
    def start(self, name: str, track: Optional[str] = None, **args) -> int:
        """Open a span; returns the id ``finish()`` closes. ``args`` become
        the Chrome event's ``args`` payload (attributes)."""
        ts = self.clock() * 1e6
        with self._lock:
            sid = self._next_sid
            self._next_sid += 1
            ev = {"ph": _B, "name": name, "ts": ts, "pid": self.pid,
                  "tid": self._tid(track), "args": args}
            self._emit(ev)
            self._spans[sid] = ev
        return sid

    def finish(self, sid: Optional[int], **args) -> None:
        """Close a span opened by ``start``. Unknown/None ids are ignored
        (a request may have no open span at a terminal transition)."""
        if sid is None:
            return
        ts = self.clock() * 1e6
        with self._lock:
            b = self._spans.pop(sid, None)
            if b is None:
                return
            self._emit({"ph": _E, "name": b["name"], "ts": max(ts, b["ts"]),
                        "pid": self.pid, "tid": b["tid"], "args": args})

    def span(self, name: str, track: Optional[str] = None,
             **args) -> _SpanCtx:
        """``with tracer.span("serve.decode_step", active=3): ...``"""
        return _SpanCtx(self, name, track, args)

    def complete(self, name: str, t0: float, t1: float,
                 track: Optional[str] = None, **args) -> None:
        """Record a span from caller-measured timestamps (same clock base
        as ``self.clock`` — seconds)."""
        with self._lock:
            tid = self._tid(track)
            self._emit({"ph": _B, "name": name, "ts": t0 * 1e6,
                        "pid": self.pid, "tid": tid, "args": args})
            self._emit({"ph": _E, "name": name, "ts": max(t0, t1) * 1e6,
                        "pid": self.pid, "tid": tid, "args": {}})

    def instant(self, name: str, track: Optional[str] = None,
                **args) -> None:
        """A point annotation (shed / expired / quarantined / compile)."""
        ts = self.clock() * 1e6
        with self._lock:
            self._emit({"ph": _I, "name": name, "ts": ts, "pid": self.pid,
                        "tid": self._tid(track), "s": "t", "args": args})

    def sync(self, x) -> None:
        """Host-sync a JAX value so the enclosing span measures device
        time, not dispatch time. No-op on the null tracer — so callers can
        leave the call in place and the OFF path never adds a sync."""
        try:
            import jax
            jax.block_until_ready(x)
        except ImportError:                      # host-only usage
            pass

    # ------------------------------------------------------------ exporting
    def chrome_events(self) -> List[dict]:
        with self._lock:
            return [dict(ev) for ev in self._events]

    def to_chrome(self, metrics: Optional[dict] = None) -> dict:
        """The Chrome trace-event JSON object (load in chrome://tracing or
        Perfetto). ``metrics`` (a ``MetricsRegistry.to_dict()``) rides
        along under an ignored-by-viewers top-level key so one artifact
        carries spans AND the metric snapshot."""
        doc = {"traceEvents": self.chrome_events(),
               "displayTimeUnit": "ms",
               "otherData": {"process": self.process_name,
                             "dropped_events": self.dropped}}
        if metrics is not None:
            doc["metrics"] = metrics
        return doc

    def export(self, path: str, metrics: Optional[dict] = None) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(metrics), f, indent=1)
        return path

    def timeline(self, track: Optional[str] = None) -> str:
        """Plain-text per-track timeline: one line per span/instant, with
        offsets from the trace start in ms and nesting by depth — the
        no-GUI view of the same events."""
        evs = self.chrome_events()
        evs = [e for e in evs if e["ph"] in (_B, _E, _I)]
        if not evs:
            return "(no events)"
        tid_name = {tid: name for name, tid in self._tracks.items()}
        t0 = min(e["ts"] for e in evs)
        lines = []
        for tname in sorted({tid_name.get(e["tid"], str(e["tid"]))
                             for e in evs}):
            if track is not None and tname != track:
                continue
            lines.append(f"-- {tname}")
            depth = 0
            open_ts: List[float] = []
            for e in sorted((e for e in evs
                             if tid_name.get(e["tid"]) == tname),
                            key=lambda e: (e["ts"], e["ph"] == _B)):
                off = (e["ts"] - t0) / 1e3
                args = ", ".join(f"{k}={v}" for k, v in
                                 e.get("args", {}).items())
                args = f"  [{args}]" if args else ""
                if e["ph"] == _B:
                    lines.append(f"  {off:9.3f}ms {'  ' * depth}"
                                 f"{e['name']}{args}")
                    depth += 1
                    open_ts.append(e["ts"])
                elif e["ph"] == _E:
                    depth = max(0, depth - 1)
                    dur = (e["ts"] - open_ts.pop()) / 1e3 if open_ts else 0.0
                    lines.append(f"  {off:9.3f}ms {'  ' * depth}"
                                 f"/{e['name']} ({dur:.3f}ms){args}")
                else:
                    lines.append(f"  {off:9.3f}ms {'  ' * depth}"
                                 f"* {e['name']}{args}")
        return "\n".join(lines)


class NullTracer(Tracer):
    """The OFF state: full Tracer API, every method a no-op. Instrumented
    code calls it unconditionally; a disabled serve engine's token streams
    are bit-identical to pre-instrumentation behaviour because nothing
    here reads the clock, takes a lock, or syncs the device."""

    enabled = False

    def __init__(self):                          # no state at all
        self.dropped = 0

    def start(self, name, track=None, **args):
        return None

    def finish(self, sid=None, **args):
        pass

    def span(self, name, track=None, **args):
        return _NULL_CTX

    def complete(self, name, t0, t1, track=None, **args):
        pass

    def instant(self, name, track=None, **args):
        pass

    def sync(self, x):
        pass

    def chrome_events(self):
        return []

    def to_chrome(self, metrics=None):
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def export(self, path, metrics=None):
        raise RuntimeError("cannot export a trace from the disabled "
                           "tracer — construct Obs.on() / Tracer() to "
                           "record one")

    def timeline(self, track=None):
        return "(tracing disabled)"


_NULL_CTX = contextlib.nullcontext()
NULL_TRACER = NullTracer()
