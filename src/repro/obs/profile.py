"""Optional jax.profiler integration with graceful degradation.

``profile_region(obs, name)`` annotates a region so it shows up in an XLA
profile (``jax.profiler.TraceAnnotation``) AND as a host span in the obs
tracer. ``step_region`` is the per-train-step variant
(``StepTraceAnnotation`` carries ``step_num`` into the profile's step
view). When jax.profiler is missing (stripped builds) or tracing is off,
both degrade cleanly: the jax side becomes a nullcontext, the host side a
NullTracer no-op — callers never branch.

``profiler_session(dir)`` wraps ``jax.profiler.start_trace/stop_trace``
for the ``--profile-dir`` flags on launch/serve.py and launch/train.py:
the captured TensorBoard-format profile lands under ``dir`` and the
context is a nullcontext when the profiler is unavailable.
"""
from __future__ import annotations

import contextlib
from typing import Iterator, Optional


def _jax_profiler():
    try:
        import jax.profiler as prof
        return prof
    except Exception:
        return None


@contextlib.contextmanager
def profile_region(obs, name: str, track: Optional[str] = None,
                   **attrs) -> Iterator[None]:
    """Host span (via ``obs.tracer``) + XLA TraceAnnotation when available.

    ``obs`` is an ``Obs`` bundle (obs/__init__.py); a disabled tracer makes
    the host half free, an absent jax.profiler makes the device half free.
    """
    prof = _jax_profiler()
    ann = (prof.TraceAnnotation(name)
           if prof is not None and hasattr(prof, "TraceAnnotation")
           else contextlib.nullcontext())
    with ann, obs.tracer.span(name, track=track, **attrs):
        yield


@contextlib.contextmanager
def step_region(obs, name: str, step: int,
                track: Optional[str] = None, **attrs) -> Iterator[None]:
    """Per-step profile_region: StepTraceAnnotation groups device ops under
    a step number in TensorBoard's profile step view."""
    prof = _jax_profiler()
    ann = (prof.StepTraceAnnotation(name, step_num=step)
           if prof is not None and hasattr(prof, "StepTraceAnnotation")
           else contextlib.nullcontext())
    with ann, obs.tracer.span(name, track=track, step=step, **attrs):
        yield


@contextlib.contextmanager
def profiler_session(profile_dir: Optional[str]) -> Iterator[bool]:
    """Capture an XLA profile into ``profile_dir`` for the duration of the
    block (the --profile-dir flag). Yields whether a capture is actually
    running: False when dir is None or jax.profiler lacks start_trace."""
    prof = _jax_profiler()
    if not profile_dir or prof is None or not hasattr(prof, "start_trace"):
        yield False
        return
    prof.start_trace(profile_dir)
    try:
        yield True
    finally:
        prof.stop_trace()
