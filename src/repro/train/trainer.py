"""Training loop: grad accumulation, mixed precision, checkpoint/restart,
SIGTERM-safe emergency save, deterministic data replay, throughput metering.

Distribution notes (the 1000+-node posture, exercised by the dry-run):
  * train_step is built once and jit'ed with in/out shardings from
    distributed/sharding.py — batch over ("pod","data"), params FSDP×TP.
  * gradient accumulation runs as a lax.scan over microbatches with an f32
    (or bf16 — ``grad_accum_dtype``, the memory-compression knob) carried
    accumulator; XLA overlaps the per-microbatch reduce-scatter with the
    next microbatch's backward (latency-hiding scheduler, enabled in
    launch/train.py flags).
  * elastic restart: checkpoints are mesh-agnostic (see checkpoint.py);
    `Trainer.restore()` re-device_puts onto the current mesh.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamW, AdamWState
from repro.checkpoint.checkpoint import CheckpointManager
from repro.obs import Obs


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    accum: int = 1                       # gradient-accumulation microbatches
    grad_accum_dtype: Optional[str] = None   # "bfloat16" halves accum HBM
    log_every: int = 10
    ckpt_every: int = 0                  # 0 = no periodic checkpoints
    ckpt_dir: Optional[str] = None
    keep_ckpts: int = 3


def make_train_step(model, opt: AdamW, accum: int = 1,
                    grad_accum_dtype: Optional[str] = None) -> Callable:
    """Returns step_fn(state, batch) -> (state, metrics).

    state = {"params": ..., "opt": AdamWState}; batch leaves have leading
    global-batch dim divisible by ``accum``.
    """

    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb)
        return loss, metrics

    def step_fn(state, batch):
        params = state["params"]
        if accum <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            adt = jnp.dtype(grad_accum_dtype) if grad_accum_dtype else \
                jnp.float32
            mb = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) +
                                    x.shape[1:]), batch)

            def micro(carry, mbatch):
                gacc, lacc = carry
                (l, met), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mbatch)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(adt), gacc, g)
                return (gacc, lacc + l), met

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
            (grads, lsum), mets = jax.lax.scan(micro, (g0, 0.0), mb)
            grads = jax.tree.map(lambda g: (g / accum).astype(jnp.float32),
                                 grads)
            loss = lsum / accum
            metrics = jax.tree.map(lambda m: m.mean(), mets)
        new_params, new_opt, stats = opt.update(grads, state["opt"], params)
        metrics = dict(metrics, loss=loss, **stats)
        return {"params": new_params, "opt": new_opt}, metrics

    return step_fn


class Trainer:
    def __init__(self, model, opt: AdamW, loader, cfg: TrainerConfig,
                 step_fn: Optional[Callable] = None, jit: bool = True,
                 obs: Optional[Obs] = None):
        self.model = model
        self.opt = opt
        self.loader = loader
        self.cfg = cfg
        fn = step_fn or make_train_step(model, opt, cfg.accum,
                                        cfg.grad_accum_dtype)
        self.step_fn = jax.jit(fn, donate_argnums=(0,)) if jit else fn
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep_ckpts) \
            if cfg.ckpt_dir else None
        self._interrupted = False
        # telemetry: the train.* counters are THE cumulative token/step
        # metering (the log line reads deltas of these); span tracing is
        # recording only when the caller passes Obs.on(). Note the fused
        # train step is ONE jit-compiled function — forward-backward and
        # the optimizer update share the "train.step" span (an XLA profile
        # via --profile-dir splits them at operator level).
        self.obs = obs if obs is not None else Obs.off()
        m = self.obs.metrics
        self._c_steps = m.counter("train.steps",
                                  help="optimizer steps completed")
        self._c_real = m.counter("train.real_tokens",
                                 help="non-padding tokens trained on")
        self._c_buf = m.counter("train.buffer_tokens",
                                help="buffer tokens incl. padding")
        self._c_compiles = m.counter(
            "train.compiles", help="distinct batch token-shapes seen "
                                   "(first-call = compile)")
        self._g_data = m.gauge("train.data_ms",
                               help="cumulative ms waiting on the loader")
        self._g_step = m.gauge("train.step_ms",
                               help="cumulative ms in the fused train step")
        self._g_loss = m.gauge("train.loss", help="last logged loss")
        self._shapes_seen = set()

    # ----------------------------------------------------------- lifecycle
    def init_state(self, key) -> Dict[str, Any]:
        params = self.model.init(key)
        return {"params": params, "opt": self.opt.init(params)}

    def restore_or_init(self, key) -> Tuple[Dict[str, Any], int]:
        state = self.init_state(key)
        if self.ckpt and self.ckpt.latest_step() is not None:
            step = self.ckpt.latest_step()
            state = self.ckpt.restore(state)
            return state, int(self.ckpt.read_meta(step)["meta"]["step"])
        return state, 0

    def _install_signal_handlers(self):
        def handler(signum, frame):
            self._interrupted = True
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass      # non-main thread (tests)

    # ----------------------------------------------------------- train loop
    def train(self, key, start_step: Optional[int] = None, verbose=True):
        self._install_signal_handlers()
        state, step0 = self.restore_or_init(key)
        if start_step is not None:
            step0 = start_step
        history = []
        tr = self.obs.tracer
        t_last = time.perf_counter()
        # the log line meters real/buffer tokens as DELTAS of the train.*
        # registry counters — one source of numbers shared with the trace
        # snapshot and any Prometheus scrape
        real_mark = self._c_real.value
        buf_mark = self._c_buf.value
        for step in range(step0, self.cfg.steps):
            t0 = time.perf_counter()
            with tr.span("train.data", track="train", step=step):
                batch = self.loader.batch(step)
            t1 = time.perf_counter()
            # meter from the batch itself, not metrics["tokens"]: a loss fn
            # that omits the metric must not silently report 0 tok/s
            seg = batch.get("segment_ids")
            real = int((seg > 0).sum()) if seg is not None \
                else int(batch["tokens"].size)
            # first occurrence of a batch token-shape = jit compile on this
            # call (first-call timing shows up as an outsized train.step)
            shape = tuple(batch["tokens"].shape)
            compiled = shape not in self._shapes_seen
            if compiled:
                self._shapes_seen.add(shape)
                self._c_compiles.inc()
            sid = tr.start("train.step", track="train", step=step,
                           compile=compiled)
            state, metrics = self.step_fn(state, batch)
            # sync so the span covers device time, not dispatch time — a
            # no-op on the disabled tracer (no extra syncs when off)
            tr.sync(metrics["loss"])
            tr.finish(sid)
            t2 = time.perf_counter()
            self._c_steps.inc()
            self._c_real.inc(real)
            self._c_buf.inc(int(batch["tokens"].size))
            self._g_data.add((t1 - t0) * 1e3)
            self._g_step.add((t2 - t1) * 1e3)
            if verbose and (step + 1) % self.cfg.log_every == 0:
                jax.block_until_ready(metrics["loss"])
                self._g_loss.set(float(metrics["loss"]))
                dt = time.perf_counter() - t_last
                real_since = self._c_real.value - real_mark
                buffer_since = self._c_buf.value - buf_mark
                real_tput = real_since / max(dt, 1e-9)
                buf_tput = buffer_since / max(dt, 1e-9)
                print(f"step {step + 1:5d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"tok/s {real_tput:,.0f} "
                      f"(buffer {buf_tput:,.0f}, "
                      f"{real_since / max(buffer_since, 1):.0%} real)")
                t_last = time.perf_counter()
                real_mark = self._c_real.value
                buf_mark = self._c_buf.value
            row = {k: float(v) for k, v in metrics.items()
                   if jnp.ndim(v) == 0}
            row["real_tokens"] = float(real)
            row["buffer_tokens"] = float(batch["tokens"].size)
            history.append(row)
            if self.ckpt and self.cfg.ckpt_every and \
                    (step + 1) % self.cfg.ckpt_every == 0:
                self.ckpt.save(step + 1, state, meta={"step": step + 1})
            if self._interrupted:
                if self.ckpt:        # emergency checkpoint on SIGTERM
                    self.ckpt.save(step + 1, state,
                                   meta={"step": step + 1,
                                         "emergency": True}, blocking=True)
                break
        if self.ckpt:
            self.ckpt.wait()
        return state, history
