"""Model assembly: embedding → scan-over-pattern-units → norm → head.

Heterogeneous stacks (RecurrentGemma's rec/rec/attn, xLSTM's mLSTM/sLSTM
mix) are expressed as a repeating *unit*; whole units are stacked and
lax.scan'ed (compact HLO, O(1) compile time in depth), any remainder layers
run unstacked. ``remat="unit"`` wraps each unit in jax.checkpoint.

Three entry points per model, matching the assigned shapes:
  * ``loss``/``forward``    — packed training fwd (train_4k)
  * ``prefill``             — packed fwd that also collects decode caches and
                              per-row cursor (prefill_32k)
  * ``decode_step``         — one token against the cache (decode_32k,
                              long_500k)

Packing-awareness is uniform: every sequence-wise sub-block receives
``positions``/``segment_ids`` and applies the paper's boundary rules.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import blocks as B
from repro.models.blocks import Ctx


# ---------------------------------------------------------------------------
# unit layout
# ---------------------------------------------------------------------------

def unit_layout(cfg: ArchConfig) -> Tuple[Tuple[str, str], ...]:
    """(param_name, kind) pairs for one pattern unit."""
    out: List[Tuple[str, str]] = []
    for i, kind in enumerate(cfg.unit):
        if kind == "attn":
            out.append((f"{i}_attn", "attn"))
            if cfg.d_ff:
                out.append((f"{i}_ffn", "mlp"))
        elif kind == "moe_attn":
            out.append((f"{i}_attn", "attn"))
            out.append((f"{i}_moe", "moe"))
        elif kind == "rec":
            out.append((f"{i}_rec", "rec"))
            if cfg.d_ff:
                out.append((f"{i}_ffn", "mlp"))
        elif kind == "mamba":
            out.append((f"{i}_mamba", "mamba"))
        elif kind == "mamba2":
            out.append((f"{i}_mamba2", "mamba2"))
        elif kind == "mlstm":
            out.append((f"{i}_mlstm", "mlstm"))
            if cfg.d_ff:
                out.append((f"{i}_ffn", "mlp"))
        elif kind == "slstm":
            out.append((f"{i}_slstm", "slstm"))
            if cfg.d_ff:
                out.append((f"{i}_ffn", "mlp"))
        else:
            raise ValueError(f"unknown unit kind {kind!r}")
    return tuple(out)


_APPLY = {"attn": B.apply_attn, "mlp": B.apply_mlp, "moe": B.apply_moe,
          "mamba": B.apply_mamba, "mamba2": B.apply_mamba2,
          "rec": B.apply_rec, "mlstm": B.apply_mlstm, "slstm": B.apply_slstm}


def _apply_sub(kind, p, x, ctx, cfg, collect: int = 0, collect_ends=None):
    """Uniform (x, aux, state) return. ``collect`` (= cache max_len when
    nonzero) asks state-bearing blocks to also emit their decode cache —
    per row, or per packed segment when ``collect_ends`` (B, S) is given."""
    if kind in ("mlp", "moe"):
        out = _APPLY[kind](p, x, ctx, cfg)
        if kind == "moe":
            return out[0], out[1], None
        return out, None, None
    if collect:
        x, state = _APPLY[kind](p, x, ctx, cfg, collect=collect,
                                collect_ends=collect_ends)
        return x, None, state
    return _APPLY[kind](p, x, ctx, cfg), None, None


class LM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.layout = unit_layout(cfg)
        self.n_units = cfg.n_layers // len(cfg.unit)
        self.n_tail = cfg.n_layers % len(cfg.unit)
        # tail layers reuse the unit layout prefix
        self.tail_layout = unit_layout(cfg)[:self._tail_sublocks()] \
            if self.n_tail else ()

    def _tail_sublocks(self) -> int:
        # count sub-blocks belonging to the first n_tail layers of the unit
        n = 0
        for name, kind in self.layout:
            layer_idx = int(name.split("_")[0])
            if layer_idx < self.n_tail:
                n += 1
        return n

    # ------------------------------------------------------------------ init
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        k_embed, k_units, k_tail, k_head = jax.random.split(key, 4)
        params: Dict[str, Any] = {}
        if cfg.family == "audio":
            params["input_proj"] = B._dense(k_embed, cfg.d_model, cfg.d_model)
        else:
            params["embed"] = jax.random.normal(
                k_embed, (cfg.vocab, cfg.d_model)) * 0.02

        def unit_init(k):
            p = {}
            ks = jax.random.split(k, len(self.layout))
            for kk, (name, kind) in zip(ks, self.layout):
                p[name] = B.INIT[kind](kk, cfg)
            return p

        if self.n_units:
            params["units"] = jax.vmap(unit_init)(
                jax.random.split(k_units, self.n_units))
        if self.n_tail:
            p = {}
            ks = jax.random.split(k_tail, len(self.tail_layout))
            for kk, (name, kind) in zip(ks, self.tail_layout):
                p[name] = B.INIT[kind](kk, cfg)
            params["tail"] = p
        params["final_norm"] = jnp.ones((cfg.d_model,))
        if not cfg.tie_embeddings:
            params["head"] = B._dense(k_head, cfg.d_model, cfg.vocab)
        pdt = jnp.dtype(cfg.param_dtype)
        if pdt != jnp.float32:
            # low-precision storage: cast float leaves only (optim keeps f32
            # masters; blocks re-cast at use via the .astype(h.dtype) idiom)
            params = jax.tree.map(
                lambda x: x.astype(pdt)
                if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
        return params

    # ------------------------------------------------------------- embedding
    def _embed(self, params, batch) -> jnp.ndarray:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        if cfg.family == "audio":
            x = batch["frames"].astype(dt) @ params["input_proj"].astype(dt)
            return x
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dt)
        if cfg.tie_embeddings:
            x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
        if cfg.family == "vlm" and "vision_embeds" in batch:
            ve = batch["vision_embeds"].astype(dt)          # (B, Nv, d)
            vp = batch["vision_positions"]                  # (B, Nv) i32
            bidx = jnp.arange(x.shape[0])[:, None]
            x = x.at[bidx, vp].set(ve)
        return x

    def _ctx(self, batch) -> Ctx:
        return Ctx(positions=batch.get("positions"),
                   segment_ids=batch.get("segment_ids"),
                   mrope_positions=batch.get("mrope_positions"))

    # ----------------------------------------------------------- forward
    def _stack(self, params, x, ctx) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        """Run all layers. Returns (hidden, aux)."""
        cfg = self.cfg

        def constrain(x):
            # Megatron-SP analogue: the residual carried (and saved for
            # backward) between units is sequence-sharded over "model";
            # XLA re-gathers at TP matmuls and reduce-scatters afterwards.
            if cfg.act_pspec is not None:
                from jax.sharding import PartitionSpec as P
                x = jax.lax.with_sharding_constraint(x, P(*cfg.act_pspec))
            return x

        def unit_body(carry, unit_p):
            x, lb, zl = carry
            for name, kind in self.layout:
                x, aux, _ = _apply_sub(kind, unit_p[name], x, ctx, cfg)
                if aux:
                    lb = lb + aux["lb_loss"]
                    zl = zl + aux["z_loss"]
            return (constrain(x), lb, zl), None

        if cfg.remat == "unit":
            unit_body = jax.checkpoint(unit_body)
        elif cfg.remat == "dots":
            # save matmul outputs, recompute elementwise only — trades the
            # HBM headroom won by act_sp/accum for less recompute traffic
            unit_body = jax.checkpoint(
                unit_body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        lb = jnp.zeros((), jnp.float32)
        zl = jnp.zeros((), jnp.float32)
        x = constrain(x)
        if self.n_units:
            (x, lb, zl), _ = jax.lax.scan(unit_body, (x, lb, zl),
                                          params["units"])
        if self.n_tail:
            for name, kind in self.tail_layout:
                x, aux, _ = _apply_sub(kind, params["tail"][name], x, ctx,
                                       cfg)
                if aux:
                    lb = lb + aux["lb_loss"]
                    zl = zl + aux["z_loss"]
        x = B._norm(params["final_norm"], x, cfg.norm_eps)
        return x, {"lb_loss": lb, "z_loss": zl}

    def _head_t(self, params):
        cfg = self.cfg
        w = params["embed"].T if cfg.tie_embeddings else params["head"]
        return w

    def forward(self, params, batch) -> jnp.ndarray:
        """Full logits (B, L, V) — small models / tests only."""
        x = self._embed(params, batch)
        x, _ = self._stack(params, x, self._ctx(batch))
        return (x @ self._head_t(params).astype(x.dtype)).astype(jnp.float32)

    # ----------------------------------------------------------- loss
    def loss(self, params, batch, loss_chunk: int = 512):
        """Packed next-token CE. Labels: explicit batch['labels'] (with -1 =
        masked) or derived by in-segment shift. Vocab-dim logits are computed
        in L-chunks so the (B, L, V) f32 tensor never materializes."""
        cfg = self.cfg
        x = self._embed(params, batch)
        x, aux = self._stack(params, x, self._ctx(batch))
        if "labels" in batch:
            labels = batch["labels"]
        else:
            seg = batch["segment_ids"]
            tok = batch["tokens"]
            nxt_same = (seg[:, 1:] == seg[:, :-1]) & (seg[:, 1:] != 0)
            labels = jnp.where(nxt_same, tok[:, 1:], -1)
            labels = jnp.concatenate(
                [labels, jnp.full((labels.shape[0], 1), -1, labels.dtype)],
                axis=1)
        Bz, L, d = x.shape
        W = self._head_t(params)
        nchunk = max(1, L // min(loss_chunk, L))
        if L % nchunk:
            nchunk = 1
        xs = x.reshape(Bz, nchunk, L // nchunk, d)
        ls = labels.reshape(Bz, nchunk, L // nchunk)

        def chunk_ce(args):
            xc, lc = args                                  # (B, C, d), (B, C)
            logits = (xc @ W.astype(xc.dtype)).astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(
                logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
            nll = lse - gold
            mask = (lc >= 0).astype(jnp.float32)
            return (nll * mask).sum(), mask.sum()

        tot, cnt = jax.lax.map(chunk_ce, (jnp.moveaxis(xs, 1, 0),
                                          jnp.moveaxis(ls, 1, 0)))
        loss = tot.sum() / jnp.maximum(cnt.sum(), 1.0)
        if cfg.n_experts:
            loss = loss + 0.01 * aux["lb_loss"] + 0.001 * aux["z_loss"]
        metrics = {"ce": tot.sum() / jnp.maximum(cnt.sum(), 1.0),
                   "tokens": cnt.sum(), **aux}
        return loss, metrics

    def prefill_logits(self, params, batch):
        """Serving prefill: logits at each row's last valid position.
        (The dry-run prefill cell lowers this — the forward pass dominates
        its roofline; `prefill` below additionally hands off caches.)"""
        x = self._embed(params, batch)
        x, _ = self._stack(params, x, self._ctx(batch))
        lens = (batch["segment_ids"] > 0).sum(-1)           # (B,)
        xlast = x[jnp.arange(x.shape[0]), jnp.maximum(lens - 1, 0)]
        W = self._head_t(params)
        return (xlast @ W.astype(xlast.dtype)).astype(jnp.float32)

    def prefill(self, params, batch, max_len: int):
        """Full serving prefill: one forward pass over a batch of
        left-aligned prompts (one sequence per row; segment_ids mark
        validity) that also collects every layer's decode cache — O(L)
        handoff instead of token replay. Recurrent states are frozen across
        right-padding (Δ=0 / gate masking / slstm freeze) so the handed-off
        state is exactly the state after each row's last valid token.

        Returns (last_logits (B, V), cache, cache_len (B,))."""
        cfg = self.cfg
        x = self._embed(params, batch)
        ctx = self._ctx(batch)
        lens = (batch["segment_ids"] > 0).sum(-1)

        def unit_body(x, unit_p):
            states = {}
            for name, kind in self.layout:
                x, _, st = _apply_sub(kind, unit_p[name], x, ctx, cfg,
                                      collect=max_len)
                if st is not None:
                    states[name] = st
            return x, states

        cache: Dict[str, Any] = {}
        if self.n_units:
            x, unit_states = jax.lax.scan(unit_body, x, params["units"])
            cache["units"] = unit_states
        if self.n_tail:
            tail_states = {}
            for name, kind in self.tail_layout:
                x, _, st = _apply_sub(kind, params["tail"][name], x, ctx,
                                      cfg, collect=max_len)
                if st is not None:
                    tail_states[name] = st
            cache["tail"] = tail_states
        x = B._norm(params["final_norm"], x, cfg.norm_eps)
        xlast = x[jnp.arange(x.shape[0]), jnp.maximum(lens - 1, 0)]
        W = self._head_t(params)
        logits = (xlast @ W.astype(xlast.dtype)).astype(jnp.float32)
        return logits, cache, lens

    def prefill_packed(self, params, batch, max_len: int, ends):
        """Packed multi-prompt prefill: ONE forward over PACKED rows (many
        prompts laid back-to-back per row, core/packing.py layout) that
        hands off a decode cache for EVERY packed segment — the
        continuous-batching admission path. ``ends`` (B, S) int32 is each
        segment's last-token index in its row (−1 = absent segment; S is the
        static per-row segment capacity).

        The paper's reset rule makes each segment's state independent of its
        neighbors, so per-segment finals are trajectory samples at ``ends``
        (see models/blocks.py docstring) — no replay, no per-prompt rows.

        Returns (logits (B, S, V) at segment ends, states pytree whose
        leaves carry (B, S, …) leading dims ((n_units, B, S, …) for
        unit-stacked layers), seg_lens (B, S) int32 — 0 where absent).
        Feed the states to ``scatter_into_cache`` to land them in decode
        slots."""
        cfg = self.cfg
        x = self._embed(params, batch)
        ctx = self._ctx(batch)

        def unit_body(x, unit_p):
            states = {}
            for name, kind in self.layout:
                x, _, st = _apply_sub(kind, unit_p[name], x, ctx, cfg,
                                      collect=max_len, collect_ends=ends)
                if st is not None:
                    states[name] = st
            return x, states

        states: Dict[str, Any] = {}
        if self.n_units:
            x, unit_states = jax.lax.scan(unit_body, x, params["units"])
            states["units"] = unit_states
        if self.n_tail:
            tail_states = {}
            for name, kind in self.tail_layout:
                x, _, st = _apply_sub(kind, params["tail"][name], x, ctx,
                                      cfg, collect=max_len, collect_ends=ends)
                if st is not None:
                    tail_states[name] = st
            states["tail"] = tail_states
        x = B._norm(params["final_norm"], x, cfg.norm_eps)
        Bsz, L, d = x.shape
        S = ends.shape[1]
        idx = jnp.clip(ends, 0, L - 1)[..., None]
        xe = jnp.take_along_axis(x, jnp.broadcast_to(idx, (Bsz, S, d)),
                                 axis=1)
        W = self._head_t(params)
        logits = (xe @ W.astype(xe.dtype)).astype(jnp.float32)
        logits = jnp.where((ends >= 0)[..., None], logits, 0.0)
        seg_lens = B._ends_lens(ctx, ends)
        return logits, states, seg_lens

    # -------------------------------------------------- chunk-resume prefill
    @property
    def supports_chunked_prefill(self) -> bool:
        """True when every state-bearing sub-block has a chunk-resume step
        (``blocks.CHUNK``) — the serve engine's gate for accepting prompts
        longer than its largest prefill bucket."""
        if self.cfg.family in ("audio", "vlm"):
            return False
        return all(kind in B.CHUNK or kind in ("mlp", "moe")
                   for _, kind in self.layout)

    def prefill_chunk(self, params, cache, batch, cache_len):
        """Advance DECODE-layout caches by one (B, T) slab of long prompts
        — resumable prefill from the carried O(1) state, so a prompt far
        beyond any prefill bucket is consumed in fixed-shape chunks while
        decode slots keep stepping. ``batch`` holds tokens/positions/
        segment_ids for the slab (positions GLOBAL, segment_ids 0 marks
        trailing padding — all-padding rows are exact state no-ops);
        ``cache_len`` (B,) counts tokens already consumed. Returns
        (logits (B, V) at each row's last valid slab token, new_cache,
        new cache_len)."""
        cfg = self.cfg
        x = self._embed(params, batch)
        ctx = Ctx(positions=batch.get("positions"),
                  segment_ids=batch.get("segment_ids"),
                  cache_len=cache_len)

        def unit_step(x, unit_p, unit_c):
            new_c = {}
            for name, kind in self.layout:
                if kind in ("mlp", "moe"):
                    x, _, _ = _apply_sub(kind, unit_p[name], x, ctx, cfg)
                else:
                    x, new_c[name] = B.CHUNK[kind](unit_p[name], x,
                                                   unit_c[name], ctx, cfg)
            return x, new_c

        if self.n_units:
            def body(x, pc):
                p_u, c_u = pc
                return unit_step(x, p_u, c_u)
            x, new_units = jax.lax.scan(body, x,
                                        (params["units"], cache["units"]))
            cache = dict(cache, units=new_units)
        if self.n_tail:
            new_tail = {}
            for name, kind in self.tail_layout:
                if kind in ("mlp", "moe"):
                    x, _, _ = _apply_sub(kind, params["tail"][name], x, ctx,
                                         cfg)
                else:
                    x, new_tail[name] = B.CHUNK[kind](
                        params["tail"][name], x, cache["tail"][name], ctx,
                        cfg)
            cache = dict(cache, tail=new_tail)
        x = B._norm(params["final_norm"], x, cfg.norm_eps)
        nvalid = (batch["segment_ids"] > 0).sum(-1)
        xlast = x[jnp.arange(x.shape[0]), jnp.maximum(nvalid - 1, 0)]
        logits = (xlast @ self._head_t(params).astype(xlast.dtype))
        return logits.astype(jnp.float32), cache, cache_len + nvalid

    def reset_cache_rows(self, cache, fresh):
        """Zero the given cache rows (``fresh`` (B,) bool) back to their
        ``init_cache`` values — the engine calls this when it claims a
        chunk row for a new request, so no stale conv tail / attention ring
        / stabilizer state leaks across tenants. Leaves named ``m`` are
        log-domain stabilizers whose empty value is -1e30, not 0."""
        def one(path, leaf):
            stacked = any(getattr(p, "key", None) == "units" for p in path)
            extra = leaf.ndim - (2 if stacked else 1)
            m = fresh.reshape(((1,) if stacked else ())
                              + fresh.shape + (1,) * extra)
            empty = -1e30 if getattr(path[-1], "key", None) == "m" else 0
            return jnp.where(m, jnp.asarray(empty, leaf.dtype), leaf)

        return jax.tree_util.tree_map_with_path(one, cache)

    def expand_chunk_states(self, cache):
        """View a chunk cache (``init_cache`` layout, (B, …) leaves) as a
        1-segment packed-states tree ((B, 1, …) leaves) so the existing
        ``scatter_into_cache`` / ``prefill_probe`` machinery handles the
        chunk→decode-slot handoff unchanged."""
        def one(path, leaf):
            stacked = any(getattr(p, "key", None) == "units" for p in path)
            if stacked:
                return leaf.reshape(leaf.shape[:2] + (1,) + leaf.shape[2:])
            return leaf.reshape((leaf.shape[0], 1) + leaf.shape[1:])

        return jax.tree_util.tree_map_with_path(one, cache)

    def scatter_into_cache(self, cache, states, src, dst):
        """Land harvested per-segment states in arbitrary decode slots.

        cache: slot-major decode cache (``init_cache`` layout); states: the
        pytree from ``prefill_packed`` ((B, S, …) leading dims); src (M,)
        int32 flat indices into the flattened B·S segment axis; dst (M,)
        int32 target slot rows. Entries with dst outside [0, n_slots) are
        DROPPED (use n_slots as a sentinel), so a fixed M compiles once
        regardless of how many slots a round actually refills.

        Returns the updated cache (jit/donate-friendly: pure function)."""
        def one(path, c, s):
            stacked = any(getattr(p, "key", None) == "units" for p in path)
            if stacked:                     # (n_units, B, S, …) leaves
                flat = s.reshape((s.shape[0], -1) + s.shape[3:])
                return c.at[:, dst].set(flat[:, src].astype(c.dtype),
                                        mode="drop")
            flat = s.reshape((-1,) + s.shape[2:])
            return c.at[dst].set(flat[src].astype(c.dtype), mode="drop")

        return jax.tree_util.tree_map_with_path(one, cache, states)

    # ----------------------------------------------------------- decode
    def init_cache(self, batch_size: int, max_len: int) -> Dict[str, Any]:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)

        def one_unit(layout):
            c = {}
            for name, kind in layout:
                if kind in ("mlp", "moe"):
                    continue
                if kind == "attn":
                    c[name] = B.init_attn_cache(cfg, batch_size, max_len, dt)
                else:
                    c[name] = B.CACHE_INIT[kind](cfg, batch_size, dt)
            return c

        cache: Dict[str, Any] = {}
        if self.n_units:
            u = one_unit(self.layout)
            cache["units"] = jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a[None], (self.n_units,) + a.shape).copy(), u)
        if self.n_tail:
            cache["tail"] = one_unit(self.tail_layout)
        return cache

    def decode_step(self, params, cache, tokens_t, cache_len,
                    reset: Optional[jnp.ndarray] = None,
                    mrope_positions: Optional[jnp.ndarray] = None):
        """tokens_t (B, 1) [or frames_t (B,1,d) for audio, unused];
        cache_len (B,) cursor. Returns (logits (B, V), new_cache)."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = jnp.take(params["embed"], tokens_t, axis=0).astype(dt)
        if cfg.tie_embeddings:
            x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
        ctx = Ctx(cache_len=cache_len, reset_t=reset,
                  mrope_positions=mrope_positions)

        def unit_step(x, unit_p, unit_c):
            new_c = {}
            for name, kind in self.layout:
                if kind in ("mlp", "moe"):
                    x, _, _ = _apply_sub(kind, unit_p[name], x, ctx, cfg)
                else:
                    x, new_c[name] = B.STEP[kind](unit_p[name], x,
                                                  unit_c[name], ctx, cfg)
            return x, new_c

        if self.n_units:
            def body(x, pc):
                p_u, c_u = pc
                x, c_new = unit_step(x, p_u, c_u)
                return x, c_new
            x, new_units = jax.lax.scan(body, x,
                                        (params["units"], cache["units"]))
            cache = dict(cache, units=new_units)
        if self.n_tail:
            new_tail = {}
            for name, kind in self.tail_layout:
                if kind in ("mlp", "moe"):
                    x, _, _ = _apply_sub(kind, params["tail"][name], x, ctx,
                                         cfg)
                else:
                    x, new_tail[name] = B.STEP[kind](
                        params["tail"][name], x, cache["tail"][name], ctx,
                        cfg)
            cache = dict(cache, tail=new_tail)
        x = B._norm(params["final_norm"], x, cfg.norm_eps)
        logits = (x[:, 0] @ self._head_t(params).astype(x.dtype))
        return logits.astype(jnp.float32), cache

    def decode_step_sample(self, params, cache, tokens_t, cache_len, keys,
                           temperature, top_k, top_p,
                           reset: Optional[jnp.ndarray] = None):
        """One fused decode + batched-sampling step over all slots.

        keys (B, 2) uint32 per-slot PRNG carry; temperature/top_k/top_p (B,)
        per-slot sampling knobs (temperature <= 0 → greedy; see
        blocks.sample_from_logits). ONE jitted call per token — the sampled
        token never round-trips to the host between the forward and the
        sample. Returns (tokens (B,) int32, logits (B, V) f32, new_cache,
        new_keys)."""
        logits, cache = self.decode_step(params, cache, tokens_t, cache_len,
                                         reset)
        tok, keys = B.sample_from_logits(logits, keys, temperature, top_k,
                                         top_p)
        return tok, logits, cache, keys

    def decode_step_sample_guarded(self, params, cache, tokens_t, cache_len,
                                   keys, temperature, top_k, top_p, poison,
                                   reset: Optional[jnp.ndarray] = None):
        """``decode_step_sample`` with the serve engine's numerical guard
        rail fused in: a per-slot finiteness probe on the decode logits
        (one (B, V) isfinite + all-reduce — cheap next to the forward) so a
        poisoned slot is caught the step it goes bad instead of silently
        emitting garbage.

        ``poison`` (B,) f32 is the fault-injection seam: it is ADDED to the
        logits before the probe and the sampler. In production it is all
        zeros — ``x + 0.0`` is a bitwise no-op on every finite logit, so
        guarded token streams are bit-identical to unguarded ones — while a
        fault plan puts NaN/Inf there to script a numerical failure the
        probe must catch. Returns (tokens (B,), logits (B, V) f32,
        new_cache, new_keys, finite (B,) bool)."""
        logits, cache = self.decode_step(params, cache, tokens_t, cache_len,
                                         reset)
        logits = logits + poison[:, None]
        finite = jnp.all(jnp.isfinite(logits), axis=-1)
        tok, keys = B.sample_from_logits(logits, keys, temperature, top_k,
                                         top_p)
        return tok, logits, cache, keys, finite

    # ------------------------------------------------- speculative decode
    def decode_verify(self, params, cache, tokens_t, cache_len, draft):
        """Score K draft tokens with K+1 chained greedy decode steps in ONE
        call, keeping the per-step cache trajectory for rollback.

        ``tokens_t`` (B, 1) is each slot's last committed token; ``draft``
        (B, K) the proposed continuations. Step j feeds input j of
        [tokens_t, draft] at position ``cache_len + j`` and takes the
        argmax — exactly what sequential ``decode_step`` + argmax would
        compute, so a draft token is *accepted* iff it matches the argmax
        and the committed stream is bit-identical to non-speculative
        greedy decode by construction.

        Returns (toks (B, K+1) int32 — the argmax after each step,
        finite (B, K+1) bool — per-step logits finiteness for the guard
        rail, traj — cache pytree with a leading (K+1,) axis; entry j is
        the cache after consuming j+1 inputs). ``spec_rollback`` selects
        each row's post-accept cache from ``traj``."""
        inputs = jnp.moveaxis(
            jnp.concatenate([tokens_t, draft.astype(jnp.int32)], axis=1),
            1, 0)                                       # (K+1, B)

        def body(carry, inp):
            c, off = carry
            logits, c = self.decode_step(params, c, inp[:, None],
                                         cache_len + off)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            fin = jnp.all(jnp.isfinite(logits), axis=-1)
            return (c, off + 1), (tok, fin, c)

        (_, _), (toks, fins, traj) = jax.lax.scan(
            body, (cache, jnp.zeros((), jnp.int32)), inputs)
        return jnp.moveaxis(toks, 0, 1), jnp.moveaxis(fins, 0, 1), traj

    def spec_rollback(self, traj, idx):
        """Per-row rollback select over a verify trajectory: row ``b`` of
        the returned decode cache is row ``b`` of ``traj`` entry
        ``idx[b]`` (idx (B,) int32 in [0, K]) — the O(1) state is what
        makes rejecting draft tokens a cheap gather instead of a replay.
        Rows whose idx points at entry 0 simply keep the state after their
        first (always-valid) verify step."""
        def one(path, leaf):
            stacked = any(getattr(p, "key", None) == "units" for p in path)
            if stacked:                     # (K+1, n_units, B, …)
                bsz = leaf.shape[2]
                out = leaf[idx, :, jnp.arange(bsz)]     # (B, n_units, …)
                return jnp.moveaxis(out, 0, 1)
            bsz = leaf.shape[1]             # (K+1, B, …)
            return leaf[idx, jnp.arange(bsz)]

        return jax.tree_util.tree_map_with_path(one, traj)

    def prefill_probe(self, states, logits):
        """Per-segment finiteness of a packed prefill's harvest: True at
        (b, s) iff every state leaf AND the segment-end logits of that
        segment are finite. One all-reduce per leaf over the non-(B, S)
        axes — the admission-path guard rail: a poisoned segment is
        quarantined before its state is ever trusted by a decode slot.
        Absent segments (states zeroed, logits masked to 0) probe True."""
        ok = jnp.all(jnp.isfinite(logits), axis=-1)         # (B, S)

        def leaf_ok(path, a):
            stacked = any(getattr(p, "key", None) == "units" for p in path)
            if stacked:                                     # (n_units,B,S,…)
                axes = (0,) + tuple(range(3, a.ndim))
            else:                                           # (B, S, …)
                axes = tuple(range(2, a.ndim))
            return jnp.all(jnp.isfinite(a), axis=axes)

        for leaf in jax.tree_util.tree_leaves_with_path(states):
            ok = ok & leaf_ok(*leaf)
        return ok

    def sample_tokens(self, logits, keys, temperature, top_k, top_p):
        """Sample one token per row from already-computed logits (the packed
        prefill's (K, V) segment-end logits, flattened). Same per-row knob
        semantics as ``decode_step_sample``; returns (tokens (K,) int32,
        new_keys (K, 2))."""
        return B.sample_from_logits(logits, keys, temperature, top_k, top_p)


def build_model(cfg: ArchConfig) -> LM:
    return LM(cfg)
