"""Composable blocks for every architecture family.

Each block kind provides ``init_<kind>(key, cfg) -> params`` and
``apply_<kind>(params, x, ctx, cfg) -> x`` (full-sequence, packed-aware) and
``step_<kind>(params, x_t, cache, ctx, cfg) -> (x_t, cache)`` (single-token
decode). ``ctx`` carries the packing side-tensors (positions, segment_ids)
plus decode cursor.

Param leaves use conventional names (embed, head, wq, wkv, wo, w_gate, w_up,
w_down, experts_*, conv_w, A_log, …) that distributed/sharding.py
pattern-matches into PartitionSpecs.

State handoff (serving): every state-bearing ``apply_<kind>`` supports two
collect modes, selected by ``collect`` (= cache max_len) and
``collect_ends``:
  * per-ROW (``collect_ends=None``) — one right-padded sequence per row;
    state is frozen across the padding and the row's final state handed off
    (the historical ``model.prefill`` path).
  * per-SEGMENT (``collect_ends`` (B, S) int32, −1 = absent) — a PACKED row
    holds several prompts; the paper's reset rule makes the state at each
    segment's last token that segment's final state, so one packed forward
    hands off S caches per row (``model.prefill_packed``). State leaves gain
    a (B, S, …) leading pair.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.attention import (attention, decode_attention, rope, mrope)
from repro.core.recurrence import (rglru, rglru_step, mlstm, mlstm_step,
                                   slstm)
from repro.core import ssm as core_ssm
from repro.core.conv import conv1d_pack_update
from repro.kernels import ops as kops
from repro.configs.base import ArchConfig


@dataclasses.dataclass
class Ctx:
    """Per-call context threaded through blocks."""
    positions: Optional[jnp.ndarray] = None      # (B, L) intra-seq positions
    segment_ids: Optional[jnp.ndarray] = None    # (B, L)
    mrope_positions: Optional[jnp.ndarray] = None  # (B, L, S) for vlm
    # decode:
    cache_len: Optional[jnp.ndarray] = None      # (B,) current cursor
    reset_t: Optional[jnp.ndarray] = None        # (B,) new-sequence flag


def _norm(scale, x, eps):
    v = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(v + eps) *
            scale.astype(jnp.float32)).astype(x.dtype)


def _dense(key, din, dout, scale=None, dtype=jnp.float32):
    s = scale if scale is not None else din ** -0.5
    return jax.random.normal(key, (din, dout), dtype) * s


def _act(name: str):
    return jax.nn.gelu if name == "geglu" else jax.nn.silu


# ===========================================================================
# attention (+ shared MLP)
# ===========================================================================

def init_attn(key, cfg: ArchConfig) -> Dict[str, Any]:
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 5)
    return {
        "norm": jnp.ones((d,)),
        "wq": _dense(ks[0], d, H * hd),
        "wkv": _dense(ks[1], d, 2 * Hkv * hd),
        "wo": _dense(ks[2], H * hd, d, scale=(H * hd) ** -0.5),
    }


def _apply_rope(cfg, q, k, ctx: Ctx):
    if cfg.mrope_sections is not None:
        pos3 = ctx.mrope_positions
        if pos3 is None and ctx.positions is not None:
            pos3 = jnp.repeat(ctx.positions[..., None],
                              len(cfg.mrope_sections), axis=-1)
        if pos3 is not None:
            q = mrope(q, pos3, cfg.mrope_sections, cfg.rope_theta)
            k = mrope(k, pos3, cfg.mrope_sections, cfg.rope_theta)
        return q, k
    if ctx.positions is not None:
        q = rope(q, ctx.positions, cfg.rope_theta)
        k = rope(k, ctx.positions, cfg.rope_theta)
    return q, k


def apply_attn(p, x, ctx: Ctx, cfg: ArchConfig, collect: int = 0,
               collect_ends=None):
    B, L, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = _norm(p["norm"], x, cfg.norm_eps)
    q = (h @ p["wq"].astype(h.dtype)).reshape(B, L, H, hd)
    kv = (h @ p["wkv"].astype(h.dtype)).reshape(B, L, 2, Hkv, hd)
    k, v = kv[:, :, 0], kv[:, :, 1]
    q, k = _apply_rope(cfg, q, k, ctx)
    chunk = cfg.attn_chunk
    if chunk is None and L > 4096:
        chunk = 1024                       # online-softmax for long prefill
    o = attention(q, k, v,
                  segment_ids_q=ctx.segment_ids,
                  segment_ids_kv=ctx.segment_ids,
                  causal=not cfg.encoder_only,
                  window=cfg.attn_window,
                  chunk_kv=chunk)
    o = o.reshape(B, L, H * hd) @ p["wo"].astype(x.dtype)
    if collect:
        S = collect if cfg.attn_window is None else \
            min(collect, cfg.attn_window)
        if collect_ends is not None:
            lens = _ends_lens(ctx, collect_ends)
            return x + o, _ring_fill_ends(k, v, collect_ends, lens, S)
        lens = _valid(ctx, x).sum(-1)
        return x + o, _ring_fill(k, v, lens, S)
    return x + o


def init_attn_cache(cfg: ArchConfig, batch: int, max_len: int, dtype):
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    S = max_len if cfg.attn_window is None else min(max_len, cfg.attn_window)
    return {"k": jnp.zeros((batch, S, Hkv, hd), dtype),
            "v": jnp.zeros((batch, S, Hkv, hd), dtype)}


def _ring_fill(k, v, lens, S):
    """Lay a prefill's K/V into the ring-buffer layout step_attn uses:
    slot s holds the LAST token t < len with t ≡ s (mod S)."""
    B, L, Hkv, hd = k.shape
    s = jnp.arange(S)[None, :]                         # (1, S)
    nb = lens[:, None]                                 # (B, 1)
    t = s + ((nb - 1 - s) // S) * S                    # largest ≡ s (mod S)
    ok = (s < nb) & (t >= 0)
    tcl = jnp.clip(t, 0, L - 1)[..., None, None]       # (B, S, 1, 1)
    gk = jnp.take_along_axis(k, jnp.broadcast_to(tcl, (B, S) + k.shape[2:]),
                             axis=1)
    gv = jnp.take_along_axis(v, jnp.broadcast_to(tcl, (B, S) + v.shape[2:]),
                             axis=1)
    m = ok[..., None, None]
    return {"k": jnp.where(m, gk, 0), "v": jnp.where(m, gv, 0)}


def _ring_fill_ends(k, v, ends, lens, S):
    """Per-SEGMENT ring fill: slot s of segment (b, g) holds that segment's
    last token with intra-segment position ≡ s (mod S) — the packed-prefill
    generalization of ``_ring_fill``. Returns (B, Sg, S, Hkv, hd) K/V."""
    B, L, Hkv, hd = k.shape
    Sg = ends.shape[1]
    s = jnp.arange(S)[None, None, :]                   # (1, 1, S)
    nb = lens[..., None]                               # (B, Sg, 1)
    p = s + ((nb - 1 - s) // S) * S                    # largest ≡ s (mod S)
    ok = (s < nb) & (p >= 0) & (ends[..., None] >= 0)
    t = ends[..., None] - (nb - 1) + p                 # global token index
    tcl = jnp.clip(t, 0, L - 1).reshape(B, Sg * S)[..., None, None]
    gk = jnp.take_along_axis(
        k, jnp.broadcast_to(tcl, (B, Sg * S) + k.shape[2:]), axis=1)
    gv = jnp.take_along_axis(
        v, jnp.broadcast_to(tcl, (B, Sg * S) + v.shape[2:]), axis=1)
    m = ok[..., None, None]
    return {"k": jnp.where(m, gk.reshape(B, Sg, S, Hkv, hd), 0),
            "v": jnp.where(m, gv.reshape(B, Sg, S, Hkv, hd), 0)}


def _conv_tail(x_in, lens, W):
    """Last W-1 *valid* inputs per row → decode conv state (B, W-1, D)."""
    B, L, D = x_in.shape
    j = jnp.arange(W - 1)[None, :]                     # (1, W-1)
    t = lens[:, None] - (W - 1) + j                    # (B, W-1)
    ok = t >= 0
    tcl = jnp.clip(t, 0, L - 1)[..., None]
    g = jnp.take_along_axis(x_in, jnp.broadcast_to(tcl, (B, W - 1, D)),
                            axis=1)
    return jnp.where(ok[..., None], g, 0)


def _valid(ctx: Ctx, x):
    if ctx.segment_ids is None:
        return jnp.ones(x.shape[:2], bool)
    return ctx.segment_ids != 0


def _ends_lens(ctx: Ctx, ends):
    """Per-segment length at each end index: positions[end] + 1 (0 = absent).

    ends: (B, S) int32, −1 = absent. Returns (B, S) int32."""
    L = ctx.positions.shape[1]
    p = jnp.take_along_axis(ctx.positions, jnp.clip(ends, 0, L - 1), axis=1)
    return jnp.where(ends >= 0, p + 1, 0)


def _conv_tail_ends(x_in, ends, lens, W):
    """Last W-1 in-SEGMENT inputs per segment end → (B, S, W-1, D).

    Same layout as ``_conv_tail`` (zeros where the segment is shorter than
    W-1), one tail per packed segment instead of one per row."""
    B, L, D = x_in.shape
    S = ends.shape[1]
    j = jnp.arange(W - 1)[None, None, :]               # (1, 1, W-1)
    t = ends[..., None] - (W - 1) + 1 + j              # (B, S, W-1) global
    ok = (lens[..., None] - (W - 1) + j >= 0) & (ends[..., None] >= 0)
    tcl = jnp.clip(t, 0, L - 1).reshape(B, S * (W - 1))[..., None]
    g = jnp.take_along_axis(
        x_in, jnp.broadcast_to(tcl, (B, S * (W - 1), D)), axis=1)
    return jnp.where(ok[..., None], g.reshape(B, S, W - 1, D), 0)


def step_attn(p, x_t, cache, ctx: Ctx, cfg: ArchConfig):
    """x_t: (B, 1, d). Writes K/V at ctx.cache_len then attends."""
    B, _, d = x_t.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = _norm(p["norm"], x_t, cfg.norm_eps)
    q = (h @ p["wq"].astype(h.dtype)).reshape(B, 1, H, hd)
    kv = (h @ p["wkv"].astype(h.dtype)).reshape(B, 1, 2, Hkv, hd)
    k, v = kv[:, :, 0], kv[:, :, 1]
    pos = ctx.cache_len[:, None]                      # (B, 1) intra-seq pos
    sctx = Ctx(positions=pos, mrope_positions=(
        jnp.repeat(pos[..., None], len(cfg.mrope_sections), axis=-1)
        if cfg.mrope_sections is not None else None))
    q, k = _apply_rope(cfg, q, k, sctx)
    # ring-buffer write for windowed attention (cache size = window keeps
    # long_500k decode state bounded), linear write otherwise
    S = cache["k"].shape[1]
    slot = ctx.cache_len % S
    bidx = jnp.arange(B)
    kc = cache["k"].at[bidx, slot].set(k[:, 0])
    vc = cache["v"].at[bidx, slot].set(v[:, 0])
    if cfg.attn_window is not None:
        o = _ring_decode(q[:, 0], kc, vc, ctx.cache_len, cfg.attn_window)
    else:
        o = decode_attention(q[:, 0], kc, vc, ctx.cache_len, window=None)
    o = o.reshape(B, 1, H * hd) @ p["wo"].astype(x_t.dtype)
    return x_t + o, {"k": kc, "v": vc}


def _ring_decode(q_t, kc, vc, cur, window):
    """Decode attention over a ring buffer of size S ≥ window."""
    B, S, Hkv, hd = kc.shape
    idx = jnp.arange(S)[None, :]
    slot_age = (cur[:, None] % S - idx) % S          # age of each slot
    valid = (slot_age < window) & (slot_age <= cur[:, None])
    H = q_t.shape[1]
    G = H // Hkv
    s = jnp.einsum("bhgd,bkhd->bhgk", q_t.reshape(B, Hkv, G, hd), kc,
                   preferred_element_type=jnp.float32) * hd ** -0.5
    s = jnp.where(valid[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(vc.dtype), vc)
    return o.reshape(B, H, hd)


def init_mlp(key, cfg: ArchConfig) -> Dict[str, Any]:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {"norm": jnp.ones((d,)),
            "w_gate": _dense(ks[0], d, ff),
            "w_up": _dense(ks[1], d, ff),
            "w_down": _dense(ks[2], ff, d, scale=ff ** -0.5)}


def apply_mlp(p, x, ctx: Ctx, cfg: ArchConfig):
    h = _norm(p["norm"], x, cfg.norm_eps)
    g = _act(cfg.act)(h @ p["w_gate"].astype(h.dtype))
    u = h @ p["w_up"].astype(h.dtype)
    return x + (g * u) @ p["w_down"].astype(x.dtype)


# ===========================================================================
# MoE FFN (sort-based dispatch, EP-shardable)
# ===========================================================================

def init_moe(key, cfg: ArchConfig) -> Dict[str, Any]:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 7)
    p = {"norm": jnp.ones((d,)),
         "router": _dense(ks[0], d, E),
         "experts_gate": jax.random.normal(ks[1], (E, d, ff)) * d ** -0.5,
         "experts_up": jax.random.normal(ks[2], (E, d, ff)) * d ** -0.5,
         "experts_down": jax.random.normal(ks[3], (E, ff, d)) * ff ** -0.5}
    if cfg.n_shared_experts:
        sff = ff * cfg.n_shared_experts
        p["shared_gate"] = _dense(ks[4], d, sff)
        p["shared_up"] = _dense(ks[5], d, sff)
        p["shared_down"] = _dense(ks[6], sff, d, scale=sff ** -0.5)
    return p


def _moe_ffn(p, x, cfg: ArchConfig):
    """x: (T, d) → (T, d), plus aux losses. Sort-based capacity dispatch:
    O(T·K) memory, experts batched on the leading (EP-shardable) axis."""
    T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)                 # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    # capacity per expert (static)
    C = int(math.ceil(T * K / E * cfg.capacity_factor))
    C = max(8, -(-C // 8) * 8)
    flat_e = expert_idx.reshape(-1)                                 # (T·K,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # rank within expert group
    tk = T * K
    counts = jnp.bincount(sorted_e, length=E)
    seg_start = jnp.concatenate([jnp.zeros(1, counts.dtype),
                                 jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(tk) - seg_start[sorted_e]
    keep = rank < C
    token_of = order // K                                           # (T·K,)
    # dispatch into (E, C, d)
    slot = jnp.where(keep, sorted_e * C + rank, E * C)              # drop → OOB
    buf = jnp.zeros((E * C + 1, d), x.dtype).at[slot].set(x[token_of])
    xe = buf[:E * C].reshape(E, C, d)
    # expert FFN, batched over E
    g = _act(cfg.act)(jnp.einsum("ecd,edf->ecf", xe,
                                 p["experts_gate"].astype(x.dtype)))
    u = jnp.einsum("ecd,edf->ecf", xe, p["experts_up"].astype(x.dtype))
    ye = jnp.einsum("ecf,efd->ecd", g * u,
                    p["experts_down"].astype(x.dtype))
    # combine back: gather each (t, k) choice's output
    ye_flat = jnp.concatenate([ye.reshape(E * C, d),
                               jnp.zeros((1, d), x.dtype)], axis=0)
    gathered = ye_flat[slot]                                        # (T·K, d)
    contrib = jnp.zeros((T, d), x.dtype).at[token_of].add(
        gathered * gate_vals.reshape(-1)[order][:, None].astype(x.dtype))
    # aux: load-balance + router z-loss
    me = probs.mean(0)                                              # (E,)
    ce = jnp.zeros(E, jnp.float32).at[flat_e].add(1.0) / (T * K)
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    if "shared_gate" in p:
        g = _act(cfg.act)(x @ p["shared_gate"].astype(x.dtype))
        u = x @ p["shared_up"].astype(x.dtype)
        contrib = contrib + (g * u) @ p["shared_down"].astype(x.dtype)
    return contrib, {"lb_loss": lb_loss, "z_loss": z_loss}


def apply_moe(p, x, ctx: Ctx, cfg: ArchConfig):
    B, L, d = x.shape
    h = _norm(p["norm"], x, cfg.norm_eps).reshape(B * L, d)
    Tc = cfg.moe_token_chunk
    if Tc and B * L > Tc and (B * L) % Tc == 0:
        # bound dispatch-buffer memory: route/dispatch/combine per token
        # chunk (capacity applies per chunk — slightly better balanced)
        ys, auxs = jax.lax.map(lambda hh: _moe_ffn(p, hh, cfg),
                               h.reshape(-1, Tc, d))
        y = ys.reshape(B * L, d)
        aux = jax.tree.map(lambda a: a.mean(), auxs)
    else:
        y, aux = _moe_ffn(p, h, cfg)
    return x + y.reshape(B, L, d), aux


# ===========================================================================
# Mamba block (the paper's architecture)
# ===========================================================================

def init_mamba(key, cfg: ArchConfig) -> Dict[str, Any]:
    d, di, N, W, dtr = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.d_conv, \
        cfg.dtr
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "norm": jnp.ones((d,)),
        "in_proj": _dense(ks[0], d, 2 * di),
        "conv_w": jax.random.normal(ks[1], (W, di)) * W ** -0.5,
        "conv_b": jnp.zeros((di,)),
        "x_proj": _dense(ks[2], di, dtr + 2 * N),
        "dt_w": _dense(ks[3], dtr, di, scale=dtr ** -0.5),
        "dt_b": jnp.full((di,), -4.6),        # softplus⁻¹(0.01)
        "A_log": jnp.log(A),
        "D": jnp.ones((di,)),
        "out_proj": _dense(ks[4], di, d, scale=di ** -0.5),
    }


def _cfg_tune(cfg: ArchConfig):
    """ArchConfig.scan_tune → the ``tune=`` argument of the scan entry
    points (None keeps every call site bit-identical to the pre-tuner
    code path)."""
    return None if cfg.scan_tune == "off" else cfg.scan_tune


def _tune_kw(cfg: ArchConfig):
    """The scan entry points' tuning kwargs: cache identity plus which
    sweep objective's winners to resolve (fwd vs fwdbwd — training configs
    set tune_objective="fwdbwd")."""
    return {"tune": _cfg_tune(cfg), "tune_objective": cfg.tune_objective}


def apply_mamba(p, x, ctx: Ctx, cfg: ArchConfig, collect: int = 0,
                collect_ends=None):
    B, L, d = x.shape
    di, N, dtr = cfg.d_inner, cfg.d_state, cfg.dtr
    backend = "pallas" if cfg.use_pallas else "xla"
    h = _norm(p["norm"], x, cfg.norm_eps)
    xz = h @ p["in_proj"].astype(h.dtype)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c = kops.conv1d_pack(x_in, p["conv_w"].astype(h.dtype),
                           p["conv_b"].astype(h.dtype),
                           ctx.positions, backend=backend)
    x_c = jax.nn.silu(x_c)
    dbl = x_c @ p["x_proj"].astype(h.dtype)
    dt_low, Bm, Cm = jnp.split(dbl, [dtr, dtr + N], axis=-1)
    delta = jax.nn.softplus(dt_low @ p["dt_w"].astype(h.dtype) +
                            p["dt_b"].astype(h.dtype))
    A = -jnp.exp(p["A_log"])
    if collect and collect_ends is not None:
        # per-SEGMENT handoff: resets already isolate segments, so the state
        # sampled at each segment end IS its final state — no freezing, and
        # padding (pos == 0 ⇒ reset) cannot leak into earlier samples.
        y, h_ends = core_ssm.selective_scan(
            x_c, delta, A, Bm, Cm, p["D"], positions=ctx.positions,
            method=cfg.scan_impl, chunk=cfg.scan_chunk,
            intra=cfg.scan_intra, collect_ends=collect_ends,
            **_tune_kw(cfg))
        state = {"conv": _conv_tail_ends(x_in, collect_ends,
                                         _ends_lens(ctx, collect_ends),
                                         cfg.d_conv),
                 "ssm": h_ends}
        y = y * jax.nn.silu(z)
        return x + y @ p["out_proj"].astype(x.dtype), state
    if collect:
        # freeze state across right-padding: Δ=0 ⇒ Ā=1, B̄x=0. Padding
        # positions are 0, which would trigger the Ā→0 reset and zero the
        # handed-off state — neutralize them (pos→1) there.
        valid = _valid(ctx, x)
        delta = delta * valid[..., None].astype(delta.dtype)
        pos_nz = jnp.where(valid, ctx.positions, 1)
        y, h_last = core_ssm.selective_scan(
            x_c, delta, A, Bm, Cm, p["D"], positions=pos_nz,
            method=cfg.scan_impl, chunk=cfg.scan_chunk, return_state=True,
            intra=cfg.scan_intra, **_tune_kw(cfg))
        state = {"conv": _conv_tail(x_in, valid.sum(-1), cfg.d_conv),
                 "ssm": h_last}
        y = y * jax.nn.silu(z)
        return x + y @ p["out_proj"].astype(x.dtype), state
    y = kops.selective_scan(x_c, delta, A, Bm, Cm, p["D"],
                            positions=ctx.positions, backend=backend,
                            xla_chunk=cfg.scan_chunk,
                            xla_method=cfg.scan_impl,
                            xla_dtype=(None if cfg.scan_dtype == "float32"
                                       else cfg.scan_dtype),
                            xla_intra=cfg.scan_intra,
                            schedule=cfg.pallas_schedule,
                            **_tune_kw(cfg))
    y = y * jax.nn.silu(z)
    return x + y @ p["out_proj"].astype(x.dtype)


def init_mamba_cache(cfg: ArchConfig, batch: int, dtype):
    di, N, W = cfg.d_inner, cfg.d_state, cfg.d_conv
    return {"conv": jnp.zeros((batch, W - 1, di), dtype),
            "ssm": jnp.zeros((batch, di, N), jnp.float32)}


def step_mamba(p, x_t, cache, ctx: Ctx, cfg: ArchConfig):
    B = x_t.shape[0]
    di, N, dtr = cfg.d_inner, cfg.d_state, cfg.dtr
    h = _norm(p["norm"], x_t, cfg.norm_eps)
    xz = (h[:, 0] @ p["in_proj"].astype(h.dtype))
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c, conv_state = conv1d_pack_update(
        x_in, cache["conv"], p["conv_w"].astype(h.dtype),
        p["conv_b"].astype(h.dtype), ctx.reset_t)
    x_c = jax.nn.silu(x_c)
    dbl = x_c @ p["x_proj"].astype(h.dtype)
    dt_low, Bm, Cm = jnp.split(dbl, [dtr, dtr + N], axis=-1)
    delta = jax.nn.softplus(dt_low @ p["dt_w"].astype(h.dtype) +
                            p["dt_b"].astype(h.dtype))
    A = -jnp.exp(p["A_log"])
    y, ssm = core_ssm.selective_scan_step(
        cache["ssm"], x_c, delta, A, Bm, Cm, p["D"], reset_t=ctx.reset_t)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x_t.dtype)
    return x_t + out[:, None], {"conv": conv_state, "ssm": ssm}


# ===========================================================================
# Mamba-2 block (SSD: scalar per-head decay, head-structured state)
# ===========================================================================

def init_mamba2(key, cfg: ArchConfig) -> Dict[str, Any]:
    d, di, N, W = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.d_conv
    H = cfg.n_ssm_heads
    ks = jax.random.split(key, 6)
    # Mamba-2 init: A ~ U[1, 16] per head; A = -exp(A_log) < 0
    A = jax.random.uniform(ks[5], (H,), minval=1.0, maxval=16.0)
    out = {
        "norm": jnp.ones((d,)),
        "in_proj": _dense(ks[0], d, 2 * di),
        "conv_w": jax.random.normal(ks[1], (W, di)) * W ** -0.5,
        "conv_b": jnp.zeros((di,)),
        # grouped B/C projections: one (B, C) pair shared by every head
        "bc_proj": _dense(ks[2], di, 2 * N),
        # per-head Δ head (no low-rank bottleneck: H ≪ d_inner already)
        "dt_proj": _dense(ks[3], di, H),
        "dt_b": jnp.full((H,), -4.6),         # softplus⁻¹(0.01)
        "A_log": jnp.log(A),
        "D": jnp.ones((H,)),
        "out_proj": _dense(ks[4], di, d, scale=di ** -0.5),
    }
    if cfg.ssm_norm == "rms_gate":
        out["ssm_norm_w"] = jnp.ones((di,))
    return out


def _mamba2_gates(p, x_c, cfg: ArchConfig):
    """Shared projection head: x_c (..., di) → (Δ (..., H), B, C (..., N))."""
    bc = x_c @ p["bc_proj"].astype(x_c.dtype)
    Bm, Cm = jnp.split(bc, 2, axis=-1)
    delta = jax.nn.softplus(x_c @ p["dt_proj"].astype(x_c.dtype) +
                            p["dt_b"].astype(x_c.dtype))
    return delta, Bm, Cm


def _mamba2_gate_out(p, y, z, cfg: ArchConfig):
    """Mamba-2 output gate: y·silu(z), with the optional RMSNorm-before-
    out_proj variant (``ssm_norm="rms_gate"``: normalize the gated product
    and rescale by a learned (d_inner,) weight — Mamba-2's `rmsnorm` knob,
    which decouples out_proj's input scale from sequence statistics)."""
    g = y * jax.nn.silu(z)
    if "ssm_norm_w" in p:
        g = _norm(p["ssm_norm_w"], g, cfg.norm_eps)
    return g


def apply_mamba2(p, x, ctx: Ctx, cfg: ArchConfig, collect: int = 0,
                 collect_ends=None):
    B, L, d = x.shape
    di, H, P = cfg.d_inner, cfg.n_ssm_heads, cfg.ssm_hd
    backend = "pallas" if cfg.use_pallas else "xla"
    h = _norm(p["norm"], x, cfg.norm_eps)
    xz = h @ p["in_proj"].astype(h.dtype)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c = kops.conv1d_pack(x_in, p["conv_w"].astype(h.dtype),
                           p["conv_b"].astype(h.dtype),
                           ctx.positions, backend=backend)
    x_c = jax.nn.silu(x_c)
    delta, Bm, Cm = _mamba2_gates(p, x_c, cfg)
    A = -jnp.exp(p["A_log"])
    u_h = x_c.reshape(B, L, H, P)
    if collect and collect_ends is not None:
        # per-SEGMENT handoff — same protocol as apply_mamba: no freezing,
        # sample the head-structured state at each segment end.
        y, h_ends = core_ssm.selective_scan_heads(
            u_h, delta, A, Bm, Cm, p["D"], positions=ctx.positions,
            method="blocked", chunk=cfg.scan_chunk, intra=cfg.scan_intra,
            collect_ends=collect_ends, **_tune_kw(cfg))
        state = {"conv": _conv_tail_ends(x_in, collect_ends,
                                         _ends_lens(ctx, collect_ends),
                                         cfg.d_conv),
                 "ssm": h_ends}
        y = _mamba2_gate_out(p, y.reshape(B, L, di), z, cfg)
        return x + y @ p["out_proj"].astype(x.dtype), state
    if collect:
        # freeze state across right-padding (Δ=0 ⇒ decay 1, b-term 0) and
        # neutralize the pos==0 reset at padding slots — same protocol as
        # apply_mamba.
        valid = _valid(ctx, x)
        delta = delta * valid[..., None].astype(delta.dtype)
        pos_nz = jnp.where(valid, ctx.positions, 1)
        y, h_last = core_ssm.selective_scan_heads(
            u_h, delta, A, Bm, Cm, p["D"], positions=pos_nz,
            method="blocked", chunk=cfg.scan_chunk, return_state=True,
            intra=cfg.scan_intra, **_tune_kw(cfg))
        state = {"conv": _conv_tail(x_in, valid.sum(-1), cfg.d_conv),
                 "ssm": h_last}
        y = _mamba2_gate_out(p, y.reshape(B, L, di), z, cfg)
        return x + y @ p["out_proj"].astype(x.dtype), state
    y = kops.selective_scan_heads(u_h, delta, A, Bm, Cm, p["D"],
                                  positions=ctx.positions, backend=backend,
                                  xla_chunk=cfg.scan_chunk,
                                  xla_dtype=(None
                                             if cfg.scan_dtype == "float32"
                                             else cfg.scan_dtype),
                                  xla_intra=cfg.scan_intra,
                                  **_tune_kw(cfg))
    y = _mamba2_gate_out(p, y.reshape(B, L, di), z, cfg)
    return x + y @ p["out_proj"].astype(x.dtype)


def init_mamba2_cache(cfg: ArchConfig, batch: int, dtype):
    di, N, W = cfg.d_inner, cfg.d_state, cfg.d_conv
    H, P = cfg.n_ssm_heads, cfg.ssm_hd
    return {"conv": jnp.zeros((batch, W - 1, di), dtype),
            "ssm": jnp.zeros((batch, H, P, N), jnp.float32)}


def step_mamba2(p, x_t, cache, ctx: Ctx, cfg: ArchConfig):
    B = x_t.shape[0]
    di, H, P = cfg.d_inner, cfg.n_ssm_heads, cfg.ssm_hd
    h = _norm(p["norm"], x_t, cfg.norm_eps)
    xz = (h[:, 0] @ p["in_proj"].astype(h.dtype))
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c, conv_state = conv1d_pack_update(
        x_in, cache["conv"], p["conv_w"].astype(h.dtype),
        p["conv_b"].astype(h.dtype), ctx.reset_t)
    x_c = jax.nn.silu(x_c)
    delta, Bm, Cm = _mamba2_gates(p, x_c, cfg)
    A = -jnp.exp(p["A_log"])
    y, ssm = core_ssm.selective_scan_heads_step(
        cache["ssm"], x_c.reshape(B, H, P), delta, A, Bm, Cm, p["D"],
        reset_t=ctx.reset_t)
    y = _mamba2_gate_out(p, y.reshape(B, di), z, cfg)
    out = y @ p["out_proj"].astype(x_t.dtype)
    return x_t + out[:, None], {"conv": conv_state, "ssm": ssm}


# ===========================================================================
# RG-LRU recurrent block (RecurrentGemma / Griffin)
# ===========================================================================

RGLRU_C_ = 8.0


def init_rec(key, cfg: ArchConfig) -> Dict[str, Any]:
    d = cfg.d_model
    lw = cfg.lru_width or d
    W = cfg.conv_width
    nb = cfg.lru_gate_blocks
    if lw % nb:
        raise ValueError(f"lru_width {lw} % gate blocks {nb} != 0")
    c = lw // nb
    ks = jax.random.split(key, 7)
    # a_param init so that a = exp(-c·softplus(Λ)) lands in [0.9, 0.999]
    u = jax.random.uniform(ks[5], (lw,), minval=0.9, maxval=0.999)
    a_param = jnp.log(jnp.expm1(-jnp.log(u) / RGLRU_C_))  # softplus⁻¹(-ln u/c)
    return {
        "norm": jnp.ones((d,)),
        "w_x": _dense(ks[0], d, lw),
        "w_y": _dense(ks[1], d, lw),
        "conv_w": jax.random.normal(ks[2], (W, lw)) * W ** -0.5,
        "conv_b": jnp.zeros((lw,)),
        # Griffin-faithful block-diagonal gate projections: (nb, c, c) blocks
        # are local to a model-axis shard — the gates never cross shards.
        "w_r": jax.random.normal(ks[3], (nb, c, c)) * c ** -0.5,
        "w_i": jax.random.normal(ks[4], (nb, c, c)) * c ** -0.5,
        "a_param": a_param,
        "wo": _dense(ks[6], lw, d, scale=lw ** -0.5),
    }


def _gate_blockdiag(x_c, w, nb):
    """x_c: (B, L, lw) → block-diagonal projection with (nb, c, c)."""
    B, L, lw = x_c.shape
    xb = x_c.reshape(B, L, nb, lw // nb)
    return jnp.einsum("blnc,ncd->blnd", xb, w).reshape(B, L, lw)


def apply_rec(p, x, ctx: Ctx, cfg: ArchConfig, collect: int = 0,
              collect_ends=None):
    backend = "pallas" if cfg.use_pallas else "xla"
    nb = cfg.lru_gate_blocks
    h = _norm(p["norm"], x, cfg.norm_eps)
    y_branch = jax.nn.gelu(h @ p["w_y"].astype(h.dtype))
    x_branch = h @ p["w_x"].astype(h.dtype)
    x_c = kops.conv1d_pack(x_branch, p["conv_w"].astype(h.dtype),
                           p["conv_b"].astype(h.dtype), ctx.positions,
                           backend=backend)
    r = jax.nn.sigmoid(_gate_blockdiag(x_c, p["w_r"].astype(h.dtype), nb))
    i = jax.nn.sigmoid(_gate_blockdiag(x_c, p["w_i"].astype(h.dtype), nb))
    cdt = None if cfg.scan_dtype == "float32" else cfg.scan_dtype
    if collect and collect_ends is not None:
        # per-SEGMENT handoff: the RG-LRU state trajectory is its output, so
        # segment-end states are a free gather inside rglru — no freezing.
        lru, _, h_ends = rglru(x_c, r, i, p["a_param"], ctx.positions,
                               method="chunked", chunk=cfg.scan_chunk,
                               compute_dtype=cdt, collect_ends=collect_ends)
        out = (lru * y_branch) @ p["wo"].astype(x.dtype)
        return x + out, {"conv": _conv_tail_ends(
            x_branch, collect_ends, _ends_lens(ctx, collect_ends),
            cfg.conv_width), "h": h_ends}
    pos_rec = ctx.positions
    if collect:
        # freeze across padding: r=0 ⇒ a=1, and then b = √(1-a²)·i·x = 0;
        # also neutralize the pos==0 reset at padding slots
        vmask = _valid(ctx, x)
        valid = vmask[..., None].astype(r.dtype)
        r, i = r * valid, i * valid
        pos_rec = jnp.where(vmask, ctx.positions, 1)
    lru, h_last = rglru(x_c, r, i, p["a_param"], pos_rec,
                        method="chunked", chunk=cfg.scan_chunk,
                        compute_dtype=cdt)
    out = (lru * y_branch) @ p["wo"].astype(x.dtype)
    if collect:
        lens = _valid(ctx, x).sum(-1)
        return x + out, {"conv": _conv_tail(x_branch, lens, cfg.conv_width),
                         "h": h_last}
    return x + out


def init_rec_cache(cfg: ArchConfig, batch: int, dtype):
    lw = cfg.lru_width or cfg.d_model
    return {"conv": jnp.zeros((batch, cfg.conv_width - 1, lw), dtype),
            "h": jnp.zeros((batch, lw), jnp.float32)}


def step_rec(p, x_t, cache, ctx: Ctx, cfg: ArchConfig):
    nb = cfg.lru_gate_blocks
    h = _norm(p["norm"], x_t, cfg.norm_eps)
    y_branch = jax.nn.gelu(h[:, 0] @ p["w_y"].astype(h.dtype))
    x_branch = h[:, 0] @ p["w_x"].astype(h.dtype)
    x_c, conv_state = conv1d_pack_update(
        x_branch, cache["conv"], p["conv_w"].astype(h.dtype),
        p["conv_b"].astype(h.dtype), ctx.reset_t)
    r = jax.nn.sigmoid(_gate_blockdiag(x_c[:, None],
                                       p["w_r"].astype(h.dtype), nb)[:, 0])
    i = jax.nn.sigmoid(_gate_blockdiag(x_c[:, None],
                                       p["w_i"].astype(h.dtype), nb)[:, 0])
    y, hn = rglru_step(cache["h"], x_c, r, i, p["a_param"], ctx.reset_t)
    out = (y * y_branch) @ p["wo"].astype(x_t.dtype)
    return x_t + out[:, None], {"conv": conv_state, "h": hn}


# ===========================================================================
# xLSTM blocks
# ===========================================================================

def init_mlstm(key, cfg: ArchConfig) -> Dict[str, Any]:
    d, H = cfg.d_model, cfg.n_heads
    pf = int(cfg.proj_factor * d)
    W = cfg.conv_width
    ks = jax.random.split(key, 8)
    return {
        "norm": jnp.ones((d,)),
        "w_upx": _dense(ks[0], d, pf),
        "w_upz": _dense(ks[1], d, pf),
        "conv_w": jax.random.normal(ks[2], (W, pf)) * W ** -0.5,
        "conv_b": jnp.zeros((pf,)),
        "wq": _dense(ks[3], pf, pf),
        "wk": _dense(ks[4], pf, pf),
        "wv": _dense(ks[5], pf, pf),
        "w_if": _dense(ks[6], pf, 2 * H),
        "b_if": jnp.concatenate([jnp.zeros(H), jnp.full((H,), 3.0)]),
        "w_down": _dense(ks[7], pf, d, scale=pf ** -0.5),
    }


def apply_mlstm(p, x, ctx: Ctx, cfg: ArchConfig, collect: int = 0,
                collect_ends=None):
    B, L, d = x.shape
    H = cfg.n_heads
    pf = p["w_upx"].shape[1]
    dh = pf // H
    backend = "pallas" if cfg.use_pallas else "xla"
    hin = _norm(p["norm"], x, cfg.norm_eps)
    x_in = hin @ p["w_upx"].astype(hin.dtype)
    z = hin @ p["w_upz"].astype(hin.dtype)
    x_c = kops.conv1d_pack(x_in, p["conv_w"].astype(hin.dtype),
                           p["conv_b"].astype(hin.dtype), ctx.positions,
                           backend=backend)
    x_c = jax.nn.silu(x_c)
    q = (x_c @ p["wq"].astype(hin.dtype)).reshape(B, L, H, dh)
    k = (x_c @ p["wk"].astype(hin.dtype)).reshape(B, L, H, dh)
    v = (x_in @ p["wv"].astype(hin.dtype)).reshape(B, L, H, dh)
    g = x_c @ p["w_if"].astype(hin.dtype) + p["b_if"].astype(hin.dtype)
    logi, f_pre = jnp.split(g, 2, axis=-1)
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    logi = logi.astype(jnp.float32)
    if collect and collect_ends is not None:
        # per-SEGMENT handoff. The mLSTM matrix-state trajectory is never
        # materialized (chunkwise form), so per-segment finals are computed
        # by vmapping the freeze trick over segments: gates outside segment
        # g are identity (f'=1, i'=0), so the row's FINAL state equals the
        # state at g's last token. The big projections above run once; only
        # the O(L·H·dk·dv) state update repeats S times.
        y = mlstm(q, k, v, logf, logi, positions=ctx.positions,
                  chunk=cfg.scan_chunk)

        def one_seg(sid):
            msk = ctx.segment_ids == sid
            lf = jnp.where(msk[..., None], logf, 0.0)
            li = jnp.where(msk[..., None], logi, -1e30)
            ps = jnp.where(msk, ctx.positions, 1)
            _, st = mlstm(q, k, v, lf, li, positions=ps,
                          chunk=cfg.scan_chunk, return_state=True)
            return st

        nseg = collect_ends.shape[1]
        Cs, ns, ms = jax.vmap(one_seg, out_axes=1)(
            jnp.arange(1, nseg + 1, dtype=jnp.int32))
        state = {"conv": _conv_tail_ends(x_in, collect_ends,
                                         _ends_lens(ctx, collect_ends),
                                         cfg.conv_width),
                 "C": Cs, "n": ns, "m": ms}
        y = y.reshape(B, L, pf) * jax.nn.silu(z)
        return x + y @ p["w_down"].astype(x.dtype), state
    if collect:
        # freeze across padding: f'=1 (logf=0), i'=0 (logi=-inf); neutralize
        # the pos==0 reset at padding slots
        vmask = _valid(ctx, x)
        valid = vmask[..., None]
        logf = jnp.where(valid, logf, 0.0)
        logi = jnp.where(valid, logi, -1e30)
        pos_nz = jnp.where(vmask, ctx.positions, 1)
        y, (C, n, m) = mlstm(q, k, v, logf, logi, positions=pos_nz,
                             chunk=cfg.scan_chunk, return_state=True)
        lens = _valid(ctx, x).sum(-1)
        state = {"conv": _conv_tail(x_in, lens, cfg.conv_width),
                 "C": C, "n": n, "m": m}
        y = y.reshape(B, L, pf) * jax.nn.silu(z)
        return x + y @ p["w_down"].astype(x.dtype), state
    y = mlstm(q, k, v, logf, logi, positions=ctx.positions,
              chunk=cfg.scan_chunk)
    y = y.reshape(B, L, pf) * jax.nn.silu(z)
    return x + y @ p["w_down"].astype(x.dtype)


def init_mlstm_cache(cfg: ArchConfig, batch: int, dtype):
    H = cfg.n_heads
    pf = int(cfg.proj_factor * cfg.d_model)
    dh = pf // H
    return {"conv": jnp.zeros((batch, cfg.conv_width - 1, pf), dtype),
            "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, H, dh), jnp.float32),
            "m": jnp.full((batch, H), -1e30, jnp.float32)}


def step_mlstm(p, x_t, cache, ctx: Ctx, cfg: ArchConfig):
    B = x_t.shape[0]
    H = cfg.n_heads
    pf = p["w_upx"].shape[1]
    dh = pf // H
    hin = _norm(p["norm"], x_t, cfg.norm_eps)
    x_in = hin[:, 0] @ p["w_upx"].astype(hin.dtype)
    z = hin[:, 0] @ p["w_upz"].astype(hin.dtype)
    x_c, conv_state = conv1d_pack_update(
        x_in, cache["conv"], p["conv_w"].astype(hin.dtype),
        p["conv_b"].astype(hin.dtype), ctx.reset_t)
    x_c = jax.nn.silu(x_c)
    q = (x_c @ p["wq"].astype(hin.dtype)).reshape(B, H, dh)
    k = (x_c @ p["wk"].astype(hin.dtype)).reshape(B, H, dh)
    v = (x_in @ p["wv"].astype(hin.dtype)).reshape(B, H, dh)
    g = x_c @ p["w_if"].astype(hin.dtype) + p["b_if"].astype(hin.dtype)
    logi, f_pre = jnp.split(g, 2, axis=-1)
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    y, (C, n, m) = mlstm_step((cache["C"], cache["n"], cache["m"]),
                              q, k, v, logf, logi.astype(jnp.float32),
                              ctx.reset_t)
    y = y.reshape(B, pf) * jax.nn.silu(z)
    out = y @ p["w_down"].astype(x_t.dtype)
    return x_t + out[:, None], {"conv": conv_state, "C": C, "n": n, "m": m}


def init_slstm(key, cfg: ArchConfig) -> Dict[str, Any]:
    d, H = cfg.d_model, cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 3)
    return {
        "norm": jnp.ones((d,)),
        "w_pre": _dense(ks[0], d, 4 * d),
        "R": jax.random.normal(ks[1], (4, H, dh, dh)) * dh ** -0.5 * 0.3,
        "w_out": _dense(ks[2], d, d),
    }


def apply_slstm(p, x, ctx: Ctx, cfg: ArchConfig, collect: int = 0,
                collect_ends=None):
    B, L, d = x.shape
    H = cfg.n_heads
    dh = d // H
    h = _norm(p["norm"], x, cfg.norm_eps)
    pre = (h @ p["w_pre"].astype(h.dtype)).reshape(B, L, 4, H, dh)
    if collect and collect_ends is not None:
        # per-SEGMENT handoff: sLSTM is inherently sequential, so vmap its
        # existing valid-freeze over segments (state frozen outside segment
        # g ⇒ row-final state = state at g's last token).
        y = slstm(pre, p["R"], positions=ctx.positions)

        def one_seg(sid):
            msk = ctx.segment_ids == sid
            _, st = slstm(pre, p["R"],
                          positions=jnp.where(msk, ctx.positions, 1),
                          valid=msk, return_state=True)
            return st

        nseg = collect_ends.shape[1]
        cs, ns, ms, hs = jax.vmap(one_seg, out_axes=1)(
            jnp.arange(1, nseg + 1, dtype=jnp.int32))
        out = x + y.reshape(B, L, d) @ p["w_out"].astype(x.dtype)
        return out, {"c": cs, "n": ns, "m": ms, "h": hs}
    if collect:
        y, (c, n, m, hh) = slstm(pre, p["R"], positions=ctx.positions,
                                 valid=_valid(ctx, x), return_state=True)
        out = x + y.reshape(B, L, d) @ p["w_out"].astype(x.dtype)
        return out, {"c": c, "n": n, "m": m, "h": hh}
    y = slstm(pre, p["R"], positions=ctx.positions)
    y = y.reshape(B, L, d) @ p["w_out"].astype(x.dtype)
    return x + y


def init_slstm_cache(cfg: ArchConfig, batch: int, dtype):
    H = cfg.n_heads
    dh = cfg.d_model // H
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((batch, H, dh), -1e30, jnp.float32),
            "h": z}


def step_slstm(p, x_t, cache, ctx: Ctx, cfg: ArchConfig):
    B = x_t.shape[0]
    H = cfg.n_heads
    d = cfg.d_model
    dh = d // H
    h = _norm(p["norm"], x_t, cfg.norm_eps)
    pre = (h[:, 0] @ p["w_pre"].astype(h.dtype)).reshape(B, 1, 4, H, dh)
    st = (cache["c"], cache["n"], cache["m"], cache["h"])
    pos = None
    if ctx.reset_t is not None:
        pos = jnp.where(ctx.reset_t, 0, 1)[:, None]      # (B,1): 0 ⇒ reset
    y, (c, n, m, hh) = slstm(pre, p["R"], positions=pos, state=st,
                             return_state=True)
    out = y.reshape(B, d) @ p["w_out"].astype(x_t.dtype)
    return x_t + out[:, None], {"c": c, "n": n, "m": m, "h": hh}


# ===========================================================================
# chunk-resume prefill steps
# ===========================================================================
# ``chunk_<kind>(p, x, cache, ctx, cfg) -> (x, cache)`` consumes a (B, T, d)
# slab of a LONG prompt and advances the DECODE-layout cache in place — the
# O(1) recurrent state makes resumable prefill natural (no KV re-read; the
# attention ring is the one windowed structure, handled below). Protocol:
#   ctx.positions    (B, T) GLOBAL intra-sequence positions (off + t);
#                    padding slots hold anything (they are neutralized)
#   ctx.segment_ids  (B, T) 1 = real token, 0 = padding (trailing only —
#                    one request per chunk row, never packed)
#   ctx.cache_len    (B,) tokens already consumed before this chunk
# Rows whose slab is all padding are exact state no-ops (freeze semantics:
# Δ=0 ⇒ Ā=1, B̄x=0 — the same trick the per-row collect paths use).


def _conv_resume(x_in, conv_cache, w, b, positions, backend):
    """Causal conv over a resumed chunk: prepend the cached (W-1)-tail,
    run conv1d_pack, drop the warm-up outputs. Tap validity depends only on
    the OUTPUT position, so extending positions with W-1 leading zeros
    leaves every kept output exact. Returns (x_c (B, T, D), new tail)."""
    B, T, D = x_in.shape
    W = w.shape[0]
    ext = jnp.concatenate([conv_cache.astype(x_in.dtype), x_in], axis=1)
    pos_ext = jnp.concatenate(
        [jnp.zeros((B, W - 1), positions.dtype), positions], axis=1)
    x_c = kops.conv1d_pack(ext, w, b, pos_ext, backend=backend)[:, W - 1:]
    return x_c


def chunk_mamba(p, x, cache, ctx: Ctx, cfg: ArchConfig):
    B, T, d = x.shape
    N, dtr, W = cfg.d_state, cfg.dtr, cfg.d_conv
    backend = "pallas" if cfg.use_pallas else "xla"
    h = _norm(p["norm"], x, cfg.norm_eps)
    xz = h @ p["in_proj"].astype(h.dtype)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c = _conv_resume(x_in, cache["conv"], p["conv_w"].astype(h.dtype),
                       p["conv_b"].astype(h.dtype), ctx.positions, backend)
    x_c = jax.nn.silu(x_c)
    dbl = x_c @ p["x_proj"].astype(h.dtype)
    dt_low, Bm, Cm = jnp.split(dbl, [dtr, dtr + N], axis=-1)
    delta = jax.nn.softplus(dt_low @ p["dt_w"].astype(h.dtype) +
                            p["dt_b"].astype(h.dtype))
    A = -jnp.exp(p["A_log"])
    valid = _valid(ctx, x)
    delta = delta * valid[..., None].astype(delta.dtype)
    pos_nz = jnp.where(valid, ctx.positions, 1)
    y, h_last = core_ssm.selective_scan(
        x_c, delta, A, Bm, Cm, p["D"], positions=pos_nz,
        method=cfg.scan_impl, chunk=cfg.scan_chunk, return_state=True,
        h0=cache["ssm"], intra=cfg.scan_intra, **_tune_kw(cfg))
    ext = jnp.concatenate([cache["conv"].astype(x_in.dtype), x_in], axis=1)
    state = {"conv": _conv_tail(ext, (W - 1) + valid.sum(-1), W),
             "ssm": h_last}
    y = y * jax.nn.silu(z)
    return x + y @ p["out_proj"].astype(x.dtype), state


def chunk_mamba2(p, x, cache, ctx: Ctx, cfg: ArchConfig):
    B, T, d = x.shape
    di, H, P, W = cfg.d_inner, cfg.n_ssm_heads, cfg.ssm_hd, cfg.d_conv
    backend = "pallas" if cfg.use_pallas else "xla"
    h = _norm(p["norm"], x, cfg.norm_eps)
    xz = h @ p["in_proj"].astype(h.dtype)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_c = _conv_resume(x_in, cache["conv"], p["conv_w"].astype(h.dtype),
                       p["conv_b"].astype(h.dtype), ctx.positions, backend)
    x_c = jax.nn.silu(x_c)
    delta, Bm, Cm = _mamba2_gates(p, x_c, cfg)
    A = -jnp.exp(p["A_log"])
    valid = _valid(ctx, x)
    delta = delta * valid[..., None].astype(delta.dtype)
    pos_nz = jnp.where(valid, ctx.positions, 1)
    y, h_last = core_ssm.selective_scan_heads(
        x_c.reshape(B, T, H, P), delta, A, Bm, Cm, p["D"],
        positions=pos_nz, method="blocked", chunk=cfg.scan_chunk,
        return_state=True, h0=cache["ssm"], intra=cfg.scan_intra,
        **_tune_kw(cfg))
    ext = jnp.concatenate([cache["conv"].astype(x_in.dtype), x_in], axis=1)
    state = {"conv": _conv_tail(ext, (W - 1) + valid.sum(-1), W),
             "ssm": h_last}
    y = _mamba2_gate_out(p, y.reshape(B, T, di), z, cfg)
    return x + y @ p["out_proj"].astype(x.dtype), state


def chunk_rec(p, x, cache, ctx: Ctx, cfg: ArchConfig):
    backend = "pallas" if cfg.use_pallas else "xla"
    nb = cfg.lru_gate_blocks
    W = cfg.conv_width
    h = _norm(p["norm"], x, cfg.norm_eps)
    y_branch = jax.nn.gelu(h @ p["w_y"].astype(h.dtype))
    x_branch = h @ p["w_x"].astype(h.dtype)
    x_c = _conv_resume(x_branch, cache["conv"], p["conv_w"].astype(h.dtype),
                       p["conv_b"].astype(h.dtype), ctx.positions, backend)
    r = jax.nn.sigmoid(_gate_blockdiag(x_c, p["w_r"].astype(h.dtype), nb))
    i = jax.nn.sigmoid(_gate_blockdiag(x_c, p["w_i"].astype(h.dtype), nb))
    vmask = _valid(ctx, x)
    valid = vmask[..., None].astype(r.dtype)
    r, i = r * valid, i * valid
    pos_nz = jnp.where(vmask, ctx.positions, 1)
    cdt = None if cfg.scan_dtype == "float32" else cfg.scan_dtype
    lru, h_last = rglru(x_c, r, i, p["a_param"], pos_nz, h0=cache["h"],
                        method="chunked", chunk=cfg.scan_chunk,
                        compute_dtype=cdt)
    out = (lru * y_branch) @ p["wo"].astype(x.dtype)
    ext = jnp.concatenate([cache["conv"].astype(x_branch.dtype), x_branch],
                          axis=1)
    return x + out, {"conv": _conv_tail(ext, (W - 1) + vmask.sum(-1), W),
                     "h": h_last}


def chunk_attn(p, x, cache, ctx: Ctx, cfg: ArchConfig):
    """Chunked prefill into the ring-buffer KV cache: attend (one joint
    softmax over the cached prefix ring and the intra-chunk causal keys),
    THEN write the chunk's post-rope K/V into its ring slots — the write
    may evict prefix slots the chunk itself still needed, so order matters.
    Requires chunk T ≤ ring size S (the engine sizes chunks to fit)."""
    B, T, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    S = cache["k"].shape[1]
    if T > S:
        raise ValueError(f"chunk length {T} exceeds attention cache/window "
                         f"{S} — use a chunk size ≤ the attention window")
    G = H // Hkv
    h = _norm(p["norm"], x, cfg.norm_eps)
    q = (h @ p["wq"].astype(h.dtype)).reshape(B, T, H, hd)
    kv = (h @ p["wkv"].astype(h.dtype)).reshape(B, T, 2, Hkv, hd)
    k, v = kv[:, :, 0], kv[:, :, 1]
    q, k = _apply_rope(cfg, q, k, ctx)
    valid = _valid(ctx, x)                               # (B, T)
    pos = ctx.positions                                  # (B, T) global
    clen = ctx.cache_len[:, None]                        # (B, 1)
    # prefix ring: slot s holds token t_s = s + ((clen-1-s)//S)·S < clen
    s_idx = jnp.arange(S)[None, :]
    t_s = s_idx + ((clen - 1 - s_idx) // S) * S          # (B, S)
    pref_ok = (s_idx < clen) & (t_s >= 0)
    qr = q.reshape(B, T, Hkv, G, hd)
    sc_pre = jnp.einsum("btkgd,bskd->btkgs", qr, cache["k"],
                        preferred_element_type=jnp.float32) * hd ** -0.5
    m_pre = pref_ok[:, None, :]                          # (B, 1, S)
    if cfg.attn_window is not None:
        m_pre = m_pre & (t_s[:, None, :] >
                         pos[:, :, None] - cfg.attn_window)
    sc_pre = jnp.where(m_pre[:, :, None, None, :], sc_pre, -1e30)
    # intra-chunk: causal over the slab, windowed, padding keys excluded
    sc_in = jnp.einsum("btkgd,bjkd->btkgj", qr, k,
                       preferred_element_type=jnp.float32) * hd ** -0.5
    m_in = (pos[:, :, None] >= pos[:, None, :]) & valid[:, None, :]
    m_in = m_in & (jnp.arange(T)[None, :, None] >= jnp.arange(T)[None, None])
    if cfg.attn_window is not None:
        m_in = m_in & (pos[:, None, :] > pos[:, :, None] - cfg.attn_window)
    sc_in = jnp.where(m_in[:, :, None, None, :], sc_in, -1e30)
    sc = jnp.concatenate([sc_pre, sc_in], axis=-1)       # (B, T, Hkv, G, S+T)
    pr = jax.nn.softmax(sc, axis=-1).astype(v.dtype)
    o = (jnp.einsum("btkgs,bskd->btkgd", pr[..., :S], cache["v"]) +
         jnp.einsum("btkgj,bjkd->btkgd", pr[..., S:], v))
    o = o.reshape(B, T, H * hd) @ p["wo"].astype(x.dtype)
    # write AFTER attending: valid chunk tokens land at pos % S (distinct
    # because T ≤ S), padding routes to the drop sentinel S
    slot = jnp.where(valid, pos % S, S)
    bidx = jnp.arange(B)[:, None]
    kc = cache["k"].at[bidx, slot].set(k, mode="drop")
    vc = cache["v"].at[bidx, slot].set(v, mode="drop")
    return x + o, {"k": kc, "v": vc}


def chunk_mlstm(p, x, cache, ctx: Ctx, cfg: ArchConfig):
    B, T, d = x.shape
    H = cfg.n_heads
    pf = p["w_upx"].shape[1]
    dh = pf // H
    W = cfg.conv_width
    backend = "pallas" if cfg.use_pallas else "xla"
    hin = _norm(p["norm"], x, cfg.norm_eps)
    x_in = hin @ p["w_upx"].astype(hin.dtype)
    z = hin @ p["w_upz"].astype(hin.dtype)
    x_c = _conv_resume(x_in, cache["conv"], p["conv_w"].astype(hin.dtype),
                       p["conv_b"].astype(hin.dtype), ctx.positions, backend)
    x_c = jax.nn.silu(x_c)
    q = (x_c @ p["wq"].astype(hin.dtype)).reshape(B, T, H, dh)
    k = (x_c @ p["wk"].astype(hin.dtype)).reshape(B, T, H, dh)
    v = (x_in @ p["wv"].astype(hin.dtype)).reshape(B, T, H, dh)
    g = x_c @ p["w_if"].astype(hin.dtype) + p["b_if"].astype(hin.dtype)
    logi, f_pre = jnp.split(g, 2, axis=-1)
    logf = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    logi = logi.astype(jnp.float32)
    vmask = _valid(ctx, x)
    valid = vmask[..., None]
    logf = jnp.where(valid, logf, 0.0)
    logi = jnp.where(valid, logi, -1e30)
    pos_nz = jnp.where(vmask, ctx.positions, 1)
    y, (C, n, m) = mlstm(q, k, v, logf, logi, positions=pos_nz,
                         chunk=cfg.scan_chunk,
                         state=(cache["C"], cache["n"], cache["m"]),
                         return_state=True)
    ext = jnp.concatenate([cache["conv"].astype(x_in.dtype), x_in], axis=1)
    state = {"conv": _conv_tail(ext, (W - 1) + vmask.sum(-1), W),
             "C": C, "n": n, "m": m}
    y = y.reshape(B, T, pf) * jax.nn.silu(z)
    return x + y @ p["w_down"].astype(x.dtype), state


def chunk_slstm(p, x, cache, ctx: Ctx, cfg: ArchConfig):
    B, T, d = x.shape
    H = cfg.n_heads
    dh = d // H
    h = _norm(p["norm"], x, cfg.norm_eps)
    pre = (h @ p["w_pre"].astype(h.dtype)).reshape(B, T, 4, H, dh)
    st = (cache["c"], cache["n"], cache["m"], cache["h"])
    y, (c, n, m, hh) = slstm(pre, p["R"], positions=ctx.positions,
                             state=st, valid=_valid(ctx, x),
                             return_state=True)
    out = x + y.reshape(B, T, d) @ p["w_out"].astype(x.dtype)
    return out, {"c": c, "n": n, "m": m, "h": hh}


CHUNK = {"attn": chunk_attn, "mamba": chunk_mamba, "mamba2": chunk_mamba2,
         "rec": chunk_rec, "mlstm": chunk_mlstm, "slstm": chunk_slstm}


# ===========================================================================
# batched sampling (serving decode)
# ===========================================================================
# Key plumbing is raw-uint32 (B, 2) arrays so per-slot keys live as ordinary
# pytree leaves inside jitted engine steps (scatter/carry like any other slot
# state); `request_keys` derives a request's stream from (seed, rid) so a
# request samples identically wherever its slot lands.

def request_keys(seed, rids):
    """Per-request PRNG keys: fold each rid into a base seed. rids (K,) int32
    → (K, 2) uint32 key batch."""
    base = jax.random.PRNGKey(seed)
    return jax.vmap(lambda r: jax.random.fold_in(base, r))(
        jnp.asarray(rids, jnp.int32))


def split_keys(keys):
    """Advance a (B, 2) key batch one step: returns (carry, subkey), each
    (B, 2) uint32."""
    pairs = jax.vmap(lambda k: jax.random.split(k, 2))(keys)
    return pairs[:, 0], pairs[:, 1]


def sample_from_logits(logits, keys, temperature, top_k, top_p):
    """Fixed-shape batched sampling over decode slots.

    logits (B, V) f32; keys (B, 2) uint32 per-slot PRNG carry;
    temperature (B,) f32 — ``<= 0`` means GREEDY (argmax, key unused but
    still advanced so slot streams stay aligned); top_k (B,) int32 — keep
    the k highest logits (``<= 0`` disables); top_p (B,) f32 — keep the
    smallest prefix of the sorted distribution with mass ≥ top_p
    (``>= 1`` disables). All three are per-slot so one jitted step serves a
    mixed batch. Returns (tokens (B,) int32, new_keys (B, 2) uint32).
    """
    V = logits.shape[-1]
    lg = logits.astype(jnp.float32)
    greedy_tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    sorted_lg = -jnp.sort(-lg, axis=-1)                       # descending
    # top-k: threshold at the k-th largest logit (k<=0 → full vocab)
    k = jnp.where(top_k > 0, top_k, V).astype(jnp.int32)
    kth = jnp.take_along_axis(sorted_lg,
                              jnp.clip(k - 1, 0, V - 1)[:, None], axis=-1)
    masked = jnp.where(lg >= kth, lg, -jnp.inf)
    # top-p (nucleus): keep sorted tokens while the mass BEFORE them < p —
    # always keeps at least the argmax; threshold back onto unsorted logits
    probs = jax.nn.softmax(sorted_lg, axis=-1)
    before = jnp.cumsum(probs, axis=-1) - probs
    nkeep = (before < jnp.clip(top_p, 0.0, 1.0)[:, None]).sum(-1)
    pth = jnp.take_along_axis(sorted_lg,
                              jnp.clip(nkeep - 1, 0, V - 1)[:, None], axis=-1)
    masked = jnp.where(lg >= pth, masked, -jnp.inf)
    carry, sub = split_keys(keys)
    gumbel = jax.vmap(lambda kk: jax.random.gumbel(kk, (V,), jnp.float32))(
        sub)
    temp = jnp.maximum(temperature, 1e-6)[:, None]
    sampled = jnp.argmax(masked / temp + gumbel, axis=-1).astype(jnp.int32)
    tok = jnp.where(temperature > 0.0, sampled, greedy_tok)
    return tok, carry


# ===========================================================================
# kind registry
# ===========================================================================

INIT = {"attn": init_attn, "mlp": init_mlp, "moe": init_moe,
        "mamba": init_mamba, "mamba2": init_mamba2, "rec": init_rec,
        "mlstm": init_mlstm, "slstm": init_slstm}

CACHE_INIT = {"attn": init_attn_cache, "mamba": init_mamba_cache,
              "mamba2": init_mamba2_cache, "rec": init_rec_cache,
              "mlstm": init_mlstm_cache, "slstm": init_slstm_cache}

STEP = {"attn": step_attn, "mamba": step_mamba, "mamba2": step_mamba2,
        "rec": step_rec, "mlstm": step_mlstm, "slstm": step_slstm}
