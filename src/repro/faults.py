"""Deterministic fault-injection seam for the serve stack.

A production engine meets failures the test suite never wrote: a packed
prefill dispatch dies (driver OOM, preempted device), a decode step emits
NaN/Inf logits (bad weight load, overflowed accumulator), the process is
killed mid-flight. ``FaultPlan`` makes every one of those failure modes a
*deterministic, replayable* event on CPU: the ServeEngine consults the plan
at its three seams — prefill dispatch (``fails_prefill``), the in-flight
readiness probe (``prefill_not_ready``), and the decode step
(``decode_poison`` / ``kills``) — so a test can script "fail the 2nd
prefill while it overlaps decode" or "poison slot 3's logits at step 7 and
prove the other slots' token streams are bit-identical".

The plan is *pure*: every query is a function of (plan, index), never of
call order, so an engine that re-runs the same admission trace sees the
same faults — which is what makes kill-and-restore round-trips provable.

``FaultPlan.random(seed)`` draws a randomized-but-seeded plan for the
chaos lane (``make verify-faults``): same seed, same faults, forever.

Poison values use NaN *or* Inf (both non-finite; both must trip the
engine's guard rails — ``jnp.isfinite`` catches either).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np
import jax.numpy as jnp


class EngineKilled(RuntimeError):
    """Simulated process death: the engine loses everything not persisted
    by its last ``snapshot()``. Raised *before* the indexed decode step, so
    device state is at a clean step boundary when the plan fires."""


class PrefillFault(RuntimeError):
    """Injected failure of a packed prefill dispatch (stands in for a
    device OOM / preemption on the packed forward)."""


@dataclasses.dataclass
class FaultPlan:
    """Declarative fault schedule, threaded through ServeEngine.

    fail_prefill     index of the prefill dispatch that raises
                     (0-based over ``stats.prefills``); the engine fails
                     that round's requests and keeps serving.
    delay_prefill    {prefill index: n} — the readiness probe reports
                     not-ready for the first n probes of that prefill,
                     scripting a wide overlap window deterministically.
    poison_prefill   {prefill index: [(row, seg), …]} — NaN the harvested
                     states of those packed segments (``poison_states``).
    poison_decode    {decode step: [slot, …]} — add a non-finite value to
                     those slots' logits inside the guarded decode step.
    fail_chunk       index of the chunked-prefill round that raises
                     (0-based over ``stats.chunk_rounds``); the engine
                     fails the requests on the chunk rows and keeps going.
    poison_chunk     {chunk round: [row, …]} — NaN those chunk rows' carried
                     cache state after the round (``poison_cache_rows``),
                     modelling a corrupted chunk forward at a boundary.
    drop_cache       index of the StateCache LOOKUP before which the whole
                     prefix cache is cleared (0-based over
                     ``cache.hits + cache.misses``) — the forced-evict
                     seam: a would-be hit becomes a cold miss and must
                     fall back to a full (chunked) prefill.
    poison_cache_hit [hit index, …] — NaN the restored state of those
                     cache HITS (0-based over ``cache.hits``), modelling a
                     corrupted stored state; the guard rails must
                     quarantine the request, never stream from it.
    poison_value     what the poison injects (NaN by default; ±Inf also
                     legal — anything non-finite).
    kill_at_step     raise ``EngineKilled`` before this decode step.
    """
    fail_prefill: Optional[int] = None
    delay_prefill: Dict[int, int] = dataclasses.field(default_factory=dict)
    poison_prefill: Dict[int, List[Tuple[int, int]]] = \
        dataclasses.field(default_factory=dict)
    poison_decode: Dict[int, List[int]] = \
        dataclasses.field(default_factory=dict)
    fail_chunk: Optional[int] = None
    poison_chunk: Dict[int, List[int]] = \
        dataclasses.field(default_factory=dict)
    drop_cache: Optional[int] = None
    poison_cache_hit: List[int] = dataclasses.field(default_factory=list)
    poison_value: float = float("nan")
    kill_at_step: Optional[int] = None

    # ------------------------------------------------------------- queries
    def fails_prefill(self, pidx: int) -> bool:
        return self.fail_prefill is not None and pidx == self.fail_prefill

    def prefill_not_ready(self, pidx: int, probes: int) -> bool:
        """True while the plan still delays prefill ``pidx`` (the engine
        counts the probes it has already made)."""
        return probes < self.delay_prefill.get(pidx, 0)

    def prefill_poison(self, pidx: int) -> Optional[List[Tuple[int, int]]]:
        return self.poison_prefill.get(pidx)

    def decode_poison(self, step: int, num_slots: int) \
            -> Optional[np.ndarray]:
        """(num_slots,) float32 additive poison vector for this decode
        step, or None when the step is clean. Unpoisoned slots get 0.0 —
        adding it is a bitwise no-op on their logits."""
        slots = self.poison_decode.get(step)
        if not slots:
            return None
        v = np.zeros(num_slots, np.float32)
        for s in slots:
            v[s] = self.poison_value
        return v

    def fails_chunk(self, cidx: int) -> bool:
        return self.fail_chunk is not None and cidx == self.fail_chunk

    def chunk_poison(self, cidx: int) -> Optional[List[int]]:
        return self.poison_chunk.get(cidx)

    def drops_cache(self, lidx: int) -> bool:
        return self.drop_cache is not None and lidx == self.drop_cache

    def cache_hit_poison(self, hidx: int) -> bool:
        return hidx in self.poison_cache_hit

    def kills(self, step: int) -> bool:
        return self.kill_at_step is not None and step == self.kill_at_step

    def needs_guard(self) -> bool:
        """Plans that poison numerics only observable through the engine's
        finiteness probes (the engine auto-enables its guard for them)."""
        return bool(self.poison_prefill or self.poison_decode
                    or self.poison_chunk or self.poison_cache_hit)

    def empty(self) -> bool:
        return (self.fail_prefill is None and not self.delay_prefill
                and not self.poison_prefill and not self.poison_decode
                and self.fail_chunk is None and not self.poison_chunk
                and self.drop_cache is None and not self.poison_cache_hit
                and self.kill_at_step is None)

    # ---------------------------------------------------------- generation
    @classmethod
    def random(cls, seed: int, *, max_prefills: int = 4,
               max_steps: int = 30, num_slots: int = 4,
               prefill_rows: int = 2, max_segments: int = 2,
               chunk_rows: int = 0, cache_lookups: int = 0,
               allow_kill: bool = False) -> "FaultPlan":
        """Randomized-but-seeded plan for the chaos lane: each fault
        category fires with probability 1/2, placed uniformly inside the
        given workload envelope. Same seed → same plan, on any machine.
        ``cache_lookups`` > 0 opts the StateCache seams (drop_cache /
        poison_cache_hit) into the envelope — gated so pre-cache chaos
        seeds keep drawing the exact same plans. ``allow_kill`` is opt-in
        because a kill needs the caller to orchestrate snapshot/restore
        around it."""
        rng = np.random.default_rng(seed)
        plan = cls()
        if rng.random() < 0.5:
            plan.fail_prefill = int(rng.integers(0, max_prefills))
        if rng.random() < 0.5:
            plan.delay_prefill = {int(rng.integers(0, max_prefills)):
                                  int(rng.integers(1, 5))}
        if rng.random() < 0.5:
            plan.poison_prefill = {
                int(rng.integers(0, max_prefills)):
                [(int(rng.integers(0, prefill_rows)),
                  int(rng.integers(0, max_segments)))]}
        if rng.random() < 0.5:
            plan.poison_decode = {int(rng.integers(1, max_steps)):
                                  [int(rng.integers(0, num_slots))]}
        if chunk_rows > 0 and rng.random() < 0.5:
            plan.fail_chunk = int(rng.integers(0, max_prefills))
        if chunk_rows > 0 and rng.random() < 0.5:
            plan.poison_chunk = {int(rng.integers(0, max_prefills)):
                                 [int(rng.integers(0, chunk_rows))]}
        if cache_lookups > 0 and rng.random() < 0.5:
            plan.drop_cache = int(rng.integers(0, cache_lookups))
        if cache_lookups > 0 and rng.random() < 0.5:
            plan.poison_cache_hit = [int(rng.integers(0, cache_lookups))]
        if rng.random() < 0.5:
            plan.poison_value = float(rng.choice([np.nan, np.inf, -np.inf]))
        if allow_kill and rng.random() < 0.5:
            plan.kill_at_step = int(rng.integers(2, max_steps))
        return plan


def poison_states(states, rows_segs, value: float = float("nan")):
    """Inject a non-finite value into the harvested prefill states of the
    given packed segments. ``states`` is the pytree from
    ``model.prefill_packed`` — leaves carry (B, S, …) leading dims, or
    (n_units, B, S, …) for unit-stacked layers; ``rows_segs`` is a list of
    (row, seg) targets. Implemented as a (B, S) multiplicative mask (1
    everywhere, ``value`` at the targets) broadcast into each leaf, so one
    tree_map poisons every layer's state for the segment — exactly what a
    corrupted packed forward would look like."""
    import jax

    def one(path, leaf):
        if not jnp.issubdtype(leaf.dtype, jnp.inexact):
            return leaf                 # int bookkeeping can't hold a NaN
        stacked = any(getattr(p, "key", None) == "units" for p in path)
        bs = leaf.shape[1:3] if stacked else leaf.shape[:2]
        m = np.ones(bs, np.float32)
        for r, s in rows_segs:
            m[r, s] = value
        mask = jnp.asarray(m)
        extra = leaf.ndim - (3 if stacked else 2)
        mask = mask.reshape(((1,) if stacked else ()) + bs + (1,) * extra)
        return (leaf * mask).astype(leaf.dtype)

    return jax.tree_util.tree_map_with_path(one, states)


def poison_cache_rows(cache, rows, value: float = float("nan")):
    """Inject a non-finite value into whole rows of a decode-layout cache.
    ``cache`` is the pytree from ``model.init_cache`` — leaves carry (B, …)
    leading dims, or (n_units, B, …) for unit-stacked layers; ``rows`` is a
    list of row indices. The chunked-prefill analogue of
    ``poison_states``: a corrupted chunk forward corrupts the carried cache
    of that chunk row, in every layer."""
    import jax

    def one(path, leaf):
        if not jnp.issubdtype(leaf.dtype, jnp.inexact):
            return leaf
        stacked = any(getattr(p, "key", None) == "units" for p in path)
        b = leaf.shape[1] if stacked else leaf.shape[0]
        m = np.ones(b, np.float32)
        for r in rows:
            m[r] = value
        mask = jnp.asarray(m)
        extra = leaf.ndim - (2 if stacked else 1)
        mask = mask.reshape(((1,) if stacked else ()) + (b,) + (1,) * extra)
        return (leaf * mask).astype(leaf.dtype)

    return jax.tree_util.tree_map_with_path(one, cache)
