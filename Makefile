# CI entry points. `make verify` is the tier-1 gate (ROADMAP.md).
PY := PYTHONPATH=src python

# Perf gate files: OLD/SERVE_OLD are the committed baselines; NEW/SERVE_NEW
# are what `bench-scan` / `bench-serve` write (env overrides in
# benchmarks/run.py keep the baselines untouched). To refresh a committed
# baseline instead: `make bench-scan NEW=BENCH_scan.json` /
# `make bench-serve SERVE_NEW=BENCH_serve.json`.
OLD ?= BENCH_scan.json
NEW ?= BENCH_scan.new.json
SERVE_OLD ?= BENCH_serve.json
SERVE_NEW ?= BENCH_serve.new.json

.PHONY: verify bench-scan bench-serve bench-compare quickstart

verify:
	$(PY) -m pytest -x -q

# regenerate the scan-schedule matrix into $(NEW)
bench-scan:
	BENCH_SCAN_JSON=$(NEW) $(PY) -m benchmarks.run fig2

# regenerate the serving padded-vs-packed throughput rows into $(SERVE_NEW)
bench-serve:
	BENCH_SERVE_JSON=$(SERVE_NEW) $(PY) -m benchmarks.run serve

# gate on the perf trajectories: exits nonzero on >10% regressions
# (serve compare is skipped if a side wasn't regenerated)
bench-compare:
	$(PY) benchmarks/compare.py $(OLD) $(NEW)
	$(PY) benchmarks/compare.py $(SERVE_OLD) $(SERVE_NEW) --allow-missing

quickstart:
	$(PY) examples/quickstart.py
