# CI entry points. `make verify` is the tier-1 gate (ROADMAP.md).
PY := PYTHONPATH=src python

# Scan-schedule perf gate files: OLD is the committed baseline; NEW is the
# fresh run `bench-scan` writes (BENCH_SCAN_JSON env override in
# benchmarks/run.py keeps the baseline untouched). To refresh the committed
# baseline instead: `make bench-scan NEW=BENCH_scan.json`.
OLD ?= BENCH_scan.json
NEW ?= BENCH_scan.new.json

.PHONY: verify bench-scan bench-compare quickstart

verify:
	$(PY) -m pytest -x -q

# regenerate the scan-schedule matrix into $(NEW)
bench-scan:
	BENCH_SCAN_JSON=$(NEW) $(PY) -m benchmarks.run fig2

# gate on the scan perf trajectory: exits nonzero on >10% regressions
bench-compare:
	$(PY) benchmarks/compare.py $(OLD) $(NEW)

quickstart:
	$(PY) examples/quickstart.py
